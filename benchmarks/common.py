"""Shared benchmark helpers: timing, CSV emit, dataset prep at bench scale."""
from __future__ import annotations

import time

import jax

from repro.data.svm_datasets import SVMDataset, make_dataset

# scale factors keep wall time sane on one CPU core while preserving each
# dataset's (d, sparsity, lambda) signature; row counts stay in the thousands.
BENCH_SCALE = {
    "adult": 0.15, "ccat": 0.006, "mnist": 0.08, "reuters": 0.6,
    "usps": 0.6, "webspam": 0.02,
}


def bench_dataset(name: str, seed: int = 0) -> SVMDataset:
    return make_dataset(name, scale=BENCH_SCALE[name], seed=seed)


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    out = jax.block_until_ready(out) if hasattr(out, "block_until_ready") else out
    return out, time.time() - t0


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
