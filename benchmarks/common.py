"""Shared benchmark helpers: timing, CSV emit, runner fingerprinting, dataset
prep at bench scale."""
from __future__ import annotations

import os
import platform
import time

import jax

from repro.data.svm_datasets import SVMDataset, make_dataset
from repro.kernels.hinge_subgrad.ops import default_interpret

# scale factors keep wall time sane on one CPU core while preserving each
# dataset's (d, sparsity, lambda) signature; row counts stay in the thousands.
BENCH_SCALE = {
    "adult": 0.15, "ccat": 0.006, "mnist": 0.08, "reuters": 0.6,
    "usps": 0.6, "webspam": 0.02,
}


def bench_dataset(name: str, seed: int = 0) -> SVMDataset:
    return make_dataset(name, scale=BENCH_SCALE[name], seed=seed)


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    out = jax.block_until_ready(out) if hasattr(out, "block_until_ready") else out
    return out, time.time() - t0


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def runner_fingerprint() -> dict:
    """Identity of the machine/backend a benchmark JSON was recorded on.

    check_regression.py compares wall-clock leaves only between runs whose
    fingerprints match (like-vs-like) — the first step toward hard perf
    gates: a committed baseline from one runner class never produces timing
    warnings on a different one. Structural leaves are always compared.
    """
    return {
        "os": platform.system().lower(),
        "machine": platform.machine(),
        "python": ".".join(platform.python_version_tuple()[:2]),
        "backend": jax.default_backend(),
        "pallas_interpret": int(default_interpret()),
        "cpu_count": os.cpu_count() or 0,
    }


def fingerprint_slug() -> str:
    """This runner's fingerprint as the filesystem-safe slug that names
    per-runner-class baselines (``benchmarks/baselines/<stem>.<slug>.json``).
    Delegates to check_regression's formatter so recording and matching can
    never drift apart."""
    from benchmarks.check_regression import fingerprint_slug as _slug
    return _slug(runner_fingerprint())
