"""Anytime-serving benchmark: served-model accuracy vs wall clock, measured
while training runs — the paper's anytime property exercised end to end.

The full live loop under measurement:

  * a :class:`repro.serve.TrainPublisher` trains GADGET on CCAT-shaped sparse
    partitions in a background thread and publishes a versioned checkpoint
    every ``segment_iters`` iterations (atomic rename + ``LATEST`` pointer);
  * the serving side (``SvmServer.watch``) streams its query set from an
    on-disk LibSVM file (``iter_libsvm_chunks`` → ``MicroBatcher.submit_csr``
    — the replica never materializes its queries), polls ``maybe_reload()``
    between drains, and hot-swaps whenever the published version moves;
  * every answered query is attributed to the model version that scored it,
    yielding an accuracy-at-version timeline. Versions the serving loop was
    too slow to catch live are replayed afterwards through the rollback path
    (``checkpoint.point_latest``) so every publish point gets a measurement.

Asserted on every run (the acceptance criteria, not just reported):

  * ≥ 3 publish points measured, versions monotone non-decreasing;
  * ≥ 2 hot swaps with the compile count (``distinct_shapes``) exactly flat
    from the first swap onward — swapping never recompiles;
  * every published version is a complete, loadable checkpoint and every
    submitted request is answered exactly once.

Per-point wall-clock/accuracy numbers depend on the train-vs-serve race and
are skip-listed in check_regression; the deterministic regression surface is
the structural flags plus ``final_accuracy`` (the final model is
bit-identical to an uninterrupted ``gadget_train`` run, so its accuracy on
the fixed query set is exact).

Usage:
    PYTHONPATH=src python -m benchmarks.anytime_bench [--quick] [--json out.json]
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import numpy as np

from benchmarks.common import emit, runner_fingerprint
from repro import checkpoint as ckpt
from repro import serve
from repro import telemetry as tm
from repro.core.gadget import GadgetConfig
from repro.data.libsvm import dump_libsvm, iter_libsvm_chunks
from repro.data.svm_datasets import make_dataset, partition

FIRST_CKPT_TIMEOUT_S = 600.0


class _Timeline:
    """Accuracy-at-version accumulator: every answered query is attributed
    to the model version that scored it."""

    def __init__(self, t0: float):
        self.t0 = t0
        self.by_version: dict[int, dict] = {}

    def tally(self, version: int, correct: int, n: int, live: bool) -> None:
        e = self.by_version.setdefault(
            version, {"correct": 0, "n": 0, "live": live, "t_last": 0.0})
        e["correct"] += correct
        e["n"] += n
        e["t_last"] = time.time() - self.t0

    def points(self) -> list[dict]:
        return [
            {"version": v, "wall_s": round(e["t_last"], 3),
             "served_accuracy": e["correct"] / e["n"],
             "n_queries_at_version": e["n"], "live": int(e["live"])}
            for v, e in sorted(self.by_version.items())
        ]


def _serve_pass(qpath: str, d: int, chunk_rows: int, mb, srv, tl: _Timeline,
                *, reload_between_drains: bool, live: bool,
                on_swap=None) -> tuple[int, int]:
    """One full streamed pass over the query file. Returns (correct, n) for
    the whole pass; per-version attribution goes through ``tl``."""
    pass_correct = pass_n = 0
    for csr, labels in iter_libsvm_chunks(qpath, d, chunk_rows=chunk_rows):
        if reload_between_drains:
            step = srv.maybe_reload()  # the hot-swap, between drains
            if step is not None and on_swap is not None:
                on_swap(step)
        rids = mb.submit_csr(csr)
        out = mb.drain(srv.scorer_for())
        version = int(srv.meta["iteration"])
        preds = np.array([float(np.asarray(out[r][1]).reshape(())) for r in rids])
        correct = int(np.sum(preds == np.asarray(labels)))
        tl.tally(version, correct, len(rids), live)
        pass_correct += correct
        pass_n += len(rids)
    return pass_correct, pass_n


def run(quick: bool = False, scale: float | None = None, n_nodes: int = 4,
        max_iters: int | None = None, segment_iters: int | None = None,
        json_path: str | None = None, verbose: bool = True) -> dict:
    if scale is None:
        scale = 0.002 if quick else 0.01
    if max_iters is None:
        max_iters = 20 if quick else 60
    if segment_iters is None:
        segment_iters = 4 if quick else 10
    n_queries = 32 if quick else 128
    chunk_rows = 8
    rows = 4 if quick else 8

    t0 = time.time()
    tm.reset()  # the JSON's telemetry section covers this run only
    ds = make_dataset("ccat", scale=scale, seed=0, sparse=True)
    Pe, yp, nc = partition(ds.X_train, ds.y_train, n_nodes, seed=0)
    cfg = GadgetConfig(lam=ds.lam, batch_size=4, gossip_rounds=4,
                       topology="exponential", max_iters=max_iters,
                       epsilon=0.0, use_kernels=True)
    import jax.numpy as jnp
    yp = jnp.asarray(yp)

    ell_q = ds.X_test.take_rows(np.arange(min(n_queries, ds.X_test.shape[0])))
    y_q = np.asarray(ds.y_test[:ell_q.shape[0]], np.float32)
    expected_versions = [segment_iters * j for j in
                         range(1, -(-max_iters // segment_iters) + 1)]
    expected_versions[-1] = min(expected_versions[-1], max_iters)

    with tempfile.TemporaryDirectory() as td:
        qpath = os.path.join(td, "queries.svm")
        dump_libsvm(qpath, ell_q.to_csr(), y_q)  # the on-disk streaming source
        root = os.path.join(td, "ckpts")

        # the whole train-to-serve loop reports into ONE flight recorder:
        # publisher spans + per-segment train readings, server counters +
        # kernel accounting, batcher latency histograms
        pub = serve.TrainPublisher(Pe, yp, cfg, root=root,
                                   segment_iters=segment_iters,
                                   n_counts=nc,
                                   telemetry=tm.TrainTelemetry(),
                                   registry=tm.default_registry()).start()
        # serving comes up as soon as the FIRST version lands
        deadline = time.time() + FIRST_CKPT_TIMEOUT_S
        while ckpt.read_latest(root) is None:
            if not pub.running:
                pub.join()  # surfaces the training error
            if time.time() > deadline:
                raise TimeoutError("no checkpoint published within timeout")
            time.sleep(0.02)
        srv = serve.SvmServer.watch(root, use_kernels=True,
                                    registry=tm.default_registry())

        # bucket ladder calibrated on the query planes themselves — the block
        # cap is then sound for every batch, so no cap-overflow shapes can
        # appear mid-run and the compile count is exactly len(warmed shapes)
        buckets = serve.calibrate_buckets(
            serve.bucket_ladder(ell_q.k_max, rows=rows,
                                min_k=max(8, ell_q.k_max // 4), d=ds.d),
            ell_q.cols, ell_q.vals, ds.d)
        mb = serve.MicroBatcher(buckets, registry=tm.default_registry())
        for b in buckets:  # warm every rung before measuring compile flatness
            srv.score_sparse(np.zeros((b.rows, b.k), np.int32),
                             np.zeros((b.rows, b.k), np.float32),
                             n_blocks_max=b.n_blocks_max)

        tl = _Timeline(t0)
        shapes_at_first_swap = [None]

        def on_swap(step):
            if shapes_at_first_swap[0] is None:
                shapes_at_first_swap[0] = srv.stats()["distinct_shapes"]
            if verbose:
                emit("anytime/swap", 0.0,
                     f"version={step};t={time.time() - t0:.2f}s")

        # ---- live phase: stream query passes while training runs
        live_passes = 0
        while pub.running:
            _serve_pass(qpath, ds.d, chunk_rows, mb, srv, tl,
                        reload_between_drains=True, live=True, on_swap=on_swap)
            live_passes += 1
        final_seg = pub.join()
        assert pub.published == expected_versions, (
            f"published {pub.published}, expected {expected_versions}")
        assert final_seg.iteration == expected_versions[-1]

        # ---- replay phase: publish points the live race skipped, served
        # through the rollback path so every version gets a measurement
        missed = [s for s in pub.published if s not in tl.by_version]
        for s in missed:
            ckpt.point_latest(root, s)
            step = srv.maybe_reload()
            assert step == s or int(srv.meta["iteration"]) == s
            on_swap(s)
            _serve_pass(qpath, ds.d, chunk_rows, mb, srv, tl,
                        reload_between_drains=False, live=False)

        # ---- final phase: one clean pass under the final version (its
        # accuracy is deterministic — the trajectory bit-matches gadget_train)
        ckpt.point_latest(root, pub.published[-1])
        if srv.maybe_reload() is not None:
            on_swap(pub.published[-1])
        assert int(srv.meta["iteration"]) == pub.published[-1]
        correct, n = _serve_pass(qpath, ds.d, chunk_rows, mb, srv, tl,
                                 reload_between_drains=False, live=False)
        final_accuracy = correct / n

        st = srv.stats()
        points = tl.points()
        versions = [p["version"] for p in points]
        assert len(points) >= 3, f"only {len(points)} publish points measured"
        assert versions == sorted(versions)  # monotone non-decreasing
        assert st["swaps"] >= 2, f"only {st['swaps']} hot swaps exercised"
        assert shapes_at_first_swap[0] is not None
        assert st["distinct_shapes"] == shapes_at_first_swap[0], (
            f"compile count moved across swaps: {shapes_at_first_swap[0]} -> "
            f"{st['distinct_shapes']}")
        assert st["reload_errors"] == 0
        assert mb.pending == 0
        # the registry's publish counter must agree with the publisher's list
        published_counted = int(tm.default_registry().value("publish.segments"))
        assert published_counted == len(pub.published), (
            f"registry counted {published_counted} published segments, "
            f"publisher recorded {len(pub.published)}")

        if verbose:
            for p in points:
                emit(f"anytime/point(v={p['version']})", 0.0,
                     f"acc={p['served_accuracy']:.3f};t={p['wall_s']:.2f}s"
                     f";live={p['live']};n={p['n_queries_at_version']}")
            emit("anytime/summary", 0.0,
                 f"points={len(points)};swaps={st['swaps']}"
                 f";shapes={st['distinct_shapes']};final_acc={final_accuracy:.3f}")

        out = {
            "quick": quick,
            "scale": scale,
            "runner": runner_fingerprint(),
            "model": {"d": ds.d, "k_max": ell_q.k_max, "n_nodes": n_nodes},
            "publish": {
                "segment_iters": segment_iters,
                "max_iters": max_iters,
                "n_published": len(pub.published),
                "first_version": pub.published[0],
                "final_version": pub.published[-1],
            },
            "serving": {
                "n_buckets": len(buckets),
                "bucket_ks": [b.k for b in buckets],
                "n_query_rows": int(ell_q.shape[0]),
                "distinct_shapes": st["distinct_shapes"],
                "n_swaps": st["swaps"],
                "n_live_passes": live_passes,
                "requests_total": mb.stats()["requests"],
            },
            "anytime": {
                "n_points": len(points),
                "min_points_ok": int(len(points) >= 3),
                "versions_monotone": int(versions == sorted(versions)),
                "compile_flat_across_swaps": int(
                    st["distinct_shapes"] == shapes_at_first_swap[0]),
                "final_accuracy": final_accuracy,
                "timeline": points,
            },
            "telemetry": tm.default_registry().values(),
            "total": {"seconds": time.time() - t0},
        }
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(out, fh, indent=2)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke scale (tiny row count, same d/sparsity)")
    ap.add_argument("--scale", type=float, default=None,
                    help="CCAT row-count scale")
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--iters", dest="max_iters", type=int, default=None)
    ap.add_argument("--segment-iters", type=int, default=None,
                    help="iterations per published checkpoint (the cadence)")
    ap.add_argument("--json", dest="json_path", default=None,
                    help="write results as JSON (CI uploads this as an artifact)")
    args = ap.parse_args()
    run(quick=args.quick, scale=args.scale, n_nodes=args.nodes,
        max_iters=args.max_iters, segment_iters=args.segment_iters,
        json_path=args.json_path)
