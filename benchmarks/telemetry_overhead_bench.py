"""Flight-recorder overhead: training with the telemetry ring on vs off.

The telemetry contract has two halves and this bench prices both:

  * **bit-identity** — ``telemetry=None`` must trace the exact
    pre-telemetry program, and attaching a ring must never change *what* is
    computed: the per-node weights and consensus of the on/off arms are
    asserted ``np.array_equal`` (not allclose).
  * **overhead <= 5%** — the ring adds one ``lax.cond``-gated record branch
    per iteration plus ONE extra device→host sync after termination. The
    record branch is priced by its full-data objective pass (~2 plain
    iterations' work), so the budget is a statement about cadence: at the
    bench's 20-records-per-run cadence (``every = max_iters // 20``, the
    ε-check ballpark) the amortized cost must stay <= OVERHEAD_BUDGET.
    Measured as interleaved repetitions of the same two compiled
    executables; the asserted ratio is min(on)/min(off) — best observed
    time per arm — because additive scheduler noise at this run length
    (~100ms) is the same order as the budget and min() filters it while
    the multiplicative overhead survives.

The JSON carries the assertions as structural leaves
(``overhead_within_budget`` / ``bit_identical``), the raw per-arm seconds
as wall-clock leaves, and the usual registry-backed ``telemetry`` section.
``overhead_ratio`` (and the noisier ``overhead_ratio_sum``, the ratio of
summed times) are listed in check_regression's SKIP_LEAVES — ratios of
small wall-clocks are too noisy to diff, the in-run assert is the gate.

Usage:
    PYTHONPATH=src python -m benchmarks.telemetry_overhead_bench [--quick] \
        [--json out.json]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, runner_fingerprint
from repro import telemetry as tm
from repro.core.gadget import GadgetConfig, gadget_train

OVERHEAD_BUDGET = 0.05  # telemetry-on may cost at most 5% wall-clock


def _make_parts(m: int, n_i: int, d: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=d)
    X = rng.normal(size=(m * n_i, d)).astype(np.float32)
    y = np.sign(X @ w_true).astype(np.float32)
    return (jnp.asarray(X.reshape(m, n_i, d)), jnp.asarray(y.reshape(m, n_i)))


def _timed(Xp, yp, cfg, ring):
    t0 = time.time()
    res = gadget_train(Xp, yp, cfg, telemetry=ring)
    jax.block_until_ready(res.W)
    return res, time.time() - t0


def run(quick: bool = False, n_nodes: int = 8, d: int | None = None,
        n_i: int | None = None, max_iters: int | None = None,
        reps: int | None = None, json_path: str | None = None,
        verbose: bool = True) -> dict:
    """Interleaved A/B of gadget_train with and without the trace ring."""
    if d is None:
        d = 1024 if quick else 2048
    if n_i is None:
        n_i = 32
    if max_iters is None:
        max_iters = 2000 if quick else 3000
    if reps is None:
        reps = 8

    t0 = time.time()
    tm.reset()
    Xp, yp = _make_parts(n_nodes, n_i, d)
    cfg = GadgetConfig(lam=1e-3, batch_size=8, gossip_rounds=2,
                       topology="exponential", max_iters=max_iters,
                       check_every=max(1, max_iters // 4), epsilon=0.0)
    # 20 records per run regardless of length — the ε-check-scale cadence
    # the budget is stated at (per-record cost is ~2 iterations' work, so
    # this amortizes to ~2% before scheduler noise)
    ring = tm.TrainTelemetry(every=max(1, max_iters // 20), slots=32)

    # warm-up: compile both executables before any timing
    res_off, _ = _timed(Xp, yp, cfg, None)
    res_on, _ = _timed(Xp, yp, cfg, ring)

    bit_identical = (np.array_equal(np.asarray(res_on.W), np.asarray(res_off.W))
                     and np.array_equal(np.asarray(res_on.w_consensus),
                                        np.asarray(res_off.w_consensus)))
    assert bit_identical, (
        "attaching the telemetry ring changed the training trajectory")
    tr = res_on.telemetry
    assert tr is not None and tr.count > 0, "ring recorded nothing"
    assert res_off.telemetry is None

    # interleaved reps: off/on alternate inside one loop so slow ticks
    # (GC, turbo, noisy neighbours) cannot land on one arm only
    off_times, on_times = [], []
    for _ in range(reps):
        _, s_off = _timed(Xp, yp, cfg, None)
        _, s_on = _timed(Xp, yp, cfg, ring)
        off_times.append(s_off)
        on_times.append(s_on)
    off_s, on_s = min(off_times), min(on_times)
    overhead = on_s / off_s
    overhead_sum = sum(on_times) / sum(off_times)
    assert overhead <= 1.0 + OVERHEAD_BUDGET, (
        f"telemetry overhead {overhead:.3f}x exceeds the "
        f"{1.0 + OVERHEAD_BUDGET:.2f}x budget (on={on_s:.4f}s off={off_s:.4f}s)")

    if verbose:
        emit(f"telemetry/overhead(m={n_nodes},d={d},T={max_iters})",
             on_s * 1e6,
             f"ratio={overhead:.3f}x;sum_ratio={overhead_sum:.3f}x"
             f";off={off_s*1e3:.1f}ms;on={on_s*1e3:.1f}ms"
             f";ring_count={tr.count};bit_identical={int(bit_identical)}")

    out = {
        "quick": quick,
        "runner": runner_fingerprint(),
        "config": {"n_nodes": n_nodes, "d": d, "n_i": n_i,
                   "max_iters": max_iters, "reps": reps,
                   "tele_every": ring.every},
        "points": {
            "off": {"seconds": off_s},
            "on": {"seconds": on_s, "ring_count": int(tr.count)},
        },
        "overhead_ratio": overhead,
        "overhead_ratio_sum": overhead_sum,
        "asserts": {
            "overhead_within_budget": int(overhead <= 1.0 + OVERHEAD_BUDGET),
            "bit_identical": int(bit_identical),
            "ring_recorded": int(tr.count > 0),
        },
        "telemetry": tm.default_registry().values(),
        "total": {"seconds": time.time() - t0},
    }
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(out, fh, indent=2)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke scale: d=1024, 2000 iterations, 8 reps")
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--dim", type=int, default=None)
    ap.add_argument("--rows-per-node", type=int, default=None)
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--json", dest="json_path", default=None,
                    help="write results as JSON (CI uploads this as an artifact)")
    args = ap.parse_args()
    run(quick=args.quick, n_nodes=args.nodes, d=args.dim,
        n_i=args.rows_per_node, max_iters=args.iters, reps=args.reps,
        json_path=args.json_path)
