"""Sparse-path benchmark: full-shape CCAT feasibility + sparse/dense parity.

Two claims, measured:

  * **Feasibility** — the paper's flagship large-scale scenario (CCAT:
    781,265 × 47,236 at 0.16% nonzeros) generates, partitions, and *trains*
    through ``gadget_train`` as padded-ELL planes inside container memory.
    Dense, the train split alone is ~147 GB; the planes are ~0.5 GB. The
    bytes a full-data pass touches drop by d·4 / (k·8) ≈ 310× at CCAT
    sparsity (reported as ``bytes_touched_ratio``; the acceptance floor is
    ≥10×).
  * **Parity** — on a reuters-shaped problem the sparse path's consensus
    weights agree with the dense path run on the *same* matrix (ELL→dense
    conversion, identical partitions and PRNG streams) to ≤ 1e-5.

Default is the full paper shape (scale=1.0, ~1 min generation + a short
training run); ``--quick`` shrinks rows for the CI smoke job while keeping
d/sparsity — and therefore every structural leaf except row count — exact.

Usage:
    PYTHONPATH=src python -m benchmarks.sparse_bench [--quick] [--json out.json]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.gadget import GadgetConfig, gadget_train
from repro.data.svm_datasets import PAPER_DATASETS, make_dataset, partition

DENSE_BYTES_PER_ELEM = 4      # f32
ELL_BYTES_PER_ENTRY = 4 + 4   # int32 col + f32 val


def bench_ccat_full(scale: float, n_nodes: int, n_iters: int, verbose: bool) -> dict:
    spec = PAPER_DATASETS["ccat"]
    t0 = time.time()
    ds = make_dataset("ccat", scale=scale, seed=0, sparse=True)
    t_gen = time.time() - t0
    ell = ds.X_train
    n, d = ell.shape
    k = ell.k_max

    dense_bytes = n * d * DENSE_BYTES_PER_ELEM
    bytes_ratio = (d * DENSE_BYTES_PER_ELEM) / (k * ELL_BYTES_PER_ENTRY)

    t0 = time.time()
    Pe, yp, nc = partition(ell, ds.y_train, n_nodes, seed=0)
    t_part = time.time() - t0

    cfg = GadgetConfig(lam=ds.lam, batch_size=8, gossip_rounds=4,
                       topology="exponential", max_iters=n_iters,
                       check_every=n_iters, epsilon=0.0)
    t0 = time.time()
    res = gadget_train(Pe, jnp.asarray(yp), cfg, n_counts=nc)
    jax.block_until_ready(res.W)
    t_train = time.time() - t0

    assert res.iters == n_iters, "sparse CCAT training did not run"
    assert np.isfinite(res.objective_trace).all()
    assert bytes_ratio >= 10, f"bytes-touched reduction {bytes_ratio:.1f}x < 10x"

    if verbose:
        emit(f"sparse/ccat(scale={scale})", t_train * 1e6 / n_iters,
             f"rows={n};d={d};k={k};ell_mb={ell.nbytes / 2**20:.0f};"
             f"dense_mb={dense_bytes / 2**20:.0f};bytes_ratio={bytes_ratio:.0f}x;"
             f"gen={t_gen:.1f}s;train={t_train:.1f}s")
    return {
        "rows": n, "d": d, "k_max": k,
        "paper_rows": spec.n_train,
        "ell_bytes": ell.nbytes,
        "dense_bytes_hypothetical": dense_bytes,
        "bytes_touched_ratio": round(bytes_ratio, 2),
        "final_objective_finite": 1,
        "gen": {"seconds": t_gen},
        "partition": {"seconds": t_part},
        "train": {"seconds": t_train},
    }


def bench_parity(verbose: bool) -> dict:
    """Sparse-vs-dense consensus agreement on a reuters-shaped problem."""
    ds = make_dataset("reuters", scale=0.05, seed=0, sparse=True)
    Xd = ds.X_train.to_dense()
    Pe, yp, nc = partition(ds.X_train, ds.y_train, 5, seed=3)
    Xp, _, _ = partition(Xd, ds.y_train, 5, seed=3)
    cfg = GadgetConfig(lam=ds.lam, batch_size=4, gossip_rounds=3,
                       topology="exponential", max_iters=200, check_every=50,
                       epsilon=0.0)
    t0 = time.time()
    rs = gadget_train(Pe, jnp.asarray(yp), cfg, n_counts=nc)
    t_sparse = time.time() - t0
    t0 = time.time()
    rd = gadget_train(jnp.asarray(Xp), jnp.asarray(yp), cfg, n_counts=nc)
    t_dense = time.time() - t0
    diff = float(jnp.max(jnp.abs(rs.w_consensus - rd.w_consensus)))
    assert diff <= 1e-5, f"sparse-vs-dense consensus diff {diff:.2e} > 1e-5"
    if verbose:
        emit("sparse/parity(reuters)", t_sparse * 1e6 / cfg.max_iters,
             f"consensus_diff={diff:.2e};sparse={t_sparse:.2f}s;dense={t_dense:.2f}s")
    return {
        "consensus_max_abs_diff": diff,
        "within_tolerance": 1,
        "sparse": {"seconds": t_sparse},
        "dense": {"seconds": t_dense},
    }


def run(quick: bool = False, scale: float | None = None, n_nodes: int = 8,
        n_iters: int | None = None, json_path: str | None = None,
        verbose: bool = True) -> dict:
    if scale is None:
        scale = 0.002 if quick else 1.0
    if n_iters is None:
        n_iters = 10 if quick else 40
    out = {
        "quick": quick,
        "scale": scale,
        "ccat": bench_ccat_full(scale, n_nodes, n_iters, verbose),
        "parity": bench_parity(verbose),
    }
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(out, fh, indent=2)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke scale (tiny row count, same d/sparsity)")
    ap.add_argument("--scale", type=float, default=None,
                    help="CCAT row-count scale (default 1.0 = full paper shape)")
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--json", dest="json_path", default=None,
                    help="write results as JSON (CI uploads this as an artifact)")
    args = ap.parse_args()
    run(quick=args.quick, scale=args.scale, n_nodes=args.nodes,
        n_iters=args.iters, json_path=args.json_path)
