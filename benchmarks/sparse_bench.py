"""Sparse-path benchmark: full-shape CCAT feasibility, sparse/dense parity,
and the sweep-vs-touched-block kernel schedule comparison.

Three claims, measured:

  * **Feasibility** — the paper's flagship large-scale scenario (CCAT:
    781,265 × 47,236 at 0.16% nonzeros) generates, partitions, and *trains*
    through ``gadget_train`` as padded-ELL planes inside container memory.
    Dense, the train split alone is ~147 GB; the planes are ~0.5 GB. The
    bytes a full-data pass touches drop by d·4 / (k·8) ≈ 310× at CCAT
    sparsity (reported as ``bytes_touched_ratio``; the acceptance floor is
    ≥10×).
  * **Parity** — on a reuters-shaped problem the sparse path's consensus
    weights agree with the dense path run on the *same* matrix (ELL→dense
    conversion, identical partitions and PRNG streams) to ≤ 1e-5.
  * **Schedules** — at the CCAT shape (paper batch_size=1, Zipf column
    profile), the touched-block (scalar-prefetch) kernel schedule visits
    ≤ 1/10 of the w blocks the data-oblivious sweep schedule walks —
    measured over the *actual* minibatches the training PRNG stream draws,
    with ``blocks_visited`` / ``flops_ratio`` reported per schedule and
    end-to-end prefetch-vs-dense consensus ≤ 1e-5 asserted on the same run.

Default is the full paper shape (scale=1.0, ~1 min generation + a short
training run); ``--quick`` shrinks rows for the CI smoke job while keeping
d/sparsity — and therefore every structural leaf except row count — exact.

Usage:
    PYTHONPATH=src python -m benchmarks.sparse_bench [--quick] [--json out.json]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, runner_fingerprint
from repro import telemetry as tm
# _batch_ids/_stream_keys are the training loop's own sampling: the schedule
# metrics below are measured over the exact minibatches training draws
from repro.core.gadget import (GadgetConfig, _batch_ids, _stream_keys,
                               gadget_train)
from repro.data.svm_datasets import PAPER_DATASETS, make_dataset, partition
from repro.kernels.hinge_subgrad import ops as hinge_ops
from repro.sparse.formats import bucket_by_block

DENSE_BYTES_PER_ELEM = 4      # f32
ELL_BYTES_PER_ENTRY = 4 + 4   # int32 col + f32 val


def _gen_ccat(scale: float) -> tuple:
    """One CCAT generation shared by the feasibility and schedule benches."""
    t0 = time.time()
    ds = make_dataset("ccat", scale=scale, seed=0, sparse=True)
    return ds, time.time() - t0


def bench_ccat_full(ds, t_gen: float, scale: float, n_nodes: int,
                    n_iters: int, verbose: bool) -> dict:
    spec = PAPER_DATASETS["ccat"]
    ell = ds.X_train
    n, d = ell.shape
    k = ell.k_max

    dense_bytes = n * d * DENSE_BYTES_PER_ELEM
    bytes_ratio = (d * DENSE_BYTES_PER_ELEM) / (k * ELL_BYTES_PER_ENTRY)

    t0 = time.time()
    Pe, yp, nc = partition(ell, ds.y_train, n_nodes, seed=0)
    t_part = time.time() - t0

    cfg = GadgetConfig(lam=ds.lam, batch_size=8, gossip_rounds=4,
                       topology="exponential", max_iters=n_iters,
                       check_every=n_iters, epsilon=0.0)
    t0 = time.time()
    res = gadget_train(Pe, jnp.asarray(yp), cfg, n_counts=nc)
    jax.block_until_ready(res.W)
    t_train = time.time() - t0

    assert res.iters == n_iters, "sparse CCAT training did not run"
    assert np.isfinite(res.objective_trace).all()
    assert bytes_ratio >= 10, f"bytes-touched reduction {bytes_ratio:.1f}x < 10x"

    if verbose:
        emit(f"sparse/ccat(scale={scale})", t_train * 1e6 / n_iters,
             f"rows={n};d={d};k={k};ell_mb={ell.nbytes / 2**20:.0f};"
             f"dense_mb={dense_bytes / 2**20:.0f};bytes_ratio={bytes_ratio:.0f}x;"
             f"gen={t_gen:.1f}s;train={t_train:.1f}s")
    return {
        "rows": n, "d": d, "k_max": k,
        "paper_rows": spec.n_train,
        "ell_bytes": ell.nbytes,
        "dense_bytes_hypothetical": dense_bytes,
        "bytes_touched_ratio": round(bytes_ratio, 2),
        "final_objective_finite": 1,
        "gen": {"seconds": t_gen},
        "partition": {"seconds": t_part},
        "train": {"seconds": t_train},
    }


# largest dense (rows × d × 4B) matrix the schedule bench will materialize
# for its end-to-end dense-consensus check; larger runs re-generate capped
E2E_DENSE_BYTES_CAP = 1 << 30


def bench_schedules(ds, scale: float, n_nodes: int, n_iters: int,
                    verbose: bool) -> dict:
    """Sweep vs touched-block schedule at the CCAT shape, paper batch_size=1.

    ``blocks_visited`` counts w blocks at the common 128-lane granularity so
    the two schedules compare apples-to-apples: the sweep walks every block of
    every node each kernel launch; the prefetch schedule DMAs only each node's
    live blocks (its sentinel slots alias one shared zero block). FLOPs per
    program are B·k·blk_d one-hot MACs, so ``flops_ratio`` is the same
    measurement in compute units. Asserted: prefetch ≤ 1/10 of sweep, and the
    prefetch run's consensus matches the dense path to ≤ 1e-5 end to end.

    The block/FLOP metrics run at the given scale; the end-to-end dense
    comparison needs ``to_dense()`` (full-shape CCAT would be ~147 GB — the
    thing the sparse path exists to avoid), so above ``E2E_DENSE_BYTES_CAP``
    it re-runs at a capped row count and reports that scale alongside.
    """
    B = 1  # paper Algorithm 2: one local example per sub-gradient draw
    Pe, yp, nc = partition(ds.X_train, ds.y_train, n_nodes, seed=0)
    m, n_i, d = Pe.shape
    k = Pe.cols.shape[-1]
    blk_d = hinge_ops.ELL_PREFETCH_BLK_D
    n_d_blocks = -(-d // blk_d)
    bound = Pe.block_bound(B, blk_d)

    cfg = GadgetConfig(lam=ds.lam, batch_size=B, gossip_rounds=4,
                       topology="exponential", max_iters=n_iters,
                       check_every=n_iters, epsilon=0.0)

    # schedule metrics over the actual sampled minibatches (same PRNG stream)
    data_key, _ = _stream_keys(cfg.seed)
    counts = jnp.asarray(np.asarray(nc, np.float32))
    live_per_iter = []
    for t in range(1, n_iters + 1):
        ids = np.asarray(_batch_ids(data_key, jnp.int32(t), counts, B))
        rows = np.take_along_axis(Pe.cols, ids[:, :, None], axis=1)
        vrows = np.take_along_axis(Pe.vals, ids[:, :, None], axis=1)
        live_per_iter.append(int(bucket_by_block(
            rows, vrows, blk_d, d=d, n_blocks_max=bound).blocks_visited().sum()))
    pref_blocks = float(np.mean(live_per_iter))          # per kernel launch
    sweep_blocks = m * n_d_blocks                        # 128-lane granularity
    blocks_ratio = pref_blocks / sweep_blocks
    Bk = B * k
    flops_sweep = sweep_blocks * Bk * blk_d              # one-hot MACs/launch
    flops_pref = pref_blocks * Bk * blk_d
    flops_ratio = flops_pref / flops_sweep

    # end-to-end: the prefetch schedule through the real device loop, against
    # the dense path on the same matrix — the standing ≤1e-5 acceptance bar.
    # to_dense() is capped: full-shape CCAT dense is the ~147 GB matrix the
    # sparse path exists to avoid, so big runs assert parity at a sub-scale.
    n_rows, d_full = ds.X_train.shape
    if n_rows * d_full * DENSE_BYTES_PER_ELEM > E2E_DENSE_BYTES_CAP:
        e2e_scale = E2E_DENSE_BYTES_CAP / (
            PAPER_DATASETS["ccat"].n_train * d_full * DENSE_BYTES_PER_ELEM)
        ds_e2e, _ = _gen_ccat(e2e_scale)
        Pe_e, yp_e, nc_e = partition(ds_e2e.X_train, ds_e2e.y_train,
                                     n_nodes, seed=0)
    else:
        e2e_scale, ds_e2e, Pe_e, yp_e, nc_e = scale, ds, Pe, yp, nc
    Xd, _, _ = partition(ds_e2e.X_train.to_dense(), ds_e2e.y_train,
                         n_nodes, seed=0)
    t0 = time.time()
    rp = gadget_train(Pe_e, jnp.asarray(yp_e),
                      cfg._replace(use_kernels=True, sparse_schedule="prefetch"),
                      n_counts=nc_e)
    t_pref = time.time() - t0
    t0 = time.time()
    rs = gadget_train(Pe_e, jnp.asarray(yp_e),
                      cfg._replace(use_kernels=True, sparse_schedule="sweep"),
                      n_counts=nc_e)
    t_sweep = time.time() - t0
    rd = gadget_train(jnp.asarray(Xd), jnp.asarray(yp_e), cfg, n_counts=nc_e)
    diff_dense = float(jnp.max(jnp.abs(rp.w_consensus - rd.w_consensus)))
    diff_sweep = float(jnp.max(jnp.abs(rp.w_consensus - rs.w_consensus)))

    assert blocks_ratio <= 0.1, (
        f"prefetch blocks_visited {pref_blocks:.0f} > 1/10 of sweep {sweep_blocks}")
    assert diff_dense <= 1e-5, (
        f"prefetch-vs-dense consensus diff {diff_dense:.2e} > 1e-5")
    assert diff_sweep <= 1e-5, (
        f"prefetch-vs-sweep consensus diff {diff_sweep:.2e} > 1e-5")

    if verbose:
        emit(f"sparse/schedules(ccat,B={B},blk_d={blk_d})",
             t_pref * 1e6 / n_iters,
             f"blocks={pref_blocks:.0f}v{sweep_blocks}({blocks_ratio:.3f})"
             f";flops_ratio={flops_ratio:.3f};grid_bound={bound}"
             f";dense_diff={diff_dense:.1e};sweep_diff={diff_sweep:.1e}")
    return {
        "batch_size": B, "blk_d": blk_d, "n_d_blocks": n_d_blocks,
        "grid_bound_n_blocks_max": bound,
        "e2e_scale": round(e2e_scale, 6),
        "sweep": {"blocks_visited": sweep_blocks,
                  "flops_per_launch": flops_sweep,
                  "train": {"seconds": t_sweep}},
        "prefetch": {"blocks_visited": round(pref_blocks, 2),
                     "flops_per_launch": round(flops_pref),
                     "train": {"seconds": t_pref}},
        "blocks_visited_ratio": round(blocks_ratio, 4),
        "flops_ratio": round(flops_ratio, 4),
        "consensus_max_abs_diff": diff_dense,
        "prefetch_vs_sweep_max_abs_diff": diff_sweep,
        "within_tolerance": 1,
    }


def bench_parity(verbose: bool) -> dict:
    """Sparse-vs-dense consensus agreement on a reuters-shaped problem."""
    ds = make_dataset("reuters", scale=0.05, seed=0, sparse=True)
    Xd = ds.X_train.to_dense()
    Pe, yp, nc = partition(ds.X_train, ds.y_train, 5, seed=3)
    Xp, _, _ = partition(Xd, ds.y_train, 5, seed=3)
    cfg = GadgetConfig(lam=ds.lam, batch_size=4, gossip_rounds=3,
                       topology="exponential", max_iters=200, check_every=50,
                       epsilon=0.0)
    t0 = time.time()
    rs = gadget_train(Pe, jnp.asarray(yp), cfg, n_counts=nc)
    t_sparse = time.time() - t0
    t0 = time.time()
    rd = gadget_train(jnp.asarray(Xp), jnp.asarray(yp), cfg, n_counts=nc)
    t_dense = time.time() - t0
    diff = float(jnp.max(jnp.abs(rs.w_consensus - rd.w_consensus)))
    assert diff <= 1e-5, f"sparse-vs-dense consensus diff {diff:.2e} > 1e-5"
    if verbose:
        emit("sparse/parity(reuters)", t_sparse * 1e6 / cfg.max_iters,
             f"consensus_diff={diff:.2e};sparse={t_sparse:.2f}s;dense={t_dense:.2f}s")
    return {
        "consensus_max_abs_diff": diff,
        "within_tolerance": 1,
        "sparse": {"seconds": t_sparse},
        "dense": {"seconds": t_dense},
    }


def run(quick: bool = False, scale: float | None = None, n_nodes: int = 8,
        n_iters: int | None = None, json_path: str | None = None,
        verbose: bool = True) -> dict:
    if scale is None:
        scale = 0.002 if quick else 1.0
    if n_iters is None:
        n_iters = 10 if quick else 40
    tm.reset()  # the JSON's telemetry section covers this run only
    ds, t_gen = _gen_ccat(scale)  # one generation, shared by both CCAT benches
    out = {
        "quick": quick,
        "scale": scale,
        "runner": runner_fingerprint(),
        "ccat": bench_ccat_full(ds, t_gen, scale, n_nodes, n_iters, verbose),
        "parity": bench_parity(verbose),
        "schedules": bench_schedules(ds, scale, n_nodes,
                                     max(4, n_iters // 2), verbose),
        "telemetry": tm.default_registry().values(),
    }
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(out, fh, indent=2)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke scale (tiny row count, same d/sparsity)")
    ap.add_argument("--scale", type=float, default=None,
                    help="CCAT row-count scale (default 1.0 = full paper shape)")
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--json", dest="json_path", default=None,
                    help="write results as JSON (CI uploads this as an artifact)")
    args = ap.parse_args()
    run(quick=args.quick, scale=args.scale, n_nodes=args.nodes,
        n_iters=args.iters, json_path=args.json_path)
