"""Serving benchmark: anytime snapshot → checkpoint → bucketed sparse scoring.

Exercises the whole `repro.serve` pipeline at the paper's CCAT signature
(d = 47,236, 0.16% nonzeros, Zipf column profile) and *asserts* the
subsystem's acceptance numbers on every run:

  * **Parity** — the same query batch scored three ways must agree: the dense
    fused kernel vs the query-side touched-block sparse kernel on identical
    f32 weights (≤ 1e-5), and the int8-export serving path vs the jnp oracle
    on its dequantized weights (≤ 1e-5). Quantization *drift* vs the f32
    model is reported (it is bounded by the int8 scale, orders of magnitude
    above 1e-5 — the honest number, not an assertion).
  * **Compile bound** — a fresh engine draining ragged traffic through the
    bucketed micro-batcher compiles at most one executable per bucket
    (measured ``distinct_shapes`` ≤ len(buckets)).
  * **Touched blocks** — sparse scoring visits ≤ 1/5 of the w d-blocks the
    dense sweep equivalent walks at the quick shape (the serving twin of
    sparse_bench's training-side ratio; rides the same Zipf locality).

Latency (p50/p99 per request through the batcher, queue + compute) and
throughput are measured over the drained traffic and recorded in
``BENCH_serve.json``. On this container Pallas interprets on CPU, so absolute
numbers are not TPU numbers — the structural leaves (parity, compile count,
block ratio, request accounting) are the regression surface.

Usage:
    PYTHONPATH=src python -m benchmarks.serve_bench [--quick] [--json out.json]
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, runner_fingerprint
from repro import serve
from repro import telemetry as tm
from repro.core.gadget import GadgetConfig, gadget_train
from repro.data.svm_datasets import make_dataset, partition
from repro.serve import snapshot as snap_mod

PARITY_TOL = 1e-5
BLOCKS_RATIO_BOUND = 0.2  # sparse predict must skip ≥ 4/5 of w at quick shape


def _train_snapshot(ds, n_nodes: int, n_iters: int):
    Pe, yp, nc = partition(ds.X_train, ds.y_train, n_nodes, seed=0)
    cfg = GadgetConfig(lam=ds.lam, batch_size=4, gossip_rounds=4,
                       topology="exponential", max_iters=n_iters,
                       check_every=n_iters, epsilon=0.0)
    t0 = time.time()
    res = gadget_train(Pe, jnp.asarray(yp), cfg,
                       n_counts=nc, snapshot_every=max(1, n_iters // 4))
    t_train = time.time() - t0
    return res, Pe, t_train


def bench_parity(srv, srv_q, snap, ell_test, n_rows: int, verbose: bool) -> dict:
    """Dense vs sparse-prefetch vs quantized on one CCAT-shaped batch."""
    cols, vals = ell_test.cols[:n_rows], ell_test.vals[:n_rows]
    Xq = ell_test.take_rows(np.arange(n_rows)).to_dense()  # (n_rows, d) ~6 MB

    s_dense, l_dense = srv.score(Xq)
    s_sparse, l_sparse = srv.score_sparse(cols, vals)
    diff_ds = float(np.max(np.abs(s_dense - s_sparse)))

    w_deq = snap_mod.dequantize_int8(*snap_mod.quantize_int8(snap.w))
    s_q, _ = srv_q.score(Xq)
    diff_q_oracle = float(np.max(np.abs(s_q - Xq @ w_deq)))
    drift = float(np.max(np.abs(s_q - s_dense)))
    label_agreement = float(np.mean(l_dense == np.where(s_q >= 0, 1.0, -1.0)))

    assert diff_ds <= PARITY_TOL, (
        f"dense vs sparse-prefetch scores diff {diff_ds:.2e} > {PARITY_TOL}")
    assert diff_q_oracle <= PARITY_TOL, (
        f"int8 serving path vs dequantized oracle diff {diff_q_oracle:.2e} > {PARITY_TOL}")
    assert np.array_equal(l_dense, l_sparse)

    if verbose:
        emit(f"serve/parity(B={n_rows})", 0.0,
             f"dense_vs_sparse={diff_ds:.1e};quant_vs_oracle={diff_q_oracle:.1e}"
             f";quant_drift={drift:.1e};label_agree={label_agreement:.3f}")
    return {
        "batch_rows": n_rows,
        "dense_vs_sparse_max_abs_diff": diff_ds,
        "quantized_vs_oracle_max_abs_diff": diff_q_oracle,
        "quantized_drift_vs_f32": drift,
        "quantized_label_agreement": label_agreement,
        "within_tolerance": 1,
    }


def bench_batcher(snap, Pe, ell_test, rows: int, n_queries: int,
                  verbose: bool) -> dict:
    """Ragged traffic through the bucketed batcher on a fresh engine:
    latency/throughput accounting + the compile-count and block-ratio
    assertions (fresh engine so ``distinct_shapes`` counts only this path)."""
    # shared flight-recorder registry: server counters, kernel launch/bytes
    # accounting, and batcher latency histograms land in one dump
    srv = serve.SvmServer.from_snapshot(snap, use_kernels=True,
                                        registry=tm.default_registry())
    k_max = ell_test.k_max
    buckets = serve.calibrate_buckets(
        serve.bucket_ladder(k_max, rows=rows, min_k=max(8, k_max // 4), d=snap.d),
        Pe.cols.reshape(-1, Pe.cols.shape[-1])[:2000],
        Pe.vals.reshape(-1, Pe.vals.shape[-1])[:2000], snap.d)
    mb = serve.MicroBatcher(buckets, registry=tm.default_registry())

    # warm each bucket's executable before the timed traffic so latency
    # percentiles measure steady-state serving, not first-batch compiles
    # (the compile-count assertion below still covers exactly these shapes)
    for b in buckets:
        srv.score_sparse(np.zeros((b.rows, b.k), np.int32),
                         np.zeros((b.rows, b.k), np.float32),
                         n_blocks_max=b.n_blocks_max)
    warm = srv.stats()
    blocks_warmup = warm["blocks_visited"]
    dense_warmup = warm["dense_block_equivalent"]

    row_nnz = ell_test.row_nnz()
    rids, scored = [], {}
    for i in range(n_queries):
        # ragged on purpose: truncate some queries so several rungs get traffic
        nnz = int(row_nnz[i]) if i % 3 else max(1, int(row_nnz[i]) // 4)
        live = ell_test.vals[i] != 0
        c, v = ell_test.cols[i][live][:nnz], ell_test.vals[i][live][:nnz]
        rids.append(mb.submit(c, v))
        if (i + 1) % max(1, rows * 2) == 0 or i == n_queries - 1:
            scored.update(mb.drain(srv.scorer_for()))
    assert not mb.pending
    assert set(scored) == set(rids)  # every submitted request came back

    st_mb = mb.stats()
    st_srv = srv.stats()
    # overload accounting on the closed-loop path: an unconfigured batcher
    # (no max_pending, no deadlines) must behave exactly like the historical
    # unbounded one — every submit delivered, nothing shed/expired/rejected
    assert st_mb["submitted"] == st_mb["delivered"] == n_queries, (
        f"closed-loop accounting leak: submitted {st_mb['submitted']} "
        f"delivered {st_mb['delivered']} of {n_queries}")
    assert st_mb["shed"] == st_mb["deadline_missed"] == st_mb["rejected"] == 0
    assert st_srv["distinct_shapes"] <= len(buckets), (
        f"batcher compiled {st_srv['distinct_shapes']} shapes > "
        f"{len(buckets)} buckets")
    # block accounting over the measured traffic only (warm-up batches are
    # all-pad: zero live blocks but a full dense-sweep denominator each)
    blocks_visited = st_srv["blocks_visited"] - blocks_warmup
    dense_equiv = st_srv["dense_block_equivalent"] - dense_warmup
    ratio = blocks_visited / dense_equiv
    assert ratio <= BLOCKS_RATIO_BOUND, (
        f"sparse predict visited {ratio:.3f} of w blocks > {BLOCKS_RATIO_BOUND}")

    if verbose:
        emit(f"serve/batcher(rows={rows},buckets={len(buckets)})",
             st_mb["latency_p50_ms"] * 1e3,
             f"p50={st_mb['latency_p50_ms']:.1f}ms;p99={st_mb['latency_p99_ms']:.1f}ms"
             f";qps={st_mb['queries_per_sec']:.1f}"
             f";shapes={st_srv['distinct_shapes']}/{len(buckets)}"
             f";blocks_ratio={ratio:.3f}")
    return {
        "rows_per_batch": rows,
        "n_buckets": len(buckets),
        "bucket_ks": [b.k for b in buckets],
        "bucket_block_caps": [b.n_blocks_max for b in buckets],
        "distinct_shapes": st_srv["distinct_shapes"],
        "requests": st_mb["requests"],
        "batches": st_mb["batches"],
        "pad_fraction": round(st_mb["pad_fraction"], 4),
        "latency": {"us_per_call": {
            "p50": st_mb["latency_p50_ms"] * 1e3,
            "p90": st_mb["latency_p90_ms"] * 1e3,
            "p99": st_mb["latency_p99_ms"] * 1e3,
        }},
        # deterministic per-rung routing counts (latencies stay wall-clock)
        "bucket_requests": {k: v["count"]
                            for k, v in st_mb["per_bucket_latency_ms"].items()},
        "throughput": {"queries_per_sec": st_mb["queries_per_sec"]},
        "blocks": {
            "visited": blocks_visited,
            "dense_equivalent": dense_equiv,
            "ratio": round(ratio, 4),
            "asserted_bound": BLOCKS_RATIO_BOUND,
        },
    }


def run(quick: bool = False, scale: float | None = None, n_nodes: int = 4,
        n_iters: int | None = None, json_path: str | None = None,
        verbose: bool = True) -> dict:
    if scale is None:
        scale = 0.002 if quick else 0.01
    if n_iters is None:
        n_iters = 8 if quick else 40
    rows = 4 if quick else 8
    n_queries = 48 if quick else 256

    t0 = time.time()
    tm.reset()  # the JSON's telemetry section covers this run only
    ds = make_dataset("ccat", scale=scale, seed=0, sparse=True)
    t_gen = time.time() - t0
    res, Pe, t_train = _train_snapshot(ds, n_nodes, n_iters)
    snaps = serve.snapshots_from(res)
    snap = snaps[-1]

    with tempfile.TemporaryDirectory() as td:
        serve.to_checkpoint(snap, td + "/f32", lam=ds.lam)
        serve.to_checkpoint(snap, td + "/int8", quantize="int8", lam=ds.lam)
        srv = serve.SvmServer.load(td + "/f32", use_kernels=True)
        srv_q = serve.SvmServer.load(td + "/int8", use_kernels=True)
        # restore fidelity: the f32 round-trip serves the exact snapshot
        assert np.array_equal(srv.W, np.asarray(snap.w, np.float32))

        out = {
            "quick": quick,
            "scale": scale,
            "runner": runner_fingerprint(),
            "model": {
                "d": snap.d, "k_max": ds.X_train.k_max,
                "iteration": snap.iteration,
                "n_snapshots": len(snaps),
                "objective_finite": int(np.isfinite(snap.objective)),
            },
            "gen": {"seconds": t_gen},
            "train": {"seconds": t_train},
            "parity": bench_parity(srv, srv_q, snap, ds.X_test,
                                   min(32, ds.X_test.shape[0]), verbose),
            "batcher": bench_batcher(snap, Pe, ds.X_test, rows, n_queries,
                                     verbose),
            "telemetry": tm.default_registry().values(),
        }
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(out, fh, indent=2)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke scale (tiny row count, same d/sparsity)")
    ap.add_argument("--scale", type=float, default=None,
                    help="CCAT row-count scale")
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--json", dest="json_path", default=None,
                    help="write results as JSON (CI uploads this as an artifact)")
    args = ap.parse_args()
    run(quick=args.quick, scale=args.scale, n_nodes=args.nodes,
        n_iters=args.iters, json_path=args.json_path)
