"""Benchmark harness entry point — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines (benchmarks/common.emit).

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run table3     # one suite
"""
from __future__ import annotations

import sys

from benchmarks import (fig_convergence, gossip_comm, kernel_bench, roofline,
                        table3_gadget_vs_pegasos, table4_online_baselines,
                        table5_speedup, topology_study, gossip_rounds_study)

SUITES = {
    "table3": lambda: table3_gadget_vs_pegasos.run(),
    "table4": lambda: table4_online_baselines.run(),
    "table5": lambda: table5_speedup.run(),
    "fig_convergence": lambda: fig_convergence.run(),
    "kernels": lambda: kernel_bench.run(),
    "gossip_comm": lambda: gossip_comm.run(),
    "roofline": lambda: roofline.run(),
    "topology": lambda: topology_study.run(),
    "gossip_rounds": lambda: gossip_rounds_study.run(),
}


def main() -> None:
    names = sys.argv[1:] or list(SUITES)
    print("name,us_per_call,derived")
    for n in names:
        SUITES[n]()


if __name__ == "__main__":
    main()
