"""Roofline table generator: reads the dry-run JSONL and renders the
EXPERIMENTS.md §Roofline markdown table (one row per arch x shape)."""
from __future__ import annotations

import json
import os

from benchmarks.common import emit

HDR = ("| arch | shape | mesh | compute_s | memory_s | collective_s | "
       "bottleneck | model_GFLOPs | useful_ratio | fits_16G |")
SEP = "|" + "---|" * 10


def load(path="results/dryrun_baseline.jsonl"):
    if not os.path.exists(path):
        return []
    recs = [json.loads(l) for l in open(path)]
    # keep the latest record per (arch, shape, mesh, consensus)
    seen = {}
    for r in recs:
        seen[(r["arch"], r["shape"], r["mesh"], r["consensus"])] = r
    return list(seen.values())


def table(recs, mesh="16x16", consensus="allreduce") -> str:
    lines = [HDR, SEP]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh or r["consensus"] != consensus:
            continue
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {mesh} | — | — | — | "
                         f"skipped: {r['reason']} | — | — | — |")
            continue
        if r["status"] == "failed":
            lines.append(f"| {r['arch']} | {r['shape']} | {mesh} | — | — | — | "
                         f"FAILED: {r['reason'][:60]} | — | — | — |")
            continue
        fits = "yes" if r["per_device_bytes"] <= 16 * 2**30 else \
            f"no ({r['per_device_bytes']/2**30:.0f}G)"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {mesh} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | {r['bottleneck']} | "
            f"{r['model_flops_global']/1e9:.0f} | {r['useful_flop_ratio']:.2f} | {fits} |")
    return "\n".join(lines)


def run(path="results/dryrun_baseline.jsonl", verbose=True):
    recs = load(path)
    ok = [r for r in recs if r["status"] == "ok"]
    if verbose and recs:
        by_bn = {}
        for r in ok:
            by_bn.setdefault(r["bottleneck"], []).append(r)
        for bn, rs in by_bn.items():
            emit(f"roofline/{bn}-bound", 0.0, f"count={len(rs)}")
        worst = sorted(ok, key=lambda r: max(r["memory_s"], r["collective_s"])
                       / max(r["compute_s"], 1e-9), reverse=True)[:3]
        for r in worst:
            emit(f"roofline/worst_{r['arch']}_{r['shape']}", 0.0,
                 f"compute={r['compute_s']:.2f}s mem={r['memory_s']:.2f}s "
                 f"coll={r['collective_s']:.2f}s")
    return recs


if __name__ == "__main__":
    print(table(load()))
