"""Fused device-resident gossip loop vs the PR 1 path vs the seed host loop.

Measures, on the 32-node simulator at d=4096 (paper-scale weight dimension):

  * **kernel dispatches per iteration** — the PR 1 path runs the two Pallas
    half-step kernels for each of the m nodes plus R scanned Push-Sum matmuls
    (2m + R dispatches); the fused path runs ONE ``fleet_half_step`` launch
    for the whole fleet plus ONE collapsed mix-and-renormalize matmul (2).
    Counts are structural (from m and R), reported alongside the ratio.
  * **wall-clock** — end-to-end training time of the fused path
    (``cfg.fused=True``, the default), the PR 1 path (``cfg.fused=False``)
    and the seed-style host-chunk reference, same PRNG streams, same math.
    Consensus agreement across all three is reported (the parity tests assert
    ≤1e-5 against the reference oracle).
  * **transfer counter** — host→device mixing-matrix uploads and blocking
    device→host ε-check syncs per path, via
    ``repro.core.gadget.transfer_stats``. The device paths must do exactly one
    upload (the stacked cycle — the collapsed *product* cycle when fused) and
    one sync; the host-loop reference pays one upload per iteration and two
    blocking syncs per chunk.
  * **transfer-guard proof** — the jitted fused loop is re-run under
    ``jax.transfer_guard("disallow")`` with all inputs pre-placed via
    ``jax.device_put``: any implicit host transfer inside the loop would
    raise, so a clean pass certifies the loop is device-resident.

Emits CSV rows via benchmarks.common.emit and optionally a JSON file
(CI diffs it against the committed BENCH_gossip_device.json baseline); the
JSON includes a registry-backed ``telemetry`` section (flight-recorder
iteration/gossip-byte counters accumulated across the measured runs).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, runner_fingerprint
from repro import telemetry as tm
from repro.core import gadget
from repro.core.gadget import GadgetConfig, gadget_train, gadget_train_reference


def _make_parts(m: int, n_i: int, d: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=d)
    X = rng.normal(size=(m * n_i, d)).astype(np.float32)
    y = np.sign(X @ w_true).astype(np.float32)
    return (jnp.asarray(X.reshape(m, n_i, d)), jnp.asarray(y.reshape(m, n_i)))


def _timed_train(fn, Xp, yp, cfg):
    gadget.reset_transfer_stats()
    t0 = time.time()
    res = fn(Xp, yp, cfg)
    jax.block_until_ready(res.W)
    return res, time.time() - t0, dict(gadget.transfer_stats)


def _transfer_guard_proof(Xp, yp, cfg) -> bool:
    """Run the compiled device loop under a disallow-transfer guard.

    Uses gadget._prepare_device_train — the exact (train fn, args) pair
    gadget_train executes — so the proof certifies the real path. Fresh args
    per call: the weight buffers are donated on accelerator backends."""
    train, args = gadget._prepare_device_train(cfg, Xp, yp)
    jax.block_until_ready(train(*args))  # warm-up/compile
    train, args = gadget._prepare_device_train(cfg, Xp, yp)
    args = jax.device_put(args)  # explicit placement: inputs uploaded before the guard
    with jax.transfer_guard("disallow"):
        out = train(*args)
        jax.block_until_ready(out)
    return True


def run(n_nodes=32, d=4096, n_i=64, n_iters=200, check_every=50,
        topology="exponential", verbose=True, json_path=None):
    tm.reset()  # the JSON's telemetry section covers this run only
    cfg = GadgetConfig(lam=1e-3, batch_size=8, gossip_rounds=4, topology=topology,
                       max_iters=n_iters, check_every=check_every, epsilon=0.0)
    cfg_pr1 = cfg._replace(fused=False)
    Xp, yp = _make_parts(n_nodes, n_i, d)

    # warm-up every path with the measured config so wall-clock excludes
    # compilation (the device path's jit cache is keyed on the full config)
    _timed_train(gadget_train, Xp, yp, cfg)
    _timed_train(gadget_train, Xp, yp, cfg_pr1)
    _timed_train(gadget_train_reference, Xp, yp, cfg)

    fused, fused_s, fused_stats = _timed_train(gadget_train, Xp, yp, cfg)
    pr1, pr1_s, pr1_stats = _timed_train(gadget_train, Xp, yp, cfg_pr1)
    ref, ref_s, ref_stats = _timed_train(gadget_train_reference, Xp, yp, cfg)

    consensus_diff = float(jnp.max(jnp.abs(fused.w_consensus - ref.w_consensus)))
    fused_vs_pr1 = float(jnp.max(jnp.abs(fused.w_consensus - pr1.w_consensus)))
    dev_transfers = fused_stats["matrix_uploads"] + fused_stats["host_syncs"]
    ref_transfers = ref_stats["matrix_uploads"] + ref_stats["host_syncs"]
    guard_ok = _transfer_guard_proof(Xp, yp, cfg)

    # structural dispatch counts: PR 1 ran margins + grad_update per node and
    # R scanned mixing matmuls; fused runs one fleet launch + one mix matmul.
    # The random protocol has no precomputable product cycle, so its fused
    # path still folds the R in-step draws with R (m,m)-sized matmuls — tiny
    # next to the (m,m)@(m,d) mix, but counted honestly here.
    R = cfg.gossip_rounds
    fused_per_iter = 2 if topology != "random" else 2 + R
    launches = {
        "pr1_per_iter": 2 * n_nodes + R,
        "fused_per_iter": fused_per_iter,
        "ratio": (2 * n_nodes + R) / fused_per_iter,
    }

    result = {
        "runner": runner_fingerprint(),
        "config": {"n_nodes": n_nodes, "d": d, "n_i": n_i, "n_iters": n_iters,
                   "topology": topology},
        "device": {"seconds": fused_s, **fused_stats},  # fused path (default)
        "pr1": {"seconds": pr1_s, **pr1_stats},
        "reference": {"seconds": ref_s, **ref_stats},
        "launches_per_iter": launches,
        "transfer_ratio": ref_transfers / max(dev_transfers, 1),
        "speedup": ref_s / fused_s,
        "fused_speedup_vs_pr1": pr1_s / fused_s,
        "consensus_max_abs_diff": consensus_diff,
        "fused_vs_pr1_max_abs_diff": fused_vs_pr1,
        "transfer_guard_clean": guard_ok,
        "telemetry": tm.default_registry().values(),
    }
    if verbose:
        emit(f"gossip_device/{topology}(m={n_nodes},d={d})", fused_s * 1e6,
             f"speedup={result['speedup']:.2f}x;fused_vs_pr1={result['fused_speedup_vs_pr1']:.2f}x"
             f";launches={launches['fused_per_iter']}v{launches['pr1_per_iter']}"
             f"({launches['ratio']:.0f}x);transfers={dev_transfers}v{ref_transfers}"
             f";guard={'clean' if guard_ok else 'FAIL'}"
             f";consensus_diff={consensus_diff:.1e}")
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(result, fh, indent=2)
    return result


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--nodes", type=int, default=32)
    ap.add_argument("--dim", type=int, default=4096)
    ap.add_argument("--rows-per-node", type=int, default=64)
    ap.add_argument("--iters", type=int, default=200)
    ap.add_argument("--check-every", type=int, default=50)
    ap.add_argument("--topology", default="exponential")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke scale: 8 nodes, d=256, 60 iterations")
    ap.add_argument("--json", dest="json_path", default=None)
    args = ap.parse_args()
    if args.quick:
        return run(n_nodes=8, d=256, n_i=32, n_iters=60, check_every=20,
                   topology=args.topology, json_path=args.json_path)
    return run(n_nodes=args.nodes, d=args.dim, n_i=args.rows_per_node,
               n_iters=args.iters, check_every=args.check_every,
               topology=args.topology, json_path=args.json_path)


if __name__ == "__main__":
    main()
