"""Causal tracing + gossip observatory: lineage, fates, node health, cost.

The acceptance harness for docs/ARCHITECTURE.md §10, four hard-asserted
sections:

  * **lineage** — a traced ``TrainPublisher`` run, then a deterministic
    replay (``point_latest`` → ``maybe_reload`` → one score per published
    version; the live watcher loses the race for intermediate versions, so
    replay is what makes the guarantee testable): EVERY published version's
    train.segment → publish → swap → first-score chain must be complete
    with monotone timestamps, recovered from the JSONL stream alone.
  * **fates** — a deterministic synthetic load (seeded queries, injectable
    clock, periodic drains, planted oversize submissions and short
    deadlines) through a ``RequestTracer``-hooked ``MicroBatcher``: the
    accounting identity ``submitted == delivered + shed + deadline_missed
    + pending`` must hold EXACTLY, the traced per-fate counters must equal
    the batcher's own stats, and the fate reservoir must hold at most
    ``reservoir`` records over the whole soak (O(1) memory).
  * **observatory** — per-node rings decode against host references
    (row-max == the scalar disagreement ring bit-exactly; the final row
    matches ``||W_i - w_consensus||`` within 1e-5) and a planted fault
    scenario (message drops + one dead node) must flag the dead node and a
    positive Push-Sum mass leak while the fault-free fleet stays clean.
  * **overhead** — with tracing off and the per-node ring ON at the
    default 20-records-per-run cadence, the trajectory is bit-identical to
    the bare run and amortized wall-clock overhead stays <= 5%
    (interleaved reps, min/min ratio — same protocol as
    telemetry_overhead_bench). A small untraced publisher run additionally
    asserts serve-side invariance: zero trace records, no manifest trace
    key.

``--trace-jsonl PATH`` keeps the lineage section's JSONL stream for
downstream validation (CI runs tools/check_telemetry_schema.py over it —
a real traced run, not a synthetic fixture). In the JSON, ``per_node`` and
``lineage_detail`` subtrees are observability output (listed in
check_regression's SKIP_PARENTS); the section asserts are the gate.

Usage:
    PYTHONPATH=src python -m benchmarks.observatory_bench [--quick] \
        [--json out.json] [--trace-jsonl trace.jsonl]
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import jax
import numpy as np

from benchmarks.common import emit, runner_fingerprint
from repro import checkpoint as ckpt
from repro import serve
from repro import telemetry as tm
from repro.core.faults import FaultPlan
from repro.core.gadget import GadgetConfig, gadget_train
from repro.telemetry import top as tmtop
from repro.telemetry import trace as tmtr

OVERHEAD_BUDGET = 0.05  # per-node ring at default cadence: <= 5% wall-clock


def _make_parts(m, n_i, d, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(m, n_i, d)).astype(np.float32)
    w_true = rng.normal(size=d).astype(np.float32)
    y = np.sign(X @ w_true).astype(np.float32)
    y[y == 0] = 1.0
    return X, y


# ---------------------------------------------------------------- lineage


def _run_lineage(trace_path, *, max_iters, segment_iters, d=32):
    """Traced publish run + deterministic replay; returns (section, records,
    registry)."""
    X, y = _make_parts(4, 16, d, seed=0)
    reg = tm.Registry()
    reg.attach_sink(tm.JsonlSink(trace_path))
    with tempfile.TemporaryDirectory() as td:
        root = os.path.join(td, "ckpts")
        cfg = GadgetConfig(lam=1e-3, batch_size=4, gossip_rounds=2,
                           max_iters=max_iters, check_every=segment_iters,
                           epsilon=0.0, use_kernels=False)
        pub = serve.TrainPublisher(X, y, cfg, root=root,
                                   segment_iters=segment_iters,
                                   registry=reg, trace=True).start()
        # live pass: poll-and-score while training runs (the racey half —
        # whichever versions the watcher catches get live serve spans)
        srv = None
        Xq = X.reshape(-1, d)[:4]
        while pub.running:
            if srv is None and ckpt.read_latest(root) is not None:
                srv = serve.SvmServer.watch(root, use_kernels=False,
                                            registry=reg)
            if srv is not None:
                srv.maybe_reload()
                srv.score(Xq)
            time.sleep(0.005)
        pub.join()
        if srv is None:
            srv = serve.SvmServer.watch(root, use_kernels=False, registry=reg)
        # replay pass: deterministically complete every version's chain
        for step in pub.published:
            ckpt.point_latest(root, step)
            srv.maybe_reload()
            srv.score(Xq)
        manifest_traced = "trace" in (
            ckpt.read_manifest(root, pub.published[-1]).get("extra") or {})
    reg.detach_sink()
    records = tm.read_jsonl(trace_path)
    chains = tmtr.lineage_chains(records)
    n_complete = sum(c["complete"] for c in chains.values())
    all_monotone = all(c["monotone"] for c in chains.values())
    # hard asserts: acceptance (a)
    assert sorted(chains) == pub.published, (
        f"chains for {sorted(chains)} != published {pub.published}")
    assert n_complete == len(pub.published), (
        f"only {n_complete}/{len(pub.published)} lineage chains complete")
    assert all_monotone, "a lineage chain has non-monotone stage timestamps"
    assert manifest_traced, "published manifest lost the trace context"
    section = {
        "n_published": len(pub.published),
        "n_chains": len(chains),
        "n_complete": n_complete,
        "all_monotone": int(all_monotone),
        "lineage_detail": {
            str(v): {"complete": int(c["complete"]),
                     "monotone": int(c["monotone"]),
                     "n_attempts": len(c["attempts"])}
            for v, c in sorted(chains.items())
        },
    }
    return section, records, reg


# ------------------------------------------------------------------ fates


def _run_fates(reg, *, n_requests, reservoir, d=64):
    """Deterministic synthetic load through a traced MicroBatcher."""
    clock = {"t": 0.0}
    tracer = tmtr.RequestTracer(reg, sample=1.0, reservoir=reservoir,
                                clock=lambda: clock["t"])
    mb = serve.MicroBatcher((serve.Bucket(4, 8, 32),), registry=reg,
                            tracer=tracer, max_pending=64,
                            admission="shed-oldest",
                            clock=lambda: clock["t"])
    rng = np.random.default_rng(1)

    def ok(b, cols, vals):
        return np.zeros(b.rows), np.ones(b.rows)

    rejected = 0
    for i in range(n_requests):
        if i % 97 == 0:  # planted oversize: refused at the door
            try:
                mb.submit(np.arange(9, dtype=np.int32),
                          np.ones(9, np.float32))
            except serve.QueryRejected:
                rejected += 1
            continue
        nnz = int(rng.integers(1, 9))
        cols = np.sort(rng.choice(d, size=nnz, replace=False)).astype(np.int32)
        vals = rng.normal(size=nnz).astype(np.float32)
        # every 7th request gets a deadline too short to survive the cycle
        deadline = clock["t"] + (0.5 if i % 7 == 0 else 10.0)
        mb.submit(cols, vals, deadline=deadline)
        clock["t"] += 0.01
        if i % 100 == 99:  # drain cycle: expire the short deadlines first
            mb.drain(ok)
    mb.drain(ok)
    st = mb.stats()
    fates = tracer.fate_counts()
    # hard asserts: acceptance (b)
    assert st["submitted"] == (st["delivered"] + st["shed"]
                               + st["deadline_missed"] + st["pending"]), st
    assert st["rejected"] == rejected
    assert fates.get("delivered", 0) == st["delivered"], (fates, st)
    assert fates.get("shed", 0) == st["shed"], (fates, st)
    assert fates.get("deadline", 0) == st["deadline_missed"], (fates, st)
    assert fates.get("rejected", 0) == st["rejected"], (fates, st)
    assert reg.value("trace.requests") == st["submitted"] + st["rejected"]
    kept = tracer.sampled_fates()
    assert len(kept) <= reservoir, (
        f"reservoir leaked: {len(kept)} > {reservoir}")
    return {
        "n_requests": n_requests,
        "submitted": st["submitted"],
        "delivered": st["delivered"],
        "shed": st["shed"],
        "deadline_missed": st["deadline_missed"],
        "rejected": st["rejected"],
        "pending": st["pending"],
        "reconciled": 1,
        "reservoir_cap": reservoir,
        "reservoir_len": len(kept),
    }


# ------------------------------------------------------------ observatory


def _run_observatory():
    """Per-node decode vs host references + planted-fault flagging."""
    # decode exactness on a fault-free fleet recorded every iteration
    X, y = _make_parts(4, 16, 24, seed=2)
    cfg = GadgetConfig(lam=1e-2, batch_size=2, gossip_rounds=2, max_iters=16,
                       check_every=1, epsilon=0.0, use_kernels=False)
    r_off = gadget_train(X, y, cfg)
    r_on = gadget_train(X, y, cfg,
                        telemetry=tm.TrainTelemetry(every=1, slots=16,
                                                    per_node=True))
    bit_identical = (
        np.array_equal(np.asarray(r_on.W), np.asarray(r_off.W))
        and np.array_equal(np.asarray(r_on.w_consensus),
                           np.asarray(r_off.w_consensus)))
    assert bit_identical, "per-node ring changed the training trajectory"
    tr = r_on.telemetry
    rowmax_exact = np.array_equal(tr.node_disagreement.max(axis=1),
                                  np.asarray(tr.disagreement))
    assert rowmax_exact, "row-max of node disagreement != scalar ring"
    host_ref = np.linalg.norm(
        np.asarray(r_on.W, np.float64)
        - np.asarray(r_on.w_consensus, np.float64), axis=1)
    decode_max_err = float(np.abs(tr.node_disagreement[-1] - host_ref).max())
    # hard assert: acceptance (c), decode half
    assert decode_max_err <= 1e-5, (
        f"per-node decode off by {decode_max_err} vs host reference")

    # planted faults: message drops leak mass, node 2 freezes (dead)
    Xf, yf = _make_parts(6, 16, 24, seed=0)
    cfg_f = GadgetConfig(max_iters=300, epsilon=0.0, seed=3, check_every=1,
                         use_kernels=False,
                         faults=FaultPlan(drop_prob=0.05, drop="message",
                                          dead_nodes=(2,), seed=5))
    rep = tm.analyze(gadget_train(
        Xf, yf, cfg_f, telemetry=tm.TrainTelemetry(
            every=10, slots=32, per_node=True)).telemetry)
    cfg_h = cfg_f._replace(faults=None)
    rep_h = tm.analyze(gadget_train(
        Xf, yf, cfg_h, telemetry=tm.TrainTelemetry(
            every=10, slots=32, per_node=True)).telemetry)
    # hard asserts: acceptance (c), flagging half
    assert 2 in rep.dead or 2 in rep.stragglers, (
        f"planted dead node not flagged: {rep}")
    assert rep.mass_leak > 0, "message drops must leak Push-Sum mass"
    assert rep_h.healthy, f"fault-free fleet wrongly flagged: {rep_h}"
    assert rep_h.mixing_rate < 0, "healthy fleet must have a negative slope"
    return {
        "bit_identical": int(bit_identical),
        "rowmax_exact": int(rowmax_exact),
        "decode_max_err": decode_max_err,
        "dead_node_flagged": int(2 in rep.dead or 2 in rep.stragglers),
        "mass_leak_positive": int(rep.mass_leak > 0),
        "healthy_fleet_clean": int(rep_h.healthy),
        "mixing_rate_negative": int(rep_h.mixing_rate < 0),
        "per_node": {
            str(h.node): {"disagreement": h.disagreement, "mass": h.mass,
                          "drops": h.drops, "straggler": int(h.straggler),
                          "dead": int(h.dead)}
            for h in rep.nodes
        },
    }, rep


# --------------------------------------------------------------- overhead


def _timed(Xp, yp, cfg, ring):
    t0 = time.time()
    res = gadget_train(Xp, yp, cfg, telemetry=ring)
    jax.block_until_ready(res.W)
    return res, time.time() - t0


def _run_overhead(*, d, max_iters, reps):
    """Per-node ring at default cadence vs bare run: bit-identity + <=5%."""
    X, y = _make_parts(8, 32, d, seed=3)
    cfg = GadgetConfig(lam=1e-3, batch_size=8, gossip_rounds=2,
                       topology="exponential", max_iters=max_iters,
                       check_every=max(1, max_iters // 4), epsilon=0.0)
    ring = tm.TrainTelemetry(every=max(1, max_iters // 20), slots=32,
                             per_node=True)
    res_off, _ = _timed(X, y, cfg, None)
    res_on, _ = _timed(X, y, cfg, ring)
    bit_identical = (
        np.array_equal(np.asarray(res_on.W), np.asarray(res_off.W))
        and np.array_equal(np.asarray(res_on.w_consensus),
                           np.asarray(res_off.w_consensus)))
    # hard asserts: acceptance (d), identity half
    assert bit_identical, "per-node ring changed the trajectory"
    assert res_on.telemetry.node_disagreement is not None
    off_times, on_times = [], []
    for _ in range(reps):
        _, s_off = _timed(X, y, cfg, None)
        _, s_on = _timed(X, y, cfg, ring)
        off_times.append(s_off)
        on_times.append(s_on)
    off_s, on_s = min(off_times), min(on_times)
    overhead = on_s / off_s
    # hard assert: acceptance (d), cost half
    assert overhead <= 1.0 + OVERHEAD_BUDGET, (
        f"per-node telemetry overhead {overhead:.3f}x exceeds "
        f"{1.0 + OVERHEAD_BUDGET:.2f}x (on={on_s:.4f}s off={off_s:.4f}s)")

    # serve-side invariance: an untraced publish run emits zero trace
    # records and writes no trace key into manifests
    X2, y2 = _make_parts(3, 16, 32, seed=4)
    reg2 = tm.Registry()
    with tempfile.TemporaryDirectory() as td:
        path2 = os.path.join(td, "untraced.jsonl")
        reg2.attach_sink(tm.JsonlSink(path2))
        root2 = os.path.join(td, "ckpts")
        cfg2 = GadgetConfig(lam=1e-3, batch_size=4, gossip_rounds=2,
                            max_iters=10, check_every=5, epsilon=0.0,
                            use_kernels=False)
        pub2 = serve.TrainPublisher(X2, y2, cfg2, root=root2, segment_iters=5,
                                    registry=reg2).start()
        pub2.join()
        srv2 = serve.SvmServer.watch(root2, use_kernels=False, registry=reg2)
        srv2.score(X2.reshape(-1, 32)[:4])
        untraced_manifest_clean = "trace" not in (
            ckpt.read_manifest(root2, 10).get("extra") or {})
        reg2.detach_sink()
        n_trace_records = sum("trace_id" in r for r in tm.read_jsonl(path2))
    assert n_trace_records == 0, (
        f"tracing off still emitted {n_trace_records} trace records")
    assert untraced_manifest_clean, "tracing off wrote a manifest trace key"
    return {
        "off": {"seconds": off_s},
        "on": {"seconds": on_s,
               "ring_count": int(res_on.telemetry.count)},
        "overhead_ratio": overhead,
        "bit_identical": int(bit_identical),
        "untraced_run_emits_nothing": int(n_trace_records == 0),
        "untraced_manifest_clean": int(untraced_manifest_clean),
        "config": {"d": d, "max_iters": max_iters, "reps": reps,
                   "tele_every": ring.every},
    }


# -------------------------------------------------------------------- run


def run(quick: bool = False, json_path: str | None = None,
        trace_jsonl: str | None = None, verbose: bool = True) -> dict:
    """All four sections; every acceptance assert is raised in-run."""
    t0 = time.time()
    lineage_iters = 40 if quick else 120
    n_requests = 5000 if quick else 50000
    ovh_d = 1024 if quick else 2048
    ovh_iters = 2000 if quick else 3000
    ovh_reps = 6 if quick else 8

    own_tmp = None
    if trace_jsonl is None:
        own_tmp = tempfile.mkdtemp(prefix="observatory_bench_")
        trace_jsonl = os.path.join(own_tmp, "trace.jsonl")

    lineage, records, reg = _run_lineage(trace_path=trace_jsonl,
                                         max_iters=lineage_iters,
                                         segment_iters=10)
    if verbose:
        emit("observatory/lineage", 0.0,
             f"versions={lineage['n_published']}"
             f";complete={lineage['n_complete']}"
             f";monotone={lineage['all_monotone']}")

    fates = _run_fates(reg, n_requests=n_requests, reservoir=256)
    if verbose:
        emit("observatory/fates", 0.0,
             f"submitted={fates['submitted']};delivered={fates['delivered']}"
             f";shed={fates['shed']};deadline={fates['deadline_missed']}"
             f";rejected={fates['rejected']}"
             f";reservoir={fates['reservoir_len']}/{fates['reservoir_cap']}")

    observatory, rep = _run_observatory()
    tm.publish_node_health(rep, reg)
    if verbose:
        emit("observatory/node_health", 0.0,
             f"dead_flagged={observatory['dead_node_flagged']}"
             f";decode_err={observatory['decode_max_err']:.2e}"
             f";leak_positive={observatory['mass_leak_positive']}")

    # the top console renders all three panes from the same stream
    frame = tmtop.render_registry(reg, records)
    assert "=== gossip nodes ===" in frame and "complete" in frame

    overhead = _run_overhead(d=ovh_d, max_iters=ovh_iters, reps=ovh_reps)
    if verbose:
        emit(f"observatory/overhead(d={ovh_d},T={ovh_iters})",
             overhead["on"]["seconds"] * 1e6,
             f"ratio={overhead['overhead_ratio']:.3f}x"
             f";bit_identical={overhead['bit_identical']}")

    out = {
        "quick": quick,
        "runner": runner_fingerprint(),
        "lineage": lineage,
        "fates": fates,
        "observatory": observatory,
        "overhead": overhead,
        "asserts": {
            "lineage_all_complete": int(
                lineage["n_complete"] == lineage["n_published"]),
            "lineage_all_monotone": lineage["all_monotone"],
            "fates_reconciled": fates["reconciled"],
            "reservoir_bounded": int(
                fates["reservoir_len"] <= fates["reservoir_cap"]),
            "per_node_decode_matches_host": int(
                observatory["decode_max_err"] <= 1e-5),
            "dead_node_flagged": observatory["dead_node_flagged"],
            "tracing_off_bit_identical": overhead["bit_identical"],
            "overhead_within_budget": int(
                overhead["overhead_ratio"] <= 1.0 + OVERHEAD_BUDGET),
        },
        "telemetry": reg.values(),
        "total": {"seconds": time.time() - t0},
    }
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(out, fh, indent=2)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke scale: 4 versions, 5k requests, "
                         "d=1024/2000-iter overhead arm")
    ap.add_argument("--json", dest="json_path", default=None,
                    help="write results as JSON (CI uploads this)")
    ap.add_argument("--trace-jsonl", dest="trace_jsonl", default=None,
                    help="keep the lineage section's JSONL stream here "
                         "(CI schema-validates it)")
    args = ap.parse_args()
    run(quick=args.quick, json_path=args.json_path,
        trace_jsonl=args.trace_jsonl)
