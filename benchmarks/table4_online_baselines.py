"""Paper Table 4: GADGET vs state-of-the-art online/primal baselines run
per-node WITHOUT communication — SVM-SGD (Bottou) and the cutting-plane
solver standing in for SVM-Perf (same algorithmic family, our implementation;
see core/cutting_plane.py).

Each baseline executes independently on every node's partition and reports
node-averaged test accuracy — the paper's exact protocol ("distributed,
albeit without communication").
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_dataset, emit
from repro.configs.gadget_svm import PAPER_RUNS
from repro.core import svm_objective as obj
from repro.core.cutting_plane import cutting_plane_svm, svm_sgd
from repro.core.gadget import gadget_train
from repro.data.svm_datasets import partition


def run(datasets=("reuters", "usps", "adult"), n_iters=1200, verbose=True):
    rows = []
    for name in datasets:
        runcfg = PAPER_RUNS[name]
        ds = bench_dataset(name)
        Xte, yte = jnp.asarray(ds.X_test), jnp.asarray(ds.y_test)
        Xp, yp, nc = partition(ds.X_train, ds.y_train, runcfg.n_nodes)

        t0 = time.time()
        res = gadget_train(jnp.asarray(Xp), jnp.asarray(yp),
                           runcfg.gadget._replace(max_iters=n_iters, batch_size=8),
                           n_counts=nc)
        t_gad = time.time() - t0
        acc_gad = float(obj.accuracy(res.w_consensus, Xte, yte))

        t0 = time.time()
        accs_sgd = [float(obj.accuracy(jnp.asarray(svm_sgd(Xp[i], yp[i], ds.lam)), Xte, yte))
                    for i in range(runcfg.n_nodes)]
        t_sgd = time.time() - t0

        t0 = time.time()
        accs_cp = [float(obj.accuracy(jnp.asarray(
            cutting_plane_svm(np.asarray(Xp[i]), np.asarray(yp[i]), ds.lam).w), Xte, yte))
            for i in range(runcfg.n_nodes)]
        t_cp = time.time() - t0

        rows.append({
            "dataset": name, "acc_gadget": acc_gad, "t_gadget_s": t_gad,
            "acc_svmsgd": float(np.mean(accs_sgd)), "std_svmsgd": float(np.std(accs_sgd)),
            "t_svmsgd_s": t_sgd,
            "acc_cutplane": float(np.mean(accs_cp)), "std_cutplane": float(np.std(accs_cp)),
            "t_cutplane_s": t_cp,
        })
        if verbose:
            emit(f"table4/{name}", t_gad * 1e6 / n_iters,
                 f"gadget={acc_gad:.3f}({t_gad:.1f}s);"
                 f"svmsgd={np.mean(accs_sgd):.3f}+-{np.std(accs_sgd):.3f}({t_sgd:.1f}s);"
                 f"cutplane={np.mean(accs_cp):.3f}+-{np.std(accs_cp):.3f}({t_cp:.1f}s)")
    return rows


if __name__ == "__main__":
    run()
