"""Paper Table 5 / Appendix B: speed-up including data-loading time.

Loading time for the distributed algorithm is per-node (1/k of the rows);
the centralized run loads everything. Speedup = t_distributed / t_centralized
(paper Eq. 25: values < 1 mean the distributed algorithm is faster end-to-
end, which the paper observes when instances >> features).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_dataset, emit
from repro.configs.gadget_svm import PAPER_RUNS
from repro.core import svm_objective as obj
from repro.core.gadget import gadget_train
from repro.core.pegasos import pegasos_train
from repro.data.svm_datasets import partition


def _load_proxy(X: np.ndarray) -> float:
    """Deterministic 'disk load' proxy: one pass of parsing-equivalent work
    (copy + checksum) over the rows — proportional to bytes, like real IO."""
    t0 = time.time()
    _ = X.astype(np.float32).sum()
    buf = X.tobytes()
    _ = len(buf)
    return time.time() - t0


def run(datasets=("adult", "mnist", "usps", "webspam"), n_iters=1000, verbose=True):
    rows = []
    for name in datasets:
        runcfg = PAPER_RUNS[name]
        ds = bench_dataset(name)
        Xte, yte = jnp.asarray(ds.X_test), jnp.asarray(ds.y_test)

        t_load_full = _load_proxy(ds.X_train)
        t0 = time.time()
        cen = pegasos_train(jnp.asarray(ds.X_train), jnp.asarray(ds.y_train),
                            lam=ds.lam, n_iters=n_iters, batch_size=8)
        jnp.asarray(cen.w).block_until_ready()
        t_cen = t_load_full + (time.time() - t0)

        Xp, yp, nc = partition(ds.X_train, ds.y_train, runcfg.n_nodes)
        t_load_node = _load_proxy(np.asarray(Xp[0]))  # per-node load (parallel)
        t0 = time.time()
        res = gadget_train(jnp.asarray(Xp), jnp.asarray(yp),
                           runcfg.gadget._replace(max_iters=n_iters, batch_size=8),
                           n_counts=nc)
        t_gad = t_load_node + (time.time() - t0)

        rows.append({
            "dataset": name,
            "t_gadget_s": t_gad, "acc_gadget": float(obj.accuracy(res.w_consensus, Xte, yte)),
            "t_pegasos_s": t_cen, "acc_pegasos": float(obj.accuracy(cen.w, Xte, yte)),
            "speedup_factor": t_gad / t_cen,
        })
        if verbose:
            emit(f"table5/{name}", t_gad * 1e6 / n_iters,
                 f"t_gadget={t_gad:.2f}s;t_pegasos={t_cen:.2f}s;factor={t_gad/t_cen:.2f}")
    return rows


if __name__ == "__main__":
    run()
