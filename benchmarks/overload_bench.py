"""Open-loop overload benchmark: bounded admission + deadlines + degradation.

Closed-loop benchmarks (``serve_bench``) can never overload the server —
they wait for each drain before submitting more. This harness drives a live
:class:`repro.serve.SvmServer` with a **seeded Poisson arrival process**
whose rate is independent of service completions (open loop), so offered
load above capacity actually piles up, and measures what the overload
policy (``docs/ARCHITECTURE.md`` §9) does about it.

Protocol:

1. **Measure capacity** closed-loop through the same batcher/drain
   machinery the open-loop runs use (so the calibration includes every
   per-request Python and launch overhead, not just kernel time).
2. **Sweep load factors** 0.5× / 1.0× / 2.0× of that capacity with the full
   protection stack on — ``shed-oldest`` bounded admission, default
   deadlines, and the hysteretic :class:`repro.serve.DegradeLadder` — and
   once more at 2.0× with every protection off (the historical unbounded
   batcher).
3. Record goodput, shed / deadline-miss rates, histogram-backed p50/p99 and
   queue depth per load point into ``BENCH_overload.json``.

Hard asserts (the regression surface; every run):

* **Accounting** — at every load point the counters reconcile exactly:
  ``submitted == delivered + shed + deadline_missed`` after the final flush,
  and every offered request is either submitted or typed-rejected.
* **Bounded under 2×** — the protected queue never exceeds ``max_pending``
  and delivered-request p99 stays under ``deadline + slack`` (expired work
  is dropped before launch, so the tail cannot grow past the deadline).
* **Goodput holds** — protected goodput at 2× offered load is within 10% of
  (or above) the 1× level: shedding drops requests, not throughput. The
  assert compares **busy-time** goodput (delivered / drain seconds — the
  rate the server actually sustains while scoring) so it cannot flake on
  how the critical-load random walk at exactly 1.0× happened to shed;
  wall-clock goodput is recorded beside it. Degraded rungs make surviving
  requests cheaper, so exceeding 1× goodput is success, not noise — the
  assert is one-sided from below.
* **Unprotected contrast** — the unbounded configuration's queue depth
  grows monotonically through the arrival window (non-decreasing quartile
  means, last > 2× first) and its peak blows through the protected bound.
* **Zero recompiles** — ``distinct_shapes`` is identical before and after
  the whole sweep: every ladder transition (int8 plane, cheapest-bucket
  routing) reuses already-compiled executables.

Absolute rates are CPU-host numbers, not TPU numbers; the asserts and the
structural leaves (accounting, bounds, compile count) are the regression
surface, with goodput/shed-rate visible as warn-only structural leaves.

Usage:
    PYTHONPATH=src python -m benchmarks.overload_bench [--quick] [--json out.json]
"""
from __future__ import annotations

import argparse
import json
import threading
import time

import numpy as np

from benchmarks.common import emit, runner_fingerprint
from repro import serve
from repro import telemetry as tm

#: delivered-latency tail bound at 2× protected load: requests older than the
#: deadline are dropped before launch, so p99 can only exceed the deadline by
#: scheduling slack + one batch's service time (generous for a shared CI box).
P99_SLACK_MS = 500.0
#: one-sided goodput floor: 2× goodput >= (1 - GOODPUT_TOL) * 1× goodput.
GOODPUT_TOL = 0.10


def _make_pool(d: int, k_max: int, n_pool: int, seed: int):
    """Pre-generate a pool of ragged sparse queries (1-D cols/vals each)."""
    rng = np.random.default_rng(seed)
    pool = []
    for _ in range(n_pool):
        nnz = int(rng.integers(4, k_max + 1))
        cols = np.sort(rng.choice(d, size=nnz, replace=False)).astype(np.int32)
        vals = rng.standard_normal(nnz).astype(np.float32)
        pool.append((cols, vals))
    return pool


def _warm(srv, buckets) -> int:
    """Compile every bucket shape up front; returns the compile count."""
    for b in buckets:
        srv.score_sparse(np.zeros((b.rows, b.k), np.int32),
                         np.zeros((b.rows, b.k), np.float32),
                         n_blocks_max=b.n_blocks_max)
    return srv.stats()["distinct_shapes"]


def measure_capacity(srv, buckets, pool, seconds: float) -> float:
    """Closed-loop service capacity (queries/sec) through the same
    batcher/drain machinery the open-loop runs use — submit a full wave,
    drain it, repeat — so the number includes all per-request overhead and
    1.0× offered load really is the saturation point."""
    mb = serve.MicroBatcher(buckets)
    score_fn = srv.scorer_for()
    wave = max(len(pool) // 4, buckets[0].rows * 4)
    delivered = 0
    i = 0
    t0 = time.monotonic()
    while time.monotonic() - t0 < seconds:
        for _ in range(wave):
            c, v = pool[i % len(pool)]
            mb.submit(c, v)
            i += 1
        delivered += len(mb.drain(score_fn))
    return delivered / (time.monotonic() - t0)


def open_loop_run(srv, buckets, pool, rate_qps: float, duration_s: float, *,
                  protected: bool, max_pending: int, timeout_s: float,
                  seed: int, label: str, verbose: bool) -> dict:
    """One open-loop load point: a submitter thread replays a seeded Poisson
    arrival schedule at ``rate_qps`` while the main thread drains (and, when
    ``protected``, steps the degradation ladder between drains). Returns the
    per-run record for the JSON, with the accounting asserts applied."""
    mb = serve.MicroBatcher(
        buckets,
        max_pending=max_pending if protected else None,
        admission="shed-oldest",
        default_timeout=timeout_s if protected else None)
    ladder = None
    if protected:
        ladder = serve.DegradeLadder(srv, mb, high=0.75, low=0.25, patience=2)
        ladder.prepare()

    n = max(50, int(rate_qps * duration_s))
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_qps, size=n))
    order = rng.integers(0, len(pool), size=n)
    shapes0 = srv.stats()["distinct_shapes"]

    done = threading.Event()

    def submitter():
        t0 = time.monotonic()
        for at, qi in zip(arrivals, order):
            lag = t0 + at - time.monotonic()
            if lag > 0:
                time.sleep(lag)
            cols, vals = pool[qi]
            mb.submit(cols, vals)
        done.set()

    score_fn = srv.scorer_for()
    depth = []          # queue depth sampled at each drain, arrival window only
    max_rung = 0
    th = threading.Thread(target=submitter, daemon=True,
                          name=f"overload-submitter-{label}")
    t_start = time.monotonic()
    th.start()
    while not done.is_set() or mb.pending:
        if not done.is_set():
            depth.append(mb.pending)
        if ladder is not None:
            max_rung = max(max_rung, ladder.observe())
        if mb.pending:
            mb.drain(score_fn)
        else:
            time.sleep(0.0005)
    th.join()
    mb.drain(score_fn)  # flush typed Shed/DeadlineExceeded results, if any
    wall = time.monotonic() - t_start
    if ladder is not None:  # leave the shared server at full service
        srv.set_plane("f32")
        mb.degrade_to(None)

    st = mb.stats()
    shapes1 = srv.stats()["distinct_shapes"]
    assert shapes1 == shapes0, (
        f"{label}: distinct_shapes moved {shapes0} -> {shapes1} — an overload "
        f"transition recompiled")
    assert st["pending"] == 0
    assert st["submitted"] + st["rejected"] == n, (
        f"{label}: offered {n} != submitted {st['submitted']} + "
        f"rejected {st['rejected']}")
    assert st["submitted"] == st["delivered"] + st["shed"] + st["deadline_missed"], (
        f"{label}: accounting leak — submitted {st['submitted']} != "
        f"delivered {st['delivered']} + shed {st['shed']} + "
        f"deadline_missed {st['deadline_missed']}")

    goodput = st["delivered"] / wall
    goodput_busy = (st["delivered"] / st["drain_seconds"]
                    if st["drain_seconds"] else 0.0)
    rec = {
        "protected": int(protected),
        "offered": n,
        "offered_qps": round(rate_qps, 1),
        "submitted": st["submitted"],
        "delivered": st["delivered"],
        "shed": st["shed"],
        "deadline_missed": st["deadline_missed"],
        "rejected": st["rejected"],
        "truncated": st["truncated"],
        "goodput_qps": round(goodput, 1),
        "goodput_busy_qps": round(goodput_busy, 1),
        "shed_rate": round(st["shed"] / n, 3),
        "deadline_miss_rate": round(st["deadline_missed"] / n, 3),
        "queue_peak": st["queue_peak"],
        "max_rung": max_rung,
        "us_per_call": {"p50": st["latency_p50_ms"] * 1e3,
                        "p99": st["latency_p99_ms"] * 1e3},
        "wall": {"seconds": wall},
    }
    if depth:
        # quartile-mean queue depth over the arrival window: the
        # bounded-vs-unbounded growth evidence
        quarts = [float(np.mean(q)) for q in np.array_split(np.array(depth), 4)]
        rec["depth_quartiles"] = [round(q, 1) for q in quarts]
    if verbose:
        emit(f"overload/{label}", st["latency_p99_ms"] * 1e3,
             f"goodput={goodput:.0f}qps;shed={st['shed']};"
             f"miss={st['deadline_missed']};peak={st['queue_peak']};"
             f"rung={max_rung}")
    return rec


def run(quick: bool = False, json_path: str | None = None,
        verbose: bool = True) -> dict:
    d = 2048 if quick else 8192
    k_max = 64
    cal_seconds = 0.35 if quick else 1.0
    duration_s = 0.9 if quick else 2.5

    tm.reset()  # the JSON's telemetry section covers this run only
    rng_w = np.random.default_rng(0)
    W = rng_w.standard_normal(d).astype(np.float32)
    # kernel path (interpreted on CPU, like serve_bench): per-launch service
    # cost dominates per-request queue bookkeeping, as on a real accelerator —
    # with the cheap jnp oracle the Python-side load generator itself becomes
    # the bottleneck and goodput measures GIL contention, not the server
    srv = serve.SvmServer(W, use_kernels=True, registry=tm.default_registry())
    buckets = serve.bucket_ladder(k_max, rows=8, min_k=16, d=d)
    pool = _make_pool(d, k_max, n_pool=256, seed=1)

    shapes_warm = _warm(srv, buckets)
    assert shapes_warm == len(buckets)
    capacity = measure_capacity(srv, buckets, pool, cal_seconds)
    if verbose:
        emit("overload/capacity", 1e6 / capacity, f"qps={capacity:.0f}")

    # protection knobs derived from measured capacity: the queue holds ~50 ms
    # of work, deadlines allow ~4 queue-drain times of waiting
    max_pending = max(64, int(capacity * 0.05))
    timeout_s = max(0.1, 4 * max_pending / capacity)

    points = {}
    for i, factor in enumerate((0.5, 1.0, 2.0)):
        points[f"{factor}x"] = open_loop_run(
            srv, buckets, pool, capacity * factor, duration_s,
            protected=True, max_pending=max_pending, timeout_s=timeout_s,
            seed=100 + i, label=f"{factor}x", verbose=verbose)
    points["2.0x-unprotected"] = open_loop_run(
        srv, buckets, pool, capacity * 2.0, duration_s,
        protected=False, max_pending=max_pending, timeout_s=timeout_s,
        seed=103, label="2.0x-unprotected", verbose=verbose)

    # ---- cross-point asserts: what the protection stack buys at 2× --------
    p1, p2 = points["1.0x"], points["2.0x"]
    un = points["2.0x-unprotected"]
    assert p2["queue_peak"] <= max_pending, (
        f"protected 2x queue peak {p2['queue_peak']} > bound {max_pending}")
    p99_bound_ms = timeout_s * 1e3 + P99_SLACK_MS
    assert p2["us_per_call"]["p99"] <= p99_bound_ms * 1e3, (
        f"protected 2x p99 {p2['us_per_call']['p99'] / 1e3:.0f} ms > "
        f"deadline+slack bound {p99_bound_ms:.0f} ms")
    assert p2["goodput_busy_qps"] >= (1 - GOODPUT_TOL) * p1["goodput_busy_qps"], (
        f"goodput collapsed under 2x load: {p2['goodput_busy_qps']:.0f} qps "
        f"busy < {1 - GOODPUT_TOL:.2f} * {p1['goodput_busy_qps']:.0f} qps")
    assert p2["max_rung"] >= 1, "2x overload never engaged the degrade ladder"
    assert un["queue_peak"] > max_pending, (
        f"unprotected 2x queue peak {un['queue_peak']} never exceeded the "
        f"protected bound {max_pending} — not actually overloaded")
    uq = un["depth_quartiles"]
    assert all(b >= a for a, b in zip(uq, uq[1:])) and uq[-1] > 2 * uq[0], (
        f"unprotected queue depth did not grow monotonically: {uq}")
    shapes_end = srv.stats()["distinct_shapes"]
    assert shapes_end == shapes_warm, (
        f"sweep recompiled: {shapes_warm} -> {shapes_end} shapes")

    out = {
        "quick": quick,
        "runner": runner_fingerprint(),
        "model": {"d": d, "k_max": k_max, "n_buckets": len(buckets),
                  "bucket_ks": [b.k for b in buckets]},
        "capacity_qps": round(capacity, 1),
        "max_pending": max_pending,
        "timeout_ms": round(timeout_s * 1e3, 1),
        "distinct_shapes": shapes_end,
        "load_points": points,
        "asserts_passed": 1,
        "telemetry": tm.default_registry().values(),
    }
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(out, fh, indent=2)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke scale (smaller d, shorter load windows)")
    ap.add_argument("--json", dest="json_path", default=None,
                    help="write results as JSON (CI uploads this as an artifact)")
    args = ap.parse_args()
    run(quick=args.quick, json_path=args.json_path)
