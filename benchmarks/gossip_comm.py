"""Communication-cost comparison: gossip vs all-reduce (the paper's central
efficiency claim, §2.2.3: "MoM-DSVM broadcasts ... thereby having a higher
communication cost").

Two sources:
  * analytic per-step bytes per replica for a P-byte model:
      ring all-reduce: 2 (n-1)/n P;  R gossip rounds: R * (1-self_share) P
  * measured collective bytes from the dry-run JSONL (when present) for
    llama3-8b train_4k allreduce vs gossip on the same mesh.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import emit


def analytic(P_bytes: float, n: int, rounds: int, self_share: float = 0.5):
    allreduce = 2.0 * (n - 1) / n * P_bytes
    gossip = rounds * (1.0 - self_share) * P_bytes
    return allreduce, gossip


def run(dryrun_jsonl="results/dryrun_baseline.jsonl", verbose=True):
    rows = {}
    P = 16e9  # llama3-8b bf16
    for n, rounds in [(16, 1), (16, 2), (16, 4), (2, 1)]:
        ar, go = analytic(P, n, rounds)
        rows[f"n{n}_R{rounds}"] = (ar, go)
        if verbose:
            emit(f"gossip_comm/analytic_n{n}_R{rounds}", 0.0,
                 f"allreduce={ar/1e9:.2f}GB;gossip={go/1e9:.2f}GB;ratio={go/ar:.2f}")
    if os.path.exists(dryrun_jsonl):
        recs = [json.loads(l) for l in open(dryrun_jsonl)]
        for r in recs:
            if (r.get("arch") == "llama3-8b" and r.get("shape") == "train_4k"
                    and r.get("status") == "ok"):
                if verbose:
                    emit(f"gossip_comm/measured_{r['consensus']}_{r['mesh']}", 0.0,
                         f"collective_bytes={r['collective_bytes']:.3e}")
                rows[f"measured_{r['consensus']}_{r['mesh']}"] = r["collective_bytes"]
    return rows


if __name__ == "__main__":
    run()
