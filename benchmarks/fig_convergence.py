"""Paper Figures 4.1-4.3: primal objective and zero-one test error vs
training progress for GADGET — plus the consensus curve (max inter-node
disagreement), which is the anytime property made visible. Emits CSV."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_dataset, emit
from repro.configs.gadget_svm import PAPER_RUNS
from repro.core import svm_objective as obj
from repro.core.gadget import gadget_train
from repro.data.svm_datasets import partition


def run(dataset="reuters", n_iters=1600, verbose=True, csv_path=None):
    runcfg = PAPER_RUNS[dataset]
    ds = bench_dataset(dataset)
    Xte, yte = jnp.asarray(ds.X_test), jnp.asarray(ds.y_test)
    Xp, yp, nc = partition(ds.X_train, ds.y_train, runcfg.n_nodes)
    Xpj, ypj = jnp.asarray(Xp), jnp.asarray(yp)

    # check cadence = curve resolution: traces are recorded on device every
    # `seg` iterations inside the single gadget_train call
    seg = max(100, n_iters // 12)
    cfg = runcfg.gadget._replace(max_iters=n_iters, check_every=seg, batch_size=8,
                                 epsilon=0.0)  # disable early stop for full curve
    res = gadget_train(Xpj, ypj, cfg, n_counts=nc)

    # the objective AND the anytime ε-curve (max_i ‖Δŵ_i‖ per check) come
    # straight off the device traces — no extra host-side recomputation
    rows = []
    for it, objective, eps in zip(res.time_trace, res.objective_trace, res.eps_trace):
        rows.append({"iter": int(it), "objective": float(objective), "eps": float(eps)})
    err = 1.0 - float(obj.accuracy(res.w_consensus, Xte, yte))
    W = np.asarray(res.W)
    center = W.mean(0)
    consensus = float(np.max(np.linalg.norm(W - center, axis=1)))

    lines = ["iter,objective,eps"] + [
        f"{r['iter']},{r['objective']:.6f},{r['eps']:.6g}" for r in rows]
    csv = "\n".join(lines)
    if csv_path:
        with open(csv_path, "w") as fh:
            fh.write(csv + "\n")
    if verbose:
        emit(f"fig_convergence/{dataset}", 0.0,
             f"final_obj={rows[-1]['objective']:.4f};test_err={err:.3f};"
             f"consensus_dist={consensus:.4f};n_points={len(rows)}")
    return {"rows": rows, "test_err": err, "consensus": consensus, "csv": csv}


if __name__ == "__main__":
    run()
