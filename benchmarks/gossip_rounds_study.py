"""Gossip-rounds sweep on a real transformer: the empirical counterpart of
the gamma term in the paper's Theorem 2 (regret grows with gossip error).

For R Push-Sum rounds per step on G replicas: R = log2(G) is exact averaging
(gossip == all-reduce trajectory); smaller R trades consensus error for
communication. Reports final loss and replica disagreement per R.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.data.tokens import Batcher, TokenStreamConfig
from repro.launch import steps as steps_mod
from repro.models.transformer import Model

G, STEPS, BATCH, SEQ = 8, 25, 16, 32


def _train(rounds: int, mix_every: int = 1, payload: str = "full"):
    cfg = get_config("llama3-8b").reduced(n_layers=2, d_model=128)
    model = Model(cfg)
    tcfg = steps_mod.TrainerConfig(optimizer="adamw", lr=3e-3, warmup_steps=3,
                                   total_steps=STEPS, consensus="gossip",
                                   n_replicas=G, gossip_rounds=rounds,
                                   mix_every=mix_every, gossip_payload=payload)
    state = steps_mod.make_train_state(model, tcfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(steps_mod.make_train_step(model, tcfg))
    batcher = Batcher(TokenStreamConfig(cfg.vocab_size, SEQ, BATCH, seed=0))
    losses = []
    for s in range(STEPS):
        b = {k: jnp.asarray(v).reshape(G, BATCH // G, SEQ)
             for k, v in batcher.global_batch(s).items()}
        state, m = step_fn(state, b)
        losses.append(float(m["loss"]))
    spread = 0.0
    for leaf in jax.tree.leaves(state["params"]):
        c = leaf.mean(0, keepdims=True)
        spread = max(spread, float(jnp.linalg.norm((leaf - c).astype(jnp.float32)))
                     / (float(jnp.linalg.norm(c.astype(jnp.float32))) + 1e-9))
    return float(np.mean(losses[-5:])), spread


def run(verbose=True):
    rows = []
    for label, kw in [
        ("R=3(exact)", dict(rounds=3)),
        ("R=1", dict(rounds=1)),
        ("R=1,bf16", dict(rounds=1, payload="bf16")),
        ("R=1,every4", dict(rounds=1, mix_every=4)),
    ]:
        loss, spread = _train(**kw)
        # comm bytes per step per replica relative to model size P:
        r = kw.get("rounds", 1) / kw.get("mix_every", 1)
        comm = 0.5 * r
        rows.append({"config": label, "final_loss": loss, "spread": spread,
                     "comm_x_model_bytes": comm})
        if verbose:
            emit(f"gossip_rounds/{label}", 0.0,
                 f"loss={loss:.4f};spread={spread:.5f};comm={comm:.3f}xP")
    return rows


if __name__ == "__main__":
    run()
