"""Promote a fresh benchmark JSON into a per-runner-class baseline.

Two modes:

* **Promote** (default): copy ``--fresh out.json`` to
  ``benchmarks/baselines/<stem>.<slug>.json`` where ``<slug>`` is derived
  from the JSON's own embedded ``runner`` fingerprint
  (``check_regression.fingerprint_slug``). This is the committed artifact
  that arms the wall-clock gate for the recording machine's class — the
  scripted version of step 3 in benchmarks/README.md's bootstrap recipe.

* **Bootstrap** (``--hosted``): synthesize a *provisional* baseline for the
  pinned CI runner class (``ubuntu-24.04`` hosted: linux/x86_64/3.11/cpu,
  Pallas interpret on, 4 cores) from a run recorded elsewhere. The runner
  fingerprint is rewritten to the hosted class and every wall-clock leaf is
  inflated by ``--headroom`` (default 3.0x) so the first real hosted runs
  cannot hard-fail on machine-class speed differences; structural leaves are
  copied verbatim (they are machine-independent by construction). The
  baseline notes its provenance under a ``bootstrap`` key (strings only —
  invisible to the leaf diff). Replace it with a real green bench-smoke
  artifact (plain promote mode) once one exists; until then the gate is
  armed with conservative numbers rather than not at all.

Usage:
    python benchmarks/promote_baseline.py --fresh fault_bench.json --stem BENCH_faults
    python benchmarks/promote_baseline.py --fresh BENCH_faults.json --stem BENCH_faults --hosted
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from benchmarks.check_regression import (WALLCLOCK_LEAVES, WALLCLOCK_PARENTS,
                                         fingerprint_slug)

# The fingerprint of CI's pinned runner class (.github/workflows/ci.yml:
# runs-on: ubuntu-24.04, python 3.11, JAX_PLATFORMS=cpu,
# REPRO_PALLAS_INTERPRET=1, 4-core hosted image).
HOSTED_FINGERPRINT = {
    "os": "linux", "machine": "x86_64", "python": "3.11", "backend": "cpu",
    "pallas_interpret": 1, "cpu_count": 4,
}
DEFAULT_HEADROOM = 3.0


def scale_wallclock(obj, factor: float, under_parent: bool = False):
    """Recursively multiply wall-clock leaves (``seconds`` keys and anything
    under a ``us_per_call`` subtree) by ``factor``; everything else copies."""
    if isinstance(obj, dict):
        return {
            k: scale_wallclock(
                v, factor, under_parent or k in WALLCLOCK_PARENTS)
            if not (k in WALLCLOCK_LEAVES and isinstance(v, (int, float)))
            else round(float(v) * factor, 6)
            for k, v in obj.items()
        }
    if isinstance(obj, list):
        return [scale_wallclock(v, factor, under_parent) for v in obj]
    if under_parent and isinstance(obj, (int, float)) and not isinstance(obj, bool):
        return round(float(obj) * factor, 3)
    return obj


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fresh", required=True,
                    help="benchmark JSON to promote (must embed a runner "
                         "fingerprint)")
    ap.add_argument("--stem", required=True,
                    help="baseline stem, e.g. BENCH_faults")
    ap.add_argument("--baseline-dir", default="benchmarks/baselines")
    ap.add_argument("--hosted", action="store_true",
                    help="bootstrap a provisional baseline for the pinned CI "
                         "runner class instead of this machine's class")
    ap.add_argument("--headroom", type=float, default=DEFAULT_HEADROOM,
                    help="wall-clock inflation factor for --hosted "
                         f"(default {DEFAULT_HEADROOM})")
    args = ap.parse_args(argv)

    with open(args.fresh) as fh:
        data = json.load(fh)
    fp = data.get("runner")
    if not fp:
        print(f"error: {args.fresh} has no 'runner' fingerprint", file=sys.stderr)
        return 1

    if args.hosted:
        src_slug = fingerprint_slug(fp)
        data = scale_wallclock(data, args.headroom)
        data["runner"] = dict(HOSTED_FINGERPRINT)
        data["bootstrap"] = {
            "note": ("provisional hosted-class baseline synthesized from a "
                     f"{src_slug} run; wall-clock leaves inflated "
                     f"{args.headroom}x — replace with a green bench-smoke "
                     "artifact (promote mode) when one exists"),
            "source_slug": src_slug,
        }
        fp = data["runner"]

    slug = fingerprint_slug(fp)
    os.makedirs(args.baseline_dir, exist_ok=True)
    out = os.path.join(args.baseline_dir, f"{args.stem}.{slug}.json")
    with open(out, "w") as fh:
        json.dump(data, fh, indent=2)
        fh.write("\n")
    print(f"promoted {args.fresh} -> {out}"
          + (" (provisional hosted bootstrap)" if args.hosted else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main())
