"""Chaos bench: GADGET training under injected faults — graceful degradation,
measured.

One Reuters-shaped run per fault regime, all through the fused device path
(``GadgetConfig(faults=FaultPlan(...))``):

  * **clean** — the fault-free baseline every regime is judged against;
  * **link drops** at 0.1 / 0.2 / 0.4 — ack'd-link model, mass conserved
    exactly, convergence merely slows;
  * **message drops** at 0.1 / 0.2 / 0.4 — UDP model, mass measurably leaks,
    and the leakage gauge (1 - min mass, read from the flight-recorder trace
    ring) must grow strictly with drop_prob;
  * **dead nodes** (1 and 2 of m crashed from iteration 0) — their data is
    simply gone, survivors carry the consensus.

Every run trains with the on-device telemetry ring attached
(``telemetry=TrainTelemetry(every=1, slots=max_iters)`` — never wraps), so
per-regime mass extrema, consensus disagreement, and fault-drop counts are
read from ``GadgetResult.telemetry``, not recomputed; the JSON's
``telemetry`` section snapshots the default registry (iterations, gossip
bytes, cumulative fault drops) after the sweep.

Asserted on every run (the acceptance criteria, not just reported):

  * fused-vs-host-reference parity at drop 0.2 link: consensus weights agree
    to <= 1e-5 — the fault layer never changes *what* is computed;
  * Push-Sum mass: every link-mode regime retains >= 1 - 1e-4 of its mass at
    every ε-check (exact conservation to float-sum tolerance); the
    message-mode regime visibly leaks (min mass < 0.999);
  * kill-and-resume at drop 0.2 link: a stream stopped at the halfway
    segment and resumed from its TrainState finishes bit-identical to the
    uninterrupted run;
  * graceful degradation: test accuracy at drop 0.2 (link) stays within 2
    points of the fault-free baseline.

Wall-clock leaves ride the usual check_regression gate; the per-regime
accuracy/spread numbers are deterministic at fixed seeds on one platform and
diff as structural leaves.

Usage:
    PYTHONPATH=src python -m benchmarks.fault_bench [--quick] [--json out.json]
"""
from __future__ import annotations

import argparse
import json
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, runner_fingerprint
from repro import telemetry as tm
from repro.core.faults import FaultPlan
from repro.core.gadget import (GadgetConfig, TrainState, gadget_train,
                               gadget_train_reference, gadget_train_stream)
from repro.data.svm_datasets import make_dataset, partition

DROP_RATES = (0.1, 0.2, 0.4)
DEGRADE_BUDGET = 0.02  # accuracy points drop 0.2 (link) may cost vs clean


def _accuracy(w, X, y) -> float:
    return float(np.mean(np.sign(np.asarray(X) @ np.asarray(w)) == np.asarray(y)))


def _spread(res) -> float:
    """Max per-node distance from the consensus — the disagreement the fault
    regime leaves behind (relative L2)."""
    W = np.asarray(res.W, np.float64)
    w = np.asarray(res.w_consensus, np.float64)
    num = np.sqrt(((W - w) ** 2).sum(axis=1)).max()
    return float(num / (np.linalg.norm(w) + 1e-30))


def _point(tag, res, ds, seconds) -> dict:
    acc = _accuracy(res.w_consensus, ds.X_test, ds.y_test)
    tr = res.telemetry  # flight recorder: mass/disagreement/drops per iter
    mass_min = float(np.min(tr.mass_min)) if tr.count else 1.0
    emit(f"faults/{tag}", seconds * 1e6,
         f"acc={acc:.3f};mass_min={mass_min:.4f};spread={_spread(res):.3g}")
    return {
        "accuracy": acc,
        "objective": float(res.objective_trace[-1]),
        "mass_min": mass_min,
        "consensus_spread": _spread(res),
        "disagreement": float(tr.final_disagreement),
        "fault_drops": int(np.sum(tr.drops)),
        "iters": int(res.iters),
        "seconds": seconds,
    }


def run(quick: bool = False, scale: float | None = None, n_nodes: int = 8,
        max_iters: int | None = None, json_path: str | None = None) -> dict:
    if scale is None:
        scale = 0.15 if quick else 0.6
    if max_iters is None:
        max_iters = 80 if quick else 300

    t0 = time.time()
    tm.reset()  # the JSON's telemetry section covers this sweep only
    ds = make_dataset("reuters", scale=scale, seed=0)
    X_parts, y_parts, n_counts = partition(ds.X_train, ds.y_train, n_nodes,
                                           seed=0)
    X_parts, y_parts = jnp.asarray(X_parts), jnp.asarray(y_parts)
    base = GadgetConfig(lam=ds.lam, batch_size=4, gossip_rounds=2,
                        topology="exponential", max_iters=max_iters,
                        check_every=max(1, max_iters // 8), epsilon=0.0)

    ring = tm.TrainTelemetry(every=1, slots=max_iters)  # never wraps

    def train(faults=None):
        cfg = base._replace(faults=faults)
        t = time.time()
        res = gadget_train(X_parts, y_parts, cfg, n_counts=n_counts,
                           telemetry=ring)
        return cfg, res, time.time() - t

    points: dict[str, dict] = {}

    _, clean, dt = train()
    points["clean"] = _point("clean", clean, ds, dt)
    assert clean.mass_trace.min() >= 1.0 - 1e-4, "clean run leaked mass"
    assert points["clean"]["mass_min"] >= 1.0 - 1e-4
    assert points["clean"]["fault_drops"] == 0, "clean run counted drops"

    for p in DROP_RATES:
        _, res, dt = train(FaultPlan(drop_prob=p, drop="link", seed=13))
        points[f"link_{p}"] = _point(f"link_{p}", res, ds, dt)
        assert res.mass_trace.min() >= 1.0 - 1e-4, (
            f"link mode must conserve mass, leaked at drop {p}: "
            f"{res.mass_trace.min()}")
        assert points[f"link_{p}"]["fault_drops"] > 0, (
            f"telemetry ring saw no drops at link drop {p}")

    # ---- message-mode leakage sweep: the gauge must track drop_prob
    leakage: dict[float, float] = {}
    for p in DROP_RATES:
        _, msg, dt = train(FaultPlan(drop_prob=p, drop="message", seed=13))
        pt = _point(f"message_{p}", msg, ds, dt)
        pt["leakage"] = leakage[p] = 1.0 - pt["mass_min"]
        points[f"message_{p}"] = pt
        # ring vs ε-check trace: two decimations of one mass series — the
        # ring (every iteration) can only see deeper minima
        assert pt["mass_min"] <= float(msg.mass_trace.min()) + 1e-6
    assert points["message_0.2"]["mass_min"] < 0.999, (
        "message mode at drop 0.2 should measurably leak mass")
    leak_seq = [leakage[p] for p in DROP_RATES]
    assert leak_seq == sorted(leak_seq) and leak_seq[0] < leak_seq[-1], (
        f"mass leakage should grow with drop_prob, got {leakage}")
    drop_seq = [points[f"message_{p}"]["fault_drops"] for p in DROP_RATES]
    assert drop_seq == sorted(drop_seq) and drop_seq[0] < drop_seq[-1], (
        f"fault-drop counts should grow with drop_prob, got {drop_seq}")

    for n_dead in (1, 2):
        dead = tuple(range(n_dead))
        _, res, dt = train(FaultPlan(drop_prob=0.1, drop="link",
                                     dead_nodes=dead, seed=13))
        points[f"dead_{n_dead}"] = _point(f"dead_{n_dead}", res, ds, dt)
        # crashed nodes stay bit-frozen at their (zero) init
        W = np.asarray(res.W)
        assert all(np.abs(W[i]).max() == 0.0 for i in dead)

    # ---- parity oracle: fused faulty path vs host-loop reference
    cfg02 = base._replace(faults=FaultPlan(drop_prob=0.2, drop="link",
                                           seed=13))
    t = time.time()
    ref = gadget_train_reference(X_parts, y_parts, cfg02, n_counts=n_counts)
    ref_dt = time.time() - t
    dev02 = gadget_train(X_parts, y_parts, cfg02, n_counts=n_counts)
    parity = float(jnp.max(jnp.abs(dev02.w_consensus - ref.w_consensus)))
    assert parity <= 1e-5, f"fused/reference parity broke under faults: {parity}"
    emit("faults/parity", ref_dt * 1e6, f"max_abs_diff={parity:.3g}")

    # ---- kill-and-resume: bit-identical under faults
    seg_iters = max(1, max_iters // 2)
    full = list(gadget_train_stream(X_parts, y_parts, cfg02,
                                    segment_iters=seg_iters,
                                    n_counts=n_counts))
    first = next(iter(gadget_train_stream(X_parts, y_parts, cfg02,
                                          segment_iters=seg_iters,
                                          n_counts=n_counts)))
    ts = TrainState(iteration=first.iteration, W=first.W, W_sum=first.W_sum)
    resumed = list(gadget_train_stream(X_parts, y_parts, cfg02,
                                       segment_iters=seg_iters,
                                       n_counts=n_counts, resume=ts))
    resume_ok = bool(jnp.all(resumed[-1].W == full[-1].W)) and np.array_equal(
        np.asarray(resumed[-1].w_consensus), np.asarray(full[-1].w_consensus))
    assert resume_ok, "kill-and-resume trajectory diverged under faults"
    emit("faults/resume", 0.0, "bit_identical=1")

    # ---- graceful degradation: the headline number
    degrade = points["clean"]["accuracy"] - points["link_0.2"]["accuracy"]
    assert degrade <= DEGRADE_BUDGET, (
        f"drop 0.2 (link) cost {degrade:.3f} accuracy points "
        f"(budget {DEGRADE_BUDGET}) — degradation is not graceful")
    emit("faults/degradation", 0.0,
         f"clean={points['clean']['accuracy']:.3f}"
         f";link_0.2={points['link_0.2']['accuracy']:.3f};delta={degrade:.3f}")

    out = {
        "quick": quick,
        "scale": scale,
        "runner": runner_fingerprint(),
        "model": {"d": ds.d, "n_nodes": n_nodes, "max_iters": max_iters},
        "points": points,
        "asserts": {
            "faulty_parity_max_abs_diff": parity,
            "parity_ok": int(parity <= 1e-5),
            "link_mass_conserved": 1,
            "message_mass_leaks": int(points["message_0.2"]["mass_min"] < 0.999),
            "leakage_monotone_in_drop_prob": 1,
            "drop_counts_monotone_in_drop_prob": 1,
            "resume_bit_identical": int(resume_ok),
            "accuracy_degradation_link_0.2": degrade,
            "degradation_within_budget": int(degrade <= DEGRADE_BUDGET),
        },
        "telemetry": tm.default_registry().values(),
        "total": {"seconds": time.time() - t0},
    }
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(out, fh, indent=2)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke scale (tiny row count, same d/sparsity)")
    ap.add_argument("--scale", type=float, default=None,
                    help="Reuters row-count scale")
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--iters", dest="max_iters", type=int, default=None)
    ap.add_argument("--json", dest="json_path", default=None,
                    help="write results as JSON (CI uploads this as an artifact)")
    args = ap.parse_args()
    run(quick=args.quick, scale=args.scale, n_nodes=args.nodes,
        max_iters=args.max_iters, json_path=args.json_path)
