"""Paper §5 future work: impact of the gossip topology on convergence.

For a fixed budget of GADGET iterations, sweep the four topologies and
report final accuracy, consensus spread, and the spectral mixing-time bound
— the empirical counterpart of tau_mix in the paper's O(tau_mix log 1/γ)
Push-Sum analysis.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_dataset, emit
from repro.core import svm_objective as obj
from repro.core import topology as topo
from repro.core.gadget import GadgetConfig, gadget_train
from repro.data.svm_datasets import partition


def run(dataset="usps", n_iters=900, n_nodes=10, verbose=True):
    ds = bench_dataset(dataset)
    Xte, yte = jnp.asarray(ds.X_test), jnp.asarray(ds.y_test)
    Xp, yp, nc = partition(ds.X_train, ds.y_train, n_nodes)
    Xpj, ypj = jnp.asarray(Xp), jnp.asarray(yp)
    rows = []
    for topology in ("complete", "exponential", "random", "ring"):
        res = gadget_train(Xpj, ypj, n_counts=nc, cfg=GadgetConfig(
            lam=ds.lam, batch_size=8, gossip_rounds=2, topology=topology,
            max_iters=n_iters, check_every=300, epsilon=0.0))
        acc = float(obj.accuracy(res.w_consensus, Xte, yte))
        W = np.asarray(res.W)
        spread = float(np.max(np.linalg.norm(W - W.mean(0), axis=1))
                       / (np.linalg.norm(W.mean(0)) + 1e-9))
        tau = topo.mixing_time_bound(topo.build_matrix(
            topology, n_nodes, t=0,
            rng=np.random.default_rng(0) if topology == "random" else None))
        rows.append({"topology": topology, "acc": acc, "consensus_spread": spread,
                     "tau_mix_bound": tau})
        if verbose:
            emit(f"topology/{dataset}_{topology}", 0.0,
                 f"acc={acc:.3f};spread={spread:.4f};tau_mix={tau:.2f}")
    return rows


if __name__ == "__main__":
    run()
