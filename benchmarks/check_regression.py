"""Diff of fresh --quick benchmark JSON against a committed baseline.

CI runs the --quick benchmark smoke jobs, then compares each fresh JSON
against the baseline committed at the repo root (BENCH_kernels.json,
BENCH_gossip_device.json, BENCH_sparse.json, BENCH_serve.json). Wall-clock
leaves (``seconds``, anything under ``us_per_call``) that regress by more
than ``--threshold`` (default 1.2 = +20%) emit a GitHub annotation.
Non-timing leaves (transfer counts, launch counts, guard flags, consensus
diffs) are structural and only warn, so a divergence is visible in the job
log without making CI flaky.

Every benchmark JSON carries a ``runner`` fingerprint (platform, backend,
cpu count — benchmarks.common.runner_fingerprint). Wall-clock leaves are
compared **only like-vs-like**: when the fresh fingerprint differs from the
baseline's, timing comparisons are skipped with a note and only structural
leaves are diffed — a baseline recorded on one runner class can never
produce timing noise on another, so a matching-fingerprint regression is
meaningful signal.

``--fail-on-timing`` is the hard gate that signal buys (ROADMAP bench item):
a matching-fingerprint wall-clock regression beyond ``--fail-threshold``
(default 2.5x — run-to-run load noise on a shared box reaches ~2x even
like-for-like, so the failure bar sits above it while the warning bar stays
at 1.2x) becomes a ``::error::`` and a non-zero exit. CI passes it for the
--quick smoke shapes; on runners whose fingerprint differs from the
committed baseline the gate is inert by construction, so flipping it on
cannot make heterogeneous runners flaky.

Exit status is otherwise non-zero only when a file is missing/unreadable — a
broken baseline should fail loudly; a slow runner should not (unless the
gate is armed and the fingerprints match).

Usage:
    python benchmarks/check_regression.py --fresh out.json --baseline BENCH_x.json \
        [--fail-on-timing]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

WALLCLOCK_LEAVES = {"seconds"}
WALLCLOCK_PARENTS = {"us_per_call"}
# leaves that are noisy by construction (ratios of two wall-clocks, diffs of
# float accumulations that vary across BLAS builds, and the anytime bench's
# train-vs-serve race artifacts: which versions were caught live, how many
# passes/queries/swaps the race produced, per-point wall clocks) — reported
# but never compared against the threshold
SKIP_LEAVES = {"speedup", "fused_speedup_vs_pr1", "transfer_ratio",
               "consensus_max_abs_diff", "fused_vs_pr1_max_abs_diff",
               "prefetch_vs_sweep_max_abs_diff",
               "dense_vs_sparse_max_abs_diff",
               "quantized_vs_oracle_max_abs_diff", "quantized_drift_vs_f32",
               "quantized_label_agreement", "queries_per_sec",
               "wall_s", "served_accuracy", "version", "live",
               "n_queries_at_version", "n_swaps", "n_live_passes",
               "requests_total",
               # fault_bench: float-accumulation-sensitive measurements (the
               # bench's own asserts are the regression surface for these)
               "faulty_parity_max_abs_diff", "consensus_spread", "mass_min",
               "objective", "accuracy_degradation_link_0.2",
               "disagreement", "leakage",
               # telemetry_overhead_bench: ratios of two small wall-clocks —
               # the bench's own <= 5% assert is the gate, never the diff
               "overhead_ratio", "overhead_ratio_sum",
               # overload_bench: capacity is re-measured per run and every
               # count downstream of it (offered traffic, admission-policy
               # outcomes, ladder excursions) scales with it — the bench's
               # own bounded/reconciliation asserts are the gate; the
               # goodput/shed-rate *rates* stay structural on purpose
               "capacity_qps", "offered_qps", "offered", "max_pending",
               "timeout_ms", "queue_peak", "max_rung", "delivered", "shed",
               "deadline_missed", "truncated", "submitted",
               # observatory_bench: float decode error vs a host reference
               # (BLAS-build sensitive; the bench's own <=1e-5 assert is
               # the gate)
               "decode_max_err"}
# whole subtrees that are observability output, not a regression surface:
# the flight-recorder snapshot's counter values scale with how much traffic
# the run happened to push (live-pass races, rep counts), so leaves under
# these keys are reported in the JSON but never diffed
# ("depth_quartiles": overload_bench's queue-growth evidence — asserted
# monotone by the bench itself, the raw means are load-noise;
# "per_node"/"lineage_detail": observatory_bench's per-node health table
# and per-version chain dump — diagnostics the bench's asserts already
# gate, with per-node floats that vary across BLAS builds. The lineage and
# fate *counts* outside these subtrees stay structural on purpose.)
SKIP_PARENTS = {"telemetry", "depth_quartiles", "per_node", "lineage_detail"}
# the fingerprint subtree identifies the runner; it is compared as a whole,
# never leaf-by-leaf (a different cpu_count is not a "structural change")
RUNNER_KEY = "runner"


def fingerprint_slug(fp: dict) -> str:
    """Filesystem-safe runner-class identity derived from a benchmark JSON's
    ``runner`` fingerprint — the naming key for per-runner-class baselines in
    ``benchmarks/baselines/`` (``<BENCH_stem>.<slug>.json``). Every field of
    the fingerprint participates, so a slug match implies the full
    fingerprint matches and the wall-clock gate arms."""
    keys = ("os", "machine", "python", "backend", "pallas_interpret",
            "cpu_count")
    return "-".join(str(fp.get(k, "unknown")) for k in keys).replace("/", "_")


def resolve_baseline(baseline: str, baseline_dir: str | None,
                     fresh_fp: dict | None) -> tuple[str, bool]:
    """Pick the baseline file to diff against: a fingerprint-matching
    per-runner-class baseline from ``baseline_dir`` when one exists (the
    wall-clock gate arms by construction — same slug ⇒ same fingerprint),
    else the repo-root baseline (timing comparison inert unless the root
    baseline happens to fingerprint-match). Returns ``(path, matched)``."""
    if baseline_dir and fresh_fp:
        stem = os.path.basename(baseline)
        if stem.endswith(".json"):
            stem = stem[:-5]
        cand = os.path.join(baseline_dir,
                            f"{stem}.{fingerprint_slug(fresh_fp)}.json")
        if os.path.isfile(cand):
            return cand, True
    return baseline, False


def _leaves(obj, path=()):
    if isinstance(obj, dict):
        for k, v in obj.items():
            yield from _leaves(v, path + (str(k),))
    elif isinstance(obj, list):
        # index-keyed, so list-valued structural leaves (bucket ladders,
        # per-bucket caps) participate in the diff like any other leaf
        for i, v in enumerate(obj):
            yield from _leaves(v, path + (str(i),))
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        yield path, float(obj)


def compare(fresh: dict, baseline: dict, threshold: float
            ) -> tuple[list[str], list[tuple[str, float]]]:
    """Return ``(structural_warnings, timing_regressions)`` for every
    regressed/diverged leaf; timing entries are ``(message, ratio)`` so the
    caller can grade them against the warn vs fail bars. Wall-clock leaves
    are compared only when both fingerprints exist and match (timing list is
    empty otherwise)."""
    warnings, timing = [], []
    fresh_fp = fresh.get(RUNNER_KEY)
    base_fp = baseline.get(RUNNER_KEY)
    like_for_like = fresh_fp is not None and fresh_fp == base_fp
    if not like_for_like:
        # ::notice:: surfaces in the CI annotations: the timing comparison
        # (and therefore the --fail-on-timing gate) is inert on runner
        # classes the baseline wasn't recorded on — structural leaves still
        # compare.
        print(f"::notice::check_regression: runner fingerprints differ "
              f"(fresh={fresh_fp}, baseline={base_fp}) — "
              f"skipping wall-clock comparison, structural leaves only")
    fresh_map = dict(_leaves(fresh))
    for path, base_val in _leaves(baseline):
        name = ".".join(path)
        leaf = path[-1]
        if leaf in SKIP_LEAVES or path[0] == RUNNER_KEY \
                or set(path[:-1]) & SKIP_PARENTS:
            continue
        is_time = leaf in WALLCLOCK_LEAVES or bool(set(path) & WALLCLOCK_PARENTS)
        if path not in fresh_map:
            warnings.append(f"{name}: present in baseline but missing from fresh run")
            continue
        new_val = fresh_map[path]
        if is_time:
            if like_for_like and base_val > 0 and new_val > base_val * threshold:
                # sub-50ms baselines are scheduler noise, never hard-fail
                # material: report ratio 0 so the gate ignores them
                floor = 0.05 if leaf in WALLCLOCK_LEAVES else 5e4  # 50 ms
                timing.append((
                    f"{name}: wall-clock regression {base_val:.4g} -> {new_val:.4g} "
                    f"({new_val / base_val:.2f}x, threshold {threshold:.2f}x)",
                    new_val / base_val if base_val >= floor else 0.0))
        elif new_val != base_val:
            warnings.append(f"{name}: structural change {base_val:.6g} -> {new_val:.6g}")
    return warnings, timing


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fresh", required=True, help="JSON emitted by this run")
    ap.add_argument("--baseline", required=True, help="committed BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=1.2,
                    help="wall-clock ratio above which to warn (default 1.2)")
    ap.add_argument("--fail-on-timing", action="store_true",
                    help="exit non-zero on matching-fingerprint wall-clock "
                         "regressions beyond --fail-threshold (hard gate; "
                         "inert across runner classes)")
    ap.add_argument("--fail-threshold", type=float, default=2.5,
                    help="ratio above which --fail-on-timing fails (default "
                         "2.5; between --threshold and this, it still warns)")
    ap.add_argument("--baseline-dir", default=None,
                    help="directory of per-runner-class baselines "
                         "(<stem>.<fingerprint-slug>.json); when one matches "
                         "the fresh run's fingerprint it replaces --baseline "
                         "and the wall-clock gate arms by construction")
    args = ap.parse_args(argv)

    try:
        with open(args.fresh) as fh:
            fresh = json.load(fh)
        baseline_path, matched = resolve_baseline(
            args.baseline, args.baseline_dir, fresh.get(RUNNER_KEY))
        if matched:
            print(f"::notice::check_regression: fingerprint-matched baseline "
                  f"{baseline_path} — wall-clock gate armed")
        with open(baseline_path) as fh:
            baseline = json.load(fh)
    except (OSError, json.JSONDecodeError) as e:
        print(f"::error::check_regression: cannot load benchmark JSON: {e}")
        return 1

    warnings, timing = compare(fresh, baseline, args.threshold)
    for w in warnings:
        print(f"::warning::bench {baseline_path}: {w}")
    failures = 0
    for w, ratio in timing:
        hard = args.fail_on_timing and ratio > args.fail_threshold
        failures += hard
        print(f"::{'error' if hard else 'warning'}::bench {baseline_path}: {w}")
    if not warnings and not timing:
        print(f"check_regression: {args.fresh} within {args.threshold:.2f}x of {baseline_path}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
