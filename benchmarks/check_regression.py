"""Warn-only diff of fresh --quick benchmark JSON against a committed baseline.

CI runs the --quick benchmark smoke jobs, then compares each fresh JSON
against the baseline committed at the repo root (BENCH_kernels.json,
BENCH_gossip_device.json, BENCH_sparse.json). Wall-clock leaves (``seconds``,
anything under ``us_per_call``) that regress by more than ``--threshold``
(default 1.2 = +20%) emit a GitHub ``::warning::`` annotation — warn-only,
because hosted runners vary wildly; the committed baseline records the shape
of the numbers, not a hard floor. Non-timing leaves (transfer counts, launch
counts, guard flags, consensus diffs) are structural and still only warn, so
a divergence is visible in the job log without making CI flaky.

Every benchmark JSON carries a ``runner`` fingerprint (platform, backend,
cpu count — benchmarks.common.runner_fingerprint). Wall-clock leaves are
compared **only like-vs-like**: when the fresh fingerprint differs from the
baseline's, timing comparisons are skipped with a note and only structural
leaves are diffed. This is the first step toward the hard-gate goal — a
baseline recorded on one runner class can never produce timing noise on
another, so a matching-fingerprint regression is meaningful signal.

Exit status is non-zero only when a file is missing/unreadable — a broken
baseline should fail loudly; a slow runner should not.

Usage:
    python benchmarks/check_regression.py --fresh out.json --baseline BENCH_x.json
"""
from __future__ import annotations

import argparse
import json
import sys

WALLCLOCK_LEAVES = {"seconds"}
WALLCLOCK_PARENTS = {"us_per_call"}
# leaves that are noisy by construction (ratios of two wall-clocks, diffs of
# float accumulations) — reported but never compared against the threshold
SKIP_LEAVES = {"speedup", "fused_speedup_vs_pr1", "transfer_ratio",
               "consensus_max_abs_diff", "fused_vs_pr1_max_abs_diff",
               "prefetch_vs_sweep_max_abs_diff"}
# the fingerprint subtree identifies the runner; it is compared as a whole,
# never leaf-by-leaf (a different cpu_count is not a "structural change")
RUNNER_KEY = "runner"


def _leaves(obj, path=()):
    if isinstance(obj, dict):
        for k, v in obj.items():
            yield from _leaves(v, path + (str(k),))
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        yield path, float(obj)


def compare(fresh: dict, baseline: dict, threshold: float) -> list[str]:
    """Return warning strings for every regressed/diverged leaf. Wall-clock
    leaves are compared only when both fingerprints exist and match."""
    warnings = []
    fresh_fp = fresh.get(RUNNER_KEY)
    base_fp = baseline.get(RUNNER_KEY)
    like_for_like = fresh_fp is not None and fresh_fp == base_fp
    if not like_for_like:
        # ::notice:: surfaces in the CI annotations: the timing gate is
        # intentionally inert until baselines are recorded on this runner
        # class (ROADMAP hard-gate item) — structural leaves still compare.
        print(f"::notice::check_regression: runner fingerprints differ "
              f"(fresh={fresh_fp}, baseline={base_fp}) — "
              f"skipping wall-clock comparison, structural leaves only")
    fresh_map = dict(_leaves(fresh))
    for path, base_val in _leaves(baseline):
        name = ".".join(path)
        leaf = path[-1]
        if leaf in SKIP_LEAVES or path[0] == RUNNER_KEY:
            continue
        is_time = leaf in WALLCLOCK_LEAVES or bool(set(path) & WALLCLOCK_PARENTS)
        if path not in fresh_map:
            warnings.append(f"{name}: present in baseline but missing from fresh run")
            continue
        new_val = fresh_map[path]
        if is_time:
            if like_for_like and base_val > 0 and new_val > base_val * threshold:
                warnings.append(
                    f"{name}: wall-clock regression {base_val:.4g} -> {new_val:.4g} "
                    f"({new_val / base_val:.2f}x, threshold {threshold:.2f}x)")
        elif new_val != base_val:
            warnings.append(f"{name}: structural change {base_val:.6g} -> {new_val:.6g}")
    return warnings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fresh", required=True, help="JSON emitted by this run")
    ap.add_argument("--baseline", required=True, help="committed BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=1.2,
                    help="wall-clock ratio above which to warn (default 1.2)")
    args = ap.parse_args(argv)

    try:
        with open(args.fresh) as fh:
            fresh = json.load(fh)
        with open(args.baseline) as fh:
            baseline = json.load(fh)
    except (OSError, json.JSONDecodeError) as e:
        print(f"::error::check_regression: cannot load benchmark JSON: {e}")
        return 1

    warnings = compare(fresh, baseline, args.threshold)
    for w in warnings:
        print(f"::warning::bench {args.baseline}: {w}")
    if not warnings:
        print(f"check_regression: {args.fresh} within {args.threshold:.2f}x of {args.baseline}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
