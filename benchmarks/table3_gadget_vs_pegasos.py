"""Paper Table 3: GADGET SVM (k=10 nodes, random-neighbor gossip) vs
centralized Pegasos — accuracy + model-construction time per dataset.

Datasets are the synthetic paper-signature versions (DESIGN.md §1); the
claim validated is STRUCTURAL: |acc(GADGET) - acc(Pegasos)| small, GADGET
time within a small factor of centralized.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_dataset, emit
from repro.configs.gadget_svm import PAPER_RUNS
from repro.core import svm_objective as obj
from repro.core.gadget import gadget_train
from repro.core.pegasos import pegasos_train
from repro.data.svm_datasets import partition


def run(datasets=None, n_iters=1200, verbose=True):
    rows = []
    for name in (datasets or PAPER_RUNS):
        runcfg = PAPER_RUNS[name]
        ds = bench_dataset(name)
        Xtr, ytr = jnp.asarray(ds.X_train), jnp.asarray(ds.y_train)
        Xte, yte = jnp.asarray(ds.X_test), jnp.asarray(ds.y_test)

        t0 = time.time()
        cen = pegasos_train(Xtr, ytr, lam=ds.lam, n_iters=n_iters, batch_size=8)
        jnp.asarray(cen.w).block_until_ready()
        t_cen = time.time() - t0
        acc_cen = float(obj.accuracy(cen.w, Xte, yte))

        Xp, yp, nc = partition(ds.X_train, ds.y_train, runcfg.n_nodes)
        gcfg = runcfg.gadget._replace(max_iters=n_iters, batch_size=8,
                                      check_every=max(200, n_iters // 4))
        t0 = time.time()
        res = gadget_train(jnp.asarray(Xp), jnp.asarray(yp), gcfg, n_counts=nc)
        t_gad = time.time() - t0
        acc_gad = float(obj.accuracy(res.w_consensus, Xte, yte))
        # per-node accuracy spread (the paper reports node-averaged accuracy)
        accs = [float(obj.accuracy(res.W[i], Xte, yte)) for i in range(runcfg.n_nodes)]

        rows.append({
            "dataset": name, "acc_gadget": acc_gad, "acc_node_mean": float(np.mean(accs)),
            "acc_node_std": float(np.std(accs)), "acc_pegasos": acc_cen,
            "time_gadget_s": t_gad, "time_pegasos_s": t_cen,
            "eps_at_stop": res.epsilon, "iters": res.iters,
        })
        if verbose:
            emit(f"table3/{name}", t_gad * 1e6 / max(res.iters, 1),
                 f"acc_gadget={acc_gad:.3f};acc_nodes={np.mean(accs):.3f}+-{np.std(accs):.3f};"
                 f"acc_pegasos={acc_cen:.3f};t_gadget={t_gad:.2f}s;t_pegasos={t_cen:.2f}s")
    return rows


if __name__ == "__main__":
    run()
