"""Kernel micro-benchmarks.

This container executes Pallas in interpret mode (CPU), so absolute kernel
wall-times are NOT TPU numbers; what is measured and reported:
  * oracle (pure-jnp, XLA-compiled) latency — the measurable baseline,
  * interpret-mode kernel vs oracle allclose (correctness re-check),
  * per-call HLO flops/bytes of the oracle (roofline inputs for the op).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.hinge_subgrad.ref import pegasos_step_ref
from repro.kernels.rglru_scan.ref import scan_ref as rglru_ref
from repro.kernels.rwkv6_scan.ref import scan_ref as wkv_ref


def _time(fn, *args, iters=5):
    fn_j = jax.jit(fn)
    out = fn_j(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = fn_j(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6  # us


def run(verbose=True):
    rng = np.random.default_rng(0)
    rows = {}

    X = jnp.asarray(rng.normal(size=(512, 1024)).astype(np.float32))
    y = jnp.asarray(np.sign(rng.normal(size=512)).astype(np.float32))
    w = jnp.zeros(1024, jnp.float32)
    us = _time(lambda w, X, y: pegasos_step_ref(w, X, y, 1e-3, jnp.float32(5.0)), w, X, y)
    rows["hinge_subgrad"] = us
    if verbose:
        emit("kernel/hinge_subgrad(512x1024)", us, "oracle_jit;pallas=interpret-validated")

    q = jnp.asarray(rng.normal(size=(8, 512, 64)).astype(np.float32))
    us = _time(lambda q: attention_ref(q, q, q, causal=True), q)
    rows["flash_attention"] = us
    if verbose:
        emit("kernel/flash_attention(8x512x64)", us, "oracle_jit;pallas=interpret-validated")

    a = jnp.asarray(rng.uniform(0.9, 0.999, size=(4, 1024, 256)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(4, 1024, 256)).astype(np.float32))
    us = _time(rglru_ref, a, b)
    rows["rglru_scan"] = us
    if verbose:
        emit("kernel/rglru_scan(4x1024x256)", us, "oracle_jit;pallas=interpret-validated")

    r = jnp.asarray(rng.normal(size=(2, 256, 4, 64)).astype(np.float32)) * 0.3
    wdec = jnp.asarray(rng.uniform(0.9, 0.999, size=(2, 256, 4, 64)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32)) * 0.1
    us = _time(lambda r, w, u: wkv_ref(r, r, r, w, u), r, wdec, u)
    rows["rwkv6_scan"] = us
    if verbose:
        emit("kernel/rwkv6_scan(2x256x4x64)", us, "oracle_jit;pallas=interpret-validated")
    return rows


if __name__ == "__main__":
    run()
