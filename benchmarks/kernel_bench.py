"""Kernel micro-benchmarks.

This container executes Pallas in interpret mode (CPU), so absolute kernel
wall-times are NOT TPU numbers; what is measured and reported:
  * oracle (pure-jnp, XLA-compiled) latency — the measurable baseline,
  * interpret-mode kernel vs oracle allclose (correctness re-check),
  * per-call HLO flops/bytes of the oracle (roofline inputs for the op),
  * a registry-backed ``telemetry`` section: the eager interpret-mode kernel
    calls self-record launch/bytes/flops series into the flight recorder
    (``kernel.launches{kernel=...}`` etc.), snapshotted into the JSON.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, runner_fingerprint
from repro import telemetry as tm
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.hinge_subgrad import ops as hinge_ops
from repro.kernels.hinge_subgrad.ref import (ell_fleet_half_step_ref,
                                             fleet_half_step_ref, pegasos_step_ref)
from repro.kernels.rglru_scan.ref import scan_ref as rglru_ref
from repro.kernels.rwkv6_scan.ref import scan_ref as wkv_ref
from repro.sparse.formats import minibatch_block_bound


def _time(fn, *args, iters=5):
    """Mean latency in us of the jitted fn, after one warm-up call."""
    fn_j = jax.jit(fn)
    out = fn_j(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = fn_j(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6  # us


def run(verbose=True, quick=False, json_path=None):
    rng = np.random.default_rng(0)
    tm.reset()  # the JSON's telemetry section covers this run only
    rows = {}
    # --quick shrinks every shape ~4x so the CI smoke job finishes in seconds
    # while still exercising the same jitted code paths.
    s = 4 if quick else 1

    X = jnp.asarray(rng.normal(size=(512 // s, 1024 // s)).astype(np.float32))
    y = jnp.asarray(np.sign(rng.normal(size=512 // s)).astype(np.float32))
    w = jnp.zeros(1024 // s, jnp.float32)
    us = _time(lambda w, X, y: pegasos_step_ref(w, X, y, 1e-3, jnp.float32(5.0)), w, X, y)
    rows["hinge_subgrad"] = us
    if verbose:
        emit(f"kernel/hinge_subgrad({512 // s}x{1024 // s})", us,
             "oracle_jit;pallas=interpret-validated")

    # fused fleet half-step: m-node GADGET iteration body in one launch.
    # Oracle-jit timing + an actual interpret-mode kernel allclose re-check.
    m_nodes, Bf, df = 8, 64 // s, 1024 // s
    Xf = jnp.asarray(rng.normal(size=(m_nodes, Bf, df)).astype(np.float32))
    yf = jnp.asarray(np.sign(rng.normal(size=(m_nodes, Bf))).astype(np.float32))
    Wf = jnp.asarray(rng.normal(size=(m_nodes, df)).astype(np.float32) * 0.1)
    tS = jnp.float32(5.0)
    us = _time(lambda W, X, y: fleet_half_step_ref(W, X, y, 1e-3, tS), Wf, Xf, yf)
    rows["fleet_half_step"] = us
    got = hinge_ops.fleet_half_step(Wf, Xf, yf, lam=1e-3, t=tS, interpret=True)
    want = fleet_half_step_ref(Wf, Xf, yf, 1e-3, tS)
    ok = bool(jnp.max(jnp.abs(got - want)) < 2e-5)
    if not ok:
        raise AssertionError("fleet_half_step interpret kernel diverged from oracle")
    if verbose:
        emit(f"kernel/fleet_half_step({m_nodes}x{Bf}x{df})", us,
             "oracle_jit;pallas=interpret-validated")

    # sparse (padded-ELL) fleet half-step at reuters-like density: gather-dot
    # margins + scatter-add grad, same m-node one-iteration body as above but
    # touching k instead of d feature entries per row.
    kS = max(8, df // 64)
    colsS = jnp.asarray(rng.integers(0, df, size=(m_nodes, Bf, kS)).astype(np.int32))
    valsS = jnp.asarray(np.abs(rng.normal(size=(m_nodes, Bf, kS))).astype(np.float32))
    us = _time(lambda W, c, v, y: ell_fleet_half_step_ref(W, c, v, y, 1e-3, tS),
               Wf, colsS, valsS, yf)
    rows["ell_fleet_half_step"] = us
    got = hinge_ops.ell_fleet_half_step(Wf, colsS, valsS, yf, lam=1e-3, t=tS,
                                        interpret=True, schedule="sweep")
    want = ell_fleet_half_step_ref(Wf, colsS, valsS, yf, 1e-3, tS)
    if not bool(jnp.max(jnp.abs(got - want)) < 2e-5):
        raise AssertionError("ell_fleet_half_step interpret kernel diverged from oracle")
    if verbose:
        emit(f"kernel/ell_fleet_half_step({m_nodes}x{Bf}x{df}@k={kS})", us,
             "oracle_jit;pallas=interpret-validated")

    # touched-block (scalar-prefetch) schedule: same one-iteration body over
    # block-localized planes (each node's entries inside a narrow column
    # band, the frequency-remapped text shape) — oracle-jit timing plus an
    # interpret-mode allclose of the prefetch kernels against both oracles.
    base = (np.arange(m_nodes) * 256) % max(1, df - 256)
    colsL = jnp.asarray((base[:, None, None]
                         + rng.integers(0, 256, size=(m_nodes, Bf, kS))).astype(np.int32))
    bound = minibatch_block_bound(np.asarray(colsL), np.asarray(valsS), Bf, d=df)
    us = _time(lambda W, c, v, y: ell_fleet_half_step_ref(W, c, v, y, 1e-3, tS),
               Wf, colsL, valsS, yf)
    rows["ell_fleet_half_step_prefetch"] = us
    got = hinge_ops.ell_fleet_half_step(Wf, colsL, valsS, yf, lam=1e-3, t=tS,
                                        interpret=True, schedule="prefetch",
                                        n_blocks_max=bound)
    want = ell_fleet_half_step_ref(Wf, colsL, valsS, yf, 1e-3, tS)
    sweep = hinge_ops.ell_fleet_half_step(Wf, colsL, valsS, yf, lam=1e-3, t=tS,
                                          interpret=True, schedule="sweep")
    if not bool(jnp.max(jnp.abs(got - want)) < 2e-5):
        raise AssertionError("prefetch kernels diverged from the jnp oracle")
    if not bool(jnp.max(jnp.abs(got - sweep)) < 2e-5):
        raise AssertionError("prefetch kernels diverged from the sweep kernels")
    if verbose:
        emit(f"kernel/ell_fleet_half_step_prefetch({m_nodes}x{Bf}x{df}@k={kS})",
             us, f"oracle_jit;pallas=interpret-validated;n_blocks_max={bound}")

    q = jnp.asarray(rng.normal(size=(8 // min(s, 2), 512 // s, 64)).astype(np.float32))
    us = _time(lambda q: attention_ref(q, q, q, causal=True), q)
    rows["flash_attention"] = us
    if verbose:
        emit(f"kernel/flash_attention({q.shape[0]}x{q.shape[1]}x64)", us,
             "oracle_jit;pallas=interpret-validated")

    a = jnp.asarray(rng.uniform(0.9, 0.999, size=(4, 1024 // s, 256 // s)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(4, 1024 // s, 256 // s)).astype(np.float32))
    us = _time(rglru_ref, a, b)
    rows["rglru_scan"] = us
    if verbose:
        emit(f"kernel/rglru_scan(4x{1024 // s}x{256 // s})", us,
             "oracle_jit;pallas=interpret-validated")

    r = jnp.asarray(rng.normal(size=(2, 256 // s, 4, 64)).astype(np.float32)) * 0.3
    wdec = jnp.asarray(rng.uniform(0.9, 0.999, size=(2, 256 // s, 4, 64)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32)) * 0.1
    us = _time(lambda r, w, u: wkv_ref(r, r, r, w, u), r, wdec, u)
    rows["rwkv6_scan"] = us
    if verbose:
        emit(f"kernel/rwkv6_scan(2x{256 // s}x4x64)", us,
             "oracle_jit;pallas=interpret-validated")

    if json_path:
        with open(json_path, "w") as fh:
            json.dump({"quick": quick, "runner": runner_fingerprint(),
                       "us_per_call": rows,
                       "telemetry": tm.default_registry().values()},
                      fh, indent=2)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="CI smoke scale (~4x smaller shapes)")
    ap.add_argument("--json", dest="json_path", default=None,
                    help="write results as JSON (CI uploads this as an artifact)")
    args = ap.parse_args()
    run(quick=args.quick, json_path=args.json_path)
