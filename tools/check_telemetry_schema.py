"""Validate flight-recorder JSONL streams against the record schema.

Every line of a telemetry JSONL file (``telemetry.export.dump_jsonl``
snapshots or a live ``JsonlSink`` event stream) must be a JSON object with
``ts`` (number), ``kind`` (counter | gauge | histogram | span | event),
``name`` (non-empty string) and ``labels`` (string-keyed object), plus the
kind-specific payload:

* counter / gauge — numeric ``value`` (counters additionally >= 0);
* histogram — ``count`` (int >= 0), ``sum``, ``min``/``max`` (numeric or
  null when empty), and ``buckets``: a list of ``[le, n]`` pairs with
  strictly increasing numeric ``le`` (the overflow bucket's ``le`` is null
  and must come last), bucket counts summing to ``count``;
* span — numeric ``seconds`` >= 0 (``fields`` optional).

Traced spans/events (``repro.telemetry.trace``) additionally carry
``trace_id`` / ``span_id`` / ``parent_id`` at the top level: when any is
present, ``trace_id`` and ``span_id`` must both be non-empty strings and
``parent_id`` null or a non-empty string. Across a whole file, every
``parent_id`` must appear as some record's ``span_id`` *within the same
trace_id* — no orphan parents (the lineage chain writers emit parent and
child onto one sink, so a dangling parent means a dropped or cross-wired
record).

The schema is the compatibility contract between writers (the registry
exporters) and readers (``python -m repro.telemetry.dump``, dashboards);
CI runs this over a freshly dumped stream plus ``--selftest``, and the
bench-smoke job runs it over a real traced train→publish→swap→serve run.

Usage:
    PYTHONPATH=src python tools/check_telemetry_schema.py [--selftest] [files...]
"""
from __future__ import annotations

import json
import sys

KINDS = {"counter", "gauge", "histogram", "span", "event"}


def _is_num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def validate_record(rec) -> list[str]:
    """Schema violations in one parsed record (empty list = valid)."""
    errs = []
    if not isinstance(rec, dict):
        return [f"record is {type(rec).__name__}, not an object"]
    if not _is_num(rec.get("ts")):
        errs.append("missing/non-numeric 'ts'")
    kind = rec.get("kind")
    if kind not in KINDS:
        errs.append(f"bad 'kind' {kind!r} (expected one of {sorted(KINDS)})")
    name = rec.get("name")
    if not isinstance(name, str) or not name:
        errs.append("missing/empty 'name'")
    labels = rec.get("labels")
    if not isinstance(labels, dict) or any(
            not isinstance(k, str) for k in labels):
        errs.append("'labels' must be a string-keyed object")
    if kind in ("counter", "gauge"):
        if not _is_num(rec.get("value")):
            errs.append(f"{kind} record needs numeric 'value'")
        elif kind == "counter" and rec["value"] < 0:
            errs.append(f"counter value {rec['value']} < 0")
    elif kind == "histogram":
        count = rec.get("count")
        if not isinstance(count, int) or count < 0:
            errs.append("histogram needs int 'count' >= 0")
        if not _is_num(rec.get("sum")):
            errs.append("histogram needs numeric 'sum'")
        for bound in ("min", "max"):
            v = rec.get(bound, "absent")
            if v is not None and not _is_num(v):
                errs.append(f"histogram '{bound}' must be numeric or null")
        buckets = rec.get("buckets")
        if not isinstance(buckets, list):
            errs.append("histogram needs 'buckets' list")
        else:
            prev_le = None
            total = 0
            for i, pair in enumerate(buckets):
                if (not isinstance(pair, list) or len(pair) != 2
                        or (pair[0] is not None and not _is_num(pair[0]))
                        or not isinstance(pair[1], int) or pair[1] < 0):
                    errs.append(f"bucket {i} must be [le|null, count>=0]")
                    continue
                le, n = pair
                total += n
                if le is None:
                    if i != len(buckets) - 1:
                        errs.append("null-le (overflow) bucket must be last")
                elif prev_le is not None and le <= prev_le:
                    errs.append(f"bucket edges not increasing at index {i}")
                if le is not None:
                    prev_le = le
            if isinstance(count, int) and total != count:
                errs.append(f"bucket counts sum to {total}, 'count' is {count}")
    elif kind == "span":
        s = rec.get("seconds")
        if not _is_num(s) or s < 0:
            errs.append("span record needs numeric 'seconds' >= 0")
    errs.extend(_trace_errors(rec))
    return errs


def _trace_errors(rec: dict) -> list[str]:
    """Violations of the trace-id triplet on one record (empty when the
    record carries no trace ids at all)."""
    present = [k for k in ("trace_id", "span_id", "parent_id") if k in rec]
    if not present:
        return []
    errs = []
    for key in ("trace_id", "span_id"):
        v = rec.get(key)
        if not isinstance(v, str) or not v:
            errs.append(f"traced record needs non-empty string '{key}'")
    pid = rec.get("parent_id")
    if pid is not None and (not isinstance(pid, str) or not pid):
        errs.append("'parent_id' must be null or a non-empty string")
    return errs


def validate_trace_linkage(records) -> list[str]:
    """Cross-record trace checks over ``(lineno, record)`` pairs: every
    ``parent_id`` must appear as a ``span_id`` under the same ``trace_id``
    somewhere in the stream (no orphan parents)."""
    spans_by_trace: dict[str, set[str]] = {}
    for _, rec in records:
        tid, sid = rec.get("trace_id"), rec.get("span_id")
        if isinstance(tid, str) and isinstance(sid, str):
            spans_by_trace.setdefault(tid, set()).add(sid)
    errs = []
    for lineno, rec in records:
        tid, pid = rec.get("trace_id"), rec.get("parent_id")
        if not isinstance(tid, str) or not isinstance(pid, str):
            continue
        if pid not in spans_by_trace.get(tid, set()):
            errs.append(f"line {lineno}: parent_id {pid!r} never appears as "
                        f"a span_id in trace {tid!r} (orphan parent)")
    return errs


def validate_file(path: str) -> list[str]:
    """All violations in a JSONL file, each prefixed ``path:line`` —
    per-record schema plus the file-wide trace-linkage pass."""
    errs = []
    parsed: list[tuple[int, dict]] = []
    with open(path, encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                errs.append(f"{path}:{lineno}: not JSON ({e.msg})")
                continue
            errs.extend(f"{path}:{lineno}: {msg}"
                        for msg in validate_record(rec))
            if isinstance(rec, dict):
                parsed.append((lineno, rec))
    errs.extend(f"{path}: {msg}" for msg in validate_trace_linkage(parsed))
    return errs


def selftest() -> int:
    """Round-trip a live registry through dump_jsonl and validate it, then
    confirm the checker actually rejects malformed records."""
    import tempfile

    from repro.telemetry.export import dump_jsonl
    from repro.telemetry.registry import Registry

    reg = Registry()
    reg.counter("train.iterations").inc(40)
    reg.gauge("train.objective").set(1.5)
    h = reg.histogram("serve.latency_seconds", bucket="all")
    for v in (1e-4, 3e-3, 0.2, 50.0):
        h.observe(v)
    with reg.span("publish.seconds", iteration=40):
        pass
    with tempfile.NamedTemporaryFile("w", suffix=".jsonl",
                                     delete=False) as fh:
        path = fh.name
    dump_jsonl(reg, path, mode="w")
    errs = validate_file(path)
    if errs:
        print("selftest: valid dump rejected:", *errs, sep="\n  ")
        return 1
    bad = [
        {"kind": "counter", "name": "x", "labels": {}, "value": 1},  # no ts
        {"ts": 1.0, "kind": "nope", "name": "x", "labels": {}, "value": 1},
        {"ts": 1.0, "kind": "counter", "name": "x", "labels": {}, "value": -2},
        {"ts": 1.0, "kind": "histogram", "name": "x", "labels": {},
         "count": 3, "sum": 1.0, "min": 0.1, "max": 0.9,
         "buckets": [[0.5, 1], [0.25, 2]]},  # edges not increasing
        {"ts": 1.0, "kind": "span", "name": "x", "labels": {}, "seconds": -1},
    ]
    bad += [
        {"ts": 1.0, "kind": "span", "name": "x", "labels": {}, "seconds": 0.1,
         "trace_id": "", "span_id": "s1"},  # empty trace_id
        {"ts": 1.0, "kind": "event", "name": "x", "labels": {},
         "trace_id": "t1"},  # span_id missing when trace_id present
        {"ts": 1.0, "kind": "span", "name": "x", "labels": {}, "seconds": 0.1,
         "trace_id": "t1", "span_id": "s1", "parent_id": 7},  # non-str parent
    ]
    for rec in bad:
        if not validate_record(rec):
            print(f"selftest: malformed record accepted: {rec}")
            return 1
    # Trace round-trip through the real emitters, then linkage checks.
    from repro.telemetry import trace as tmtr
    from repro.telemetry.export import JsonlSink
    treg = Registry()
    with tempfile.NamedTemporaryFile("w", suffix=".jsonl",
                                     delete=False) as fh:
        tpath = fh.name
    treg.attach_sink(JsonlSink(tpath))
    root = tmtr.TraceContext.new()
    tmtr.emit_span(treg, "train.segment", root, 0.5, iteration=10)
    tmtr.emit_span(treg, "publish.seconds", root.child(), 0.01, iteration=10)
    treg.detach_sink()
    errs = validate_file(tpath)
    if errs:
        print("selftest: valid traced stream rejected:", *errs, sep="\n  ")
        return 1
    linked = [
        (1, {"ts": 1.0, "kind": "span", "name": "a", "labels": {},
             "seconds": 0.1, "trace_id": "t1", "span_id": "s1"}),
        (2, {"ts": 1.0, "kind": "span", "name": "b", "labels": {},
             "seconds": 0.1, "trace_id": "t1", "span_id": "s2",
             "parent_id": "s1"}),
    ]
    if validate_trace_linkage(linked):
        print("selftest: well-linked trace rejected")
        return 1
    orphan = linked + [
        (3, {"ts": 1.0, "kind": "span", "name": "c", "labels": {},
             "seconds": 0.1, "trace_id": "t1", "span_id": "s3",
             "parent_id": "nope"}),
        # same parent id exists, but in a *different* trace — still orphan
        (4, {"ts": 1.0, "kind": "span", "name": "d", "labels": {},
             "seconds": 0.1, "trace_id": "t2", "span_id": "s4",
             "parent_id": "s1"}),
    ]
    if len(validate_trace_linkage(orphan)) != 2:
        print("selftest: orphan parents not flagged")
        return 1
    print("check_telemetry_schema: selftest ok")
    return 0


def main(argv: list[str]) -> int:
    """CLI entry: validate files (and/or run ``--selftest``)."""
    args = list(argv)
    run_self = "--selftest" in args
    if run_self:
        args.remove("--selftest")
    if run_self and selftest() != 0:
        return 1
    total = 0
    for path in args:
        errs = validate_file(path)
        for e in errs:
            print(e)
        if not errs:
            print(f"OK    {path}")
        total += len(errs)
    if total:
        print(f"check_telemetry_schema: {total} violation(s)")
        return 1
    if not args and not run_self:
        print("usage: check_telemetry_schema.py [--selftest] [files...]")
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
