"""Validate flight-recorder JSONL streams against the record schema.

Every line of a telemetry JSONL file (``telemetry.export.dump_jsonl``
snapshots or a live ``JsonlSink`` event stream) must be a JSON object with
``ts`` (number), ``kind`` (counter | gauge | histogram | span | event),
``name`` (non-empty string) and ``labels`` (string-keyed object), plus the
kind-specific payload:

* counter / gauge — numeric ``value`` (counters additionally >= 0);
* histogram — ``count`` (int >= 0), ``sum``, ``min``/``max`` (numeric or
  null when empty), and ``buckets``: a list of ``[le, n]`` pairs with
  strictly increasing numeric ``le`` (the overflow bucket's ``le`` is null
  and must come last), bucket counts summing to ``count``;
* span — numeric ``seconds`` >= 0 (``fields`` optional).

The schema is the compatibility contract between writers (the registry
exporters) and readers (``python -m repro.telemetry.dump``, dashboards);
CI runs this over a freshly dumped stream plus ``--selftest``.

Usage:
    PYTHONPATH=src python tools/check_telemetry_schema.py [--selftest] [files...]
"""
from __future__ import annotations

import json
import sys

KINDS = {"counter", "gauge", "histogram", "span", "event"}


def _is_num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def validate_record(rec) -> list[str]:
    """Schema violations in one parsed record (empty list = valid)."""
    errs = []
    if not isinstance(rec, dict):
        return [f"record is {type(rec).__name__}, not an object"]
    if not _is_num(rec.get("ts")):
        errs.append("missing/non-numeric 'ts'")
    kind = rec.get("kind")
    if kind not in KINDS:
        errs.append(f"bad 'kind' {kind!r} (expected one of {sorted(KINDS)})")
    name = rec.get("name")
    if not isinstance(name, str) or not name:
        errs.append("missing/empty 'name'")
    labels = rec.get("labels")
    if not isinstance(labels, dict) or any(
            not isinstance(k, str) for k in labels):
        errs.append("'labels' must be a string-keyed object")
    if kind in ("counter", "gauge"):
        if not _is_num(rec.get("value")):
            errs.append(f"{kind} record needs numeric 'value'")
        elif kind == "counter" and rec["value"] < 0:
            errs.append(f"counter value {rec['value']} < 0")
    elif kind == "histogram":
        count = rec.get("count")
        if not isinstance(count, int) or count < 0:
            errs.append("histogram needs int 'count' >= 0")
        if not _is_num(rec.get("sum")):
            errs.append("histogram needs numeric 'sum'")
        for bound in ("min", "max"):
            v = rec.get(bound, "absent")
            if v is not None and not _is_num(v):
                errs.append(f"histogram '{bound}' must be numeric or null")
        buckets = rec.get("buckets")
        if not isinstance(buckets, list):
            errs.append("histogram needs 'buckets' list")
        else:
            prev_le = None
            total = 0
            for i, pair in enumerate(buckets):
                if (not isinstance(pair, list) or len(pair) != 2
                        or (pair[0] is not None and not _is_num(pair[0]))
                        or not isinstance(pair[1], int) or pair[1] < 0):
                    errs.append(f"bucket {i} must be [le|null, count>=0]")
                    continue
                le, n = pair
                total += n
                if le is None:
                    if i != len(buckets) - 1:
                        errs.append("null-le (overflow) bucket must be last")
                elif prev_le is not None and le <= prev_le:
                    errs.append(f"bucket edges not increasing at index {i}")
                if le is not None:
                    prev_le = le
            if isinstance(count, int) and total != count:
                errs.append(f"bucket counts sum to {total}, 'count' is {count}")
    elif kind == "span":
        s = rec.get("seconds")
        if not _is_num(s) or s < 0:
            errs.append("span record needs numeric 'seconds' >= 0")
    return errs


def validate_file(path: str) -> list[str]:
    """All violations in a JSONL file, each prefixed ``path:line``."""
    errs = []
    with open(path, encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                errs.append(f"{path}:{lineno}: not JSON ({e.msg})")
                continue
            errs.extend(f"{path}:{lineno}: {msg}"
                        for msg in validate_record(rec))
    return errs


def selftest() -> int:
    """Round-trip a live registry through dump_jsonl and validate it, then
    confirm the checker actually rejects malformed records."""
    import tempfile

    from repro.telemetry.export import dump_jsonl
    from repro.telemetry.registry import Registry

    reg = Registry()
    reg.counter("train.iterations").inc(40)
    reg.gauge("train.objective").set(1.5)
    h = reg.histogram("serve.latency_seconds", bucket="all")
    for v in (1e-4, 3e-3, 0.2, 50.0):
        h.observe(v)
    with reg.span("publish.seconds", iteration=40):
        pass
    with tempfile.NamedTemporaryFile("w", suffix=".jsonl",
                                     delete=False) as fh:
        path = fh.name
    dump_jsonl(reg, path, mode="w")
    errs = validate_file(path)
    if errs:
        print("selftest: valid dump rejected:", *errs, sep="\n  ")
        return 1
    bad = [
        {"kind": "counter", "name": "x", "labels": {}, "value": 1},  # no ts
        {"ts": 1.0, "kind": "nope", "name": "x", "labels": {}, "value": 1},
        {"ts": 1.0, "kind": "counter", "name": "x", "labels": {}, "value": -2},
        {"ts": 1.0, "kind": "histogram", "name": "x", "labels": {},
         "count": 3, "sum": 1.0, "min": 0.1, "max": 0.9,
         "buckets": [[0.5, 1], [0.25, 2]]},  # edges not increasing
        {"ts": 1.0, "kind": "span", "name": "x", "labels": {}, "seconds": -1},
    ]
    for rec in bad:
        if not validate_record(rec):
            print(f"selftest: malformed record accepted: {rec}")
            return 1
    print("check_telemetry_schema: selftest ok")
    return 0


def main(argv: list[str]) -> int:
    """CLI entry: validate files (and/or run ``--selftest``)."""
    args = list(argv)
    run_self = "--selftest" in args
    if run_self:
        args.remove("--selftest")
    if run_self and selftest() != 0:
        return 1
    total = 0
    for path in args:
        errs = validate_file(path)
        for e in errs:
            print(e)
        if not errs:
            print(f"OK    {path}")
        total += len(errs)
    if total:
        print(f"check_telemetry_schema: {total} violation(s)")
        return 1
    if not args and not run_self:
        print("usage: check_telemetry_schema.py [--selftest] [files...]")
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
