"""Execute the documented quickstarts so the docs cannot rot.

Extracts every fenced ```python block from README.md and
docs/ARCHITECTURE.md and exec's each one in a fresh namespace (CI runs this
in the test job with the package installed). Blocks tagged
```python no-run   are extracted but skipped — for illustrative fragments
that are not self-contained.

Usage:
    PYTHONPATH=src python tools/check_docs.py [files...]
"""
from __future__ import annotations

import re
import sys
import time

DOCS = ["README.md", "docs/ARCHITECTURE.md"]
FENCE = re.compile(r"^```python[ \t]*(?P<tag>no-run)?[ \t]*$")


def extract_blocks(path: str) -> list[tuple[int, str, bool]]:
    """Return (start_line, source, runnable) for every python fence in path."""
    blocks = []
    lines = open(path, encoding="utf-8").read().splitlines()
    i = 0
    while i < len(lines):
        m = FENCE.match(lines[i])
        if m:
            start = i + 1
            body = []
            i += 1
            while i < len(lines) and lines[i].rstrip() != "```":
                body.append(lines[i])
                i += 1
            blocks.append((start + 1, "\n".join(body), m.group("tag") is None))
        i += 1
    return blocks


def main(argv: list[str]) -> int:
    paths = argv or DOCS
    n_run = n_skip = 0
    failures = []
    for path in paths:
        for lineno, src, runnable in extract_blocks(path):
            label = f"{path}:{lineno}"
            if not runnable:
                print(f"SKIP  {label} (no-run)")
                n_skip += 1
                continue
            t0 = time.time()
            try:
                exec(compile(src, label, "exec"), {"__name__": "__docs__"})
            except Exception as e:  # noqa: BLE001 — report every doc failure
                failures.append(f"{label}: {type(e).__name__}: {e}")
                print(f"FAIL  {label}: {type(e).__name__}: {e}")
            else:
                print(f"OK    {label} ({time.time() - t0:.1f}s)")
                n_run += 1
    if failures:
        print(f"\ncheck_docs: {len(failures)} documented example(s) broken")
        return 1
    if n_run == 0:
        print("check_docs: no runnable python blocks found — docs drifted?")
        return 1
    print(f"check_docs: {n_run} block(s) executed, {n_skip} skipped")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
