"""End-to-end driver: train a ~100M-parameter llama3-family model for a few
hundred steps on the synthetic token stream, with checkpointing and both
consensus strategies available. This is deliverable (b)'s "train ~100M model
for a few hundred steps" driver — on CPU it is slow but real; on a TPU mesh
the same script takes the production mesh via launch/train.py.

  PYTHONPATH=src python examples/train_100m.py --steps 300
  PYTHONPATH=src python examples/train_100m.py --steps 300 --consensus gossip
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.configs import get_config
from repro.data.tokens import Batcher, TokenStreamConfig
from repro.launch import steps as steps_mod
from repro.models.transformer import Model


def build_100m():
    """llama3 family, ~100M params: 8L x 512d x 8H, vocab 32k."""
    base = get_config("llama3-8b")
    return dataclasses.replace(
        base, name="llama3-100m", n_layers=8, d_model=512, d_ff=2048,
        n_heads=8, n_kv_heads=4, head_dim=64, vocab_size=32000)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--consensus", default="allreduce", choices=("allreduce", "gossip"))
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cfg = build_100m()
    model = Model(cfg)
    gossip = args.consensus == "gossip"
    tcfg = steps_mod.TrainerConfig(
        optimizer="adamw", lr=1e-3, warmup_steps=20, total_steps=args.steps,
        consensus=args.consensus, n_replicas=args.replicas if gossip else 1,
        gossip_rounds=1, remat=True)
    state = steps_mod.make_train_state(model, tcfg, jax.random.PRNGKey(0))
    n_params = sum(int(x.size) for x in jax.tree.leaves(state["params"]))
    n_params //= args.replicas if gossip else 1
    print(f"model={cfg.name} params={n_params/1e6:.1f}M consensus={args.consensus}")

    step_fn = jax.jit(steps_mod.make_train_step(model, tcfg))
    batcher = Batcher(TokenStreamConfig(cfg.vocab_size, args.seq, args.batch, seed=0))
    losses, t0 = [], time.time()
    for s in range(args.steps):
        b = {k: jnp.asarray(v) for k, v in batcher.global_batch(s).items()}
        if gossip:
            G = args.replicas
            b = {k: v.reshape(G, args.batch // G, args.seq) for k, v in b.items()}
        state, m = step_fn(state, b)
        losses.append(float(m["loss"]))
        if s % 25 == 0 or s == args.steps - 1:
            tok_s = args.batch * args.seq * (s + 1) / (time.time() - t0)
            print(f"step {s:4d} loss {losses[-1]:.4f} ({tok_s:,.0f} tok/s)")
    ckpt.save(args.ckpt_dir, args.steps, state)
    print(f"checkpoint -> {args.ckpt_dir}")
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({'IMPROVED' if last < first - 0.2 else 'check hyperparams'})")


if __name__ == "__main__":
    main()
