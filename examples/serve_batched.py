"""Batched serving example: queue of variable-length requests -> greedy
decode with a shared fixed-capacity KV cache (continuous batching lite).

Demonstrates the serve path on an SWA architecture (ring cache) so the cache
footprint stays O(window) regardless of how long decoding runs.

  PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch import steps as steps_mod
from repro.models.transformer import Model


def main():
    cfg = get_config("mixtral-8x22b").reduced(n_layers=2, d_model=256)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    step_fn = jax.jit(steps_mod.make_serve_step(model))

    B, capacity = 4, 96
    requests = [  # (prompt_len, gen_len)
        (12, 20), (30, 10), (5, 40), (22, 16),
    ]
    cache = model.init_cache(B, capacity, jnp.float32)
    max_prompt = max(p for p, _ in requests)
    prompts = jnp.stack([
        jnp.pad(jax.random.randint(jax.random.PRNGKey(i), (p,), 0, cfg.vocab_size),
                (0, max_prompt - p))
        for i, (p, _) in enumerate(requests)])

    # prefill (token-parallel across the batch, sequential over positions)
    t0 = time.time()
    logits = None
    for t in range(max_prompt):
        logits, cache = step_fn(params, prompts[:, t:t + 1], cache, jnp.int32(t))
    print(f"prefill {max_prompt} positions x {B} reqs: {time.time()-t0:.2f}s")

    # decode until every request hit its gen budget
    done_at = [p + g for p, g in requests]
    tok = jnp.argmax(logits[:, -1:], axis=-1)
    outs = {i: [] for i in range(B)}
    t0 = time.time()
    for pos in range(max_prompt, max(done_at)):
        for i in range(B):
            if pos < done_at[i]:
                outs[i].append(int(tok[i, 0]))
        logits, cache = step_fn(params, tok, cache, jnp.int32(pos))
        tok = jnp.argmax(logits[:, -1:], axis=-1)
    dt = time.time() - t0
    n_tok = sum(len(v) for v in outs.values())
    print(f"decoded {n_tok} tokens in {dt:.2f}s ({1e3*dt/max(n_tok,1):.1f} ms/tok)")
    for i, (p, g) in enumerate(requests):
        print(f"req{i}: prompt={p} gen={len(outs[i])}: {outs[i][:8]}...")


if __name__ == "__main__":
    main()
