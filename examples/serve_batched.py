"""Train → snapshot → serve: the GADGET anytime loop end to end.

GADGET's consensus model is usable at every iteration. This demo trains a
CCAT-shaped sparse SVM for a few hundred iterations with the anytime export
ring enabled, checkpoints the latest snapshot (f32 and int8+scale), then
stands up a ``repro.serve.SvmServer`` and pushes ragged sparse queries
through the bucketed micro-batcher — variable-nnz requests, a fixed set of
pad shapes, one compiled executable per bucket, and touched-block sparse
scoring that DMAs only the w d-blocks each batch actually hits.

(The transformer serving driver lives at ``repro.launch.serve`` and is kept
for architecture dry-runs; this is the SVM serving surface.)

  PYTHONPATH=src python examples/serve_batched.py
"""
import tempfile
import time

import jax.numpy as jnp
import numpy as np

from repro import serve
from repro.core.gadget import GadgetConfig, gadget_train
from repro.data.svm_datasets import make_dataset, partition


def main():
    # --- train with the anytime export ring riding the jitted loop --------
    ds = make_dataset("ccat", scale=0.003, seed=0, sparse=True)  # CCAT shape
    Pe, yp, nc = partition(ds.X_train, ds.y_train, 4, seed=0)
    cfg = GadgetConfig(lam=ds.lam, batch_size=4, gossip_rounds=4,
                       max_iters=60, check_every=30, epsilon=0.0)
    t0 = time.time()
    res = gadget_train(Pe, jnp.asarray(yp), cfg, n_counts=nc,
                       snapshot_every=15)
    print(f"trained {res.iters} iters in {time.time()-t0:.1f}s "
          f"(d={ds.d}, k_max={ds.X_train.k_max})")
    for s in serve.snapshots_from(res):
        print(f"  snapshot @ iter {s.iteration:4d}  objective {s.objective:.4f}")

    snap = serve.latest(res)
    with tempfile.TemporaryDirectory() as td:
        # --- checkpoint (versioned manifest; int8 is 4x smaller at rest) --
        path = serve.to_checkpoint(snap, td + "/f32", lam=ds.lam)
        serve.to_checkpoint(snap, td + "/int8", quantize="int8", lam=ds.lam)
        print(f"exported f32 + int8 checkpoints ({path.rsplit('/', 2)[-2]})")

        # --- serve: bucketed micro-batching over ragged sparse queries ----
        srv = serve.SvmServer.load(td + "/f32")
        k_max = ds.X_test.k_max
        buckets = serve.calibrate_buckets(
            serve.bucket_ladder(k_max, rows=8, min_k=max(8, k_max // 4), d=ds.d),
            Pe.cols.reshape(-1, Pe.cols.shape[-1])[:2000],
            Pe.vals.reshape(-1, Pe.vals.shape[-1])[:2000], ds.d)
        print("buckets:", [(b.rows, b.k, b.n_blocks_max) for b in buckets])
        mb = serve.MicroBatcher(buckets)

        n_queries = 64
        for i in range(n_queries):  # ragged: some queries truncated
            live = ds.X_test.vals[i] != 0
            nnz = int(live.sum()) if i % 2 else max(1, int(live.sum()) // 3)
            mb.submit(ds.X_test.cols[i][live][:nnz],
                      ds.X_test.vals[i][live][:nnz])
            if mb.pending >= 16:
                mb.drain(srv.scorer_for())
        mb.drain(srv.scorer_for())

        st, sv = mb.stats(), srv.stats()
        print(f"served {st['requests']} queries in {st['batches']} batches: "
              f"p50 {st['latency_p50_ms']:.0f}ms  p99 {st['latency_p99_ms']:.0f}ms  "
              f"{st['queries_per_sec']:.1f} q/s")
        print(f"compiled {sv['distinct_shapes']} shapes for {len(buckets)} buckets; "
              f"sparse scoring touched {sv['blocks_visited_ratio']:.1%} of w blocks")

        # --- quantized replica agrees on labels --------------------------
        srv_q = serve.SvmServer.load(td + "/int8")
        Xq = ds.X_test.take_rows(np.arange(32)).to_dense()
        _, l_f32 = srv.score(Xq)
        _, l_int8 = srv_q.score(Xq)
        agree = float(np.mean(l_f32 == l_int8))
        print(f"int8 vs f32 label agreement on 32 queries: {agree:.1%}")
        assert agree >= 0.9


if __name__ == "__main__":
    main()
