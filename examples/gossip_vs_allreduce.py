"""The paper's protocol lifted to deep-net training: train the same reduced
transformer with (a) classical all-reduce DP and (b) GADGET-style gossip
consensus, and compare loss curves + replica disagreement.

This is the integration the framework exists for: ``--consensus gossip``
turns every optimizer step into local-step + Push-Sum parameter mixing
(collective-permute on a real mesh; a leading replica axis here on CPU).

  PYTHONPATH=src python examples/gossip_vs_allreduce.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.tokens import Batcher, TokenStreamConfig
from repro.launch import steps as steps_mod
from repro.models.transformer import Model

STEPS, BATCH, SEQ, G = 30, 16, 64, 4


def run(consensus: str, gossip_rounds: int = 1):
    cfg = get_config("llama3-8b").reduced(n_layers=2, d_model=128)
    model = Model(cfg)
    tcfg = steps_mod.TrainerConfig(
        optimizer="adamw", lr=3e-3, total_steps=STEPS, warmup_steps=3,
        consensus=consensus, n_replicas=G if consensus == "gossip" else 1,
        gossip_rounds=gossip_rounds)
    state = steps_mod.make_train_state(model, tcfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(steps_mod.make_train_step(model, tcfg))
    batcher = Batcher(TokenStreamConfig(cfg.vocab_size, SEQ, BATCH, seed=0))
    losses = []
    for s in range(STEPS):
        b = {k: jnp.asarray(v) for k, v in batcher.global_batch(s).items()}
        if consensus == "gossip":
            b = {k: v.reshape(G, BATCH // G, SEQ) for k, v in b.items()}
        state, m = step_fn(state, b)
        losses.append(float(m["loss"]))
    spread = 0.0
    if consensus == "gossip":
        spreads = []
        for leaf in jax.tree.leaves(state["params"]):
            c = leaf.mean(0, keepdims=True)
            spreads.append(float(jnp.linalg.norm((leaf - c).astype(jnp.float32)))
                           / (float(jnp.linalg.norm(c.astype(jnp.float32))) + 1e-9))
        spread = max(spreads)
    return losses, spread


def main():
    l_ar, _ = run("allreduce")
    for rounds in (1, 2):
        l_go, spread = run("gossip", rounds)
        print(f"gossip R={rounds}: loss {l_go[0]:.3f}->{np.mean(l_go[-5:]):.3f} "
              f"(allreduce {l_ar[0]:.3f}->{np.mean(l_ar[-5:]):.3f}); "
              f"final replica disagreement {spread:.3%}")
    # comm cost note (per step per replica, P = model bytes):
    #   allreduce 2(n-1)/n P ~ 1.9P at n=16 ; gossip R/2 P = 0.5P (R=1)
    print("comm/step: allreduce ~1.9x model bytes; gossip R=1 ~0.5x "
          "(see benchmarks/gossip_comm.py for measured collective bytes)")


if __name__ == "__main__":
    main()
