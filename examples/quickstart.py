"""Quickstart: the paper in 60 seconds on one CPU.

1. Train a linear SVM with GADGET (10 gossiping nodes, random-neighbor
   Push-Sum — the paper's exact protocol) on a paper-signature dataset.
2. Compare against centralized Pegasos.
3. Show the consensus: every node ends up with (nearly) the same model.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import svm_objective as obj
from repro.core.gadget import GadgetConfig, gadget_train
from repro.core.pegasos import pegasos_train
from repro.data.svm_datasets import make_dataset, partition


def main():
    ds = make_dataset("reuters", scale=0.3, seed=0)
    Xtr, ytr = jnp.asarray(ds.X_train), jnp.asarray(ds.y_train)
    Xte, yte = jnp.asarray(ds.X_test), jnp.asarray(ds.y_test)
    print(f"dataset=reuters(synthetic signature) d={ds.d} "
          f"n_train={len(ytr)} lambda={ds.lam}")

    cen = pegasos_train(Xtr, ytr, lam=ds.lam, n_iters=1500, batch_size=8)
    print(f"centralized Pegasos   acc={float(obj.accuracy(cen.w, Xte, yte)):.3f}")

    Xp, yp, nc = partition(ds.X_train, ds.y_train, m=10)
    res = gadget_train(jnp.asarray(Xp), jnp.asarray(yp), n_counts=nc,
                       cfg=GadgetConfig(lam=ds.lam, batch_size=8, gossip_rounds=4,
                                    topology="random", epsilon=1e-3,
                                    max_iters=1500, check_every=300))
    acc = float(obj.accuracy(res.w_consensus, Xte, yte))
    print(f"GADGET (10 nodes)     acc={acc:.3f}  iters={res.iters} "
          f"eps_at_stop={res.epsilon:.2e}")

    W = np.asarray(res.W)
    spread = np.linalg.norm(W - W.mean(0), axis=1) / np.linalg.norm(W.mean(0))
    print(f"consensus: max relative node disagreement = {spread.max():.3%}")
    print("per-node accuracies:",
          [round(float(obj.accuracy(res.W[i], Xte, yte)), 3) for i in range(10)])


if __name__ == "__main__":
    main()
