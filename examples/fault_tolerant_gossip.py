"""Node failures during gossip — the paper's §5 future-work scenario, live.

Trains GADGET while links drop 20% of messages (ack'd fail-stop model) and
with two nodes crashed outright, and shows the surviving network still
converges — the Push-Sum mass bookkeeping is doing the fault tolerance.

  PYTHONPATH=src python examples/fault_tolerant_gossip.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core.resilience import FaultySim
from repro.core import svm_objective as obj
from repro.data.svm_datasets import make_dataset, partition


def gadget_with_faults(Xp, yp, lam, sim: FaultySim, n_iters=1200, batch=8, seed=0):
    """GADGET loop re-implemented over the faulty simulator (host loop,
    fine at example scale)."""
    import jax

    m, n_i, d = Xp.shape
    W = jnp.zeros((m, d), jnp.float32)
    key = jax.random.PRNGKey(seed)
    for t in range(1, n_iters + 1):
        key, sub = jax.random.split(key)
        ids = jax.random.randint(sub, (m, batch), 0, n_i)
        alpha = 1.0 / (lam * t)

        def half(w, Xi, yi, ii):
            Xb, yb = Xi[ii], yi[ii]
            L = -obj.hinge_subgradient(w, Xb, yb)
            return obj.project_ball((1 - lam * alpha) * w + alpha * L, lam)

        W = jax.vmap(half)(W, Xp, yp, ids)
        st = sim.init((W,))
        for r in range(3):
            st = sim.round(st, t * 3 + r)
        W = st.estimate()[0]
    return W


def main():
    ds = make_dataset("usps", scale=0.4, seed=0)
    Xte, yte = jnp.asarray(ds.X_test), jnp.asarray(ds.y_test)
    Xp, yp, _nc = partition(ds.X_train, ds.y_train, 10)
    Xp, yp = jnp.asarray(Xp), jnp.asarray(yp)

    for name, sim in [
        ("clean", FaultySim(10, "random", drop_prob=0.0, seed=1)),
        ("20% link drops", FaultySim(10, "random", drop_prob=0.2, drop="link", seed=1)),
        ("2 dead nodes", FaultySim(10, "random", dead_nodes=(2, 5), seed=1)),
    ]:
        W = gadget_with_faults(Xp, yp, ds.lam, sim)
        accs = [float(obj.accuracy(W[i], Xte, yte)) for i in range(10)]
        alive = [a for i, a in enumerate(accs) if i not in getattr(sim, "dead", ())]
        print(f"{name:16s}: node-acc mean {np.mean(alive):.3f} "
              f"(min {min(alive):.3f}, max {max(alive):.3f})")


if __name__ == "__main__":
    main()
