"""LibSVM/SVMlight text-format reader.

The paper's datasets ship in this format (`label idx:val idx:val ...`). The
container is offline, so this loader exists for when the real files are
present; everything else in the repo consumes the synthetic generators.
"""
from __future__ import annotations

import numpy as np

__all__ = ["load_libsvm"]


def load_libsvm(path: str, n_features: int | None = None, dtype=np.float32) -> tuple[np.ndarray, np.ndarray]:
    """Parse a LibSVM file into a dense (N, d) matrix + (N,) labels in {-1,+1}.

    Indices are 1-based per convention. ``n_features`` pads/validates d.
    Dense output keeps the pipeline simple; the paper's sparsest set (CCAT,
    0.16%) at full size would want a CSR path — documented trade-off.
    """
    labels: list[float] = []
    rows: list[list[tuple[int, float]]] = []
    max_idx = 0
    with open(path, "r") as fh:
        for line in fh:
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            parts = line.split()
            labels.append(float(parts[0]))
            feats = []
            for tok in parts[1:]:
                if ":" not in tok:
                    continue
                i_s, v_s = tok.split(":", 1)
                i = int(i_s)
                feats.append((i, float(v_s)))
                max_idx = max(max_idx, i)
            rows.append(feats)
    d = n_features if n_features is not None else max_idx
    X = np.zeros((len(rows), d), dtype=dtype)
    for r, feats in enumerate(rows):
        for i, v in feats:
            if i <= d:
                X[r, i - 1] = v
    y = np.asarray(labels, dtype=dtype)
    uniq = np.unique(y)
    if set(uniq.tolist()) <= {0.0, 1.0}:
        y = np.where(y > 0, 1.0, -1.0).astype(dtype)
    elif not set(uniq.tolist()) <= {-1.0, 1.0}:
        # multiclass source (e.g. MNIST digits): paper maps "0 vs rest"
        y = np.where(y == uniq[0], 1.0, -1.0).astype(dtype)
    return X, y
