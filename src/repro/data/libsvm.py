"""LibSVM/SVMlight text-format readers: dense, streaming-CSR, and chunked.

The paper's datasets ship in this format (`label idx:val idx:val ...`). The
container is offline, so these loaders exist for when the real files are
present; everything else in the repo consumes the synthetic generators.

Three entry points, ONE parse-and-accumulate loop (``_iter_raw_chunks``):

  * :func:`load_libsvm`       — dense (N, d) matrix; the simple path for
    small/dense sets (Adult, USPS).
  * :func:`load_libsvm_csr`   — streams the file into a
    :class:`repro.sparse.CSR` without ever materializing the dense matrix;
    memory is O(nnz). This is the full-scale CCAT/Reuters ingest path:
    ``load_libsvm_csr(path)[0].to_ell()`` feeds ``partition`` →
    ``gadget_train`` directly.
  * :func:`iter_libsvm_chunks` — chunked generator yielding
    ``(CSR, raw_labels)`` blocks of ``chunk_rows`` rows, for out-of-core
    pipelines that never hold even the CSR whole.

Out-of-range feature indices (> ``n_features`` when given): ``strict=True``
raises; the default warns **once** per call with the dropped-entry count —
never the silent clipping the seed loader did.
"""
from __future__ import annotations

import warnings
from typing import Iterator

import numpy as np

from repro.sparse.formats import CSR

__all__ = ["load_libsvm", "load_libsvm_csr", "iter_libsvm_chunks",
           "dump_libsvm"]


def _canonical_labels(y: np.ndarray, dtype) -> np.ndarray:
    """Map raw LibSVM labels to {-1, +1} (the repo-wide convention):
    {0,1} sources shift, multiclass sources map 'first class vs rest'
    (paper: MNIST digit 0 vs rest); {-1,+1} pass through."""
    y = np.asarray(y, dtype=dtype)
    uniq = np.unique(y)
    if set(uniq.tolist()) <= {0.0, 1.0}:
        return np.where(y > 0, 1.0, -1.0).astype(dtype)
    if not set(uniq.tolist()) <= {-1.0, 1.0}:
        return np.where(y == uniq[0], 1.0, -1.0).astype(dtype)
    return y


class _LineParser:
    """Shared tokenizer: tracks max index seen and out-of-range drop count."""

    def __init__(self, n_features: int | None, strict: bool, path: str):
        self.d_cap = n_features
        self.strict = strict
        self.path = path
        self.max_idx = 0
        self.n_dropped = 0

    def parse(self, line: str):
        """-> (label, [idx0...], [val...]) with 0-based in-range indices, or
        None for blank/comment lines."""
        line = line.strip()
        if not line or line.startswith("#"):
            return None
        parts = line.split()
        idxs: list[int] = []
        vals: list[float] = []
        for tok in parts[1:]:
            if ":" not in tok:
                continue
            i_s, v_s = tok.split(":", 1)
            i = int(i_s)  # 1-based per LibSVM convention
            if self.d_cap is not None and i > self.d_cap:
                if self.strict:
                    raise ValueError(
                        f"{self.path}: feature index {i} exceeds "
                        f"n_features={self.d_cap} (strict=True)")
                self.n_dropped += 1
                continue
            self.max_idx = max(self.max_idx, i)
            idxs.append(i - 1)
            vals.append(float(v_s))
        return float(parts[0]), idxs, vals

    def warn_if_dropped(self) -> None:
        if self.n_dropped:
            warnings.warn(
                f"{self.path}: dropped {self.n_dropped} feature entr"
                f"{'y' if self.n_dropped == 1 else 'ies'} with index > "
                f"n_features={self.d_cap} (pass strict=True to raise instead)",
                stacklevel=4)


def _iter_raw_chunks(path: str, parser: _LineParser, chunk_rows: int,
                     dtype) -> Iterator[tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]]:
    """The one accumulate loop: yields ``(labels, data, indices, indptr)``
    arrays per ≤ chunk_rows block (indptr local to the block). Emits the
    end-of-file drop warning after the last chunk."""
    labels: list[float] = []
    indices: list[int] = []
    data: list[float] = []
    indptr: list[int] = [0]

    def flush():
        return (np.asarray(labels, dtype), np.asarray(data, dtype),
                np.asarray(indices, np.int32), np.asarray(indptr, np.int64))

    with open(path, "r") as fh:
        for line in fh:
            parsed = parser.parse(line)
            if parsed is None:
                continue
            lab, idxs, vals = parsed
            labels.append(lab)
            indices.extend(idxs)
            data.extend(vals)
            indptr.append(len(indices))
            if len(labels) >= chunk_rows:
                yield flush()
                labels, indices, data, indptr = [], [], [], [0]
    if labels:
        yield flush()
    parser.warn_if_dropped()


def iter_libsvm_chunks(path: str, n_features: int, chunk_rows: int = 8192,
                       dtype=np.float32, strict: bool = False,
                       ) -> Iterator[tuple[CSR, np.ndarray]]:
    """Stream a LibSVM file as ``(CSR chunk, raw labels)`` blocks.

    ``n_features`` is required — every chunk must agree on d before the whole
    file has been seen. Labels are passed through **raw** (no {-1,+1}
    canonicalization: the multiclass mapping needs the global class set;
    :func:`load_libsvm_csr` applies it after the last chunk). Peak memory is
    O(chunk nnz) — this is the out-of-core ingest primitive.
    """
    if n_features is None:
        raise ValueError("iter_libsvm_chunks requires n_features (chunks must "
                         "agree on d); use load_libsvm_csr to infer it")
    parser = _LineParser(n_features, strict, path)
    for labels, data, indices, indptr in _iter_raw_chunks(path, parser,
                                                          chunk_rows, dtype):
        yield CSR(data, indices, indptr, (len(labels), n_features)), labels


def load_libsvm_csr(path: str, n_features: int | None = None,
                    dtype=np.float32, chunk_rows: int = 8192,
                    strict: bool = False) -> tuple[CSR, np.ndarray]:
    """Stream a LibSVM file into one :class:`CSR` + (N,) labels in {-1,+1}.

    Never materializes the dense matrix — memory is O(nnz), which is what
    makes full-shape CCAT (0.16% nonzeros) ingestible in container memory.
    ``n_features=None`` infers d as the max index seen (requires the whole
    file, which this reads anyway).
    """
    parser = _LineParser(n_features, strict, path)
    chunks = list(_iter_raw_chunks(path, parser, chunk_rows, dtype))
    d = n_features if n_features is not None else parser.max_idx
    if not chunks:
        return (CSR(np.zeros(0, dtype), np.zeros(0, np.int32),
                    np.zeros(1, np.int64), (0, d)),
                np.zeros(0, dtype))
    labels = np.concatenate([c[0] for c in chunks])
    data = np.concatenate([c[1] for c in chunks])
    indices = np.concatenate([c[2] for c in chunks])
    row_nnz = np.concatenate([np.diff(c[3]) for c in chunks])
    indptr = np.zeros(len(labels) + 1, np.int64)
    np.cumsum(row_nnz, out=indptr[1:])
    return (CSR(data, indices, indptr, (len(labels), d)),
            _canonical_labels(labels, dtype))


def load_libsvm(path: str, n_features: int | None = None, dtype=np.float32,
                strict: bool = False) -> tuple[np.ndarray, np.ndarray]:
    """Parse a LibSVM file into a dense (N, d) matrix + (N,) labels in {-1,+1}.

    Indices are 1-based per convention. ``n_features`` pads/validates d;
    entries beyond it raise (``strict=True``) or are dropped with one warning
    carrying the total count. Thin wrapper over :func:`load_libsvm_csr` —
    for the paper's sparse text sets at full size use the CSR loader
    directly (dense CCAT is ~147 GB).
    """
    csr, y = load_libsvm_csr(path, n_features, dtype, strict=strict)
    return csr.to_dense(dtype), y


def dump_libsvm(path: str, X, y) -> None:
    """Write ``(X, y)`` as LibSVM text (`label idx:val ...`, 1-based indices).

    ``X``: dense (N, d) array **or** anything CSR-shaped (``data`` /
    ``indices`` / ``indptr`` attributes — ``repro.sparse.CSR``,
    scipy.sparse.csr_matrix); only nonzeros are written either way, so the
    output round-trips through :func:`iter_libsvm_chunks` /
    :func:`load_libsvm_csr` structure-exactly. ``y``: (N,) labels written
    as integers when integral (the {-1,+1} convention) else as floats.
    Exists so benchmarks/tests can stage a real on-disk streaming source
    (the anytime bench's replica reads its queries this way) without
    shipping dataset files in the repo."""
    if hasattr(X, "indptr"):
        data = np.asarray(X.data)
        indices = np.asarray(X.indices)
        indptr = np.asarray(X.indptr)
        rows = [(indices[indptr[i]:indptr[i + 1]],
                 data[indptr[i]:indptr[i + 1]]) for i in range(len(indptr) - 1)]
    else:
        X = np.asarray(X)
        rows = [(np.nonzero(r)[0], r[np.nonzero(r)[0]]) for r in X]
    y = np.asarray(y)
    if len(rows) != len(y):
        raise ValueError(f"X has {len(rows)} rows but y has {len(y)} labels")
    with open(path, "w") as fh:
        for (idxs, vals), lab in zip(rows, y):
            lab_s = str(int(lab)) if float(lab).is_integer() else repr(float(lab))
            feats = " ".join(f"{int(i) + 1}:{v:.9g}" for i, v in zip(idxs, vals))
            fh.write(f"{lab_s} {feats}\n".rstrip() + "\n")
