"""Deterministic synthetic token/batch pipeline for LM training.

Produces reproducible batches without any disk dataset (container is offline).
The stream is a mixture of Zipf-distributed unigrams and short repeated
motifs, so a language model has real (learnable) structure: loss drops well
below log(vocab) within a few hundred steps — which is what the end-to-end
examples assert.

Sharding: ``Batcher.local_slice(host_id, n_hosts)`` yields the per-host rows
of the global batch, matching how a multi-host pod feeds ``jit`` with
host-local data.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np

__all__ = ["TokenStreamConfig", "Batcher", "synthetic_tokens"]


@dataclass(frozen=True)
class TokenStreamConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2          # unigram skew
    motif_len: int = 8           # repeated n-gram length
    motif_prob: float = 0.35     # fraction of positions inside a copied motif


def synthetic_tokens(cfg: TokenStreamConfig, step: int) -> np.ndarray:
    """(global_batch, seq_len+1) int32 tokens for a given step (stateless)."""
    rng = np.random.default_rng((cfg.seed, step))
    B, S = cfg.global_batch, cfg.seq_len + 1
    # Zipf unigrams clipped to vocab
    base = rng.zipf(cfg.zipf_a, size=(B, S)).astype(np.int64)
    toks = (base - 1) % cfg.vocab_size
    # overlay motifs: copy an earlier window forward (gives in-context structure)
    n_motifs = max(1, int(cfg.motif_prob * S / cfg.motif_len))
    for _ in range(n_motifs):
        src = rng.integers(0, max(1, S - 2 * cfg.motif_len), size=B)
        dst = src + cfg.motif_len + rng.integers(0, cfg.motif_len, size=B)
        for b in range(B):
            e = min(S, dst[b] + cfg.motif_len)
            toks[b, dst[b]:e] = toks[b, src[b]:src[b] + (e - dst[b])]
    return toks.astype(np.int32)


class Batcher:
    """Stateless step->batch mapping with host-local slicing."""

    def __init__(self, cfg: TokenStreamConfig):
        self.cfg = cfg

    def global_batch(self, step: int) -> dict[str, np.ndarray]:
        toks = synthetic_tokens(self.cfg, step)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    def local_slice(self, step: int, host_id: int, n_hosts: int) -> dict[str, np.ndarray]:
        b = self.cfg.global_batch
        if b % n_hosts:
            raise ValueError(f"global batch {b} not divisible by {n_hosts} hosts")
        per = b // n_hosts
        g = self.global_batch(step)
        sl = slice(host_id * per, (host_id + 1) * per)
        return {k: v[sl] for k, v in g.items()}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.global_batch(step)
            step += 1
