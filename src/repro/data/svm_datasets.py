"""Synthetic SVM datasets with the same signature as the paper's benchmarks.

The container is offline, so the six datasets of Table 2 (Adult, CCAT, MNIST,
Reuters, USPS, Webspam) are regenerated synthetically with matching
(N_train, N_test, d, sparsity, lambda). Real files in LibSVM format drop in
via :mod:`repro.data.libsvm` with zero code changes.

Generator model: a ground-truth hyperplane w* with optional sparse features
and controllable label noise + margin — this reproduces the *shape* of each
task (dimensionality, sparsity, class balance) so that the paper's structural
claims (GADGET ≈ centralized Pegasos; convergence/consensus behaviour) are
exercised at the same operating points. ``scale`` shrinks N for CI-speed runs
while keeping d and sparsity exact.

``sparse=True`` emits :class:`repro.sparse.ELL` planes **directly** — column
indices and values are drawn per row, never a dense (N, d) matrix — which is
what makes the paper's flagship scenario generable at full shape: CCAT at
scale=1.0 is ~0.5 GB of planes vs ~147 GB dense. Nonzero columns are sampled
*without replacement* (exactly ``round(sparsity·d)`` per row), on the dense
path too.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
import numpy as np

from repro.sparse.formats import ELL, EllPartitions, partition_rows

__all__ = ["SVMDataset", "PAPER_DATASETS", "make_dataset", "partition"]


@dataclass(frozen=True)
class DatasetSpec:
    name: str
    n_train: int
    n_test: int
    d: int
    sparsity: float      # fraction of nonzero features (1.0 = dense)
    lam: float           # paper's lambda for this dataset (Table 2)
    label_noise: float = 0.05
    class_balance: float = 0.5
    # Zipf exponent of the column-popularity profile (0 = uniform). Real
    # tf-idf text draws its terms from a Zipf-distributed vocabulary, so with
    # frequency-ranked column ids a document's nonzeros concentrate in the
    # leading columns — the locality that makes touched-block kernel
    # scheduling (repro.sparse.formats block bucketing) pay off. Uniform
    # column draws would erase that structure and misrepresent the workload.
    col_skew: float = 0.0


# Table 2 of the paper. Sparsity "NA" in the paper => dense here, except CCAT
# which the paper reports at 0.16% nonzeros. CCAT (RCV1 tf-idf) additionally
# carries a Zipf column-popularity profile with frequency-ranked ids — see
# DatasetSpec.col_skew.
PAPER_DATASETS: dict[str, DatasetSpec] = {
    "adult":   DatasetSpec("adult",   32561,  16281,   123, 1.0,    3.07e-5, label_noise=0.15, class_balance=0.24),
    "ccat":    DatasetSpec("ccat",    781265, 23149, 47236, 0.0016, 1e-4,    label_noise=0.05, class_balance=0.47, col_skew=1.25),
    "mnist":   DatasetSpec("mnist",   60000,  10000,   784, 0.19,   1.67e-5, label_noise=0.02, class_balance=0.099),
    "reuters": DatasetSpec("reuters", 7770,   3299,   8315, 0.01,   1.29e-4, label_noise=0.03, class_balance=0.3),
    "usps":    DatasetSpec("usps",    7329,   1969,    256, 1.0,    1.36e-4, label_noise=0.02, class_balance=0.167),
    "webspam": DatasetSpec("webspam", 234500, 115500,  254, 0.33,   1e-5,    label_noise=0.1,  class_balance=0.39),
}


@dataclass
class SVMDataset:
    name: str
    X_train: "np.ndarray | ELL"  # (n_train, d) float32, dense or ELL planes
    y_train: np.ndarray          # (n_train,)  float32 in {-1, +1}
    X_test: "np.ndarray | ELL"
    y_test: np.ndarray
    lam: float

    @property
    def d(self) -> int:
        return self.X_train.shape[1]

    @property
    def sparse(self) -> bool:
        return isinstance(self.X_train, ELL)


def _sample_cols(rng: np.random.Generator, n: int, nnz: int, d: int,
                 skew: float = 0.0) -> np.ndarray:
    """(n, nnz) nonzero column ids, **without replacement** within each row —
    realized per-row nnz is exact, where the old with-replacement draw
    undershot the spec increasingly with density.

    ``skew`` > 0 draws each row's columns with Zipf popularity
    P(col = r) ∝ (r+1)^-skew (frequency-ranked ids: column 0 is the hottest
    term). Implemented as a chunked exponential race — ``key_r = E_r / w_r``
    with E ~ Exp(1), keep the nnz smallest keys — which is exact weighted
    sampling without replacement, vectorized with an O(chunk·d) transient.

    Uniform regimes: when collisions are rare (nnz² ≤ d — all the text-like
    specs), rejection-resample colliding rows (exactly uniform, O(n·nnz)
    memory); otherwise chunked Gumbel-top-k via argpartition, bounding the
    (chunk, d) scratch so full-shape generation never goes dense-scale.
    """
    if nnz >= d:
        return np.tile(np.arange(d, dtype=np.int64), (n, 1))
    if skew > 0.0:
        inv_w = np.arange(1, d + 1, dtype=np.float32) ** np.float32(skew)
        chunk = max(1, (1 << 25) // d)
        out = np.empty((n, nnz), np.int64)
        for s in range(0, n, chunk):
            e = min(n, s + chunk)
            u = rng.random((e - s, d), dtype=np.float32)
            with np.errstate(divide="ignore"):  # u=0 → -inf: never selected
                np.log(u, out=u)   # -E ~ -Exp(1)
            u *= inv_w             # key = -E/w: keep the nnz *largest* -keys
            out[s:e] = np.argpartition(u, d - nnz, axis=1)[:, d - nnz:]
        return out
    if nnz * nnz <= d:
        cols = rng.integers(0, d, size=(n, nnz))
        bad = np.arange(n)
        for _ in range(200):
            s = np.sort(cols[bad], axis=1)
            bad = bad[(s[:, 1:] == s[:, :-1]).any(axis=1)]
            if bad.size == 0:
                break
            cols[bad] = rng.integers(0, d, size=(bad.size, nnz))
        else:  # pathological tail: per-row exact draw for the few left
            for r in bad:
                cols[r] = rng.choice(d, nnz, replace=False)
        return cols
    chunk = max(1, (1 << 25) // d)
    out = np.empty((n, nnz), np.int64)
    for s in range(0, n, chunk):
        e = min(n, s + chunk)
        r = rng.random((e - s, d), dtype=np.float32)
        out[s:e] = np.argpartition(r, nnz, axis=1)[:, :nnz]
    return out


def _labels_for(margin: np.ndarray, spec: DatasetSpec,
                rng: np.random.Generator) -> np.ndarray:
    """Threshold margins at the class-balance quantile, then flip with the
    spec's label noise — shared by the dense and ELL generators."""
    thr = np.quantile(margin, 1.0 - spec.class_balance)
    y = np.where(margin > thr, 1.0, -1.0).astype(np.float32)
    flip = rng.random(len(margin)) < spec.label_noise
    return np.where(flip, -y, y)


def _gen_split(spec: DatasetSpec, n: int, w_star: np.ndarray, rng: np.random.Generator) -> tuple[np.ndarray, np.ndarray]:
    d = spec.d
    X = rng.normal(0.0, 1.0, size=(n, d)).astype(np.float32)
    if spec.sparsity < 1.0:
        nnz = max(1, int(round(spec.sparsity * d)))
        # sparse nonnegative "text-like" features; exact nnz per row
        mask = np.zeros((n, d), dtype=bool)
        cols = _sample_cols(rng, n, nnz, d, spec.col_skew)
        mask[np.arange(n)[:, None], cols] = True
        X = np.where(mask, np.abs(X), 0.0).astype(np.float32)
    # normalize rows (the paper's text sets are tf-idf normalized)
    norms = np.linalg.norm(X, axis=1, keepdims=True)
    X = X / np.maximum(norms, 1e-8)
    return X, _labels_for(X @ w_star, spec, rng)


def _gen_split_ell(spec: DatasetSpec, n: int, w_star: np.ndarray,
                   rng: np.random.Generator) -> tuple[ELL, np.ndarray]:
    """ELL twin of :func:`_gen_split`: same feature model (nonneg text-like
    values, unit rows, quantile-thresholded labels) drawn directly as
    (n, nnz) column/value planes — the dense matrix never exists."""
    d = spec.d
    nnz = max(1, int(round(spec.sparsity * d)))
    cols = np.sort(_sample_cols(rng, n, nnz, d, spec.col_skew), axis=1).astype(np.int32)
    vals = np.abs(rng.normal(0.0, 1.0, size=(n, nnz)).astype(np.float32))
    vals /= np.maximum(np.linalg.norm(vals, axis=1, keepdims=True), 1e-8)
    # chunked gather-dot keeps the transient at (chunk, nnz)
    margin = np.empty(n, np.float32)
    step = max(1, (1 << 24) // max(nnz, 1))
    for s in range(0, n, step):
        e = min(n, s + step)
        margin[s:e] = np.einsum("rk,rk->r", vals[s:e], w_star[cols[s:e]])
    return ELL(cols, vals, (n, d)), _labels_for(margin, spec, rng)


def make_dataset(name: str, scale: float = 1.0, seed: int = 0,
                 sparse: bool = False) -> SVMDataset:
    """Build a paper-signature dataset. ``scale`` < 1 shrinks row counts.

    ``sparse=True`` (sparse specs only) returns :class:`repro.sparse.ELL`
    feature planes generated without ever materializing the dense matrix —
    the path that makes full-shape CCAT (781,265 × 47,236 at 0.16% nonzeros)
    feasible in container memory. Feed through :func:`partition` straight
    into ``gadget_train``.
    """
    spec = PAPER_DATASETS[name]
    if sparse and spec.sparsity >= 1.0:
        raise ValueError(f"dataset {name!r} is dense (sparsity=1.0); "
                         "sparse=True only applies to sparse specs")
    # crc32, not hash(): Python string hashing is randomized per process
    # (PYTHONHASHSEED), which silently made every "seeded" dataset differ
    # between runs — the structural leaves in the committed BENCH_*.json
    # baselines could never reproduce. A stable hash makes (name, seed)
    # fully deterministic across processes, which the bench regression
    # gates (check_regression --fail-on-timing and structural diffs) need.
    rng = np.random.default_rng((seed, zlib.crc32(name.encode()) & 0xFFFF))
    w_star = rng.normal(size=spec.d).astype(np.float32)
    if spec.sparsity < 1.0:
        w_star = np.abs(w_star)  # nonneg features need signed-balance via threshold
    gen = _gen_split_ell if sparse else _gen_split
    n_tr = max(64, int(spec.n_train * scale))
    n_te = max(64, int(spec.n_test * scale))
    X_tr, y_tr = gen(spec, n_tr, w_star, rng)
    X_te, y_te = gen(spec, n_te, w_star, rng)
    return SVMDataset(name, X_tr, y_tr, X_te, y_te, spec.lam)


def partition(X, y: np.ndarray, m: int, seed: int = 0):
    """Horizontal partition over m nodes (paper §3): shuffle, split into
    near-equal chunks, and **pad** the last chunks instead of dropping tail
    rows (the seed dropped up to m-1 of them silently).

    Returns ``(X_parts, y_parts, n_counts)``: for dense X an (m, n_i, d)
    array, for :class:`repro.sparse.ELL` (or CSR) input an
    :class:`repro.sparse.EllPartitions` of stacked planes — both with
    (m, n_i) labels and the real per-node valid-row counts. Padded rows carry
    X=0/y=0 and n_counts wires straight into ``gadget_train(n_counts=...)``
    (they are never sampled, carry no Push-Sum mass, and are excluded from
    the objective). Row permutation depends only on ``(len(y), m, seed)``, so
    a dense matrix and its ELL conversion partition identically.
    """
    y = np.asarray(y)
    idx, counts, n_i = partition_rows(len(y), m, seed)

    def zero_pads(parts):
        # fancy-indexed gathers above are fresh arrays: zero the ≤ m-1 pad
        # slots in place rather than np.where-copying the whole dataset
        for i in range(m):
            parts[i, counts[i]:] = 0
        return parts

    y_parts = zero_pads(y[idx].reshape(m, n_i).copy())

    if hasattr(X, "to_ell"):  # CSR input: convert once, partition as ELL
        X = X.to_ell()
    if isinstance(X, ELL):
        # the partitions object carries the touched-block schedule metadata:
        # .row_block_counts()/.block_bound() compute lazily (cached per
        # blk_d) so only prefetch-schedule consumers pay the O(nnz) pass
        return (EllPartitions(zero_pads(X.cols[idx].reshape(m, n_i, -1)),
                              zero_pads(X.vals[idx].reshape(m, n_i, -1)),
                              X.shape[1]),
                y_parts, counts)
    X = np.asarray(X)
    return zero_pads(X[idx].reshape(m, n_i, X.shape[1])), y_parts, counts
