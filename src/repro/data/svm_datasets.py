"""Synthetic SVM datasets with the same signature as the paper's benchmarks.

The container is offline, so the six datasets of Table 2 (Adult, CCAT, MNIST,
Reuters, USPS, Webspam) are regenerated synthetically with matching
(N_train, N_test, d, sparsity, lambda). Real files in LibSVM format drop in
via :mod:`repro.data.libsvm` with zero code changes.

Generator model: a ground-truth hyperplane w* with optional sparse features
and controllable label noise + margin — this reproduces the *shape* of each
task (dimensionality, sparsity, class balance) so that the paper's structural
claims (GADGET ≈ centralized Pegasos; convergence/consensus behaviour) are
exercised at the same operating points. ``scale`` shrinks N for CI-speed runs
while keeping d and sparsity exact.
"""
from __future__ import annotations

from dataclasses import dataclass
import numpy as np

__all__ = ["SVMDataset", "PAPER_DATASETS", "make_dataset", "partition"]


@dataclass(frozen=True)
class DatasetSpec:
    name: str
    n_train: int
    n_test: int
    d: int
    sparsity: float      # fraction of nonzero features (1.0 = dense)
    lam: float           # paper's lambda for this dataset (Table 2)
    label_noise: float = 0.05
    class_balance: float = 0.5


# Table 2 of the paper. Sparsity "NA" in the paper => dense here, except CCAT
# which the paper reports at 0.16% nonzeros.
PAPER_DATASETS: dict[str, DatasetSpec] = {
    "adult":   DatasetSpec("adult",   32561,  16281,   123, 1.0,    3.07e-5, label_noise=0.15, class_balance=0.24),
    "ccat":    DatasetSpec("ccat",    781265, 23149, 47236, 0.0016, 1e-4,    label_noise=0.05, class_balance=0.47),
    "mnist":   DatasetSpec("mnist",   60000,  10000,   784, 0.19,   1.67e-5, label_noise=0.02, class_balance=0.099),
    "reuters": DatasetSpec("reuters", 7770,   3299,   8315, 0.01,   1.29e-4, label_noise=0.03, class_balance=0.3),
    "usps":    DatasetSpec("usps",    7329,   1969,    256, 1.0,    1.36e-4, label_noise=0.02, class_balance=0.167),
    "webspam": DatasetSpec("webspam", 234500, 115500,  254, 0.33,   1e-5,    label_noise=0.1,  class_balance=0.39),
}


@dataclass
class SVMDataset:
    name: str
    X_train: np.ndarray  # (n_train, d) float32
    y_train: np.ndarray  # (n_train,)  float32 in {-1, +1}
    X_test: np.ndarray
    y_test: np.ndarray
    lam: float

    @property
    def d(self) -> int:
        return self.X_train.shape[1]


def _gen_split(spec: DatasetSpec, n: int, w_star: np.ndarray, rng: np.random.Generator) -> tuple[np.ndarray, np.ndarray]:
    d = spec.d
    X = rng.normal(0.0, 1.0, size=(n, d)).astype(np.float32)
    if spec.sparsity < 1.0:
        nnz = max(1, int(round(spec.sparsity * d)))
        # sparse nonnegative "text-like" features: top-|nnz| mask per row
        mask = np.zeros((n, d), dtype=bool)
        cols = rng.integers(0, d, size=(n, nnz))
        mask[np.arange(n)[:, None], cols] = True
        X = np.where(mask, np.abs(X), 0.0).astype(np.float32)
    # normalize rows (the paper's text sets are tf-idf normalized)
    norms = np.linalg.norm(X, axis=1, keepdims=True)
    X = X / np.maximum(norms, 1e-8)
    margin = X @ w_star
    # shift threshold to match class balance
    thr = np.quantile(margin, 1.0 - spec.class_balance)
    y = np.where(margin > thr, 1.0, -1.0).astype(np.float32)
    flip = rng.random(n) < spec.label_noise
    y = np.where(flip, -y, y)
    return X, y


def make_dataset(name: str, scale: float = 1.0, seed: int = 0) -> SVMDataset:
    """Build a paper-signature dataset. ``scale`` < 1 shrinks row counts."""
    spec = PAPER_DATASETS[name]
    rng = np.random.default_rng((seed, hash(name) & 0xFFFF))
    w_star = rng.normal(size=spec.d).astype(np.float32)
    if spec.sparsity < 1.0:
        w_star = np.abs(w_star)  # nonneg features need signed-balance via threshold
    n_tr = max(64, int(spec.n_train * scale))
    n_te = max(64, int(spec.n_test * scale))
    X_tr, y_tr = _gen_split(spec, n_tr, w_star, rng)
    X_te, y_te = _gen_split(spec, n_te, w_star, rng)
    return SVMDataset(name, X_tr, y_tr, X_te, y_te, spec.lam)


def partition(X: np.ndarray, y: np.ndarray, m: int, seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Horizontal partition over m nodes (paper §3): shuffle then split into
    equal chunks, returning (m, n_i, d) and (m, n_i). Rows beyond m*n_i are
    dropped (at most m-1 rows)."""
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(y))
    n_i = len(y) // m
    idx = idx[: m * n_i]
    return X[idx].reshape(m, n_i, X.shape[1]), y[idx].reshape(m, n_i)
