"""Data substrate: synthetic SVM datasets (paper signatures), LibSVM loader,
and the deterministic token pipeline for the LM architectures."""
from repro.data.svm_datasets import PAPER_DATASETS, SVMDataset, make_dataset, partition  # noqa: F401
from repro.data.libsvm import load_libsvm  # noqa: F401
from repro.data.tokens import Batcher, TokenStreamConfig, synthetic_tokens  # noqa: F401
