"""Data substrate: synthetic SVM datasets (paper signatures, dense or ELL),
LibSVM loaders (dense + streaming CSR), and the deterministic token pipeline
for the LM architectures."""
from repro.data.svm_datasets import PAPER_DATASETS, SVMDataset, make_dataset, partition  # noqa: F401
from repro.data.libsvm import iter_libsvm_chunks, load_libsvm, load_libsvm_csr  # noqa: F401
from repro.data.tokens import Batcher, TokenStreamConfig, synthetic_tokens  # noqa: F401
