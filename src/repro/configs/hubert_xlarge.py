"""HuBERT X-Large — audio encoder-only (wav2vec2 backbone arch), masked
frame prediction over 504 cluster targets [arXiv:2106.07447].

Per the assignment carve-out, the conv feature extractor (waveform ->
frames) is a stub: the pipeline provides precomputed frame embeddings
(B, S, d_model). Encoder-only => bidirectional attention, no decode shapes
(noted in DESIGN.md §Arch-applicability).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    d_ff=5120,
    vocab_size=504,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    block_pattern=("attn",),
    mlp="gelu",
    norm="layernorm",
    is_encoder=True,
    embed_kind="frames",
    tie_embeddings=False,
    citation="arXiv:2106.07447",
).validate()
