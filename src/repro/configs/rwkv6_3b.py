"""RWKV-6 "Finch" 3B — attention-free SSM with data-dependent decay
[arXiv:2404.05892]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    d_ff=8960,
    vocab_size=65536,
    n_heads=0,           # attention-free
    n_kv_heads=0,
    block_pattern=("rwkv6",),
    rwkv_head_dim=64,
    mlp="squared_relu",  # rwkv channel-mix uses relu^2 internally
    norm="layernorm",
    citation="arXiv:2404.05892",
).validate()
