"""Architecture registry: the 10 assigned configs (+ the paper's own SVM run
parameters in gadget_svm.py, and the four input shapes in shapes.py)."""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

__all__ = ["ARCH_IDS", "get_config", "list_configs"]

_MODULES = {
    "llama3-8b": "llama3_8b",
    "llama3-405b": "llama3_405b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "mixtral-8x22b": "mixtral_8x22b",
    "mistral-large-123b": "mistral_large_123b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "rwkv6-3b": "rwkv6_3b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "nemotron-4-15b": "nemotron_4_15b",
    "hubert-xlarge": "hubert_xlarge",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def list_configs() -> dict[str, ModelConfig]:
    return {k: get_config(k) for k in _MODULES}
