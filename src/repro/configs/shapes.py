"""The four assigned input shapes.

train_4k / prefill_32k lower a full-sequence step; decode shapes lower
``serve_step`` (one token against a seq_len-deep cache). Applicability per
architecture follows DESIGN.md §Arch-applicability: long_500k only for
sub-quadratic attention; no decode shapes for encoder-only models.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.models.config import ModelConfig

__all__ = ["InputShape", "SHAPES", "shape_applies", "skip_reason"]


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, InputShape] = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}


def skip_reason(cfg: ModelConfig, shape: InputShape) -> str | None:
    """None = runs; else the DESIGN.md-documented reason to skip."""
    if shape.kind == "decode":
        if not cfg.supports_decode():
            return "encoder-only architecture: no decode step"
        if shape.name == "long_500k" and not cfg.subquadratic():
            return "pure full attention: 524k context requires sub-quadratic attention"
    return None


def shape_applies(cfg: ModelConfig, shape: InputShape) -> bool:
    return skip_reason(cfg, shape) is None
