"""Mixtral 8x22B — MoE decoder: 8 experts, top-2, SWA [arXiv:2401.04088]."""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    d_ff=16384,          # per-expert FFN width
    vocab_size=32768,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    block_pattern=("swa",),
    window=4096,
    mlp="gated_silu",
    norm="rmsnorm",
    rope_theta=1000000.0,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=16384),
    citation="arXiv:2401.04088",
).validate()
