"""Llama 3 8B — dense GQA decoder, 128k vocab [arXiv:2407.21783]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=128256,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    block_pattern=("attn",),
    mlp="gated_silu",
    norm="rmsnorm",
    rope_theta=500000.0,
    citation="arXiv:2407.21783",
).validate()
