"""LLaVA-NeXT (Mistral-7B backbone) — VLM: anyres patch embeddings prefixed to
the text stream [hf:llava-hf/llava-v1.6-mistral-7b-hf].

Per the assignment carve-out, the vision tower (CLIP ViT-L/336 + projector)
is a stub: input_specs()/the data pipeline provide precomputed patch
embeddings of shape (B, n_prefix_embeds, d_model). 576 tokens = one 336px
tile; anyres tiling raises this to up to 2880 (4 tiles + base) via
``n_prefix_embeds`` override. The backbone keeps Mistral-7B's native
sliding-window attention, which is what qualifies this arch for long_500k.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=32000,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    block_pattern=("swa",),
    window=4096,
    mlp="gated_silu",
    norm="rmsnorm",
    rope_theta=10000.0,
    embed_kind="patches",
    n_prefix_embeds=576,
    citation="hf:llava-hf/llava-v1.6-mistral-7b-hf",
).validate()
