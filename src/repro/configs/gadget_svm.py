"""The paper's own model: a linear SVM trained with GADGET gossip consensus.

Not one of the 10 assigned transformer architectures — this config carries
the paper-faithful experiment parameters (Table 2/3: k=10 nodes, epsilon
1e-3, per-dataset lambda) for the benchmarks and examples.
"""
from dataclasses import dataclass

from repro.core.gadget import GadgetConfig

__all__ = ["PaperRun", "PAPER_RUNS"]


@dataclass(frozen=True)
class PaperRun:
    dataset: str
    n_nodes: int
    gadget: GadgetConfig


def _run(dataset: str, lam: float, max_iters: int = 4000) -> PaperRun:
    return PaperRun(
        dataset=dataset,
        n_nodes=10,  # k = 10 in the paper's experiments
        gadget=GadgetConfig(
            lam=lam,
            batch_size=1,           # paper: one instance per iteration
            gossip_rounds=4,        # ~log2(10) + slack: gamma ~ 1e-2 per step
            topology="random",      # the paper's uniform random neighbor
            epsilon=1e-3,           # paper's convergence epsilon
            check_every=200,
            max_iters=max_iters,
        ),
    )


PAPER_RUNS = {
    "adult":   _run("adult",   3.07e-5),
    "ccat":    _run("ccat",    1e-4),
    "mnist":   _run("mnist",   1.67e-5),
    "reuters": _run("reuters", 1.29e-4),
    "usps":    _run("usps",    1.36e-4),
    "webspam": _run("webspam", 1e-5),
}
