"""Llama 3 405B — dense GQA decoder, 126 layers [arXiv:2407.21783]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    d_ff=53248,
    vocab_size=128256,
    n_heads=128,
    n_kv_heads=8,
    head_dim=128,
    block_pattern=("attn",),
    mlp="gated_silu",
    norm="rmsnorm",
    rope_theta=500000.0,
    citation="arXiv:2407.21783",
).validate()
