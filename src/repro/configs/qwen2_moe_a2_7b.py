"""Qwen1.5/2-MoE A2.7B — fine-grained MoE: 60 routed experts top-4 plus
shared experts (shared FFN width 5632 = 4x1408) [hf:Qwen/Qwen1.5-MoE-A2.7B]."""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    d_ff=1408,            # routed per-expert FFN width
    vocab_size=151936,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    block_pattern=("attn",),
    mlp="gated_silu",
    norm="rmsnorm",
    rope_theta=1000000.0,
    moe=MoEConfig(n_experts=60, top_k=4, d_expert=1408, d_shared=5632),
    citation="hf:Qwen/Qwen1.5-MoE-A2.7B",
).validate()
