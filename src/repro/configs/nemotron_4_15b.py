"""Nemotron-4 15B — dense GQA decoder with squared-ReLU MLP and 256k vocab
[arXiv:2402.16819]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    d_ff=24576,
    vocab_size=256000,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    block_pattern=("attn",),
    mlp="squared_relu",
    norm="layernorm",
    rope_theta=10000.0,
    tie_embeddings=False,   # Nemotron-4 uses untied output layer
    citation="arXiv:2402.16819",
).validate()
