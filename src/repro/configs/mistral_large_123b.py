"""Mistral Large 2 (123B) — dense GQA decoder
[hf:mistralai/Mistral-Large-Instruct-2407]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    d_ff=28672,
    vocab_size=32768,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    block_pattern=("attn",),   # Large 2 dropped SWA: full attention
    mlp="gated_silu",
    norm="rmsnorm",
    rope_theta=1000000.0,
    citation="hf:mistralai/Mistral-Large-Instruct-2407",
).validate()
