"""RecurrentGemma 9B — Griffin hybrid: RG-LRU + local attention, 1 attn per
2 recurrent blocks, MQA (kv=1), 256k vocab [arXiv:2402.19427].

38 layers = 12 full (rglru, rglru, local_attn) cycles + 2 trailing rglru
blocks (compile_stages handles the tail as its own scan stage).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    d_ff=12288,
    vocab_size=256000,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    block_pattern=("rglru", "rglru", "local_attn"),
    window=2048,
    mlp="gated_silu",
    norm="rmsnorm",
    rope_theta=10000.0,
    citation="arXiv:2402.19427",
).validate()
