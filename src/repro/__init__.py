"""GADGET SVM reproduction: gossip-based sub-gradient linear SVM on JAX/Pallas."""

__version__ = "0.1.0"
