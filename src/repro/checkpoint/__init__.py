"""Checkpoint substrate: pytree <-> .npz + versioned JSON manifest, with
rotation and caller metadata (``extra``) for model exports."""
from repro.checkpoint.io import (latest_step, point_latest,  # noqa: F401
                                 read_latest, read_manifest, restore, save)
