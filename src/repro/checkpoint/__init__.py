"""Checkpoint substrate: pytree <-> .npz + JSON treedef, with rotation."""
from repro.checkpoint.io import latest_step, restore, save  # noqa: F401
