"""Checkpoint substrate: pytree <-> .npz + versioned JSON manifest, with
rotation and caller metadata (``extra``) for model exports."""
from repro.checkpoint.io import (latest_step, read_manifest, restore,  # noqa: F401
                                 save)
