"""Checkpointing: flatten any pytree of arrays to an .npz plus a JSON treedef.

No orbax in the container; this covers the trainer's needs — atomic writes
(tmp + rename), step-numbered directories, keep-last-k rotation, and dtype/
shape-faithful restore onto the caller's tree structure (so restored arrays
can be re-sharded by the caller's jit in/out shardings).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any

import jax
import numpy as np

Pytree = Any

__all__ = ["save", "restore", "latest_step"]

_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"


def _step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:09d}")


def save(root: str, step: int, tree: Pytree, keep: int = 3) -> str:
    """Write ``tree`` under root/step_XXXXXXXXX atomically; rotate old steps."""
    os.makedirs(root, exist_ok=True)
    leaves, treedef = jax.tree.flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "dtypes": [str(a.dtype) for a in arrays.values()],
        "shapes": [list(a.shape) for a in arrays.values()],
    }
    tmp = tempfile.mkdtemp(dir=root, prefix=".tmp_ckpt_")
    try:
        np.savez(os.path.join(tmp, _ARRAYS), **arrays)
        with open(os.path.join(tmp, _MANIFEST), "w") as fh:
            json.dump(manifest, fh)
        final = _step_dir(root, step)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _rotate(root, keep)
    return final


def _rotate(root: str, keep: int) -> None:
    steps = sorted(_list_steps(root))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(_step_dir(root, s), ignore_errors=True)


def _list_steps(root: str) -> list[int]:
    out = []
    if not os.path.isdir(root):
        return out
    for name in os.listdir(root):
        if name.startswith("step_"):
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return out


def latest_step(root: str) -> int | None:
    steps = _list_steps(root)
    return max(steps) if steps else None


def restore(root: str, like: Pytree, step: int | None = None) -> Pytree:
    """Restore arrays into the structure of ``like`` (shape/dtype validated)."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    path = _step_dir(root, step)
    with np.load(os.path.join(path, _ARRAYS)) as z:
        arrays = [z[f"leaf_{i}"] for i in range(len(z.files))]
    leaves, treedef = jax.tree.flatten(like)
    if len(leaves) != len(arrays):
        raise ValueError(f"checkpoint has {len(arrays)} leaves, expected {len(leaves)}")
    for i, (a, l) in enumerate(zip(arrays, leaves)):
        if tuple(a.shape) != tuple(np.shape(l)):
            raise ValueError(f"leaf {i}: checkpoint shape {a.shape} != expected {np.shape(l)}")
    return jax.tree.unflatten(treedef, arrays)
