"""Checkpointing: flatten any pytree of arrays to an .npz plus a JSON treedef.

No orbax in the container; this covers the trainer's and the serving
subsystem's needs — atomic writes (tmp + rename), step-numbered directories,
keep-last-k rotation, a versioned manifest with caller ``extra`` metadata
(``repro.serve.snapshot`` records model kind / quantization there), and
dtype/shape-faithful restore onto the caller's tree structure (so restored
arrays can be re-sharded by the caller's jit in/out shardings). Quantized
int8 leaves round-trip dtype-exact — ``restore`` validates dtype as well as
shape, and a structure mismatch fails with the saved-vs-expected treedefs
spelled out instead of leaking a leaf-order scramble to the caller.

Live-publishing contract (the train-to-serve loop leans on all three):

  * **Atomicity** — a checkpoint is staged in a dot-prefixed temp dir and
    enters the namespace via one ``os.rename``; readers either see a complete
    ``step_*`` directory or nothing. A crashed writer leaves only
    ``.tmp_ckpt_*`` litter, which no reader ever lists.
  * **Completeness** — discovery (:func:`latest_step`, :func:`read_latest`)
    only counts directories holding both the manifest and the arrays, so even
    a hand-torn directory is invisible rather than a crash at restore time.
  * **LATEST pointer** — :func:`save` advances a root-level ``LATEST`` file
    (atomic write + ``os.replace``) monotonically; :func:`point_latest` moves
    it explicitly in either direction (rollback). Watchers poll
    :func:`read_latest` instead of scanning the directory.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from typing import Any

import jax
import numpy as np

Pytree = Any

__all__ = ["save", "restore", "latest_step", "read_latest", "point_latest",
           "read_manifest", "MANIFEST_VERSION"]

_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"
_LATEST = "LATEST"

# Bumped when the on-disk layout changes shape. Version 1: arrays.npz with
# leaf_<i> keys + this manifest schema (step/treedef/n_leaves/dtypes/shapes,
# optional caller "extra"). Pre-versioned checkpoints read as version 0.
MANIFEST_VERSION = 1


def _step_dir(root: str, step: int) -> str:
    """Path of the step's directory: ``root/step_%09d`` (sorts numerically)."""
    return os.path.join(root, f"step_{step:09d}")


def _is_complete(root: str, step: int) -> bool:
    """True when the step directory holds both manifest and arrays — the
    completeness gate every discovery path applies, so a torn directory
    (crashed writer, partial copy) is invisible instead of half-loadable."""
    path = _step_dir(root, step)
    return (os.path.isfile(os.path.join(path, _MANIFEST))
            and os.path.isfile(os.path.join(path, _ARRAYS)))


def save(root: str, step: int, tree: Pytree, keep: int = 3,
         extra: dict | None = None, point: bool = True) -> str:
    """Write ``tree`` under root/step_XXXXXXXXX atomically; rotate old steps.

    The arrays + manifest are staged in a dot-prefixed temp dir and published
    with a single ``os.rename`` — a reader polling ``root`` never observes a
    partial checkpoint. After the rename, the root-level ``LATEST`` pointer
    is advanced (monotonically — saving an *older* step never moves it back;
    use :func:`point_latest` for explicit rollback). ``keep`` > 0 retains the
    newest ``keep`` steps and deletes the rest; ``keep=0`` retains all
    (what a live publisher uses so readers never race a rotation).

    ``extra`` (optional, JSON-serializable) is stored verbatim under the
    manifest's ``"extra"`` key — caller-owned metadata (model kind, export
    quantization, training iteration) readable via :func:`read_manifest`
    without touching the arrays. Returns the published step directory path.

    ``point=False`` writes the step directory but leaves the ``LATEST``
    pointer untouched — the checkpoint is complete on disk yet invisible to
    pointer-following readers until the caller hands it off explicitly via
    :func:`point_latest`. A traced publisher uses this to emit its lineage
    records *before* any watcher can observe the new version, keeping
    publish→swap timestamps causally ordered.
    """
    os.makedirs(root, exist_ok=True)
    leaves, treedef = jax.tree.flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
    manifest = {
        "version": MANIFEST_VERSION,
        "step": step,
        "ts": time.time(),  # wall-clock write time (lineage/forensics anchor)
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "dtypes": [str(a.dtype) for a in arrays.values()],
        "shapes": [list(a.shape) for a in arrays.values()],
    }
    if extra is not None:
        manifest["extra"] = extra
    tmp = tempfile.mkdtemp(dir=root, prefix=".tmp_ckpt_")
    try:
        np.savez(os.path.join(tmp, _ARRAYS), **arrays)
        with open(os.path.join(tmp, _MANIFEST), "w") as fh:
            json.dump(manifest, fh)
        final = _step_dir(root, step)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    if point:
        current = _read_pointer(root)
        if current is None or step >= current:
            _write_pointer(root, step)
    _rotate(root, keep)
    return final


def _rotate(root: str, keep: int) -> None:
    """Delete all but the newest ``keep`` steps; ``keep <= 0`` keeps all."""
    steps = sorted(_list_steps(root))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(_step_dir(root, s), ignore_errors=True)


def _list_steps(root: str) -> list[int]:
    """Step numbers of every *complete* checkpoint under ``root``. Temp dirs
    (``.tmp_ckpt_*``) and torn directories are excluded."""
    out = []
    if not os.path.isdir(root):
        return out
    for name in os.listdir(root):
        if name.startswith("step_"):
            try:
                step = int(name[5:])
            except ValueError:
                continue
            if _is_complete(root, step):
                out.append(step)
    return out


def latest_step(root: str) -> int | None:
    """Highest complete step under ``root`` by directory scan (pointer-blind);
    None when the root is empty or missing. :func:`read_latest` is the
    pointer-aware twin a serving watcher should poll."""
    steps = _list_steps(root)
    return max(steps) if steps else None


# --------------------------------------------------------- the LATEST pointer


def _read_pointer(root: str) -> int | None:
    try:
        with open(os.path.join(root, _LATEST)) as fh:
            return int(fh.read().strip())
    except (OSError, ValueError):
        return None


def _write_pointer(root: str, step: int) -> None:
    # atomic even against a concurrent reader: write-then-replace, and the
    # payload is a bare integer so a torn read cannot half-parse
    fd, tmp = tempfile.mkstemp(dir=root, prefix=".tmp_latest_")
    try:
        with os.fdopen(fd, "w") as fh:
            fh.write(f"{step}\n")
        os.replace(tmp, os.path.join(root, _LATEST))
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def read_latest(root: str) -> int | None:
    """The step the ``LATEST`` pointer currently designates, or None.

    Pointer-first: if the pointer file exists and its step directory is
    complete, that step wins — including when it is *older* than other steps
    on disk (an operator rolled back via :func:`point_latest`). A stale or
    corrupt pointer (missing file, unparseable payload, pointed-at step
    rotated away) falls back to the :func:`latest_step` scan, so a watcher
    never wedges on pointer damage."""
    step = _read_pointer(root)
    if step is not None and _is_complete(root, step):
        return step
    return latest_step(root)


def point_latest(root: str, step: int) -> None:
    """Move the ``LATEST`` pointer to ``step`` explicitly (atomic).

    Unlike :func:`save`'s monotonic advance this moves in either direction —
    the rollback path when a published model regresses. Raises
    ``FileNotFoundError`` if ``step`` is not a complete checkpoint, so the
    pointer can never be aimed at a torn or missing directory."""
    if not _is_complete(root, step):
        raise FileNotFoundError(
            f"cannot point LATEST at step {step}: no complete checkpoint at "
            f"{_step_dir(root, step)}")
    _write_pointer(root, step)


def _resolve_step(root: str, step: int | None) -> int:
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    return step


def read_manifest(root: str, step: int | None = None) -> dict:
    """The checkpoint's manifest dict (version, treedef, dtypes/shapes, caller
    ``extra``) without loading any arrays — how serving discovers a model's
    layout before building the ``like`` tree for :func:`restore`."""
    step = _resolve_step(root, step)
    with open(os.path.join(_step_dir(root, step), _MANIFEST)) as fh:
        manifest = json.load(fh)
    manifest.setdefault("version", 0)  # pre-versioned checkpoints
    return manifest


def restore(root: str, like: Pytree, step: int | None = None) -> Pytree:
    """Restore arrays into the structure of ``like``.

    Structure, shape and dtype are all validated *before* unflattening, each
    with an error naming the checkpoint side and the expected side — a
    checkpoint written with a different tree structure (or a leaf that was
    quantized on one side only) fails loudly instead of handing back leaves
    in a scrambled order or silently casting. Dtypes round-trip exactly
    (``np.savez`` preserves them), so int8-quantized exports restore as int8.
    """
    step = _resolve_step(root, step)
    path = _step_dir(root, step)
    manifest = read_manifest(root, step)
    with np.load(os.path.join(path, _ARRAYS)) as z:
        arrays = [z[f"leaf_{i}"] for i in range(len(z.files))]
    leaves, treedef = jax.tree.flatten(like)
    saved_treedef = manifest.get("treedef")
    if manifest.get("n_leaves", len(arrays)) != len(arrays):
        raise ValueError(
            f"checkpoint at {path} is corrupt: manifest records "
            f"{manifest['n_leaves']} leaves but {_ARRAYS} holds {len(arrays)}")
    if len(leaves) != len(arrays):
        raise ValueError(
            f"checkpoint structure mismatch: saved {len(arrays)} leaves "
            f"(treedef {saved_treedef}), caller expects {len(leaves)} "
            f"(treedef {treedef})")
    if saved_treedef is not None and saved_treedef != str(treedef):
        raise ValueError(
            "checkpoint structure mismatch: saved treedef\n  "
            f"{saved_treedef}\ndoes not match the caller's ``like`` treedef\n  "
            f"{treedef}")
    for i, (a, l) in enumerate(zip(arrays, leaves)):
        if tuple(a.shape) != tuple(np.shape(l)):
            raise ValueError(f"leaf {i}: checkpoint shape {a.shape} != expected {np.shape(l)}")
        want_dtype = getattr(l, "dtype", None)
        if want_dtype is not None and a.dtype != want_dtype:
            raise ValueError(
                f"leaf {i}: checkpoint dtype {a.dtype} != expected {want_dtype} "
                "(quantized exports must be restored into a matching-dtype tree)")
    return jax.tree.unflatten(treedef, arrays)
