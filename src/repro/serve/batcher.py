"""Bucketed micro-batcher: variable-nnz sparse queries → fixed pad shapes.

Serving traffic is ragged — each query carries its own nonzero count — but
XLA/Pallas want static shapes, and every novel shape is a recompile. The
batcher quantizes the raggedness away: a small fixed ladder of
``(rows, k, n_blocks_max)`` :class:`Bucket` shapes, each query routed to the
narrowest bucket whose ``k`` fits its nnz, batches padded with the standard
inert ``(col=0, val=0)`` convention (``formats.pad_query_planes`` — pad rows
score 0 and are dropped before results are returned). The engine therefore
compiles **at most one executable per bucket**, no matter what arrives —
``benchmarks/serve_bench.py`` asserts the measured compile count against
``len(buckets)``.

``n_blocks_max`` is each bucket's static grid cap for the query-side
touched-block predict kernel — the serving twin of the training loop's
host-derived ``minibatch_block_bound``. :func:`calibrate_buckets` derives it
from a sample of representative queries (sum of the ``rows`` largest per-row
distinct-block counts, the same sound bound training uses); uncalibrated
buckets fall back to the structural ``min(rows·k, n_d_blocks)``, which is
correct but gives the prefetch schedule nothing to skip.

Accounting: every request is stamped at submit and at result-ready (the
score function is forced to completion before the stamp), and the
submit→sync latency is observed into bounded log-bucket histograms on the
batcher's telemetry registry — one aggregate series plus one per bucket —
so :meth:`stats` reports percentile latency (p50/p90/p99 as histogram
bucket edges) and drain throughput with **flat memory**: soaking the
batcher with 10k requests costs the same bytes as 10 (the fix for the old
unbounded per-request latency list; tests pin the soak).
"""
from __future__ import annotations

import math
import time
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.sparse.formats import (DEFAULT_BUCKET_BLK_D, minibatch_block_bound,
                                  pad_query_planes, row_block_counts)
from repro.telemetry.registry import Registry

__all__ = ["Bucket", "bucket_ladder", "calibrate_buckets", "MicroBatcher"]


@dataclass(frozen=True)
class Bucket:
    """One static serving shape: batches of ``rows`` queries padded to ``k``
    nonzeros each, scored with a ``n_blocks_max``-slot touched-block map."""

    rows: int
    k: int
    n_blocks_max: int

    def __post_init__(self):
        if self.rows < 1 or self.k < 1 or self.n_blocks_max < 1:
            raise ValueError(f"degenerate bucket {self}")


def bucket_ladder(k_max: int, *, rows: int = 8, min_k: int = 16, d: int = None,
                  blk_d: int = DEFAULT_BUCKET_BLK_D) -> tuple[Bucket, ...]:
    """Doubling-``k`` ladder up to ``k_max``: [min_k, 2·min_k, …, ≥ k_max].

    A doubling ladder bounds pad waste at 2× while keeping the shape set (and
    so the compile count) logarithmic in ``k_max``. ``n_blocks_max`` defaults
    to each rung's structural cap — tighten with :func:`calibrate_buckets`.
    """
    if k_max < 1:
        raise ValueError("k_max must be >= 1")
    n_d_blocks = -(-d // blk_d) if d else None
    ks = []
    k = min(min_k, k_max)
    while k < k_max:
        ks.append(k)
        k *= 2
    ks.append(k_max)

    def cap(k):
        structural = rows * k
        return max(1, min(structural, n_d_blocks) if n_d_blocks else structural)

    return tuple(Bucket(rows, k, cap(k)) for k in ks)


def calibrate_buckets(buckets, sample_cols: np.ndarray, sample_vals: np.ndarray,
                      d: int, *, blk_d: int = DEFAULT_BUCKET_BLK_D
                      ) -> tuple[Bucket, ...]:
    """Tighten every bucket's ``n_blocks_max`` from representative queries.

    ``sample_cols/vals``: (n, k) ELL planes of typical traffic (e.g. a slice
    of the training set). The cap per bucket is
    ``minibatch_block_bound(sample, batch_size=rows)`` — sound for any
    ``rows`` sample-like queries, and on Zipf/frequency-ranked text features
    far below the structural bound, which is what lets the sparse predict
    kernel skip most of w."""
    counts = row_block_counts(sample_cols, sample_vals, blk_d)
    return tuple(
        Bucket(b.rows, b.k, minibatch_block_bound(
            sample_cols, sample_vals, b.rows, blk_d, d=d, counts=counts))
        for b in buckets)


@dataclass
class _Request:
    rid: int
    cols: np.ndarray
    vals: np.ndarray
    t_submit: float
    t_done: float | None = None
    scores: np.ndarray | None = None
    label: np.ndarray | None = None


@dataclass
class MicroBatcher:
    """FIFO request queue drained in bucketed, padded batches.

    ``score_fn(bucket, cols, vals)`` — supplied per drain, typically
    ``SvmServer.scorer_for`` — receives exactly ``(bucket.rows, bucket.k)``
    planes and returns ``(scores, labels)`` for every row (pad rows included;
    the batcher drops them). Results are forced (``np.asarray``) before the
    done-stamp so latency numbers include device time, not dispatch time.

    ``registry`` (optional :class:`repro.telemetry.Registry`): where the
    latency histograms and request/batch counters live — pass the process
    default to fold serving latency into a unified dump, or leave None for a
    private registry per batcher (stats are identical either way).
    """

    buckets: tuple[Bucket, ...]
    clock: callable = time.monotonic
    registry: Registry | None = None
    _queue: deque = field(default_factory=deque, repr=False)
    _next_rid: int = 0
    _undelivered: dict = field(default_factory=dict, repr=False)
    _batches: int = 0
    _requests: int = 0
    _padded_rows: int = 0
    _drain_seconds: float = 0.0

    def __post_init__(self):
        if not self.buckets:
            raise ValueError("need at least one bucket")
        self.buckets = tuple(sorted(self.buckets, key=lambda b: b.k))
        if self.registry is None:
            self.registry = Registry(clock=self.clock)

    def _latency_hist(self, bucket_label: str):
        return self.registry.histogram("serve.latency_seconds",
                                       bucket=bucket_label)

    def bucket_for(self, nnz: int) -> Bucket:
        """Narrowest bucket that fits ``nnz`` nonzeros."""
        for b in self.buckets:
            if b.k >= nnz:
                return b
        raise ValueError(
            f"query with {nnz} nonzeros exceeds the widest bucket "
            f"(k={self.buckets[-1].k}) — add a wider rung")

    def submit(self, cols, vals) -> int:
        """Enqueue one query (1-D cols/vals of its nonzero features)."""
        cols = np.asarray(cols, np.int32).reshape(-1)
        vals = np.asarray(vals, np.float32).reshape(-1)
        self.bucket_for(len(cols))  # reject oversize at submit, not drain
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(_Request(rid, cols, vals, self.t_now()))
        return rid

    def submit_csr(self, csr) -> list[int]:
        """Enqueue every row of a CSR chunk; returns the request ids in row
        order. The streaming ingestion path: feed
        ``data.libsvm.iter_libsvm_chunks`` chunks straight in, so a serving
        replica never materializes its query set — each row's (cols, vals)
        slice views the chunk's arrays (copied into the pad planes only at
        drain). ``csr`` is anything with CSR attributes ``data`` / ``indices``
        / ``indptr`` (``repro.data.libsvm.CSR``, scipy.sparse.csr_matrix);
        rows whose nnz exceeds the widest bucket raise at submit, before
        anything is enqueued for that row."""
        indptr = np.asarray(csr.indptr)
        indices = np.asarray(csr.indices, np.int32)
        data = np.asarray(csr.data, np.float32)
        return [
            self.submit(indices[indptr[i]:indptr[i + 1]],
                        data[indptr[i]:indptr[i + 1]])
            for i in range(len(indptr) - 1)
        ]

    def t_now(self) -> float:
        """Current time on the batcher's clock (injectable for tests)."""
        return self.clock()

    @property
    def pending(self) -> int:
        """Number of submitted-but-undrained requests in the queue."""
        return len(self._queue)

    def drain(self, score_fn) -> dict[int, tuple[np.ndarray, np.ndarray]]:
        """Score every pending request; returns {rid: (scores, label)}.

        Requests are grouped by bucket in FIFO order and emitted in full
        ``bucket.rows``-sized pad shapes — partial tail batches still launch
        at the bucket shape (pad rows are inert), so shapes stay static.

        If ``score_fn`` raises, the exception propagates but no request or
        result is lost: batches not yet scored (including the failing one)
        go back on the queue, and results scored before the failure are held
        and delivered by the next successful drain."""
        t0 = self.t_now()
        by_bucket: dict[Bucket, list[_Request]] = {}
        while self._queue:
            r = self._queue.popleft()
            by_bucket.setdefault(self.bucket_for(len(r.cols)), []).append(r)
        batches = [
            (bucket, reqs[i:i + bucket.rows])
            for bucket, reqs in by_bucket.items()
            for i in range(0, len(reqs), bucket.rows)
        ]
        n_scored = 0
        try:
            for bucket, chunk in batches:
                cols, vals = pad_query_planes(
                    [(r.cols, r.vals) for r in chunk], bucket.rows, bucket.k)
                scores, labels = score_fn(bucket, cols, vals)
                scores, labels = np.asarray(scores), np.asarray(labels)  # sync
                t_done = self.t_now()
                self._batches += 1
                self._padded_rows += bucket.rows - len(chunk)
                self.registry.counter("serve.batches",
                                      bucket=f"k{bucket.k}").inc()
                agg = self._latency_hist("all")
                per = self._latency_hist(f"k{bucket.k}")
                for j, r in enumerate(chunk):
                    r.scores, r.label, r.t_done = scores[j], labels[j], t_done
                    self._undelivered[r.rid] = (r.scores, r.label)
                    lat = t_done - r.t_submit
                    agg.observe(lat)
                    per.observe(lat)
                self._requests += len(chunk)
                n_scored += 1
        finally:
            for bucket, chunk in batches[n_scored:]:
                self._queue.extend(chunk)
            self._drain_seconds += self.t_now() - t0
        out, self._undelivered = self._undelivered, {}
        return out

    def stats(self) -> dict:
        """Latency/throughput over everything drained so far.

        Percentiles come from the bounded log-bucket histograms (bucket upper
        edges, within one ~19% growth factor of exact — the overflow bucket
        reports the true max), never from raw per-request lists:
        ``latency_p50/p90/p99_ms`` over all traffic plus a
        ``per_bucket_latency_ms`` breakdown keyed ``k<bucket.k>``."""
        n = self._requests

        def pct(h, q):
            if h is None or not h.count:
                return float("nan")
            return float(h.quantile(q) * 1e3)

        agg = self.registry.get("serve.latency_seconds", bucket="all")
        per_bucket = {}
        for b in self.buckets:
            hb = self.registry.get("serve.latency_seconds", bucket=f"k{b.k}")
            if hb is not None and hb.count:
                per_bucket[f"k{b.k}"] = {
                    "count": hb.count,
                    "p50_ms": pct(hb, 0.50),
                    "p90_ms": pct(hb, 0.90),
                    "p99_ms": pct(hb, 0.99),
                    "max_ms": float(hb.max * 1e3) if math.isfinite(hb.max) else float("nan"),
                }
        return {
            "requests": n,
            "batches": self._batches,
            "padded_rows": self._padded_rows,
            "pad_fraction": (self._padded_rows / max(1, n + self._padded_rows)),
            "latency_p50_ms": pct(agg, 0.50),
            "latency_p90_ms": pct(agg, 0.90),
            "latency_p99_ms": pct(agg, 0.99),
            "per_bucket_latency_ms": per_bucket,
            "queries_per_sec": n / self._drain_seconds if self._drain_seconds else float("nan"),
            "drain_seconds": self._drain_seconds,
        }
