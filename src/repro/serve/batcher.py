"""Bucketed micro-batcher: variable-nnz sparse queries → fixed pad shapes.

Serving traffic is ragged — each query carries its own nonzero count — but
XLA/Pallas want static shapes, and every novel shape is a recompile. The
batcher quantizes the raggedness away: a small fixed ladder of
``(rows, k, n_blocks_max)`` :class:`Bucket` shapes, each query routed to the
narrowest bucket whose ``k`` fits its nnz, batches padded with the standard
inert ``(col=0, val=0)`` convention (``formats.pad_query_planes`` — pad rows
score 0 and are dropped before results are returned). The engine therefore
compiles **at most one executable per bucket**, no matter what arrives —
``benchmarks/serve_bench.py`` asserts the measured compile count against
``len(buckets)``.

``n_blocks_max`` is each bucket's static grid cap for the query-side
touched-block predict kernel — the serving twin of the training loop's
host-derived ``minibatch_block_bound``. :func:`calibrate_buckets` derives it
from a sample of representative queries (sum of the ``rows`` largest per-row
distinct-block counts, the same sound bound training uses); uncalibrated
buckets fall back to the structural ``min(rows·k, n_d_blocks)``, which is
correct but gives the prefetch schedule nothing to skip.

Accounting: every request is stamped at submit and at result-ready (the
score function is forced to completion before the stamp), and the
submit→sync latency is observed into bounded log-bucket histograms on the
batcher's telemetry registry — one aggregate series plus one per bucket —
so :meth:`stats` reports percentile latency (p50/p90/p99 as histogram
bucket edges) and drain throughput with **flat memory**: soaking the
batcher with 10k requests costs the same bytes as 10 (the fix for the old
unbounded per-request latency list; tests pin the soak).

Overload policy (``docs/ARCHITECTURE.md`` §9): the queue is **bounded** when
``max_pending`` is set — admission follows :attr:`MicroBatcher.admission`
(``reject-new`` raises a typed :class:`QueryRejected` at submit,
``shed-oldest`` evicts the head of the queue and delivers a typed
:class:`Shed` result for it, ``block`` parks the submitting thread until a
drain frees space) — and every request can carry a **deadline** (absolute
time on the batcher clock, defaulted from ``default_timeout``): drains drop
expired requests *before* padding/launch and deliver typed
:class:`DeadlineExceeded` results, so a burst never spends kernel launches
on dead work. Every stage has a counter (``serve.submitted`` /
``serve.rejected{reason=…}`` / ``serve.shed`` / ``serve.deadline_missed`` /
``serve.delivered``) and the invariant ``submitted == delivered + shed +
deadline_missed + pending`` holds at every drain boundary —
``benchmarks/overload_bench.py`` asserts the reconciliation under 2×
offered load.
"""
from __future__ import annotations

import math
import threading
import time
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.sparse.formats import (DEFAULT_BUCKET_BLK_D, minibatch_block_bound,
                                  pad_query_planes, row_block_counts)
from repro.telemetry import trace as tmtr
from repro.telemetry.registry import Registry

__all__ = ["Bucket", "bucket_ladder", "calibrate_buckets", "MicroBatcher",
           "QueryRejected", "Shed", "DeadlineExceeded", "ADMISSION_POLICIES"]

#: Admission policies for a bounded (``max_pending``) queue, in the order of
#: how much the *submitter* learns: ``reject-new`` pushes back synchronously
#: (typed raise), ``shed-oldest`` accepts and sacrifices the stalest queued
#: request (typed :class:`Shed` result), ``block`` applies backpressure by
#: parking the submitting thread until a drain frees a slot.
ADMISSION_POLICIES = ("reject-new", "shed-oldest", "block")


class QueryRejected(ValueError):
    """Typed submit-time rejection: the query never entered the queue.

    ``reason`` is one of ``"oversize"`` (nnz exceeds the widest bucket —
    malformed traffic; carries ``nnz`` and ``k_max``), ``"queue-full"``
    (bounded queue at capacity under the ``reject-new`` policy; carries
    ``pending`` and ``max_pending``) or ``"block-timeout"`` (``block``
    policy waited ``block_timeout`` real seconds without a slot freeing).
    Subclasses :class:`ValueError` so pre-typed callers that caught the
    old bare ``ValueError`` keep working unchanged.
    """

    def __init__(self, message: str, *, reason: str, nnz: int | None = None,
                 k_max: int | None = None, pending: int | None = None,
                 max_pending: int | None = None):
        super().__init__(message)
        self.reason = reason
        self.nnz = nnz
        self.k_max = k_max
        self.pending = pending
        self.max_pending = max_pending


@dataclass(frozen=True)
class Shed:
    """Typed drain result for a request evicted by ``shed-oldest`` admission:
    it was accepted at ``t_submit`` but sacrificed at ``t_shed`` to admit
    newer work under a full queue. Delivered through the same
    ``drain() -> {rid: result}`` channel as scores, so every accepted
    request's fate is observable."""

    rid: int
    t_submit: float
    t_shed: float
    reason: str = "shed-oldest"


@dataclass(frozen=True)
class DeadlineExceeded:
    """Typed drain result for a request whose deadline passed before it was
    scored: dropped at ``t_expired`` *before* padding/launch, so expired
    work never costs a kernel launch."""

    rid: int
    t_submit: float
    deadline: float
    t_expired: float


@dataclass(frozen=True)
class Bucket:
    """One static serving shape: batches of ``rows`` queries padded to ``k``
    nonzeros each, scored with a ``n_blocks_max``-slot touched-block map."""

    rows: int
    k: int
    n_blocks_max: int

    def __post_init__(self):
        if self.rows < 1 or self.k < 1 or self.n_blocks_max < 1:
            raise ValueError(f"degenerate bucket {self}")


def bucket_ladder(k_max: int, *, rows: int = 8, min_k: int = 16, d: int = None,
                  blk_d: int = DEFAULT_BUCKET_BLK_D) -> tuple[Bucket, ...]:
    """Doubling-``k`` ladder up to ``k_max``: [min_k, 2·min_k, …, ≥ k_max].

    A doubling ladder bounds pad waste at 2× while keeping the shape set (and
    so the compile count) logarithmic in ``k_max``. ``n_blocks_max`` defaults
    to each rung's structural cap — tighten with :func:`calibrate_buckets`.
    """
    if k_max < 1:
        raise ValueError("k_max must be >= 1")
    n_d_blocks = -(-d // blk_d) if d else None
    ks = []
    k = min(min_k, k_max)
    while k < k_max:
        ks.append(k)
        k *= 2
    ks.append(k_max)

    def cap(k):
        structural = rows * k
        return max(1, min(structural, n_d_blocks) if n_d_blocks else structural)

    return tuple(Bucket(rows, k, cap(k)) for k in ks)


def calibrate_buckets(buckets, sample_cols: np.ndarray, sample_vals: np.ndarray,
                      d: int, *, blk_d: int = DEFAULT_BUCKET_BLK_D
                      ) -> tuple[Bucket, ...]:
    """Tighten every bucket's ``n_blocks_max`` from representative queries.

    ``sample_cols/vals``: (n, k) ELL planes of typical traffic (e.g. a slice
    of the training set). The cap per bucket is
    ``minibatch_block_bound(sample, batch_size=rows)`` — sound for any
    ``rows`` sample-like queries, and on Zipf/frequency-ranked text features
    far below the structural bound, which is what lets the sparse predict
    kernel skip most of w."""
    counts = row_block_counts(sample_cols, sample_vals, blk_d)
    return tuple(
        Bucket(b.rows, b.k, minibatch_block_bound(
            sample_cols, sample_vals, b.rows, blk_d, d=d, counts=counts))
        for b in buckets)


@dataclass
class _Request:
    rid: int
    cols: np.ndarray
    vals: np.ndarray
    t_submit: float
    deadline: float | None = None
    t_done: float | None = None
    scores: np.ndarray | None = None
    label: np.ndarray | None = None


@dataclass
class MicroBatcher:
    """FIFO request queue drained in bucketed, padded batches.

    ``score_fn(bucket, cols, vals)`` — supplied per drain, typically
    ``SvmServer.scorer_for`` — receives exactly ``(bucket.rows, bucket.k)``
    planes and returns ``(scores, labels)`` for every row (pad rows included;
    the batcher drops them). Results are forced (``np.asarray``) before the
    done-stamp so latency numbers include device time, not dispatch time.

    ``registry`` (optional :class:`repro.telemetry.Registry`): where the
    latency histograms and request/batch counters live — pass the process
    default to fold serving latency into a unified dump, or leave None for a
    private registry per batcher (stats are identical either way).

    Overload knobs (all off by default — an unconfigured batcher behaves
    exactly like the historical unbounded one):

    * ``max_pending`` — queue capacity; ``None`` keeps the queue unbounded.
    * ``admission`` — what :meth:`submit` does at capacity (one of
      :data:`ADMISSION_POLICIES`; default ``reject-new``).
    * ``default_timeout`` — seconds on the batcher clock after which an
      accepted request expires unless scored; per-request ``deadline=``
      overrides it. ``None`` disables default deadlines.
    * ``block_timeout`` — real-time cap for the ``block`` policy's wait
      (``None`` parks the submitter until a drain frees a slot).

    Tracing: ``tracer`` (optional
    :class:`repro.telemetry.trace.RequestTracer`) samples submissions into
    per-request fate traces — one ``serve.request`` span per sampled request,
    closed by its terminal fate (``delivered`` with the executed bucket and
    the degrade rung at execution, ``shed``, ``deadline``, or ``rejected``
    with the rejection reason) — and each scored batch gets a
    ``serve.score.seconds`` span that closes even when ``score_fn`` raises
    (error-annotated). ``tracer=None`` (default) adds nothing to the hot
    path.

    Submit and drain are thread-safe (one condition variable guards the
    queue and the result ledger); ``score_fn`` runs *outside* the lock so
    an open-loop submitter thread is never serialized behind a kernel
    launch.
    """

    buckets: tuple[Bucket, ...]
    clock: callable = time.monotonic
    registry: Registry | None = None
    max_pending: int | None = None
    admission: str = "reject-new"
    default_timeout: float | None = None
    block_timeout: float | None = None
    tracer: tmtr.RequestTracer | None = None
    _queue: deque = field(default_factory=deque, repr=False)
    _next_rid: int = 0
    _undelivered: dict = field(default_factory=dict, repr=False)
    _batches: int = 0
    _requests: int = 0
    _padded_rows: int = 0
    _drain_seconds: float = 0.0
    _queue_peak: int = 0
    _degraded_bucket: Bucket | None = field(default=None, repr=False)
    _cond: threading.Condition = field(default_factory=threading.Condition,
                                       repr=False)

    def __post_init__(self):
        if not self.buckets:
            raise ValueError("need at least one bucket")
        if self.admission not in ADMISSION_POLICIES:
            raise ValueError(f"admission must be one of {ADMISSION_POLICIES}, "
                             f"got {self.admission!r}")
        if self.max_pending is not None and self.max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {self.max_pending}")
        if self.default_timeout is not None and self.default_timeout <= 0:
            raise ValueError(
                f"default_timeout must be > 0, got {self.default_timeout}")
        self.buckets = tuple(sorted(self.buckets, key=lambda b: b.k))
        if self.registry is None:
            self.registry = Registry(clock=self.clock)

    def _latency_hist(self, bucket_label: str):
        return self.registry.histogram("serve.latency_seconds",
                                       bucket=bucket_label)

    def bucket_for(self, nnz: int) -> Bucket:
        """Narrowest bucket that fits ``nnz`` nonzeros; raises a typed
        :class:`QueryRejected` (``reason="oversize"``, carrying the query's
        nnz and the widest rung's k) when none does."""
        for b in self.buckets:
            if b.k >= nnz:
                return b
        self.registry.counter("serve.rejected", reason="oversize").inc()
        raise QueryRejected(
            f"query with {nnz} nonzeros exceeds the widest bucket "
            f"(k={self.buckets[-1].k}) — add a wider rung",
            reason="oversize", nnz=int(nnz), k_max=self.buckets[-1].k)

    # ----------------------------------------------------------- admission

    def _admit_locked(self, n_new: int = 1) -> None:
        """Enforce ``max_pending`` for ``n_new`` incoming requests (caller
        holds the lock). ``reject-new`` raises; ``shed-oldest`` evicts from
        the queue head into typed :class:`Shed` results; ``block`` waits on
        the condition until drains free enough slots (or ``block_timeout``
        real seconds pass)."""
        if self.max_pending is None:
            return
        if self.admission == "reject-new":
            if len(self._queue) + n_new > self.max_pending:
                self.registry.counter("serve.rejected",
                                      reason="queue-full").inc(n_new)
                raise QueryRejected(
                    f"queue full ({len(self._queue)}/{self.max_pending} "
                    f"pending) — reject-new admission",
                    reason="queue-full", pending=len(self._queue),
                    max_pending=self.max_pending)
        elif self.admission == "shed-oldest":
            while len(self._queue) + n_new > self.max_pending and self._queue:
                victim = self._queue.popleft()
                self._undelivered[victim.rid] = Shed(
                    rid=victim.rid, t_submit=victim.t_submit,
                    t_shed=self.t_now())
                self.registry.counter("serve.shed").inc()
                if self.tracer is not None:
                    self.tracer.finish(victim.rid, "shed")
        else:  # block: park the submitter until a drain frees a slot
            t_end = (time.monotonic() + self.block_timeout
                     if self.block_timeout is not None else None)
            while len(self._queue) + n_new > self.max_pending:
                remaining = None if t_end is None else t_end - time.monotonic()
                if remaining is not None and remaining <= 0:
                    self.registry.counter("serve.rejected",
                                          reason="block-timeout").inc(n_new)
                    raise QueryRejected(
                        f"queue full ({len(self._queue)}/{self.max_pending} "
                        f"pending) after blocking {self.block_timeout}s",
                        reason="block-timeout", pending=len(self._queue),
                        max_pending=self.max_pending)
                self._cond.wait(remaining)

    def submit(self, cols, vals, *, deadline: float | None = None) -> int:
        """Enqueue one query (1-D cols/vals of its nonzero features).

        ``deadline`` (optional): absolute time on the batcher clock after
        which the request is dead — an expired request is dropped at drain
        (before any padding or kernel launch) and delivered as a typed
        :class:`DeadlineExceeded` result. Defaults to ``t_now() +
        default_timeout`` when the batcher has a ``default_timeout``, else
        no deadline. Oversize queries and ``reject-new``/``block-timeout``
        admission failures raise :class:`QueryRejected` without enqueuing."""
        cols = np.asarray(cols, np.int32).reshape(-1)
        vals = np.asarray(vals, np.float32).reshape(-1)
        try:
            self.bucket_for(len(cols))  # reject oversize at submit, not drain
            with self._cond:
                self._admit_locked()
                now = self.t_now()
                if deadline is None and self.default_timeout is not None:
                    deadline = now + self.default_timeout
                rid = self._next_rid
                self._next_rid += 1
                self._queue.append(_Request(rid, cols, vals, now,
                                            deadline=deadline))
                self.registry.counter("serve.submitted").inc()
                self._queue_peak = max(self._queue_peak, len(self._queue))
        except QueryRejected as e:
            if self.tracer is not None:
                # refused at the door: no rid, zero-duration rejected span
                self.tracer.reject(reason=e.reason)
            raise
        if self.tracer is not None:
            self.tracer.start(rid)
        return rid

    def submit_csr(self, csr, *, deadline: float | None = None) -> list[int]:
        """Enqueue every row of a CSR chunk; returns the request ids in row
        order. The streaming ingestion path: feed
        ``data.libsvm.iter_libsvm_chunks`` chunks straight in, so a serving
        replica never materializes its query set — each row's (cols, vals)
        slice views the chunk's arrays (copied into the pad planes only at
        drain). ``csr`` is anything with CSR attributes ``data`` / ``indices``
        / ``indptr`` (``repro.data.libsvm.CSR``, scipy.sparse.csr_matrix).

        All-or-nothing on validity: **every** row's nnz is checked against
        the widest bucket before anything is enqueued, so an oversize row in
        the middle of a chunk raises :class:`QueryRejected` with zero rows
        queued (the old behavior enqueued the rows before it). Admission
        (``max_pending``) is still enforced per row — a ``reject-new``
        queue-full raise mid-chunk keeps the rows admitted before it.
        ``deadline`` applies to every row of the chunk."""
        indptr = np.asarray(csr.indptr)
        indices = np.asarray(csr.indices, np.int32)
        data = np.asarray(csr.data, np.float32)
        nnz = np.diff(indptr)
        widest = self.buckets[-1].k
        bad = np.nonzero(nnz > widest)[0]
        if bad.size:
            self.registry.counter("serve.rejected",
                                  reason="oversize").inc(int(bad.size))
            if self.tracer is not None:
                self.tracer.reject(reason="oversize")
            raise QueryRejected(
                f"chunk row {int(bad[0])} with {int(nnz[bad[0]])} nonzeros "
                f"exceeds the widest bucket (k={widest}) — "
                f"{int(bad.size)} oversize row(s), nothing enqueued",
                reason="oversize", nnz=int(nnz[bad[0]]), k_max=widest)
        return [
            self.submit(indices[indptr[i]:indptr[i + 1]],
                        data[indptr[i]:indptr[i + 1]], deadline=deadline)
            for i in range(len(indptr) - 1)
        ]

    def t_now(self) -> float:
        """Current time on the batcher's clock (injectable for tests)."""
        return self.clock()

    @property
    def pending(self) -> int:
        """Number of submitted-but-undrained requests in the queue."""
        return len(self._queue)

    # ---------------------------------------------------------- degradation

    def degrade_to(self, bucket: Bucket | None) -> None:
        """Route **all** traffic to one rung (the overload ladder's cheapest-
        bucket step): queries wider than ``bucket.k`` are truncated to their
        ``k`` largest-|value| features at drain time (counted in
        ``serve.truncated`` — an explicit accuracy-for-latency trade), so
        every launch uses the one already-compiled shape. ``None`` restores
        normal narrowest-fit routing. Takes effect from the next drain;
        queued requests keep their full feature lists until then."""
        if bucket is not None and bucket not in self.buckets:
            raise ValueError(f"{bucket} is not one of this batcher's buckets")
        self._degraded_bucket = bucket

    def _route(self, r: _Request) -> tuple[Bucket, _Request]:
        """Pick the bucket for one request, applying degraded routing."""
        b = self._degraded_bucket
        if b is None:
            return self.bucket_for(len(r.cols)), r
        if len(r.cols) > b.k:
            keep = np.argpartition(np.abs(r.vals), len(r.vals) - b.k)[-b.k:]
            keep.sort()  # preserve column order in the truncated planes
            r.cols, r.vals = r.cols[keep], r.vals[keep]
            self.registry.counter("serve.truncated").inc()
        return b, r

    # --------------------------------------------------------------- drain

    def _expire(self, reqs: list[_Request], now: float) -> list[_Request]:
        """Split off expired requests: each becomes a typed
        :class:`DeadlineExceeded` result (+ ``serve.deadline_missed``);
        returns the still-live ones."""
        live = []
        for r in reqs:
            if r.deadline is not None and now >= r.deadline:
                with self._cond:
                    self._undelivered[r.rid] = DeadlineExceeded(
                        rid=r.rid, t_submit=r.t_submit, deadline=r.deadline,
                        t_expired=now)
                self.registry.counter("serve.deadline_missed").inc()
                if self.tracer is not None:
                    self.tracer.finish(r.rid, "deadline")
            else:
                live.append(r)
        return live

    def drain(self, score_fn) -> dict[int, tuple[np.ndarray, np.ndarray]]:
        """Score every pending request; returns {rid: result}.

        A result is a ``(scores, label)`` tuple for scored requests, or a
        typed :class:`Shed` / :class:`DeadlineExceeded` record for accepted
        requests the overload policy dropped — callers distinguish with
        ``isinstance``. Requests are grouped by bucket in FIFO order and
        emitted in full ``bucket.rows``-sized pad shapes — partial tail
        batches still launch at the bucket shape (pad rows are inert), so
        shapes stay static. Expired requests are dropped before padding (and
        re-checked per batch right before each launch), so dead work never
        reaches the device.

        If ``score_fn`` raises, the exception propagates but no request or
        result is lost: batches not yet scored (including the failing one)
        go back on the queue, and results scored before the failure are held
        and delivered by the next successful drain."""
        t0 = self.t_now()
        with self._cond:
            popped = list(self._queue)
            self._queue.clear()
            self._cond.notify_all()  # freed slots: wake block-policy submitters
        by_bucket: dict[Bucket, list[_Request]] = {}
        for r in self._expire(popped, self.t_now()):
            bucket, r = self._route(r)
            by_bucket.setdefault(bucket, []).append(r)
        batches = [
            (bucket, reqs[i:i + bucket.rows])
            for bucket, reqs in by_bucket.items()
            for i in range(0, len(reqs), bucket.rows)
        ]
        n_scored = 0
        try:
            for bucket, chunk in batches:
                # deadline re-check at launch time: a long multi-batch drain
                # must not launch work that died while earlier batches ran
                chunk = self._expire(chunk, self.t_now())
                batches[n_scored] = (bucket, chunk)
                if not chunk:
                    n_scored += 1
                    continue
                cols, vals = pad_query_planes(
                    [(r.cols, r.vals) for r in chunk], bucket.rows, bucket.k)
                if self.tracer is not None:
                    # the span closes on the exception path too: a flaky
                    # score_fn raise still records it, error-annotated
                    with tmtr.TracedSpan(self.registry, "serve.score.seconds",
                                         tmtr.TraceContext.new(),
                                         bucket=f"k{bucket.k}"):
                        scores, labels = score_fn(bucket, cols, vals)
                        scores = np.asarray(scores)  # force inside the span
                        labels = np.asarray(labels)
                else:
                    scores, labels = score_fn(bucket, cols, vals)
                    scores, labels = np.asarray(scores), np.asarray(labels)  # sync
                t_done = self.t_now()
                self._batches += 1
                self._padded_rows += bucket.rows - len(chunk)
                self.registry.counter("serve.batches",
                                      bucket=f"k{bucket.k}").inc()
                agg = self._latency_hist("all")
                per = self._latency_hist(f"k{bucket.k}")
                rung = (int(self.tracer.registry.value("serve.degrade_rung")
                            or 0) if self.tracer is not None else 0)
                with self._cond:
                    for j, r in enumerate(chunk):
                        r.scores, r.label, r.t_done = scores[j], labels[j], t_done
                        self._undelivered[r.rid] = (r.scores, r.label)
                        lat = t_done - r.t_submit
                        agg.observe(lat)
                        per.observe(lat)
                    self._requests += len(chunk)
                    self.registry.counter("serve.delivered").inc(len(chunk))
                if self.tracer is not None:
                    for r in chunk:
                        self.tracer.finish(r.rid, "delivered",
                                           bucket=f"k{bucket.k}", rung=rung)
                n_scored += 1
        finally:
            with self._cond:
                for bucket, chunk in batches[n_scored:]:
                    self._queue.extend(chunk)
            self._drain_seconds += self.t_now() - t0
        with self._cond:
            out, self._undelivered = self._undelivered, {}
        return out

    # --------------------------------------------------------------- stats

    def stats(self) -> dict:
        """Latency/throughput over everything drained so far.

        Percentiles come from the bounded log-bucket histograms (bucket upper
        edges, within one ~19% growth factor of exact — the overflow bucket
        reports the true max), never from raw per-request lists:
        ``latency_p50/p90/p99_ms`` over all traffic plus a
        ``per_bucket_latency_ms`` breakdown keyed ``k<bucket.k>``. Overload
        accounting rides along: ``submitted`` / ``delivered`` / ``shed`` /
        ``deadline_missed`` / ``rejected`` counter totals, the live
        ``pending`` depth and its high-water mark ``queue_peak`` — at every
        drain boundary ``submitted == delivered + shed + deadline_missed +
        pending`` (rejected requests were never admitted)."""
        n = self._requests

        def pct(h, q):
            if h is None or not h.count:
                return float("nan")
            return float(h.quantile(q) * 1e3)

        def cnt(name, **labels):
            return int(self.registry.value(name, **labels) or 0)

        agg = self.registry.get("serve.latency_seconds", bucket="all")
        per_bucket = {}
        for b in self.buckets:
            hb = self.registry.get("serve.latency_seconds", bucket=f"k{b.k}")
            if hb is not None and hb.count:
                per_bucket[f"k{b.k}"] = {
                    "count": hb.count,
                    "p50_ms": pct(hb, 0.50),
                    "p90_ms": pct(hb, 0.90),
                    "p99_ms": pct(hb, 0.99),
                    "max_ms": float(hb.max * 1e3) if math.isfinite(hb.max) else float("nan"),
                }
        return {
            "requests": n,
            "batches": self._batches,
            "padded_rows": self._padded_rows,
            "pad_fraction": (self._padded_rows / max(1, n + self._padded_rows)),
            "latency_p50_ms": pct(agg, 0.50),
            "latency_p90_ms": pct(agg, 0.90),
            "latency_p99_ms": pct(agg, 0.99),
            "per_bucket_latency_ms": per_bucket,
            "queries_per_sec": n / self._drain_seconds if self._drain_seconds else float("nan"),
            "drain_seconds": self._drain_seconds,
            "pending": len(self._queue),
            "queue_peak": self._queue_peak,
            "submitted": cnt("serve.submitted"),
            "delivered": cnt("serve.delivered"),
            "shed": cnt("serve.shed"),
            "deadline_missed": cnt("serve.deadline_missed"),
            "rejected": sum(cnt("serve.rejected", reason=r)
                            for r in ("oversize", "queue-full",
                                      "block-timeout")),
            "truncated": cnt("serve.truncated"),
        }
