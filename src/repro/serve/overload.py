"""Degradation ladder: trade accuracy for latency under sustained pressure.

The last stage of the overload policy (``docs/ARCHITECTURE.md`` §9). The
bounded queue and deadlines protect the *server* — they keep memory and
launch work finite — but under a sustained 2× offered load they protect it
by throwing half the traffic away. :class:`DegradeLadder` instead makes each
request cheaper so more of the offered load fits under the capacity line:

* **rung 0** — normal service: f32 weight plane, narrowest-fit bucket
  routing.
* **rung 1** — int8 weight plane: the server swaps to the quantize→
  dequantize image of the live weights (``SvmServer.set_plane("int8")``) —
  what an int8 export would serve, the cheapest model the checkpoint format
  already supports.
* **rung 2** — int8 plane + cheapest bucket: the batcher routes everything
  to its narrowest rung (``MicroBatcher.degrade_to``), truncating wide
  queries to their largest-|value| features — smaller pad planes, fewer
  touched blocks per launch.

Every transition is a runtime-argument change against already-compiled
executables — pre-warm with :meth:`DegradeLadder.prepare` and
``stats()["distinct_shapes"]`` stays flat across the whole ladder
(``benchmarks/overload_bench.py`` asserts it).

The **pressure signal** combines the bounded queue (occupancy fraction) with
the latency histograms (p99 against an optional SLO); **hysteresis** comes
from two watermarks plus a patience count — the ladder steps only after
``patience`` consecutive observations beyond a watermark, so one bursty
drain cannot flap the model quality. Telemetry: ``serve.degrade_steps{
direction=down|up}`` counters and a ``serve.degrade_rung`` gauge on the
server's registry, beside the ``serve.degraded`` flag ``set_plane`` keeps.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.serve.batcher import MicroBatcher
from repro.serve.engine import SvmServer
from repro.telemetry import trace as tmtr

__all__ = ["DegradeLadder"]


@dataclass
class DegradeLadder:
    """Hysteretic controller stepping a server/batcher pair down the overload
    ladder and back.

    Call :meth:`observe` between drains (the same cadence as
    ``SvmServer.maybe_reload``). Pressure ≥ ``high`` for ``patience``
    consecutive observations steps one rung down; pressure ≤ ``low`` for
    ``patience`` observations steps one rung up; anything in between resets
    both streaks (the hysteresis band). ``max_rung`` caps how far the ladder
    may degrade (2 = int8 + cheapest bucket, 1 = int8 plane only).

    ``latency_slo_ms`` (optional): fold the latency histograms into the
    pressure signal — p99 at the SLO contributes pressure 1.0, so a server
    whose queue is short but whose tail is blown still degrades. Without a
    bounded queue (``max_pending=None``) *only* the latency term can drive
    the ladder; configure at least one or :meth:`observe` is inert.

    ``trace=True`` additionally emits a traced ``serve.degrade`` event on
    every rung transition (direction + new rung) so the observatory's fate
    view can correlate degraded delivery with the transition that caused it.
    """

    server: SvmServer
    batcher: MicroBatcher
    high: float = 0.75
    low: float = 0.25
    patience: int = 2
    max_rung: int = 2
    latency_slo_ms: float | None = None
    trace: bool = False
    rung: int = 0
    _above: int = field(default=0, repr=False)
    _below: int = field(default=0, repr=False)

    def __post_init__(self):
        if not 0.0 <= self.low < self.high:
            raise ValueError(f"need 0 <= low < high, got low={self.low} "
                             f"high={self.high}")
        if self.patience < 1:
            raise ValueError(f"patience must be >= 1, got {self.patience}")
        if not 0 <= self.max_rung <= 2:
            raise ValueError(f"max_rung must be 0..2, got {self.max_rung}")
        if self.latency_slo_ms is not None and self.latency_slo_ms <= 0:
            raise ValueError(
                f"latency_slo_ms must be > 0, got {self.latency_slo_ms}")

    def prepare(self) -> None:
        """Pre-build the degraded weight plane so the first mid-overload
        step-down costs a dict lookup, not a quantization pass. (Executable
        warm-up is per bucket shape and happens wherever the serving loop
        warms its buckets — the ladder adds no new shapes.)"""
        self.server.set_plane("int8")
        self.server.set_plane("f32")

    def pressure(self) -> float:
        """Instantaneous pressure in [0, ∞): max of queue occupancy
        (pending / max_pending) and p99 latency / SLO (when configured).
        1.0 means "at the configured limit"."""
        p = 0.0
        if self.batcher.max_pending:
            p = self.batcher.pending / self.batcher.max_pending
        if self.latency_slo_ms is not None:
            h = self.batcher.registry.get("serve.latency_seconds",
                                          bucket="all")
            if h is not None and h.count:
                p = max(p, float(h.quantile(0.99)) * 1e3 / self.latency_slo_ms)
        return p

    def observe(self) -> int:
        """One control step: read the pressure, update the hysteresis
        streaks, apply at most one rung transition. Returns the current
        rung (0 = full service)."""
        p = self.pressure()
        if p >= self.high:
            self._above += 1
            self._below = 0
        elif p <= self.low:
            self._below += 1
            self._above = 0
        else:
            self._above = self._below = 0
        if self._above >= self.patience and self.rung < self.max_rung:
            self.rung += 1
            self._above = 0
            self._apply("down")
        elif self._below >= self.patience and self.rung > 0:
            self.rung -= 1
            self._below = 0
            self._apply("up")
        return self.rung

    def _apply(self, direction: str) -> None:
        """Install the current rung on the server/batcher pair."""
        self.server.set_plane("int8" if self.rung >= 1 else "f32")
        self.batcher.degrade_to(
            self.batcher.buckets[0] if self.rung >= 2 else None)
        reg = self.server.registry
        reg.counter("serve.degrade_steps", direction=direction).inc()
        reg.gauge("serve.degrade_rung").set(float(self.rung))
        if self.trace:
            tmtr.emit_event(reg, "serve.degrade", tmtr.TraceContext.new(),
                            direction=direction, rung=self.rung)
