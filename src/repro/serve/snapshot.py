"""Anytime snapshot export: ring decoding, versioned checkpoints, quantization.

GADGET is an *anytime* algorithm — the consensus model is usable at every
iteration — and ``gadget_train(..., snapshot_every=K)`` taps that: the jitted
loop records the last few ``(iteration, consensus w, objective)`` triples into
an on-device ring (:class:`repro.core.gadget.SnapshotRing`). This module is
the host half of the export path:

  * :func:`snapshots_from` / :func:`latest` — decode the ring (device slot
    layout) into ordered :class:`Snapshot` records, final iterate included.
  * :func:`to_checkpoint` / :func:`from_checkpoint` — wire a snapshot into
    ``repro.checkpoint`` with a versioned manifest (``kind`` +
    ``serve_format`` under the manifest's ``extra``), so a serving process can
    discover the model's shape/dtype without guessing a tree structure.
  * :func:`quantize_int8` / :func:`dequantize_int8` — symmetric per-class-row
    int8 + f32 scale export, the same shrink-the-payload trade the quantized
    gossip path makes (``consensus.gossip_mix_stacked(payload_dtype=...)``
    quantizes the *sent* share per round; here the shipped artifact is the
    weights themselves, 4× smaller on the wire and dtype-faithful on restore).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro import checkpoint as ckpt
from repro.core.gadget import SnapshotRing, TrainState

__all__ = [
    "Snapshot", "snapshots_from", "latest",
    "to_checkpoint", "from_checkpoint",
    "train_state_from_checkpoint", "latest_train_state",
    "quantize_int8", "dequantize_int8",
    "SERVE_KIND", "SERVE_FORMAT_VERSION",
]

SERVE_KIND = "gadget_svm_model"
SERVE_FORMAT_VERSION = 1


@dataclass(frozen=True)
class Snapshot:
    """One servable model state: the consensus weights at ``iteration`` and
    the primal objective they achieved. ``w`` is (d,) for the paper's binary
    SVM or (C, d) for the one-vs-rest multiclass extension."""

    iteration: int
    w: np.ndarray
    objective: float

    @property
    def d(self) -> int:
        """Feature dimension of the snapshotted weights (last axis of w)."""
        return self.w.shape[-1]

    @property
    def n_classes(self) -> int:
        """1 for a binary (d,) snapshot, C for a multiclass (C, d) one."""
        return 1 if self.w.ndim == 1 else self.w.shape[0]


def _ring_of(source) -> SnapshotRing:
    ring = getattr(source, "snapshots", source)
    if not isinstance(ring, SnapshotRing):
        raise ValueError(
            "no snapshots attached — train with gadget_train(..., "
            "snapshot_every=K) to record the anytime ring")
    return ring


def snapshots_from(source) -> list[Snapshot]:
    """Decode a training result's ring into ordered snapshots.

    ``source``: a ``GadgetResult`` (its ``.snapshots`` field) or a raw
    :class:`SnapshotRing`. Returns oldest → newest; when the ring wrapped
    (``count > slots``) only the latest ``slots`` periodic snapshots survive.
    The final iterate is always last — appended when the run did not end
    exactly on a snapshot boundary (including ``K > iters``, where it is the
    only entry)."""
    ring = _ring_of(source)
    n_valid = min(ring.count, ring.slots)
    out = [
        Snapshot(int(ring.iterations[j % ring.slots]),
                 np.asarray(ring.W[j % ring.slots]),
                 float(ring.objectives[j % ring.slots]))
        for j in range(ring.count - n_valid, ring.count)
    ]
    if not out or out[-1].iteration != ring.final_iteration:
        out.append(Snapshot(int(ring.final_iteration), np.asarray(ring.final_w),
                            float(ring.final_objective)))
    return out


def latest(source) -> Snapshot:
    """The newest servable state (the final iterate)."""
    return snapshots_from(source)[-1]


# ------------------------------------------------------------- quantization


def quantize_int8(w: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Symmetric int8 quantization with one f32 scale per class row.

    ``w``: (d,) or (C, d) → ``(q, scale)`` with ``q`` int8 of the same shape
    and ``scale`` shaped () / (C,) such that ``q ≈ round(w / scale)`` clipped
    to ±127. Max-abs scaling keeps dequantization error ≤ scale/2 per weight.
    """
    w = np.asarray(w, np.float32)
    W2 = w[None] if w.ndim == 1 else w
    scale = (np.maximum(np.abs(W2).max(axis=1), 1e-30) / 127.0).astype(np.float32)
    q = np.clip(np.rint(W2 / scale[:, None]), -127, 127).astype(np.int8)
    if w.ndim == 1:
        return q[0], scale[0]
    return q, scale


def dequantize_int8(q: np.ndarray, scale: np.ndarray) -> np.ndarray:
    """Inverse of :func:`quantize_int8` (up to the ≤ scale/2 rounding)."""
    q = np.asarray(q)
    scale = np.asarray(scale, np.float32)
    if q.ndim == 1:
        return q.astype(np.float32) * scale
    return q.astype(np.float32) * scale[:, None]


# -------------------------------------------------------------- checkpoints


def to_checkpoint(snap: Snapshot, root: str, *, quantize: str | None = None,
                  step: int | None = None, keep: int = 3,
                  lam: float | None = None,
                  train_state: TrainState | None = None,
                  trace: dict | None = None, point: bool = True) -> str:
    """Export one snapshot as a servable checkpoint under ``root``.

    ``quantize``: ``None`` ships f32 weights; ``"int8"`` ships the int8+scale
    pair from :func:`quantize_int8` (dtype-faithful on restore — the
    regression tests pin this through ``repro.checkpoint``). The manifest's
    ``extra`` carries the versioned serving schema — kind, format version,
    dtype, shape, iteration, objective — so :func:`from_checkpoint` (and the
    serving engine) can rebuild the restore tree without out-of-band
    knowledge. ``step`` defaults to the snapshot's iteration.

    ``train_state`` (optional :class:`repro.core.gadget.TrainState`) rides
    along as extra ``train_W`` / ``train_W_sum`` leaves plus a
    ``train_state`` manifest record — enough for
    :func:`train_state_from_checkpoint` to rebuild the exact per-node solver
    state, so a crashed trainer can resume bit-identically from its last
    published model instead of restarting from zero.

    ``trace`` (optional dict — a
    :meth:`repro.telemetry.trace.TraceContext.to_extra`) is stored verbatim
    under ``extra["trace"]``: the cross-process half of version-lineage
    tracing, letting the serving watcher's swap span link back to the
    publish/segment spans that produced this checkpoint.

    ``point=False`` defers the ``LATEST`` pointer handoff to the caller
    (see :func:`repro.checkpoint.save`) — the traced publisher's ordering
    lever, so its publish records always precede any watcher's swap.
    """
    if quantize not in (None, "int8"):
        raise ValueError(f"unknown quantize mode {quantize!r}")
    if quantize == "int8":
        q, scale = quantize_int8(snap.w)
        tree = {"w": q, "scale": np.asarray(scale, np.float32)}
    else:
        tree = {"w": np.asarray(snap.w, np.float32)}
    extra = {
        "kind": SERVE_KIND,
        "serve_format": SERVE_FORMAT_VERSION,
        "dtype": "int8" if quantize == "int8" else "float32",
        "d": int(snap.d),
        "n_classes": int(snap.n_classes),
        "binary": snap.w.ndim == 1,
        "iteration": int(snap.iteration),
        "objective": float(snap.objective),
    }
    if lam is not None:
        extra["lam"] = float(lam)
    if trace is not None:
        extra["trace"] = dict(trace)
    if train_state is not None:
        W = np.asarray(train_state.W)
        W_sum = np.asarray(train_state.W_sum)
        if W.shape != W_sum.shape:
            raise ValueError(
                f"train_state W/W_sum shapes differ: {W.shape} vs {W_sum.shape}")
        tree["train_W"] = W
        tree["train_W_sum"] = W_sum
        extra["train_state"] = {
            "iteration": int(train_state.iteration),
            "shape": list(W.shape),
            "dtype": str(W.dtype),
        }
    return ckpt.save(root, snap.iteration if step is None else step, tree,
                     keep=keep, extra=extra, point=point)


def from_checkpoint(root: str, step: int | None = None
                    ) -> tuple[np.ndarray, dict]:
    """Load a servable checkpoint back to f32 weights.

    Returns ``(w, extra)`` — int8 exports are dequantized here (serving
    kernels run f32; the quantization already paid for itself on the wire /
    at rest). Rejects checkpoints that are not serving exports or carry a
    newer format version, with the manifest contents in the error."""
    manifest = ckpt.read_manifest(root, step)
    extra = manifest.get("extra") or {}
    if extra.get("kind") != SERVE_KIND:
        raise ValueError(
            f"checkpoint under {root} is not a serving export "
            f"(manifest extra: {extra!r}) — write it with serve.snapshot.to_checkpoint")
    if extra.get("serve_format", 0) > SERVE_FORMAT_VERSION:
        raise ValueError(
            f"serving checkpoint format {extra['serve_format']} is newer than "
            f"this build understands ({SERVE_FORMAT_VERSION})")
    d, C, binary = extra["d"], extra["n_classes"], extra["binary"]
    w_shape = (d,) if binary else (C, d)
    if extra["dtype"] == "int8":
        like = {"w": np.zeros(w_shape, np.int8),
                "scale": np.zeros(() if binary else (C,), np.float32)}
    else:
        like = {"w": np.zeros(w_shape, np.float32)}
    like.update(_train_like(extra))
    tree = ckpt.restore(root, like, step)
    if extra["dtype"] == "int8":
        return dequantize_int8(tree["w"], tree["scale"]), extra
    return np.asarray(tree["w"]), extra


def _train_like(extra: dict) -> dict:
    """Template leaves for an embedded train state (empty when absent).

    ``repro.checkpoint.restore`` validates the *full* treedef, so a serving
    load of a resume-capable checkpoint must name the train leaves even when
    it only wants ``w``."""
    ts = extra.get("train_state")
    if not ts:
        return {}
    shape, dtype = tuple(ts["shape"]), np.dtype(ts["dtype"])
    return {"train_W": np.zeros(shape, dtype),
            "train_W_sum": np.zeros(shape, dtype)}


def train_state_from_checkpoint(root: str, step: int | None = None) -> TrainState:
    """Rebuild the solver :class:`TrainState` embedded in a checkpoint.

    Raises ``ValueError`` when the checkpoint is not a serving export or was
    written without ``train_state=`` — resume needs the full per-node state,
    not just the consensus weights."""
    manifest = ckpt.read_manifest(root, step)
    extra = manifest.get("extra") or {}
    if extra.get("kind") != SERVE_KIND:
        raise ValueError(
            f"checkpoint under {root} is not a serving export "
            f"(manifest extra: {extra!r})")
    ts = extra.get("train_state")
    if not ts:
        raise ValueError(
            f"checkpoint step {manifest.get('step')} under {root} carries no "
            "train state — publish with TrainPublisher(save_train_state=True) "
            "or to_checkpoint(..., train_state=...) to enable crash-resume")
    d, C, binary = extra["d"], extra["n_classes"], extra["binary"]
    w_shape = (d,) if binary else (C, d)
    if extra["dtype"] == "int8":
        like = {"w": np.zeros(w_shape, np.int8),
                "scale": np.zeros(() if binary else (C,), np.float32)}
    else:
        like = {"w": np.zeros(w_shape, np.float32)}
    like.update(_train_like(extra))
    tree = ckpt.restore(root, like, step)
    return TrainState(iteration=int(ts["iteration"]),
                      W=tree["train_W"], W_sum=tree["train_W_sum"])


def latest_train_state(root: str) -> TrainState | None:
    """Lenient resume probe: the latest embedded train state, else ``None``.

    Unlike :func:`train_state_from_checkpoint` this swallows *expected*
    cold-start conditions — no checkpoint directory yet, no published step,
    or a latest step written without train state — so a restarting publisher
    can call it unconditionally and fall back to a fresh run."""
    step = ckpt.read_latest(root)
    if step is None:
        return None
    try:
        return train_state_from_checkpoint(root, step)
    except (ValueError, FileNotFoundError):
        # not a serve export / no embedded state / step rotated away mid-probe
        return None
