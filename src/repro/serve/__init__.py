"""Anytime serving subsystem: snapshot → checkpoint → score.

GADGET's consensus model is usable at every iteration; this package is the
half of the system that *uses* it. ``snapshot`` decodes the training loop's
on-device export ring and wires it into versioned ``repro.checkpoint``
exports (f32 or int8+scale); ``batcher`` buckets ragged sparse queries into a
small fixed set of pad shapes (static shapes ⇒ bounded compile count);
``engine`` is the ``SvmServer`` scoring path over the fused dense and
query-side touched-block sparse predict kernels, plus the ``shard_map``
batch-parallel scorer. ``benchmarks/serve_bench.py`` measures and asserts
the whole pipeline.
"""
from repro.serve.batcher import (Bucket, MicroBatcher, bucket_ladder,  # noqa: F401
                                 calibrate_buckets)
from repro.serve.engine import SvmServer, make_mesh_scorer  # noqa: F401
from repro.serve.snapshot import (Snapshot, dequantize_int8,  # noqa: F401
                                  from_checkpoint, latest, quantize_int8,
                                  snapshots_from, to_checkpoint)
