"""Anytime serving subsystem: snapshot → checkpoint → score, live.

GADGET's consensus model is usable at every iteration; this package is the
half of the system that *uses* it. ``snapshot`` decodes the training loop's
on-device export ring and wires it into versioned ``repro.checkpoint``
exports (f32 or int8+scale); ``publisher`` runs training in a background
thread and flushes those exports continuously (monotone versions, atomic
rename, a ``LATEST`` pointer); ``batcher`` buckets ragged sparse queries into
a small fixed set of pad shapes (static shapes ⇒ bounded compile count);
``engine`` is the ``SvmServer`` scoring path over the fused dense and
query-side touched-block sparse predict kernels — with ``watch`` /
``maybe_reload`` hot-swapping the weight plane between drains without
recompiling — plus the ``shard_map`` batch-parallel scorer. ``overload``
makes the whole path survive traffic it cannot absorb: bounded admission
(``max_pending`` + reject/shed/block policies), per-request deadlines with
typed ``QueryRejected`` / ``Shed`` / ``DeadlineExceeded`` outcomes, and the
hysteretic ``DegradeLadder`` stepping to the int8 plane and cheapest bucket
under sustained pressure. ``benchmarks/serve_bench.py``,
``benchmarks/anytime_bench.py`` and ``benchmarks/overload_bench.py``
measure and assert the whole pipeline; ``docs/ARCHITECTURE.md`` walks it
end to end (§9 is the overload policy).
"""
from repro.serve.batcher import (ADMISSION_POLICIES, Bucket,  # noqa: F401
                                 DeadlineExceeded, MicroBatcher, QueryRejected,
                                 Shed, bucket_ladder, calibrate_buckets)
from repro.serve.engine import SvmServer, make_mesh_scorer  # noqa: F401
from repro.serve.overload import DegradeLadder  # noqa: F401
from repro.serve.publisher import TrainPublisher  # noqa: F401
from repro.serve.snapshot import (Snapshot, dequantize_int8,  # noqa: F401
                                  from_checkpoint, latest, quantize_int8,
                                  snapshots_from, to_checkpoint)
