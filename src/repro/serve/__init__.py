"""Anytime serving subsystem: snapshot → checkpoint → score, live.

GADGET's consensus model is usable at every iteration; this package is the
half of the system that *uses* it. ``snapshot`` decodes the training loop's
on-device export ring and wires it into versioned ``repro.checkpoint``
exports (f32 or int8+scale); ``publisher`` runs training in a background
thread and flushes those exports continuously (monotone versions, atomic
rename, a ``LATEST`` pointer); ``batcher`` buckets ragged sparse queries into
a small fixed set of pad shapes (static shapes ⇒ bounded compile count);
``engine`` is the ``SvmServer`` scoring path over the fused dense and
query-side touched-block sparse predict kernels — with ``watch`` /
``maybe_reload`` hot-swapping the weight plane between drains without
recompiling — plus the ``shard_map`` batch-parallel scorer.
``benchmarks/serve_bench.py`` and ``benchmarks/anytime_bench.py`` measure
and assert the whole pipeline; ``docs/ARCHITECTURE.md`` walks it end to end.
"""
from repro.serve.batcher import (Bucket, MicroBatcher, bucket_ladder,  # noqa: F401
                                 calibrate_buckets)
from repro.serve.engine import SvmServer, make_mesh_scorer  # noqa: F401
from repro.serve.publisher import TrainPublisher  # noqa: F401
from repro.serve.snapshot import (Snapshot, dequantize_int8,  # noqa: F401
                                  from_checkpoint, latest, quantize_int8,
                                  snapshots_from, to_checkpoint)
