"""Live checkpoint publisher: train in the background, flush every segment.

The producing half of the live train-to-serve loop. :class:`TrainPublisher`
runs :func:`repro.core.gadget.gadget_train_stream` — trajectory bit-identical
to one ``gadget_train`` call — in a daemon thread, and at every segment
boundary exports the current consensus model through
:func:`repro.serve.snapshot.to_checkpoint`:

  * **versioned** — the checkpoint step is the global training iteration, so
    versions are strictly monotone across a run;
  * **atomic** — ``repro.checkpoint`` stages in a temp dir and publishes via
    one ``os.rename``, so a concurrently-polling server never sees a torn
    checkpoint;
  * **discoverable** — each save advances the root's ``LATEST`` pointer,
    which ``SvmServer.watch(root).maybe_reload()`` polls between drains.

Publish cadence is ``segment_iters`` (training iterations per checkpoint);
``keep=0`` (the default here, unlike the offline exporter) retains every
version so a reader can never race a rotation and rollback targets survive.
Exceptions in the training thread are captured, surfaced by :meth:`join`,
and flagged via :attr:`error` — the publisher never kills the serving
process that owns it.
"""
from __future__ import annotations

import threading

from repro.core.gadget import GadgetConfig, SegmentResult, gadget_train_stream
from repro.serve.snapshot import Snapshot, to_checkpoint

__all__ = ["TrainPublisher"]


class TrainPublisher:
    """Background trainer that publishes a servable checkpoint per segment.

    ``X_parts``/``y_parts``/``cfg``/``n_counts`` follow the
    ``gadget_train`` conventions (dense (m, n_i, d) or ``EllPartitions``
    planes; (m, n_i) ±1 labels with 0 on pad rows). ``root`` is the
    checkpoint directory the serving side watches. ``segment_iters`` sets
    the publish cadence; ``quantize`` (None | "int8") and ``keep`` pass
    through to :func:`~repro.serve.snapshot.to_checkpoint`.

    Lifecycle: ``start()`` launches the daemon thread and returns ``self``;
    ``join()`` blocks until training converges (or ``cfg.max_iters``) and
    returns the final :class:`~repro.core.gadget.SegmentResult`, re-raising
    any training-thread exception. ``published`` grows by one step number
    per flushed checkpoint (monotone — append-only under the GIL, safe to
    read concurrently); ``wait(timeout)`` parks on the done event without
    consuming the error.
    """

    def __init__(self, X_parts, y_parts, cfg: GadgetConfig = GadgetConfig(), *,
                 root: str, segment_iters: int, n_counts=None,
                 quantize: str | None = None, keep: int = 0):
        self.root = root
        self.cfg = cfg
        self.segment_iters = int(segment_iters)
        self.quantize = quantize
        self.keep = int(keep)
        self._data = (X_parts, y_parts, n_counts)
        self.published: list[int] = []
        self.final: SegmentResult | None = None
        self.error: BaseException | None = None
        self._done = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="gadget-train-publisher")

    # ----------------------------------------------------------- lifecycle

    def start(self) -> "TrainPublisher":
        """Launch the training thread (idempotence not attempted — one
        publisher is one training run). Returns ``self`` for chaining."""
        self._thread.start()
        return self

    def _run(self) -> None:
        X_parts, y_parts, n_counts = self._data
        try:
            for seg in gadget_train_stream(X_parts, y_parts, self.cfg,
                                           segment_iters=self.segment_iters,
                                           n_counts=n_counts):
                self._publish(seg)
                self.final = seg
        except BaseException as e:  # surfaced via join()/error, never lost
            self.error = e
        finally:
            self._done.set()

    def _publish(self, seg: SegmentResult) -> None:
        snap = Snapshot(iteration=seg.iteration, w=seg.w_consensus,
                        objective=seg.objective)
        to_checkpoint(snap, self.root, quantize=self.quantize,
                      keep=self.keep, lam=self.cfg.lam)
        self.published.append(seg.iteration)

    def wait(self, timeout: float | None = None) -> bool:
        """Block until training finishes (or ``timeout`` seconds); True when
        done. Does not raise the captured error — use :meth:`join` for that."""
        return self._done.wait(timeout)

    def join(self, timeout: float | None = None) -> SegmentResult | None:
        """Join the training thread and return the final segment result.

        Re-raises a training-thread exception here, on the caller's thread.
        Returns None only when ``timeout`` expired before completion."""
        self._thread.join(timeout)
        if self.error is not None:
            raise RuntimeError("training thread failed") from self.error
        return self.final if self._done.is_set() else None

    @property
    def running(self) -> bool:
        """True while the training thread is alive."""
        return self._thread.is_alive()
