"""Live checkpoint publisher: train in the background, flush every segment.

The producing half of the live train-to-serve loop. :class:`TrainPublisher`
runs :func:`repro.core.gadget.gadget_train_stream` — trajectory bit-identical
to one ``gadget_train`` call — in a daemon thread, and at every segment
boundary exports the current consensus model through
:func:`repro.serve.snapshot.to_checkpoint`:

  * **versioned** — the checkpoint step is the global training iteration, so
    versions are strictly monotone across a run;
  * **atomic** — ``repro.checkpoint`` stages in a temp dir and publishes via
    one ``os.rename``, so a concurrently-polling server never sees a torn
    checkpoint;
  * **discoverable** — each save advances the root's ``LATEST`` pointer,
    which ``SvmServer.watch(root).maybe_reload()`` polls between drains.

Publish cadence is ``segment_iters`` (training iterations per checkpoint);
``keep=0`` (the default here, unlike the offline exporter) retains every
version so a reader can never race a rotation and rollback targets survive.

Hardening (the fault-tolerance layer):

  * **Publish retries** — transient checkpoint-write failures (full disk,
    flaky network filesystem) are retried with capped exponential backoff
    before the run is declared failed; attempts are counted in
    :attr:`publish_retries_used`.
  * **Error surfacing** — a training-thread exception is captured, flagged
    via :attr:`error`, and re-raised by *both* :meth:`join` and :meth:`wait`
    — a supervisor parked on either call can never mistake a crashed run for
    a finished one. The publisher itself never kills the serving process
    that owns it.
  * **Crash-resume** — ``save_train_state=True`` embeds the full per-node
    :class:`~repro.core.gadget.TrainState` in every checkpoint, and
    ``resume="latest"`` (or an explicit ``TrainState``) continues a killed
    run from its last published state, bit-identical to the uninterrupted
    trajectory (the stream keys its PRNG on the global iteration counter).
"""
from __future__ import annotations

import threading
import time

import numpy as np

from repro import checkpoint as ckpt
from repro.core.gadget import (GadgetConfig, NonFiniteWeightsError,
                               SegmentResult, TrainState, gadget_train_stream)
from repro.serve.snapshot import (Snapshot, latest_train_state, to_checkpoint)
from repro.telemetry import trace as tmtr
from repro.telemetry.registry import Registry
from repro.telemetry.train import TrainTelemetry

__all__ = ["TrainPublisher"]


class TrainPublisher:
    """Background trainer that publishes a servable checkpoint per segment.

    ``X_parts``/``y_parts``/``cfg``/``n_counts`` follow the
    ``gadget_train`` conventions (dense (m, n_i, d) or ``EllPartitions``
    planes; (m, n_i) ±1 labels with 0 on pad rows). ``root`` is the
    checkpoint directory the serving side watches. ``segment_iters`` sets
    the publish cadence; ``quantize`` (None | "int8") and ``keep`` pass
    through to :func:`~repro.serve.snapshot.to_checkpoint`.

    Fault tolerance:

    * ``publish_retries`` / ``publish_backoff`` / ``publish_backoff_cap`` —
      each checkpoint write gets ``1 + publish_retries`` attempts, sleeping
      ``publish_backoff * 2**k`` (capped) between them; only the final
      failure propagates. :attr:`publish_retries_used` counts retries spent.
    * ``save_train_state=True`` embeds the resumable
      :class:`~repro.core.gadget.TrainState` in every checkpoint.
    * ``resume`` — an explicit ``TrainState``, or ``"latest"`` to probe
      ``root`` for the newest embedded state (falling back to a fresh run
      when none exists); the resolved choice is recorded in
      :attr:`resumed_from` (the resume iteration, or None for fresh).

    Telemetry: ``telemetry`` (a :class:`repro.telemetry.TrainTelemetry`)
    forwards to the stream, attaching per-segment flight-recorder readings to
    every ``SegmentResult``; ``registry`` is where the publisher's own series
    land — a ``publish.seconds`` span per flushed segment plus
    ``publish.segments`` / ``publish.retries`` counters, and the segment's
    disagreement/objective/drop readings mirrored beside them. Private per
    publisher by default; pass a shared registry for a unified dump.

    Tracing: ``trace=True`` turns on version-lineage tracing — the stream
    roots one :class:`~repro.telemetry.trace.TraceContext` per segment
    (``train.segment`` span on :attr:`registry`), each publish extends it
    with a ``publish.seconds`` span (plus one ``publish.attempt`` child span
    per write attempt, error-annotated on OSError retries — same trace_id
    across attempts) and a ``publish.visible`` event marking the LATEST
    pointer handoff (emitted immediately before the pointer write, so every
    watcher swap timestamp causally follows it — the checkpoint is written
    unpointed and only becomes observable at the handoff), and the context
    is embedded in the checkpoint manifest
    (``extra["trace"]``) so the serving watcher's swap span links back. On
    ``resume="latest"`` the fresh run starts new traces but stamps the prior
    run's trace_id onto the first segment span as ``resumed_from_trace``.
    ``trace=False`` (default) emits nothing — byte-identical telemetry to
    the pre-tracing publisher.

    Lifecycle: ``start()`` launches the daemon thread and returns ``self``;
    ``join()`` blocks until training converges (or ``cfg.max_iters``) and
    returns the final :class:`~repro.core.gadget.SegmentResult`. Both
    ``join()`` and a completed ``wait(timeout)`` re-raise a training-thread
    exception. ``published`` grows by one step number per flushed checkpoint
    (monotone — append-only under the GIL, safe to read concurrently).
    """

    def __init__(self, X_parts, y_parts, cfg: GadgetConfig = GadgetConfig(), *,
                 root: str, segment_iters: int, n_counts=None,
                 quantize: str | None = None, keep: int = 0,
                 save_train_state: bool = False,
                 resume: TrainState | str | None = None,
                 publish_retries: int = 3, publish_backoff: float = 0.05,
                 publish_backoff_cap: float = 1.0,
                 telemetry: TrainTelemetry | None = None,
                 registry: Registry | None = None,
                 trace: bool = False):
        if resume is not None and resume != "latest" \
                and not isinstance(resume, TrainState):
            raise ValueError(
                f"resume must be None, 'latest', or a TrainState; got {resume!r}")
        if publish_retries < 0:
            raise ValueError(f"publish_retries must be >= 0, got {publish_retries}")
        self.root = root
        self.cfg = cfg
        self.segment_iters = int(segment_iters)
        self.quantize = quantize
        self.keep = int(keep)
        self.save_train_state = bool(save_train_state)
        self.resume = resume
        self.resumed_from: int | None = None
        self.publish_retries = int(publish_retries)
        self.publish_backoff = float(publish_backoff)
        self.publish_backoff_cap = float(publish_backoff_cap)
        self.publish_retries_used = 0
        self.telemetry = telemetry
        # publish.* series land here: one "publish.seconds" span per flushed
        # segment, "publish.segments" / "publish.retries" counters, and the
        # per-segment train.* gauges the stream writes when telemetry is on.
        self.registry = registry if registry is not None else Registry()
        self.trace = bool(trace)
        self._trace_link: str | None = None
        self._data = (X_parts, y_parts, n_counts)
        self.published: list[int] = []
        self.final: SegmentResult | None = None
        self.error: BaseException | None = None
        self._done = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="gadget-train-publisher")

    # ----------------------------------------------------------- lifecycle

    def start(self) -> "TrainPublisher":
        """Launch the training thread (idempotence not attempted — one
        publisher is one training run). Returns ``self`` for chaining."""
        self._thread.start()
        return self

    def _resolve_resume(self) -> TrainState | None:
        """Materialize the ``resume`` argument into a TrainState (or None).

        When tracing and resuming from the watched root, also recover the
        prior run's trace_id from the resume checkpoint's manifest — the
        fresh run's first segment span links back to it
        (``resumed_from_trace``)."""
        if self.resume is None:
            return None
        state = (latest_train_state(self.root) if self.resume == "latest"
                 else self.resume)
        self.resumed_from = None if state is None else int(state.iteration)
        if self.trace and state is not None and self.resume == "latest":
            try:
                extra = ckpt.read_manifest(self.root).get("extra") or {}
                prior = tmtr.TraceContext.from_extra(extra.get("trace"))
                self._trace_link = prior.trace_id if prior else None
            except (OSError, ValueError):
                self._trace_link = None
        return state

    def _run(self) -> None:
        X_parts, y_parts, n_counts = self._data
        try:
            for seg in gadget_train_stream(X_parts, y_parts, self.cfg,
                                           segment_iters=self.segment_iters,
                                           n_counts=n_counts,
                                           resume=self._resolve_resume(),
                                           telemetry=self.telemetry,
                                           trace=self.trace,
                                           trace_link=self._trace_link,
                                           trace_registry=self.registry):
                self._publish(seg)
                self.final = seg
        except BaseException as e:  # surfaced via join()/wait()/error
            self.error = e
        finally:
            self._done.set()

    def _publish(self, seg: SegmentResult) -> None:
        if not np.all(np.isfinite(np.asarray(seg.w_consensus))):
            # Defense in depth: the stream raises its own typed failure at
            # the segment boundary, so this only fires when a caller hands
            # _publish a crafted/corrupted segment — either way a NaN plane
            # must never become a published checkpoint a watcher would swap
            # in. Surfaced like any training failure via join()/wait().
            self.registry.counter("publish.nonfinite").inc()
            raise NonFiniteWeightsError(seg.iteration, context="publish")
        snap = Snapshot(iteration=seg.iteration, w=seg.w_consensus,
                        objective=seg.objective)
        train_state = None
        if self.save_train_state:
            train_state = TrainState(iteration=seg.iteration, W=seg.W,
                                     W_sum=seg.W_sum)
        # The publish span is a child of the segment's lineage root; its
        # context rides into the checkpoint manifest so the serving watcher
        # can link its swap span back. TracedSpan (vs the plain registry
        # span) closes on the exception path too — a final-attempt OSError
        # still records the span, error-annotated.
        pub_ctx = seg.trace.child() if seg.trace is not None else None
        span_cm = (tmtr.TracedSpan(self.registry, "publish.seconds", pub_ctx,
                                   iteration=seg.iteration)
                   if pub_ctx is not None
                   else self.registry.span("publish.seconds",
                                           iteration=seg.iteration))
        with span_cm:
            for attempt in range(self.publish_retries + 1):
                t_att = time.monotonic()
                try:
                    # point=False: the checkpoint is complete on disk but
                    # invisible to pointer-following watchers until the
                    # explicit handoff below — publish records must land
                    # before any swap can observe the version, or chain
                    # timestamps go non-monotone under thread scheduling.
                    to_checkpoint(snap, self.root, quantize=self.quantize,
                                  keep=self.keep, lam=self.cfg.lam,
                                  train_state=train_state,
                                  trace=(pub_ctx.to_extra()
                                         if pub_ctx is not None else None),
                                  point=False)
                    if pub_ctx is not None:
                        tmtr.emit_span(self.registry, "publish.attempt",
                                       pub_ctx.child(),
                                       time.monotonic() - t_att,
                                       attempt=attempt)
                    break
                except OSError as e:
                    if pub_ctx is not None:
                        # per-attempt child span, same trace_id as the run:
                        # the retry story is reconstructable from the JSONL
                        tmtr.emit_span(self.registry, "publish.attempt",
                                       pub_ctx.child(),
                                       time.monotonic() - t_att,
                                       attempt=attempt,
                                       error=f"OSError: {e}")
                    if attempt == self.publish_retries:
                        raise
                    self.publish_retries_used += 1
                    self.registry.counter("publish.retries").inc()
                    time.sleep(min(self.publish_backoff * 2 ** attempt,
                                   self.publish_backoff_cap))
        if pub_ctx is not None:
            # emitted after the publish span record closes and BEFORE the
            # pointer handoff, so chain timestamps are causally monotone:
            # segment-end < publish-end <= visible <= pointer-land <= swap
            tmtr.emit_event(self.registry, "publish.visible", pub_ctx,
                            iteration=seg.iteration)
        # the handoff: only now can a watcher's maybe_reload observe the
        # version (monotone by construction — publisher steps only grow)
        ckpt.point_latest(self.root, seg.iteration)
        self.registry.counter("publish.segments").inc()
        if seg.telemetry is not None:
            # Mirror the segment's flight-recorder readings next to the
            # publish series, so one registry tells the whole producer story.
            self.registry.gauge("train.final_disagreement").set(
                seg.telemetry.disagreement)
            self.registry.gauge("train.objective").set(seg.telemetry.objective)
            self.registry.counter("train.fault_drops").inc(seg.telemetry.drops)
        self.published.append(seg.iteration)

    def _raise_error(self) -> None:
        if self.error is not None:
            raise RuntimeError("training thread failed") from self.error

    def wait(self, timeout: float | None = None) -> bool:
        """Block until training finishes (or ``timeout`` seconds); True when
        done. Re-raises the captured training-thread error once the run is
        done, so a supervisor parked here cannot mistake a crash for
        success; a timeout returns False without consuming the error."""
        done = self._done.wait(timeout)
        if done:
            self._raise_error()
        return done

    def join(self, timeout: float | None = None) -> SegmentResult | None:
        """Join the training thread and return the final segment result.

        Re-raises a training-thread exception here, on the caller's thread.
        Returns None only when ``timeout`` expired before completion."""
        self._thread.join(timeout)
        self._raise_error()
        return self.final if self._done.is_set() else None

    @property
    def running(self) -> bool:
        """True while the training thread is alive."""
        return self._thread.is_alive()
