"""SvmServer: snapshot-and-serve engine over the fused predict kernels.

The serving half of the anytime loop: load a model (live
:class:`~repro.serve.snapshot.Snapshot` or versioned checkpoint, f32 or
int8+scale), then answer queries three ways —

  * :meth:`score` — dense (B, d) batches through the fused scores+argmax
    kernel (``ops.dense_predict``), one launch per batch;
  * :meth:`score_sparse` — padded-ELL (B, k) batches through the query-side
    touched-block kernel (``ops.ell_predict``): the batch's compact
    touched-block-id map is built on host (``formats.block_map``) and steers
    the W DMA, so a CCAT-shaped sparse query touches only the d-blocks its
    features live in;
  * :func:`make_mesh_scorer` — the batch-parallel ``shard_map`` path: w
    replicated (closed over), queries sharded over the mesh's batch axis, the
    multi-device shape of the ROADMAP's serve-heavy-traffic goal.

Every distinct static shape is jitted once and cached;
``stats()["distinct_shapes"]`` is the measured compile count the bucketed
batcher's ≤ len(buckets) guarantee is asserted against
(``benchmarks/serve_bench.py``). The same stats dict tracks blocks visited by
the sparse path vs the dense sweep equivalent — the serving twin of the
training bench's ``blocks_visited_ratio``.

Live updates: the compiled executables take the weight plane as a *runtime*
argument, so :meth:`SvmServer.swap_weights` replaces the model under load
without invalidating the jit cache — same shapes, same executables,
``distinct_shapes`` stays flat across swaps (the hot-swap tests pin this).
:meth:`SvmServer.watch` + :meth:`SvmServer.maybe_reload` turn that into the
consuming half of the live train-to-serve loop: between batcher drains the
server polls the checkpoint root's ``LATEST`` pointer
(``repro.checkpoint.read_latest``) and hot-swaps whenever the version moved —
forward when :class:`~repro.serve.publisher.TrainPublisher` publishes,
backward when an operator rolls back via ``checkpoint.point_latest``.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.kernels.hinge_subgrad import ops as hinge_ops
from repro.kernels.hinge_subgrad import ref as hinge_ref
from repro.serve import snapshot as snap_mod
from repro.serve.batcher import Bucket
from repro.sparse.formats import DEFAULT_BUCKET_BLK_D, block_map
from repro.telemetry import trace as tmtr
from repro.telemetry.registry import Registry

__all__ = ["SvmServer", "make_mesh_scorer"]

# Counters every server keeps on its registry (as ``serve.<key>`` series);
# stats() reads them back under these exact keys for back-compat.
_STAT_KEYS = ("queries", "batches", "sparse_batches", "blocks_visited",
              "dense_block_equivalent", "cap_overflows", "swaps",
              "reload_errors", "quarantined", "plane_swaps")


class SvmServer:
    """Load-once, score-many serving engine for GADGET SVM models.

    ``W``: (d,) binary weights or (C, d) one-vs-rest class matrix.
    ``use_kernels=None`` (default) follows the package convention — Pallas
    kernels wherever they compile natively, jnp oracles where they would only
    interpret — so a CPU replica and a TPU replica run the same engine.
    ``use_kernels=True`` forces the kernel path (interpret off-TPU; what CI
    exercises). ``meta`` carries the checkpoint's manifest ``extra`` when
    loaded from disk (iteration, objective, export dtype). ``registry``: the
    telemetry registry the ``serve.*`` counters and per-call kernel
    launch/bytes accounting land on — private per server by default, pass a
    shared one to fold several components into one dump.
    """

    def __init__(self, W, *, meta: dict | None = None,
                 blk_d: int = DEFAULT_BUCKET_BLK_D,
                 use_kernels: bool | None = None,
                 reload_quarantine: int = 3,
                 registry: Registry | None = None):
        W = np.asarray(W, np.float32)
        if W.ndim not in (1, 2):
            raise ValueError(f"W must be (d,) or (C, d), got {W.shape}")
        if reload_quarantine < 1:
            raise ValueError(
                f"reload_quarantine must be >= 1, got {reload_quarantine}")
        self.W = W
        self.binary = W.ndim == 1
        self.d = int(W.shape[-1])
        self.n_classes = 1 if self.binary else int(W.shape[0])
        self.meta = dict(meta or {})
        self.blk_d = int(blk_d)
        self.n_d_blocks = -(-self.d // self.blk_d)
        if use_kernels is None:
            use_kernels = not hinge_ops.default_interpret()
        self.use_kernels = bool(use_kernels)
        self.reload_quarantine = int(reload_quarantine)
        self._W_dev = jnp.asarray(W)
        # Weight planes the degradation ladder can step between: "f32" is the
        # full-precision model, "int8" (built lazily on first use) is the
        # int8-quantize→dequantize image of the same weights. Same shape and
        # dtype, so switching planes is a runtime-argument swap — the jit
        # cache (and therefore ``distinct_shapes``) never moves.
        self._planes: dict[str, jax.Array] = {"f32": self._W_dev}
        self._plane = "f32"
        self._compiled: dict[tuple, object] = {}
        self._watch_root: str | None = None
        self._watch_step: int | None = None
        self._reload_failures: dict[int, int] = {}
        # (step, swap ctx) awaiting its first scoring call — the lineage
        # chain's terminal "serve.first_score" event fires once per swap
        self._pending_first_score: tuple[int, tmtr.TraceContext] | None = None
        # All serving counters live on a telemetry registry (private per
        # server unless one is shared in) — stats() is a *view* over it, and
        # kernel launch/bytes accounting lands beside the serve counters.
        self.registry = registry if registry is not None else Registry()

    def _count(self, key: str, n: int = 1) -> None:
        self.registry.counter(f"serve.{key}").inc(n)

    # ------------------------------------------------------------- loading

    @classmethod
    def from_snapshot(cls, snap: snap_mod.Snapshot, **kw) -> "SvmServer":
        """Serve a live training snapshot (no disk round-trip)."""
        meta = {"iteration": snap.iteration, "objective": snap.objective}
        return cls(snap.w, meta=meta, **kw)

    @classmethod
    def load(cls, root: str, step: int | None = None, **kw) -> "SvmServer":
        """Restore a ``serve.snapshot.to_checkpoint`` export (f32 or int8 —
        quantized weights are dequantized once here; scoring runs f32)."""
        w, extra = snap_mod.from_checkpoint(root, step)
        return cls(w, meta=extra, **kw)

    @classmethod
    def watch(cls, root: str, **kw) -> "SvmServer":
        """Serve the checkpoint the root's ``LATEST`` pointer designates and
        keep watching it: the returned server's :meth:`maybe_reload` polls
        the pointer and hot-swaps when the published version moves (forward
        — a live :class:`~repro.serve.publisher.TrainPublisher` — or
        backward — an operator rollback via ``checkpoint.point_latest``).
        Call ``maybe_reload()`` between batcher drains; it is cheap (one
        small file read) when nothing changed."""
        step = ckpt.read_latest(root)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoints under {root}")
        t0 = time.monotonic()
        w, extra = snap_mod.from_checkpoint(root, step)
        srv = cls(w, meta=extra, **kw)
        srv._watch_root = root
        srv._watch_step = step
        # the initial install is a swap too (version 0 of this server's
        # life) — without it the first published version's lineage chain
        # would have no serve-side stages
        srv._emit_swap_span(step, time.monotonic() - t0, extra=extra)
        return srv

    # ------------------------------------------------------------ hot swap

    def swap_weights(self, W, *, meta: dict | None = None) -> None:
        """Replace the served model in place, under load, without recompiling.

        ``W`` must match the current model's shape — (d,) vs (C, d) and both
        extents — because shapes key the compiled-executable cache; the cache
        itself is untouched (every executable takes the weight plane as a
        runtime argument), so ``stats()["distinct_shapes"]`` is invariant
        across swaps and in-flight batches simply score against whichever
        plane was installed when their launch read it. A shape change is a
        different model: build a new server. ``meta`` (e.g. the new
        checkpoint's manifest ``extra``) replaces :attr:`meta` when given."""
        W = np.asarray(W, np.float32)
        if W.shape != self.W.shape:
            raise ValueError(
                f"hot swap must preserve the weight shape {self.W.shape} "
                f"(compiled executables are shape-keyed), got {W.shape}")
        self.W = W
        had_int8 = "int8" in self._planes
        self._planes = {"f32": jnp.asarray(W)}
        if had_int8:
            # keep the degraded plane in lockstep with the live model, so a
            # hot swap while degraded serves the NEW weights' int8 image
            self._planes["int8"] = self._build_int8_plane()
        self._W_dev = self._planes[self._plane]
        if meta is not None:
            self.meta = dict(meta)
        self._count("swaps")

    def maybe_reload(self) -> int | None:
        """Poll the watched root once; hot-swap if ``LATEST`` moved.

        Returns the newly-installed step when a swap happened, None when the
        pointer is unchanged (the overwhelmingly common case — one small
        file read, no array I/O). Any failure mid-reload (pointer damage, a
        checkpoint deleted between pointer read and restore, a bad export)
        counts ``stats()["reload_errors"]`` and keeps serving the current
        model — a live replica must never wedge on a bad publish.

        A step that fails to load ``reload_quarantine`` times is
        *quarantined*: the server stops retrying it every poll (no repeated
        array I/O against a known-bad export, counted once in
        ``stats()["quarantined"]``) while continuing to watch the pointer —
        the next *different* published step gets a fresh chance, and an
        operator rollback to a good older step swaps normally."""
        if self._watch_root is None:
            raise RuntimeError(
                "server is not watching a checkpoint root — construct it "
                "with SvmServer.watch(root)")
        try:
            step = ckpt.read_latest(self._watch_root)
        except Exception:
            self._count("reload_errors")
            return None
        if step is None or step == self._watch_step:
            return None
        fails = self._reload_failures.get(step, 0)
        if fails >= self.reload_quarantine:
            return None
        t0 = time.monotonic()
        try:
            w, extra = snap_mod.from_checkpoint(self._watch_root, step)
            self.swap_weights(w, meta=extra)
        except Exception as e:
            self._count("reload_errors")
            self._reload_failures[step] = fails + 1
            quarantined = fails + 1 == self.reload_quarantine
            if quarantined:
                self._count("quarantined")
            self._emit_swap_span(step, time.monotonic() - t0, extra=None,
                                 error=("quarantined" if quarantined
                                        else f"{type(e).__name__}: {e}"))
            return None
        self._watch_step = step
        self._reload_failures.pop(step, None)
        self._emit_swap_span(step, time.monotonic() - t0, extra=extra)
        return step

    def _emit_swap_span(self, step: int, seconds: float, *,
                        extra: dict | None, error: str | None = None) -> None:
        """Emit the lineage ``serve.swap`` span for one reload attempt.

        Linked through the checkpoint manifest's ``extra["trace"]`` (the
        publish span's context); the failed-load path re-reads the manifest
        best-effort since ``from_checkpoint`` never returned. No-op for
        untraced checkpoints, so tracing off emits nothing. A successful
        swap arms the one-shot ``serve.first_score`` event the next scoring
        call completes the chain with."""
        trace = (extra or {}).get("trace")
        if trace is None:
            try:
                manifest = ckpt.read_manifest(self._watch_root, step)
                trace = (manifest.get("extra") or {}).get("trace")
            except Exception:
                return
        parent = tmtr.TraceContext.from_extra(trace)
        if parent is None:
            return
        ctx = parent.child()
        tmtr.emit_span(self.registry, "serve.swap", ctx, seconds,
                       version=step, error=error)
        if error is None:
            self._pending_first_score = (step, ctx)

    def _note_first_score(self) -> None:
        """Fire the pending ``serve.first_score`` lineage event, if armed —
        called by every scoring path; one event per successful swap."""
        if self._pending_first_score is None:
            return
        step, ctx = self._pending_first_score
        self._pending_first_score = None
        tmtr.emit_event(self.registry, "serve.first_score", ctx.child(),
                        version=step)

    @property
    def quarantined_steps(self) -> list[int]:
        """Checkpoint steps the watcher has given up retrying (sorted)."""
        return sorted(s for s, n in self._reload_failures.items()
                      if n >= self.reload_quarantine)

    # ------------------------------------------------- degradation ladder

    def _build_int8_plane(self) -> "jax.Array":
        """The int8-quantize→dequantize image of the current weights —
        what an int8 export of this model would serve (same shape/dtype as
        the f32 plane, so it swaps in without touching the jit cache)."""
        q, scale = snap_mod.quantize_int8(self.W)
        return jnp.asarray(snap_mod.dequantize_int8(q, scale))

    @property
    def plane(self) -> str:
        """The weight plane currently being served (``"f32"`` or ``"int8"``)."""
        return self._plane

    @property
    def degraded(self) -> bool:
        """True while the server is on a degraded (non-f32) weight plane."""
        return self._plane != "f32"

    def set_plane(self, name: str) -> None:
        """Serve from the named weight plane — the overload ladder's
        precision step (``repro.serve.overload.DegradeLadder`` drives this).

        ``"int8"`` installs the quantize→dequantize image of the current
        weights (built on device the first time — call once at startup to
        pre-warm so a mid-overload step-down never pays the build);
        ``"f32"`` restores full precision. Either way the swap is a runtime
        argument change: same shapes, same compiled executables,
        ``stats()["distinct_shapes"]`` stays flat across ladder transitions
        (asserted by ``benchmarks/overload_bench.py``). Composes with
        :meth:`swap_weights`: a hot swap while degraded re-quantizes the new
        weights and keeps serving the degraded plane."""
        if name not in ("f32", "int8"):
            raise ValueError(f"unknown weight plane {name!r} "
                             "(expected 'f32' or 'int8')")
        if name == "int8" and "int8" not in self._planes:
            self._planes["int8"] = self._build_int8_plane()
        if name != self._plane:
            self._count("plane_swaps")
        self._plane = name
        self._W_dev = self._planes[name]
        self.registry.gauge("serve.degraded").set(float(self.degraded))

    # ------------------------------------------------------------- scoring

    def _jit(self, key, build):
        fn = self._compiled.get(key)
        if fn is None:
            fn = self._compiled[key] = build()
        return fn

    def score(self, X) -> tuple[np.ndarray, np.ndarray]:
        """Dense batch: X (B, d) → (scores, labels) — binary ((B,), ±1 f32),
        multiclass ((B, C), int32 argmax). One fused kernel launch per call;
        one compile per distinct B."""
        X = np.asarray(X, np.float32)
        B, d = X.shape
        if d != self.d:
            raise ValueError(f"query d={d} != model d={self.d}")
        if self.use_kernels:
            fn = self._jit(("dense", B), lambda: jax.jit(functools.partial(
                hinge_ops.dense_predict, interpret=hinge_ops.default_interpret())))
        else:
            fn = self._jit(("dense", B), lambda: jax.jit(self._dense_oracle))
        scores, labels = fn(self._W_dev, jnp.asarray(X))
        self._count("queries", B)
        self._count("batches")
        self._note_first_score()
        if self.use_kernels:
            # The kernel runs inside jit, so the eager self-recording in ops
            # never fires — account the launch here, at the host boundary.
            hinge_ops.record_launch("dense_predict", registry=self.registry,
                                    B=B, d=d, C=self.n_classes)
        return np.asarray(scores), np.asarray(labels)

    def _dense_oracle(self, W, X):
        scores = hinge_ref.predict_scores_ref(W[None] if self.binary else W, X)
        return hinge_ops._finish_predict(scores, jnp.argmax(scores, axis=-1)
                                         .astype(jnp.int32), X.shape[0],
                                         self.n_classes, self.binary)

    def score_sparse(self, cols, vals, *, n_blocks_max: int | None = None
                     ) -> tuple[np.ndarray, np.ndarray]:
        """Sparse ELL batch: (B, k) padded planes → (scores, labels).

        ``n_blocks_max`` is the static map width (per-bucket constant when
        called through the batcher — one compile per bucket); defaults to the
        structural ``min(B·k, n_d_blocks)``. The touched-block map is built
        on host over the *actual batch* — blocks the batch doesn't live in
        are never DMA'd — and padded with sentinels to the static width.

        A batch touching more blocks than the cap (live traffic heavier than
        the calibration sample) is still served correctly: the map widens to
        the realized count, rounded up to an 8-multiple so over-cap traffic
        adds a bounded number of shapes, and ``stats()["cap_overflows"]``
        counts it — the signal to re-run ``calibrate_buckets``. It never
        raises mid-drain, so the batcher queue cannot wedge on one batch."""
        cols = np.asarray(cols, np.int32)
        vals = np.asarray(vals, np.float32)
        B, k = cols.shape
        if k == 0:
            cols = np.zeros((B, 1), np.int32)
            vals = np.zeros((B, 1), np.float32)
            k = 1
        cap = hinge_ops.resolve_block_cap(B, k, n_d_blocks=self.n_d_blocks,
                                          n_blocks_max=n_blocks_max)
        live = len(np.unique(cols[vals != 0] // self.blk_d))
        if live > cap:
            cap = min(-(-live // 8) * 8, self.n_d_blocks)
            self._count("cap_overflows")
        bm = block_map(cols[None], vals[None], self.blk_d, self.n_d_blocks, cap)[0]
        key = ("ell", B, k, cap)
        if self.use_kernels:
            fn = self._jit(key, lambda: jax.jit(functools.partial(
                hinge_ops.ell_predict, blk_d=self.blk_d,
                interpret=hinge_ops.default_interpret())))
            scores, labels = fn(self._W_dev, jnp.asarray(cols),
                                jnp.asarray(vals), block_ids=jnp.asarray(bm))
        else:
            fn = self._jit(key, lambda: jax.jit(self._ell_oracle))
            scores, labels = fn(self._W_dev, jnp.asarray(cols), jnp.asarray(vals))
        self._count("queries", B)
        self._count("batches")
        self._count("sparse_batches")
        self._note_first_score()
        self._count("blocks_visited", live)
        self._count("dense_block_equivalent", self.n_d_blocks)
        if self.use_kernels:
            hinge_ops.record_launch("ell_predict", registry=self.registry,
                                    blocks_visited=live, B=B, k=k,
                                    C=self.n_classes, blk_d=self.blk_d,
                                    n_blocks_max=cap)
        return np.asarray(scores), np.asarray(labels)

    def _ell_oracle(self, W, cols, vals):
        scores = hinge_ref.ell_predict_scores_ref(
            W[None] if self.binary else W, cols, vals)
        return hinge_ops._finish_predict(scores, jnp.argmax(scores, axis=-1)
                                         .astype(jnp.int32), cols.shape[0],
                                         self.n_classes, self.binary)

    def scorer_for(self, bucket: Bucket | None = None):
        """The ``score_fn`` the micro-batcher drains with. Each batch is
        scored with its own bucket's static ``n_blocks_max`` (the batcher
        passes the bucket per batch), so every batch of a bucket reuses one
        compiled executable; pass ``bucket`` to pin one cap for every batch
        instead."""
        def score_fn(b: Bucket, cols, vals):
            cap = (bucket or b).n_blocks_max
            return self.score_sparse(cols, vals, n_blocks_max=cap)
        return score_fn

    # --------------------------------------------------------------- stats

    def stats(self) -> dict:
        """Serving counters: queries/batches served, ``distinct_shapes``
        (jit-cache size — the compile count asserted flat across hot swaps
        *and* degradation-ladder transitions), ``swaps`` / ``reload_errors``
        / ``quarantined`` from the watch path, the sparse blocks-visited
        accounting vs a dense sweep, and the overload ladder's visible state
        (``degraded`` 0/1, the served ``plane`` name, ``plane_swaps``).

        A *view* over :attr:`registry` (the ``serve.*`` counter series) with
        the historical flat keys preserved — consumers that want the kernel
        launch/bytes series too should read the registry directly."""
        s = {k: int(self.registry.value(f"serve.{k}")) for k in _STAT_KEYS}
        s["distinct_shapes"] = len(self._compiled)
        s["blocks_visited_ratio"] = (
            s["blocks_visited"] / s["dense_block_equivalent"]
            if s["dense_block_equivalent"] else float("nan"))
        s["degraded"] = int(self.degraded)
        s["plane"] = self._plane
        return s


def make_mesh_scorer(W, *, mesh=None, axis: str = "batch",
                     use_kernels: bool | None = None):
    """Batch-parallel serving step: w replicated, queries sharded.

    Returns ``scorer(X) -> (scores, labels)`` where X's leading axis is
    sharded over ``mesh``'s ``axis`` (defaults to a 1-D mesh over every local
    device) and the class weights are closed over — replicated to each shard,
    never gathered. B must divide by the axis size (pad with zero rows; they
    score 0 and slice away). ``check_rep=False`` for the kernel path — jax
    has no ``pallas_call`` replication rule inside ``shard_map`` yet, same
    pin as the training mesh step."""
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    if mesh is None:
        mesh = Mesh(np.array(jax.devices()), (axis,))
    W_dev = jnp.asarray(np.asarray(W, np.float32))
    if use_kernels is None:
        use_kernels = not hinge_ops.default_interpret()
    binary = W_dev.ndim == 1

    def per_shard(Xl):
        if use_kernels:
            return hinge_ops.dense_predict(
                W_dev, Xl, interpret=hinge_ops.default_interpret())
        scores = hinge_ref.predict_scores_ref(
            W_dev[None] if binary else W_dev, Xl)
        labels = jnp.argmax(scores, axis=-1).astype(jnp.int32)
        return hinge_ops._finish_predict(scores, labels, Xl.shape[0],
                                         1 if binary else W_dev.shape[0], binary)

    sharded = shard_map(per_shard, mesh=mesh, in_specs=P(axis),
                        out_specs=(P(axis), P(axis)), check_rep=False)
    return jax.jit(sharded)
