"""Mixture-of-Experts channel mixing (Mixtral / Qwen2-MoE style).

Capacity-based top-k routing with **scatter dispatch / gather combine**:
tokens are scatter-added into per-expert capacity buffers (E, C, D) and
gathered back weighted by renormalized router probabilities. This avoids the
GShard (tokens, experts, capacity) one-hot dispatch tensor, which at 60
experts × 64k tokens/device would materialize terabytes; the scatter form
keeps live memory at O(E·C·D) and lowers to dynamic-scatter/gather HLO that
SPMD partitions over the `model` (expert) axis.

Overflowing tokens (beyond capacity_factor) are dropped and pass through via
the residual — standard Switch/GLaM semantics. Auxiliary outputs: Switch
load-balance loss and router z-loss (summed into the objective by the caller).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import MoEConfig

__all__ = ["init_moe", "moe_apply", "MoEAux"]


class MoEAux(NamedTuple):
    load_balance_loss: jax.Array
    router_z_loss: jax.Array
    expert_fraction: jax.Array  # (E,) fraction of top-1 tokens per expert


def init_moe(key, d_model: int, cfg: MoEConfig, mlp_kind: str, dtype=jnp.float32):
    kr, ke, ks = jax.random.split(key, 3)
    e, dff = cfg.n_experts, cfg.d_expert
    s_in = 1.0 / jnp.sqrt(d_model)
    s_out = 1.0 / jnp.sqrt(dff)
    p = {
        "router": jax.random.normal(kr, (d_model, e), jnp.float32) * s_in,
        # stacked expert FFNs (gated SiLU): sharded on E over the model axis
        "wi": jax.random.normal(jax.random.fold_in(ke, 0), (e, d_model, dff), dtype) * s_in,
        "wg": jax.random.normal(jax.random.fold_in(ke, 1), (e, d_model, dff), dtype) * s_in,
        "wo": jax.random.normal(jax.random.fold_in(ke, 2), (e, dff, d_model), dtype) * s_out,
    }
    if cfg.d_shared:
        p["shared"] = L.init_mlp(ks, d_model, cfg.d_shared, mlp_kind, dtype)
    return p


def capacity(n_tokens: int, cfg: MoEConfig) -> int:
    c = int(cfg.capacity_factor * cfg.top_k * n_tokens / cfg.n_experts)
    return max(cfg.top_k, min(n_tokens, c))


def _group_moe(p, xt: jax.Array, cfg: MoEConfig, cap: int):
    """Route one token group (S, D) -> (y (S,D), lb_parts, z_parts, frac).

    Groups are batch rows (GShard "G" axis): routing state stays O(S·E),
    the group axis shards over `data`, and capacity buffers stay per-group —
    without this, global routing materializes (E, B·S·k/E, D) monsters.
    """
    s, d = xt.shape
    e, k = cfg.n_experts, cfg.top_k

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])  # (S, E)
    probs = jax.nn.softmax(logits, axis=-1)

    topv, topi = jax.lax.top_k(probs, k)                       # (S, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # rank of each (token, choice) within its expert -> capacity slot
    onehot = jax.nn.one_hot(topi, e, dtype=jnp.int32)          # (S, k, E)
    flat = onehot.reshape(s * k, e)
    ranks = (jnp.cumsum(flat, axis=0) - flat)                  # exclusive prefix count
    pos = jnp.sum(ranks.reshape(s, k, e) * onehot, axis=-1)    # (S, k)
    keep = pos < cap

    eid = topi.reshape(-1)                                     # (S*k,)
    slot = jnp.where(keep, pos, cap).reshape(-1)               # overflow -> sink slot
    toks = jnp.broadcast_to(xt[:, None, :], (s, k, d)).reshape(-1, d)

    # dispatch: scatter-add into (E, C+1, D); slot C is the overflow sink.
    # constrain() after each step keeps the group (vmapped batch) axis
    # sharded — XLA's scatter partitioner otherwise replicates the fresh
    # zeros operand and everything downstream of it.
    from repro.sharding.api import constrain

    xe = jnp.zeros((e, cap + 1, d), xt.dtype).at[eid, slot].add(toks)
    xe = constrain(xe[:, :cap], ("expert", "capacity", "embed"))

    h = jnp.einsum("ecd,edf->ecf", xe, p["wi"])
    g = jnp.einsum("ecd,edf->ecf", xe, p["wg"])
    h = constrain(h, ("expert", "capacity", "mlp"))
    g = constrain(g, ("expert", "capacity", "mlp"))
    ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * g, p["wo"])  # (E, C, D)
    ye = constrain(ye, ("expert", "capacity", "embed"))

    # combine: gather each kept choice's expert output, weight, sum over k
    gathered = ye[eid, jnp.minimum(slot, cap - 1)]             # (S*k, D)
    w = (topv.reshape(-1) * keep.reshape(-1)).astype(xt.dtype)
    y = jnp.sum((gathered * w[:, None]).reshape(s, k, d), axis=1)

    frac_routed = jnp.mean(jax.nn.one_hot(topi[:, 0], e, dtype=jnp.float32), axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    lb = e * jnp.sum(frac_routed * mean_prob)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return y, lb, z, frac_routed


def moe_apply(p, x: jax.Array, cfg: MoEConfig, mlp_kind: str) -> tuple[jax.Array, MoEAux]:
    """x: (B, S, D) -> (B, S, D). Routing is per batch-row group.

    The group vmap carries the active "batch" mesh axes as spmd_axis_name so
    the dispatch/expert buffers stay sharded on the group axis — without it
    XLA's scatter partitioner replicates them (observed: 10 GiB/device
    buffers on mixtral train_4k).
    """
    from repro.sharding.api import current_rules

    b, s, d = x.shape
    cap = capacity(s, cfg)
    r = current_rules()
    spmd = r.rules.get("batch") if r is not None else None
    vmap_kw = {"spmd_axis_name": spmd} if spmd else {}
    y, lb, z, frac = jax.vmap(lambda xt: _group_moe(p, xt, cfg, cap), **vmap_kw)(x)
    if cfg.d_shared:
        y = y + L.mlp_apply(p["shared"], x, mlp_kind)
    return y, MoEAux(load_balance_loss=jnp.mean(lb) * cfg.aux_coef,
                     router_z_loss=jnp.mean(z) * cfg.router_z_coef,
                     expert_fraction=jnp.mean(frac, axis=0))
