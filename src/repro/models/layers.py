"""Shared neural layers: norms, embeddings, rotary, MLP variants.

Parameters are plain dicts of jax arrays; every layer is a pure function
(init_*, apply pairs). Stacking across scan repeats is done by the caller
via vmapped init.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]

__all__ = [
    "rms_norm", "layer_norm", "init_norm",
    "init_embedding", "embed", "unembed",
    "rotary", "init_dense", "dense",
    "init_mlp", "mlp_apply",
]


def init_norm(d: int, dtype=jnp.float32, with_bias: bool = False) -> Params:
    p = {"scale": jnp.ones((d,), dtype)}
    if with_bias:
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def rms_norm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


def layer_norm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    if "bias" in p:
        out = out + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


def init_embedding(key: jax.Array, vocab: int, d: int, dtype=jnp.float32) -> Params:
    return {"table": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def embed(p: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["table"], tokens, axis=0)


def unembed(p: Params, x: jax.Array) -> jax.Array:
    """Tied unembedding: logits = x @ table^T (f32 accumulation)."""
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                      p["table"].astype(jnp.float32))


def rotary(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """RoPE on the last dim of x: (..., S, H, Dh), positions (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def init_dense(key: jax.Array, d_in: int, d_out: int, dtype=jnp.float32,
               scale: float | None = None) -> Params:
    s = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    return {"w": jax.random.normal(key, (d_in, d_out), dtype) * s}


def dense(p: Params, x: jax.Array) -> jax.Array:
    return jnp.einsum("...d,df->...f", x, p["w"])


MLP_KINDS = ("gated_silu", "squared_relu", "gelu")


def init_mlp(key: jax.Array, d: int, d_ff: int, kind: str, dtype=jnp.float32) -> Params:
    """Param dicts hold arrays only (kind is a static arg of mlp_apply) so the
    whole tree maps cleanly under optimizers/checkpointing/gossip."""
    ks = jax.random.split(key, 3)
    if kind == "gated_silu":
        return {
            "wi": init_dense(ks[0], d, d_ff, dtype),
            "wg": init_dense(ks[1], d, d_ff, dtype),
            "wo": init_dense(ks[2], d_ff, d, dtype),
        }
    if kind in ("squared_relu", "gelu"):
        return {
            "wi": init_dense(ks[0], d, d_ff, dtype),
            "wo": init_dense(ks[2], d_ff, d, dtype),
        }
    raise ValueError(f"unknown mlp kind {kind!r}")


def mlp_apply(p: Params, x: jax.Array, kind: str) -> jax.Array:
    if kind == "gated_silu":
        h = jax.nn.silu(dense(p["wi"], x)) * dense(p["wg"], x)
    elif kind == "squared_relu":
        h = jnp.square(jax.nn.relu(dense(p["wi"], x)))
    elif kind == "gelu":
        h = jax.nn.gelu(dense(p["wi"], x))
    else:
        raise ValueError(f"unknown mlp kind {kind!r}")
    return dense(p["wo"], h)
