"""Model assembly: scan-over-layers decoder/encoder covering all six assigned
families (dense GQA, MoE, RG-LRU hybrid, RWKV-6 SSM, VLM backbone, audio
encoder).

Depth is organized as *stages* (see config.compile_stages): each stage scans a
parameter tree stacked over ``repeats`` of a fixed block-kind group, so HLO
size is O(pattern length), not O(n_layers) — a 126-layer model lowers as fast
as a 2-layer one, and ``cost_analysis`` stays exact (XLA multiplies loop-body
costs by trip count).

Two entry points per model:
  * ``loss(params, batch)``      — training / prefill objective (+ aux)
  * ``decode_step(params, tok, cache, pos)`` — one-token serve step

Caches are pytrees stacked the same way as stage params, so the very same
scan drives decode.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import rglru as G
from repro.models import rwkv6 as W
from repro.models.config import ModelConfig, compile_stages
from repro.sharding.api import constrain

Params = Any

__all__ = ["Model"]

_ATTN_KINDS = ("attn", "swa", "local_attn")


def _init_block(key, kind: str, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"norm1": L.init_norm(cfg.d_model, dtype)}
    if kind in _ATTN_KINDS:
        p["attn"] = A.init_attention(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                     cfg.head_dim, dtype)
    elif kind == "rglru":
        p["rglru"] = G.init_rglru_block(ks[0], cfg.d_model, dtype=dtype)
    elif kind == "rwkv6":
        p["rwkv"] = W.init_rwkv6_block(ks[0], cfg.d_model, cfg.d_ff, cfg.rwkv_head_dim, dtype)
        p["norm2"] = L.init_norm(cfg.d_model, dtype)
        return p  # rwkv brings its own channel mix
    else:
        raise ValueError(kind)
    p["norm2"] = L.init_norm(cfg.d_model, dtype)
    if cfg.moe is not None:
        p["ch"] = M.init_moe(ks[1], cfg.d_model, cfg.moe, cfg.mlp, dtype)
    else:
        p["ch"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp, dtype)
    return p


@dataclass
class Model:
    cfg: ModelConfig
    dtype: Any = jnp.float32        # activation dtype (bf16 on TPU)
    param_dtype: Any = jnp.float32

    # ------------------------------------------------------------------ init
    def init(self, key: jax.Array) -> Params:
        cfg = self.cfg
        stages = compile_stages(cfg.n_layers, cfg.block_pattern)
        kemb, khead, *kstages = jax.random.split(key, 2 + len(stages))
        params: dict[str, Any] = {}
        if cfg.embed_kind == "tokens" or cfg.family == "vlm":
            params["embed"] = L.init_embedding(kemb, cfg.vocab_size, cfg.d_model, self.param_dtype)
        params["final_norm"] = L.init_norm(cfg.d_model, self.param_dtype)
        if not cfg.tie_embeddings or cfg.embed_kind == "frames":
            params["head"] = L.init_dense(khead, cfg.d_model, cfg.vocab_size, self.param_dtype)
        params["stages"] = []
        for (kinds, repeats), ks in zip(stages, kstages):
            def group_init(k):
                kb = jax.random.split(k, len(kinds))
                return {f"blk{j}": _init_block(kb[j], kind, cfg, self.param_dtype)
                        for j, kind in enumerate(kinds)}
            params["stages"].append(jax.vmap(group_init)(jax.random.split(ks, repeats)))
        return params

    # ----------------------------------------------------------- norms/mixes
    def _norm(self, p, x):
        return L.rms_norm(p, x) if self.cfg.norm == "rmsnorm" else L.layer_norm(p, x)

    def _channel(self, p, x):
        """Returns (y, aux_losses_scalar)."""
        if self.cfg.moe is not None:
            y, aux = M.moe_apply(p, x, self.cfg.moe, self.cfg.mlp)
            return y, aux.load_balance_loss + aux.router_z_loss
        return L.mlp_apply(p, x, self.cfg.mlp), jnp.float32(0.0)

    def _block_train(self, kind: str, p, x, positions):
        cfg = self.cfg
        aux = jnp.float32(0.0)
        if kind in _ATTN_KINDS:
            window = cfg.window if kind in ("swa", "local_attn") else 0
            h = A.attention_train(p["attn"], self._norm(p["norm1"], x), positions,
                                  window=window, causal=not cfg.is_encoder,
                                  rope_theta=cfg.rope_theta)
            x = x + h
            ch, aux = self._channel(p["ch"], self._norm(p["norm2"], x))
            x = x + ch
        elif kind == "rglru":
            x = x + G.rglru_train(p["rglru"], self._norm(p["norm1"], x))
            ch, aux = self._channel(p["ch"], self._norm(p["norm2"], x))
            x = x + ch
        elif kind == "rwkv6":
            x = x + W.time_mix_train(p["rwkv"], self._norm(p["norm1"], x), cfg.rwkv_head_dim)
            x = x + W.channel_mix_train(p["rwkv"], self._norm(p["norm2"], x))
        else:
            raise ValueError(kind)
        x = constrain(x, ("batch", "seq", "embed"))
        return x, aux

    # -------------------------------------------------------------- forward
    def _embed_inputs(self, params, batch):
        cfg = self.cfg
        if cfg.embed_kind == "tokens":
            x = L.embed(params["embed"], batch["tokens"]).astype(self.dtype)
        elif cfg.embed_kind == "patches":
            tok = L.embed(params["embed"], batch["tokens"]).astype(self.dtype)
            img = batch["patch_embeds"].astype(self.dtype)
            x = jnp.concatenate([img, tok], axis=1)
        elif cfg.embed_kind == "frames":
            x = batch["frames"].astype(self.dtype)
        else:
            raise ValueError(cfg.embed_kind)
        return constrain(x, ("batch", "seq", "embed"))

    def group_fwd_fn(self, kinds: tuple[str, ...], *, remat: bool = False,
                     remat_policy: str = "full"):
        """(x, stage_params_slice, positions) -> (x, aux) for one block group —
        the scan body; exposed for the per-stage roofline analysis.

        remat_policy: "full" recomputes everything in the backward pass
        (min memory, max HBM re-reads); "dots" saves matmul outputs and
        recomputes only elementwise ops (≈2x fewer backward reads for ~10-20%
        more live memory — the right trade for memory-BANDWIDTH-bound MoE)."""

        def group_fwd(x, p, positions):
            aux = jnp.float32(0.0)
            for j, kind in enumerate(kinds):
                x, a = self._block_train(kind, p[f"blk{j}"], x, positions)
                aux = aux + a
            return x, aux

        if not remat:
            return group_fwd
        if remat_policy == "dots":
            return jax.checkpoint(
                group_fwd,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        return jax.checkpoint(group_fwd)

    def forward(self, params: Params, batch: dict, *, remat: bool = False,
                remat_policy: str = "full"):
        """Full-sequence forward -> (logits, aux_loss)."""
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
        stages = compile_stages(cfg.n_layers, cfg.block_pattern)

        aux_total = jnp.float32(0.0)
        for (kinds, repeats), stage_params in zip(stages, params["stages"]):
            group_fwd = self.group_fwd_fn(kinds, remat=remat, remat_policy=remat_policy)

            def scan_body(carry, p):
                x, aux = carry
                x, a = group_fwd(x, p, positions)
                return (x, aux + a), None

            (x, aux_total), _ = jax.lax.scan(scan_body, (x, aux_total), stage_params)

        x = self._norm(params["final_norm"], x)
        if "head" in params:
            logits = L.dense(params["head"], x.astype(jnp.float32))
        else:
            logits = L.unembed(params["embed"], x)
        logits = constrain(logits, ("batch", "seq", "vocab"))
        return logits, aux_total

    # ----------------------------------------------------------------- loss
    def loss(self, params: Params, batch: dict, *, remat: bool = False,
             remat_policy: str = "full"):
        """Scalar objective + metrics. Batch layouts:
        tokens:  {tokens (B,S), targets (B,S)}
        patches: {patch_embeds (B,P,D), tokens (B,St), targets (B,St)}
        frames:  {frames (B,S,D), targets (B,S), mask (B,S) bool}
        """
        cfg = self.cfg
        logits, aux = self.forward(params, batch, remat=remat, remat_policy=remat_policy)
        targets = batch["targets"]
        if cfg.embed_kind == "patches":
            logits = logits[:, -targets.shape[1]:]  # loss on text positions only
        # fused CE: lse(logits) - logit[target] — avoids materializing the
        # full (B, S, V) log-softmax array (one less 128k-vocab round trip)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
        nll = lse - tgt
        if cfg.embed_kind == "frames":
            mask = batch["mask"].astype(jnp.float32)
            ce = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        else:
            ce = jnp.mean(nll)
        total = ce + aux
        return total, {"ce": ce, "aux": aux}

    # ---------------------------------------------------------------- cache
    def init_cache(self, batch: int, seq_len: int, cache_dtype=jnp.bfloat16) -> list:
        """Per-stage stacked decode state. seq_len = context capacity."""
        cfg = self.cfg
        if not cfg.supports_decode():
            raise ValueError(f"{cfg.name} is encoder-only: no decode path")
        stages = compile_stages(cfg.n_layers, cfg.block_pattern)
        caches = []
        for kinds, repeats in stages:
            group: dict[str, Any] = {}
            for j, kind in enumerate(kinds):
                if kind in _ATTN_KINDS:
                    window = cfg.window if kind in ("swa", "local_attn") else 0
                    c = A.init_kv_cache(batch, seq_len, cfg.n_kv_heads, cfg.head_dim,
                                        window, cache_dtype)
                elif kind == "rglru":
                    c = G.init_rglru_state(batch, cfg.d_model, self.dtype)
                elif kind == "rwkv6":
                    c = W.init_rwkv6_state(batch, cfg.d_model, cfg.rwkv_head_dim, self.dtype)
                group[f"blk{j}"] = c
            # stack over repeats
            caches.append(jax.tree.map(lambda a: jnp.broadcast_to(a, (repeats,) + a.shape), group))
        return caches

    def group_decode_fn(self, kinds: tuple[str, ...]):
        """(x, stage_params_slice, cache_slice, pos) -> (x, new_cache) —
        the decode scan body; exposed for per-stage roofline analysis."""

        def group_dec(x, p, c, pos):
            new_c = {}
            for j, kind in enumerate(kinds):
                x, cj = self._block_decode(kind, p[f"blk{j}"], x, c[f"blk{j}"], pos)
                new_c[f"blk{j}"] = cj
            return x, new_c

        return group_dec

    def _block_decode(self, kind: str, p, x, cache, pos):
        cfg = self.cfg
        if kind in _ATTN_KINDS:
            window = cfg.window if kind in ("swa", "local_attn") else 0
            h, cache = A.attention_decode(p["attn"], self._norm(p["norm1"], x), cache, pos,
                                          window=window, rope_theta=cfg.rope_theta)
            x = x + h
            ch, _ = self._channel(p["ch"], self._norm(p["norm2"], x))
            x = x + ch
        elif kind == "rglru":
            h, cache = G.rglru_decode(p["rglru"], self._norm(p["norm1"], x), cache)
            x = x + h
            ch, _ = self._channel(p["ch"], self._norm(p["norm2"], x))
            x = x + ch
        elif kind == "rwkv6":
            tm, cache = W.time_mix_decode(p["rwkv"], self._norm(p["norm1"], x), cache,
                                          cfg.rwkv_head_dim)
            x = x + tm
            cm, cache = W.channel_mix_decode(p["rwkv"], self._norm(p["norm2"], x), cache)
            x = x + cm
        return x, cache

    def decode_step(self, params: Params, tokens: jax.Array, caches: list, pos: jax.Array):
        """One-token serve step. tokens: (B, 1) -> (logits (B,1,V), new caches)."""
        cfg = self.cfg
        x = L.embed(params["embed"], tokens).astype(self.dtype)
        x = constrain(x, ("batch", "seq", "embed"))
        stages = compile_stages(cfg.n_layers, cfg.block_pattern)
        new_caches = []
        for (kinds, repeats), stage_params, stage_cache in zip(stages, params["stages"], caches):
            group_dec = self.group_decode_fn(kinds)

            def scan_body(x, pc):
                p, c = pc
                return group_dec(x, p, c, pos)

            x, nc = jax.lax.scan(scan_body, x, (stage_params, stage_cache))
            new_caches.append(nc)
        x = self._norm(params["final_norm"], x)
        if "head" in params:
            logits = L.dense(params["head"], x.astype(jnp.float32))
        else:
            logits = L.unembed(params["embed"], x)
        return logits, new_caches
