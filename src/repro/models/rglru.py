"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Temporal-mixing block:  x -> [branch A: dense -> GeLU]  x  [branch B: dense ->
causal conv1d(w=4) -> RG-LRU] -> elementwise product -> dense out.

RG-LRU recurrence (per channel):
    r_t = sigmoid(W_a x_t + b_a)            recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            input gate
    a_t = exp(-c * softplus(Lambda) * r_t)  data-dependent decay, c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training path uses ``jax.lax.associative_scan`` on the linear recurrence —
O(log S) depth, the TPU-native replacement for the paper-adjacent CUDA linear
scan. Decode path is the single-step update carrying h as state. The Pallas
kernel in ``repro.kernels.rglru_scan`` implements the blocked sequential scan
form and is validated against ``rglru_scan_ref`` here.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers as L

__all__ = ["init_rglru_block", "rglru_scan_ref", "rglru_train", "rglru_decode", "RGLRUState", "CONV_WIDTH"]

CONV_WIDTH = 4
_C = 8.0  # decay sharpening constant from the Griffin paper


class RGLRUState(NamedTuple):
    h: jax.Array       # (B, D_rnn) recurrence carry
    conv: jax.Array    # (B, CONV_WIDTH-1, D_rnn) causal conv tail


def init_rglru_block(key, d_model: int, d_rnn: int | None = None, dtype=jnp.float32):
    d_rnn = d_rnn or d_model
    ks = jax.random.split(key, 6)
    s = 1.0 / jnp.sqrt(d_model)
    # Lambda init so that a^(1/c)=softplus^-1 decay spreads over [0.9, 0.999]
    u = jax.random.uniform(ks[5], (d_rnn,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))
    return {
        "w_gate_in": jax.random.normal(ks[0], (d_model, d_rnn), dtype) * s,   # branch A
        "w_rnn_in": jax.random.normal(ks[1], (d_model, d_rnn), dtype) * s,    # branch B
        "conv_w": jax.random.normal(ks[2], (CONV_WIDTH, d_rnn), dtype) * 0.5,
        "w_a": jax.random.normal(ks[3], (d_rnn, d_rnn), dtype) * s,
        "b_a": jnp.zeros((d_rnn,), dtype),
        "w_x": jax.random.normal(ks[4], (d_rnn, d_rnn), dtype) * s,
        "b_x": jnp.zeros((d_rnn,), dtype),
        "lambda": lam,
        "w_out": jax.random.normal(jax.random.fold_in(key, 7), (d_rnn, d_model), dtype) * (1.0 / jnp.sqrt(d_rnn)),
    }


def _gates(p, u: jax.Array):
    """u: (..., D_rnn) post-conv activations -> (a, beta_scaled_input)."""
    r = jax.nn.sigmoid(jnp.einsum("...d,de->...e", u, p["w_a"]).astype(jnp.float32) + p["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("...d,de->...e", u, p["w_x"]).astype(jnp.float32) + p["b_x"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lambda"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * i * u.astype(jnp.float32)


def rglru_scan_ref(a: jax.Array, b: jax.Array, h0: jax.Array) -> jax.Array:
    """Oracle: h_t = a_t h_{t-1} + b_t along axis 1. a, b: (B, S, D); h0 (B, D)."""
    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    _, hs = jax.lax.scan(step, h0, (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0)))
    return jnp.moveaxis(hs, 0, 1)


def _assoc_scan(a: jax.Array, b: jax.Array, h0: jax.Array) -> jax.Array:
    """associative_scan over composed affine maps; O(log S) depth on TPU."""
    b0 = b.at[:, 0].add(a[:, 0] * h0)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a2 * a1, a2 * b1 + b2

    _, hs = jax.lax.associative_scan(combine, (a, b0), axis=1)
    return hs


def _conv1d_train(p, x: jax.Array) -> jax.Array:
    """Causal depthwise conv, width CONV_WIDTH. x: (B, S, D)."""
    pads = jnp.pad(x, ((0, 0), (CONV_WIDTH - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for w in range(CONV_WIDTH):
        out = out + pads[:, w:w + x.shape[1]].astype(jnp.float32) * p["conv_w"][w].astype(jnp.float32)
    return out.astype(x.dtype)


def rglru_train(p, x: jax.Array) -> jax.Array:
    """Full-sequence Griffin recurrent block. x: (B, S, D_model)."""
    gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, p["w_gate_in"]))
    u = jnp.einsum("bsd,de->bse", x, p["w_rnn_in"])
    u = _conv1d_train(p, u)
    a, b = _gates(p, u)
    h0 = jnp.zeros((x.shape[0], u.shape[-1]), jnp.float32)
    h = _assoc_scan(a, b, h0).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", h * gate, p["w_out"])


def init_rglru_state(batch: int, d_rnn: int, dtype=jnp.float32) -> RGLRUState:
    return RGLRUState(
        h=jnp.zeros((batch, d_rnn), jnp.float32),
        conv=jnp.zeros((batch, CONV_WIDTH - 1, d_rnn), dtype),
    )


def rglru_decode(p, x: jax.Array, state: RGLRUState) -> tuple[jax.Array, RGLRUState]:
    """One-token step. x: (B, 1, D_model)."""
    gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, p["w_gate_in"]))
    u = jnp.einsum("bsd,de->bse", x, p["w_rnn_in"])  # (B, 1, D)
    hist = jnp.concatenate([state.conv, u], axis=1)  # (B, W, D)
    u_c = jnp.einsum("bwd,wd->bd", hist.astype(jnp.float32),
                     p["conv_w"].astype(jnp.float32))[:, None].astype(x.dtype)
    a, b = _gates(p, u_c)
    h = a[:, 0] * state.h + b[:, 0]
    y = jnp.einsum("be,ed->bd", h.astype(x.dtype) * gate[:, 0], p["w_out"])[:, None]
    return y, RGLRUState(h=h, conv=hist[:, 1:])
