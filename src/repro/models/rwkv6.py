"""RWKV-6 "Finch" block (arXiv:2404.05892): attention-free time mixing with
data-dependent decay, plus RWKV channel mixing.

Time mixing (per head, head_dim = n):
    state S in R^{n x n};  per step t with receptance r, key k, value v, decay
    w_t (data-dependent, per channel) and bonus u:
        out_t = r_t @ (S_{t-1} + diag(u) k_t^T v_t)
        S_t   = diag(w_t) S_{t-1} + k_t^T v_t

Token shift: x'_t = lerp(x_t, x_{t-1}, mu) with per-projection learned mu
(the paper's LoRA-parameterized shifts are folded into per-channel mu plus a
low-rank data-dependent term for the decay, ddlerp_w).

Training path: jax.lax.scan over time carrying S (exact recurrence — the
oracle for the chunked Pallas kernel in repro.kernels.rwkv6_scan). Decode:
single-step update; state is O(H·n·n) regardless of context length, which is
why rwkv6 runs the 524k shape.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["init_rwkv6_block", "time_mix_train", "channel_mix_train",
           "time_mix_decode", "channel_mix_decode", "RWKV6State",
           "wkv_scan_ref", "init_rwkv6_state"]

_DECAY_LORA = 32


class RWKV6State(NamedTuple):
    S: jax.Array        # (B, H, n, n) wkv state
    x_prev_tm: jax.Array  # (B, D) last token for time-mix shift
    x_prev_cm: jax.Array  # (B, D) last token for channel-mix shift


def init_rwkv6_block(key, d_model: int, d_ff: int, head_dim: int, dtype=jnp.float32):
    h = d_model // head_dim
    ks = jax.random.split(key, 12)
    s = 1.0 / jnp.sqrt(d_model)
    # decay base spread per channel (RWKV init: -6..-0.3 in log space)
    ratios = jnp.arange(d_model, dtype=jnp.float32) / max(1, d_model - 1)
    decay_base = -6.0 + 5.7 * ratios
    return {
        # time-mix projections
        "w_r": jax.random.normal(ks[0], (d_model, d_model), dtype) * s,
        "w_k": jax.random.normal(ks[1], (d_model, d_model), dtype) * s,
        "w_v": jax.random.normal(ks[2], (d_model, d_model), dtype) * s,
        "w_g": jax.random.normal(ks[3], (d_model, d_model), dtype) * s,
        "w_o": jax.random.normal(ks[4], (d_model, d_model), dtype) * s,
        # token-shift interpolants (mu) per projection
        "mu_r": jnp.full((d_model,), 0.5, dtype),
        "mu_k": jnp.full((d_model,), 0.5, dtype),
        "mu_v": jnp.full((d_model,), 0.5, dtype),
        "mu_g": jnp.full((d_model,), 0.5, dtype),
        "mu_w": jnp.full((d_model,), 0.5, dtype),
        # data-dependent decay: w_t = exp(-exp(decay_base + lora(x')))
        "decay_base": decay_base,
        "decay_lora_a": jax.random.normal(ks[5], (d_model, _DECAY_LORA), dtype) * s,
        "decay_lora_b": jax.random.normal(ks[6], (_DECAY_LORA, d_model), dtype) * 0.01,
        "bonus_u": jax.random.normal(ks[7], (h, head_dim), jnp.float32) * 0.1,
        # channel mix
        "cm_mu": jnp.full((d_model,), 0.5, dtype),
        "cm_wi": jax.random.normal(ks[8], (d_model, d_ff), dtype) * s,
        "cm_wo": jax.random.normal(ks[9], (d_ff, d_model), dtype) * (1.0 / jnp.sqrt(d_ff)),
        "cm_wr": jax.random.normal(ks[10], (d_model, d_model), dtype) * s,
        "ln_x_scale": jnp.ones((d_model,), dtype),  # group-norm on wkv output
    }


def _shift_train(x: jax.Array, x0: jax.Array) -> jax.Array:
    """x_{t-1} along seq axis; position 0 gets x0 (decode carry or zeros)."""
    return jnp.concatenate([x0[:, None], x[:, :-1]], axis=1)


def _lerp(x, x_prev, mu):
    return x + (x_prev - x) * mu.astype(x.dtype)


def wkv_scan_ref(r, k, v, w, u, S0):
    """Oracle wkv recurrence.

    r,k,v: (B, S, H, n); w: (B, S, H, n) decay in (0,1); u: (H, n) bonus;
    S0: (B, H, n, n). Returns (out (B,S,H,n), S_final).
    S layout: S[b,h,i,j] accumulates k_i v_j.
    """
    def step(S, inp):
        r_t, k_t, v_t, w_t = inp  # (B, H, n)
        kv = jnp.einsum("bhi,bhj->bhij", k_t, v_t)
        out = jnp.einsum("bhi,bhij->bhj", r_t, S + u[None] [..., None] * kv)
        S = w_t[..., None] * S + kv
        return S, out

    seq = (jnp.moveaxis(r, 1, 0), jnp.moveaxis(k, 1, 0),
           jnp.moveaxis(v, 1, 0), jnp.moveaxis(w, 1, 0))
    S, outs = jax.lax.scan(step, S0, seq)
    return jnp.moveaxis(outs, 0, 1), S


def _heads(x, head_dim):
    b, s, d = x.shape
    return x.reshape(b, s, d // head_dim, head_dim)


def _time_mix(p, x: jax.Array, x_prev: jax.Array, S0: jax.Array, head_dim: int):
    """Shared by train (S: full seq) and decode (S: one step)."""
    xs = x_prev
    r = jnp.einsum("bsd,de->bse", _lerp(x, xs, p["mu_r"]), p["w_r"])
    k = jnp.einsum("bsd,de->bse", _lerp(x, xs, p["mu_k"]), p["w_k"])
    v = jnp.einsum("bsd,de->bse", _lerp(x, xs, p["mu_v"]), p["w_v"])
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", _lerp(x, xs, p["mu_g"]), p["w_g"]))
    xw = _lerp(x, xs, p["mu_w"])
    dd = jnp.einsum("bsd,dr,re->bse", xw, p["decay_lora_a"], p["decay_lora_b"])
    w = jnp.exp(-jnp.exp(p["decay_base"].astype(jnp.float32) + dd.astype(jnp.float32)))  # (B,S,D) in (0,1)

    hd = head_dim
    rh, kh, vh = _heads(r, hd).astype(jnp.float32), _heads(k, hd).astype(jnp.float32), _heads(v, hd).astype(jnp.float32)
    wh = _heads(w, hd)
    out, S = wkv_scan_ref(rh, kh, vh, wh, p["bonus_u"].astype(jnp.float32), S0)
    b, s, h, n = out.shape
    o = out.reshape(b, s, h * n)
    # per-head group norm
    o = o.reshape(b, s, h, n)
    o = (o - o.mean(-1, keepdims=True)) * jax.lax.rsqrt(o.var(-1, keepdims=True) + 1e-5)
    o = o.reshape(b, s, h * n) * p["ln_x_scale"].astype(jnp.float32)
    o = (o.astype(x.dtype) * g)
    return jnp.einsum("bsd,de->bse", o, p["w_o"]), S


def _channel_mix(p, x: jax.Array, x_prev: jax.Array) -> jax.Array:
    xk = _lerp(x, x_prev, p["cm_mu"])
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xk, p["cm_wr"]).astype(jnp.float32)).astype(x.dtype)
    h = jnp.square(jax.nn.relu(jnp.einsum("bsd,de->bse", xk, p["cm_wi"])))
    return rr * jnp.einsum("bsd,de->bse", h, p["cm_wo"])


def time_mix_train(p, x: jax.Array, head_dim: int) -> jax.Array:
    """Full-sequence time mixing; x is the post-norm stream (B, S, D)."""
    b, s, d = x.shape
    S0 = jnp.zeros((b, d // head_dim, head_dim, head_dim), jnp.float32)
    tm, _ = _time_mix(p, x, _shift_train(x, jnp.zeros_like(x[:, 0])), S0, head_dim)
    return tm


def channel_mix_train(p, x: jax.Array) -> jax.Array:
    """Full-sequence channel mixing; x is the post-norm stream (B, S, D)."""
    return _channel_mix(p, x, _shift_train(x, jnp.zeros_like(x[:, 0])))


def init_rwkv6_state(batch: int, d_model: int, head_dim: int, dtype=jnp.float32) -> RWKV6State:
    h = d_model // head_dim
    return RWKV6State(
        S=jnp.zeros((batch, h, head_dim, head_dim), jnp.float32),
        x_prev_tm=jnp.zeros((batch, d_model), dtype),
        x_prev_cm=jnp.zeros((batch, d_model), dtype),
    )


def time_mix_decode(p, x: jax.Array, state: RWKV6State, head_dim: int):
    """One-token time mixing; x: (B, 1, D) post-norm."""
    tm, S = _time_mix(p, x, state.x_prev_tm[:, None], state.S, head_dim)
    return tm, state._replace(S=S, x_prev_tm=x[:, 0])


def channel_mix_decode(p, x: jax.Array, state: RWKV6State):
    """One-token channel mixing; x: (B, 1, D) post-norm."""
    cm = _channel_mix(p, x, state.x_prev_cm[:, None])
    return cm, state._replace(x_prev_cm=x[:, 0])
