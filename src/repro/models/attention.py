"""Grouped-query attention: full / sliding-window / local, train + decode.

Pure-jnp reference path (used on CPU and for the dry-run lowering); the
Pallas flash kernel in ``repro.kernels.flash_attention`` is the TPU hot-path
and is validated against ``_attend`` below.

Layouts: activations (B, S, D); q/k/v (B, S, H, Dh) with H_kv <= H (GQA).
KV cache for decode: (B, S_cache, H_kv, Dh) absolute-position layout for full
attention, ring layout (pos % window) for SWA — the ring keeps the long_500k
cache O(window) instead of O(seq), which is the sub-quadratic carve-in that
lets SWA architectures run the 524k shape at all.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers as L

__all__ = ["AttnParams", "KVCache", "init_attention", "attention_train", "attention_decode", "init_kv_cache"]

NEG_INF = -1e30


def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int, head_dim: int, dtype=jnp.float32):
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = 1.0 / jnp.sqrt(d_model)
    return {
        "wq": jax.random.normal(kq, (d_model, n_heads, head_dim), dtype) * s,
        "wk": jax.random.normal(kk, (d_model, n_kv_heads, head_dim), dtype) * s,
        "wv": jax.random.normal(kv, (d_model, n_kv_heads, head_dim), dtype) * s,
        "wo": jax.random.normal(ko, (n_heads, head_dim, d_model), dtype) * (1.0 / jnp.sqrt(n_heads * head_dim)),
    }


AttnParams = dict


class KVCache(NamedTuple):
    k: jax.Array      # (B, S_cache, H_kv, Dh)
    v: jax.Array      # (B, S_cache, H_kv, Dh)

    @property
    def size(self) -> int:
        return self.k.shape[1]


def init_kv_cache(batch: int, seq: int, n_kv: int, head_dim: int, window: int, dtype=jnp.bfloat16) -> KVCache:
    s_cache = min(seq, window) if window else seq
    shape = (batch, s_cache, n_kv, head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def _attend(q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array,
            score_axes: tuple | None = None) -> jax.Array:
    """GQA-native softmax(q k^T / sqrt(dh) + mask) v, f32 softmax.

    q: (B,Sq,H,Dh); k/v: (B,Sk,Hkv,Dh) with Hkv | H — queries are grouped
    per kv head in the einsum itself, so K/V are NEVER materialized at H
    copies (repeat_kv expansion cost ~n_rep x cache bytes in f32; observed
    141 GB/step on mistral-large decode_32k).

    ``score_axes``: optional logical axes pinned onto the
    (B,Hkv,rep,Sq,Sk) scores — the decode path keeps scores sharded on the
    cache-sequence axis (flash-decode), overriding XLA's backward
    propagation of the output projection's head sharding (which otherwise
    all-gathers the KV cache).
    """
    from repro.sharding.api import constrain

    b, sq, h, dh = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    qg = q.reshape(b, sq, hkv, rep, dh)
    scores = (jnp.einsum("bqhrd,bkhd->bhrqk", qg, k).astype(jnp.float32)
              / jnp.sqrt(float(dh)))
    scores = jnp.where(mask[:, :, None], scores, NEG_INF)  # mask (1,1,Sq,Sk)
    if score_axes is not None:
        scores = constrain(scores, score_axes)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    if score_axes is not None:
        probs = constrain(probs, score_axes)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", probs, v)
    return out.reshape(b, sq, h, dh)


def _train_mask(seq: int, window: int, causal: bool) -> jax.Array:
    """(1, 1, S, S) bool mask: causal (+band when window>0); full iff not causal."""
    q_pos = jnp.arange(seq)[:, None]
    k_pos = jnp.arange(seq)[None, :]
    mask = jnp.ones((seq, seq), bool) if not causal else (k_pos <= q_pos)
    if window:
        mask = mask & (k_pos > q_pos - window)
    return mask[None, None]


def attention_train(p: AttnParams, x: jax.Array, positions: jax.Array, *,
                    window: int = 0, causal: bool = True, rope_theta: float = 10000.0) -> jax.Array:
    """Full-sequence attention (training / prefill)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = L.rotary(q, positions, rope_theta)
    k = L.rotary(k, positions, rope_theta)
    mask = _train_mask(x.shape[1], window, causal)
    out = _attend(q, k, v, mask)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def attention_decode(p: AttnParams, x: jax.Array, cache: KVCache, pos: jax.Array, *,
                     window: int = 0, rope_theta: float = 10000.0) -> tuple[jax.Array, KVCache]:
    """One-token decode: x (B, 1, D), pos scalar int32 (same for all rows).

    Full attention: write at absolute slot ``pos``, attend over slots <= pos.
    SWA: ring slot ``pos % window``, attend over the last ``window`` slots.
    """
    from repro.sharding.api import constrain

    b = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    posb = jnp.full((b, 1), pos, jnp.int32)
    q = L.rotary(q, posb, rope_theta)
    k_new = L.rotary(k_new, posb, rope_theta)
    # flash-decode sharding: q heads REPLICATED (a ~100 KB gather) so the
    # (B, H, 1, S) scores inherit the cache's sequence sharding — otherwise
    # q's head sharding conflicts with K's seq sharding on the same mesh
    # axis and XLA all-gathers the 2 GiB cache per layer instead.
    q = constrain(q, ("batch", None, "heads_dec", None))

    s_cache = cache.size
    slot = (pos % window) if window else pos
    # masked arithmetic write instead of dynamic_update_slice: a DUS on the
    # (sequence-)sharded cache dim makes XLA SPMD all-gather the whole cache
    # per layer per token (observed: 2 GiB/layer on mistral-large decode_32k);
    # the where-write shards perfectly and costs one elementwise pass.
    write = (jnp.arange(s_cache) == slot)[None, :, None, None]
    k = jnp.where(write, k_new.astype(cache.k.dtype), cache.k)
    v = jnp.where(write, v_new.astype(cache.v.dtype), cache.v)
    new_cache = KVCache(k=k, v=v)

    slots = jnp.arange(s_cache)
    if window:
        # ring: slot i holds absolute position p_i = the latest p <= pos with p % window == i
        abs_pos = pos - ((pos - slots) % window)
        valid = (abs_pos >= 0) & (abs_pos >= pos - window + 1)
    else:
        valid = slots <= pos
    mask = valid[None, None, None, :]  # (1,1,1,S_cache)

    out = _attend(q, k, v, mask,
                  score_axes=("batch", "kv_heads", "heads_dec", None, "cache_seq"))
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache
