"""ModelConfig — one dataclass describing every assigned architecture family.

``block_pattern`` is the repeating cycle of temporal-mixing block kinds
(e.g. ("rglru", "rglru", "local_attn") for RecurrentGemma). n_layers need not
divide the cycle: the tail takes the pattern prefix. ``compile_stages`` turns
(n_layers, pattern) into scan stages: [(group_kinds, repeats)] with parameters
stacked over repeats, so HLO size is O(pattern) not O(depth).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Sequence

__all__ = ["MoEConfig", "ModelConfig", "compile_stages"]


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden dim
    d_shared: int = 0             # shared-expert FFN hidden dim (0 = none)
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3   # router z-loss (beyond-paper stability)
    aux_coef: float = 1e-2        # load-balance auxiliary loss


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    n_heads: int = 0              # 0 for attention-free (rwkv)
    n_kv_heads: int = 0
    head_dim: int = 128
    block_pattern: tuple[str, ...] = ("attn",)   # attn | swa | local_attn | rglru | rwkv6
    mlp: str = "gated_silu"       # gated_silu | squared_relu | gelu
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    window: int = 0               # sliding/local attention window (0 = full)
    rope_theta: float = 10000.0
    moe: MoEConfig | None = None
    is_encoder: bool = False      # bidirectional, no decode path (hubert)
    embed_kind: str = "tokens"    # tokens | patches (vlm) | frames (audio)
    n_prefix_embeds: int = 0      # vlm: image patch tokens preceding text
    rwkv_head_dim: int = 64
    tie_embeddings: bool = True
    citation: str = ""

    # --- derived ---
    @property
    def attn_layers(self) -> int:
        stages = compile_stages(self.n_layers, self.block_pattern)
        return sum(r * sum(1 for k in kinds if "attn" in k or k == "swa")
                   for kinds, r in stages)

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    def supports_decode(self) -> bool:
        return not self.is_encoder

    def subquadratic(self) -> bool:
        """True when no block attends over unbounded context (window or recurrent)."""
        return all(k in ("rglru", "rwkv6", "swa", "local_attn") for k in self.block_pattern)

    def reduced(self, n_layers: int = 2, d_model: int = 256, seed_ff_ratio: float | None = None) -> "ModelConfig":
        """CI-scale variant of the same family: <=2 layers, d_model<=512,
        <=4 experts — structure preserved (pattern, mlp kind, GQA ratio)."""
        d_model = min(d_model, 512)
        ratio = (self.d_ff / self.d_model) if seed_ff_ratio is None else seed_ff_ratio
        n_heads = max(1, min(self.n_heads, 4)) if self.n_heads else 0
        kv_ratio = max(1, self.n_heads // max(1, self.n_kv_heads)) if self.n_heads else 1
        n_kv = max(1, n_heads // kv_ratio) if n_heads else 0
        head_dim = d_model // n_heads if n_heads else 64
        moe = None
        if self.moe is not None:
            moe = replace(
                self.moe,
                n_experts=min(4, self.moe.n_experts),
                top_k=min(2, self.moe.top_k),
                d_expert=max(32, int(d_model * self.moe.d_expert / self.d_model)),
                d_shared=(max(32, int(d_model * self.moe.d_shared / self.d_model))
                          if self.moe.d_shared else 0),
            )
        n_layers = min(n_layers, self.n_layers)
        # keep at least one full pattern cycle when it fits
        if len(self.block_pattern) > n_layers:
            n_layers = len(self.block_pattern)
        return replace(
            self,
            name=f"{self.name}-reduced",
            n_layers=n_layers,
            d_model=d_model,
            d_ff=max(64, int(d_model * ratio)),
            vocab_size=min(self.vocab_size, 512),
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=head_dim,
            window=min(self.window, 64) if self.window else 0,
            moe=moe,
            n_prefix_embeds=min(self.n_prefix_embeds, 16),
            rwkv_head_dim=min(self.rwkv_head_dim, max(16, d_model // 4)),
        )

    def validate(self) -> "ModelConfig":
        if self.n_heads:
            if self.n_heads % max(1, self.n_kv_heads):
                raise ValueError(f"{self.name}: n_heads {self.n_heads} must divide by kv {self.n_kv_heads}")
        if self.family == "moe" and self.moe is None:
            raise ValueError(f"{self.name}: moe family needs MoEConfig")
        for k in self.block_pattern:
            if k not in ("attn", "swa", "local_attn", "rglru", "rwkv6"):
                raise ValueError(f"{self.name}: unknown block kind {k!r}")
        if self.family == "ssm" and self.d_model % self.rwkv_head_dim:
            raise ValueError(f"{self.name}: d_model must divide rwkv_head_dim")
        return self


def compile_stages(n_layers: int, pattern: Sequence[str]) -> list[tuple[tuple[str, ...], int]]:
    """[(group_kinds, repeats)] — full cycles scanned, tail as its own stage."""
    p = len(pattern)
    full, rem = divmod(n_layers, p)
    stages: list[tuple[tuple[str, ...], int]] = []
    if full:
        stages.append((tuple(pattern), full))
    if rem:
        stages.append((tuple(pattern[:rem]), 1))
    return stages
