"""Model substrate: config, shared layers, the six architecture families, and
the scan-over-layers assembly."""
from repro.models.config import ModelConfig, MoEConfig, compile_stages  # noqa: F401
from repro.models.transformer import Model  # noqa: F401
