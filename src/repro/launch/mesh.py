"""Production meshes.

Single pod: 256 chips as (data=16, model=16). Multi-pod: 2 pods = 512 chips
as (pod=2, data=16, model=16) — the `pod` axis is the gossip axis of the
hierarchical-consensus deployment (DESIGN.md §4).

``make_production_mesh`` is a function (never a module constant) so importing
this module never touches jax device state; dryrun.py sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "axis_sizes"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever local devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"requested {data}x{model} mesh but only {n} devices")
    return jax.make_mesh((data, model), ("data", "model"))


def axis_sizes(mesh: jax.sharding.Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
