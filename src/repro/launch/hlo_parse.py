"""HLO-text collective parser (no jax/device side effects — import freely).

Convention (documented in EXPERIMENTS.md): ring-algorithm bytes from the
per-device output shape O and group size g —
  all-gather: (g-1)/g * O;  reduce-scatter: (g-1) * O (input is g*O);
  all-reduce: 2*(g-1)/g * O;  all-to-all: (g-1)/g * O;
  collective-permute: O.
"""
from __future__ import annotations

import re

__all__ = ["parse_collectives", "cost_analysis_dict", "_COLL_RE", "_GROUPS_RE", "_shape_bytes"]


def cost_analysis_dict(compiled) -> dict:
    """Normalize ``Compiled.cost_analysis()`` across jax versions.

    Older jaxlibs return a one-element list of per-computation dicts; newer
    ones return the dict directly (or None when analysis is unavailable).
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}

_COLL_RE = re.compile(
    r"%(?P<name>[\w.\-]+) = (?P<dtype>\w+)\[(?P<dims>[\d,]*)\][^=]*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)\(")
_GROUPS_RE = re.compile(r"replica_groups=(\{\{(?P<explicit>[\d,]+)\}|\[(?P<iota>\d+),(?P<gsz>\d+)\])")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> dict:
    """Per-device bytes moved by collectives, by op kind.

    Convention (documented in EXPERIMENTS.md): ring-algorithm bytes from the
    per-device output shape O and group size g —
      all-gather: (g-1)/g * O;  reduce-scatter: (g-1) * O (input is g*O);
      all-reduce: 2*(g-1)/g * O;  all-to-all: (g-1)/g * O;
      collective-permute: O.
    """
    totals: dict[str, float] = {}
    count: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        out_b = _shape_bytes(m.group("dtype"), m.group("dims"))
        g = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            if gm.group("explicit") is not None:
                g = gm.group("explicit").count(",") + 1
            else:
                g = int(gm.group("gsz"))
        if op == "all-gather":
            moved = out_b * (g - 1) / max(g, 1)
        elif op == "reduce-scatter":
            moved = out_b * (g - 1)
        elif op == "all-reduce":
            moved = 2 * out_b * (g - 1) / max(g, 1)
        elif op == "all-to-all":
            moved = out_b * (g - 1) / max(g, 1)
        else:  # collective-permute
            moved = out_b
        totals[op] = totals.get(op, 0.0) + moved
        count[op] = count.get(op, 0) + 1
    return {"bytes_by_op": totals, "count_by_op": count,
            "total_bytes": sum(totals.values())}


