"""Train / serve step builders — the paper's consensus strategies wired into
generic model training.

Two training modes (TrainerConfig.consensus):

* ``allreduce`` — single logical param copy; the batch is sharded over
  (`pod`, `data`) and gradient reduction is the implicit SPMD psum of the
  mean loss. The deep-net analogue of the paper's centralized Pegasos.

* ``gossip`` — every param leaf gains a leading replica axis of size
  ``n_replicas`` sharded over the gossip axis (default `pod`); replicas
  compute *local* gradients on their batch slice (vmap — no cross-replica
  reduction), take local optimizer steps, then mix parameters with Push-Sum
  rounds (collective-permute). GADGET SVM lifted to arbitrary models.

State layout: {"params": pytree, "opt": optimizer state, "step": scalar}.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro import optim
from repro.core.consensus import gossip_mix_stacked
from repro.models.transformer import Model

Pytree = Any

__all__ = ["TrainerConfig", "make_train_state", "make_train_step", "make_serve_step",
           "make_prefill_step", "train_state_specs"]


@dataclass(frozen=True)
class TrainerConfig:
    optimizer: str = "adamw"        # adamw | sgd
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    consensus: str = "allreduce"    # allreduce | gossip
    n_replicas: int = 1             # gossip replicas (== gossip axis size)
    replica_axis: str = "pod"       # mesh axis the replicas live on
    gossip_rounds: int = 1          # Push-Sum rounds per step
    gossip_self_share: float = 0.5
    mix_every: int = 1
    remat: bool = False
    remat_policy: str = "full"   # full | dots (save matmul outputs)
    gossip_payload: str = "full"  # full | bf16 (quantized gossip shares)


def _make_opt(tcfg: TrainerConfig) -> optim.GradientTransformation:
    sched = optim.cosine_warmup(tcfg.lr, tcfg.warmup_steps, tcfg.total_steps)
    if tcfg.optimizer == "adamw":
        return optim.adamw(sched, weight_decay=tcfg.weight_decay)
    if tcfg.optimizer == "sgd":
        return optim.sgd(sched, momentum=0.9)
    raise ValueError(tcfg.optimizer)


def make_train_state(model: Model, tcfg: TrainerConfig, key: jax.Array) -> Pytree:
    opt = _make_opt(tcfg)
    params = model.init(key)
    if tcfg.consensus == "gossip":
        # replicas start from identical params (paper: w_0 = 0 at every node);
        # divergence comes from per-replica batch slices.
        params = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (tcfg.n_replicas,) + x.shape), params)
        opt_state = jax.vmap(opt.init)(params)
    else:
        opt_state = opt.init(params)
    return {"params": params, "opt": opt_state, "step": jnp.zeros((), jnp.int32)}


def make_train_step(model: Model, tcfg: TrainerConfig) -> Callable:
    """Returns step(state, batch) -> (state, metrics).

    Gossip mode expects every batch leaf with a leading replica axis
    (G, per_replica_batch, ...).
    """
    opt = _make_opt(tcfg)

    if tcfg.consensus == "gossip":
        G = tcfg.n_replicas

        def loss_fn(params, batch):
            # spmd_axis_name lets with_sharding_constraint inside the model
            # compose with the mapped replica axis.
            per = jax.vmap(lambda p, b: model.loss(p, b, remat=tcfg.remat,
                                                   remat_policy=tcfg.remat_policy),
                           spmd_axis_name=tcfg.replica_axis)(params, batch)
            (losses, metrics) = per
            return jnp.mean(losses), metrics

        def step_fn(state, batch):
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state["params"], batch)
            # d(mean_g)/d(p_g) = (1/G) local grad: undo the scaling
            grads = jax.tree.map(lambda g: g * G, grads)
            if tcfg.clip_norm:
                grads = jax.vmap(
                    lambda g: optim.clip_by_global_norm(tcfg.clip_norm).update(g, (), None)[0]
                )(grads)
            updates, opt_state = jax.vmap(opt.update)(grads, state["opt"], state["params"])
            params = optim.apply_updates(state["params"], updates)
            do_mix = (tcfg.mix_every == 1)
            payload = jnp.bfloat16 if tcfg.gossip_payload == "bf16" else None
            mixed = gossip_mix_stacked(params, state["step"], n_nodes=G,
                                       rounds=tcfg.gossip_rounds,
                                       self_share=tcfg.gossip_self_share,
                                       payload_dtype=payload)
            if not do_mix:
                skip = (state["step"] % tcfg.mix_every) != 0
                mixed = jax.tree.map(lambda m, p: jnp.where(skip, p, m), mixed, params)
            new_state = {"params": mixed, "opt": opt_state, "step": state["step"] + 1}
            out_metrics = {"loss": loss, "ce": jnp.mean(metrics["ce"]),
                           "aux": jnp.mean(metrics["aux"])}
            return new_state, out_metrics

        return step_fn

    def step_fn(state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: model.loss(p, batch, remat=tcfg.remat,
                                 remat_policy=tcfg.remat_policy),
            has_aux=True)(state["params"])
        if tcfg.clip_norm:
            grads, _ = optim.clip_by_global_norm(tcfg.clip_norm).update(grads, (), None)
        updates, opt_state = opt.update(grads, state["opt"], state["params"])
        params = optim.apply_updates(state["params"], updates)
        new_state = {"params": params, "opt": opt_state, "step": state["step"] + 1}
        return new_state, {"loss": loss, **metrics}

    return step_fn


def make_prefill_step(model: Model) -> Callable:
    """Full-sequence inference forward (prefill_32k shape)."""

    def prefill(params, batch):
        logits, _ = model.forward(params, batch)
        return logits

    return prefill


def make_serve_step(model: Model) -> Callable:
    """One-token decode against a seq_len-deep cache (decode shapes)."""

    def serve(params, tokens, caches, pos):
        return model.decode_step(params, tokens, caches, pos)

    return serve


# ------------------------------------------------------------------ specs

def train_state_specs(pspecs: Pytree, tcfg: TrainerConfig, moment_specs: Pytree | None = None):
    """Spec tree matching make_train_state's output, given param specs
    (which already include the gossip replica axis when applicable).

    ``moment_specs``: optional separate specs for the optimizer moments —
    ZeRO-1 passes FSDP-style (data-sharded) specs here while the params
    themselves stay TP-only."""
    from jax.sharding import PartitionSpec as P

    from repro.optim.transforms import AdamState, MomentumState, ScheduleState

    mspecs = moment_specs if moment_specs is not None else pspecs
    scalar = P() if tcfg.consensus != "gossip" else P(None)
    if tcfg.optimizer == "adamw":
        opt_spec = AdamState(step=scalar, mu=mspecs, nu=mspecs)
    else:
        opt_spec = (MomentumState(momentum=mspecs), ScheduleState(step=scalar))
    return {"params": pspecs, "opt": opt_spec, "step": P()}
