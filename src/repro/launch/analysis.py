"""Per-stage roofline accounting.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, ignoring
the trip count (verified empirically on the CPU backend in this repo). Since
depth is scanned, the rolled module's numbers undercount layers. Correction:

    total_cost = rolled_module_cost + sum_stages (repeats_s - 1) * body_cost_s

where ``body_cost_s`` comes from lowering exactly the scan body (the model's
group_fwd / group_decode closure, fwd+bwd for training) against the same
shardings on the same mesh, where it is loop-free and therefore counted
exactly. Memory analysis is NOT corrected (buffers are reused across
iterations, so the rolled module's temp bytes are the true peak).

Collective bytes get the same treatment: collectives inside the scanned body
appear once in the rolled HLO, so per-stage collective bytes are scaled by
(repeats - 1) as well.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.hlo_parse import cost_analysis_dict
from repro.models.config import compile_stages
from repro.models.transformer import Model

Pytree = Any

__all__ = ["stage_costs", "CostTriple"]


class CostTriple(dict):
    """{"flops", "bytes", "collective_bytes"} per device."""

    @staticmethod
    def of(flops: float, bytes_: float, coll: float) -> "CostTriple":
        return CostTriple(flops=flops, bytes=bytes_, collective_bytes=coll)

    def __add__(self, o):  # type: ignore[override]
        return CostTriple.of(self["flops"] + o["flops"], self["bytes"] + o["bytes"],
                             self["collective_bytes"] + o["collective_bytes"])

    def __mul__(self, k: float):
        return CostTriple.of(self["flops"] * k, self["bytes"] * k,
                             self["collective_bytes"] * k)


def _cost_of(lowered, parse_collectives: Callable[[str], dict]) -> CostTriple:
    compiled = lowered.compile()
    ca = cost_analysis_dict(compiled)
    colls = parse_collectives(compiled.as_text())
    return CostTriple.of(float(ca.get("flops", 0.0)),
                         float(ca.get("bytes accessed", 0.0)),
                         float(colls["total_bytes"]))


def _is_sds(x) -> bool:
    return isinstance(x, jax.ShapeDtypeStruct)


def _drop_axis(sds: jax.ShapeDtypeStruct, mesh, axis: int) -> jax.ShapeDtypeStruct:
    """SDS with dim ``axis`` removed, preserving the sharding of other dims."""
    spec = sds.sharding.spec if sds.sharding is not None else P(*([None] * len(sds.shape)))
    spec = tuple(spec) + (None,) * (len(sds.shape) - len(tuple(spec)))
    new_shape = sds.shape[:axis] + sds.shape[axis + 1:]
    new_spec = P(*(spec[:axis] + spec[axis + 1:]))
    return jax.ShapeDtypeStruct(new_shape, sds.dtype, sharding=NamedSharding(mesh, new_spec))


def _positions_like(x: jax.Array) -> jax.Array:
    return jnp.broadcast_to(jnp.arange(x.shape[-2]), x.shape[:-1])


def stage_costs(model: Model, *, mesh, kind: str,
                x_sds: jax.ShapeDtypeStruct,
                params_sds: Pytree,
                cache_sds: Pytree | None,
                parse_collectives: Callable[[str], dict],
                gossip: bool = False) -> CostTriple:
    """sum_stages (repeats - 1) * body_cost — the while-loop correction term.

    ``x_sds``: SDS of the activation entering the stages — (B, S, D) sharded
    like the embedding output ((G, B/G, S, D) in gossip mode). ``params_sds``
    leaves carry their shardings (as passed to the main lowering).
    """
    cfg = model.cfg
    stages = compile_stages(cfg.n_layers, cfg.block_pattern)
    repeat_axis = 1 if gossip else 0
    total = CostTriple.of(0.0, 0.0, 0.0)
    for s_idx, (kinds, repeats) in enumerate(stages):
        if repeats <= 1:
            continue
        sp_sds = jax.tree.map(lambda s: _drop_axis(s, mesh, repeat_axis),
                              params_sds["stages"][s_idx], is_leaf=_is_sds)

        if kind == "train":
            group = model.group_fwd_fn(kinds)

            if gossip:
                def loss_body(p, x):
                    def one(p_, x_):
                        y, aux = group(x_, p_, _positions_like(x_))
                        return jnp.sum(y.astype(jnp.float32)) + aux
                    return jnp.mean(jax.vmap(one)(p, x))
            else:
                def loss_body(p, x):
                    y, aux = group(x, p, _positions_like(x))
                    return jnp.sum(y.astype(jnp.float32)) + aux

            lowered = jax.jit(jax.grad(loss_body, argnums=(0, 1))).lower(sp_sds, x_sds)
        elif kind == "prefill":
            group = model.group_fwd_fn(kinds)
            lowered = jax.jit(
                lambda p, x: group(x, p, _positions_like(x))).lower(sp_sds, x_sds)
        else:  # decode
            group_dec = model.group_decode_fn(kinds)
            c_sds = jax.tree.map(lambda s: _drop_axis(s, mesh, 0),
                                 cache_sds[s_idx], is_leaf=_is_sds)
            pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = jax.jit(
                lambda p, x, c, pos: group_dec(x, p, c, pos)).lower(
                    sp_sds, x_sds, c_sds, pos_sds)
        total = total + _cost_of(lowered, parse_collectives) * (repeats - 1)
    return total
