import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combination
against ShapeDtypeStruct inputs, print memory/cost analysis, and extract the
roofline terms (EXPERIMENTS.md §Dry-run / §Roofline).

The two lines above MUST stay first: jax locks the device count at first
init, and the production meshes need 512 placeholder host devices. Nothing
else in the repo sets this flag (tests/benches see the real single device).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.jsonl
  ... --multi-pod            (2 x 16 x 16 mesh; default single-pod 16 x 16)
  ... --consensus gossip     (paper technique; gossip axis = pod or data)
"""

import argparse
import json
import re
import sys
import time
from dataclasses import asdict, dataclass

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, InputShape, skip_reason
from repro.launch import input_specs as ispecs
from repro.launch import shardings as shard
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_production_mesh
from repro.models.transformer import Model

# ------------------------------------------------------------ HW constants
PEAK_FLOPS = 197e12      # TPU v5e bf16 FLOP/s per chip
HBM_BW = 819e9           # bytes/s per chip
LINK_BW = 50e9           # bytes/s per ICI link

from repro.launch.hlo_parse import (  # noqa: F401 — re-exported API
    _COLL_RE, _GROUPS_RE, _shape_bytes, cost_analysis_dict, parse_collectives)


def model_flops(cfg, shape: InputShape, n_params_active: int, n_params_total: int) -> float:
    """6*N*D with N = active params (MoE counts top-k+shared experts only)."""
    tokens = shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len)
    mult = 6 if shape.kind == "train" else 2
    return mult * n_params_active * tokens


def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def count_active_params(cfg, params) -> int:
    """Total params minus the non-routed share of expert weights."""
    total = count_params(params)
    if cfg.moe is None:
        return total
    import numpy as np
    expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        ps = shard._path_str(path)
        if re.search(r"ch/w[igo]$", ps):
            expert += int(np.prod(leaf.shape))
    active = total - expert + int(expert * cfg.moe.top_k / cfg.moe.n_experts)
    return active


@dataclass
class DryrunResult:
    arch: str
    shape: str
    mesh: str
    consensus: str
    status: str                  # ok | skipped | failed
    reason: str = ""
    compile_secs: float = 0.0
    per_device_bytes: int = 0    # peak (args+temp+output) from memory_analysis
    arg_bytes: int = 0
    temp_bytes: int = 0
    hlo_flops: float = 0.0       # per device, scan-corrected (see analysis.py)
    hlo_bytes: float = 0.0       # per device, scan-corrected
    collective_bytes: float = 0.0
    rolled_flops: float = 0.0    # uncorrected (while bodies counted once)
    collectives: dict | None = None
    n_params: int = 0
    n_params_active: int = 0
    model_flops_global: float = 0.0
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    useful_flop_ratio: float = 0.0


def _roofline(res: DryrunResult, n_chips: int) -> None:
    res.compute_s = res.hlo_flops / PEAK_FLOPS
    res.memory_s = res.hlo_bytes / HBM_BW
    res.collective_s = res.collective_bytes / LINK_BW
    terms = {"compute": res.compute_s, "memory": res.memory_s,
             "collective": res.collective_s}
    res.bottleneck = max(terms, key=terms.get)
    global_hlo_flops = res.hlo_flops * n_chips
    res.useful_flop_ratio = (res.model_flops_global / global_hlo_flops
                             if global_hlo_flops else 0.0)


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            consensus: str = "allreduce", remat: bool = False,
            verbose: bool = True, extra_tag: str = "",
            param_mode: str = "auto", seq_shard: bool = False,
            remat_policy: str = "full", swa_variant: bool = False) -> DryrunResult:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    arch_label = arch
    if swa_variant and not cfg.subquadratic() and not cfg.is_encoder:
        # sliding-window variant of a full-attention arch: the sanctioned
        # carve-in that makes long_500k runnable for dense models. Reported
        # as "<arch>+swa" — a variant, not the assigned config.
        import dataclasses
        cfg = dataclasses.replace(
            cfg, name=f"{cfg.name}+swa",
            block_pattern=tuple("swa" for _ in cfg.block_pattern), window=4096)
        arch_label = f"{arch}+swa"
    mesh_name = ("2x16x16" if multi_pod else "16x16") + (extra_tag or "")
    res = DryrunResult(arch=arch_label, shape=shape_name, mesh=mesh_name,
                       consensus=consensus, status="ok")

    why = skip_reason(cfg, shape)
    if why:
        res.status, res.reason = "skipped", why
        if verbose:
            _print_result(res)
        return res

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    model = Model(cfg, dtype=jnp.bfloat16, param_dtype=jnp.bfloat16)

    gossip = consensus == "gossip"
    replica_axis = "pod" if multi_pod else "data"
    n_replicas = dict(zip(mesh.axis_names, mesh.devices.shape))[replica_axis] if gossip else 1
    if gossip and shape.kind != "train":
        res.status, res.reason = "skipped", "gossip consensus applies to training only"
        if verbose:
            _print_result(res)
        return res

    tcfg = steps_mod.TrainerConfig(consensus=consensus, n_replicas=n_replicas,
                                   replica_axis=replica_axis, remat=remat,
                                   remat_policy=remat_policy)

    # logical-axis rules: batch over the DP axes (minus the gossip replica
    # axis, which vmap handles via spmd_axis_name), vocab over `model`.
    from repro.sharding.api import AxisRules, activate
    batch_axes_all = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    rule_batch = tuple(a for a in batch_axes_all if not (gossip and a == replica_axis))
    rules = AxisRules(mesh, {
        "batch": rule_batch or None,
        "seq": ("model" if seq_shard else None),
        "embed": None,
        "vocab": "model",
        "mlp": "model",        # MoE expert hidden dim
        "expert": None,
        "capacity": None,
        "heads_dec": None,     # decode q heads replicated (flash-decode)
        "cache_seq": "model",  # decode scores sharded on cache sequence
    })

    t0 = time.time()
    _rules_ctx = activate(rules)
    _rules_ctx.__enter__()
    try:
        key = jax.random.PRNGKey(0)
        if shape.kind == "train":
            state_shapes = jax.eval_shape(
                lambda k: steps_mod.make_train_state(model, tcfg, k), key)
            # ZeRO-1 (weights TP-only, moments data-sharded) for models whose
            # TP shard fits comfortably; ZeRO-3/FSDP for the 100B+ ones.
            param_bytes = sum(x.size * x.dtype.itemsize
                              for x in jax.tree.leaves(state_shapes["params"]))
            mode = "zero1" if (param_mode == "auto" and param_bytes < 60e9) else \
                ("fsdp" if param_mode == "auto" else param_mode)
            pspecs = shard.param_specs(mesh, state_shapes["params"], gossip=gossip,
                                       replica_axis=replica_axis, mode=mode)
            mspecs = shard.param_specs(mesh, state_shapes["params"], gossip=gossip,
                                       replica_axis=replica_axis, mode="fsdp")
            sspecs = steps_mod.train_state_specs(pspecs, tcfg, moment_specs=mspecs)
            bspecs = shard.batch_specs(mesh, cfg, shape, gossip_stacked=gossip,
                                       replica_axis=replica_axis)
            bshapes = ispecs.train_batch_shapes(cfg, shape,
                                                n_replicas=n_replicas if gossip else 0)
            state_sds = jax.tree.map(
                lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                   sharding=NamedSharding(mesh, sp)),
                state_shapes, sspecs,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
            batch_sds = {k: jax.ShapeDtypeStruct(v.shape, v.dtype,
                                                 sharding=NamedSharding(mesh, bspecs[k]))
                         for k, v in bshapes.items()}
            step_fn = steps_mod.make_train_step(model, tcfg)
            lowered = jax.jit(step_fn).lower(state_sds, batch_sds)
        elif shape.kind == "prefill":
            params_shape = jax.eval_shape(model.init, key)
            pb = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params_shape))
            mode = "zero1" if (param_mode == "auto" and pb < 60e9) else \
                ("fsdp" if param_mode == "auto" else param_mode)
            pspecs = shard.param_specs(mesh, params_shape, mode=mode)
            bspecs = shard.batch_specs(mesh, cfg, shape)
            bshapes = ispecs.train_batch_shapes(cfg, shape)
            params_sds = jax.tree.map(
                lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                   sharding=NamedSharding(mesh, sp)),
                params_shape, pspecs,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
            batch_sds = {k: jax.ShapeDtypeStruct(v.shape, v.dtype,
                                                 sharding=NamedSharding(mesh, bspecs[k]))
                         for k, v in bshapes.items()}
            lowered = jax.jit(steps_mod.make_prefill_step(model)).lower(params_sds, batch_sds)
        else:  # decode
            params_shape = jax.eval_shape(model.init, key)
            pb = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params_shape))
            mode = "zero1" if (param_mode == "auto" and pb < 60e9) else \
                ("fsdp" if param_mode == "auto" else param_mode)
            pspecs = shard.param_specs(mesh, params_shape, mode=mode)
            params_sds = jax.tree.map(
                lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                   sharding=NamedSharding(mesh, sp)),
                params_shape, pspecs,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
            tokens_sds, cache_shapes, pos_sds = ispecs.decode_input_shapes(model, shape)
            cspecs = shard.cache_spec_tree(mesh, cache_shapes)
            cache_sds = jax.tree.map(
                lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                   sharding=NamedSharding(mesh, sp)),
                cache_shapes, cspecs,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
            batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
            tok_spec = (P(batch_axes, None)
                        if shape.global_batch % n_chips_axis(mesh, batch_axes) == 0
                        else P(*([None] * 2)))
            tokens_sds = jax.ShapeDtypeStruct(tokens_sds.shape, tokens_sds.dtype,
                                              sharding=NamedSharding(mesh, tok_spec))
            lowered = jax.jit(steps_mod.make_serve_step(model)).lower(
                params_sds, tokens_sds, cache_sds, pos_sds)

        compiled = lowered.compile()
        res.compile_secs = time.time() - t0

        ma = compiled.memory_analysis()
        res.arg_bytes = int(getattr(ma, "argument_size_in_bytes", 0))
        res.temp_bytes = int(getattr(ma, "temp_size_in_bytes", 0))
        res.per_device_bytes = (res.arg_bytes + res.temp_bytes
                                + int(getattr(ma, "output_size_in_bytes", 0))
                                - int(getattr(ma, "alias_size_in_bytes", 0)))
        ca = cost_analysis_dict(compiled)
        res.rolled_flops = float(ca.get("flops", 0.0))
        res.hlo_flops = res.rolled_flops
        res.hlo_bytes = float(ca.get("bytes accessed", 0.0))
        colls = parse_collectives(compiled.as_text())
        res.collectives = colls
        res.collective_bytes = float(colls["total_bytes"])

        # scan-body correction (XLA counts while bodies once; analysis.py)
        D = cfg.d_model
        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        if shape.kind == "train":
            if gossip:
                x_spec = P(replica_axis, tuple(a for a in batch_axes if a != replica_axis) or None,
                           None, None)
                x_shape = (n_replicas, shape.global_batch // n_replicas, shape.seq_len, D)
            else:
                x_spec = P(batch_axes, None, None)
                x_shape = (shape.global_batch, shape.seq_len, D)
        elif shape.kind == "prefill":
            x_spec = P(batch_axes, None, None)
            x_shape = (shape.global_batch, shape.seq_len, D)
        else:
            divisible = shape.global_batch % n_chips_axis(mesh, batch_axes) == 0
            x_spec = P(batch_axes if divisible else None, None, None)
            x_shape = (shape.global_batch, 1, D)
        x_sds = jax.ShapeDtypeStruct(x_shape, jnp.bfloat16,
                                     sharding=NamedSharding(mesh, x_spec))
        from repro.launch.analysis import stage_costs
        params_sds_tree = (state_sds["params"] if shape.kind == "train" else params_sds)
        corr = stage_costs(model, mesh=mesh, kind=shape.kind, x_sds=x_sds,
                           params_sds=params_sds_tree,
                           cache_sds=(cache_sds if shape.kind == "decode" else None),
                           parse_collectives=parse_collectives, gossip=gossip)
        res.hlo_flops += corr["flops"]
        res.hlo_bytes += corr["bytes"]
        res.collective_bytes += corr["collective_bytes"]

        params_tree = (state_shapes["params"] if shape.kind == "train" else params_shape)
        res.n_params = count_params(params_tree) // (n_replicas if gossip else 1)
        res.n_params_active = count_active_params(cfg, params_tree) // (n_replicas if gossip else 1)
        res.model_flops_global = model_flops(cfg, shape, res.n_params_active, res.n_params)
        _roofline(res, n_chips)
    except Exception as e:  # noqa: BLE001 — dry-run failures are data
        res.status = "failed"
        res.reason = f"{type(e).__name__}: {e}"[:500]
        res.compile_secs = time.time() - t0
    finally:
        _rules_ctx.__exit__(None, None, None)
    if verbose:
        _print_result(res)
    return res


def n_chips_axis(mesh, axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in axes:
        n *= sizes[a]
    return max(n, 1)


def _print_result(res: DryrunResult) -> None:
    if res.status != "ok":
        print(f"[{res.status}] {res.arch} x {res.shape} ({res.mesh}, {res.consensus}): {res.reason}")
        return
    print(f"[ok] {res.arch} x {res.shape} ({res.mesh}, {res.consensus}) "
          f"compile={res.compile_secs:.1f}s")
    print(f"     per-device bytes: args={res.arg_bytes/2**30:.2f}GiB "
          f"temp={res.temp_bytes/2**30:.2f}GiB total={res.per_device_bytes/2**30:.2f}GiB")
    print(f"     per-device HLO: flops={res.hlo_flops:.3e} bytes={res.hlo_bytes:.3e} "
          f"collective_bytes={res.collective_bytes:.3e}")
    print(f"     roofline: compute={res.compute_s*1e3:.2f}ms memory={res.memory_s*1e3:.2f}ms "
          f"collective={res.collective_s*1e3:.2f}ms -> {res.bottleneck}-bound; "
          f"useful-flop ratio={res.useful_flop_ratio:.2f}")
    if res.collectives and res.collectives["count_by_op"]:
        print(f"     collectives: {res.collectives['count_by_op']}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--all", action="store_true", help="every (arch x shape)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--consensus", default="allreduce", choices=("allreduce", "gossip"))
    ap.add_argument("--remat", action=argparse.BooleanOptionalAction, default=True,
                    help="activation-checkpoint each block group in train steps")
    ap.add_argument("--remat-policy", default="full", choices=("full", "dots"))
    ap.add_argument("--swa-variant", action="store_true",
                    help="replace full attention with SWA(4096) — unlocks "
                         "long_500k for dense archs, labeled '<arch>+swa'")
    ap.add_argument("--seq-shard", action="store_true",
                    help="Megatron-style sequence parallelism: residual stream "
                         "sharded on `model` between blocks")
    ap.add_argument("--param-mode", default="auto", choices=("auto", "fsdp", "zero1"),
                    help="weight sharding: fsdp (ZeRO-3), zero1 (TP-only weights, "
                         "data-sharded moments), or auto by model size")
    ap.add_argument("--out", help="append JSONL records here")
    args = ap.parse_args(argv)

    combos = []
    archs = ARCH_IDS if (args.all or not args.arch) else (args.arch,)
    shapes = tuple(SHAPES) if (args.all or not args.shape) else (args.shape,)
    meshes = (False, True) if args.both_meshes else (args.multi_pod,)
    for a in archs:
        for s in shapes:
            for mp in meshes:
                combos.append((a, s, mp))

    n_fail = 0
    records = []
    for a, s, mp in combos:
        res = run_one(a, s, multi_pod=mp, consensus=args.consensus, remat=args.remat,
                      param_mode=args.param_mode, seq_shard=args.seq_shard,
                      remat_policy=args.remat_policy, swa_variant=args.swa_variant)
        records.append(res)
        n_fail += res.status == "failed"
        if args.out:
            with open(args.out, "a") as fh:
                fh.write(json.dumps(asdict(res)) + "\n")
    ok = sum(r.status == "ok" for r in records)
    sk = sum(r.status == "skipped" for r in records)
    print(f"\n== dry-run summary: {ok} ok, {sk} skipped, {n_fail} failed "
          f"of {len(records)} ==")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
