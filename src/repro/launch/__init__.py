"""Launch layer: production meshes, sharding plans, step builders, dry-run,
and the train/serve drivers. dryrun.py must be executed as its own process
(it forces 512 placeholder devices before jax init)."""
