"""Launch layer for the *transformer* seed scaffolding: production meshes,
sharding plans, step builders, dry-run, and token-level train/serve drivers
(``launch.train`` / ``launch.serve`` decode tokens, not SVM scores).

This package predates the GADGET SVM work and is kept for architecture
dry-runs and the gossip-consensus-for-deep-nets experiments. The SVM serving
path — anytime snapshots, checkpoint publishing, hot-swapping ``SvmServer``,
bucketed sparse queries — lives in ``repro.serve`` (see
``docs/ARCHITECTURE.md``). dryrun.py must be executed as its own process
(it forces 512 placeholder devices before jax init)."""
