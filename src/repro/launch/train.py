"""Training driver — runs real steps on whatever devices exist.

On this container (CPU) it trains reduced configs end-to-end; on a TPU slice
the same driver takes the production mesh. Consensus strategy is selectable:
``--consensus gossip`` turns on the paper's Push-Sum parameter mixing across
``--n-replicas`` divergent replicas (the GADGET protocol applied to deep
nets); default is classical all-reduce DP.

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --reduced \
      --steps 50 --batch 8 --seq 128 --consensus gossip --n-replicas 4
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.configs import ARCH_IDS, get_config
from repro.launch import input_specs as ispecs
from repro.launch import steps as steps_mod
from repro.models.transformer import Model


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llama3-8b", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="train the reduced (CI-scale) variant")
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="adamw", choices=("adamw", "sgd"))
    ap.add_argument("--consensus", default="allreduce", choices=("allreduce", "gossip"))
    ap.add_argument("--n-replicas", type=int, default=4)
    ap.add_argument("--gossip-rounds", type=int, default=1)
    ap.add_argument("--mix-every", type=int, default=1)
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", help="save checkpoints here")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-jsonl", help="append step metrics here")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(n_layers=args.layers, d_model=args.d_model)
    model = Model(cfg)
    gossip = args.consensus == "gossip"
    tcfg = steps_mod.TrainerConfig(
        optimizer=args.optimizer, lr=args.lr, total_steps=args.steps,
        warmup_steps=max(1, args.steps // 10), consensus=args.consensus,
        n_replicas=args.n_replicas if gossip else 1,
        gossip_rounds=args.gossip_rounds, mix_every=args.mix_every,
        remat=args.remat)

    key = jax.random.PRNGKey(args.seed)
    state = make_state = steps_mod.make_train_state(model, tcfg, key)
    step_fn = jax.jit(steps_mod.make_train_step(model, tcfg))

    print(f"arch={cfg.name} params={sum(x.size for x in jax.tree.leaves(state['params'])):,} "
          f"consensus={args.consensus}"
          + (f" replicas={args.n_replicas} rounds={args.gossip_rounds}" if gossip else ""))

    # structured synthetic stream (Zipf + motifs) for token models so the
    # loss actually has something to learn; random embeddings otherwise.
    batcher = None
    if cfg.embed_kind == "tokens":
        from repro.data.tokens import Batcher, TokenStreamConfig
        batcher = Batcher(TokenStreamConfig(vocab_size=cfg.vocab_size,
                                            seq_len=args.seq,
                                            global_batch=args.batch,
                                            seed=args.seed))

    def get_batch(step: int):
        if batcher is None:
            return ispecs.make_host_batch(
                cfg, args.batch, args.seq, key=jax.random.PRNGKey(1000 + step),
                n_replicas=args.n_replicas if gossip else 0)
        b = batcher.global_batch(step)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        if gossip:
            G = args.n_replicas
            b = {k: v.reshape(G, v.shape[0] // G, *v.shape[1:]) for k, v in b.items()}
        return b

    losses = []
    t0 = time.time()
    for step in range(args.steps):
        batch = get_batch(step)
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % max(1, args.steps // 10) == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} ({time.time()-t0:.1f}s)")
        if args.log_jsonl:
            with open(args.log_jsonl, "a") as fh:
                fh.write(json.dumps({"step": step, "loss": loss,
                                     "t": time.time() - t0}) + "\n")
        if args.ckpt_dir and args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, step + 1, state)

    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps, state)
    return 0 if last < first else 1


if __name__ == "__main__":
    raise SystemExit(main())
