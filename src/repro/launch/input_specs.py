"""ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
shardable, zero allocation. The dry-run lowers against these.

Layouts:
  train/prefill tokens:  {tokens (B,S) i32, targets (B,S) i32}
  vlm:     {patch_embeds (B,P,D) bf16, tokens (B,S-P) i32, targets (B,S-P)}
  frames:  {frames (B,S,D) bf16, targets (B,S) i32, mask (B,S) bool}
  decode:  tokens (B,1) i32, caches (eval_shape of model.init_cache), pos ()

Gossip-mode training batches gain a leading replica axis: (G, B/G, ...).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.shapes import InputShape
from repro.models.config import ModelConfig
from repro.models.transformer import Model

__all__ = ["train_batch_shapes", "decode_input_shapes", "make_host_batch"]


def train_batch_shapes(cfg: ModelConfig, shape: InputShape, *,
                       n_replicas: int = 0, act_dtype=jnp.bfloat16) -> dict[str, jax.ShapeDtypeStruct]:
    B, S = shape.global_batch, shape.seq_len
    lead = (n_replicas, B // n_replicas) if n_replicas else (B,)

    def sds(*dims, dtype=jnp.int32):
        return jax.ShapeDtypeStruct(lead + dims, dtype)

    if cfg.embed_kind == "tokens":
        return {"tokens": sds(S), "targets": sds(S)}
    if cfg.embed_kind == "patches":
        P_ = min(cfg.n_prefix_embeds, S // 2)
        St = S - P_
        return {
            "patch_embeds": sds(P_, cfg.d_model, dtype=act_dtype),
            "tokens": sds(St),
            "targets": sds(St),
        }
    if cfg.embed_kind == "frames":
        return {
            "frames": sds(S, cfg.d_model, dtype=act_dtype),
            "targets": sds(S),
            "mask": sds(S, dtype=jnp.bool_),
        }
    raise ValueError(cfg.embed_kind)


def decode_input_shapes(model: Model, shape: InputShape, *, cache_dtype=jnp.bfloat16):
    """(tokens_sds, cache_shapes, pos_sds) for serve_step lowering."""
    cfg = model.cfg
    B, S = shape.global_batch, shape.seq_len
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    cache_shapes = jax.eval_shape(
        lambda: model.init_cache(B, S, cache_dtype))
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return tokens, cache_shapes, pos


def make_host_batch(cfg: ModelConfig, batch: int, seq: int, *, key=None,
                    n_replicas: int = 0, dtype=jnp.float32) -> dict[str, jax.Array]:
    """Small *concrete* batch for CPU smoke training (same layouts)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    lead = (n_replicas, batch // n_replicas) if n_replicas else (batch,)

    def toks(k, *dims):
        return jax.random.randint(k, lead + dims, 0, cfg.vocab_size)

    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.embed_kind == "tokens":
        t = toks(k1, seq + 1)
        return {"tokens": t[..., :-1], "targets": t[..., 1:]}
    if cfg.embed_kind == "patches":
        P_ = min(cfg.n_prefix_embeds, seq // 2)
        t = toks(k1, seq - P_ + 1)
        return {
            "patch_embeds": 0.02 * jax.random.normal(k2, lead + (P_, cfg.d_model), dtype),
            "tokens": t[..., :-1],
            "targets": t[..., 1:],
        }
    if cfg.embed_kind == "frames":
        return {
            "frames": 0.02 * jax.random.normal(k2, lead + (seq, cfg.d_model), dtype),
            "targets": toks(k1, seq),
            "mask": jax.random.bernoulli(k3, 0.5, lead + (seq,)),
        }
    raise ValueError(cfg.embed_kind)
