"""Serving driver for the *transformer* architectures: prefill a batch of
requests, then decode tokens.

NOTE: this is the seed's token-decode surface, kept for architecture
dry-runs (``launch.dryrun`` lowers the same serve_step on the production
mesh). It is NOT the SVM serving path — for scoring GADGET SVM models
(anytime snapshots, bucketed sparse queries, fused predict kernels) use
``repro.serve`` (``SvmServer``; see ``examples/serve_batched.py``).

Runs reduced configs on CPU end-to-end (greedy sampling); the same
serve_step is what the decode dry-run shapes lower on the production mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x22b \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.launch import steps as steps_mod
from repro.models.transformer import Model


def prefill_into_cache(model: Model, params, tokens: jax.Array, cache, step_fn):
    """Feed the prompt one token at a time (simple, reuses serve_step; a
    production prefill would batch this — covered by prefill_32k lowering)."""
    B, S = tokens.shape
    logits = None
    for t in range(S):
        logits, cache = step_fn(params, tokens[:, t:t + 1], cache, jnp.int32(t))
    return logits, cache


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llama3-8b", choices=ARCH_IDS)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced(n_layers=args.layers, d_model=args.d_model)
    if not cfg.supports_decode():
        print(f"{cfg.name} is encoder-only: no decode path (see DESIGN.md)")
        return 0
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    step_fn = jax.jit(steps_mod.make_serve_step(model))

    total = args.prompt_len + args.gen
    cache = model.init_cache(args.batch, total, jnp.float32)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (args.batch, args.prompt_len),
                                0, cfg.vocab_size)

    t0 = time.time()
    logits, cache = prefill_into_cache(model, params, prompt, cache, step_fn)
    t_prefill = time.time() - t0

    out_tokens = []
    tok = jnp.argmax(logits[:, -1:], axis=-1)
    t0 = time.time()
    for i in range(args.gen):
        out_tokens.append(tok)
        logits, cache = step_fn(params, tok, cache, jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits[:, -1:], axis=-1)
    t_decode = time.time() - t0
    gen = jnp.concatenate(out_tokens, axis=1)

    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} gen={args.gen}")
    print(f"prefill {t_prefill*1e3:.1f}ms  decode {t_decode*1e3/args.gen:.2f}ms/tok")
    print("sample row 0:", gen[0].tolist())
    assert bool(jnp.all((gen >= 0) & (gen < cfg.vocab_size)))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
