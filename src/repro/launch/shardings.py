"""Parameter / batch / cache PartitionSpec assignment.

Divisibility-aware: every preferred mesh-axis placement is checked against
the actual dim size and falls back to replication when it does not divide —
one rule table serves all ten architectures on any mesh.

Default layout (single pod): tensor parallel over `model`, FSDP over `data`
(ZeRO-3-style: 405B params + AdamW moments shard over all 256 chips). The
gossip-consensus variant stacks a leading replica axis on every param leaf,
sharded over the gossip axis (`pod` on the multi-pod mesh) — see
launch/steps.py.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.shapes import InputShape
from repro.models.config import ModelConfig

Pytree = Any

__all__ = ["param_specs", "batch_specs", "cache_spec_tree", "named", "ShardingPlan"]


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    if axes is None:
        return True
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    prod = int(np.prod([sizes[a] for a in axes]))
    return dim % prod == 0


def _spec(mesh: Mesh, shape: tuple[int, ...], *wants) -> P:
    """wants[i] = preferred mesh axis (or tuple) for dim i; falls back to the
    largest prefix of the axis tuple that divides, then to None."""
    entries = []
    used: set[str] = set()
    for dim, want in zip(shape, wants):
        placed = None
        if want is not None:
            cands = (want,) if isinstance(want, str) else tuple(want)
            # try longest prefix first: ("model","data") -> both, then model only
            for k in range(len(cands), 0, -1):
                pre = tuple(a for a in cands[:k] if a not in used)
                if pre and _fits(dim, mesh, pre):
                    placed = pre if len(pre) > 1 else pre[0]
                    used.update(pre)
                    break
        entries.append(placed)
    return P(*entries)


# ----------------------------------------------------------------- params

_PARAM_RULES: list[tuple[str, tuple]] = [
    # (path regex, wants per dim) — first match wins
    (r"embed/table$",        ("model", "data")),       # (V, D) vocab-parallel + fsdp
    (r"attn/wq$",            ("data", "model", None)),  # (D, H, Dh)
    (r"attn/w[kv]$",         (("model", "data"), None, None)),  # (D, Hkv, Dh) row-parallel
    (r"attn/wo$",            ("model", None, "data")),  # (H, Dh, D)
    (r"ch/router$",          ("data", None)),           # (D, E)
    (r"shared/w[ig]/w$",     ("data", "model")),        # moe shared-expert mlp (D, F)
    (r"shared/wo/w$",        ("model", "data")),
    (r"ch/w[ig]$",           (None, "data", "model")),  # moe (E, D, F) TP-in-expert
    (r"ch/wo$",              (None, "model", "data")),  # moe (E, F, D)
    (r"ch/w[ig]/w$",         ("data", "model")),        # dense mlp (D, F)
    (r"ch/wo/w$",            ("model", "data")),        # dense mlp (F, D)
    (r"rglru/w_(gate_in|rnn_in)$", ("data", "model")),  # (D, Drnn)
    (r"rglru/w_[ax]$",       (None, "model")),          # (Drnn, Drnn)
    (r"rglru/conv_w$",       (None, "model")),
    (r"rglru/(lambda|b_[ax])$", ("model",)),
    (r"rglru/w_out$",        ("model", "data")),
    (r"rwkv/w_[rkvg]$",      ("data", "model")),        # (D, D)
    (r"rwkv/w_o$",           ("model", "data")),
    (r"rwkv/cm_w[ir]$",      ("data", "model")),
    (r"rwkv/cm_wo$",         ("model", "data")),
    (r"rwkv/decay_lora_a$",  ("data", None)),
    (r"rwkv/decay_lora_b$",  (None, "model")),
    (r"rwkv/bonus_u$",       (None, None)),
    (r"head/w$",             ("data", "model")),        # (D, V)
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _strip_axis(wants: tuple, axis: str) -> tuple:
    out = []
    for w in wants:
        if w is None:
            out.append(None)
            continue
        ws = tuple(a for a in ((w,) if isinstance(w, str) else w) if a != axis)
        out.append(ws[0] if len(ws) == 1 else (ws or None))
    return tuple(out)


def param_specs(mesh: Mesh, params_shape: Pytree, *, gossip: bool = False,
                replica_axis: str = "pod", mode: str = "fsdp") -> Pytree:
    """PartitionSpec tree for a param (shape-)tree.

    Stage params carry a leading layer-repeat axis (replicated) from their
    vmapped init. ``gossip=True`` expects one more leading axis on *every*
    leaf — the divergent-replica axis — sharded on ``replica_axis``.

    ``mode``: "fsdp" shards weight dims over `data` too (ZeRO-3 — required
    for 100B+ models); "zero1" keeps weights TP-only (replicated over
    `data`) — XLA then never gathers *activations* to feed a data-sharded
    contraction, which measured 6 GiB/layer on llama3-8b train_4k. ZeRO-1
    memory is recovered by sharding the optimizer moments over `data`
    (see steps.train_state_specs).
    """

    def leaf_spec(path, leaf) -> P:
        ps = _path_str(path)
        shape = tuple(leaf.shape)
        lead: list = []
        if gossip:
            lead.append(replica_axis if replica_axis in mesh.axis_names else None)
        if ps.startswith("stages"):
            lead.append(None)  # layer-repeat axis
        core_shape = shape[len(lead):]
        for rx, wants in _PARAM_RULES:
            if re.search(rx, ps):
                if gossip:  # the replica axis is taken by the leading dim
                    wants = _strip_axis(wants, replica_axis)
                if mode == "zero1":
                    wants = _strip_axis(wants, "data")
                core = _spec(mesh, core_shape, *wants)
                break
        else:
            core = P(*([None] * len(core_shape)))
        return P(*lead, *core)

    return jax.tree_util.tree_map_with_path(leaf_spec, params_shape)


# ------------------------------------------------------------ batch/cache

def batch_specs(mesh: Mesh, cfg: ModelConfig, shape: InputShape, *,
                gossip_stacked: bool = False, replica_axis: str = "pod") -> dict[str, P]:
    """Specs for the input batch dict (matches launch.input_specs layouts)."""
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if gossip_stacked:
        batch_axes = tuple(a for a in batch_axes if a != replica_axis)
    bspec = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)

    def vec(*extra):
        lead = (replica_axis,) if gossip_stacked and replica_axis in mesh.axis_names else ()
        return P(*lead, bspec, *extra)

    out = {"tokens": vec(None), "targets": vec(None)}
    if cfg.embed_kind == "patches":
        out["patch_embeds"] = vec(None, None)
    if cfg.embed_kind == "frames":
        out = {"frames": vec(None, None), "targets": vec(None), "mask": vec(None)}
    return out


def cache_spec_tree(mesh: Mesh, cache_shapes: Pytree) -> Pytree:
    """PartitionSpec tree for an eval_shape'd decode-cache tree.

    Attention KV (R, B, S_cache, Hkv, Dh): batch on `data` when divisible;
    cache sequence on `model` (flash-decode-style partial-softmax sharding —
    Hkv is too small to cover the axis) — memory-balances the 32k caches.
    RWKV state (R, B, H, n, n) hits the same 5-dim rule; its H dim simply
    fails divisibility and replicates, which is right (state is KBs).
    Recurrent channel dims go on `model` when divisible.
    """
    def leaf(x) -> P:
        shape = tuple(x.shape)
        if len(shape) == 5:       # (R, B, S_cache, Hkv, Dh) or rwkv (R, B, H, n, n)
            return _spec(mesh, shape, None, "data", "model", None, None)
        if len(shape) == 4:       # rglru conv tail (R, B, W-1, D)
            return _spec(mesh, shape, None, "data", None, "model")
        if len(shape) == 3:       # (R, B, D) recurrent carries
            return _spec(mesh, shape, None, "data", "model")
        return P(*([None] * len(shape)))

    return jax.tree.map(leaf, cache_shapes)


def named(mesh: Mesh, spec_tree: Pytree) -> Pytree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


class ShardingPlan:
    """Bundle of spec trees for one (arch, shape, mesh, consensus) combo."""

    def __init__(self, mesh: Mesh, params: Pytree, batch: Pytree, opt: Pytree | None = None,
                 cache: Pytree | None = None):
        self.mesh = mesh
        self.params = params
        self.batch = batch
        self.opt = opt
        self.cache = cache
