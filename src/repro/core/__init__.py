"""Core: the GADGET SVM paper's contribution — gossip/Push-Sum consensus
learning — as composable JAX modules.

* topology      — gossip graphs + doubly-stochastic mixing matrices
* push_sum      — Push-Sum/Push-Vector (simulator + mesh/ppermute paths)
* svm_objective — primal SVM math shared by Pegasos/GADGET/kernels
* pegasos       — centralized baseline solver
* gadget        — the distributed GADGET SVM algorithm
* consensus     — gossip vs all-reduce strategies for deep-net training
* faults        — device-resident fault injection (FaultPlan) for gossip
* resilience    — host-side faulty Push-Sum simulator over the same plan
"""
from repro.core.topology import (  # noqa: F401
    TOPOLOGIES,
    build_matrix,
    is_doubly_stochastic,
    mixing_time_bound,
)
from repro.core.push_sum import (  # noqa: F401
    GossipRound,
    PushSumSim,
    PushSumState,
    exponential_schedule,
    push_sum_mesh,
    push_sum_round,
)
from repro.core.faults import (  # noqa: F401
    FaultPlan,
    apply_faults,
    faulty_rounds,
    validate_plan,
)
from repro.core.resilience import FaultySim  # noqa: F401
from repro.core.gadget import GadgetConfig, GadgetResult, TrainState, gadget_train  # noqa: F401
from repro.core.pegasos import PegasosResult, pegasos_train  # noqa: F401
from repro.core.consensus import ConsensusConfig, allreduce_grads, gossip_mix, mix_params  # noqa: F401
