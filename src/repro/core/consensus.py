"""Consensus strategies: the paper's gossip protocol as a first-class
alternative to all-reduce data parallelism for arbitrary models.

* ``allreduce`` — classical synchronous DP: gradients are ``pmean``-ed over
  the replica axes every step. This is the "centralized" reference point,
  the deep-net analogue of the paper's Pegasos baseline.

* ``gossip`` — Stochastic-Gradient-Push / GADGET-style: gradients are NOT
  synchronized; each replica applies its local optimizer update, then the
  *parameters* are mixed with ``R`` Push-Sum rounds over the time-varying
  one-peer exponential graph (one ``ppermute`` per round). ``R`` per step is a
  knob: R = log2(n_replicas) gives exact averaging (gossip-equivalent of
  all-reduce); R < log2(n) gives the paper's partial-consensus anytime
  behaviour at a fraction of the per-step communication.

Collective-cost napkin math (recorded for §Roofline): ring all-reduce moves
2·(n−1)/n · |params| bytes per step per replica; R gossip rounds move
R/2 · |params| (each round ships self_share-weighted halves one hop). With
R = 2 on a 16-way axis gossip ships ~1.0× |params| vs ~1.9× for all-reduce —
the paper's "cheaper than centralizing" claim, now measurable in the dry-run.

The mixing runs *inside shard_map*; schedule rotation across steps uses
``lax.switch`` on the traced step counter so one compiled program serves all
steps.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core.push_sum import GossipRound, PushSumState, exponential_schedule, push_sum_round

Pytree = Any

__all__ = ["ConsensusConfig", "allreduce_grads", "gossip_mix", "gossip_mix_stacked", "mix_params"]


class ConsensusConfig(NamedTuple):
    kind: str = "allreduce"       # "allreduce" | "gossip" | "none"
    gossip_rounds: int = 2        # R — Push-Sum rounds per optimizer step
    self_share: float = 0.5
    mix_every: int = 1            # gossip only every k-th step (local SGD flavor)

    def validate(self) -> "ConsensusConfig":
        if self.kind not in ("allreduce", "gossip", "none"):
            raise ValueError(f"unknown consensus kind {self.kind!r}")
        if self.gossip_rounds < 1 or self.mix_every < 1:
            raise ValueError("gossip_rounds and mix_every must be >= 1")
        return self


def allreduce_grads(grads: Pytree, axis_names: Sequence[str]) -> Pytree:
    """pmean over the replica axes (inside shard_map)."""
    axes = tuple(axis_names)
    return jax.tree.map(lambda g: jax.lax.pmean(g, axes), grads)


def _one_round_branches(sched: list[GossipRound], self_share: float):
    """One lax.switch branch per schedule entry (static ppermute perms)."""
    return [
        (lambda state, rnd=rnd: push_sum_round(state, rnd, self_share=self_share))
        for rnd in sched
    ]


def gossip_mix(
    params: Pytree,
    step: jax.Array,
    *,
    axis_sizes: dict[str, int],
    rounds: int,
    self_share: float = 0.5,
) -> Pytree:
    """R Push-Sum rounds on the parameter pytree (inside shard_map).

    The hop schedule is rotated by the traced ``step`` so consecutive steps
    continue the exponential hop sequence — without this, repeating hop=1
    every step never contracts the slow modes of the consensus error.
    """
    sched = exponential_schedule(axis_sizes)
    if not sched:
        return params
    L = len(sched)
    branches = _one_round_branches(sched, self_share)
    state = PushSumState(values=params, weight=jnp.float32(1.0))
    base = (step.astype(jnp.int32) * rounds) % L
    for k in range(rounds):
        idx = (base + k) % L
        state = jax.lax.switch(idx, branches, state)
    return state.estimate()


def gossip_mix_stacked(
    params: Pytree,
    step: jax.Array,
    *,
    n_nodes: int,
    rounds: int = 1,
    self_share: float = 0.5,
    payload_dtype: Any = None,
) -> Pytree:
    """Global-view gossip: every leaf carries a leading replica axis of size
    ``n_nodes`` (sharded over the gossip mesh axis); one Push-Sum round is
    ``x <- s*x + (1-s)*roll(x, hop, axis=0)`` which XLA lowers to a
    collective-permute across that axis. Hop schedule rotates with the traced
    step via lax.switch (hops 1, 2, ..., n/2).

    With the deterministic doubly-stochastic schedule the Push-Sum mass
    weight is identically 1, so no weight tracking is needed here (property-
    tested in tests/test_consensus.py against PushSumSim).

    ``payload_dtype`` (beyond-paper): quantize the SENT share only (e.g.
    jnp.bfloat16) — halves gossip wire bytes; the kept self-share stays full
    precision, so the quantization noise per round is bounded by
    (1-self_share) * one payload-dtype ulp of the neighbor value.
    """
    if n_nodes == 1:
        return params
    if n_nodes & (n_nodes - 1):
        raise ValueError("n_nodes must be a power of two")
    hops = [1 << k for k in range((n_nodes - 1).bit_length())]

    def mk(hop):
        def f(p):
            def mix(x):
                sent = x.astype(payload_dtype) if payload_dtype is not None else x
                recv = jnp.roll(sent, hop, axis=0).astype(jnp.float32)
                return (self_share * x.astype(jnp.float32)
                        + (1.0 - self_share) * recv).astype(x.dtype)
            return jax.tree.map(mix, p)
        return f

    branches = [mk(h) for h in hops]
    L = len(hops)
    base = (step.astype(jnp.int32) * rounds) % L
    for k in range(rounds):
        params = jax.lax.switch((base + k) % L, branches, params)
    return params


def mix_params(
    cfg: ConsensusConfig,
    params: Pytree,
    step: jax.Array,
    *,
    axis_sizes: dict[str, int],
) -> Pytree:
    """Post-update parameter mixing per the configured strategy."""
    if cfg.kind != "gossip":
        return params
    mixed = gossip_mix(params, step, axis_sizes=axis_sizes,
                       rounds=cfg.gossip_rounds, self_share=cfg.self_share)
    if cfg.mix_every == 1:
        return mixed
    skip = (step % cfg.mix_every) != 0
    return jax.tree.map(lambda m, p: jnp.where(skip, p, m), mixed, params)
