"""Multi-class GADGET SVM (paper §5 future work: "extension to multi-class
variants of SVMs").

One-vs-rest over the binary GADGET solver: class c gets its own weight
vector trained on (x, +1 if y==c else -1); prediction is argmax_c <w_c, x>.
All classes train in ONE run — the per-node weight matrix W (m, C, d) rides
through the same local Pegasos half-step and Push-Sum rounds (Push-Vector
over the stacked class dimension), so gossip cost is shared across classes.

Prediction dispatches the serving-side fused scores+argmax kernel
(``hinge_subgrad.ops.dense_predict`` — one launch for margins AND argmax),
the same path ``repro.serve.SvmServer`` scores multiclass checkpoints with;
the pure-jnp argmax stays available as ``predict_multiclass(use_kernels=False)``
and remains the oracle the kernel is tested against.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gadget import GadgetConfig
from repro.core.push_sum import PushSumSim
from repro.kernels.hinge_subgrad import ops as hinge_ops

__all__ = ["MulticlassResult", "gadget_train_multiclass", "predict_multiclass"]


class MulticlassResult(NamedTuple):
    W: jax.Array            # (m, C, d) per-node per-class weights
    w_consensus: jax.Array  # (C, d)
    iters: int


def _half_step_all_classes(W, Xi, yi, ids, lam, t, project):
    """W: (C, d); one shared minibatch drives every class's binary problem."""
    Xb = Xi[ids]                       # (B, d)
    yb = yi[ids]                       # (B,) integer labels
    C = W.shape[0]
    y_bin = jnp.where(yb[None, :] == jnp.arange(C)[:, None], 1.0, -1.0)  # (C, B)
    margins = y_bin * (Xb @ W.T).T     # (C, B)
    viol = (margins < 1.0).astype(Xb.dtype)
    L = jnp.einsum("cb,bd->cd", viol * y_bin, Xb) / Xb.shape[0]
    alpha = 1.0 / (lam * t)
    W_half = (1.0 - lam * alpha) * W + alpha * L
    if project:
        norms = jnp.linalg.norm(W_half, axis=1, keepdims=True)
        scale = jnp.minimum(1.0, (1.0 / jnp.sqrt(lam)) / jnp.maximum(norms, 1e-30))
        W_half = W_half * scale
    return W_half


def gadget_train_multiclass(X_parts: jax.Array, y_parts: jax.Array, n_classes: int,
                            cfg: GadgetConfig = GadgetConfig()) -> MulticlassResult:
    """X_parts: (m, n_i, d); y_parts: (m, n_i) int labels in [0, C)."""
    m, n_i, d = X_parts.shape
    C = n_classes
    sim = PushSumSim(m, cfg.topology, seed=cfg.seed)
    key = jax.random.PRNGKey(cfg.seed)

    @jax.jit
    def chunk(W, t0, B_stack, key0):
        def step(carry, inp):
            W, t = carry
            Bs, k = inp
            tf = t.astype(jnp.float32)
            keys = jax.random.split(k, m)
            ids = jax.vmap(lambda kk: jax.random.randint(kk, (cfg.batch_size,), 0, n_i))(keys)
            W_half = jax.vmap(
                lambda w, Xi, yi, ii: _half_step_all_classes(
                    w, Xi, yi, ii, cfg.lam, tf, cfg.project_before_gossip)
            )(W, X_parts, y_parts, ids)
            flat = W_half.reshape(m, C * d)
            for r in range(cfg.gossip_rounds):
                flat = Bs[r].T @ flat
            W_new = flat.reshape(m, C, d)
            return (W_new, t + 1), None

        keys = jax.random.split(key0, B_stack.shape[0])
        (W, t0), _ = jax.lax.scan(step, (W, t0), (B_stack, keys))
        return W, t0

    W = jnp.zeros((m, C, d), X_parts.dtype)
    t = jnp.int32(1)
    it = 0
    while it < cfg.max_iters:
        n = min(cfg.check_every, cfg.max_iters - it)
        B_stack = np.stack([
            np.stack([sim.matrix(it + s * cfg.gossip_rounds + r)
                      for r in range(cfg.gossip_rounds)])
            for s in range(n)]).astype(np.float32)
        key, sub = jax.random.split(key)
        W_prev = W
        W, t = chunk(W, t, jnp.asarray(B_stack), sub)
        it += n
        eps = float(jnp.max(jnp.linalg.norm((W - W_prev).reshape(m, -1), axis=1)))
        if eps < cfg.epsilon:
            break
    return MulticlassResult(W=W, w_consensus=jnp.mean(W, axis=0), iters=it)


def predict_multiclass(w_consensus: jax.Array, X: jax.Array, *,
                       use_kernels: bool | None = None) -> jax.Array:
    """argmax_c <w_c, x> per row. ``use_kernels=None`` follows the package
    convention (fused kernel wherever it compiles natively, interpret-mode
    kernel when forced via True, jnp oracle via False)."""
    if use_kernels is None:
        use_kernels = not hinge_ops.default_interpret()
    if use_kernels:
        _, labels = hinge_ops.dense_predict(w_consensus, X)
        return labels
    return jnp.argmax(X @ w_consensus.T, axis=-1).astype(jnp.int32)
