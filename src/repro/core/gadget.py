"""GADGET SVM — Gossip-bAseD sub-GradiEnT solver (paper Algorithm 2).

Every node i holds a horizontal partition M_i (n_i × d) and a weight vector
ŵ_i. One iteration t:

  (a-c)  sample a local mini-batch, L̂_i = mean_{violators} y·x under ŵ_i
  (d)    α_t = 1 / (λ t)
  (e)    w̃_i = (1 − λ α_t) ŵ_i + α_t L̂_i          (local Pegasos half-step)
  (f)    [optional] project w̃_i onto the 1/√λ ball
  (g)    ŵ_i ← PushSum(B, w̃_i)                     (gossip consensus)
  (h)    [optional] project again
The algorithm is *anytime*: it stops when max_i ‖ŵ_i^(t+1) − ŵ_i^(t)‖ < ε.

The simulator path is **device-resident and fused** (cfg.fused, the default):
steps (a)-(e) for all m nodes run as ONE Pallas ``fleet_half_step`` launch per
iteration (node axis = parallel grid dimension, each X tile read from HBM
once), and the R Push-Sum rounds of step (g) — a linear map — are collapsed
into a single precomputed product ``P_t = (B_1 ⋯ B_R)^T`` applied as one
mix-and-renormalize matmul. ``cfg.fused=False`` keeps the PR 1 path (two
vmapped kernels per node + an R-round ``lax.scan``) for A/B benchmarking.
Either way the whole training loop — half-steps, mixing, the ε-check and the
objective trace — is one jitted ``lax.while_loop`` with donated weight
buffers. Mixing matrices never cross the host boundary inside the loop:
deterministic topologies (exponential, ring, clique/complete, torus) are
uploaded once as a stacked (period, m, m) array — the per-iteration *product*
cycle when fused, R× smaller — and the paper's random one-neighbor protocol
is drawn with ``jax.random`` inside the step (R draws folded into one (m, m)
product on device when fused). The host wrapper (`gadget_train`) syncs
exactly once, after termination, to materialize traces.

``gadget_train_reference`` keeps the seed's host-chunk loop (per-iteration
host matrix builds, per-chunk ``float(...)`` syncs) on the *same* PRNG
streams — it is the parity oracle for tests and the baseline the transfer
counter in ``benchmarks/gossip_device_bench.py`` measures against.

Sparse partitions: ``gadget_train`` / ``gadget_train_reference`` also accept
``repro.sparse.EllPartitions`` — stacked (m, n_i, k) padded-ELL column/value
planes — in place of the dense (m, n_i, d) array. The local half-step then
runs over the ELL planes (``ell_fleet_half_step`` kernels, or the jnp gather/
scatter oracle off-kernel) touching O(B·k) feature bytes per iteration instead
of O(B·d), and the objective trace does its full-data pass as a gather-dot.
``cfg.sparse_schedule`` picks how those kernels walk w: the data-oblivious
sweep over all d-blocks, or the scalar-prefetch touched-block schedule whose
per-node cost scales with the blocks its own minibatch actually hits (the
static grid cap is derived on host from the partition planes before tracing).
Gossip/Push-Sum are over the *dense* resident weights and are untouched —
mixing is linear in w, so the PR 2 collapsed-product path applies verbatim.
The sparse half-step is inherently fleet-wide (one launch for all m nodes);
``cfg.fused`` therefore only selects collapsed vs sequential mixing in sparse
mode. At CCAT sparsity (0.16%) this is the difference between a ~147 GB dense
train split and ~0.5 GB of planes — the full-shape paper scenario fits.

Weighted consensus: the paper pushes n_i·ŵ_i so the consensus target is the
data-weighted network average Σ n_i ŵ_i / N. We implement this by initializing
the Push-Sum mass weight to n_i — the v/w ratio then converges to exactly that
weighted mean for free, including under non-uniform partitions. Non-uniform
partitions are expressed by passing explicit per-node ``n_counts`` to
`gadget_train` / `gadget_train_reference`: node i's valid rows are the first
n_counts[i] of its (padded) partition, and sampling, mass weights, consensus
and the objective trace all respect them.
"""
from __future__ import annotations

import functools
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults as flt
from repro.core import svm_objective as obj
from repro.core import topology as topo
from repro.core.faults import FaultPlan
from repro.core.push_sum import (PushSumState, collapse_rounds, exponential_schedule,
                                 mix_collapsed, mix_rounds, push_sum_round)
from repro.kernels.hinge_subgrad import ops as hinge_ops
from repro.kernels.hinge_subgrad import ref as hinge_ref
from repro.telemetry import registry as tmr
from repro.telemetry import trace as tmtr
from repro.telemetry import train as tmt

__all__ = [
    "GadgetConfig",
    "GadgetResult",
    "NonFiniteWeightsError",
    "SegmentResult",
    "SnapshotRing",
    "TrainState",
    "gadget_train",
    "gadget_train_stream",
    "gadget_train_reference",
    "make_gadget_mesh_step",
    "transfer_stats",
    "reset_transfer_stats",
]


class NonFiniteWeightsError(FloatingPointError):
    """Typed divergence failure: the consensus weight plane went non-finite.

    Raised by ``gadget_train`` / ``gadget_train_stream`` when the on-device
    guard (checked at the ε-check / segment-boundary cadence) finds NaN/Inf
    in the consensus weights — bad input features, a zero/negative λ, or
    fault-starved Push-Sum mass can all produce it — and by
    ``TrainPublisher`` when asked to publish such a plane. ``iteration`` is
    the last completed global iteration when the guard fired; ``context``
    says which stage refused (``"training"`` or ``"publish"``). Each raise
    increments the ``train.nonfinite`` counter on the default registry, so
    a supervisor can alert on divergence without parsing tracebacks.
    """

    def __init__(self, iteration: int, context: str = "training"):
        super().__init__(
            f"non-finite consensus weight plane at iteration {iteration} "
            f"({context}) — training diverged; refusing to treat NaN/Inf "
            f"weights as a servable model")
        self.iteration = int(iteration)
        self.context = context


class GadgetConfig(NamedTuple):
    """Hyperparameters + execution knobs for one GADGET training run.

    The paper's parameters (λ, minibatch size, Push-Sum rounds R, topology,
    the two projection steps, the anytime ε) ride alongside execution
    switches (`use_kernels`, `fused`, `sparse_schedule`) that change *how*
    the same trajectory is computed, never *what* it computes — every path
    is bit- or 1e-5-level parity-checked against the host-loop reference.
    A config is hashable (NamedTuple) and is part of the jit cache key, so
    reusing one across `gadget_train` / `gadget_train_stream` calls reuses
    compiled executables."""

    lam: float = 1e-4            # λ — SVM regularization / learning parameter
    batch_size: int = 1          # local examples per sub-gradient estimate
    gossip_rounds: int = 4       # Push-Sum rounds per iteration (R)
    topology: str = "exponential"
    project_before_gossip: bool = True   # paper step (f)
    project_after_gossip: bool = True    # paper step (h)
    epsilon: float = 1e-3        # anytime stopping tolerance (paper: 0.001)
    check_every: int = 100       # ε-check / trace cadence (on device)
    max_iters: int = 5000
    seed: int = 0
    # None → Pallas half-step kernels wherever they compile natively (TPU),
    # pure-jnp where they would only interpret (CPU). True forces the kernel
    # path (interpret-mode off-TPU — what CI's device-path tests exercise).
    use_kernels: bool | None = None
    # Fused per-iteration path (default): one fleet_half_step launch for all m
    # nodes + one collapsed mix-and-renormalize matmul. False keeps the PR 1
    # path (2 vmapped kernels per node + R scanned matmuls) for A/B benches.
    fused: bool = True
    # How the sparse half-step kernels walk w's d-blocks: "sweep" visits every
    # block (PR 3 one-hot grid), "prefetch" visits only the blocks the
    # minibatch touches (scalar-prefetch schedule), "auto" picks prefetch
    # exactly when the data-derived block bound makes it cheaper in w-lanes.
    # Ignored on the dense path and on the jnp (use_kernels=False) path.
    sparse_schedule: str = "auto"
    # Fault injection (repro.core.faults.FaultPlan): per-round link/message
    # drops + dead nodes, generated on device inside the jitted step. None
    # (default) is the perfect-network path — bit-identical to pre-fault
    # builds. With faults, deterministic topologies upload the per-round
    # matrix cycle instead of the precomputed product cycle and fold the
    # faulty rounds on device per iteration (the fused path keeps its
    # one-matmul mix). Note the plan — including its fault seed — is baked
    # into the compiled step (unlike cfg.seed).
    faults: FaultPlan | None = None


class SnapshotRing(NamedTuple):
    """Anytime-export ring: the last ``slots`` consensus snapshots taken every
    ``every`` iterations *inside* the jitted training loop, plus the final
    iterate. Raw device-layout buffers — ``repro.serve.snapshot`` decodes them
    into ordered :class:`~repro.serve.snapshot.Snapshot` records; snapshot j
    (1-based, at iteration j·every) lives in slot ``(j - 1) % slots`` and
    ``count`` is the total number taken (> slots ⇒ the ring wrapped and only
    the latest ``slots`` survive)."""

    every: int
    W: np.ndarray             # (slots, d) consensus weights per snapshot
    iterations: np.ndarray    # (slots,) int32 iteration index (0 = never used)
    objectives: np.ndarray    # (slots,) primal objective of each snapshot
    count: int                # snapshots taken in total (may exceed slots)
    final_w: np.ndarray       # (d,) consensus at termination
    final_iteration: int
    final_objective: float

    @property
    def slots(self) -> int:
        return self.W.shape[0]


class GadgetResult(NamedTuple):
    W: jax.Array            # (m, d) final per-node weights
    w_consensus: jax.Array  # (d,) data-weighted network average
    iters: int
    epsilon: float          # max_i ‖Δŵ_i‖ at termination
    objective_trace: np.ndarray  # (n_checks,) primal objective of consensus w
    time_trace: np.ndarray       # iteration index per check
    eps_trace: np.ndarray        # (n_checks,) max_i ‖Δŵ_i‖ per check
    W_avg: jax.Array | None = None  # (m, d) per-node iterate averages w̄_i
    # (Pegasos' Theorem-2-style guarantee bounds the averaged iterate, not the
    # last one — same reason pegasos_train exposes w_avg)
    snapshots: SnapshotRing | None = None  # anytime export (snapshot_every=K)
    # (n_checks,) minimum per-iteration Push-Sum mass retention over each
    # ε-check chunk: sum of post-mix mass weights / sum of initial mass
    # (Σ n_i). Exactly 1.0 (to float-sum tolerance) on the perfect network and
    # under FaultPlan(drop="link"); < 1 measures the leakage of drop="message".
    mass_trace: np.ndarray | None = None
    # Decoded on-device training trace ring (telemetry=TrainTelemetry(...)):
    # per-record consensus disagreement, windowed Push-Sum mass extrema,
    # objective, fault-drop counts. None when telemetry is off — and the
    # telemetry=None trajectory is bit-identical to pre-telemetry builds.
    telemetry: tmt.TrainTrace | None = None


class SegmentResult(NamedTuple):
    """One :func:`gadget_train_stream` segment — everything a live publisher
    needs to export a servable model mid-training. ``W`` stays on device
    (per-node (m, d) weights, useful for parity checks / resuming);
    ``w_consensus`` is the host-side (d,) f32 data-weighted average —
    exactly what :class:`~repro.serve.snapshot.Snapshot` wraps."""

    iteration: int          # global iteration index reached (1-based count)
    W: jax.Array            # (m, d) per-node weights after the segment
    w_consensus: np.ndarray  # (d,) f32 consensus at the segment boundary
    objective: float        # primal objective of w_consensus
    epsilon: float          # max_i ‖Δŵ_i‖ across the segment
    done: bool              # ε-converged or cfg.max_iters reached
    # (m, d) running iterate sum — with ``iteration`` and ``W`` this is the
    # full resumable TrainState at the boundary (crash-resume support)
    W_sum: jax.Array | None = None
    # min per-iteration Push-Sum mass retention across the segment (1.0 on a
    # perfect network / link-mode faults; < 1 measures message-mode leakage)
    mass: float = float("nan")
    # Per-segment telemetry (gadget_train_stream(..., telemetry=...)):
    # boundary disagreement/objective + active-iteration mass extrema and
    # fault-drop counts. None when telemetry is off.
    telemetry: tmt.SegmentTelemetry | None = None
    # Root trace context of this segment's version-lineage trace
    # (gadget_train_stream(..., trace=True)): the publisher derives its
    # publish span from it and embeds it in the checkpoint manifest, so the
    # swap/first-serve spans downstream join the same causal chain. None
    # when tracing is off.
    trace: tmtr.TraceContext | None = None


class TrainState(NamedTuple):
    """Resumable trainer state at a segment boundary: ``iteration`` completed
    global iterations plus the (m, d) per-node weights and running iterate
    sum. Feed to ``gadget_train_stream(..., resume=...)`` to continue a run —
    because every PRNG draw keys on the *global* iteration counter, the
    resumed trajectory is bit-identical to the uninterrupted one.
    ``repro.serve.snapshot.to_checkpoint(..., train_state=...)`` persists it
    alongside the servable weights and ``train_state_from_checkpoint``
    restores it."""

    iteration: int
    W: jax.Array            # (m, d) per-node weights
    W_sum: jax.Array        # (m, d) running sum of iterates


# Host↔device traffic instrumentation, read by benchmarks/gossip_device_bench.py:
# `matrix_uploads` counts host→device transfers of mixing matrices, `host_syncs`
# counts device→host scalar pulls made for the anytime ε-check / traces.
transfer_stats = {"matrix_uploads": 0, "host_syncs": 0}


def reset_transfer_stats() -> None:
    transfer_stats["matrix_uploads"] = 0
    transfer_stats["host_syncs"] = 0


def _partition_counts(y_parts: jax.Array, n_counts=None) -> jax.Array:
    """Per-node valid-row counts as f32: uniform n_i unless the caller passes
    explicit ``n_counts`` (non-uniform partitions, padded to a common n_i)."""
    m, n_i = y_parts.shape
    if n_counts is None:
        return jnp.full((m,), float(n_i), jnp.float32)
    counts = np.asarray(n_counts, np.float32)
    if counts.shape != (m,):
        raise ValueError(f"n_counts must have shape ({m},), got {counts.shape}")
    if np.any(counts < 1) or np.any(counts > n_i):
        raise ValueError(f"n_counts must lie in [1, {n_i}]")
    return jnp.asarray(counts)


def _valid_row_mask(m: int, n_i: int, n_counts: jax.Array) -> jax.Array:
    """Flat (m*n_i,) mask of real rows — the padded-partition counterpart of
    ops.padded_row_mask, shared by the device loop and the reference oracle
    so their objective traces mask identically."""
    return (jnp.arange(n_i)[None, :]
            < n_counts.astype(jnp.int32)[:, None]).reshape(m * n_i)


def _unpack_partitions(X_parts):
    """Normalize the data argument: returns ``(X, m, n_i, d, dtype)`` where X
    is the dense (m, n_i, d) device array, or the ``(cols, vals)`` tuple of
    stacked padded-ELL planes when the caller passed
    ``repro.sparse.EllPartitions`` (duck-typed on ``.cols``/``.vals``/``.d``)."""
    if hasattr(X_parts, "cols") and hasattr(X_parts, "vals"):
        cols = jnp.asarray(X_parts.cols, jnp.int32)
        vals = jnp.asarray(X_parts.vals, jnp.float32)
        m, n_i, _ = cols.shape
        return (cols, vals), m, n_i, int(X_parts.d), vals.dtype
    X = jnp.asarray(X_parts)
    m, n_i, d = X.shape
    return X, m, n_i, d, X.dtype


def _sparse_block_bound(cfg: GadgetConfig, X_parts, X) -> int | None:
    """Static n_blocks_max cap for the prefetch kernel schedule, derived on
    host from the partition planes before tracing (the traced loop needs a
    concrete grid bound). None for dense data / the jnp path / the sweep
    schedule, where no bound is consumed."""
    if not isinstance(X, tuple) or not cfg.use_kernels or cfg.sparse_schedule == "sweep":
        return None
    if hasattr(X_parts, "block_bound"):  # EllPartitions caches row counts
        return X_parts.block_bound(cfg.batch_size)
    from repro.sparse.formats import minibatch_block_bound
    cols, vals = np.asarray(X_parts.cols), np.asarray(X_parts.vals)
    return minibatch_block_bound(
        cols.reshape(cols.shape[0], -1, cols.shape[-1]), vals,
        cfg.batch_size, d=int(X_parts.d))


def _resolve_kernels(cfg: GadgetConfig) -> GadgetConfig:
    """Pin cfg.use_kernels to a concrete bool (it keys the jit cache)."""
    if cfg.use_kernels is None:
        return cfg._replace(use_kernels=not hinge_ops.default_interpret())
    return cfg


def _local_half_step(w, X_i, y_i, ids, lam, t, project, use_kernels):
    Xb, yb = X_i[ids], y_i[ids]
    if use_kernels:
        return hinge_ops.local_half_step(w, Xb, yb, lam=lam, t=t, project=project)
    alpha = 1.0 / (lam * t)
    L_hat = -obj.hinge_subgradient(w, Xb, yb)
    w_half = (1.0 - lam * alpha) * w + alpha * L_hat
    return obj.project_ball(w_half, lam) if project else w_half


# ---------------------------------------------------------------------------
# Shared PRNG / mixing-matrix derivations — the device loop and the host-loop
# reference use these verbatim so their trajectories are comparable.
# ---------------------------------------------------------------------------


def _stream_keys(seed: int):
    data_key, mix_key = jax.random.split(jax.random.PRNGKey(seed))
    return data_key, mix_key


def _batch_ids(data_key: jax.Array, t: jax.Array, n_counts: jax.Array, batch_size: int):
    """Per-node minibatch row ids, sampled from each node's first n_counts[i]
    (valid) rows — identical to the old uniform draw when counts are uniform."""
    keys = jax.random.split(jax.random.fold_in(data_key, t), n_counts.shape[0])
    bounds = n_counts.astype(jnp.int32)
    return jax.vmap(
        lambda k, c: jax.random.randint(k, (batch_size,), 0, c)
    )(keys, bounds)


def _iter_mixing(mix_key: jax.Array, B_stack: jax.Array | None, t: jax.Array,
                 m: int, R: int, topology: str, fused: bool,
                 faults: FaultPlan | None = None,
                 count_drops: bool = False, drops_node: bool = False):
    """Mixing for iteration t (1-based), fully on device: the (R, m, m)
    per-round stack, or — when ``fused`` — the single collapsed (m, m) product
    ``P_t = (B_1 ⋯ B_R)^T``. Fault-free deterministic topologies index the
    precomputed product cycle (``B_stack`` then IS
    topology.build_product_stack); the random protocol draws the same R
    matrices either way (same PRNG stream as the sequential path) and folds
    them on device. With ``faults`` the per-round matrices (``B_stack`` is
    then the *matrix* cycle) pass through :func:`repro.core.faults.
    faulty_rounds` before the fold — fault injection composes with the fused
    one-matmul mix by collapsing the faulty rounds on device per iteration,
    exactly the pattern the random topology already uses.

    ``count_drops`` (telemetry) additionally returns the iteration's faulted
    message count (:func:`repro.core.faults.count_drops` on the clean rounds
    — int32 0 when fault-free) as a second output; ``drops_node`` switches
    that output to the (m,) per-sender vector
    (:func:`repro.core.faults.count_drops_node`, rows summing to the
    scalar). The default single-output form is byte-identical to
    pre-telemetry builds."""
    def zero_drops():
        return (jnp.zeros((m,), jnp.int32) if drops_node else jnp.int32(0))

    if topology == "random":
        kt = jax.random.fold_in(mix_key, t)
        Bs = jax.vmap(
            lambda r: topo.random_neighbor_matrix_device(jax.random.fold_in(kt, r), m)
        )(jnp.arange(R))
    else:
        T = B_stack.shape[0]
        if fused and faults is None:
            P = B_stack[(t - 1) % T]
            return (P, zero_drops()) if count_drops else P
        idx = ((t - 1) * R + jnp.arange(R)) % T
        Bs = B_stack[idx]
    drops = None
    if faults is not None:
        if count_drops:
            drops = (flt.count_drops_node(Bs, faults, t) if drops_node
                     else flt.count_drops(Bs, faults, t))
        Bs = flt.faulty_rounds(Bs, faults, t)
    mix = collapse_rounds(Bs) if fused else Bs
    if count_drops:
        return mix, (zero_drops() if drops is None else drops)
    return mix


# ---------------------------------------------------------------------------
# Device-resident training loop (tentpole)
# ---------------------------------------------------------------------------


def _gossip_step(cfg: GadgetConfig, m: int,
                 X: jax.Array, y: jax.Array, n_counts: jax.Array,
                 data_key: jax.Array, W: jax.Array, W_sum: jax.Array,
                 t: jax.Array, Bs: jax.Array, sparse_block_bound: int | None = None,
                 node_mass: bool = False):
    """Steps (a)-(h) for all m nodes at iteration t. ``Bs`` is the (R, m, m)
    per-round stack (sequential path) or the collapsed (m, m) product P_t
    (``cfg.fused``). ``X`` is the dense (m, n_i, d) array or the (cols, vals)
    tuple of stacked ELL planes; ``sparse_block_bound`` is the static
    n_blocks_max cap for the prefetch kernel schedule (host-derived from the
    partition planes — formats.minibatch_block_bound). The single shared step
    body — the device loop and the host-loop reference differ only in
    orchestration (where Bs comes from, where the ε-check runs).

    Returns ``(W_new, W_sum + W_new, mass)`` where ``mass`` is this
    iteration's Push-Sum mass retention Σ post-mix weights / Σ n_i — exactly
    1.0 (to float-sum tolerance) on a perfect network or under link-mode
    faults, < 1 under message-mode leakage. With ``cfg.faults`` dead nodes
    are frozen bit-exactly: their half-step is suppressed (W_half ← W) and
    their mixing row is e_d, so W_new equals W on dead rows (project_ball is
    exact identity on an already-projected weight).

    ``node_mass`` (per-node telemetry) appends the (m,) per-node Push-Sum
    mass ratio ``wts_i / n_i`` — the node-level decomposition of ``mass``
    (its n-weighted mean is the scalar) — as a fourth output; the default
    three-output form traces the identical program."""
    tf = t.astype(jnp.float32)
    ids = _batch_ids(data_key, t, n_counts, cfg.batch_size)

    def gather(a):
        return jax.vmap(lambda ai, ii: ai[ii])(a, ids)

    if isinstance(X, tuple):
        # sparse: per-node ELL minibatch planes; the half-step is fleet-wide
        # either way (the sparse kernels take the whole node axis), so fused
        # vs unfused only selects the mixing path below.
        Cb, Vb, yb = gather(X[0]), gather(X[1]), gather(y)
        if cfg.use_kernels:
            W_half = hinge_ops.ell_fleet_half_step(W, Cb, Vb, yb, lam=cfg.lam,
                                                   t=tf,
                                                   project=cfg.project_before_gossip,
                                                   schedule=cfg.sparse_schedule,
                                                   n_blocks_max=sparse_block_bound)
        else:
            W_half = hinge_ref.ell_fleet_half_step_ref(W, Cb, Vb, yb, cfg.lam, tf,
                                                       project=cfg.project_before_gossip)
    elif cfg.fused:
        # one gather, then steps (a)-(e) for the whole fleet in one launch
        Xb, yb = gather(X), gather(y)
        if cfg.use_kernels:
            W_half = hinge_ops.fleet_half_step(W, Xb, yb, lam=cfg.lam, t=tf,
                                               project=cfg.project_before_gossip)
        else:
            W_half = hinge_ref.fleet_half_step_ref(W, Xb, yb, cfg.lam, tf,
                                                   project=cfg.project_before_gossip)
    else:
        W_half = jax.vmap(
            lambda w, Xi, yi, ii: _local_half_step(w, Xi, yi, ii, cfg.lam, tf,
                                                   cfg.project_before_gossip, cfg.use_kernels)
        )(W, X, y, ids)
    # Push-Sum: values n_i·w̃_i with mass weights n_i ⇒ weighted mean; R
    # rounds collapsed into one fused mix-and-renormalize matmul when fused.
    mix = mix_collapsed if cfg.fused else mix_rounds
    vals, wts = mix(W_half * n_counts[:, None], n_counts, Bs)
    mass = jnp.sum(wts) / jnp.sum(n_counts)
    W_new = vals / wts[:, None]
    if cfg.project_after_gossip:
        W_new = jax.vmap(lambda w: obj.project_ball(w, cfg.lam))(W_new)
    if cfg.faults is not None and cfg.faults.dead_nodes:
        # crashed nodes neither train nor receive: their mixing row is e_d
        # (nothing reaches the others), and the bit-exact freeze of their own
        # row happens here, after the mix's renormalizing divide
        W_new = jnp.where(flt.dead_mask(cfg.faults, m)[:, None], W, W_new)
    if node_mass:
        return W_new, W_sum + W_new, mass, wts / n_counts
    return W_new, W_sum + W_new, mass


def _one_iteration(cfg: GadgetConfig, m: int,
                   X: jax.Array, y: jax.Array, n_counts: jax.Array,
                   data_key: jax.Array, mix_key: jax.Array, B_stack: jax.Array | None,
                   W: jax.Array, W_sum: jax.Array, t: jax.Array,
                   sparse_block_bound: int | None = None,
                   count_drops: bool = False, node_stats: bool = False):
    """One fully device-resident iteration: derive this iteration's mixing
    (stack slice, product-cycle slice, or in-step draw — faults applied on
    device when cfg.faults), then the shared step. Returns
    ``(W, W_sum, mass)`` — or ``(W, W_sum, mass, drops)`` with the
    iteration's faulted-message count when ``count_drops`` (telemetry).

    ``node_stats`` (per-node telemetry; supersedes ``count_drops``) returns
    ``(W, W_sum, mass, ndrops, nmass)`` where ``ndrops`` is the (m,) int32
    per-sender faulted-message count (zeros when fault-free) and ``nmass``
    the (m,) per-node Push-Sum mass ratio."""
    if node_stats:
        if cfg.faults is not None:
            Bs, ndrops = _iter_mixing(mix_key, B_stack, t, m, cfg.gossip_rounds,
                                      cfg.topology, cfg.fused, cfg.faults,
                                      count_drops=True, drops_node=True)
        else:
            Bs = _iter_mixing(mix_key, B_stack, t, m, cfg.gossip_rounds,
                              cfg.topology, cfg.fused, None)
            ndrops = jnp.zeros((m,), jnp.int32)
        W, W_sum, mass, nmass = _gossip_step(cfg, m, X, y, n_counts, data_key,
                                             W, W_sum, t, Bs,
                                             sparse_block_bound,
                                             node_mass=True)
        return W, W_sum, mass, ndrops, nmass
    if count_drops:
        Bs, drops = _iter_mixing(mix_key, B_stack, t, m, cfg.gossip_rounds,
                                 cfg.topology, cfg.fused, cfg.faults,
                                 count_drops=True)
        W, W_sum, mass = _gossip_step(cfg, m, X, y, n_counts, data_key, W,
                                      W_sum, t, Bs, sparse_block_bound)
        return W, W_sum, mass, drops
    Bs = _iter_mixing(mix_key, B_stack, t, m, cfg.gossip_rounds, cfg.topology,
                      cfg.fused, cfg.faults)
    return _gossip_step(cfg, m, X, y, n_counts, data_key, W, W_sum, t, Bs,
                        sparse_block_bound)


def _trace_closures(cfg: GadgetConfig, X, y: jax.Array, n_counts: jax.Array,
                    m: int, n_i: int, d: int):
    """The two traced reductions every loop variant shares: ``objective_of(w)``
    (masked full-data primal, dense or ELL gather-dot) and ``consensus_of(W)``
    (data-weighted network average). Built identically by the while-loop
    trainer, the segment trainer and the host reference so their traces agree
    bit-for-bit."""
    y_flat = y.reshape(m * n_i)
    total_n = jnp.sum(n_counts)
    valid_flat = _valid_row_mask(m, n_i, n_counts)
    if isinstance(X, tuple):  # ELL planes: full-data pass as a gather-dot
        cols_flat = X[0].reshape(m * n_i, -1)
        vals_flat = X[1].reshape(m * n_i, -1)

        def objective_of(w):
            return obj.primal_objective_masked_ell(
                w, cols_flat, vals_flat, y_flat, cfg.lam, valid_flat, total_n)
    else:
        X_flat = X.reshape(m * n_i, d)

        def objective_of(w):
            return obj.primal_objective_masked(
                w, X_flat, y_flat, cfg.lam, valid_flat, total_n)

    def consensus_of(W):
        return jnp.sum(W * n_counts[:, None], axis=0) / total_n

    return objective_of, consensus_of


def _cache_cfg(cfg: GadgetConfig) -> GadgetConfig:
    """Key for the jit-factory caches: the traced program never reads
    cfg.seed (PRNG keys are runtime arguments), so multi-seed sweeps must
    share one compiled executable."""
    return cfg._replace(seed=0)


@functools.lru_cache(maxsize=32)
def _make_device_train(cfg: GadgetConfig, m: int, n_i: int, d: int,
                       n_chunks: int, chunk: int,
                       sparse_block_bound: int | None = None,
                       snap_every: int = 0, snap_slots: int = 0,
                       tele_every: int = 0, tele_slots: int = 0,
                       tele_nodes: bool = False):
    """Jitted whole-training function: while_loop over ε-check chunks, scan
    over iterations inside each chunk, donated weight buffers, on-device
    objective/ε traces. Returns arrays only — the caller syncs once.

    ``snap_every`` > 0 additionally threads the anytime-export ring through
    the loop: every K-th iteration writes (consensus w, iteration, objective)
    into slot ``count % snap_slots`` under a ``lax.cond`` — non-snapshot
    iterations pay nothing, and the whole ring stays on device until the
    single post-termination sync.

    ``tele_every`` > 0 threads the telemetry trace ring the same way: every
    K-th active iteration records (iteration, consensus disagreement,
    windowed mass min/max, objective, windowed fault-drop count) into slot
    ``count % tele_slots``; the window accumulators reset at each record.
    With ``tele_every == 0`` the telemetry carry is the empty tuple — no
    pytree leaves, so the traced program (and the trajectory) is
    bit-identical to the telemetry-free build.

    ``tele_nodes`` appends per-node ring leaves to the telemetry carry:
    ``(tele_slots, m)`` rings of per-node disagreement-to-consensus, per-node
    Push-Sum mass ratio at the record iteration, and windowed per-node
    fault-drop counts (plus the (m,) drop window accumulator). The scalar
    rings are unchanged — the scalar disagreement is the row-max of the
    per-node record, the scalar drop window the row-sum — and
    ``tele_nodes=False`` traces the exact per-node-free program."""
    # drop counting re-draws the fault stream per iteration — only pay for
    # it when there is both a telemetry ring and a fault plan to observe
    tele_drops = bool(tele_every) and cfg.faults is not None

    def train(X, y, B_stack, data_key, mix_key, n_counts, W0, W_sum0):
        # padded rows of non-uniform partitions are masked out of the trace
        objective_of, consensus_of = _trace_closures(cfg, X, y, n_counts,
                                                     m, n_i, d)

        def disagreement_of(W_now, w_cons):
            return jnp.max(jnp.linalg.norm(W_now - w_cons[None, :], axis=1))

        def step(carry, _):
            W, W_sum, t, snaps, tele = carry
            active = t <= cfg.max_iters
            # inactive tail iterations report full mass so the per-chunk min
            # below only reflects iterations that actually gossiped
            if tele_nodes:
                W, W_sum, mass, ndrops, nmass = jax.lax.cond(
                    active,
                    lambda a: _one_iteration(cfg, m, X, y, n_counts,
                                             data_key, mix_key, B_stack, *a,
                                             sparse_block_bound=sparse_block_bound,
                                             node_stats=True),
                    lambda a: (a[0], a[1], jnp.float32(1.0),
                               jnp.zeros((m,), jnp.int32),
                               jnp.ones((m,), jnp.float32)),
                    (W, W_sum, t),
                )
                drops = jnp.sum(ndrops)
            elif tele_drops:
                W, W_sum, mass, drops = jax.lax.cond(
                    active,
                    lambda a: _one_iteration(cfg, m, X, y, n_counts,
                                             data_key, mix_key, B_stack, *a,
                                             sparse_block_bound=sparse_block_bound,
                                             count_drops=True),
                    lambda a: (a[0], a[1], jnp.float32(1.0), jnp.int32(0)),
                    (W, W_sum, t),
                )
            else:
                W, W_sum, mass = jax.lax.cond(
                    active,
                    lambda a: _one_iteration(cfg, m, X, y, n_counts,
                                             data_key, mix_key, B_stack, *a,
                                             sparse_block_bound=sparse_block_bound),
                    lambda a: (a[0], a[1], jnp.float32(1.0)),
                    (W, W_sum, t),
                )
                drops = jnp.int32(0)
            if snap_every:
                def do_snap(op):
                    (sw, si, so, sc), W_now = op
                    w_cons = consensus_of(W_now)
                    slot = sc % snap_slots
                    return (sw.at[slot].set(w_cons), si.at[slot].set(t),
                            so.at[slot].set(objective_of(w_cons)), sc + 1)

                snaps = jax.lax.cond(active & (t % snap_every == 0),
                                     do_snap, lambda op: op[0], (snaps, W))
            if tele_every and tele_nodes:
                (ti, tdis, tmn, tmx, tob, tdr, tc, wmin, wmax, wdr,
                 ndisr, nmassr, ndropr, wndr) = tele
                # window accumulators only see iterations that gossiped
                wmin = jnp.where(active, jnp.minimum(wmin, mass), wmin)
                wmax = jnp.where(active, jnp.maximum(wmax, mass), wmax)
                wdr = wdr + jnp.where(active, drops, 0)
                wndr = wndr + jnp.where(active, ndrops, 0)

                def do_rec_nodes(op):
                    ((ti, tdis, tmn, tmx, tob, tdr, tc, ndisr, nmassr,
                      ndropr), (W_now, wmin, wmax, wdr, nmass_now, wndr)) = op
                    w_cons = consensus_of(W_now)
                    node_dis = jnp.linalg.norm(W_now - w_cons[None, :], axis=1)
                    slot = tc % tele_slots
                    ring = (ti.at[slot].set(t),
                            # scalar ring = row-max of the per-node record
                            tdis.at[slot].set(jnp.max(node_dis)),
                            tmn.at[slot].set(wmin), tmx.at[slot].set(wmax),
                            tob.at[slot].set(objective_of(w_cons)),
                            tdr.at[slot].set(wdr), tc + 1,
                            ndisr.at[slot].set(node_dis),
                            nmassr.at[slot].set(nmass_now),
                            ndropr.at[slot].set(wndr))
                    # record consumed the window: reset the accumulators
                    return ring, (jnp.float32(jnp.inf), jnp.float32(-jnp.inf),
                                  jnp.int32(0), jnp.zeros((m,), jnp.int32))

                ring, (wmin, wmax, wdr, wndr) = jax.lax.cond(
                    active & (t % tele_every == 0), do_rec_nodes,
                    lambda op: (op[0], (op[1][1], op[1][2], op[1][3],
                                        op[1][5])),
                    ((ti, tdis, tmn, tmx, tob, tdr, tc, ndisr, nmassr,
                      ndropr), (W, wmin, wmax, wdr, nmass, wndr)))
                (ti, tdis, tmn, tmx, tob, tdr, tc,
                 ndisr, nmassr, ndropr) = ring
                tele = (ti, tdis, tmn, tmx, tob, tdr, tc, wmin, wmax, wdr,
                        ndisr, nmassr, ndropr, wndr)
            elif tele_every:
                ti, tdis, tmn, tmx, tob, tdr, tc, wmin, wmax, wdr = tele
                # window accumulators only see iterations that gossiped
                wmin = jnp.where(active, jnp.minimum(wmin, mass), wmin)
                wmax = jnp.where(active, jnp.maximum(wmax, mass), wmax)
                wdr = wdr + jnp.where(active, drops, 0)

                def do_rec(op):
                    (ti, tdis, tmn, tmx, tob, tdr, tc), (W_now, wmin, wmax, wdr) = op
                    w_cons = consensus_of(W_now)
                    slot = tc % tele_slots
                    ring = (ti.at[slot].set(t),
                            tdis.at[slot].set(disagreement_of(W_now, w_cons)),
                            tmn.at[slot].set(wmin), tmx.at[slot].set(wmax),
                            tob.at[slot].set(objective_of(w_cons)),
                            tdr.at[slot].set(wdr), tc + 1)
                    # record consumed the window: reset the accumulators
                    return ring, (jnp.float32(jnp.inf), jnp.float32(-jnp.inf),
                                  jnp.int32(0))

                ring, (wmin, wmax, wdr) = jax.lax.cond(
                    active & (t % tele_every == 0), do_rec,
                    lambda op: (op[0], op[1][1:]),
                    ((ti, tdis, tmn, tmx, tob, tdr, tc), (W, wmin, wmax, wdr)))
                tele = ring + (wmin, wmax, wdr)
            return (W, W_sum, jnp.where(active, t + 1, t), snaps, tele), mass

        def chunk_body(carry):
            (W, W_sum, t, snaps, tele, ci, _, obj_tr, it_tr, eps_tr, mass_tr,
             bad) = carry
            W_prev = W
            (W, W_sum, t, snaps, tele), masses = jax.lax.scan(
                step, (W, W_sum, t, snaps, tele), None, length=chunk)
            eps = jnp.max(jnp.linalg.norm(W - W_prev, axis=1))
            w_cons = consensus_of(W)
            obj_tr = obj_tr.at[ci].set(objective_of(w_cons))
            it_tr = it_tr.at[ci].set(t - 1)
            eps_tr = eps_tr.at[ci].set(eps)
            mass_tr = mass_tr.at[ci].set(jnp.min(masses))
            # Non-finite guard at the ε-check cadence: one lax.cond-gated
            # isfinite reduction on the consensus already computed for the
            # trace (a sum is NaN/±Inf iff some element is non-finite under
            # the ball-projected magnitudes). Records the first bad
            # iteration; the while cond stops the run there, and the host
            # raises a typed NonFiniteWeightsError instead of returning —
            # or publishing — a NaN plane. Pure observation: a finite
            # trajectory is bit-identical with or without the guard firing.
            bad = jax.lax.cond(
                bad == 0,
                lambda: jnp.where(jnp.isfinite(jnp.sum(w_cons)),
                                  jnp.int32(0), t - 1),
                lambda: bad)
            return (W, W_sum, t, snaps, tele, ci + 1, eps, obj_tr, it_tr,
                    eps_tr, mass_tr, bad)

        def cond(carry):
            _, _, t, _, _, ci, eps, _, _, _, _, bad = carry
            return ((ci < n_chunks) & (eps >= cfg.epsilon)
                    & (t <= cfg.max_iters) & (bad == 0))

        snaps0 = (jnp.zeros((snap_slots, d), jnp.float32),
                  jnp.zeros((snap_slots,), jnp.int32),
                  jnp.full((snap_slots,), jnp.nan, jnp.float32),
                  jnp.int32(0))
        if tele_every:
            tele0 = (jnp.zeros((tele_slots,), jnp.int32),
                     jnp.full((tele_slots,), jnp.nan, jnp.float32),
                     jnp.full((tele_slots,), jnp.nan, jnp.float32),
                     jnp.full((tele_slots,), jnp.nan, jnp.float32),
                     jnp.full((tele_slots,), jnp.nan, jnp.float32),
                     jnp.zeros((tele_slots,), jnp.int32),
                     jnp.int32(0),
                     jnp.float32(jnp.inf), jnp.float32(-jnp.inf), jnp.int32(0))
            if tele_nodes:
                tele0 = tele0 + (
                    jnp.full((tele_slots, m), jnp.nan, jnp.float32),
                    jnp.full((tele_slots, m), jnp.nan, jnp.float32),
                    jnp.zeros((tele_slots, m), jnp.int32),
                    jnp.zeros((m,), jnp.int32))
        else:
            tele0 = ()
        init = (W0, W_sum0, jnp.int32(1), snaps0, tele0, jnp.int32(0),
                jnp.float32(jnp.inf),
                jnp.full((n_chunks,), jnp.nan, jnp.float32),
                jnp.zeros((n_chunks,), jnp.int32),
                jnp.full((n_chunks,), jnp.nan, jnp.float32),
                jnp.full((n_chunks,), jnp.nan, jnp.float32),
                jnp.int32(0))
        (W, W_sum, t, snaps, tele, ci, eps, obj_tr, it_tr, eps_tr, mass_tr,
         bad) = jax.lax.while_loop(cond, chunk_body, init)
        w_cons = consensus_of(W)
        final_obj = objective_of(w_cons) if snap_every else jnp.float32(jnp.nan)
        # ONE extra reduction at the already-synced boundary — the telemetry
        # ring adds no mid-loop host traffic
        tele_out = tele + (disagreement_of(W, w_cons),) if tele_every else ()
        return (W, W_sum, w_cons, t - 1, ci, eps, obj_tr, it_tr, eps_tr,
                mass_tr, snaps, tele_out, final_obj, bad)

    # Buffer donation is a no-op (with a warning) on CPU — only request it
    # where the runtime honors it.
    donate = (6, 7) if jax.default_backend() != "cpu" else ()
    return jax.jit(train, donate_argnums=donate)


def _validate_topology(cfg: GadgetConfig) -> None:
    if cfg.topology not in topo.TOPOLOGIES:
        raise ValueError(f"unknown topology {cfg.topology!r}")


def _resolve_faults(cfg: GadgetConfig, m: int) -> GadgetConfig:
    """Validate + canonicalize cfg.faults against the m-node fleet (sorted
    dead tuple, plain scalars) so equal plans key one compiled executable.
    A fully inert plan (no drops, no dead) is normalized to None — it must
    hit the bit-identical perfect-network path, not a faulty recompile."""
    if cfg.faults is None:
        return cfg
    plan = flt.validate_plan(cfg.faults, m)
    if plan.drop_prob == 0.0 and not plan.dead_nodes:
        return cfg._replace(faults=None)
    return cfg._replace(faults=plan)


# Default anytime-export ring capacity: enough history for serve-side A/B
# (previous vs current snapshot) without holding every iterate.
DEFAULT_SNAPSHOT_SLOTS = 8


def _validate_snapshotting(snapshot_every, snapshot_slots) -> int:
    if snapshot_every is None:
        return 0
    if int(snapshot_every) < 1:
        raise ValueError(f"snapshot_every must be >= 1, got {snapshot_every}")
    if int(snapshot_slots) < 1:
        raise ValueError(f"snapshot_slots must be >= 1, got {snapshot_slots}")
    return int(snapshot_every)


def _prepare_device_train(cfg: GadgetConfig, X_parts: jax.Array, y_parts: jax.Array,
                          n_counts=None, snapshot_every=None,
                          snapshot_slots: int = DEFAULT_SNAPSHOT_SLOTS,
                          telemetry: tmt.TrainTelemetry | None = None):
    """Build the exact (jitted train fn, argument tuple) pair `gadget_train`
    executes: resolved config, one stacked-matrix upload, PRNG streams, fresh
    (donatable) weight buffers. The transfer-guard benchmark calls this too,
    so the device-residency proof certifies the real path, not a replica.
    Requires cfg.max_iters > 0."""
    X, m, n_i, d, dtype = _unpack_partitions(X_parts)
    cfg = _resolve_kernels(cfg)
    cfg = _resolve_faults(cfg, m)
    snap_every = _validate_snapshotting(snapshot_every, snapshot_slots)
    n_counts = _partition_counts(y_parts, n_counts)
    data_key, mix_key = _stream_keys(cfg.seed)
    sparse_block_bound = _sparse_block_bound(cfg, X_parts, X)

    if cfg.topology == "random":
        B_stack = None
    else:
        # fused: upload the per-iteration collapsed-product cycle (R× smaller
        # per iteration consumed) instead of the per-round matrix cycle.
        # Under faults the product can no longer be precomputed on host (each
        # round's matrix mutates per iteration), so the per-round matrix
        # cycle is uploaded and the faulty product is folded on device.
        use_product = cfg.fused and cfg.faults is None
        stack = (topo.build_product_stack(cfg.topology, m, cfg.gossip_rounds)
                 if use_product else topo.build_matrix_stack(cfg.topology, m))
        B_stack = jnp.asarray(stack)
        transfer_stats["matrix_uploads"] += 1  # the only upload, ever

    tele = tmt.validate_telemetry(telemetry)
    chunk = min(cfg.check_every, cfg.max_iters)
    n_chunks = -(-cfg.max_iters // chunk)
    train = _make_device_train(_cache_cfg(cfg), m, n_i, d, n_chunks, chunk,
                               sparse_block_bound, snap_every,
                               int(snapshot_slots) if snap_every else 0,
                               tele.every if tele else 0,
                               tele.slots if tele else 0,
                               tele.per_node if tele else False)
    args = (X, jnp.asarray(y_parts), B_stack, data_key, mix_key,
            n_counts, jnp.zeros((m, d), dtype), jnp.zeros((m, d), dtype))
    return train, args


@functools.lru_cache(maxsize=64)
def _gossip_bytes_per_iter(topology: str, m: int, R: int, d: int) -> int:
    """Analytic gossip payload bytes one iteration moves: R rounds × live
    off-diagonal links per round × (d weight floats + 1 mass float) × 4.
    Deterministic topologies count their matrix cycle's mean off-diagonal
    support; the random protocol pushes to exactly one neighbor per node per
    round. Feeds the ``train.gossip_bytes`` counter."""
    if topology == "random":
        links = float(m)
    else:
        stack = np.asarray(topo.build_matrix_stack(topology, m))
        offdiag = (stack != 0).sum(axis=(1, 2)) - (
            np.diagonal(stack, axis1=1, axis2=2) != 0).sum(axis=1)
        links = float(offdiag.mean())
    return int(round(R * links * (d + 1) * 4))


def _record_train_telemetry(cfg: GadgetConfig, m: int, d: int, X,
                            sparse_block_bound, n_iters: int,
                            registry=None) -> None:
    """Registry accounting for ``n_iters`` finished training iterations.

    The jitted loop cannot count its own kernel launches, so the host mirrors
    what the traced program dispatches per iteration — iteration and
    gossip-byte counters always, kernel launch/bytes/FLOPs series when the
    Pallas path is active — onto the (default) registry. Pure host-side
    bookkeeping: it never touches the traced program or the trajectory."""
    if n_iters <= 0:
        return
    reg = tmr.default_registry() if registry is None else registry
    reg.counter("train.iterations").inc(n_iters)
    reg.counter("train.gossip_bytes").inc(
        n_iters * _gossip_bytes_per_iter(cfg.topology, m, cfg.gossip_rounds, d))
    if not cfg.use_kernels:
        return
    B = cfg.batch_size
    if isinstance(X, tuple):
        k = int(X[0].shape[-1])
        schedule, blk_d, n_blocks_max = hinge_ops.resolve_ell_schedule(
            cfg.sparse_schedule, B=B, k=k, d=d, n_blocks_max=sparse_block_bound)
        hinge_ops.record_launch("ell_fleet_half_step", n_iters, registry=reg,
                                m=m, B=B, k=k, d=d, schedule=schedule,
                                blk_d=blk_d, n_blocks_max=n_blocks_max)
    elif cfg.fused:
        hinge_ops.record_launch("fleet_half_step", n_iters, registry=reg,
                                m=m, B=B, d=d)
    else:
        hinge_ops.record_launch("local_half_step", n_iters * m, registry=reg,
                                B=B, d=d)


def gadget_train(
    X_parts: jax.Array,
    y_parts: jax.Array,
    cfg: GadgetConfig = GadgetConfig(),
    *,
    n_counts=None,
    snapshot_every: int | None = None,
    snapshot_slots: int = DEFAULT_SNAPSHOT_SLOTS,
    telemetry: tmt.TrainTelemetry | None = None,
) -> GadgetResult:
    """Simulator-path GADGET over m nodes. X_parts: (m, n_i, d) dense, or a
    ``repro.sparse.EllPartitions`` of stacked padded-ELL planes (sparse local
    half-steps; gossip stays dense in w). y_parts: (m, n_i).

    Thin host wrapper around the jitted device loop: uploads the data and (for
    deterministic topologies) one stacked mixing-matrix cycle, runs the
    entire anytime loop on device, then syncs the result and traces once.

    ``n_counts`` (optional, shape (m,)): per-node valid-row counts for
    non-uniform partitions padded to a common n_i. Padded rows (beyond
    n_counts[i]) must carry y=0; they are never sampled, carry no Push-Sum
    mass, and are excluded from the consensus weighting and objective trace.
    ``repro.data.svm_datasets.partition`` returns exactly these counts.

    ``snapshot_every=K`` (optional): anytime export — every K-th iteration
    records ``(iteration, consensus w, primal objective)`` into an on-device
    ring of ``snapshot_slots`` entries riding the jitted while_loop (GADGET is
    usable at every iteration; this is the serving tap). The ring plus the
    final iterate come back as ``result.snapshots`` (:class:`SnapshotRing`) in
    the same single post-termination sync; decode with
    ``repro.serve.snapshot.snapshots_from``. K > the realized iteration count
    simply yields the final snapshot alone.

    ``telemetry`` (optional :class:`repro.telemetry.TrainTelemetry`): thread
    the flight-recorder trace ring through the same jitted loop — consensus
    disagreement, windowed Push-Sum mass extrema, objective, and fault-drop
    counts every ``telemetry.every`` iterations into ``telemetry.slots`` ring
    slots, decoded into ``result.telemetry`` (:class:`repro.telemetry.
    TrainTrace`) in the same single sync and mirrored onto the default
    registry. ``telemetry=None`` (default) leaves the traced program — and
    therefore the trajectory — bit-identical to builds without the ring
    (asserted in tests).
    """
    _validate_topology(cfg)
    tele_cfg = tmt.validate_telemetry(telemetry)

    empty = np.zeros((0,), np.float32)
    if cfg.max_iters <= 0:  # zero-iteration call: return the initial state
        snap_every = _validate_snapshotting(snapshot_every, snapshot_slots)
        _, m, n_i, d, dtype = _unpack_partitions(X_parts)
        trace = None
        if tele_cfg:
            # W = 0 everywhere: disagreement is exactly 0, nothing recorded
            empty_i = np.zeros((0,), np.int64)
            empty_f = np.zeros((0,), np.float64)
            empty_nf = np.zeros((0, m), np.float64)
            trace = tmt.TrainTrace(
                every=tele_cfg.every, iterations=empty_i,
                disagreement=empty_f, mass_min=empty_f,
                mass_max=empty_f, objective=empty_f,
                drops=empty_i, final_iteration=0,
                final_disagreement=0.0,
                node_disagreement=empty_nf if tele_cfg.per_node else None,
                node_mass=empty_nf if tele_cfg.per_node else None,
                node_drops=(empty_nf.astype(np.int64)
                            if tele_cfg.per_node else None))
            tmt.publish_trace(trace)
        ring = None
        if snap_every:
            # empty ring, initial state as the final iterate: w = 0 scores
            # every margin 0, so the masked primal objective is exactly 1
            ring = SnapshotRing(
                every=snap_every,
                W=np.zeros((int(snapshot_slots), d), np.float32),
                iterations=np.zeros((int(snapshot_slots),), np.int32),
                objectives=np.full((int(snapshot_slots),), np.nan, np.float32),
                count=0, final_w=np.zeros((d,), np.float32),
                final_iteration=0, final_objective=1.0)
        return GadgetResult(W=jnp.zeros((m, d), dtype),
                            w_consensus=jnp.zeros((d,), dtype),
                            iters=0, epsilon=float("inf"),
                            objective_trace=empty, time_trace=empty.astype(np.int32),
                            eps_trace=empty, W_avg=jnp.zeros((m, d), dtype),
                            snapshots=ring, mass_trace=empty, telemetry=trace)

    train, args = _prepare_device_train(cfg, X_parts, y_parts, n_counts,
                                        snapshot_every, snapshot_slots,
                                        telemetry=tele_cfg)
    out = train(*args)
    (W, W_sum, w_cons, iters, n_done, eps, obj_tr, it_tr, eps_tr,
     mass_tr, snaps, tele_out, final_obj, bad) = jax.block_until_ready(out)
    transfer_stats["host_syncs"] += 1  # single post-termination sync
    if int(bad):
        # the on-device guard caught a non-finite consensus plane: typed
        # failure, never a silently-NaN GadgetResult
        tmr.default_registry().counter("train.nonfinite").inc()
        raise NonFiniteWeightsError(int(bad))

    n_done = int(n_done)
    iters = int(iters)
    trace = None
    if tele_cfg:
        ndisr = nmassr = ndropr = None
        if tele_cfg.per_node:
            (ti, tdis, tmn, tmx, tob, tdr, tc, _, _, _,
             ndisr, nmassr, ndropr, _, final_dis) = tele_out
        else:
            ti, tdis, tmn, tmx, tob, tdr, tc, _, _, _, final_dis = tele_out
        trace = tmt.decode_ring(tele_cfg.every, tele_cfg.slots, int(tc),
                                ti, tdis, tmn, tmx, tob, tdr,
                                iters, float(final_dis),
                                node_disagreement=ndisr, node_mass=nmassr,
                                node_drops=ndropr)
        tmt.publish_trace(trace)
    rcfg = _resolve_kernels(cfg)
    X_in, m_in, _, d_in, _ = _unpack_partitions(X_parts)
    _record_train_telemetry(rcfg, m_in, d_in, X_in,
                            _sparse_block_bound(rcfg, X_parts, X_in), iters)
    ring = None
    if snapshot_every:
        sw, si, so, sc = snaps
        ring = SnapshotRing(every=int(snapshot_every), W=np.asarray(sw),
                            iterations=np.asarray(si), objectives=np.asarray(so),
                            count=int(sc), final_w=np.asarray(w_cons),
                            final_iteration=iters,
                            final_objective=float(final_obj))
    return GadgetResult(
        W=W,
        w_consensus=w_cons,
        iters=iters,
        epsilon=float(eps),
        objective_trace=np.asarray(obj_tr)[:n_done],
        time_trace=np.asarray(it_tr)[:n_done],
        eps_trace=np.asarray(eps_tr)[:n_done],
        W_avg=W_sum / max(iters, 1),
        snapshots=ring,
        mass_trace=np.asarray(mass_tr)[:n_done],
        telemetry=trace,
    )


# ---------------------------------------------------------------------------
# Segmented streaming trainer — the live train-to-serve tap
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=32)
def _make_segment_train(cfg: GadgetConfig, m: int, n_i: int, d: int,
                        seg_len: int, sparse_block_bound: int | None = None,
                        tele: bool = False):
    """Jitted ``seg_len``-iteration training segment, compiled once per
    (cfg, shape, seg_len): a ``lax.scan`` over the same ``_one_iteration``
    body as the while-loop trainer, with the global iteration counter ``t0``
    as a *runtime* argument. Every segment of a run — tail included — reuses
    this one executable: iterations past ``cfg.max_iters`` are masked inactive
    under ``lax.cond`` (exactly the while-loop trainer's tail handling), and
    because the PRNG streams are keyed on the global ``t``
    (``fold_in(data_key, t)``), a segmented run's trajectory is bit-identical
    to one uninterrupted ``gadget_train`` call.

    ``tele`` additionally returns per-segment telemetry extras — boundary
    consensus disagreement, Push-Sum mass extrema over the segment's *active*
    iterations (NaN when the whole segment sat past ``cfg.max_iters``), and
    the segment's fault-drop count. ``tele=False`` traces the exact
    pre-telemetry program (bit-identity pinned by tests)."""
    tele_drops = tele and cfg.faults is not None

    def segment(X, y, B_stack, data_key, mix_key, n_counts, W, W_sum, t0):
        objective_of, consensus_of = _trace_closures(cfg, X, y, n_counts,
                                                     m, n_i, d)

        def step(carry, _):
            W, W_sum, t = carry
            active = t <= cfg.max_iters
            if tele_drops:
                W, W_sum, mass, drops = jax.lax.cond(
                    active,
                    lambda a: _one_iteration(cfg, m, X, y, n_counts,
                                             data_key, mix_key, B_stack, *a,
                                             sparse_block_bound=sparse_block_bound,
                                             count_drops=True),
                    lambda a: (a[0], a[1], jnp.float32(1.0), jnp.int32(0)),
                    (W, W_sum, t),
                )
                ys = (mass, drops)
            else:
                W, W_sum, mass = jax.lax.cond(
                    active,
                    lambda a: _one_iteration(cfg, m, X, y, n_counts,
                                             data_key, mix_key, B_stack, *a,
                                             sparse_block_bound=sparse_block_bound),
                    lambda a: (a[0], a[1], jnp.float32(1.0)),
                    (W, W_sum, t),
                )
                ys = (mass, jnp.int32(0)) if tele else mass
            return (W, W_sum, jnp.where(active, t + 1, t)), ys

        W_prev = W
        (W, W_sum, t), ys = jax.lax.scan(step, (W, W_sum, t0), None,
                                         length=seg_len)
        masses, drops = ys if tele else (ys, None)
        eps = jnp.max(jnp.linalg.norm(W - W_prev, axis=1))
        w_cons = consensus_of(W)
        base = (W, W_sum, t, w_cons, objective_of(w_cons), eps,
                jnp.min(masses))
        if not tele:
            return base
        # telemetry extras mask out the inactive tail (iterations clamped
        # past cfg.max_iters report a dummy mass of 1.0)
        n_active = jnp.clip(cfg.max_iters - (t0 - 1), 0, seg_len)
        act = jnp.arange(seg_len) < n_active
        any_act = n_active > 0
        mass_min = jnp.where(any_act,
                             jnp.min(jnp.where(act, masses, jnp.inf)), jnp.nan)
        mass_max = jnp.where(any_act,
                             jnp.max(jnp.where(act, masses, -jnp.inf)), jnp.nan)
        dis = jnp.max(jnp.linalg.norm(W - w_cons[None, :], axis=1))
        return base + (dis, mass_min, mass_max,
                       jnp.sum(jnp.where(act, drops, 0)))

    donate = (6, 7) if jax.default_backend() != "cpu" else ()
    return jax.jit(segment, donate_argnums=donate)


def gadget_train_stream(
    X_parts: jax.Array,
    y_parts: jax.Array,
    cfg: GadgetConfig = GadgetConfig(),
    *,
    segment_iters: int,
    n_counts=None,
    resume: TrainState | None = None,
    telemetry: tmt.TrainTelemetry | None = None,
    trace: bool = False,
    trace_link: str | None = None,
    trace_registry=None,
):
    """Generator twin of :func:`gadget_train`: yield a :class:`SegmentResult`
    every ``segment_iters`` iterations while training stays device-resident.

    This is the live train-to-serve tap (``repro.serve.publisher`` runs it in
    a background thread): the trajectory is **bit-identical** to a single
    ``gadget_train`` call on the same config — segments reuse one compiled
    executable with the global iteration counter as a runtime argument, and
    all PRNG draws key on that global counter — but control returns to the
    host at every segment boundary, where the current consensus model can be
    published. ``segment_iters`` is also the ε-check cadence (it plays the
    role ``cfg.check_every`` plays in ``gadget_train``); the stream ends after
    the segment where ``ε < cfg.epsilon`` or ``cfg.max_iters`` is reached
    (that last result carries ``done=True``). Accepts the same dense
    (m, n_i, d) / ``EllPartitions`` data and ``n_counts`` conventions as
    ``gadget_train``. One host sync per segment, by construction.

    ``resume`` (optional :class:`TrainState`, e.g. from
    ``repro.serve.snapshot.train_state_from_checkpoint``): continue a
    previous run from its last completed iteration. Because every PRNG draw
    keys on the *global* iteration counter and segments reuse one compiled
    executable with that counter as a runtime argument, a killed-and-resumed
    run's trajectory is **bit-identical** to the uninterrupted one — the
    crash-recovery half of the fault story (tests pin this).

    ``telemetry`` (optional :class:`repro.telemetry.TrainTelemetry`): attach
    per-segment flight-recorder readings — boundary consensus disagreement,
    active-iteration Push-Sum mass extrema, fault-drop counts — to each
    yielded ``SegmentResult.telemetry`` and mirror them onto the default
    registry (``every``/``slots`` are ring parameters and don't apply here:
    the segment boundary IS the cadence). ``telemetry=None`` (default)
    traces the exact pre-telemetry program: trajectories stay bit-identical.

    ``trace=True`` starts one causal trace per segment (the version-lineage
    root): a ``train.segment`` span — segment wall seconds, iteration,
    objective — is emitted on ``trace_registry`` (default: the process
    default registry) at every boundary, and
    the root :class:`~repro.telemetry.trace.TraceContext` rides out on
    ``SegmentResult.trace`` for the publisher to extend (explicit
    propagation across the thread boundary; host-side only, the traced
    device program is untouched). ``trace_link`` (the prior run's trace_id,
    e.g. recovered from a checkpoint manifest by the publisher on
    ``resume="latest"``) is stamped onto the first segment's span as a
    ``resumed_from_trace`` attr, linking the fresh traces to the
    pre-crash lineage.
    """
    _validate_topology(cfg)
    tele_cfg = tmt.validate_telemetry(telemetry)
    if int(segment_iters) < 1:
        raise ValueError(f"segment_iters must be >= 1, got {segment_iters}")
    if cfg.max_iters <= 0:
        raise ValueError("gadget_train_stream needs cfg.max_iters > 0 "
                         "(use gadget_train for the zero-iteration case)")
    X, m, n_i, d, dtype = _unpack_partitions(X_parts)
    cfg = _resolve_kernels(cfg)
    cfg = _resolve_faults(cfg, m)
    y = jnp.asarray(y_parts)
    n_counts = _partition_counts(y, n_counts)
    data_key, mix_key = _stream_keys(cfg.seed)
    sparse_block_bound = _sparse_block_bound(cfg, X_parts, X)

    if cfg.topology == "random":
        B_stack = None
    else:
        use_product = cfg.fused and cfg.faults is None
        stack = (topo.build_product_stack(cfg.topology, m, cfg.gossip_rounds)
                 if use_product else topo.build_matrix_stack(cfg.topology, m))
        B_stack = jnp.asarray(stack)
        transfer_stats["matrix_uploads"] += 1  # one upload, same as gadget_train

    segment = _make_segment_train(_cache_cfg(cfg), m, n_i, d,
                                  int(segment_iters), sparse_block_bound,
                                  tele=tele_cfg is not None)
    if resume is not None:
        W = jnp.asarray(resume.W, dtype)
        W_sum = jnp.asarray(resume.W_sum, dtype)
        if W.shape != (m, d) or W_sum.shape != (m, d):
            raise ValueError(
                f"resume state shape {W.shape}/{W_sum.shape} does not match "
                f"the ({m}, {d}) fleet")
        if int(resume.iteration) < 0:
            raise ValueError(f"resume iteration must be >= 0, got {resume.iteration}")
        t = jnp.int32(int(resume.iteration) + 1)
    else:
        W = jnp.zeros((m, d), dtype)
        W_sum = jnp.zeros((m, d), dtype)
        t = jnp.int32(1)
    first_segment = True
    while True:
        prev_iteration = int(t) - 1
        seg_t0 = time.monotonic()
        out = segment(X, y, B_stack, data_key, mix_key, n_counts, W, W_sum, t)
        out = jax.block_until_ready(out)
        seg_seconds = time.monotonic() - seg_t0
        seg_tele = None
        if tele_cfg:
            (W, W_sum, t, w_cons, objective, eps, mass,
             dis, seg_mn, seg_mx, seg_drops) = out
            seg_tele = tmt.SegmentTelemetry(
                disagreement=float(dis), mass_min=float(seg_mn),
                mass_max=float(seg_mx), objective=float(objective),
                drops=int(seg_drops))
        else:
            W, W_sum, t, w_cons, objective, eps, mass = out
        transfer_stats["host_syncs"] += 1  # one sync per segment boundary
        iteration = int(t) - 1
        if not np.all(np.isfinite(np.asarray(w_cons))):
            # segment boundaries ARE the stream's check cadence and the
            # consensus is already host-synced here, so the guard is a free
            # host-side reduction — same typed failure as the device loop,
            # and it fires before a publisher could flush the segment
            tmr.default_registry().counter("train.nonfinite").inc()
            raise NonFiniteWeightsError(iteration)
        _record_train_telemetry(cfg, m, d, X, sparse_block_bound,
                                iteration - prev_iteration)
        if seg_tele is not None:
            reg = tmr.default_registry()
            reg.gauge("train.final_disagreement").set(seg_tele.disagreement)
            reg.gauge("train.objective").set(seg_tele.objective)
            if np.isfinite(seg_tele.mass_min):
                reg.gauge("train.mass_min").set(seg_tele.mass_min)
                reg.gauge("train.mass_max").set(seg_tele.mass_max)
            reg.counter("train.fault_drops").inc(seg_tele.drops)
        eps_f = float(eps)
        done = eps_f < cfg.epsilon or iteration >= cfg.max_iters
        seg_ctx = None
        if trace:
            # one fresh trace per segment: this span is the lineage root the
            # publisher/server chain hangs off (via SegmentResult.trace)
            seg_ctx = tmtr.TraceContext.new()
            attrs = {"iteration": iteration, "objective": float(objective),
                     "epsilon": eps_f, "done": done}
            if first_segment and trace_link:
                attrs["resumed_from_trace"] = trace_link
            tmtr.emit_span(trace_registry if trace_registry is not None
                           else tmr.default_registry(),
                           "train.segment", seg_ctx, seg_seconds, **attrs)
        first_segment = False
        yield SegmentResult(iteration=iteration, W=W,
                            w_consensus=np.asarray(w_cons),
                            objective=float(objective), epsilon=eps_f,
                            done=done, W_sum=W_sum, mass=float(mass),
                            telemetry=seg_tele, trace=seg_ctx)
        if done:
            return


# ---------------------------------------------------------------------------
# Host-loop reference (seed semantics) — parity oracle and transfer baseline
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=32)
def _make_reference_step(cfg: GadgetConfig, m: int, n_i: int, d: int,
                         sparse_block_bound: int | None = None):
    """One jitted GADGET iteration for the host-loop reference, compiled once
    per (cfg, shape): data/keys are runtime arguments, not baked-in constants.
    Deterministic topologies receive this iteration's matrices via ``Bs``
    (the per-iteration host upload being measured); the random protocol draws
    them in-step like the device path and ignores ``Bs``. The sparse block
    bound rides along so the oracle resolves the *same* kernel schedule as
    the device loop — otherwise ``sparse_schedule="auto"`` could pick prefetch
    on one side and sweep on the other and the trajectories would differ in
    float rounding."""

    def step(X, y, n_counts, data_key, mix_key, W, W_sum, t, Bs):
        if cfg.topology == "random":
            Bs = _iter_mixing(mix_key, None, t, m, cfg.gossip_rounds,
                              cfg.topology, cfg.fused, cfg.faults)
        elif cfg.faults is not None:
            # host-uploaded clean rounds, device-applied faults — the same
            # (seed, t, r) fault stream the fused path consumes
            Bs = flt.faulty_rounds(Bs, cfg.faults, t)
        return _gossip_step(cfg, m, X, y, n_counts, data_key, W, W_sum, t, Bs,
                            sparse_block_bound)

    return jax.jit(step)


def gadget_train_reference(
    X_parts: jax.Array,
    y_parts: jax.Array,
    cfg: GadgetConfig = GadgetConfig(),
    *,
    n_counts=None,
    snapshot_every: int | None = None,
    snapshot_slots: int = DEFAULT_SNAPSHOT_SLOTS,
) -> GadgetResult:
    """Seed-style host chunk loop on the same PRNG streams as `gadget_train`:
    mixing matrices cross the host boundary every iteration (deterministic
    topologies) and every ε-check is a blocking ``float(...)`` sync. Always
    runs *unfused* (two kernels per node, R sequential Push-Sum rounds) —
    it is the seed-semantics parity oracle the fused device path is accepted
    against, and the baseline for the transfer-counter benchmark.

    ``snapshot_every=K`` mirrors the device loop's anytime-export ring on the
    host, slot for slot — the reference trace the device snapshots are
    accepted against (tests/test_serve.py sweeps K).
    """
    X, m, n_i, d, dtype = _unpack_partitions(X_parts)
    _validate_topology(cfg)
    cfg = _resolve_kernels(cfg)._replace(fused=False)
    cfg = _resolve_faults(cfg, m)
    n_counts = _partition_counts(y_parts, n_counts)
    data_key, mix_key = _stream_keys(cfg.seed)
    stack = None if cfg.topology == "random" else topo.build_matrix_stack(cfg.topology, m)
    R = cfg.gossip_rounds

    y = jnp.asarray(y_parts)
    total_n = jnp.sum(n_counts)
    objective_of, _ = _trace_closures(cfg, X, y, n_counts, m, n_i, d)
    one_iter = _make_reference_step(_cache_cfg(cfg), m, n_i, d,
                                    _sparse_block_bound(cfg, X_parts, X))
    snap_every = _validate_snapshotting(snapshot_every, snapshot_slots)
    if snap_every:  # host twin of the device ring, slot for slot
        snap_w = np.zeros((snapshot_slots, d), np.float32)
        snap_it = np.zeros((snapshot_slots,), np.int32)
        snap_obj = np.full((snapshot_slots,), np.nan, np.float32)
        snap_count = 0

    W = jnp.zeros((m, d), dtype)
    W_sum = jnp.zeros((m, d), dtype)
    obj_trace, time_trace, eps_trace, mass_trace = [], [], [], []
    eps = float("inf")
    it = 0
    while it < cfg.max_iters:
        chunk = min(cfg.check_every, cfg.max_iters - it)
        W_prev = W
        chunk_masses = []
        for s in range(chunk):
            t = jnp.int32(it + s + 1)
            if stack is not None:
                idx = ((it + s) * R + np.arange(R)) % stack.shape[0]
                Bs = jnp.asarray(stack[idx])  # host→device upload, every iteration
                transfer_stats["matrix_uploads"] += 1
            else:
                Bs = None  # drawn in-step, same as the device path
            W, W_sum, mass = one_iter(X, y, n_counts, data_key, mix_key,
                                      W, W_sum, t, Bs)
            chunk_masses.append(mass)  # device scalar; min'd at the ε-check
            if snap_every and (it + s + 1) % snap_every == 0:
                w_snap = jnp.sum(W * n_counts[:, None], axis=0) / total_n
                slot = snap_count % snapshot_slots
                snap_w[slot] = np.asarray(w_snap)
                snap_it[slot] = it + s + 1
                snap_obj[slot] = float(objective_of(w_snap))
                snap_count += 1
        it += chunk
        eps = float(jnp.max(jnp.linalg.norm(W - W_prev, axis=1)))  # blocking sync
        transfer_stats["host_syncs"] += 1
        w_cons = jnp.sum(W * n_counts[:, None], axis=0) / total_n
        obj_trace.append(float(objective_of(w_cons)))
        transfer_stats["host_syncs"] += 1  # objective pull is a second blocking sync
        time_trace.append(it)
        eps_trace.append(eps)
        mass_trace.append(float(jnp.min(jnp.stack(chunk_masses))))
        if eps < cfg.epsilon:
            break

    w_cons = jnp.sum(W * n_counts[:, None], axis=0) / jnp.sum(n_counts)
    ring = None
    if snap_every:
        ring = SnapshotRing(every=snap_every, W=snap_w, iterations=snap_it,
                            objectives=snap_obj, count=snap_count,
                            final_w=np.asarray(w_cons), final_iteration=it,
                            final_objective=float(objective_of(w_cons)))
    return GadgetResult(
        W=W,
        w_consensus=w_cons,
        iters=it,
        epsilon=eps,
        objective_trace=np.asarray(obj_trace),
        time_trace=np.asarray(time_trace),
        eps_trace=np.asarray(eps_trace),
        W_avg=W_sum / max(it, 1),
        snapshots=ring,
        mass_trace=np.asarray(mass_trace, np.float32),
    )


# ---------------------------------------------------------------------------
# Mesh path: one GADGET iteration as a shard_map-able step
# ---------------------------------------------------------------------------


def make_gadget_mesh_step(cfg: GadgetConfig, axis_sizes: dict[str, int],
                          sparse_block_bound: int | None = None):
    """Build a per-node GADGET step for use inside ``shard_map``.

    The returned ``step(w, X_local, y_local, t, key)`` runs the local Pegasos
    half-step (kernel-backed when ``cfg.use_kernels``) then
    ``cfg.gossip_rounds`` ppermute Push-Sum rounds over the given mesh axes.
    ``t`` is a traced scalar; the gossip hop schedule is rotated by the
    *python-level* step index captured at trace time via closure — callers jit
    once per schedule offset or (default) keep the full exponential schedule
    per step so rotation is unnecessary.

    ``X_local`` is the node's dense (n_local, d) shard **or** a
    ``(cols_local, vals_local)`` tuple of its (n_local, k) padded-ELL planes —
    the node-sharded sparse layout: each shard of the mesh holds only its own
    rows' planes, the half-step runs the ELL kernels on them
    (``cfg.sparse_schedule`` picks sweep vs touched-block, with
    ``sparse_block_bound`` as the prefetch grid cap — derive it on host with
    ``formats.minibatch_block_bound`` over the full planes so every shard
    traces the same grid), and only the dense w crosses the mesh in gossip.
    Kernel-backed steps need ``shard_map(..., check_rep=False)`` — jax has no
    replication rule for ``pallas_call`` yet (tests pin this).

    ``cfg.faults`` injects the same fault model as the simulator path, as
    masked ``ppermute`` sends: each round every node draws a fail bit from
    the plan's salted ``(seed, t, round, node)`` stream and its outgoing
    share is zeroed before the permute (kept locally in ``"link"`` mode,
    dropped in ``"message"`` mode); sends to or from a dead node always
    fail, and dead nodes are frozen entirely. Node ids in
    ``plan.dead_nodes`` index the *linearized* position over ``axis_sizes``
    in dict order (row-major), matching the simulator's node axis for a
    single-axis mesh.
    """
    cfg = _resolve_kernels(cfg)
    sched = exponential_schedule(axis_sizes)
    R = len(sched) if cfg.gossip_rounds is None else cfg.gossip_rounds
    if not sched:
        R = 0  # single-node mesh: no neighbors to gossip with

    n_total = 1
    for n_ax in axis_sizes.values():
        n_total *= int(n_ax)
    faults = None
    if cfg.faults is not None:
        faults = flt.validate_plan(cfg.faults, n_total)
        if faults.drop_prob == 0.0 and not faults.dead_nodes:
            faults = None  # inert plan: keep the unmasked collective path
    dead_ids = (jnp.asarray(faults.dead_nodes, jnp.int32)
                if faults is not None and faults.dead_nodes else None)
    axes = list(axis_sizes)
    strides = {}
    acc = 1
    for ax in reversed(axes):  # row-major linearization over axis_sizes order
        strides[ax] = acc
        acc *= int(axis_sizes[ax])

    def _is_dead(lin):
        if dead_ids is None:
            return jnp.bool_(False)
        return jnp.any(lin == dead_ids)

    def step(w: jax.Array, X_local, y_local: jax.Array,
             t: jax.Array, key: jax.Array) -> jax.Array:
        sparse = isinstance(X_local, tuple)
        n_local = (X_local[0] if sparse else X_local).shape[0]
        ids = jax.random.randint(key, (cfg.batch_size,), 0, n_local)
        tf = t.astype(jnp.float32)
        if sparse:
            cols_l, vals_l = X_local
            Cb, Vb, yb = cols_l[ids], vals_l[ids], y_local[ids]
            # the sparse kernels are fleet-shaped: one-node fleet per shard
            if cfg.use_kernels:
                w_half = hinge_ops.ell_fleet_half_step(
                    w[None], Cb[None], Vb[None], yb[None], lam=cfg.lam, t=tf,
                    project=cfg.project_before_gossip,
                    schedule=cfg.sparse_schedule,
                    n_blocks_max=sparse_block_bound)[0]
            else:
                w_half = hinge_ref.ell_fleet_half_step_ref(
                    w[None], Cb[None], Vb[None], yb[None], cfg.lam, tf,
                    project=cfg.project_before_gossip)[0]
        else:
            w_half = _local_half_step(w, X_local, y_local, ids, cfg.lam,
                                      tf, cfg.project_before_gossip,
                                      cfg.use_kernels)
        state = PushSumState(values=(w_half,), weight=jnp.float32(1.0))
        if faults is not None:
            coords = {ax: jax.lax.axis_index(ax) for ax in axes}
            lin = jnp.int32(0)
            for ax in axes:
                lin = lin * axis_sizes[ax] + coords[ax]
            dead = _is_dead(lin)
        for k in range(R):
            rnd = sched[k % len(sched)]
            if faults is None:
                state = push_sum_round(state, rnd)
                continue
            c = coords[rnd.axis]
            partner_lin = lin + (((c + rnd.hop) % axis_sizes[rnd.axis]) - c) * strides[rnd.axis]
            fail = jax.random.bernoulli(
                jax.random.fold_in(flt.round_fail_key(faults, t, k), lin),
                faults.drop_prob)
            fail = fail | dead | _is_dead(partner_lin)
            state = push_sum_round(state, rnd,
                                   fault=(fail, dead, faults.drop))
        (w_new,) = state.estimate()
        if cfg.project_after_gossip:
            w_new = obj.project_ball(w_new, cfg.lam)
        if faults is not None:
            w_new = jnp.where(dead, w, w_new)  # crashed nodes are frozen
        return w_new

    return step
