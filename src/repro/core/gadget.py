"""GADGET SVM — Gossip-bAseD sub-GradiEnT solver (paper Algorithm 2).

Every node i holds a horizontal partition M_i (n_i × d) and a weight vector
ŵ_i. One iteration t:

  (a-c)  sample a local mini-batch, L̂_i = mean_{violators} y·x under ŵ_i
  (d)    α_t = 1 / (λ t)
  (e)    w̃_i = (1 − λ α_t) ŵ_i + α_t L̂_i          (local Pegasos half-step)
  (f)    [optional] project w̃_i onto the 1/√λ ball
  (g)    ŵ_i ← PushSum(B, w̃_i)                     (gossip consensus)
  (h)    [optional] project again

The algorithm is *anytime*: it stops when max_i ‖ŵ_i^(t+1) − ŵ_i^(t)‖ < ε.

Two execution paths (see core/push_sum.py): the **simulator** runs all m nodes
in one array with matrix-form Push-Sum (any topology, incl. the paper's random
one-neighbor protocol) and is what the paper-validation benchmarks use; the
**mesh** path (`make_gadget_mesh_step`) shards nodes over mesh axes with
ppermute gossip and is what scales to pods.

Weighted consensus: the paper pushes n_i·ŵ_i so the consensus target is the
data-weighted network average Σ n_i ŵ_i / N. We implement this by initializing
the Push-Sum mass weight to n_i — the v/w ratio then converges to exactly that
weighted mean for free, including under non-uniform partitions.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import svm_objective as obj
from repro.core.push_sum import PushSumSim, PushSumState, exponential_schedule, push_sum_round

__all__ = ["GadgetConfig", "GadgetState", "GadgetResult", "gadget_train", "make_gadget_mesh_step"]


class GadgetConfig(NamedTuple):
    lam: float = 1e-4            # λ — SVM regularization / learning parameter
    batch_size: int = 1          # local examples per sub-gradient estimate
    gossip_rounds: int = 4       # Push-Sum rounds per iteration (R)
    topology: str = "exponential"
    project_before_gossip: bool = True   # paper step (f)
    project_after_gossip: bool = True    # paper step (h)
    epsilon: float = 1e-3        # anytime stopping tolerance (paper: 0.001)
    check_every: int = 100       # host-side ε check cadence
    max_iters: int = 5000
    seed: int = 0


class GadgetState(NamedTuple):
    W: jax.Array        # (m, d) per-node weight vectors ŵ_i
    W_sum: jax.Array    # (m, d) running iterate sums (for w̄_i / T)
    t: jax.Array        # iteration counter (scalar int32)


class GadgetResult(NamedTuple):
    W: jax.Array            # (m, d) final per-node weights
    w_consensus: jax.Array  # (d,) data-weighted network average
    iters: int
    epsilon: float          # max_i ‖Δŵ_i‖ at termination
    objective_trace: np.ndarray  # (n_checks,) primal objective of consensus w
    time_trace: np.ndarray       # iteration index per check


def _partition_counts(y_parts: jax.Array) -> jax.Array:
    m, n_i = y_parts.shape
    return jnp.full((m,), float(n_i), jnp.float32)


def _local_half_step(w, X_i, y_i, ids, lam, t, project):
    Xb, yb = X_i[ids], y_i[ids]
    alpha = 1.0 / (lam * t)
    L_hat = -obj.hinge_subgradient(w, Xb, yb)
    w_half = (1.0 - lam * alpha) * w + alpha * L_hat
    return obj.project_ball(w_half, lam) if project else w_half


def _make_sim_chunk(cfg: GadgetConfig, m: int, n_i: int):
    """Scan body for `chunk` iterations of the simulator path. Mixing matrices
    are precomputed per round and fed as scan inputs (the paper's random
    topology needs fresh host-side draws each round)."""

    def chunk_fn(state: GadgetState, X: jax.Array, y: jax.Array,
                 B_stack: jax.Array, key0: jax.Array, n_counts: jax.Array):
        # X: (m, n_i, d), y: (m, n_i), B_stack: (chunk, R, m, m)
        def step(carry, inp):
            W, W_sum, t = carry
            Bs, step_key = inp
            tf = t.astype(jnp.float32)
            keys = jax.random.split(step_key, m)
            ids = jax.vmap(lambda k: jax.random.randint(k, (cfg.batch_size,), 0, n_i))(keys)
            W_half = jax.vmap(
                lambda w, Xi, yi, ii: _local_half_step(w, Xi, yi, ii, cfg.lam, tf,
                                                       cfg.project_before_gossip)
            )(W, X, y, ids)
            # Push-Sum: values n_i·w̃_i with mass weights n_i ⇒ weighted mean.
            vals = W_half * n_counts[:, None]
            wts = n_counts
            for r in range(cfg.gossip_rounds):
                B = Bs[r]
                vals = B.T @ vals
                wts = B.T @ wts
            W_new = vals / wts[:, None]
            if cfg.project_after_gossip:
                W_new = jax.vmap(lambda w: obj.project_ball(w, cfg.lam))(W_new)
            return (W_new, W_sum + W_new, t + 1), None

        keys = jax.random.split(key0, B_stack.shape[0])
        (W, W_sum, t), _ = jax.lax.scan(step, (state.W, state.W_sum, state.t), (B_stack, keys))
        return GadgetState(W, W_sum, t)

    return jax.jit(chunk_fn)


def gadget_train(
    X_parts: jax.Array,
    y_parts: jax.Array,
    cfg: GadgetConfig = GadgetConfig(),
) -> GadgetResult:
    """Simulator-path GADGET over m nodes. X_parts: (m, n_i, d), y_parts: (m, n_i).

    Runs in chunks of ``cfg.check_every`` iterations; between chunks the host
    checks the paper's anytime criterion max_i ‖Δŵ_i‖ < ε and records the
    consensus primal objective.
    """
    m, n_i, d = X_parts.shape
    sim = PushSumSim(m, cfg.topology, seed=cfg.seed)
    n_counts = _partition_counts(y_parts)
    chunk_fn = _make_sim_chunk(cfg, m, n_i)
    key = jax.random.PRNGKey(cfg.seed)

    X_flat = X_parts.reshape(m * n_i, d)
    y_flat = y_parts.reshape(m * n_i)

    state = GadgetState(
        W=jnp.zeros((m, d), X_parts.dtype),
        W_sum=jnp.zeros((m, d), X_parts.dtype),
        t=jnp.int32(1),
    )
    obj_trace, time_trace = [], []
    eps = float("inf")
    it = 0
    while it < cfg.max_iters:
        chunk = min(cfg.check_every, cfg.max_iters - it)
        B_stack = np.stack([
            np.stack([sim.matrix(it + s * cfg.gossip_rounds + r) for r in range(cfg.gossip_rounds)])
            for s in range(chunk)
        ]).astype(np.float32)  # (chunk, R, m, m)
        key, sub = jax.random.split(key)
        W_prev = state.W
        state = chunk_fn(state, X_parts, y_parts, jnp.asarray(B_stack), sub, n_counts)
        it += chunk
        eps = float(jnp.max(jnp.linalg.norm(state.W - W_prev, axis=1)))
        w_cons = jnp.sum(state.W * n_counts[:, None], axis=0) / jnp.sum(n_counts)
        obj_trace.append(float(obj.primal_objective(w_cons, X_flat, y_flat, cfg.lam)))
        time_trace.append(it)
        if eps < cfg.epsilon:
            break

    w_cons = jnp.sum(state.W * n_counts[:, None], axis=0) / jnp.sum(n_counts)
    return GadgetResult(
        W=state.W,
        w_consensus=w_cons,
        iters=it,
        epsilon=eps,
        objective_trace=np.asarray(obj_trace),
        time_trace=np.asarray(time_trace),
    )


# ---------------------------------------------------------------------------
# Mesh path: one GADGET iteration as a shard_map-able step
# ---------------------------------------------------------------------------


def make_gadget_mesh_step(cfg: GadgetConfig, axis_sizes: dict[str, int]):
    """Build a per-node GADGET step for use inside ``shard_map``.

    The returned ``step(w, X_local, y_local, t, key)`` runs the local Pegasos
    half-step then ``cfg.gossip_rounds`` ppermute Push-Sum rounds over the
    given mesh axes. ``t`` is a traced scalar; the gossip hop schedule is
    rotated by the *python-level* step index captured at trace time via
    closure — callers jit once per schedule offset or (default) keep the full
    exponential schedule per step so rotation is unnecessary.
    """
    sched = exponential_schedule(axis_sizes)
    R = len(sched) if cfg.gossip_rounds is None else cfg.gossip_rounds

    def step(w: jax.Array, X_local: jax.Array, y_local: jax.Array,
             t: jax.Array, key: jax.Array) -> jax.Array:
        n_local = X_local.shape[0]
        ids = jax.random.randint(key, (cfg.batch_size,), 0, n_local)
        w_half = _local_half_step(w, X_local, y_local, ids, cfg.lam,
                                  t.astype(jnp.float32), cfg.project_before_gossip)
        state = PushSumState(values=(w_half,), weight=jnp.float32(1.0))
        for k in range(R):
            state = push_sum_round(state, sched[k % len(sched)])
        (w_new,) = state.estimate()
        if cfg.project_after_gossip:
            w_new = obj.project_ball(w_new, cfg.lam)
        return w_new

    return step
