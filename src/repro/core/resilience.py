"""Fault-tolerant Push-Sum — the paper's §5 future work ("resilience to node
failures") made concrete.

Push-Sum's mass-conservation bookkeeping is exactly what makes gossip robust
to *message* loss: when a node's outgoing share is dropped, both the value
AND the weight share vanish together, so every surviving ratio v/w remains
an unbiased convex combination of the initial values. (This is the classical
argument from Kempe et al. 2003 §3.3 — mass is never double-counted.)

The catch — and what this module makes explicit — is that a dropped share
permanently removes its mass from the network, so the *global average
estimate* becomes a weighted average over surviving mass. With self-loop
retention (sender keeps its share when the link fails — "fail-stop link with
acknowledgment"), mass is conserved exactly and the estimate remains the
true average. Both models are implemented:

* ``drop="message"``  — share lost in flight (UDP-style); ratios stay
  consistent, estimate drifts toward surviving mass.
* ``drop="link"``     — sender detects failure and keeps its share
  (TCP/ack-style); exact mass conservation, convergence merely slows by
  the drop rate.

Node *crashes* are permanent outages of all links of a node; the simulator
marks nodes dead and their mass frozen (measured, not hidden).
"""
from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.push_sum import PushSumState
from repro.core import topology as topo

__all__ = ["FaultySim"]


class FaultySim:
    """Matrix-form Push-Sum with per-round random link failures / dead nodes."""

    def __init__(self, n_nodes: int, topology: str = "random", seed: int = 0,
                 drop_prob: float = 0.0,
                 drop: Literal["message", "link"] = "link",
                 dead_nodes: tuple[int, ...] = ()):
        self.n = int(n_nodes)
        self.topology = topology
        self.seed = int(seed)
        self.drop_prob = float(drop_prob)
        self.drop = drop
        self.dead = set(int(d) for d in dead_nodes)

    def matrix(self, t: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, t))
        B = topo.build_matrix(self.topology, self.n,
                              t=t, rng=rng if self.topology == "random" else None)
        B = B.copy()
        # dead nodes: no sends, no receives; their mass freezes on the diagonal
        for d in self.dead:
            B[d, :] = 0.0
            B[:, d] = 0.0
            B[d, d] = 1.0
        # link failures on off-diagonal shares
        fail = rng.random((self.n, self.n)) < self.drop_prob
        np.fill_diagonal(fail, False)
        lost = np.where(fail, B, 0.0)
        B = np.where(fail, 0.0, B)
        if self.drop == "link":
            # sender keeps the undeliverable share: exact mass conservation
            B[np.arange(self.n), np.arange(self.n)] += lost.sum(axis=1)
        # drop == "message": mass vanishes (rows no longer sum to 1)
        return B

    def init(self, values) -> PushSumState:
        return PushSumState(values=values, weight=jnp.ones((self.n,), jnp.float32))

    def round(self, state: PushSumState, t: int) -> PushSumState:
        B = jnp.asarray(self.matrix(t), jnp.float32)

        def mix(v):
            flat = v.reshape(self.n, -1).astype(jnp.float32)
            return (B.T @ flat).reshape(v.shape).astype(v.dtype)

        return PushSumState(values=jax.tree.map(mix, state.values),
                            weight=B.T @ state.weight)

    def run(self, values, n_rounds: int) -> PushSumState:
        st = self.init(values)
        for t in range(n_rounds):
            st = self.round(st, t)
        return st
