"""Fault-tolerant Push-Sum — the paper's §5 future work ("resilience to node
failures") made concrete.

Push-Sum's mass-conservation bookkeeping is exactly what makes gossip robust
to *message* loss: when a node's outgoing share is dropped, both the value
AND the weight share vanish together, so every surviving ratio v/w remains
an unbiased convex combination of the initial values. (This is the classical
argument from Kempe et al. 2003 §3.3 — mass is never double-counted.)

The catch — and what this module makes explicit — is that a dropped share
permanently removes its mass from the network, so the *global average
estimate* becomes a weighted average over surviving mass. With self-loop
retention (sender keeps its share when the link fails — "fail-stop link with
acknowledgment"), mass is conserved exactly and the estimate remains the
true average. Both models are implemented:

* ``drop="message"``  — share lost in flight (UDP-style); ratios stay
  consistent, estimate drifts toward surviving mass.
* ``drop="link"``     — sender detects failure and keeps its share
  (TCP/ack-style); exact mass conservation, convergence merely slows by
  the drop rate.

Node *crashes* are permanent outages of all links of a node; dead nodes are
frozen with their mass on the diagonal (measured, not hidden), and links
*into* a dead node fail like any other (kept by the sender in link mode).

Since the fault layer went device-resident this simulator is a thin host
shell over :mod:`repro.core.faults` — ``matrix(t)`` is ``apply_faults`` on
the clean topology matrix, same PRNG stream, same semantics — so anything
validated here transfers verbatim to the fused training path
(``GadgetConfig(faults=FaultPlan(...))``); tests pin the two matrix
generators against each other at fixed seeds.
"""
from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults as flt
from repro.core import topology as topo
from repro.core.push_sum import PushSumState

__all__ = ["FaultySim"]


class FaultySim:
    """Matrix-form Push-Sum with per-round random link failures / dead nodes.

    A thin host wrapper over the device fault model: ``matrix(t)`` builds the
    clean round-t topology matrix and pushes it through
    :func:`repro.core.faults.apply_faults` under the plan's salted PRNG
    stream — the exact transformation the fused trainer applies on device."""

    def __init__(self, n_nodes: int, topology: str = "random", seed: int = 0,
                 drop_prob: float = 0.0,
                 drop: Literal["message", "link"] = "link",
                 dead_nodes: tuple[int, ...] = ()):
        self.n = int(n_nodes)
        self.topology = topology
        self.seed = int(seed)
        self.plan = flt.validate_plan(
            flt.FaultPlan(drop_prob=drop_prob, drop=drop,
                          dead_nodes=tuple(dead_nodes), seed=seed), self.n)

    @property
    def drop_prob(self) -> float:
        return self.plan.drop_prob

    @property
    def drop(self) -> str:
        return self.plan.drop

    @property
    def dead(self) -> set[int]:
        return set(self.plan.dead_nodes)

    def matrix(self, t: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, t))
        B = topo.build_matrix(self.topology, self.n,
                              t=t, rng=rng if self.topology == "random" else None)
        return flt.faulty_matrix_host(B, self.plan, t)

    def init(self, values) -> PushSumState:
        return PushSumState(values=values, weight=jnp.ones((self.n,), jnp.float32))

    def round(self, state: PushSumState, t: int) -> PushSumState:
        B = jnp.asarray(self.matrix(t), jnp.float32)

        def mix(v):
            flat = v.reshape(self.n, -1).astype(jnp.float32)
            return (B.T @ flat).reshape(v.shape).astype(v.dtype)

        return PushSumState(values=jax.tree.map(mix, state.values),
                            weight=B.T @ state.weight)

    def run(self, values, n_rounds: int) -> PushSumState:
        st = self.init(values)
        for t in range(n_rounds):
            st = self.round(st, t)
        return st
