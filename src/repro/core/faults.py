"""Device-resident fault injection for gossip training — the paper's §5
"resilience to node failures" made a first-class, measured dimension.

A :class:`FaultPlan` is a tiny hashable NamedTuple (it rides inside
``GadgetConfig`` and therefore inside jit cache keys) describing one fault
regime:

* ``drop_prob`` — per-round, per-directed-link iid Bernoulli failure
  probability on every off-diagonal share of the mixing matrix;
* ``drop`` — what a failure means. ``"link"`` is the ack'd/TCP model: the
  sender detects the failure and keeps the undeliverable share on its own
  diagonal, so every row still sums to 1 and Push-Sum mass is conserved
  *exactly*. ``"message"`` is the UDP model: the share vanishes in flight,
  rows sum to < 1 and mass leaks — but because value and weight mass vanish
  *together*, every surviving v/w ratio remains an unbiased convex
  combination of the inputs (Kempe et al. 2003 §3.3);
* ``dead_nodes`` — permanently crashed nodes. A dead node's row collapses to
  e_d (it sends nothing, trains nothing, its mass freezes on its diagonal)
  and every link *into* it fails (in link mode the sender keeps those shares
  — still exact conservation; in message mode they are lost);
* ``seed`` — the fault PRNG stream. Salted so it never collides with the
  data/mixing streams even when the integer seed matches ``cfg.seed``.

Faulty matrices are generated *on device* with ``jax.random`` keyed on
``(seed, iteration t, round r)``: :func:`faulty_rounds` maps a clean
(R, m, m) per-round stack to its faulty counterpart inside the jitted step,
and the result still composes with ``push_sum.collapse_rounds`` — the fused
one-matmul gossip path survives fault injection (the product is simply
folded per-iteration on device, the same pattern the random topology already
uses, instead of precomputed on host).

The host-side :class:`repro.core.resilience.FaultySim` delegates to the same
:func:`apply_faults` so host and device share one fault model bit-for-bit
(pinned by tests/test_resilience.py).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "FaultPlan",
    "DROP_MODES",
    "validate_plan",
    "fault_stream_key",
    "dead_mask",
    "apply_faults",
    "faulty_rounds",
    "faulty_matrix_host",
    "round_fail_key",
    "count_drops",
    "count_drops_node",
]

DROP_MODES = ("link", "message")

# Domain-separation salt for the fault PRNG stream: fold_in'd once onto
# PRNGKey(plan.seed) so a FaultPlan(seed=s) never replays the data or mixing
# draws of a GadgetConfig(seed=s).
_FAULT_SALT = 0x0FA17


class FaultPlan(NamedTuple):
    """One fault regime for gossip training. Hashable (rides in
    ``GadgetConfig`` and keys jit caches — note this means the fault *seed*
    is baked into the compiled step, unlike ``cfg.seed``); validate/normalize
    with :func:`validate_plan` before use."""

    drop_prob: float = 0.0       # per-round per-link Bernoulli failure prob
    drop: str = "link"           # "link" (sender keeps) | "message" (lost)
    dead_nodes: tuple[int, ...] = ()  # permanently crashed node ids
    seed: int = 0                # fault PRNG stream (salted, see module doc)


def validate_plan(plan: FaultPlan, m: int) -> FaultPlan:
    """Check a plan against an m-node network and return it normalized
    (canonical sorted-unique dead tuple, plain python scalars) so equal plans
    hash equal and share compiled executables."""
    if plan.drop not in DROP_MODES:
        raise ValueError(f"unknown drop mode {plan.drop!r}; expected one of {DROP_MODES}")
    p = float(plan.drop_prob)
    if not (0.0 <= p < 1.0):
        raise ValueError(f"drop_prob must lie in [0, 1), got {p}")
    dead = tuple(sorted({int(d) for d in plan.dead_nodes}))
    if dead and (dead[0] < 0 or dead[-1] >= m):
        raise ValueError(f"dead_nodes must lie in [0, {m}), got {dead}")
    if len(dead) >= m:
        raise ValueError(f"all {m} nodes dead — nothing left to train")
    return FaultPlan(drop_prob=p, drop=str(plan.drop), dead_nodes=dead,
                     seed=int(plan.seed))


def fault_stream_key(plan: FaultPlan) -> jax.Array:
    """Base PRNG key of the plan's fault stream (salted off the data/mixing
    streams)."""
    return jax.random.fold_in(jax.random.PRNGKey(plan.seed), _FAULT_SALT)


def round_fail_key(plan: FaultPlan, t, r) -> jax.Array:
    """Key of the failure draw at (iteration t, gossip round r) — the single
    derivation the simulator matrices, the host FaultySim and the mesh path's
    per-node fail bits all hang off, so every execution path sees the same
    fault stream."""
    return jax.random.fold_in(jax.random.fold_in(fault_stream_key(plan), t), r)


def dead_mask(plan: FaultPlan, m: int) -> jax.Array:
    """(m,) bool — True on crashed nodes. Built from the static plan tuple,
    constant-folded inside jitted steps."""
    mask = jnp.zeros((m,), bool)
    if plan.dead_nodes:
        mask = mask.at[jnp.asarray(plan.dead_nodes, jnp.int32)].set(True)
    return mask


def apply_faults(B: jax.Array, key: jax.Array, plan: FaultPlan) -> jax.Array:
    """One faulty mixing matrix: dead rows collapse to e_d, then every
    off-diagonal share fails iid Bernoulli(drop_prob) — plus every share into
    a dead node — under ``key``. ``"link"`` returns lost shares to the
    sender's diagonal (rows still sum to 1: exact mass conservation);
    ``"message"`` drops them (rows sum to < 1: measured leakage). Diagonal
    self-shares never fail — a node cannot lose mass to itself."""
    m = B.shape[-1]
    B = B.astype(jnp.float32)
    dead = dead_mask(plan, m)
    eye = jnp.eye(m, dtype=B.dtype)
    B = jnp.where(dead[:, None], eye, B)  # dead sender: mass frozen on diag
    fail = jax.random.bernoulli(key, plan.drop_prob, (m, m))
    fail = (fail | dead[None, :]) & ~jnp.eye(m, dtype=bool)
    lost = jnp.where(fail, B, 0.0)
    B = jnp.where(fail, 0.0, B)
    if plan.drop == "link":
        B = B + eye * jnp.sum(lost, axis=1, keepdims=True)
    return B


def faulty_rounds(Bs: jax.Array, plan: FaultPlan, t) -> jax.Array:
    """Map a clean (R, m, m) per-round stack to its faulty counterpart for
    iteration ``t`` (traced ok), each round drawing its own failure pattern
    from :func:`round_fail_key`. The result feeds ``mix_rounds`` directly or
    ``collapse_rounds`` for the fused one-matmul path."""
    R = Bs.shape[0]
    keys = jax.vmap(lambda r: round_fail_key(plan, t, r))(jnp.arange(R))
    return jax.vmap(lambda B, k: apply_faults(B, k, plan))(Bs, keys)


def count_drops(Bs: jax.Array, plan: FaultPlan, t) -> jax.Array:
    """Number of messages lost to faults at iteration ``t`` (traced ok).

    Replays the exact per-round failure draws of :func:`faulty_rounds` on the
    *clean* (R, m, m) stack and counts only failures that destroy a real
    share: live-sender rows (a dead sender's off-diagonal is already zero)
    whose clean share is nonzero (sparse topologies don't "lose" edges they
    never had). This is the telemetry counter behind the training trace
    ring's ``drops`` series — int32 scalar, zero for an inert plan."""
    R, m = Bs.shape[0], Bs.shape[-1]
    dead = dead_mask(plan, m)
    eye = jnp.eye(m, dtype=bool)

    def one_round(B, key):
        fail = jax.random.bernoulli(key, plan.drop_prob, (m, m))
        fail = (fail | dead[None, :]) & ~eye
        real = fail & ~dead[:, None] & (B != 0)
        return jnp.sum(real.astype(jnp.int32))

    keys = jax.vmap(lambda r: round_fail_key(plan, t, r))(jnp.arange(R))
    return jnp.sum(jax.vmap(one_round)(Bs, keys))


def count_drops_node(Bs: jax.Array, plan: FaultPlan, t) -> jax.Array:
    """Per-node twin of :func:`count_drops`: (m,) int32 of messages each
    node failed to deliver at iteration ``t`` — the same replayed failure
    draws, reduced over each sender's row of the clean mixing stack instead
    of the whole matrix, so the vector sums exactly to the scalar counter.
    Feeds the telemetry ring's per-node fault-drop leaves."""
    R, m = Bs.shape[0], Bs.shape[-1]
    dead = dead_mask(plan, m)
    eye = jnp.eye(m, dtype=bool)

    def one_round(B, key):
        fail = jax.random.bernoulli(key, plan.drop_prob, (m, m))
        fail = (fail | dead[None, :]) & ~eye
        real = fail & ~dead[:, None] & (B != 0)
        return jnp.sum(real.astype(jnp.int32), axis=1)

    keys = jax.vmap(lambda r: round_fail_key(plan, t, r))(jnp.arange(R))
    return jnp.sum(jax.vmap(one_round)(Bs, keys), axis=0)


def faulty_matrix_host(B: np.ndarray, plan: FaultPlan, t: int,
                       r: int = 0) -> np.ndarray:
    """Host-convenience twin of :func:`apply_faults` for a single round:
    numpy in, numpy out, same device code underneath (this IS the device
    fault model, just executed eagerly). Used by ``resilience.FaultySim`` so
    the orphaned host simulator and the training loop share one fault
    model."""
    out = apply_faults(jnp.asarray(B, jnp.float32),
                       round_fail_key(plan, t, r), plan)
    return np.asarray(out)
