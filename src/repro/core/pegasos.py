"""Centralized Pegasos (Shalev-Shwartz, Singer & Srebro 2007).

The paper's "Centralized" baseline (Table 3): primal estimated sub-gradient
solver running on the whole dataset on one node. Mini-batch size k is a free
parameter that does not affect the convergence guarantee.

Implemented as a jax.lax.scan over iterations so the whole solve is one XLA
program; batch indices are drawn with a threefry key folded per step
(deterministic, reproducible).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import svm_objective as obj

__all__ = ["PegasosResult", "pegasos_train", "pegasos_objective_trace"]


class PegasosResult(NamedTuple):
    w: jax.Array           # final weight vector (d,)
    w_avg: jax.Array       # iterate average (the vector Theorem 2 bounds)
    objective: jax.Array   # primal objective trace, (T,) if traced else ()


def _batch_ids(key: jax.Array, n: int, k: int) -> jax.Array:
    return jax.random.randint(key, (k,), 0, n)


def pegasos_train(
    X: jax.Array,
    y: jax.Array,
    lam: float,
    n_iters: int,
    batch_size: int = 1,
    seed: int = 0,
    trace_every: int = 0,
) -> PegasosResult:
    """Run T Pegasos iterations; optionally record the primal objective every
    ``trace_every`` steps (0 = never, cheapest)."""
    n, d = X.shape
    key0 = jax.random.PRNGKey(seed)

    def step(carry, t):
        w, w_sum = carry
        key = jax.random.fold_in(key0, t)
        ids = _batch_ids(key, n, batch_size)
        w = obj.pegasos_update(w, X[ids], y[ids], lam, t.astype(jnp.float32))
        w_sum = w_sum + w
        out = ()
        if trace_every:
            rec = jax.lax.cond(
                (t % trace_every) == 0,
                lambda: obj.primal_objective(w, X, y, lam),
                lambda: jnp.float32(jnp.nan),
            )
            out = rec
        return (w, w_sum), out

    w0 = jnp.zeros((d,), X.dtype)
    (w, w_sum), trace = jax.lax.scan(step, (w0, jnp.zeros_like(w0)), jnp.arange(1, n_iters + 1))
    objective = trace if trace_every else obj.primal_objective(w, X, y, lam)
    return PegasosResult(w=w, w_avg=w_sum / n_iters, objective=objective)


def pegasos_objective_trace(result: PegasosResult) -> jax.Array:
    """Objective trace with NaN (non-recorded) entries dropped."""
    tr = result.objective
    return tr[~jnp.isnan(tr)] if tr.ndim else tr[None]
