"""Primal linear-SVM objective, hinge loss, and Pegasos sub-gradient.

This module is the pure-jnp oracle for ``repro.kernels.hinge_subgrad`` and the
shared math for both the centralized Pegasos baseline and GADGET.

Objective (paper Eq. 1):
    f(w) = (lambda/2) ||w||^2 + (1/N) sum_j max{0, 1 - y_j <w, x_j>}
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "hinge_loss",
    "primal_objective",
    "primal_objective_masked",
    "primal_objective_masked_ell",
    "hinge_subgradient",
    "pegasos_update",
    "project_ball",
    "accuracy",
]


def hinge_loss(w: jax.Array, X: jax.Array, y: jax.Array) -> jax.Array:
    """Mean hinge loss (1/N) sum max(0, 1 - y <w, x>). X: (N, d), y: (N,) in {-1,+1}."""
    margins = y * (X @ w)
    return jnp.mean(jnp.maximum(0.0, 1.0 - margins))


def primal_objective(w: jax.Array, X: jax.Array, y: jax.Array, lam: float) -> jax.Array:
    return 0.5 * lam * jnp.dot(w, w) + hinge_loss(w, X, y)


def primal_objective_masked(w: jax.Array, X: jax.Array, y: jax.Array,
                            lam: float, valid: jax.Array,
                            total: jax.Array) -> jax.Array:
    """Primal objective over the ``valid`` rows of a padded sample matrix.

    Non-uniform GADGET partitions pad every node to the same n_i; padded rows
    carry y=0 and would each contribute a spurious hinge of 1 under the
    unmasked mean. ``total`` is the true sample count (sum of per-node
    n_counts), so for an all-true mask this reduces to ``primal_objective``.
    """
    margins = y * (X @ w)
    hinge = jnp.sum(jnp.where(valid, jnp.maximum(0.0, 1.0 - margins), 0.0)) / total
    return 0.5 * lam * jnp.dot(w, w) + hinge


def primal_objective_masked_ell(w: jax.Array, cols: jax.Array, vals: jax.Array,
                                y: jax.Array, lam: float, valid: jax.Array,
                                total: jax.Array) -> jax.Array:
    """``primal_objective_masked`` over padded-ELL planes (N, k) — margins as
    a gather-dot against w, never materializing dense X. Pad entries
    (col=0, val=0) are inert; pad *rows* are excluded via ``valid``."""
    margins = y * jnp.sum(vals * jnp.take(w, cols, axis=0), axis=-1)
    hinge = jnp.sum(jnp.where(valid, jnp.maximum(0.0, 1.0 - margins), 0.0)) / total
    return 0.5 * lam * jnp.dot(w, w) + hinge


def hinge_subgradient(w: jax.Array, X: jax.Array, y: jax.Array) -> jax.Array:
    """Sub-gradient of the mean hinge loss term only (the paper's L̂ direction
    is the *negative* of this: L̂ = mean over violators of y·x).

    Returns (1/B) sum_{j: margin_j < 1} (-y_j x_j), shape (d,).
    """
    margins = y * (X @ w)
    viol = (margins < 1.0).astype(X.dtype)
    return -(X.T @ (viol * y)) / X.shape[0]


def pegasos_update(w: jax.Array, X: jax.Array, y: jax.Array, lam: float, t: jax.Array) -> jax.Array:
    """One Pegasos step on mini-batch (X, y) at iteration t (1-based):
        alpha_t = 1/(lambda t)
        w <- (1 - lambda alpha_t) w + alpha_t * mean_{violators} y x
    followed by projection onto the 1/sqrt(lambda) ball.
    """
    alpha = 1.0 / (lam * t)
    L_hat = -hinge_subgradient(w, X, y)  # paper's L̂ = mean violator y·x
    w_half = (1.0 - lam * alpha) * w + alpha * L_hat
    return project_ball(w_half, lam)


def project_ball(w: jax.Array, lam: float) -> jax.Array:
    """min{1, (1/sqrt(lam)) / ||w||} * w — Pegasos ball projection (paper steps f/h)."""
    norm = jnp.linalg.norm(w)
    scale = jnp.minimum(1.0, (1.0 / jnp.sqrt(lam)) / jnp.maximum(norm, 1e-30))
    return w * scale


def accuracy(w: jax.Array, X: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.mean((jnp.sign(X @ w) == y).astype(jnp.float32))
