"""Push-Sum / Push-Vector protocol (Kempe, Dobra & Gehrke 2003).

Two execution paths, same semantics:

* **Simulator path** (`PushSumSim`): all n nodes live in one array with a
  leading node axis. One gossip round is the linear map ``x' = B^T x`` applied
  to both the value tensor and the mass weights — the exact matrix form of
  Algorithm 1 in the GADGET paper, usable with *any* mixing matrix (including
  the paper's random-neighbor draws). Runs on a single device; this is the
  path used to validate the paper's claims.

* **Mesh path** (`push_sum_round` / `push_sum_mesh`): each node is one slice of
  a mesh axis inside ``shard_map``; a round is one ``jax.lax.ppermute`` with a
  static time-varying one-peer-exponential hop. Multi-axis meshes (pod × data)
  gossip on one axis per round following ``exponential_schedule`` — a torus
  factorization of the hypercube exchange that maps 1:1 onto ICI links.

Invariant (property-tested): total mass is conserved —
``sum_i v_{t,i} = sum_i v_{0,i}`` and ``sum_i w_{t,i} = n`` for every t; the
ratio v/w at every node converges to the initial network average.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import topology as topo

Pytree = Any

__all__ = [
    "PushSumState",
    "PushSumSim",
    "GossipRound",
    "exponential_schedule",
    "mix_rounds",
    "collapse_rounds",
    "mix_collapsed",
    "push_sum_round",
    "push_sum_mesh",
]


class PushSumState(NamedTuple):
    """Node-local Push-Sum mass: values pytree + scalar weight.

    Simulator path: every leaf carries a leading node axis of size n and
    ``weight`` has shape (n,). Mesh path: leaves are the node's local values
    and ``weight`` is a scalar.
    """

    values: Pytree
    weight: jax.Array

    def estimate(self) -> Pytree:
        """Current average estimate v_{t,i} / w_{t,i} at every node."""
        w = self.weight

        def _div(v):
            return (v / jnp.reshape(w, w.shape + (1,) * (v.ndim - w.ndim)).astype(v.dtype)
                    if w.ndim else v / w.astype(v.dtype))

        return jax.tree.map(_div, self.values)


# ---------------------------------------------------------------------------
# Simulator path (matrix form, any topology)
# ---------------------------------------------------------------------------


class PushSumSim:
    """Matrix-form Push-Sum over n simulated nodes.

    Mixing semantics: B[i, j] is the share of node i's mass pushed to node j,
    so one round applies ``x' = B^T x`` (columns of B^T sum to 1 => mass
    conserved even when B is only column-stochastic, e.g. the paper's random
    one-neighbor protocol).
    """

    def __init__(self, n_nodes: int, topology: str = "exponential", seed: int = 0):
        if topology not in topo.TOPOLOGIES:
            raise ValueError(f"unknown topology {topology!r}")
        self.n = int(n_nodes)
        self.topology = topology
        self.seed = int(seed)

    def matrix(self, t: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, t)) if self.topology == "random" else None
        return topo.build_matrix(self.topology, self.n, t=t, rng=rng)

    def init(self, values: Pytree) -> PushSumState:
        leaves = jax.tree.leaves(values)
        if not leaves or any(l.shape[0] != self.n for l in leaves):
            raise ValueError(f"every leaf needs leading node axis of size {self.n}")
        return PushSumState(values=values, weight=jnp.ones((self.n,), jnp.float32))

    def round(self, state: PushSumState, t: int) -> PushSumState:
        B = jnp.asarray(self.matrix(t), dtype=jnp.float32)  # (n, n)

        def _mix(v):
            flat = v.reshape(self.n, -1).astype(jnp.float32)
            out = B.T @ flat
            return out.reshape(v.shape).astype(v.dtype)

        values = jax.tree.map(_mix, state.values)
        weight = B.T @ state.weight
        return PushSumState(values, weight)

    def run(self, values: Pytree, n_rounds: int, t0: int = 0) -> PushSumState:
        state = self.init(values)
        for t in range(t0, t0 + n_rounds):
            state = self.round(state, t)
        return state

    def rounds_for_error(self, gamma: float) -> int:
        """O(tau_mix * log(1/gamma)) round count from the spectral bound."""
        tau = topo.mixing_time_bound(self.matrix(0))
        if not np.isfinite(tau):
            raise ValueError("disconnected topology: infinite mixing time")
        return max(1, int(np.ceil(tau * np.log(1.0 / gamma))))


def mix_rounds(values: jax.Array, weight: jax.Array, B_rounds: jax.Array):
    """Apply R Push-Sum rounds ``x' = B^T x`` to (n, ...) values and (n,) mass
    weights, entirely on device. ``B_rounds``: (R, n, n) — precomputed stack
    slices for deterministic topologies or fresh ``jax.random`` draws for the
    paper's random protocol. Mass-conserving for any row-stochastic B.
    """

    def body(carry, B):
        v, w = carry
        return (B.T @ v, B.T @ w), None

    (v, w), _ = jax.lax.scan(body, (values, weight), B_rounds)
    return v, w


def collapse_rounds(B_rounds: jax.Array) -> jax.Array:
    """Fold an (R, n, n) round stack into the single matrix P = B_R^T … B_1^T.

    Push-Sum rounds are linear maps, so R sequential rounds collapse exactly:
    ``mix_rounds(v, w, Bs) == (P @ v, P @ w)``. The fold runs R-1 small
    (n, n)×(n, n) products instead of R (n, n)×(n, d) value mixes — the win
    when d ≫ n, and the device-side counterpart of
    :func:`repro.core.topology.build_product_stack` for matrices only known
    inside the jitted step (the paper's random one-neighbor draws).
    """

    def body(P, B):
        return B.T @ P, None

    P0 = jnp.eye(B_rounds.shape[-1], dtype=B_rounds.dtype)
    P, _ = jax.lax.scan(body, P0, B_rounds)
    return P


def mix_collapsed(values: jax.Array, weight: jax.Array, P: jax.Array):
    """Apply a collapsed round product to (n, ...) values and (n,) mass
    weights: one matmul per tensor, replacing the R-round ``mix_rounds`` scan.
    ``P`` comes from :func:`collapse_rounds` or a precomputed
    ``topology.build_product_stack`` slice."""
    return P @ values, P @ weight


# ---------------------------------------------------------------------------
# Mesh path (shard_map + ppermute, one-peer exponential graph per axis)
# ---------------------------------------------------------------------------


class GossipRound(NamedTuple):
    axis: str  # mesh axis the exchange runs on
    hop: int   # ring distance 2^k on that axis


def exponential_schedule(axis_sizes: dict[str, int]) -> list[GossipRound]:
    """Torus factorization of the one-peer exponential exchange.

    For mesh axes {a_1: n_1, a_2: n_2, ...} emit hops 1, 2, ..., n_i/2 on each
    axis in turn: sum_i log2(n_i) rounds total, after which (with
    self_share=0.5) every node holds the exact global average. This is the
    deterministic-gossip analogue of a recursive-doubling all-reduce, but each
    round is one ppermute (one ICI neighbor hop) instead of a blocking
    collective — the property the GADGET protocol is built around.
    """
    rounds: list[GossipRound] = []
    for axis, n in axis_sizes.items():
        if n == 1:
            continue
        if n & (n - 1):
            raise ValueError(f"axis {axis!r} size {n} must be a power of two for the exponential schedule")
        hop = 1
        while hop < n:
            rounds.append(GossipRound(axis=axis, hop=hop))
            hop *= 2
    return rounds


def _ring_perm(n: int, hop: int) -> list[tuple[int, int]]:
    return [(i, (i + hop) % n) for i in range(n)]


def push_sum_round(
    state: PushSumState,
    rnd: GossipRound,
    *,
    self_share: float = 0.5,
    fault: tuple | None = None,
) -> PushSumState:
    """One Push-Sum round inside ``shard_map``: keep ``self_share`` of the
    local mass, ppermute the rest ``hop`` steps along ``rnd.axis``.

    ``fault`` (optional) injects the :mod:`repro.core.faults` model into the
    collective as a masked send: a ``(fail_send, dead, drop)`` triple where
    ``fail_send`` is this shard's scalar bool — its outgoing share this round
    is zeroed before the permute (every shard still executes the ppermute, so
    the collective stays uniform across the mesh); ``drop="link"`` folds the
    undeliverable share back into the local mass (exact conservation),
    ``drop="message"`` loses it. ``dead`` freezes this shard's values and
    weight entirely — a crashed node neither mixes nor accumulates."""
    # jax.lax.axis_size only exists on newer jax; psum of 1 is the portable
    # spelling (constant-folded at trace time, no collective is emitted)
    axis_size = getattr(jax.lax, "axis_size", None)
    n = int(axis_size(rnd.axis) if axis_size is not None
            else jax.lax.psum(1, rnd.axis))
    if n == 1:
        return state
    pairs = _ring_perm(n, rnd.hop)
    send = 1.0 - self_share

    def _shift(x):
        return jax.lax.ppermute(x, rnd.axis, pairs)

    if fault is None:
        def _mix(v):
            v32 = v.astype(jnp.float32)
            return (v32 * self_share + _shift(v32 * send)).astype(v.dtype)

        values = jax.tree.map(_mix, state.values)
        weight = state.weight * self_share + _shift(state.weight * send)
        return PushSumState(values, weight)

    fail_send, dead, drop = fault
    fail_send = fail_send | dead  # dead nodes never deliver
    send_gate = jnp.where(fail_send, 0.0, send)
    # "link": the sender detects the failure and keeps its share; "message":
    # the share is lost in flight (value and weight mass vanish together)
    keep = self_share + (jnp.where(fail_send, send, 0.0) if drop == "link" else 0.0)

    def _mix(v):
        v32 = v.astype(jnp.float32)
        out = v32 * keep + _shift(v32 * send_gate)
        return jnp.where(dead, v32, out).astype(v.dtype)

    values = jax.tree.map(_mix, state.values)
    w = state.weight
    weight = jnp.where(dead, w, w * keep + _shift(w * send_gate))
    return PushSumState(values, weight)


def push_sum_mesh(
    values: Pytree,
    *,
    axis_sizes: dict[str, int],
    n_rounds: int | None = None,
    t0: int = 0,
    self_share: float = 0.5,
    normalize: bool = True,
) -> Pytree:
    """Run Push-Sum rounds inside shard_map and return the per-node estimate.

    ``n_rounds=None`` runs one full exponential schedule (exact averaging).
    Fewer rounds gives the paper's anytime/partial-consensus behaviour; the
    schedule is rotated by ``t0`` so successive optimizer steps continue the
    hop sequence instead of repeating hop=1 forever.
    """
    sched = exponential_schedule(axis_sizes)
    if not sched:
        return values
    total = len(sched) if n_rounds is None else int(n_rounds)
    state = PushSumState(values=values, weight=jnp.float32(1.0))
    for k in range(total):
        state = push_sum_round(state, sched[(t0 + k) % len(sched)], self_share=self_share)
    return state.estimate() if normalize else state.values
