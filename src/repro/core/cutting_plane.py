"""Cutting-plane primal SVM solver (Joachims 2006 "structural formulation").

The paper's Table 4 compares GADGET against SVM-Perf; this is that baseline's
algorithm at reproduction scale: iteratively add the most-violated aggregate
constraint c in {0,1}^n of

    min_w  (lam/2)|w|^2 + xi
    s.t.   forall c: (1/n) w^T sum_i c_i y_i x_i >= (1/n) sum_i c_i - xi

and solve the reduced master problem through its dual — a k-variable QP over
the simplex {alpha >= 0, sum alpha <= 1} with w = (1/lam) A^T alpha — by
projected gradient ascent (k stays small: tens of cuts).

Terminates when the true empirical risk is within ``tol`` of the cutting-
plane lower bound (the certificate from Joachims' analysis).
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

__all__ = ["CuttingPlaneResult", "cutting_plane_svm", "svm_sgd"]


class CuttingPlaneResult(NamedTuple):
    w: np.ndarray
    n_cuts: int
    gap: float
    objective: float


def _project_capped_simplex(alpha: np.ndarray) -> np.ndarray:
    """Project onto {a >= 0, sum a <= 1}."""
    a = np.maximum(alpha, 0.0)
    s = a.sum()
    if s <= 1.0:
        return a
    # euclidean projection onto the simplex (Duchi et al. 2008)
    u = np.sort(a)[::-1]
    css = np.cumsum(u)
    rho = np.nonzero(u * np.arange(1, len(a) + 1) > (css - 1.0))[0][-1]
    theta = (css[rho] - 1.0) / (rho + 1.0)
    return np.maximum(a - theta, 0.0)


def cutting_plane_svm(X: np.ndarray, y: np.ndarray, lam: float,
                      max_cuts: int = 60, tol: float = 1e-3,
                      inner_iters: int = 300) -> CuttingPlaneResult:
    n, d = X.shape
    w = np.zeros(d, dtype=np.float64)
    A: list[np.ndarray] = []
    b: list[float] = []
    gap = np.inf
    for k in range(max_cuts):
        margins = y * (X @ w)
        c = margins < 1.0
        A.append((y[c, None] * X[c]).sum(axis=0) / n)
        b.append(float(c.mean()))

        Am = np.stack(A)           # (k, d)
        bv = np.asarray(b)
        G = Am @ Am.T              # (k, k)
        L = max(np.linalg.eigvalsh(G).max() / lam, 1e-12)
        alpha = np.full(len(b), 1.0 / len(b))
        for _ in range(inner_iters):
            grad = bv - G @ alpha / lam
            alpha = _project_capped_simplex(alpha + grad / L)
        w = Am.T @ alpha / lam

        risk_true = np.maximum(0.0, 1.0 - y * (X @ w)).mean()
        risk_lb = max(0.0, float((bv - Am @ w).max()))
        gap = risk_true - risk_lb
        if gap < tol:
            break
    obj = 0.5 * lam * float(w @ w) + float(np.maximum(0.0, 1.0 - y * (X @ w)).mean())
    return CuttingPlaneResult(w=w.astype(np.float32), n_cuts=len(b), gap=float(gap),
                              objective=obj)


def svm_sgd(X: np.ndarray, y: np.ndarray, lam: float, n_epochs: int = 2,
            seed: int = 0) -> np.ndarray:
    """Bottou's SVM-SGD: one-example SGD on the regularized hinge objective,
    eta_t = 1 / (lam (t + t0)) — the paper's other online baseline."""
    rng = np.random.default_rng(seed)
    n, d = X.shape
    w = np.zeros(d, dtype=np.float64)
    t0 = 1.0 / lam  # standard warm start heuristic
    t = 0
    for _ in range(n_epochs):
        for i in rng.permutation(n):
            t += 1
            eta = 1.0 / (lam * (t + t0))
            margin = y[i] * (X[i] @ w)
            w *= (1.0 - eta * lam)
            if margin < 1.0:
                w += eta * y[i] * X[i]
    return w.astype(np.float32)
