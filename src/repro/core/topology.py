"""Gossip graph topologies and doubly-stochastic mixing matrices.

The GADGET paper assumes an arbitrary communication graph G(V, E) with a
doubly-stochastic transition matrix B (b_ij = 0 when (i, j) is not an edge).
Push-Sum's convergence rate is O(tau_mix * log(1/gamma)) where tau_mix is the
mixing time of the Markov chain defined by B.

On a TPU mesh we replace random one-hop neighbor selection with deterministic
*time-varying one-peer exponential graphs*: at round t every node i sends to
node (i + 2^(t mod log2 n)) mod n. Each round is a single permutation (one
``collective_permute``), the round-averaged chain is doubly stochastic, and the
sequence mixes in exactly log2(n) rounds — provably faster than uniform random
gossip (tau_mix = Theta(log n) with constant ~1).

All builders return dense (n, n) numpy arrays — they are *protocol metadata*,
tiny (n <= 512), and are either consumed by the matrix-form simulator or used
to derive ppermute partner tables for the mesh path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ring_matrix",
    "complete_matrix",
    "torus_matrix",
    "random_neighbor_matrix",
    "random_neighbor_matrix_device",
    "metropolis_matrix",
    "one_peer_exponential_matrix",
    "exponential_partner",
    "exponential_cycle_length",
    "is_doubly_stochastic",
    "mixing_time_bound",
    "matrix_period",
    "build_matrix_stack",
    "product_period",
    "build_product_stack",
    "TOPOLOGIES",
    "DETERMINISTIC_TOPOLOGIES",
]


def _check_n(n: int) -> None:
    if n < 1:
        raise ValueError(f"need at least one node, got n={n}")


def ring_matrix(n: int, self_weight: float = 1.0 / 3.0) -> np.ndarray:
    """Symmetric ring: each node averages with its two ring neighbors."""
    _check_n(n)
    if n == 1:
        return np.ones((1, 1))
    if n == 2:
        return np.full((2, 2), 0.5)
    side = (1.0 - self_weight) / 2.0
    B = np.zeros((n, n))
    idx = np.arange(n)
    B[idx, idx] = self_weight
    B[idx, (idx + 1) % n] = side
    B[idx, (idx - 1) % n] = side
    return B


def complete_matrix(n: int) -> np.ndarray:
    """Uniform gossip on the complete graph: B = 11^T / n (one-shot mixing)."""
    _check_n(n)
    return np.full((n, n), 1.0 / n)


def torus_matrix(n: int, self_weight: float = 0.2) -> np.ndarray:
    """2-D torus (grid with wraparound): each node averages with its four
    lattice neighbors. The grid is r × c with r the largest divisor of n not
    exceeding sqrt(n) — degenerate rows/columns fold duplicate neighbors back
    onto the same entry, so the matrix stays symmetric doubly stochastic for
    every n (an r=1 torus is just the ring).
    """
    _check_n(n)
    if n == 1:
        return np.ones((1, 1))
    r = int(np.sqrt(n))
    while n % r:
        r -= 1
    c = n // r
    share = (1.0 - self_weight) / 4.0
    B = np.zeros((n, n))
    idx = np.arange(n)
    row, col = np.divmod(idx, c)
    for dr, dc in ((1, 0), (-1, 0), (0, 1), (0, -1)):
        j = ((row + dr) % r) * c + (col + dc) % c
        np.add.at(B, (idx, j), share)
    B[idx, idx] += self_weight
    return B


def random_neighbor_matrix(n: int, rng: np.random.Generator, self_share: float = 0.5) -> np.ndarray:
    """The paper's protocol: each node keeps ``self_share`` of its mass and
    pushes the rest to one uniformly-random other node.

    Column-stochastic (mass conserving) but NOT row-stochastic for a single
    draw — which is exactly why Push-Sum carries the weight scalar w_{t,i}.
    In expectation the chain is doubly stochastic.
    """
    _check_n(n)
    if n == 1:
        return np.ones((1, 1))
    B = np.zeros((n, n))
    targets = rng.integers(0, n - 1, size=n)
    targets = targets + (targets >= np.arange(n))  # uniform over others
    B[np.arange(n), np.arange(n)] = self_share
    B[np.arange(n), targets] += 1.0 - self_share
    # Push-Sum semantics: B[i, j] = share of node i's mass sent to node j,
    # mixing update is x_{t+1} = B^T x_t. Columns of B^T sum to 1.
    return B


def metropolis_matrix(adj: np.ndarray) -> np.ndarray:
    """Metropolis-Hastings weights for an arbitrary undirected graph.

    B[i, j] = 1 / (1 + max(deg_i, deg_j)) for edges, diagonal gets the rest.
    Always symmetric doubly stochastic — the textbook choice when node degrees
    are heterogeneous.
    """
    adj = np.asarray(adj, dtype=bool)
    n = adj.shape[0]
    if adj.shape != (n, n):
        raise ValueError("adjacency must be square")
    deg = adj.sum(axis=1)
    B = np.zeros((n, n))
    for i in range(n):
        for j in range(n):
            if i != j and adj[i, j]:
                B[i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
        B[i, i] = 1.0 - B[i].sum()
    return B


def exponential_cycle_length(n: int) -> int:
    """k = ceil(log2 n): hops cycle through 1, 2, ..., 2^(k-1). The single
    source of truth for the one-peer exponential schedule length — both the
    per-round partner map and the stacked-matrix period derive from it."""
    return max(1, int(np.ceil(np.log2(n)))) if n > 1 else 1


def exponential_partner(n: int, t: int) -> np.ndarray:
    """Send-partner of every node at round t of the one-peer exponential graph.

    partner(i, t) = (i + 2^(t mod ceil(log2 n))) mod n.  For power-of-two n the
    sequence of rounds 0..log2(n)-1 realizes a hypercube all-to-all, i.e. exact
    averaging after log2(n) rounds.
    """
    _check_n(n)
    if n == 1:
        return np.zeros(1, dtype=np.int64)
    hop = 1 << (t % exponential_cycle_length(n))
    return (np.arange(n) + hop) % n


def one_peer_exponential_matrix(n: int, t: int, self_share: float = 0.5) -> np.ndarray:
    """Mixing matrix of round t of the deterministic one-peer exponential graph."""
    _check_n(n)
    if n == 1:
        return np.ones((1, 1))
    B = np.zeros((n, n))
    partners = exponential_partner(n, t)
    B[np.arange(n), np.arange(n)] = self_share
    B[np.arange(n), partners] += 1.0 - self_share
    return B


def is_doubly_stochastic(B: np.ndarray, atol: float = 1e-9) -> bool:
    B = np.asarray(B)
    return bool(
        np.all(B >= -atol)
        and np.allclose(B.sum(axis=0), 1.0, atol=atol)
        and np.allclose(B.sum(axis=1), 1.0, atol=atol)
    )


def mixing_time_bound(B: np.ndarray) -> float:
    """tau_mix estimate: 1 / log(1/|lambda_2|) from the second-largest singular
    value of the mixing matrix (= spectral gap bound on Push-Sum error decay)."""
    s = np.linalg.svd(np.asarray(B, dtype=np.float64), compute_uv=False)
    lam2 = s[1] if len(s) > 1 else 0.0
    if lam2 >= 1.0 - 1e-12:
        return float("inf")
    if lam2 <= 0.0:
        return 1.0
    return float(1.0 / np.log(1.0 / lam2))


TOPOLOGIES = ("ring", "complete", "torus", "random", "exponential")

#: topologies whose round-t matrix is a deterministic function of (n, t) — these
#: can be precomputed as a stacked (period, n, n) array and kept device-resident.
DETERMINISTIC_TOPOLOGIES = ("ring", "complete", "torus", "exponential")


def build_matrix(topology: str, n: int, t: int = 0, rng: np.random.Generator | None = None) -> np.ndarray:
    """Round-t mixing matrix for a named topology (simulator path)."""
    if topology == "ring":
        return ring_matrix(n)
    if topology == "complete":
        return complete_matrix(n)
    if topology == "torus":
        return torus_matrix(n)
    if topology == "random":
        rng = rng if rng is not None else np.random.default_rng(t)
        return random_neighbor_matrix(n, rng)
    if topology == "exponential":
        return one_peer_exponential_matrix(n, t)
    raise ValueError(f"unknown topology {topology!r}; expected one of {TOPOLOGIES}")


def matrix_period(topology: str, n: int) -> int:
    """Length of the round-t matrix cycle for a deterministic topology.

    ``exponential`` cycles through hops 1, 2, ..., 2^(k-1) with k = ceil(log2 n);
    the static graphs (ring, clique, torus) have period 1. ``random`` has no
    period — its matrices are drawn fresh each round (on device, see
    :func:`random_neighbor_matrix_device`).
    """
    if topology not in DETERMINISTIC_TOPOLOGIES:
        raise ValueError(f"{topology!r} has no deterministic period")
    return exponential_cycle_length(n) if topology == "exponential" else 1


def build_matrix_stack(topology: str, n: int) -> np.ndarray:
    """Stacked (period, n, n) mixing matrices covering one full cycle of a
    deterministic topology. Upload once, index with ``t % period`` on device —
    no per-round host builds remain in the training loop.
    """
    T = matrix_period(topology, n)
    return np.stack([build_matrix(topology, n, t=t) for t in range(T)]).astype(np.float32)


def product_period(topology: str, n: int, rounds_per_iter: int) -> int:
    """Length of the *per-iteration* collapsed-product cycle.

    Iteration t (1-based) consumes rounds ``(t-1)*R .. (t-1)*R + R-1`` of the
    round-matrix cycle (period T), so its product depends only on the start
    offset ``s_t = ((t-1)*R) mod T`` — which cycles with period T / gcd(T, R).
    For the static graphs (T=1) every iteration shares one product; for the
    exponential graph the cycle is at most T entries, i.e. the uploaded stack
    shrinks by R× relative to storing the R matrices of each iteration.
    """
    if rounds_per_iter < 1:
        raise ValueError(f"need rounds_per_iter >= 1, got {rounds_per_iter}")
    T = matrix_period(topology, n)
    return T // np.gcd(T, rounds_per_iter)


def build_product_stack(topology: str, n: int, rounds_per_iter: int) -> np.ndarray:
    """Stacked (product_period, n, n) collapsed per-iteration mixing products.

    ``mix_rounds`` is linear, so the R sequential Push-Sum rounds of one GADGET
    iteration fold exactly into a single matrix: applying rounds B_1..B_R as
    ``x' = B_R^T … B_1^T x`` equals ``x' = P x`` with ``P = (B_1 ⋯ B_R)^T``.
    Entry k of the stack is the product for start offset ``s = (k*R) mod T``;
    the device loop indexes it with ``(t-1) % product_period``. Products are
    accumulated in float64 and cast once, so the collapsed path carries one
    rounding step where the sequential path carries R.
    """
    R = int(rounds_per_iter)
    T = matrix_period(topology, n)
    singles = build_matrix_stack(topology, n).astype(np.float64)
    period = product_period(topology, n, R)
    out = np.empty((period, n, n), np.float64)
    for k in range(period):
        s = (k * R) % T
        M = np.eye(n)
        for r in range(R):
            M = M @ singles[(s + r) % T]
        out[k] = M.T
    return out.astype(np.float32)


def random_neighbor_matrix_device(key, n: int, self_share: float = 0.5):
    """Device-side draw of the paper's random one-neighbor mixing matrix.

    Same distribution as :func:`random_neighbor_matrix` (each node keeps
    ``self_share``, pushes the rest to one uniformly-random *other* node) but
    generated with ``jax.random`` inside the jitted step, so the training loop
    performs no host draws and no host→device transfers. Row-stochastic, mass
    conserving under the ``x' = B^T x`` update.
    """
    if n == 1:
        return jnp.ones((1, 1), jnp.float32)
    targets = jax.random.randint(key, (n,), 0, n - 1)
    targets = targets + (targets >= jnp.arange(n))  # uniform over others
    return (self_share * jnp.eye(n, dtype=jnp.float32)
            + (1.0 - self_share) * jax.nn.one_hot(targets, n, dtype=jnp.float32))
