"""Pallas kernel package: rglru_scan."""
