"""Oracle for the RG-LRU linear recurrence (re-exported from the model)."""
from repro.models.rglru import rglru_scan_ref  # noqa: F401
import jax.numpy as jnp


def scan_ref(a, b):
    """h_t = a_t h_{t-1} + b_t with h_{-1} = 0. a, b: (B, S, D)."""
    h0 = jnp.zeros_like(a[:, 0])
    return rglru_scan_ref(a, b, h0)
