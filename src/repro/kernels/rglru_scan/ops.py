"""Jitted wrapper for the RG-LRU scan kernel (padding + dtype policy)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rglru_scan.rglru_scan import rglru_scan

__all__ = ["linear_recurrence"]


@functools.partial(jax.jit, static_argnames=("blk_s", "blk_d", "interpret"))
def linear_recurrence(a: jax.Array, b: jax.Array, *, blk_s: int = 256,
                      blk_d: int = 256, interpret: bool = False) -> jax.Array:
    """h_t = a_t h_{t-1} + b_t along axis 1; pads S and D to block multiples.

    Padding with a=1, b=0 on channels is harmless (identity recurrence);
    padded sequence tail is sliced away.
    """
    B, S, D = a.shape
    bs, bd = min(blk_s, S), min(blk_d, D)
    ps, pd = (-S) % bs, (-D) % bd
    if ps or pd:
        a = jnp.pad(a, ((0, 0), (0, ps), (0, pd)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, ps), (0, pd)))
    h = rglru_scan(a, b, blk_s=bs, blk_d=bd, interpret=interpret)
    return h[:, :S, :D]
