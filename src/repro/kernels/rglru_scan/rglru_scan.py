"""Pallas TPU kernel for the RG-LRU linear recurrence h_t = a_t h_{t-1} + b_t.

Grid: (batch, d/blk_d, S/blk_s) with the sequence axis "arbitrary"
(sequential): the carry h lives in VMEM scratch across sequence blocks, and
within a block the recurrence unrolls with a fori_loop over VREG rows. The
channel axis is the lane dimension (blk_d a multiple of 128), so each step is
a pure VPU axpy — this is the TPU-native shape of the GPU "linear scan"
kernels used by Griffin-style models (HBM traffic = one read of a,b + one
write of h; arithmetic intensity ~1 FLOP/byte, i.e. purely memory-bound,
which the roofline table confirms).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels._compat import CompilerParams

__all__ = ["rglru_scan"]


def _kernel(a_ref, b_ref, h_ref, carry, *, blk_s: int):
    sj = pl.program_id(2)

    @pl.when(sj == 0)
    def _():
        carry[...] = jnp.zeros_like(carry)

    a = a_ref[0].astype(jnp.float32)  # (blk_s, blk_d)
    b = b_ref[0].astype(jnp.float32)

    def step(i, h):
        h = a[i] * h + b[i]
        h_ref[0, i, :] = h.astype(h_ref.dtype)
        return h

    carry[...] = jax.lax.fori_loop(0, blk_s, step, carry[...])


def rglru_scan(a: jax.Array, b: jax.Array, *, blk_s: int = 256, blk_d: int = 256,
               interpret: bool = False) -> jax.Array:
    """a, b: (B, S, D) -> h: (B, S, D) with h_t = a_t h_{t-1} + b_t, h_0 = b_0."""
    B, S, D = a.shape
    blk_s, blk_d = min(blk_s, S), min(blk_d, D)
    assert S % blk_s == 0 and D % blk_d == 0, "wrapper must pad"
    kern = functools.partial(_kernel, blk_s=blk_s)
    return pl.pallas_call(
        kern,
        grid=(B, D // blk_d, S // blk_s),
        in_specs=[
            pl.BlockSpec((1, blk_s, blk_d), lambda bb, dd, ss: (bb, ss, dd)),
            pl.BlockSpec((1, blk_s, blk_d), lambda bb, dd, ss: (bb, ss, dd)),
        ],
        out_specs=pl.BlockSpec((1, blk_s, blk_d), lambda bb, dd, ss: (bb, ss, dd)),
        out_shape=jax.ShapeDtypeStruct((B, S, D), a.dtype),
        scratch_shapes=[pltpu.VMEM((blk_d,), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
