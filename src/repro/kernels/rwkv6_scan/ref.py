"""Oracle for the WKV recurrence (re-exported from the model)."""
import jax.numpy as jnp

from repro.models.rwkv6 import wkv_scan_ref  # noqa: F401


def scan_ref(r, k, v, w, u):
    """out only (state discarded); S_0 = 0."""
    B, S, H, n = r.shape
    S0 = jnp.zeros((B, H, n, n), jnp.float32)
    out, _ = wkv_scan_ref(r, k, v, w, u, S0)
    return out
