"""Pallas kernel package: rwkv6_scan."""
