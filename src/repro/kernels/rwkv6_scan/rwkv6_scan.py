"""Pallas TPU kernel for the RWKV-6 WKV recurrence.

Per (batch, head): state S in R^{n x n} (n = head_dim = 64 for the assigned
config; 16 KiB fp32 — comfortably VMEM-resident). Grid:
(batch, heads, S/blk_s) with the time axis "arbitrary"; the state carries in
VMEM scratch across time blocks, and a fori_loop walks the steps inside a
block:

    out_t = r_t (S + diag(u) k_t^T v_t)
    S     = diag(w_t) S + k_t^T v_t

Each step is two rank-1 outer products + one (1 x n) @ (n x n) matvec — VPU
work with the n x n state held in registers/VMEM, never touching HBM. HBM
traffic is one read of r/k/v/w and one write of out: like the RG-LRU scan
this is purely memory-bound, the structural reason RWKV decode beats
attention at long context.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels._compat import CompilerParams

__all__ = ["wkv_scan"]


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, S_scr, *, blk_s: int):
    sj = pl.program_id(2)

    @pl.when(sj == 0)
    def _():
        S_scr[...] = jnp.zeros_like(S_scr)

    r = r_ref[0, :, 0].astype(jnp.float32)  # (blk_s, n)
    k = k_ref[0, :, 0].astype(jnp.float32)
    v = v_ref[0, :, 0].astype(jnp.float32)
    w = w_ref[0, :, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)        # (n,)

    def step(i, S):
        kv = jnp.outer(k[i], v[i])                    # (n, n)
        out = r[i] @ (S + u[:, None] * kv)            # (n,)
        o_ref[0, i, 0, :] = out.astype(o_ref.dtype)
        return w[i][:, None] * S + kv

    S_scr[...] = jax.lax.fori_loop(0, blk_s, step, S_scr[...])


def wkv_scan(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
             u: jax.Array, *, blk_s: int = 128, interpret: bool = False) -> jax.Array:
    """r,k,v,w: (B, S, H, n); u: (H, n). Returns out (B, S, H, n)."""
    B, S, H, n = r.shape
    blk_s = min(blk_s, S)
    assert S % blk_s == 0, "wrapper must pad"
    kern = functools.partial(_kernel, blk_s=blk_s)
    spec = pl.BlockSpec((1, blk_s, 1, n), lambda bb, hh, ss: (bb, ss, hh, 0))
    return pl.pallas_call(
        kern,
        grid=(B, H, S // blk_s),
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((1, n), lambda bb, hh, ss: (hh, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, S, H, n), r.dtype),
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, w, u)
