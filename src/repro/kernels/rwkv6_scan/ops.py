"""Jitted wrapper for the WKV kernel (sequence padding)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6_scan.rwkv6_scan import wkv_scan

__all__ = ["wkv"]


@functools.partial(jax.jit, static_argnames=("blk_s", "interpret"))
def wkv(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array, u: jax.Array,
        *, blk_s: int = 128, interpret: bool = False) -> jax.Array:
    """RWKV-6 wkv. r,k,v,w: (B, S, H, n); u: (H, n). Pads S; w pads with 1
    (identity decay), k/v with 0 (no state update)."""
    B, S, H, n = r.shape
    bs = min(blk_s, S)
    ps = (-S) % bs
    if ps:
        pad = ((0, 0), (0, ps), (0, 0), (0, 0))
        r, k, v = (jnp.pad(x, pad) for x in (r, k, v))
        w = jnp.pad(w, pad, constant_values=1.0)
    out = wkv_scan(r, k, v, w, u, blk_s=bs, interpret=interpret)
    return out[:, :S]
