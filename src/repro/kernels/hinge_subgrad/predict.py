"""Pallas TPU kernels for serving-side SVM prediction (scores + argmax).

Training's hot loop updates w; serving's hot loop *applies* it: scores
S = X @ W^T for a (B, d) query batch against a (C, d) model (C = 1 for the
paper's binary SVMs, C > 1 for the one-vs-rest multiclass extension), then
``argmax_c S[b, c]``. Two kernels, mirroring the training-side pair:

  * ``dense_scores`` — blocked (B, d)·(C, d)^T matmul, grid
    (B/blk_b, d/blk_d), per-query partial scores accumulated in VMEM scratch
    across the d axis; the final d-block writes BOTH the scores tile and the
    argmax labels, so one launch produces everything a serving response
    needs (no separate O(B·C) argmax pass over HBM).
  * ``ell_scores_prefetch`` — the sparse twin for padded-ELL query planes
    (B, k): the *query-side* reuse of the training prefetch machinery. A
    compact touched-block-id map (repro.sparse.formats.block_map over the
    query batch) rides in as a ``PrefetchScalarGridSpec`` scalar operand, the
    W ``index_map`` DMAs exactly one live (C, blk_d) block per program, and
    the in-block gather is the same one-hot contraction as the training
    kernels (``sparse._onehot_gather``): onehot @ W_blk^T gives every query
    entry its per-class weight rows in one MXU pass. Sentinel slots alias
    the all-zero pad block appended after W's last real block and skip the
    contraction under ``pl.when`` — scoring a sparse batch touches
    O(live · C · blk_d) weight lanes instead of O(C · d).

Class-lane convention: C is padded to a 128-lane multiple (``Cp``) by the
ops.py wrapper with all-zero rows; their score is exactly 0, which can exceed
a real class's negative score, so the argmax masks lanes ≥ n_classes to -inf
in-kernel (first-occurrence tie-breaking, matching ``jnp.argmax``). Pad
convention for the ELL planes is unchanged: (col=0, val=0) entries and
all-pad rows are inert — a pad query row scores 0 for every class.
Interpret mode off-TPU as everywhere else in this package.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels._compat import CompilerParams
from repro.kernels.hinge_subgrad.sparse import _onehot_gather

__all__ = ["dense_scores", "ell_scores_prefetch"]


def _argmax_lanes(scores: jax.Array, n_classes: int) -> jax.Array:
    """First-occurrence argmax over the class-lane axis with pad lanes
    (≥ n_classes) masked out — jnp.argmax semantics built from max/min
    reductions only (Mosaic-safe, no 1D argmax lowering needed)."""
    Cp = scores.shape[-1]
    lanes = jax.lax.broadcasted_iota(jnp.int32, scores.shape, scores.ndim - 1)
    masked = jnp.where(lanes < n_classes, scores, -jnp.inf)
    best = jnp.max(masked, axis=-1, keepdims=True)
    return jnp.min(jnp.where(masked == best, lanes, Cp), axis=-1).astype(jnp.int32)


def _dense_scores_kernel(x_ref, w_ref, s_ref, l_ref, acc, *, n_classes):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)

    # (blk_b, blk_d) @ (Cp, blk_d)^T — partial scores for this d block
    acc[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        s_ref[...] = acc[...]
        l_ref[...] = _argmax_lanes(acc[...], n_classes)


def dense_scores(X: jax.Array, W: jax.Array, *, n_classes: int,
                 blk_b: int, blk_d: int,
                 interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """Fused scores-and-argmax: X (B, d) queries against W (Cp, d) class
    weights → (scores (B, Cp) f32, labels (B,) int32). B/d must be block
    multiples and Cp a 128-lane multiple (ops.dense_predict pads); rows of W
    beyond ``n_classes`` must be zero and are excluded from the argmax."""
    B, d = X.shape
    Cp = W.shape[0]
    assert B % blk_b == 0 and d % blk_d == 0 and Cp % 128 == 0, "wrapper must pad"
    kern = functools.partial(_dense_scores_kernel, n_classes=n_classes)
    return pl.pallas_call(
        kern,
        grid=(B // blk_b, d // blk_d),
        in_specs=[
            pl.BlockSpec((blk_b, blk_d), lambda i, j: (i, j)),
            pl.BlockSpec((Cp, blk_d), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((blk_b, Cp), lambda i, j: (i, 0)),
            pl.BlockSpec((blk_b,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Cp), jnp.float32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((blk_b, Cp), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(X, W)


def _ell_scores_prefetch_kernel(bids_ref, cols_ref, vals_ref, w_ref,
                                s_ref, l_ref, acc, *, blk_d, n_d_blocks,
                                n_classes):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)

    bid = bids_ref[j]

    @pl.when(bid < n_d_blocks)  # sentinel slots: DMA aliases the pad block,
    def _():                    # contraction skipped — work tracks live blocks
        B, k = cols_ref.shape
        onehot, v = _onehot_gather(cols_ref[...] - bid * blk_d, vals_ref[...],
                                   blk_d)
        # (B·k, blk_d) @ (Cp, blk_d)^T: per-entry class rows in one MXU pass
        gathered = jax.lax.dot_general(
            onehot, w_ref[...], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc[...] += jnp.sum((v[:, None] * gathered).reshape(B, k, -1), axis=1)

    @pl.when(j == pl.num_programs(0) - 1)
    def _():
        s_ref[...] = acc[...]
        l_ref[...] = _argmax_lanes(acc[...], n_classes)


def ell_scores_prefetch(cols: jax.Array, vals: jax.Array, W: jax.Array,
                        block_ids: jax.Array, *, blk_d: int, n_d_blocks: int,
                        n_classes: int,
                        interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """Touched-block twin of :func:`dense_scores` for one ELL query batch.

    cols/vals: (B, k) padded query planes; ``block_ids``: (n_blocks_max,)
    compact touched-block-id map for the *whole batch* (live ids ascending,
    then the sentinel ``n_d_blocks`` — formats.block_map with m=1). W must
    carry the sentinel's landing pad: (Cp, (n_d_blocks + 1)·blk_d) with the
    last block all-zero. Returns (scores (B, Cp), labels (B,))."""
    B, k = cols.shape
    Cp = W.shape[0]
    assert W.shape[1] == (n_d_blocks + 1) * blk_d, "caller pads W + zero block"
    n_blocks_max = block_ids.shape[0]
    kern = functools.partial(_ell_scores_prefetch_kernel, blk_d=blk_d,
                             n_d_blocks=n_d_blocks, n_classes=n_classes)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_blocks_max,),
        in_specs=[
            pl.BlockSpec((B, k), lambda j, b: (0, 0)),
            pl.BlockSpec((B, k), lambda j, b: (0, 0)),
            pl.BlockSpec((Cp, blk_d), lambda j, b: (0, b[j])),
        ],
        out_specs=[
            pl.BlockSpec((B, Cp), lambda j, b: (0, 0)),
            pl.BlockSpec((B,), lambda j, b: (0,)),
        ],
        scratch_shapes=[pltpu.VMEM((B, Cp), jnp.float32)],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Cp), jnp.float32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
        ],
        compiler_params=CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(block_ids, cols, vals, W)
