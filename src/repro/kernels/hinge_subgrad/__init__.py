"""Pallas kernel package: hinge_subgrad."""
