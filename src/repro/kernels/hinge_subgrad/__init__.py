"""Pallas kernel package: hinge_subgrad.

Dense kernels in ``hinge_subgrad.py`` (blocked margins / grad_update and the
fused ``fleet_half_step``), padded-ELL sparse kernels in ``sparse.py``
(gather-dot margins, scatter-add grad), the serving-side predict family in
``predict.py`` (fused dense scores+argmax and the query-side touched-block
ELL predict), jnp oracles in ``ref.py``, and the padding/dispatch layer in
``ops.py``.
"""
