"""Pallas kernel package: hinge_subgrad.

Dense kernels in ``hinge_subgrad.py`` (blocked margins / grad_update and the
fused ``fleet_half_step``), padded-ELL sparse kernels in ``sparse.py``
(gather-dot margins, scatter-add grad), jnp oracles in ``ref.py``, and the
padding/dispatch layer in ``ops.py``.
"""
