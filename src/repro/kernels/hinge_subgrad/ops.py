"""Jitted public op: fused Pegasos step backed by the Pallas kernels.

Handles padding to block multiples, violator-coefficient computation, the
global-norm ball projection (O(d) in jnp), and the loss scalar.

Also the *dispatch layer* for callers that embed the kernels inside larger
jitted programs (GADGET's device-resident gossip loop): ``local_half_step`` is
jit/vmap/scan-safe (no jit of its own) and ``default_interpret`` picks Pallas
interpret mode automatically off-TPU so CPU CI runs the same code path.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.hinge_subgrad import hinge_subgrad as K

__all__ = ["pegasos_step", "local_half_step", "default_interpret"]


def default_interpret() -> bool:
    """True when the Pallas kernels should run in interpret mode.

    ``REPRO_PALLAS_INTERPRET=0/1`` overrides; otherwise interpret everywhere
    except a real TPU backend, so CPU CI exercises the kernel code path.
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET", "").strip()
    if env:  # set-but-empty falls through to the auto default
        return env.lower() not in ("0", "false", "off", "no")
    return jax.default_backend() != "tpu"


def _project_ball(w: jax.Array, lam: float) -> jax.Array:
    """Pegasos 1/sqrt(lam)-ball projection. Duplicates obj.project_ball on
    purpose: core imports kernels, so kernels cannot import core."""
    norm = jnp.linalg.norm(w)
    scale = jnp.minimum(1.0, (1.0 / jnp.sqrt(lam)) / jnp.maximum(norm, 1e-30))
    return w * scale


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def local_half_step(w: jax.Array, X: jax.Array, y: jax.Array, *, lam: float,
                    t: jax.Array, project: bool = True,
                    blk_b: int = K.DEFAULT_BLK_B, blk_d: int = K.DEFAULT_BLK_D,
                    interpret: bool | None = None) -> jax.Array:
    """GADGET step (e)+(f): kernel-backed Pegasos half-step, no loss scalar.

    Deliberately NOT jitted — it is traced inside the caller's jit (vmapped
    over the node axis, scanned over iterations in the gossip loop). Padded
    rows carry y=0, so they select into the violator set with coefficient 0
    and contribute nothing to the gradient — no validity mask needed.
    """
    B, d = X.shape
    if interpret is None:
        interpret = default_interpret()
    blk_b_, blk_d_ = min(blk_b, B), min(blk_d, d)
    Xp = _pad_to(_pad_to(X.astype(jnp.float32), blk_b_, 0), blk_d_, 1)
    wp = _pad_to(w.astype(jnp.float32), blk_d_, 0)
    yp = _pad_to(y.astype(jnp.float32), blk_b_, 0)

    m = K.margins(Xp, wp, yp, blk_b=blk_b_, blk_d=blk_d_, interpret=interpret)
    coeff = jnp.where(m < 1.0, yp, 0.0)

    tf = jnp.asarray(t, jnp.float32)
    alpha = 1.0 / (lam * tf)
    scal = jnp.stack([lam * alpha, alpha / B])
    w_half = K.grad_update(Xp, wp, coeff, scal, blk_b=blk_b_, blk_d=blk_d_,
                           interpret=interpret)[:d]
    if project:
        w_half = _project_ball(w_half, lam)
    return w_half.astype(w.dtype)


@functools.partial(jax.jit, static_argnames=("lam", "blk_b", "blk_d", "interpret"))
def pegasos_step(w: jax.Array, X: jax.Array, y: jax.Array, *, lam: float,
                 t: jax.Array, blk_b: int = K.DEFAULT_BLK_B,
                 blk_d: int = K.DEFAULT_BLK_D, interpret: bool = False):
    """Kernel-backed equivalent of ref.pegasos_step_ref -> (w_new, loss)."""
    B, d = X.shape
    blk_b_, blk_d_ = min(blk_b, B), min(blk_d, d)
    Xp = _pad_to(_pad_to(X.astype(jnp.float32), blk_b_, 0), blk_d_, 1)
    wp = _pad_to(w.astype(jnp.float32), blk_d_, 0)
    yp = _pad_to(y.astype(jnp.float32), blk_b_, 0)

    m = K.margins(Xp, wp, yp, blk_b=blk_b_, blk_d=blk_d_, interpret=interpret)
    # padded rows have y=0 => margin 0 < 1: mask them out of the violator set
    row_valid = (jnp.arange(Xp.shape[0]) < B)
    viol = (m < 1.0) & row_valid
    coeff = jnp.where(viol, yp, 0.0)
    loss = jnp.sum(jnp.where(row_valid, jnp.maximum(0.0, 1.0 - m), 0.0)) / B

    alpha = 1.0 / (lam * t.astype(jnp.float32))
    scal = jnp.stack([lam * alpha, alpha / B])
    w_half = K.grad_update(Xp, wp, coeff, scal, blk_b=blk_b_, blk_d=blk_d_,
                           interpret=interpret)[:d]
    return _project_ball(w_half, lam).astype(w.dtype), loss
