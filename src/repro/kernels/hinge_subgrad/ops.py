"""Jitted public op: fused Pegasos step backed by the Pallas kernels.

Handles padding to block multiples, violator-coefficient computation, the
global-norm ball projection (O(d) in jnp), and the loss scalar.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.hinge_subgrad import hinge_subgrad as K

__all__ = ["pegasos_step"]


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("lam", "blk_b", "blk_d", "interpret"))
def pegasos_step(w: jax.Array, X: jax.Array, y: jax.Array, *, lam: float,
                 t: jax.Array, blk_b: int = K.DEFAULT_BLK_B,
                 blk_d: int = K.DEFAULT_BLK_D, interpret: bool = False):
    """Kernel-backed equivalent of ref.pegasos_step_ref -> (w_new, loss)."""
    B, d = X.shape
    blk_b_, blk_d_ = min(blk_b, B), min(blk_d, d)
    Xp = _pad_to(_pad_to(X.astype(jnp.float32), blk_b_, 0), blk_d_, 1)
    wp = _pad_to(w.astype(jnp.float32), blk_d_, 0)
    yp = _pad_to(y.astype(jnp.float32), blk_b_, 0)

    m = K.margins(Xp, wp, yp, blk_b=blk_b_, blk_d=blk_d_, interpret=interpret)
    # padded rows have y=0 => margin 0 < 1: mask them out of the violator set
    row_valid = (jnp.arange(Xp.shape[0]) < B)
    viol = (m < 1.0) & row_valid
    coeff = jnp.where(viol, yp, 0.0)
    loss = jnp.sum(jnp.where(row_valid, jnp.maximum(0.0, 1.0 - m), 0.0)) / B

    alpha = 1.0 / (lam * t.astype(jnp.float32))
    scal = jnp.stack([lam * alpha, alpha / B])
    w_half = K.grad_update(Xp, wp, coeff, scal, blk_b=blk_b_, blk_d=blk_d_,
                           interpret=interpret)[:d]
    norm = jnp.linalg.norm(w_half)
    scale = jnp.minimum(1.0, (1.0 / jnp.sqrt(lam)) / jnp.maximum(norm, 1e-30))
    return (w_half * scale).astype(w.dtype), loss
