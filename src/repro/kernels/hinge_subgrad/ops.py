"""Jitted public op: fused Pegasos step backed by the Pallas kernels.

Handles padding to block multiples, violator-coefficient computation, the
global-norm ball projection (O(d) in jnp), and the loss scalar.

Also the *dispatch layer* for callers that embed the kernels inside larger
jitted programs (GADGET's device-resident gossip loop): ``local_half_step``
(one node) and ``fleet_half_step`` (all m nodes, one fused launch) are
jit/vmap/scan-safe (no jit of their own) and ``default_interpret`` picks
Pallas interpret mode automatically off-TPU so CPU CI runs the same code path.
``padded_row_mask`` is the single statement of the padded-row convention all
three wrappers share.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.hinge_subgrad import hinge_subgrad as K
from repro.kernels.hinge_subgrad import predict as P
from repro.kernels.hinge_subgrad import sparse as S
from repro.sparse.formats import DEFAULT_BUCKET_BLK_D
from repro.telemetry import registry as tmr

__all__ = ["pegasos_step", "local_half_step", "fleet_half_step",
           "ell_fleet_half_step", "ell_block_map", "resolve_ell_schedule",
           "dense_predict", "ell_predict", "resolve_block_cap",
           "padded_row_mask", "default_interpret",
           "launch_cost", "record_launch",
           "FLEET_TILE_BUDGET_BYTES", "ELL_ONEHOT_BUDGET",
           "ELL_PREFETCH_BLK_D"]

# Largest per-node (B_pad, d_pad) f32 minibatch tile the fused fleet kernel
# will keep resident in VMEM (per grid program). Above this, fleet_half_step
# falls back to the two-kernel vmapped path, which streams X in blocks.
FLEET_TILE_BUDGET_BYTES = 4 * 1024 * 1024


def default_interpret() -> bool:
    """True when the Pallas kernels should run in interpret mode.

    ``REPRO_PALLAS_INTERPRET=0/1`` overrides; otherwise interpret everywhere
    except a real TPU backend, so CPU CI exercises the kernel code path.
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET", "").strip()
    if env:  # set-but-empty falls through to the auto default
        return env.lower() not in ("0", "false", "off", "no")
    return jax.default_backend() != "tpu"


def launch_cost(kind: str, *, m: int = 1, B: int = 0, d: int = 0, k: int = 0,
                C: int = 1, schedule: str = "sweep", blk_d: int = 0,
                n_blocks_max: int = 0) -> dict:
    """Analytic per-call cost of one Pallas entry point, from shapes alone.

    Returns ``{"launches", "bytes", "flops"}`` (plus ``"blocks_visited"``
    for the block-scheduled sparse kinds) — the single cost model behind the
    registry's ``kernel.*`` series, shared by the dispatch wrappers, the
    training loop's host accounting, the serving engine, and the benches
    (which previously each derived their own). Bytes count f32 data planes
    crossing HBM per launch (int32 column planes count 4 bytes like values);
    FLOPs count multiply-add pairs as 2. These are *model* numbers — the
    roofline/accounting currency, not measured traffic.

    Kinds: ``local_half_step`` (two launches: margins + grad),
    ``fleet_half_step`` (one fused launch, or the 2m-launch vmapped fallback
    above ``FLEET_TILE_BUDGET_BYTES`` — the model applies the same cutover),
    ``ell_fleet_half_step`` (two launches; prefetch visits
    ``m·n_blocks_max`` w blocks, sweep visits every block),
    ``dense_predict`` / ``ell_predict`` (one fused launch each).
    """
    if kind == "local_half_step":
        return {"launches": 2, "bytes": 4 * (2 * B * d + 3 * d + 3 * B),
                "flops": 4 * B * d + 2 * d}
    if kind == "fleet_half_step":
        Bp, dp = -(-B // 8) * 8, -(-d // 128) * 128
        if Bp * dp * 4 > FLEET_TILE_BUDGET_BYTES:  # blocked two-kernel path
            per = launch_cost("local_half_step", B=B, d=d)
            return {key: m * v for key, v in per.items()}
        return {"launches": 1, "bytes": 4 * m * (B * d + 2 * d + 2 * B),
                "flops": m * (4 * B * d + 2 * d)}
    if kind == "ell_fleet_half_step":
        entry_bytes = 16 * m * B * k  # cols+vals, read by both passes
        if schedule == "prefetch":
            blocks = m * n_blocks_max
            w_bytes = 12 * blocks * blk_d + 8 * m * d  # 2R+1W blocks + axpy
        else:
            n_d_blocks = -(-d // max(blk_d, 1))
            blocks = m * n_d_blocks
            w_bytes = 12 * m * n_d_blocks * max(blk_d, 1)
        return {"launches": 2, "bytes": entry_bytes + w_bytes,
                "flops": m * (4 * B * k + 2 * d), "blocks_visited": blocks}
    if kind == "dense_predict":
        return {"launches": 1, "bytes": 4 * (B * d + C * d + B * C + B),
                "flops": 2 * B * C * d}
    if kind == "ell_predict":
        blocks = n_blocks_max
        return {"launches": 1,
                "bytes": 8 * B * k + 4 * (blocks * blk_d * C + B * C + B),
                "flops": 2 * C * B * k, "blocks_visited": blocks}
    raise ValueError(f"unknown kernel kind {kind!r}")


def record_launch(kind: str, n: int = 1, *, registry=None,
                  blocks_visited: float | None = None, **shape) -> dict:
    """Account ``n`` executions of a Pallas entry point on the registry.

    Increments ``kernel.launches`` / ``kernel.bytes`` / ``kernel.flops``
    (and ``kernel.blocks_visited`` for block-scheduled kinds — pass
    ``blocks_visited`` to override the static cap with a measured live
    count), all labeled ``kernel=<kind>``, using :func:`launch_cost` for the
    per-call numbers. Host-side bookkeeping only; returns the per-call cost
    dict. Jitted callers account at their host boundary (the wrappers only
    self-record when executed eagerly — tracing must stay side-effect-free
    so retraces don't double-count)."""
    reg = tmr.default_registry() if registry is None else registry
    cost = launch_cost(kind, **shape)
    reg.counter("kernel.launches", kernel=kind).inc(n * cost["launches"])
    reg.counter("kernel.bytes", kernel=kind).inc(n * cost["bytes"])
    reg.counter("kernel.flops", kernel=kind).inc(n * cost["flops"])
    bv = cost.get("blocks_visited") if blocks_visited is None else blocks_visited
    if bv is not None:
        reg.counter("kernel.blocks_visited", kernel=kind).inc(n * bv)
    return cost


def _maybe_record(kind: str, probe, **shape) -> None:
    """Self-record one eager execution of a dispatch wrapper.

    ``probe`` is any input array: when it is a tracer the wrapper is being
    traced into a caller's jit (the body runs once, not per execution), so
    recording would count compiles, not launches — the caller's host
    boundary accounts instead (``gadget_train`` post-run, the serving
    engine per score call)."""
    if isinstance(probe, jax.core.Tracer):
        return
    record_launch(kind, **shape)


def _project_ball(w: jax.Array, lam: float) -> jax.Array:
    """Pegasos 1/sqrt(lam)-ball projection. Duplicates obj.project_ball on
    purpose: core imports kernels, so kernels cannot import core."""
    norm = jnp.linalg.norm(w)
    scale = jnp.minimum(1.0, (1.0 / jnp.sqrt(lam)) / jnp.maximum(norm, 1e-30))
    return w * scale


def padded_row_mask(n_padded: int, n_valid: int) -> jax.Array:
    """Validity mask for minibatch rows introduced by block padding.

    The single statement of the padded-row invariant all hinge_subgrad
    wrappers share: X/y/w are zero-padded to block multiples, so padded rows
    carry **y = 0**. A padded row therefore selects into the violator set
    (margin 0 < 1) but with coefficient ``1[m<1]·y = 0`` — consumers that only
    need the violator *coefficients* (``local_half_step``) are correct with no
    mask at all. Anything that counts, sums, or re-weights rows — the hinge
    loss in ``pegasos_step``, the explicit coefficient masking in the fused
    fleet kernel — must AND/multiply with this mask instead of re-deriving
    its own convention.
    """
    return jnp.arange(n_padded) < n_valid


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def local_half_step(w: jax.Array, X: jax.Array, y: jax.Array, *, lam: float,
                    t: jax.Array, project: bool = True,
                    blk_b: int = K.DEFAULT_BLK_B, blk_d: int = K.DEFAULT_BLK_D,
                    interpret: bool | None = None) -> jax.Array:
    """GADGET step (e)+(f): kernel-backed Pegasos half-step, no loss scalar.

    Deliberately NOT jitted — it is traced inside the caller's jit (vmapped
    over the node axis, scanned over iterations in the gossip loop). Needs no
    validity mask: per the ``padded_row_mask`` invariant, padded rows carry
    y=0 and so contribute coefficient 0 to the gradient.
    """
    B, d = X.shape
    _maybe_record("local_half_step", X, B=B, d=d)
    if interpret is None:
        interpret = default_interpret()
    blk_b_, blk_d_ = min(blk_b, B), min(blk_d, d)
    Xp = _pad_to(_pad_to(X.astype(jnp.float32), blk_b_, 0), blk_d_, 1)
    wp = _pad_to(w.astype(jnp.float32), blk_d_, 0)
    yp = _pad_to(y.astype(jnp.float32), blk_b_, 0)

    m = K.margins(Xp, wp, yp, blk_b=blk_b_, blk_d=blk_d_, interpret=interpret)
    coeff = jnp.where(m < 1.0, yp, 0.0)

    tf = jnp.asarray(t, jnp.float32)
    alpha = 1.0 / (lam * tf)
    scal = jnp.stack([lam * alpha, alpha / B])
    w_half = K.grad_update(Xp, wp, coeff, scal, blk_b=blk_b_, blk_d=blk_d_,
                           interpret=interpret)[:d]
    if project:
        w_half = _project_ball(w_half, lam)
    return w_half.astype(w.dtype)


def fleet_half_step(W: jax.Array, X: jax.Array, y: jax.Array, *, lam: float,
                    t: jax.Array, project: bool = True,
                    interpret: bool | None = None) -> jax.Array:
    """GADGET steps (a)-(e) for the whole node fleet in ONE kernel launch.

    W: (m, d) per-node weights, X: (m, B, d) gathered minibatch tiles,
    y: (m, B). Replaces ``vmap(local_half_step)`` — the node axis becomes the
    kernel's parallel grid dimension, so one ``pallas_call`` does the work of
    2m launches and each X tile crosses HBM once instead of twice.

    Like ``local_half_step`` this is trace-safe (no jit of its own) for use
    inside the device-resident gossip loop. Tiles larger than
    ``FLEET_TILE_BUDGET_BYTES`` fall back to the blocked two-kernel path,
    which never needs the whole tile resident.
    """
    m, B, d = X.shape
    _maybe_record("fleet_half_step", X, m=m, B=B, d=d)
    if interpret is None:
        interpret = default_interpret()

    Bp = -(-B // 8) * 8        # f32 sublane multiple
    dp = -(-d // 128) * 128    # lane multiple
    if Bp * dp * 4 > FLEET_TILE_BUDGET_BYTES:
        return jax.vmap(
            lambda w, Xi, yi: local_half_step(w, Xi, yi, lam=lam, t=t,
                                              project=project, interpret=interpret)
        )(W, X, y)

    Xp = _pad_to(_pad_to(X.astype(jnp.float32), 8, 1), 128, 2)
    Wp = _pad_to(W.astype(jnp.float32), 128, 1)
    yp = _pad_to(y.astype(jnp.float32), 8, 1)
    mask = padded_row_mask(Bp, B).astype(jnp.float32)

    tf = jnp.asarray(t, jnp.float32)
    alpha = 1.0 / (lam * tf)
    scal = jnp.stack([lam * alpha, alpha / B])
    W_half = K.fleet_half_step(Xp, Wp, yp, mask, scal, interpret=interpret)[:, :d]
    if project:
        W_half = jax.vmap(lambda w: _project_ball(w, lam))(W_half)
    return W_half.astype(W.dtype)


# Cap on the (B·k, blk_d) f32 one-hot each sparse-kernel program materializes
# in VMEM; the wrapper shrinks blk_d (lane-multiple floor) to stay under it.
ELL_ONEHOT_BUDGET = 4 * 1024 * 1024

# Touched-block (scalar-prefetch) schedule block width: the 128-lane minimum,
# single-sourced from the formats layer so host bounds and kernel grids agree.
# Fine blocks over-fetch the least per live block; the sweep schedule makes
# the opposite trade (coarse blocks, short data-oblivious grid).
ELL_PREFETCH_BLK_D = DEFAULT_BUCKET_BLK_D


def _ell_blk_d(d_pad: int, Bk: int) -> int:
    blk = min(S.DEFAULT_BLK_D_SPARSE, d_pad)
    while blk > 128 and Bk * blk * 4 > ELL_ONEHOT_BUDGET:
        # shrink in 128-lane multiples only — Mosaic block shapes require it
        blk = max(128, blk // 2 // 128 * 128)
    return blk


def ell_block_map(cols: jax.Array, vals: jax.Array, *, blk_d: int,
                  n_d_blocks: int, n_blocks_max: int) -> jax.Array:
    """Compact per-node touched-block-id map, on device and trace-safe: the
    twin of ``repro.sparse.formats.block_map`` (tests pin them together).

    cols/vals: (m, B, k) minibatch planes → (m, n_blocks_max) int32 with each
    node's distinct live d-block ids ascending, then the inert sentinel
    ``n_d_blocks``. Pad entries (val = 0) mark nothing. Cost is one O(B·k)
    scatter plus an O(n_d_blocks log n_d_blocks) sort per node — noise next to
    the half-step itself.

    **Caller contract**: ``n_blocks_max`` must be ≥ the realized live count —
    use ``formats.minibatch_block_bound`` (sound for every drawable
    minibatch). Traced code cannot raise, so an undersized cap silently drops
    the highest live block ids (margins and gradients lose their
    contributions); the host twin ``formats.block_map`` raises ``ValueError``
    on the same input and is the debugging tool for suspect schedules.
    """
    m = cols.shape[0]
    blk = jnp.where(vals != 0, cols // blk_d, n_d_blocks).reshape(m, -1)
    touched = jax.vmap(
        lambda b: jnp.zeros((n_d_blocks,), jnp.bool_).at[b].set(True, mode="drop")
    )(blk)
    ids = jnp.where(touched, jnp.arange(n_d_blocks, dtype=jnp.int32)[None, :],
                    n_d_blocks)
    ids = jnp.sort(ids, axis=1).astype(jnp.int32)
    if n_d_blocks < n_blocks_max:  # fewer real blocks than map slots: all live
        pad = jnp.full((m, n_blocks_max - n_d_blocks), n_d_blocks, jnp.int32)
        return jnp.concatenate([ids, pad], axis=1)
    return ids[:, :n_blocks_max]


def resolve_ell_schedule(schedule: str, *, B: int, k: int, d: int,
                         n_blocks_max: int | None = None,
                         blk_d: int | None = None) -> tuple[str, int, int]:
    """Pin an ELL schedule request to concrete ``(schedule, blk_d, n_blocks_max)``.

    ``schedule``: "sweep", "prefetch", or "auto". Auto picks prefetch exactly
    when its worst-case w-lane footprint beats the sweep's —
    ``n_blocks_max · ELL_PREFETCH_BLK_D < d_pad`` — which needs a data-derived
    ``n_blocks_max`` (formats.minibatch_block_bound) to ever fire: the
    structural fallback cap ``min(B·k, n_d_blocks)`` is the no-information
    bound. n_blocks_max is clamped to the structural cap either way.
    """
    if schedule not in ("auto", "prefetch", "sweep"):
        raise ValueError(f"unknown ELL schedule {schedule!r}")
    kp = -(-max(k, 1) // 128) * 128
    Bp = -(-B // 8) * 8
    sweep_blk = _ell_blk_d(-(-d // 128) * 128, Bp * kp)
    if schedule == "sweep":
        return "sweep", (blk_d or sweep_blk), 0
    pref_blk = blk_d or ELL_PREFETCH_BLK_D
    n_d_blocks = -(-d // pref_blk)
    cap = max(1, min(n_blocks_max or B * max(k, 1), B * max(k, 1), n_d_blocks))
    if schedule == "prefetch":
        return "prefetch", pref_blk, cap
    sweep_lanes = (-(-d // sweep_blk)) * sweep_blk
    if cap * pref_blk < sweep_lanes:
        return "prefetch", pref_blk, cap
    return "sweep", sweep_blk, 0


def ell_fleet_half_step(W: jax.Array, cols: jax.Array, vals: jax.Array,
                        y: jax.Array, *, lam: float, t: jax.Array,
                        project: bool = True,
                        interpret: bool | None = None,
                        schedule: str = "auto",
                        n_blocks_max: int | None = None,
                        blk_d: int | None = None) -> jax.Array:
    """Sparse GADGET steps (a)-(e) for the whole fleet over ELL planes.

    W: (m, d) per-node weights; cols/vals: (m, B, k) gathered ELL minibatch
    planes (repro.sparse.formats pad convention: pad entries (col=0, val=0),
    pad rows y=0); y: (m, B). Sparse counterpart of ``fleet_half_step`` — two
    kernel launches (gather-dot margins, scatter-add grad) touching O(B·k)
    feature bytes instead of O(B·d).

    ``schedule`` selects how the kernels walk w's d-blocks:

    * ``"sweep"`` — the data-oblivious grid (m, d/blk_d): every node visits
      every block (the PR 3 one-hot kernels; parity oracle).
    * ``"prefetch"`` — grid (m, n_blocks_max) over the per-minibatch compact
      touched-block-id map (computed here on device, scalar-prefetched into
      the kernels' index_map): each program DMAs one live w block, so cost
      scales with the blocks this minibatch actually touches. ``n_blocks_max``
      is the static grid bound — pass the data-derived cap from
      ``formats.minibatch_block_bound`` (falls back to min(B·k, n_d_blocks),
      correct but saving-free). The grad kernel emits raw per-bucket
      scatter-adds; the Pegasos axpy is folded here as one elementwise decay
      plus a masked scatter (untouched blocks only decay — same math).
    * ``"auto"`` — prefetch iff its worst-case w-lane footprint beats the
      sweep's (see ``resolve_ell_schedule``).

    Trace-safe (no jit of its own) for use inside the device-resident gossip
    loop. Padding: k → 128-lane multiple, B → 8-sublane multiple, d → blk_d
    multiple (+ one all-zero block, the prefetch sentinel's landing pad); all
    pads are inert under the ELL convention.
    """
    m, B, k = cols.shape
    d = W.shape[1]
    if k == 0:  # k_max=0 planes (e.g. all rows empty after bucketing): widen
        cols = jnp.zeros((m, B, 1), jnp.int32)  # to one inert (0, 0) entry so
        vals = jnp.zeros((m, B, 1), jnp.float32)  # block shapes stay nonzero
        k = 1
    if interpret is None:
        interpret = default_interpret()
    schedule, blk_d, n_blocks_max = resolve_ell_schedule(
        schedule, B=B, k=k, d=d, n_blocks_max=n_blocks_max, blk_d=blk_d)
    _maybe_record("ell_fleet_half_step", vals, m=m, B=B, k=k, d=d,
                  schedule=schedule, blk_d=blk_d, n_blocks_max=n_blocks_max)

    colsP = _pad_to(_pad_to(cols.astype(jnp.int32), 8, 1), 128, 2)
    valsP = _pad_to(_pad_to(vals.astype(jnp.float32), 8, 1), 128, 2)
    yp = _pad_to(y.astype(jnp.float32), 8, 1)

    tf = jnp.asarray(t, jnp.float32)
    alpha = 1.0 / (lam * tf)
    scal = jnp.stack([lam * alpha, alpha / B])

    if schedule == "prefetch":
        n_d_blocks = -(-d // blk_d)
        d_pad = n_d_blocks * blk_d
        bids = ell_block_map(colsP, valsP, blk_d=blk_d, n_d_blocks=n_d_blocks,
                             n_blocks_max=n_blocks_max)
        # one extra zero block after the last real one: the sentinel's DMA pad
        Wp = _pad_to(W.astype(jnp.float32), (n_d_blocks + 1) * blk_d, 1)
        margins = S.ell_margins_prefetch(colsP, valsP, Wp, yp, bids,
                                         blk_d=blk_d, n_d_blocks=n_d_blocks,
                                         interpret=interpret)
        coeff = jnp.where(margins < 1.0, yp, 0.0)
        G = S.ell_grad_update_prefetch(colsP, valsP, coeff, bids, blk_d=blk_d,
                                       n_d_blocks=n_d_blocks, interpret=interpret)
        # fold buckets into the axpy: decay everywhere, scatter-add the live
        # buckets (sentinel buckets index past d_pad → dropped, and are zero)
        flat = (bids[:, :, None] * blk_d
                + jnp.arange(blk_d, dtype=jnp.int32)[None, None, :]).reshape(m, -1)
        W_half = jax.vmap(
            lambda w_row, g, fi: ((1.0 - scal[0]) * w_row)
            .at[fi].add(scal[1] * g, mode="drop")
        )(Wp[:, :d_pad], G.reshape(m, -1), flat)[:, :d]
    else:
        Wp = _pad_to(W.astype(jnp.float32), blk_d, 1)
        margins = S.ell_margins(colsP, valsP, Wp, yp, blk_d=blk_d,
                                interpret=interpret)
        # pad rows carry y=0 ⇒ coefficient 0 (padded_row_mask invariant):
        # inert in the scatter though their margin 0 selects as a violator
        coeff = jnp.where(margins < 1.0, yp, 0.0)
        W_half = S.ell_grad_update(colsP, valsP, Wp, coeff, scal, blk_d=blk_d,
                                   interpret=interpret)[:, :d]
    if project:
        W_half = jax.vmap(lambda w: _project_ball(w, lam))(W_half)
    return W_half.astype(W.dtype)


# ------------------------------------------------------------------- predict
# Serving-side dispatch (repro.serve): scores + argmax against a trained
# model. ``W`` is either the binary (d,) weight vector or a one-vs-rest
# (C, d) class matrix; both wrappers are trace-safe (no jit of their own) so
# the serving engine and the shard_map batch-parallel path jit them once per
# bucket shape.


def _as_class_matrix(W: jax.Array) -> tuple[jax.Array, bool]:
    W = jnp.asarray(W)
    if W.ndim == 1:
        return W[None, :], True
    if W.ndim != 2:
        raise ValueError(f"W must be (d,) or (C, d), got shape {W.shape}")
    return W, False


def _finish_predict(scores, labels, B, C, binary):
    scores, labels = scores[:B, :C], labels[:B]
    if binary:
        s = scores[:, 0]
        return s, jnp.where(s >= 0.0, 1.0, -1.0)
    return scores, labels


def resolve_block_cap(B: int, k: int, *, n_d_blocks: int,
                      n_blocks_max: int | None = None) -> int:
    """The one statement of the touched-block map width: the requested cap
    (or the no-information ``B·k``) clamped to the structural limits. The
    serving engine's jit-cache key and host-side map width must agree with
    ``ell_predict``'s internal computation — both call this."""
    return max(1, min(n_blocks_max or B * k, B * k, n_d_blocks))


def dense_predict(W: jax.Array, X: jax.Array, *,
                  interpret: bool | None = None,
                  blk_b: int = K.DEFAULT_BLK_B,
                  blk_d: int = K.DEFAULT_BLK_D) -> tuple[jax.Array, jax.Array]:
    """Fused serving scores-and-argmax in one kernel launch.

    W: (d,) binary weights or (C, d) one-vs-rest class matrix; X: (B, d)
    query batch. Returns ``(scores, labels)``: binary → ((B,) margins,
    (B,) f32 sign labels in {-1, +1}); multiclass → ((B, C) scores,
    (B,) int32 argmax). Pads B to a sublane multiple, d to blk_d, C to a
    128-lane multiple (zero class rows, masked out of the in-kernel argmax).
    """
    W2, binary = _as_class_matrix(W)
    C, d = W2.shape
    B = X.shape[0]
    _maybe_record("dense_predict", X, B=B, d=d, C=C)
    if interpret is None:
        interpret = default_interpret()
    blk_b_ = min(blk_b, -(-B // 8) * 8)
    blk_d_ = min(blk_d, -(-d // 128) * 128)
    Xp = _pad_to(_pad_to(X.astype(jnp.float32), blk_b_, 0), blk_d_, 1)
    Wp = _pad_to(_pad_to(W2.astype(jnp.float32), 128, 0), blk_d_, 1)
    scores, labels = P.dense_scores(Xp, Wp, n_classes=C, blk_b=blk_b_,
                                    blk_d=blk_d_, interpret=interpret)
    return _finish_predict(scores, labels, B, C, binary)


def ell_predict(W: jax.Array, cols: jax.Array, vals: jax.Array, *,
                n_blocks_max: int | None = None,
                blk_d: int | None = None,
                block_ids: jax.Array | None = None,
                interpret: bool | None = None) -> tuple[jax.Array, jax.Array]:
    """Sparse serving scores-and-argmax over one padded-ELL query batch.

    cols/vals: (B, k) query planes (formats pad convention: (col=0, val=0)
    entries and all-pad rows are inert — a pad row scores 0 every class and
    labels +1/class 0). The *query-side* touched-block schedule: the batch's
    compact touched-block-id map steers the W DMA so scoring touches only
    live d-blocks — O(live·C·blk_d) weight lanes instead of O(C·d).

    ``n_blocks_max`` is the static grid cap — per-bucket in the serving
    engine (one compile per bucket shape), from
    ``formats.minibatch_block_bound`` over the query set; defaults to the
    structural ``min(B·k, n_d_blocks)``. ``block_ids`` optionally supplies a
    host-computed map (``formats.block_map`` with m=1, shape
    (n_blocks_max,)); by default the map is computed on device
    (``ell_block_map``), keeping the wrapper trace-safe. Returns
    ``(scores, labels)`` with the same shapes/dtypes as ``dense_predict``.
    """
    W2, binary = _as_class_matrix(W)
    C, d = W2.shape
    B, k = cols.shape
    if k == 0:  # all-empty batch: widen to one inert entry (shapes nonzero)
        cols = jnp.zeros((B, 1), jnp.int32)
        vals = jnp.zeros((B, 1), jnp.float32)
        k = 1
    if interpret is None:
        interpret = default_interpret()
    blk_d = blk_d or ELL_PREFETCH_BLK_D
    n_d_blocks = -(-d // blk_d)

    colsP = _pad_to(_pad_to(cols.astype(jnp.int32), 8, 0), 128, 1)
    valsP = _pad_to(_pad_to(vals.astype(jnp.float32), 8, 0), 128, 1)
    if block_ids is not None:
        bids = jnp.asarray(block_ids, jnp.int32)
    else:
        cap = resolve_block_cap(B, k, n_d_blocks=n_d_blocks,
                                n_blocks_max=n_blocks_max)
        bids = ell_block_map(colsP[None], valsP[None], blk_d=blk_d,
                             n_d_blocks=n_d_blocks, n_blocks_max=cap)[0]
    _maybe_record("ell_predict", vals, B=B, k=k, C=C, blk_d=blk_d,
                  n_blocks_max=int(bids.shape[0]))
    # one extra zero block after the last real one: the sentinel's DMA pad
    Wp = _pad_to(_pad_to(W2.astype(jnp.float32), 128, 0),
                 (n_d_blocks + 1) * blk_d, 1)
    scores, labels = P.ell_scores_prefetch(colsP, valsP, Wp, bids,
                                           blk_d=blk_d, n_d_blocks=n_d_blocks,
                                           n_classes=C, interpret=interpret)
    return _finish_predict(scores, labels, B, C, binary)


@functools.partial(jax.jit, static_argnames=("lam", "blk_b", "blk_d", "interpret"))
def pegasos_step(w: jax.Array, X: jax.Array, y: jax.Array, *, lam: float,
                 t: jax.Array, blk_b: int = K.DEFAULT_BLK_B,
                 blk_d: int = K.DEFAULT_BLK_D, interpret: bool = False):
    """Kernel-backed equivalent of ref.pegasos_step_ref -> (w_new, loss)."""
    B, d = X.shape
    blk_b_, blk_d_ = min(blk_b, B), min(blk_d, d)
    Xp = _pad_to(_pad_to(X.astype(jnp.float32), blk_b_, 0), blk_d_, 1)
    wp = _pad_to(w.astype(jnp.float32), blk_d_, 0)
    yp = _pad_to(y.astype(jnp.float32), blk_b_, 0)

    m = K.margins(Xp, wp, yp, blk_b=blk_b_, blk_d=blk_d_, interpret=interpret)
    # the loss sums rows, so it needs the shared padded-row mask (see
    # padded_row_mask: y=0 padding alone only protects the coefficients)
    row_valid = padded_row_mask(Xp.shape[0], B)
    viol = (m < 1.0) & row_valid
    coeff = jnp.where(viol, yp, 0.0)
    loss = jnp.sum(jnp.where(row_valid, jnp.maximum(0.0, 1.0 - m), 0.0)) / B

    alpha = 1.0 / (lam * t.astype(jnp.float32))
    scal = jnp.stack([lam * alpha, alpha / B])
    w_half = K.grad_update(Xp, wp, coeff, scal, blk_b=blk_b_, blk_d=blk_d_,
                           interpret=interpret)[:d]
    return _project_ball(w_half, lam).astype(w.dtype), loss
