"""Pallas TPU kernels for the hinge-subgradient step over padded-ELL planes.

Sparse counterpart of ``hinge_subgrad.py``: the minibatch is two (m, B, k)
planes — column indices and values — instead of an (m, B, d) dense tile, so
at CCAT sparsity (0.16%) the per-iteration bytes drop ~600×. The dense weight
vector w stays resident; only the feature matrix is sparse (mixing/Push-Sum
are over weights and never see the ELL planes).

Both kernels run over grid (m, d/blk_d) and express the irregular access as
an on-the-fly one-hot contraction against the current d-block — the
MXU-friendly form of gather/scatter on TPU (compare iota, then matmul):

  * ``ell_margins``    — margins m_b = y_b · Σ_k vals[b,k] · w[cols[b,k]].
    Per d-block: one-hot(cols - block_base) @ w_blk gathers the in-block
    weight entries (out-of-block indices match no lane and contribute 0 — no
    explicit mask needed), accumulated over blocks in VMEM scratch.
  * ``ell_grad_update`` — the scatter-add g += Σ_b coeff_b · vals[b,:] onto
    the violator columns, fused with the Pegasos axpy
    w_half = (1 - lam·alpha) w + (alpha/B) g. Each d-block owns its output
    slice, so the grid is embarrassingly parallel — no cross-block scratch.

Pad convention (repro.sparse.formats.ELL): pad entries carry (col=0, val=0),
pad *rows* carry y=0 — both are inert in the contraction, so the kernels take
no validity plane. VMEM per program is the (B·k, blk_d) one-hot plus the
planes: callers bound B·k·blk_d (ops.ell_fleet_half_step picks blk_d).
Interpret mode off-TPU as everywhere else in this package.

Two schedules per op:

  * **sweep** (``ell_margins`` / ``ell_grad_update``) — grid (m, d/blk_d):
    every node walks *all* d-blocks every launch. Data-oblivious, and the
    parity oracle for the schedule below.
  * **touched-block** (``ell_margins_prefetch`` / ``ell_grad_update_prefetch``)
    — grid (m, n_blocks_max) over a compact per-node touched-block-id map
    (repro.sparse.formats.block_map; ops.ell_block_map is the on-device twin).
    The map rides in as a ``PrefetchScalarGridSpec`` scalar-prefetch operand so
    the ``index_map`` can steer each program's DMA to exactly one *live* w
    block. Empty slots carry the sentinel id ``n_d_blocks`` and alias the
    all-zero pad block appended after w's last real block — inert on read, and
    ``pl.when`` skips their contraction so FLOPs track live blocks too.
    Sentinel slots are contiguous at the map's tail (the map is sorted), so
    Mosaic's revisit logic collapses their DMAs into one. Per-node cost
    becomes O(touched · B·k·blk_d) instead of O(B·k·d) — proportional to the
    node's own nonzero structure, which is the GADGET paper's per-node-local
    cost model.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels._compat import CompilerParams

__all__ = ["ell_margins", "ell_grad_update", "ell_margins_prefetch",
           "ell_grad_update_prefetch", "DEFAULT_BLK_D_SPARSE"]

DEFAULT_BLK_D_SPARSE = 512


def _onehot_gather(cols, vals, blk_d: int):
    """(B, k) in-block entry selectors: returns the (B·k, blk_d) one-hot and
    the flattened (B·k,) values. ``cols`` are already rebased to the block."""
    Bk = cols.shape[0] * cols.shape[1]
    local = cols.reshape(Bk, 1)
    lanes = jax.lax.broadcasted_iota(jnp.int32, (Bk, blk_d), 1)
    onehot = (local == lanes).astype(jnp.float32)  # out-of-block rows: all 0
    return onehot, vals.reshape(Bk)


def _ell_margins_kernel(cols_ref, vals_ref, w_ref, y_ref, m_ref, acc, *, blk_d):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)

    B, k = cols_ref.shape[1], cols_ref.shape[2]
    onehot, v = _onehot_gather(cols_ref[0] - j * blk_d, vals_ref[0], blk_d)
    gathered = onehot @ w_ref[0]                      # (B·k,) w[cols] | in-block
    acc[...] += jnp.sum((v * gathered).reshape(B, k), axis=1)

    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        m_ref[0] = y_ref[0] * acc[...]


def ell_margins(cols: jax.Array, vals: jax.Array, W: jax.Array, y: jax.Array, *,
                blk_d: int = DEFAULT_BLK_D_SPARSE,
                interpret: bool = False) -> jax.Array:
    """y * (X @ w) per node over ELL planes. cols/vals: (m, B, k) int32/f32,
    W: (m, d), y: (m, B) → (m, B) margins. d must be a blk_d multiple."""
    m, B, k = cols.shape
    d = W.shape[1]
    assert d % blk_d == 0, "wrapper must pad d"
    kern = functools.partial(_ell_margins_kernel, blk_d=blk_d)
    return pl.pallas_call(
        kern,
        grid=(m, d // blk_d),
        in_specs=[
            pl.BlockSpec((1, B, k), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, B, k), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, blk_d), lambda i, j: (i, j)),
            pl.BlockSpec((1, B), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, B), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, B), jnp.float32),
        scratch_shapes=[pltpu.VMEM((B,), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(cols, vals, W, y)


def _ell_grad_kernel(cols_ref, vals_ref, w_ref, c_ref, scal_ref, o_ref, *, blk_d):
    j = pl.program_id(1)
    coeff = c_ref[0]                                   # (B,) violator coeffs
    onehot, v = _onehot_gather(cols_ref[0] - j * blk_d, vals_ref[0], blk_d)
    contrib = (coeff[:, None] * vals_ref[0]).reshape(v.shape)
    g = contrib @ onehot                               # (blk_d,) scatter-add
    o_ref[0] = (1.0 - scal_ref[0]) * w_ref[0] + scal_ref[1] * g


def ell_grad_update(cols: jax.Array, vals: jax.Array, W: jax.Array,
                    coeff: jax.Array, scal: jax.Array, *,
                    blk_d: int = DEFAULT_BLK_D_SPARSE,
                    interpret: bool = False) -> jax.Array:
    """W_half = (1 - scal[0]) W + scal[1] * scatter(coeff · vals → cols), per
    node. coeff: (m, B) = 1[margin<1]·y; scal: (2,) = [lam·alpha, alpha/B] in
    SMEM. Each (node, d-block) program writes its own output slice."""
    m, B, k = cols.shape
    d = W.shape[1]
    assert d % blk_d == 0, "wrapper must pad d"
    kern = functools.partial(_ell_grad_kernel, blk_d=blk_d)
    return pl.pallas_call(
        kern,
        grid=(m, d // blk_d),
        in_specs=[
            pl.BlockSpec((1, B, k), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, B, k), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, blk_d), lambda i, j: (i, j)),
            pl.BlockSpec((1, B), lambda i, j: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, blk_d), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(cols, vals, W, coeff, scal)


# ---------------------------------------------------------------------------
# Touched-block schedule (scalar-prefetch): grid (m, n_blocks_max)
# ---------------------------------------------------------------------------


def _ell_margins_prefetch_kernel(bids_ref, cols_ref, vals_ref, w_ref, y_ref,
                                 m_ref, acc, *, blk_d, n_d_blocks):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)

    bid = bids_ref[i, j]

    @pl.when(bid < n_d_blocks)  # sentinel slots: DMA aliases the pad block,
    def _():                    # contraction skipped — FLOPs track live blocks
        B, k = cols_ref.shape[1], cols_ref.shape[2]
        onehot, v = _onehot_gather(cols_ref[0] - bid * blk_d, vals_ref[0], blk_d)
        gathered = onehot @ w_ref[0]
        acc[...] += jnp.sum((v * gathered).reshape(B, k), axis=1)

    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        m_ref[0] = y_ref[0] * acc[...]


def ell_margins_prefetch(cols: jax.Array, vals: jax.Array, W: jax.Array,
                         y: jax.Array, block_ids: jax.Array, *, blk_d: int,
                         n_d_blocks: int, interpret: bool = False) -> jax.Array:
    """Touched-block twin of :func:`ell_margins`.

    ``block_ids``: (m, n_blocks_max) compact touched-block-id map (live ids
    ascending, then the sentinel ``n_d_blocks``), scalar-prefetched so the
    w ``index_map`` DMAs exactly the one live block each program contracts
    against. W must carry the sentinel's landing pad: shape
    (m, (n_d_blocks + 1)·blk_d) with the last block all-zero."""
    m, B, k = cols.shape
    assert W.shape[1] == (n_d_blocks + 1) * blk_d, "caller pads W + zero block"
    n_blocks_max = block_ids.shape[1]
    kern = functools.partial(_ell_margins_prefetch_kernel, blk_d=blk_d,
                             n_d_blocks=n_d_blocks)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m, n_blocks_max),
        in_specs=[
            pl.BlockSpec((1, B, k), lambda i, j, b: (i, 0, 0)),
            pl.BlockSpec((1, B, k), lambda i, j, b: (i, 0, 0)),
            pl.BlockSpec((1, blk_d), lambda i, j, b: (i, b[i, j])),
            pl.BlockSpec((1, B), lambda i, j, b: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, B), lambda i, j, b: (i, 0)),
        scratch_shapes=[pltpu.VMEM((B,), jnp.float32)],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, B), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(block_ids, cols, vals, W, y)


def _ell_grad_prefetch_kernel(bids_ref, cols_ref, vals_ref, c_ref, g_ref, *,
                              blk_d, n_d_blocks):
    i, j = pl.program_id(0), pl.program_id(1)
    bid = bids_ref[i, j]
    g_ref[0, 0] = jnp.zeros_like(g_ref[0, 0])

    @pl.when(bid < n_d_blocks)
    def _():
        onehot, v = _onehot_gather(cols_ref[0] - bid * blk_d, vals_ref[0], blk_d)
        contrib = (c_ref[0][:, None] * vals_ref[0]).reshape(v.shape)
        g_ref[0, 0] = contrib @ onehot


def ell_grad_update_prefetch(cols: jax.Array, vals: jax.Array,
                             coeff: jax.Array, block_ids: jax.Array, *,
                             blk_d: int, n_d_blocks: int,
                             interpret: bool = False) -> jax.Array:
    """Touched-block twin of :func:`ell_grad_update`'s scatter phase.

    Returns the raw per-bucket scatter-adds g — (m, n_blocks_max, blk_d),
    bucket j of node i holding Σ_b coeff_b · vals[b, :] over the entries in
    d-block ``block_ids[i, j]`` (sentinel buckets are zeros). Unlike the sweep
    kernel it neither reads w nor applies the Pegasos axpy: untouched blocks
    still need the (1 − λα) decay, so the wrapper folds the buckets into the
    decayed weights with one masked scatter — see ops.ell_fleet_half_step."""
    m, B, k = cols.shape
    n_blocks_max = block_ids.shape[1]
    kern = functools.partial(_ell_grad_prefetch_kernel, blk_d=blk_d,
                             n_d_blocks=n_d_blocks)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m, n_blocks_max),
        in_specs=[
            pl.BlockSpec((1, B, k), lambda i, j, b: (i, 0, 0)),
            pl.BlockSpec((1, B, k), lambda i, j, b: (i, 0, 0)),
            pl.BlockSpec((1, B), lambda i, j, b: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, blk_d), lambda i, j, b: (i, j, 0)),
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n_blocks_max, blk_d), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(block_ids, cols, vals, coeff)
