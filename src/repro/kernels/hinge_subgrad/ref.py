"""Pure-jnp oracle for the fused Pegasos hinge-subgradient step.

Matches repro.core.svm_objective.pegasos_update exactly (same math, one
function) — the kernel is the paper's per-iteration compute hot-spot:
margins = X w;  L = X^T (1[margin<1] * y) / B;
w' = (1 - lam*alpha) w + alpha L;  project to the 1/sqrt(lam) ball.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def half_step_ref(w: jax.Array, X: jax.Array, y: jax.Array, lam: float, t: jax.Array,
                  project: bool = True) -> jax.Array:
    """Oracle for ops.local_half_step: Pegasos half-step, optional projection,
    no loss scalar — the per-node body of GADGET's device-resident loop."""
    margins = y * (X @ w)
    viol = (margins < 1.0).astype(X.dtype)
    L = (X.T @ (viol * y)) / X.shape[0]
    alpha = 1.0 / (lam * t)
    w_half = (1.0 - lam * alpha) * w + alpha * L
    if project:
        norm = jnp.linalg.norm(w_half)
        scale = jnp.minimum(1.0, (1.0 / jnp.sqrt(lam)) / jnp.maximum(norm, 1e-30))
        w_half = w_half * scale
    return w_half


def fleet_half_step_ref(W: jax.Array, X: jax.Array, y: jax.Array, lam: float,
                        t: jax.Array, project: bool = True) -> jax.Array:
    """Oracle for the fused fleet kernel: steps (a)-(e) for all m nodes at
    once. X: (m, B, d) minibatch tiles, W: (m, d), y: (m, B) with padded rows
    carrying y=0. Same per-node math as half_step_ref, batched over the node
    axis — this is also the fused jnp path GADGET uses where the Pallas
    kernels would only interpret (CPU)."""
    B = X.shape[1]
    margins = y * jnp.einsum("mbd,md->mb", X, W)
    coeff = jnp.where(margins < 1.0, y, 0.0)
    L = jnp.einsum("mb,mbd->md", coeff, X) / B
    alpha = 1.0 / (lam * t)
    W_half = (1.0 - lam * alpha) * W + alpha * L
    if project:
        norms = jnp.linalg.norm(W_half, axis=1, keepdims=True)
        scale = jnp.minimum(1.0, (1.0 / jnp.sqrt(lam)) / jnp.maximum(norms, 1e-30))
        W_half = W_half * scale
    return W_half


def pegasos_step_ref(w: jax.Array, X: jax.Array, y: jax.Array, lam: float, t: jax.Array):
    """Returns (w_new (d,), mean_hinge_loss ()). X: (B, d); y: (B,) in {-1,+1}."""
    margins = y * (X @ w)
    viol = (margins < 1.0).astype(X.dtype)
    L = (X.T @ (viol * y)) / X.shape[0]
    alpha = 1.0 / (lam * t)
    w_half = (1.0 - lam * alpha) * w + alpha * L
    norm = jnp.linalg.norm(w_half)
    scale = jnp.minimum(1.0, (1.0 / jnp.sqrt(lam)) / jnp.maximum(norm, 1e-30))
    loss = jnp.mean(jnp.maximum(0.0, 1.0 - margins))
    return w_half * scale, loss
