"""Pure-jnp oracle for the fused Pegasos hinge-subgradient step.

Matches repro.core.svm_objective.pegasos_update exactly (same math, one
function) — the kernel is the paper's per-iteration compute hot-spot:
margins = X w;  L = X^T (1[margin<1] * y) / B;
w' = (1 - lam*alpha) w + alpha L;  project to the 1/sqrt(lam) ball.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def half_step_ref(w: jax.Array, X: jax.Array, y: jax.Array, lam: float, t: jax.Array,
                  project: bool = True) -> jax.Array:
    """Oracle for ops.local_half_step: Pegasos half-step, optional projection,
    no loss scalar — the per-node body of GADGET's device-resident loop."""
    margins = y * (X @ w)
    viol = (margins < 1.0).astype(X.dtype)
    L = (X.T @ (viol * y)) / X.shape[0]
    alpha = 1.0 / (lam * t)
    w_half = (1.0 - lam * alpha) * w + alpha * L
    if project:
        norm = jnp.linalg.norm(w_half)
        scale = jnp.minimum(1.0, (1.0 / jnp.sqrt(lam)) / jnp.maximum(norm, 1e-30))
        w_half = w_half * scale
    return w_half


def fleet_half_step_ref(W: jax.Array, X: jax.Array, y: jax.Array, lam: float,
                        t: jax.Array, project: bool = True) -> jax.Array:
    """Oracle for the fused fleet kernel: steps (a)-(e) for all m nodes at
    once. X: (m, B, d) minibatch tiles, W: (m, d), y: (m, B) with padded rows
    carrying y=0. Same per-node math as half_step_ref, batched over the node
    axis — this is also the fused jnp path GADGET uses where the Pallas
    kernels would only interpret (CPU)."""
    B = X.shape[1]
    margins = y * jnp.einsum("mbd,md->mb", X, W)
    coeff = jnp.where(margins < 1.0, y, 0.0)
    L = jnp.einsum("mb,mbd->md", coeff, X) / B
    alpha = 1.0 / (lam * t)
    W_half = (1.0 - lam * alpha) * W + alpha * L
    if project:
        norms = jnp.linalg.norm(W_half, axis=1, keepdims=True)
        scale = jnp.minimum(1.0, (1.0 / jnp.sqrt(lam)) / jnp.maximum(norms, 1e-30))
        W_half = W_half * scale
    return W_half


# --------------------------------------------------------------------- sparse
# Padded-ELL oracles (repro.sparse.formats layout: pad entries (col=0, val=0),
# pad rows y=0 — inert in every gather-dot / scatter-add below).


def ell_margins_ref(w: jax.Array, cols: jax.Array, vals: jax.Array,
                    y: jax.Array) -> jax.Array:
    """y * (X @ w) over one node's ELL minibatch planes: (B, k) cols/vals."""
    return y * jnp.sum(vals * jnp.take(w, cols, axis=0), axis=-1)


def ell_matvec_flat(w: jax.Array, cols: jax.Array, vals: jax.Array) -> jax.Array:
    """X @ w for flat (N, k) ELL planes — the full-data pass the objective
    trace uses (never materializes dense X)."""
    return jnp.sum(vals * jnp.take(w, cols, axis=0), axis=-1)


def ell_fleet_half_step_ref(W: jax.Array, cols: jax.Array, vals: jax.Array,
                            y: jax.Array, lam: float, t: jax.Array,
                            project: bool = True) -> jax.Array:
    """Oracle for the sparse fleet half-step: GADGET steps (a)-(e) for all m
    nodes over ELL minibatch planes. cols/vals: (m, B, k), W: (m, d),
    y: (m, B). Margins are a gather-dot against each node's resident w; the
    subgradient is a scatter-add of the violator-weighted values — same math
    as fleet_half_step_ref with X = dense(cols, vals). Also the fused jnp path
    GADGET's sparse mode uses where Pallas would only interpret (CPU)."""
    B = cols.shape[1]
    d = W.shape[1]
    margins = y * jax.vmap(
        lambda w, c, v: jnp.sum(v * jnp.take(w, c, axis=0), axis=-1)
    )(W, cols, vals)
    coeff = jnp.where(margins < 1.0, y, 0.0)
    L = jax.vmap(
        lambda c, v, cf: jnp.zeros(d, jnp.float32)
        .at[c.reshape(-1)].add((cf[:, None] * v).reshape(-1))
    )(cols, vals, coeff) / B
    alpha = 1.0 / (lam * t)
    W_half = (1.0 - lam * alpha) * W + alpha * L
    if project:
        norms = jnp.linalg.norm(W_half, axis=1, keepdims=True)
        scale = jnp.minimum(1.0, (1.0 / jnp.sqrt(lam)) / jnp.maximum(norms, 1e-30))
        W_half = W_half * scale
    return W_half


# -------------------------------------------------------------------- predict
# Serving-side oracles (repro.serve / ops.dense_predict / ops.ell_predict):
# scores S = X @ W^T against a (C, d) class-weight matrix, labels = argmax_c.


def predict_scores_ref(W: jax.Array, X: jax.Array) -> jax.Array:
    """S = X @ W^T. W: (C, d) class weights (C=1 for binary), X: (B, d)."""
    return X @ W.T


def predict_labels_ref(W: jax.Array, X: jax.Array) -> jax.Array:
    """argmax_c S[b, c] — first occurrence, the convention the fused kernel's
    masked max/min argmax reproduces."""
    return jnp.argmax(predict_scores_ref(W, X), axis=-1).astype(jnp.int32)


def ell_predict_scores_ref(W: jax.Array, cols: jax.Array,
                           vals: jax.Array) -> jax.Array:
    """Sparse twin: scores for one (B, k) padded-ELL query batch as a
    gather-dot against every class row — S[b, c] = Σ_k vals[b,k]·W[c, cols[b,k]].
    Pad entries (val=0) are inert; an all-pad row scores 0 for every class."""
    return jnp.einsum("bk,cbk->bc", vals, jnp.take(W, cols, axis=1))


def pegasos_step_ref(w: jax.Array, X: jax.Array, y: jax.Array, lam: float, t: jax.Array):
    """Returns (w_new (d,), mean_hinge_loss ()). X: (B, d); y: (B,) in {-1,+1}."""
    margins = y * (X @ w)
    viol = (margins < 1.0).astype(X.dtype)
    L = (X.T @ (viol * y)) / X.shape[0]
    alpha = 1.0 / (lam * t)
    w_half = (1.0 - lam * alpha) * w + alpha * L
    norm = jnp.linalg.norm(w_half)
    scale = jnp.minimum(1.0, (1.0 / jnp.sqrt(lam)) / jnp.maximum(norm, 1e-30))
    loss = jnp.mean(jnp.maximum(0.0, 1.0 - margins))
    return w_half * scale, loss
