"""Pallas TPU kernels for the fused Pegasos hinge-subgradient step.

The paper's per-iteration hot-spot is `margins = X w` followed by the
violator-weighted gradient `X^T (1[m<1] y)` — two passes over the minibatch
block X. Two kernels, both VMEM-tiled:

  * ``margins_kernel``  — blocked mat-vec, grid (B/blk_b, d/blk_d), partial
    dot-products accumulated in a VMEM scratch across the d (arbitrary) axis.
  * ``update_kernel``   — blocked transposed mat-vec fused with the Pegasos
    axpy: grid (d/blk_d, B/blk_b); per d-block accumulates g = X^T c over B
    blocks in VMEM scratch and, on the last B block, writes
    w_half = (1 - lam*alpha) w + (alpha/B) g.

``fleet_half_step`` fuses both phases for *all m nodes* in one ``pallas_call``:
the node axis is a parallel grid dimension (replacing ``jax.vmap`` over the
two kernels above), each node's (B, d) minibatch tile is read from HBM once
and stays in VMEM across both phases, and margins → violator coefficients →
gradient → the Pegasos axpy never touch HBM — only w_half is written back.
One kernel launch per GADGET iteration instead of 2m.

The ball projection needs a global ||w_half|| reduction and lives in the
ops.py wrapper (O(d), bandwidth-trivial). Block shapes default to MXU/VREG
friendly multiples of (8, 128); d and B are padded by the wrapper when
needed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels._compat import CompilerParams

__all__ = ["margins", "grad_update", "fleet_half_step",
           "DEFAULT_BLK_B", "DEFAULT_BLK_D"]

DEFAULT_BLK_B = 128
DEFAULT_BLK_D = 512


def _margins_kernel(x_ref, w_ref, y_ref, m_ref, acc):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += x_ref[...] @ w_ref[...]

    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        m_ref[...] = y_ref[...] * acc[...]


def margins(X: jax.Array, w: jax.Array, y: jax.Array, *,
            blk_b: int = DEFAULT_BLK_B, blk_d: int = DEFAULT_BLK_D,
            interpret: bool = False) -> jax.Array:
    """y * (X @ w) via the blocked mat-vec kernel. X: (B, d)."""
    B, d = X.shape
    blk_b, blk_d = min(blk_b, B), min(blk_d, d)
    assert B % blk_b == 0 and d % blk_d == 0, "wrapper must pad"
    return pl.pallas_call(
        _margins_kernel,
        grid=(B // blk_b, d // blk_d),
        in_specs=[
            pl.BlockSpec((blk_b, blk_d), lambda i, j: (i, j)),
            pl.BlockSpec((blk_d,), lambda i, j: (j,)),
            pl.BlockSpec((blk_b,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((blk_b,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((blk_b,), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(X, w, y)


def _fleet_kernel(x_ref, w_ref, y_ref, mask_ref, scal_ref, o_ref):
    x = x_ref[0]       # (B, d) — the node's minibatch tile, resident in VMEM
    w = w_ref[0]       # (d,)
    yv = y_ref[0]      # (B,)
    m = yv * (x @ w)                                   # phase 1: margins
    coeff = jnp.where(m < 1.0, yv, 0.0) * mask_ref[...]  # violator selection
    g = coeff @ x                                      # phase 2: X^T c, same tile
    o_ref[0] = (1.0 - scal_ref[0]) * w + scal_ref[1] * g


def fleet_half_step(X: jax.Array, W: jax.Array, y: jax.Array,
                    row_mask: jax.Array, scal: jax.Array, *,
                    interpret: bool = False) -> jax.Array:
    """Fused GADGET steps (a)-(e) for all m nodes in one launch.

    X: (m, B, d) per-node minibatch tiles; W: (m, d); y: (m, B);
    row_mask: (B,) validity of padded rows (shared across nodes —
    ops.padded_row_mask); scal: (2,) = [lam*alpha, alpha/B] in SMEM.
    Returns W_half (m, d) = (1 - scal[0]) W + scal[1] * (coeff @ X).

    Grid is the node axis only (fully parallel); each program keeps its whole
    (B, d) tile in VMEM across the margins and gradient phases, so X is read
    from HBM exactly once and no intermediate (margins, coefficients) ever
    round-trips through HBM. The wrapper bounds B*d so the tile fits VMEM.
    """
    m, B, d = X.shape
    return pl.pallas_call(
        _fleet_kernel,
        grid=(m,),
        in_specs=[
            pl.BlockSpec((1, B, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec((B,), lambda i: (0,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(X, W, y, row_mask, scal)


def _update_kernel(x_ref, w_ref, c_ref, scal_ref, o_ref, gacc):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        gacc[...] = jnp.zeros_like(gacc)

    # g_d += X[b_blk, d_blk]^T c[b_blk]
    gacc[...] += c_ref[...] @ x_ref[...]

    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        lam_alpha = scal_ref[0]      # lam * alpha
        alpha_over_b = scal_ref[1]   # alpha / B
        o_ref[...] = (1.0 - lam_alpha) * w_ref[...] + alpha_over_b * gacc[...]


def grad_update(X: jax.Array, w: jax.Array, coeff: jax.Array, scal: jax.Array, *,
                blk_b: int = DEFAULT_BLK_B, blk_d: int = DEFAULT_BLK_D,
                interpret: bool = False) -> jax.Array:
    """w_half = (1 - scal[0]) w + scal[1] * (coeff @ X).

    coeff: (B,) = 1[margin<1] * y (violator selection, computed by wrapper);
    scal: (2,) = [lam*alpha, alpha/B] in SMEM.
    """
    B, d = X.shape
    blk_b, blk_d = min(blk_b, B), min(blk_d, d)
    assert B % blk_b == 0 and d % blk_d == 0, "wrapper must pad"
    return pl.pallas_call(
        _update_kernel,
        grid=(d // blk_d, B // blk_b),
        in_specs=[
            pl.BlockSpec((blk_b, blk_d), lambda i, j: (j, i)),
            pl.BlockSpec((blk_d,), lambda i, j: (i,)),
            pl.BlockSpec((blk_b,), lambda i, j: (j,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((blk_d,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((blk_d,), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(X, w, coeff, scal)
