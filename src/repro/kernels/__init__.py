"""Pallas TPU kernels for the compute hot-spots.

Each subpackage: <name>.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jit'd public wrapper), ref.py (pure-jnp oracle used by tests).

* hinge_subgrad   — fused Pegasos hinge-subgradient step (the paper's hot-spot)
* flash_attention — causal/SWA online-softmax attention (prefill hot-spot)
* rglru_scan      — RG-LRU linear recurrence (RecurrentGemma)
* rwkv6_scan      — RWKV-6 WKV state recurrence

The models use the pure-jnp paths by default (this container lowers for CPU);
on a real TPU deployment the ops here replace those call-sites 1:1 — they are
shape/dtype-compatible and tested against the same oracles.
"""
