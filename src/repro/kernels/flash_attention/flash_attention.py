"""Pallas TPU flash attention (causal / sliding-window), online-softmax form.

Grid: (batch*heads, n_q_blocks, n_k_blocks) with the k axis "arbitrary"
(sequential) so the running max / denominator / accumulator live in VMEM
scratch across k blocks. Block shapes (blk_q, head_dim) / (blk_k, head_dim)
— head_dim is kept whole (<=256 for the assigned archs) so each MXU matmul
is (blk_q x head_dim) @ (head_dim x blk_k), lane-dim 128-aligned.

Causality/window are enforced two ways:
  * block-level: fully-masked k blocks are skipped (no compute, no loads of
    the probs path) via pl.when on the block indices;
  * element-level: an iota-based mask inside partially-masked blocks.

GQA is handled by the ops.py wrapper (kv heads are expanded logically via an
index map — no materialized repeat_kv copy).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels._compat import CompilerParams

__all__ = ["flash_attention"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            blk_q: int, blk_k: int, sm_scale: float, causal: bool, window: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * blk_q
    k_start = kj * blk_k

    # block-level skip: in causal mode k block strictly after q block's end;
    # in window mode k block strictly before the band.
    live = True
    if causal:
        live = k_start <= q_start + blk_q - 1
    if window:
        live = live & (k_start + blk_k - 1 >= q_start - window + 1)

    @pl.when(live)
    def _():
        q = q_ref[0].astype(jnp.float32)          # (blk_q, dh)
        k = k_ref[0].astype(jnp.float32)          # (blk_k, dh)
        s = (q @ k.T) * sm_scale                   # (blk_q, blk_k)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
        mask = jnp.ones((blk_q, blk_k), jnp.bool_)
        if causal:
            mask = mask & (k_pos <= q_pos)
        if window:
            mask = mask & (k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + p @ v_ref[0].astype(jnp.float32)
        m_scr[...] = m_new

    @pl.when(kj == pl.num_programs(2) - 1)
    def _():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    blk_q: int = 128, blk_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (BH, Sq, dh); k, v: (BH, Sk, dh). Returns (BH, Sq, dh).

    BH is the flattened batch*query-heads axis; the wrapper maps GQA kv heads
    into the same BH indexing via its own reshape/index plan.
    """
    bh, sq, dh = q.shape
    _, sk, _ = k.shape
    blk_q = min(blk_q, sq)
    blk_k = min(blk_k, sk)
    assert sq % blk_q == 0 and sk % blk_k == 0, "wrapper must pad seq lens"
    sm_scale = 1.0 / (dh ** 0.5)

    kern = functools.partial(_kernel, blk_q=blk_q, blk_k=blk_k,
                             sm_scale=sm_scale, causal=causal, window=window)
    return pl.pallas_call(
        kern,
        grid=(bh, sq // blk_q, sk // blk_k),
        in_specs=[
            pl.BlockSpec((1, blk_q, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, blk_k, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, blk_k, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q, dh), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
