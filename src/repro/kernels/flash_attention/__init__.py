"""Pallas kernel package: flash_attention."""
