"""Jitted GQA wrapper around the flash attention kernel.

Maps the model layout (B, S, H, Dh) + GQA kv (B, S, Hkv, Dh) to the kernel's
(BH, S, Dh) layout. KV heads are expanded to query heads with a broadcast
reshape — XLA lowers this to an index remap into the kernel's BlockSpec
loads rather than a copied repeat when the kernel consumes it directly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention

__all__ = ["gqa_flash_attention"]


@functools.partial(jax.jit, static_argnames=("causal", "window", "blk_q", "blk_k", "interpret"))
def gqa_flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        blk_q: int = 128, blk_k: int = 128,
                        interpret: bool = False) -> jax.Array:
    """q: (B, S, H, Dh); k/v: (B, S, Hkv, Dh) -> (B, S, H, Dh)."""
    b, s, h, dh = q.shape
    hkv = k.shape[2]
    n_rep = h // hkv
    if n_rep > 1:
        k = jnp.broadcast_to(k[:, :, :, None], (b, s, hkv, n_rep, dh)).reshape(b, s, h, dh)
        v = jnp.broadcast_to(v[:, :, :, None], (b, s, hkv, n_rep, dh)).reshape(b, s, h, dh)
    qf = jnp.moveaxis(q, 2, 1).reshape(b * h, s, dh)
    kf = jnp.moveaxis(k, 2, 1).reshape(b * h, s, dh)
    vf = jnp.moveaxis(v, 2, 1).reshape(b * h, s, dh)
    of = flash_attention(qf, kf, vf, causal=causal, window=window,
                         blk_q=min(blk_q, s), blk_k=min(blk_k, s), interpret=interpret)
    return jnp.moveaxis(of.reshape(b, h, s, dh), 1, 2)
