"""Pure-jnp oracle for flash attention (matches models.attention masking)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0) -> jax.Array:
    """q: (BH, Sq, dh); k, v: (BH, Sk, dh)."""
    dh = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / jnp.sqrt(float(dh))
    sq, sk = q.shape[1], k.shape[1]
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
