"""Version compatibility for Pallas TPU symbols.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``; support
both so the kernels run on every jaxlib the containers ship.
"""
from __future__ import annotations

import jax.experimental.pallas.tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
