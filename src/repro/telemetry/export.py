"""Exporters for the flight recorder: Prometheus text format and JSONL.

Two consumption paths out of a :class:`~repro.telemetry.registry.Registry`:

* :func:`to_prometheus` renders a point-in-time scrape in the Prometheus
  text exposition format (``repro_`` prefix, counters get ``_total``,
  histograms expand to cumulative ``_bucket{le=...}`` / ``_sum`` /
  ``_count``) — paste-able into a pushgateway or served from a debug
  endpoint.
* :func:`registry_records` / :func:`dump_jsonl` snapshot every series as
  one JSON object per line, and :class:`JsonlSink` streams span/event
  records live when attached via ``registry.attach_sink``. The
  ``python -m repro.telemetry.dump`` CLI reads these files back;
  ``tools/check_telemetry_schema.py`` validates them.
"""
from __future__ import annotations

import json
import math
import time

__all__ = [
    "to_prometheus",
    "write_prometheus",
    "registry_records",
    "dump_jsonl",
    "read_jsonl",
    "JsonlSink",
]

PROM_PREFIX = "repro_"


def _prom_name(name: str) -> str:
    """Metric name mangled for Prometheus: prefixed, dots to underscores."""
    return PROM_PREFIX + name.replace(".", "_").replace("-", "_")


def _prom_labels(labels: dict) -> str:
    """Render a label dict as ``{k="v",...}`` (empty string when none)."""
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def _fmt(v: float) -> str:
    """Format a sample value (Prometheus spells infinity ``+Inf``)."""
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return repr(float(v))


def to_prometheus(registry) -> str:
    """Render every series in ``registry`` as Prometheus exposition text."""
    by_name: dict[str, list] = {}
    for name, labels, metric in registry.series():
        by_name.setdefault(name, []).append((labels, metric))
    lines: list[str] = []
    for name in sorted(by_name):
        entries = by_name[name]
        kind = entries[0][1].kind
        pname = _prom_name(name)
        lines.append(f"# TYPE {pname + '_total' if kind == 'counter' else pname} {kind}")
        for labels, metric in entries:
            if kind == "counter":
                lines.append(f"{pname}_total{_prom_labels(labels)} {_fmt(metric.value)}")
            elif kind == "gauge":
                lines.append(f"{pname}{_prom_labels(labels)} {_fmt(metric.value)}")
            else:  # histogram
                cum = 0
                for j, c in enumerate(metric._counts):
                    cum += c
                    le = _fmt(metric.upper_edge(j))
                    lab = dict(labels, le=le)
                    lines.append(f"{pname}_bucket{_prom_labels(lab)} {cum}")
                lines.append(f"{pname}_sum{_prom_labels(labels)} {_fmt(metric.sum)}")
                lines.append(f"{pname}_count{_prom_labels(labels)} {metric.count}")
        lines.append("")
    return "\n".join(lines)


def write_prometheus(registry, path) -> str:
    """Write :func:`to_prometheus` output to ``path``; returns the text."""
    text = to_prometheus(registry)
    with open(path, "w") as fh:
        fh.write(text)
    return text


def registry_records(registry, ts: float | None = None) -> list[dict]:
    """Snapshot every series as JSONL-ready records.

    Record schema (validated by ``tools/check_telemetry_schema.py``): every
    record has ``ts`` (float), ``kind`` (counter/gauge/histogram/span/event),
    ``name`` (str), ``labels`` (dict). Counters and gauges add ``value``;
    histograms add ``count``/``sum``/``min``/``max``/``buckets`` (pairs of
    ``[le, count]``, ``le`` null for overflow); spans add ``seconds``.
    """
    if ts is None:
        ts = time.time()
    records = []
    for name, labels, metric in registry.series():
        rec = {"ts": ts, "kind": metric.kind, "name": name, "labels": labels}
        if metric.kind == "histogram":
            rec.update(metric.to_dict())
        else:
            rec["value"] = metric.value
        records.append(rec)
    return records


def dump_jsonl(registry, path, ts: float | None = None, mode: str = "a") -> int:
    """Append a full registry snapshot to ``path`` as JSONL; returns the
    number of records written."""
    records = registry_records(registry, ts)
    with open(path, mode) as fh:
        for rec in records:
            fh.write(json.dumps(rec) + "\n")
    return len(records)


def read_jsonl(path) -> list[dict]:
    """Parse a telemetry JSONL file back into a list of records (blank
    lines skipped)."""
    out = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


class JsonlSink:
    """Streaming sink writing one JSON object per line as events arrive.

    Attach with ``registry.attach_sink(JsonlSink(path))`` to capture spans
    and explicit ``registry.emit`` events live; call :meth:`close` (or use
    as a context manager) when done.
    """

    def __init__(self, path):
        self.path = path
        self._fh = open(path, "a")

    def emit(self, record: dict) -> None:
        """Write one record and flush (readers may be tailing the file)."""
        self._fh.write(json.dumps(record) + "\n")
        self._fh.flush()

    def close(self) -> None:
        """Close the underlying file."""
        self._fh.close()

    def __enter__(self) -> "JsonlSink":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()
