"""On-device training telemetry: the trace ring and its host-side decode.

:class:`TrainTelemetry` is the user-facing config accepted by
``gadget_train(..., telemetry=...)`` and ``gadget_train_stream``. When set,
the jitted training loop carries a fixed-size ring (alongside the snapshot
ring) recording, every ``every`` iterations:

* consensus disagreement — ``max_i ||w_i - w_consensus||_2``,
* Push-Sum mass min/max over the window since the previous record,
* primal objective at the consensus iterate,
* fault-drop counts (messages lost to the :class:`~repro.core.faults
  .FaultPlan`, summed over the window; 0 when fault-free).

The ring costs ``slots * 4`` f32/i32 device words and is materialized with
ONE extra post-termination sync; ``telemetry=None`` leaves the traced
program untouched (bit-identical trajectories — asserted in tests).

:class:`TrainTrace` is the decoded host-side result attached to
``GadgetResult.telemetry``; :func:`publish_trace` mirrors its headline
numbers onto a :class:`~repro.telemetry.registry.Registry` so benches and
the dump CLI read training health from the same place as serve metrics.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import numpy as np

from .registry import default_registry

__all__ = [
    "TrainTelemetry",
    "TrainTrace",
    "SegmentTelemetry",
    "validate_telemetry",
    "publish_trace",
]


class TrainTelemetry(NamedTuple):
    """Config for the on-device training trace ring.

    ``every`` — record a trace point every this many iterations (>= 1).
    ``slots`` — ring capacity; when more than ``slots`` points are recorded
    the oldest are overwritten (ring semantics, like the snapshot ring).
    ``per_node`` — also carry per-node leaves: ``(slots, m)`` rings of
    per-node disagreement-to-consensus ``||w_i - w_cons||_2``, per-node
    Push-Sum mass ratio at the record iteration, and per-node fault-drop
    counts over the window (by mixing-matrix row; rows sum to the scalar
    ``drops`` series). The observatory (:mod:`repro.telemetry.observatory`)
    decodes these into node-health records.
    """

    every: int = 1
    slots: int = 256
    per_node: bool = False


class TrainTrace(NamedTuple):
    """Decoded training trace: per-record arrays in iteration order.

    All arrays share length ``count`` (<= slots; ring-decoded oldest
    first). ``mass_min``/``mass_max`` are windowed extrema of the Push-Sum
    mass over the iterations since the previous record — under message-drop
    faults ``1 - mass_min`` is the leakage gauge the fault bench asserts
    on. ``drops`` counts faulted messages per window (int64, zeros when
    fault-free). ``final_disagreement`` is measured at the returned
    consensus regardless of ring cadence.

    When the ring ran with ``per_node=True`` the three ``node_*`` arrays are
    ``(count, m)`` (else None): per-node disagreement ``||w_i - w_cons||_2``
    at each record (its row-max equals ``disagreement`` exactly), the
    per-node Push-Sum mass ratio at the record iteration, and per-node
    fault drops over the window (rows sum to ``drops``).
    """

    every: int
    iterations: np.ndarray
    disagreement: np.ndarray
    mass_min: np.ndarray
    mass_max: np.ndarray
    objective: np.ndarray
    drops: np.ndarray
    final_iteration: int
    final_disagreement: float
    node_disagreement: Optional[np.ndarray] = None
    node_mass: Optional[np.ndarray] = None
    node_drops: Optional[np.ndarray] = None

    @property
    def count(self) -> int:
        """Number of trace points retained in the ring."""
        return int(self.iterations.shape[0])


class SegmentTelemetry(NamedTuple):
    """Per-segment telemetry from ``gadget_train_stream``.

    One record per published segment: disagreement and objective are
    measured at the segment boundary; mass/drops aggregate over the
    segment's active iterations (mass extrema are NaN for segments that
    run zero active iterations).
    """

    disagreement: float
    mass_min: float
    mass_max: float
    objective: float
    drops: int


def validate_telemetry(telemetry: Optional[TrainTelemetry]) -> Optional[TrainTelemetry]:
    """Normalize/validate a ``telemetry=`` argument.

    Accepts None (off), a :class:`TrainTelemetry`, or anything with
    ``every``/``slots`` attributes; returns a validated
    :class:`TrainTelemetry` or None.
    """
    if telemetry is None:
        return None
    every = int(getattr(telemetry, "every", 1))
    slots = int(getattr(telemetry, "slots", 256))
    per_node = bool(getattr(telemetry, "per_node", False))
    if every < 1:
        raise ValueError(f"telemetry.every must be >= 1, got {every}")
    if slots < 1:
        raise ValueError(f"telemetry.slots must be >= 1, got {slots}")
    return TrainTelemetry(every=every, slots=slots, per_node=per_node)


def _ring_order(count: int, slots: int) -> np.ndarray:
    """Indices that reorder a ring written ``count`` times (slot ``i %
    slots``) into oldest-first retained order."""
    kept = min(count, slots)
    start = count % slots if count > slots else 0
    return (start + np.arange(kept)) % slots


def decode_ring(every: int, slots: int, count: int, iterations, disagreement,
                mass_min, mass_max, objective, drops,
                final_iteration: int, final_disagreement: float,
                node_disagreement=None, node_mass=None,
                node_drops=None) -> TrainTrace:
    """Assemble a :class:`TrainTrace` from raw device ring arrays; the three
    optional ``node_*`` arguments are the ``(slots, m)`` per-node rings
    (decoded with the same ring order) when the run carried them."""
    order = _ring_order(int(count), slots)
    return TrainTrace(
        every=every,
        iterations=np.asarray(iterations)[order].astype(np.int64),
        disagreement=np.asarray(disagreement)[order].astype(np.float64),
        mass_min=np.asarray(mass_min)[order].astype(np.float64),
        mass_max=np.asarray(mass_max)[order].astype(np.float64),
        objective=np.asarray(objective)[order].astype(np.float64),
        drops=np.asarray(drops)[order].astype(np.int64),
        final_iteration=int(final_iteration),
        final_disagreement=float(final_disagreement),
        node_disagreement=(None if node_disagreement is None else
                           np.asarray(node_disagreement)[order].astype(np.float64)),
        node_mass=(None if node_mass is None else
                   np.asarray(node_mass)[order].astype(np.float64)),
        node_drops=(None if node_drops is None else
                    np.asarray(node_drops)[order].astype(np.int64)),
    )


def publish_trace(trace: TrainTrace, registry=None) -> None:
    """Mirror a decoded trace's headline numbers onto a registry.

    Sets ``train.final_disagreement`` / ``train.mass_min`` /
    ``train.mass_max`` / ``train.objective`` gauges and increments the
    ``train.fault_drops`` counter; no-op details (empty trace) publish
    only the final disagreement.
    """
    reg = default_registry() if registry is None else registry
    reg.gauge("train.final_disagreement").set(trace.final_disagreement)
    if trace.count:
        reg.gauge("train.objective").set(float(trace.objective[-1]))
        finite_min = trace.mass_min[np.isfinite(trace.mass_min)]
        finite_max = trace.mass_max[np.isfinite(trace.mass_max)]
        if finite_min.size:
            reg.gauge("train.mass_min").set(float(finite_min.min()))
        if finite_max.size:
            reg.gauge("train.mass_max").set(float(finite_max.max()))
        reg.counter("train.fault_drops").inc(int(trace.drops.sum()))
