"""Flight-recorder metrics core: counters, gauges, log-bucket histograms, spans.

One :class:`Registry` holds every labeled series a process emits — training
loop counters, kernel launch accounting, serve latency histograms, publisher
spans. A process-wide default registry (:func:`default_registry`) is the
"unified" recorder the module-level conveniences write to; subsystems that
need isolation (one :class:`~repro.serve.engine.SvmServer` per test, one
:class:`~repro.serve.batcher.MicroBatcher` per bench) hold their own
``Registry`` instance — the API is identical.

Design constraints, in order:

* **No dependencies** — this package sits below ``repro.core`` and
  ``repro.kernels`` (both import it), so it imports nothing from ``repro``.
* **Bounded memory** — :class:`Histogram` is HDR-style log-bucketed: a fixed
  geometric ladder of ``n_buckets`` buckets (growth factor ``growth``), so
  observing ten million latencies costs the same bytes as observing ten.
  Quantiles come back as bucket upper edges: for any value inside the ladder
  the reported quantile ``q̂`` brackets the exact one as ``q ≤ q̂ ≤ q·growth``
  (tests pin this against a sorted-array oracle).
* **Thread-safe** — the training publisher mutates counters from its daemon
  thread while the serving loop reads them; every update takes the registry's
  lock.

Export lives in :mod:`repro.telemetry.export` (Prometheus text + JSONL);
``python -m repro.telemetry.dump`` tails/summarizes a JSONL run.
"""
from __future__ import annotations

import math
import threading
import time

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "Span",
    "Registry",
    "default_registry",
    "counter",
    "gauge",
    "histogram",
    "span",
    "reset",
]

# Default histogram ladder: 10 µs lowest bucket, ~19% relative resolution
# (2^(1/4) growth), 128 buckets → covers ~10 µs .. ~1 hour in seconds units.
DEFAULT_BASE = 1e-5
DEFAULT_GROWTH = 2.0 ** 0.25
DEFAULT_BUCKETS = 128


class Counter:
    """Monotonically non-decreasing series (queries served, bytes moved)."""

    kind = "counter"

    def __init__(self, name: str, labels: dict, lock: threading.RLock):
        self.name = name
        self.labels = dict(labels)
        self._lock = lock
        self._value = 0.0

    def inc(self, n: float = 1.0) -> "Counter":
        """Add ``n`` (must be >= 0) to the counter; returns self."""
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease (inc {n})")
        with self._lock:
            self._value += n
        return self

    @property
    def value(self) -> float:
        """Current accumulated total."""
        return self._value


class Gauge:
    """Point-in-time series (last mass retention, jit-cache size)."""

    kind = "gauge"

    def __init__(self, name: str, labels: dict, lock: threading.RLock):
        self.name = name
        self.labels = dict(labels)
        self._lock = lock
        self._value = 0.0

    def set(self, v: float) -> "Gauge":
        """Overwrite the gauge with ``v``; returns self."""
        with self._lock:
            self._value = float(v)
        return self

    def inc(self, n: float = 1.0) -> "Gauge":
        """Add ``n`` (either sign) to the gauge; returns self."""
        with self._lock:
            self._value += n
        return self

    @property
    def value(self) -> float:
        """Current gauge reading."""
        return self._value


class Histogram:
    """Bounded log-bucketed (HDR-style) histogram.

    Bucket 0 holds ``(-inf, base]``; bucket ``j >= 1`` holds
    ``(base·growth^(j-1), base·growth^j]``; the last bucket is the overflow
    catch-all. Memory is a fixed ``n_buckets`` integer array regardless of
    observation count — the bounded replacement for keeping raw latency
    lists. Exact ``count`` / ``sum`` / ``min`` / ``max`` ride alongside, so
    means are exact and the overflow quantile can return the true max.
    """

    kind = "histogram"

    def __init__(self, name: str, labels: dict, lock: threading.RLock, *,
                 base: float = DEFAULT_BASE, growth: float = DEFAULT_GROWTH,
                 n_buckets: int = DEFAULT_BUCKETS):
        if base <= 0 or growth <= 1.0 or n_buckets < 2:
            raise ValueError(
                f"need base > 0, growth > 1, n_buckets >= 2; got "
                f"({base}, {growth}, {n_buckets})")
        self.name = name
        self.labels = dict(labels)
        self._lock = lock
        self.base = float(base)
        self.growth = float(growth)
        self.n_buckets = int(n_buckets)
        self._log_growth = math.log(self.growth)
        self._counts = [0] * self.n_buckets
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    # -------------------------------------------------------------- buckets

    def bucket_index(self, v: float) -> int:
        """Index of the bucket ``v`` lands in (edges belong to the bucket
        they bound above; everything past the ladder clamps to overflow)."""
        if v <= self.base:
            return 0
        idx = 1 + int(math.floor(
            math.log(v / self.base) / self._log_growth - 1e-12))
        return min(idx, self.n_buckets - 1)

    def upper_edge(self, j: int) -> float:
        """Upper bound of bucket ``j`` (``inf`` for the overflow bucket)."""
        if j >= self.n_buckets - 1:
            return math.inf
        return self.base if j == 0 else self.base * self.growth ** j

    # ------------------------------------------------------------- updates

    def observe(self, v: float) -> "Histogram":
        """Record one observation; returns self."""
        v = float(v)
        with self._lock:
            self._counts[self.bucket_index(v)] += 1
            self._count += 1
            self._sum += v
            self._min = min(self._min, v)
            self._max = max(self._max, v)
        return self

    def merge(self, other: "Histogram") -> "Histogram":
        """Fold ``other``'s observations into this histogram (in place).

        Requires identical bucket ladders. Bucket counts add exactly, so
        merging is associative and commutative on the counts (tests pin
        associativity); ``sum`` adds in float.
        """
        if (other.base, other.growth, other.n_buckets) != (
                self.base, self.growth, self.n_buckets):
            raise ValueError(
                f"cannot merge histograms with different ladders: "
                f"({self.base}, {self.growth}, {self.n_buckets}) vs "
                f"({other.base}, {other.growth}, {other.n_buckets})")
        with self._lock:
            for j, c in enumerate(other._counts):
                self._counts[j] += c
            self._count += other._count
            self._sum += other._sum
            self._min = min(self._min, other._min)
            self._max = max(self._max, other._max)
        return self

    def copy(self) -> "Histogram":
        """Deep copy (fresh lock) — lets tests build pure merge expressions."""
        out = Histogram(self.name, self.labels, threading.RLock(),
                        base=self.base, growth=self.growth,
                        n_buckets=self.n_buckets)
        out._counts = list(self._counts)
        out._count, out._sum = self._count, self._sum
        out._min, out._max = self._min, self._max
        return out

    # -------------------------------------------------------------- reads

    @property
    def count(self) -> int:
        """Total observations recorded."""
        return self._count

    @property
    def sum(self) -> float:
        """Exact sum of all observations."""
        return self._sum

    @property
    def min(self) -> float:
        """Exact minimum observation (``inf`` when empty)."""
        return self._min

    @property
    def max(self) -> float:
        """Exact maximum observation (``-inf`` when empty)."""
        return self._max

    def quantile(self, q: float) -> float:
        """Upper bucket edge covering the ``q``-quantile observation.

        For values within the ladder ``(base, top)`` the result brackets the
        exact quantile within one growth factor; bucket-0 quantiles report
        ``base`` and overflow quantiles report the exact tracked max. NaN
        when empty.
        """
        if self._count == 0:
            return math.nan
        q = min(max(q, 0.0), 1.0)
        target = max(1, math.ceil(q * self._count))
        cum = 0
        for j, c in enumerate(self._counts):
            cum += c
            if cum >= target:
                return self._max if j == self.n_buckets - 1 else self.upper_edge(j)
        return self._max

    @property
    def value(self) -> float:
        """Mean observation (NaN when empty) — the scalar view exports use."""
        return self._sum / self._count if self._count else math.nan

    def to_dict(self) -> dict:
        """JSON-ready snapshot: count/sum/min/max + nonzero ``[le, n]``
        buckets (overflow bucket's ``le`` is ``None``)."""
        with self._lock:
            buckets = [
                [None if j == self.n_buckets - 1 else self.upper_edge(j), c]
                for j, c in enumerate(self._counts) if c
            ]
            return {"count": self._count, "sum": self._sum,
                    "min": None if self._count == 0 else self._min,
                    "max": None if self._count == 0 else self._max,
                    "buckets": buckets}


class Span:
    """Context manager timing one host-side phase into a histogram.

    ``with registry.span("publisher.publish_seconds", step=40): ...``
    observes the wall-clock duration into the histogram named ``name`` (one
    series per name) and, when the registry has a JSONL sink attached, emits
    a ``span`` event carrying ``fields`` (e.g. the step number) and the
    measured seconds.

    Spans close on the exception path too: a raise inside the block still
    observes the histogram and emits the record, with an ``error`` field
    naming the exception (the raise itself propagates unchanged).
    """

    def __init__(self, registry: "Registry", name: str, fields: dict):
        self.registry = registry
        self.name = name
        self.fields = dict(fields)
        self.seconds: float | None = None
        self._t0: float | None = None

    def __enter__(self) -> "Span":
        self._t0 = self.registry.clock()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.seconds = self.registry.clock() - self._t0
        if exc_type is not None:
            self.fields.setdefault("error", f"{exc_type.__name__}: {exc}")
        self.registry.histogram(self.name).observe(self.seconds)
        self.registry.emit({"kind": "span", "name": self.name, "labels": {},
                            "seconds": self.seconds, "fields": self.fields})


class Registry:
    """Process- or subsystem-scoped store of labeled metric series.

    Series are created on first touch (``registry.counter("kernel.launches",
    kernel="fleet_half_step")``) and keyed by ``(name, sorted labels)``; the
    same call always returns the same object. ``clock`` is injectable so
    span tests are deterministic. An optional JSONL sink
    (:meth:`attach_sink`) receives span/event records as they happen —
    metric snapshots are exported separately (``export.dump_jsonl``).
    """

    def __init__(self, clock=time.monotonic):
        self._lock = threading.RLock()
        self._series: dict[tuple, object] = {}
        self._sink = None
        self.clock = clock

    # ------------------------------------------------------------- series

    def _get(self, cls, name: str, labels: dict, **kw):
        key = (name, tuple(sorted((str(k), str(v)) for k, v in labels.items())))
        with self._lock:
            m = self._series.get(key)
            if m is None:
                m = self._series[key] = cls(name, labels, self._lock, **kw)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"series {name!r} already registered as {m.kind}")
            return m

    def counter(self, name: str, **labels) -> Counter:
        """Get-or-create the counter ``name`` with ``labels``."""
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        """Get-or-create the gauge ``name`` with ``labels``."""
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, *, base: float = DEFAULT_BASE,
                  growth: float = DEFAULT_GROWTH,
                  n_buckets: int = DEFAULT_BUCKETS, **labels) -> Histogram:
        """Get-or-create the histogram ``name`` with ``labels`` (ladder
        parameters apply on first touch only)."""
        return self._get(Histogram, name, labels,
                         base=base, growth=growth, n_buckets=n_buckets)

    def span(self, name: str, **fields) -> Span:
        """Span context manager timing into histogram ``name``; ``fields``
        annotate the emitted event (not the series labels)."""
        return Span(self, name, fields)

    # -------------------------------------------------------------- reads

    def series(self) -> list[tuple[str, dict, object]]:
        """Sorted snapshot of ``(name, labels, metric)`` for every series."""
        with self._lock:
            items = sorted(self._series.items(), key=lambda kv: kv[0])
        return [(m.name, dict(m.labels), m) for _, m in items]

    def get(self, name: str, **labels):
        """The existing series object, or None when never touched."""
        key = (name, tuple(sorted((str(k), str(v)) for k, v in labels.items())))
        with self._lock:
            return self._series.get(key)

    def value(self, name: str, **labels) -> float:
        """Scalar value of a counter/gauge series; 0.0 when never touched."""
        m = self.get(name, **labels)
        return 0.0 if m is None else m.value

    def values(self) -> dict[str, float]:
        """Flat ``{"name{k=v,...}": value}`` of every counter/gauge — the
        deterministic slice benchmark JSONs embed as their telemetry
        section (histograms excluded: their values are wall-clock)."""
        out = {}
        for name, labels, m in self.series():
            if m.kind not in ("counter", "gauge"):
                continue
            key = name
            if labels:
                key += "{" + ",".join(f"{k}={v}" for k, v in sorted(labels.items())) + "}"
            out[key] = m.value
        return out

    # ---------------------------------------------------------- lifecycle

    def reset(self) -> None:
        """Drop every series (tests / bench sections start clean)."""
        with self._lock:
            self._series.clear()

    def attach_sink(self, sink) -> None:
        """Attach a JSONL event sink (anything with ``emit(dict)``); spans
        and :meth:`emit` calls stream to it as they happen."""
        self._sink = sink

    def detach_sink(self) -> None:
        """Stop streaming events."""
        self._sink = None

    def emit(self, record: dict) -> None:
        """Send one event record to the attached sink (no-op without one);
        a wall-clock ``ts`` is stamped if absent."""
        if self._sink is None:
            return
        record.setdefault("ts", time.time())
        self._sink.emit(record)


_DEFAULT = Registry()


def default_registry() -> Registry:
    """The process-wide registry every unscoped emitter writes to."""
    return _DEFAULT


def counter(name: str, **labels) -> Counter:
    """Counter on the default registry."""
    return _DEFAULT.counter(name, **labels)


def gauge(name: str, **labels) -> Gauge:
    """Gauge on the default registry."""
    return _DEFAULT.gauge(name, **labels)


def histogram(name: str, **kw) -> Histogram:
    """Histogram on the default registry."""
    return _DEFAULT.histogram(name, **kw)


def span(name: str, **fields) -> Span:
    """Span on the default registry."""
    return _DEFAULT.span(name, **fields)


def reset() -> None:
    """Reset the default registry (bench sections / tests start clean)."""
    _DEFAULT.reset()
