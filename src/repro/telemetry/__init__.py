"""Flight recorder: unified telemetry for train, gossip, kernel, and serve.

Public surface:

* :class:`Registry` / :func:`default_registry` and the module-level
  :func:`counter` / :func:`gauge` / :func:`histogram` / :func:`span` /
  :func:`reset` conveniences (see :mod:`repro.telemetry.registry`).
* :class:`TrainTelemetry` — pass as ``gadget_train(..., telemetry=...)``
  to record the on-device trace ring; results come back as
  :class:`TrainTrace` on ``GadgetResult.telemetry``.
* :func:`to_prometheus` / :func:`dump_jsonl` / :class:`JsonlSink`
  exporters, and the ``python -m repro.telemetry.dump`` CLI.
* :class:`TraceContext` / :class:`RequestTracer` and the lineage helpers
  (see :mod:`repro.telemetry.trace`; ``python -m repro.telemetry.trace``
  prints causal chains), :func:`analyze` / :func:`publish_node_health`
  per-node health (:mod:`repro.telemetry.observatory`), and the
  ``python -m repro.telemetry.top`` live console.
"""
from .export import (
    JsonlSink,
    dump_jsonl,
    read_jsonl,
    registry_records,
    to_prometheus,
    write_prometheus,
)
from .registry import (
    Counter,
    Gauge,
    Histogram,
    Registry,
    Span,
    counter,
    default_registry,
    gauge,
    histogram,
    reset,
    span,
)
from .observatory import (
    NodeHealth,
    ObservatoryReport,
    analyze,
    publish_node_health,
)
from .trace import (
    RequestTracer,
    TraceContext,
    TracedSpan,
    emit_event,
    emit_span,
    format_chain,
    lineage_chains,
)
from .train import (
    SegmentTelemetry,
    TrainTelemetry,
    TrainTrace,
    publish_trace,
    validate_telemetry,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "Registry",
    "Span",
    "counter",
    "default_registry",
    "gauge",
    "histogram",
    "reset",
    "span",
    "JsonlSink",
    "dump_jsonl",
    "read_jsonl",
    "registry_records",
    "to_prometheus",
    "write_prometheus",
    "SegmentTelemetry",
    "TrainTelemetry",
    "TrainTrace",
    "publish_trace",
    "validate_telemetry",
    "TraceContext",
    "TracedSpan",
    "RequestTracer",
    "emit_span",
    "emit_event",
    "lineage_chains",
    "format_chain",
    "NodeHealth",
    "ObservatoryReport",
    "analyze",
    "publish_node_health",
]
