"""Causal tracing: explicit-propagation trace contexts over the registry.

The flight recorder (:mod:`repro.telemetry.registry`) says *what* the system
did; this module records *why* — which training segment produced which
checkpoint, which server swap picked it up, which query met which fate. A
:class:`TraceContext` is an immutable ``(trace_id, span_id, parent_id)``
triple passed **explicitly** across the trainer/publisher/watch/drain thread
boundaries (no thread-locals: the publisher's daemon thread, the server's
watch thread and the caller's drain loop would each see a different
thread-local, so ambient context cannot work here).

Two record families ride the registry's JSONL sink:

* **Version lineage** — one trace per published model version:
  ``train.segment`` (root, emitted by ``gadget_train_stream``) →
  ``publish.seconds`` + per-attempt ``publish.attempt`` spans
  (:class:`~repro.serve.publisher.TrainPublisher`) → ``publish.visible``
  (LATEST pointer handoff — the publisher writes the checkpoint unpointed
  and advances the pointer only after this record, so swap timestamps
  causally follow it) → ``serve.swap``
  (:meth:`~repro.serve.engine.SvmServer.maybe_reload`, linked through the
  checkpoint manifest ``extra["trace"]``) → ``serve.first_score`` (first
  scoring under the new plane). ``python -m repro.telemetry.trace
  <jsonl> --version N`` prints the chain with per-hop latencies.
* **Request fates** — :class:`RequestTracer` samples ``MicroBatcher``
  submissions and emits one ``serve.request`` span per sampled request whose
  terminal attributes are its typed fate (``delivered`` / ``shed`` /
  ``rejected`` / ``deadline``), the bucket it executed in and the degrade
  rung at execution. Retention is a reservoir, so a 50k-request soak holds
  O(reservoir) memory.

Span records carry ``trace_id`` / ``span_id`` / ``parent_id`` at the top
level (next to ``kind``/``name``) so ``tools/check_telemetry_schema.py`` can
validate linkage without knowing span semantics.
"""
from __future__ import annotations

import argparse
import random
import secrets
import sys
import threading
import time
from typing import NamedTuple, Optional

from .registry import Registry, default_registry

__all__ = [
    "TraceContext",
    "TracedSpan",
    "emit_span",
    "emit_event",
    "RequestTracer",
    "LINEAGE_NAMES",
    "lineage_chains",
    "format_chain",
]

# Lineage chain members in causal order. ``publish.attempt`` spans are
# children of ``publish.seconds`` and annotate (retries) rather than extend
# the chain, so they are not chain stages.
LINEAGE_NAMES = ("train.segment", "publish.seconds", "publish.visible",
                 "serve.swap", "serve.first_score")
# The hops a *complete* chain must contain (``publish.visible`` collapses
# into the publish stage when absent — old streams — but the four below are
# mandatory).
_REQUIRED = ("train.segment", "publish.seconds", "serve.swap",
             "serve.first_score")


def _gen_id() -> str:
    """16-hex-char random id (64 bits — collision-safe at trace volume)."""
    return secrets.token_hex(8)


class TraceContext(NamedTuple):
    """Immutable causal coordinates for one span.

    ``trace_id`` groups every span of one causal story (one model version's
    life, one request's life); ``span_id`` names this span; ``parent_id`` is
    the ``span_id`` of the causally-preceding span (None for roots).
    Propagation is always explicit — pass the context object across thread
    boundaries, derive children with :meth:`child`.
    """

    trace_id: str
    span_id: str
    parent_id: Optional[str] = None

    @classmethod
    def new(cls) -> "TraceContext":
        """Fresh root context (new trace_id, no parent)."""
        return cls(trace_id=_gen_id(), span_id=_gen_id(), parent_id=None)

    def child(self) -> "TraceContext":
        """Context for a span caused by this one (same trace, new span id,
        parent set to this span)."""
        return TraceContext(self.trace_id, _gen_id(), self.span_id)

    def to_extra(self) -> dict:
        """JSON-ready dict for embedding in a checkpoint manifest
        (``extra["trace"]``) — the cross-process propagation format."""
        return {"trace_id": self.trace_id, "span_id": self.span_id,
                "parent_id": self.parent_id}

    @classmethod
    def from_extra(cls, extra) -> Optional["TraceContext"]:
        """Rebuild a context from a manifest ``extra["trace"]`` dict; None
        when the dict is absent or malformed (untraced checkpoint)."""
        if not isinstance(extra, dict):
            return None
        tid, sid = extra.get("trace_id"), extra.get("span_id")
        if not (isinstance(tid, str) and tid and isinstance(sid, str) and sid):
            return None
        return cls(tid, sid, extra.get("parent_id"))


def _trace_fields(ctx: TraceContext) -> dict:
    fields = {"trace_id": ctx.trace_id, "span_id": ctx.span_id}
    if ctx.parent_id is not None:
        fields["parent_id"] = ctx.parent_id
    return fields


def emit_span(registry: Registry, name: str, ctx: TraceContext,
              seconds: float, **attrs) -> None:
    """Record one completed traced span: observes ``seconds`` into the
    histogram ``name`` and emits a ``span`` record (trace ids at top level,
    ``attrs`` under ``fields``) to the registry's sink."""
    registry.histogram(name).observe(seconds)
    registry.emit({"kind": "span", "name": name, "labels": {},
                   "seconds": float(seconds), **_trace_fields(ctx),
                   "fields": {k: v for k, v in attrs.items() if v is not None}})


def emit_event(registry: Registry, name: str, ctx: TraceContext,
               **attrs) -> None:
    """Emit an instantaneous traced ``event`` record (a point on the chain
    with no duration, e.g. ``publish.visible``)."""
    registry.emit({"kind": "event", "name": name, "labels": {},
                   **_trace_fields(ctx),
                   "fields": {k: v for k, v in attrs.items() if v is not None}})


class TracedSpan:
    """Context manager timing one phase into a traced span.

    Like :class:`~repro.telemetry.registry.Span` but carries a
    :class:`TraceContext` and — critically — closes on the exception path
    too: a raise inside the block still observes the histogram and emits the
    span record, with an ``error`` attribute naming the exception.
    """

    def __init__(self, registry: Registry, name: str, ctx: TraceContext,
                 **attrs):
        self.registry = registry
        self.name = name
        self.ctx = ctx
        self.attrs = dict(attrs)
        self.seconds: Optional[float] = None
        self._t0: Optional[float] = None

    def __enter__(self) -> "TracedSpan":
        self._t0 = self.registry.clock()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.seconds = self.registry.clock() - self._t0
        if exc_type is not None:
            self.attrs.setdefault("error", f"{exc_type.__name__}: {exc}")
        emit_span(self.registry, self.name, self.ctx, self.seconds,
                  **self.attrs)


class RequestTracer:
    """Sampled per-request fate traces for the micro-batcher.

    ``sample`` is the fraction of submissions traced (1.0 = all, 0.0 = off —
    the batcher's hot path then does nothing beyond one predicate). Each
    traced request gets a root :class:`TraceContext` at submit; its terminal
    fate (``delivered`` / ``shed`` / ``deadline`` / ``rejected``) closes the
    span with the bucket and degrade rung at execution. Completed fate
    records are retained in a fixed-size **reservoir** (uniform over all
    completions), so memory is O(``reservoir``) regardless of soak length;
    exact totals ride the ``trace.requests`` counter and the per-fate
    ``trace.fate{fate=...}`` counters.

    Thread-safe: submit happens on caller threads, delivery on the drain
    thread, expiry under the batcher lock.
    """

    def __init__(self, registry: Optional[Registry] = None, *,
                 sample: float = 1.0, reservoir: int = 256, seed: int = 0,
                 clock=time.monotonic):
        if not (0.0 <= sample <= 1.0):
            raise ValueError(f"sample must be in [0, 1], got {sample}")
        if reservoir < 1:
            raise ValueError(f"reservoir must be >= 1, got {reservoir}")
        self.registry = default_registry() if registry is None else registry
        self.sample = float(sample)
        self.reservoir = int(reservoir)
        self.clock = clock
        self._rng = random.Random(seed)
        self._lock = threading.Lock()
        self._live: dict[int, tuple[TraceContext, float]] = {}
        self._kept: list[dict] = []
        self._n_done = 0

    # ------------------------------------------------------------ sampling

    def _sampled(self) -> bool:
        if self.sample >= 1.0:
            return True
        if self.sample <= 0.0:
            return False
        with self._lock:
            return self._rng.random() < self.sample

    # ------------------------------------------------------------- lifecycle

    def start(self, rid: int) -> None:
        """Begin a trace for request ``rid`` (sampling applies); call at
        successful submit."""
        if not self._sampled():
            return
        ctx = TraceContext.new()
        with self._lock:
            self._live[rid] = (ctx, self.clock())
        self.registry.counter("trace.requests").inc()

    def finish(self, rid: int, fate: str, **attrs) -> None:
        """Close request ``rid``'s trace with its terminal ``fate``; no-op
        for unsampled/unknown rids."""
        with self._lock:
            entry = self._live.pop(rid, None)
        if entry is None:
            return
        ctx, t0 = entry
        seconds = self.clock() - t0
        self.registry.counter("trace.fate", fate=fate).inc()
        emit_span(self.registry, "serve.request", ctx, seconds,
                  fate=fate, rid=rid, **attrs)
        self._retain({"rid": rid, "fate": fate, "seconds": seconds, **attrs})

    def reject(self, fate: str = "rejected", **attrs) -> None:
        """Record a submission refused at the door (no rid was assigned):
        a zero-duration root span with the rejection fate."""
        if not self._sampled():
            return
        self.registry.counter("trace.requests").inc()
        self.registry.counter("trace.fate", fate=fate).inc()
        emit_span(self.registry, "serve.request", TraceContext.new(), 0.0,
                  fate=fate, **attrs)
        self._retain({"rid": None, "fate": fate, "seconds": 0.0, **attrs})

    def _retain(self, rec: dict) -> None:
        with self._lock:
            self._n_done += 1
            if len(self._kept) < self.reservoir:
                self._kept.append(rec)
            else:
                j = self._rng.randrange(self._n_done)
                if j < self.reservoir:
                    self._kept[j] = rec

    # --------------------------------------------------------------- reads

    @property
    def pending(self) -> int:
        """Number of sampled requests submitted but not yet resolved."""
        with self._lock:
            return len(self._live)

    def sampled_fates(self) -> list[dict]:
        """Snapshot of the retained fate reservoir (uniform sample of all
        completed fates)."""
        with self._lock:
            return [dict(r) for r in self._kept]

    def fate_counts(self) -> dict[str, int]:
        """Exact per-fate completion totals from the registry counters."""
        out = {}
        for name, labels, m in self.registry.series():
            if name == "trace.fate" and m.kind == "counter":
                out[labels.get("fate", "?")] = int(m.value)
        return out


# --------------------------------------------------------------------------
# Lineage assembly (host-side, over decoded JSONL records)
# --------------------------------------------------------------------------

def _version_of(rec: dict):
    f = rec.get("fields") or {}
    for k in ("version", "step", "iteration"):
        if k in f:
            return f[k]
    return None


def lineage_chains(records) -> dict[int, dict]:
    """Assemble version-lineage chains from decoded JSONL records.

    Returns ``{version: chain}`` where each chain has ``trace_id``,
    ``events`` (``{name: record}`` for the chain stages present, first
    occurrence wins), ``attempts`` (the ``publish.attempt`` spans),
    ``complete`` (all four mandatory stages present) and ``monotone``
    (stage timestamps non-decreasing in causal order, 1 ms slack for wall
    clock steps).
    """
    by_trace: dict[str, list[dict]] = {}
    for r in records:
        tid = r.get("trace_id")
        if tid and (r.get("name") in LINEAGE_NAMES
                    or r.get("name") == "publish.attempt"):
            by_trace.setdefault(tid, []).append(r)
    chains: dict[int, dict] = {}
    for tid, recs in sorted(by_trace.items()):
        recs.sort(key=lambda r: r.get("ts", 0.0))
        events: dict[str, dict] = {}
        attempts = []
        for r in recs:
            name = r["name"]
            if name == "publish.attempt":
                attempts.append(r)
            else:
                events.setdefault(name, r)
        version = None
        for name in ("serve.swap", "publish.seconds", "train.segment"):
            if name in events:
                version = _version_of(events[name])
                if version is not None:
                    break
        if version is None:
            continue
        ts = [events[n].get("ts", 0.0) for n in LINEAGE_NAMES if n in events]
        chains[int(version)] = {
            "trace_id": tid,
            "events": events,
            "attempts": attempts,
            "complete": all(n in events for n in _REQUIRED),
            "monotone": all(b >= a - 1e-3 for a, b in zip(ts, ts[1:])),
        }
    return chains


_HOP_LABELS = {
    "train.segment": "segment-end",
    "publish.seconds": "publish",
    "publish.visible": "visible",
    "serve.swap": "swapped",
    "serve.first_score": "first-serve",
}


def format_chain(version: int, chain: dict) -> str:
    """Human-readable lineage chain for one version: the stages present, the
    per-hop latencies between them, and any publish retry attempts."""
    events = chain["events"]
    lines = [f"version {version}  trace {chain['trace_id']}"
             f"  {'complete' if chain['complete'] else 'INCOMPLETE'}"
             f"{'' if chain['monotone'] else '  NON-MONOTONE'}"]
    present = [(n, events[n]) for n in LINEAGE_NAMES if n in events]
    t_first = present[0][1].get("ts", 0.0) if present else 0.0
    for name, rec in present:
        dur = f"  ({rec['seconds'] * 1e3:.2f} ms)" if "seconds" in rec else ""
        attrs = rec.get("fields") or {}
        shown = {k: v for k, v in attrs.items() if k != "rid"}
        lines.append(f"  {_HOP_LABELS[name]:<12} +{(rec.get('ts', 0.0) - t_first) * 1e3:9.2f} ms"
                     f"{dur}  {shown}")
    for rec in chain["attempts"]:
        err = (rec.get("fields") or {}).get("error")
        lines.append(f"    attempt {(rec.get('fields') or {}).get('attempt')}"
                     f"  {'ERROR ' + str(err) if err else 'ok'}")
    hops = [f"{_HOP_LABELS[a]}→{_HOP_LABELS[b]} "
            f"{(events[b].get('ts', 0.0) - events[a].get('ts', 0.0)) * 1e3:.2f} ms"
            for (a, _), (b, _) in zip(present, present[1:])]
    if hops:
        lines.append("  hops: " + " · ".join(hops))
    return "\n".join(lines)


def main(argv=None) -> int:
    """CLI: print version-lineage chains from a telemetry JSONL file.

    Usage:
        python -m repro.telemetry.trace run.jsonl [--version N]

    Without ``--version``, summarizes every chain found; with it, prints the
    full causal chain for that version (exit 1 when absent).
    """
    from .export import read_jsonl

    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry.trace",
        description="Print train→publish→swap→serve lineage chains from a "
                    "telemetry JSONL stream.")
    ap.add_argument("path", help="JSONL file written by a JsonlSink")
    ap.add_argument("--version", type=int, default=None,
                    help="print the full chain for this model version")
    args = ap.parse_args(argv)

    chains = lineage_chains(read_jsonl(args.path))
    if not chains:
        print("no lineage chains found")
        return 1
    if args.version is not None:
        chain = chains.get(args.version)
        if chain is None:
            print(f"version {args.version} not found "
                  f"(have: {sorted(chains)})")
            return 1
        print(format_chain(args.version, chain))
        return 0
    for version in sorted(chains):
        print(format_chain(version, chains[version]))
    n_complete = sum(c["complete"] for c in chains.values())
    print(f"{len(chains)} chain(s), {n_complete} complete")
    return 0


if __name__ == "__main__":
    sys.exit(main())
