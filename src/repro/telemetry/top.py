"""Live top-style console over the flight recorder + causal traces.

``python -m repro.telemetry.top run.jsonl`` renders a refresh-in-place
console from a telemetry JSONL file (a ``JsonlSink`` stream, periodic
``dump_jsonl`` snapshots, or both appended to one file — the live pattern
``benchmarks/observatory_bench.py`` uses). Three panes:

* **nodes** — the per-node health table :func:`repro.telemetry.observatory.
  publish_node_health` mirrors onto the registry (disagreement, mass,
  drops, straggler/dead flags) plus the fleet mixing rate;
* **serve** — request-fate accounting (submitted/delivered/shed/deadline/
  rejected, the ``trace.fate`` counters) and the degrade rung;
* **lineage** — the tail of the version-lineage chains assembled by
  :func:`repro.telemetry.trace.lineage_chains` (version, completeness,
  publish→serve latency).

``--once`` prints a single frame and exits (what CI runs); the default
loop re-reads the file every ``--interval`` seconds and redraws in place
(ANSI home+clear). Programmatic use: :func:`render` takes decoded records
directly, :func:`render_registry` a live in-process registry.
"""
from __future__ import annotations

import argparse
import sys
import time

from . import trace as tmtr
from .registry import Registry

__all__ = ["snapshot_values", "render", "render_registry", "main"]


def snapshot_values(records) -> dict[str, float]:
    """Last-write-wins flat values from counter/gauge snapshot records.

    Keys follow the registry ``values()`` convention:
    ``name`` or ``name{k=v,...}`` for labelled series.
    """
    out: dict[str, float] = {}
    for r in records:
        if r.get("kind") not in ("counter", "gauge"):
            continue
        labels = r.get("labels") or {}
        key = r["name"]
        if labels:
            inner = ",".join(f"{k}={v}" for k, v in sorted(labels.items()))
            key = f"{key}{{{inner}}}"
        out[key] = r.get("value", 0.0)
    return out


def _node_rows(values: dict[str, float]) -> list[tuple]:
    """(node, disagreement, mass, drops, flag) rows from node.* series."""
    nodes = {}
    for key, v in values.items():
        if not key.startswith("node.") or "{node=" not in key:
            continue
        metric = key[len("node."):key.index("{")]
        node = key[key.index("{node=") + 6:-1]
        nodes.setdefault(node, {})[metric] = v
    rows = []
    for node in sorted(nodes, key=lambda s: int(s) if s.isdigit() else 0):
        d = nodes[node]
        flag = ("DEAD" if d.get("dead") else
                "STRAGGLER" if d.get("straggler") else "")
        rows.append((node, d.get("disagreement", float("nan")),
                     d.get("mass", float("nan")), int(d.get("drops", 0)),
                     flag))
    return rows


def render(values: dict[str, float], records=None, *,
           lineage_tail: int = 5) -> str:
    """One console frame from flat ``values`` (+ optional full records for
    the lineage pane). Returns the frame text (no ANSI)."""
    lines = []

    def v(key, default=0.0):
        return values.get(key, default)

    rows = _node_rows(values)
    lines.append("=== gossip nodes ===")
    if rows:
        mix = values.get("train.mixing_rate")
        leak = values.get("train.mass_leak", 0.0)
        lines.append(f"  mixing rate {mix:+.4f}/iter" if mix is not None
                     else "  mixing rate n/a")
        if leak:
            lines.append(f"  MASS LEAK {leak:.4f}")
        lines.append(f"  {'node':>4} {'disagree':>10} {'mass':>8} "
                     f"{'drops':>6}  flag")
        for node, dis, mass, drops, flag in rows:
            lines.append(f"  {node:>4} {dis:>10.4f} {mass:>8.4f} "
                         f"{drops:>6d}  {flag}")
    else:
        lines.append("  (no node health published — train with "
                     "TrainTelemetry(per_node=True) and publish_node_health)")

    lines.append("=== serve fates ===")
    fates = {k[k.index("{fate=") + 6:-1]: int(val)
             for k, val in values.items() if k.startswith("trace.fate{")}
    lines.append(f"  submitted {int(v('serve.submitted'))}  "
                 f"delivered {int(v('serve.delivered'))}  "
                 f"shed {int(v('serve.shed'))}  "
                 f"deadline {int(v('serve.deadline_missed'))}")
    if fates:
        lines.append("  traced fates: " + "  ".join(
            f"{k}={fates[k]}" for k in sorted(fates)))
    rung = v("serve.degrade_rung")
    if rung:
        lines.append(f"  DEGRADED rung {int(rung)}")
    lines.append(f"  publishes {int(v('publish.segments'))}  "
                 f"swaps {int(v('serve.swaps'))}  "
                 f"reload errors {int(v('serve.reload_errors'))}")

    lines.append("=== lineage tail ===")
    if records:
        chains = tmtr.lineage_chains(records)
        for version in sorted(chains)[-lineage_tail:]:
            c = chains[version]
            events = c["events"]
            span = ""
            if "train.segment" in events and "serve.first_score" in events:
                dt = (events["serve.first_score"].get("ts", 0.0)
                      - events["train.segment"].get("ts", 0.0))
                span = f"  segment→serve {dt * 1e3:.1f} ms"
            lines.append(f"  v{version}: "
                         f"{'complete' if c['complete'] else 'incomplete'}"
                         f"{'' if c['monotone'] else ' NON-MONOTONE'}{span}")
        if not chains:
            lines.append("  (no lineage spans yet)")
    else:
        lines.append("  (lineage needs span records — stream via JsonlSink)")
    return "\n".join(lines)


def render_registry(registry: Registry, records=None, **kw) -> str:
    """Frame from a live in-process registry (counters/gauges read
    directly; pass streamed ``records`` too for the lineage pane)."""
    return render(registry.values(), records, **kw)


def main(argv=None) -> int:
    """CLI: top-style console over a telemetry JSONL file.

    Usage:
        python -m repro.telemetry.top run.jsonl [--interval S] [--once]

    Redraws in place every ``--interval`` seconds (the file is re-read, so
    a live run streaming through a ``JsonlSink`` updates the frame);
    ``--once`` prints one frame and exits 0 — the CI mode.
    """
    from .export import read_jsonl

    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry.top",
        description="Refresh-in-place console: node health, request fates "
                    "and version lineage from a telemetry JSONL stream.")
    ap.add_argument("path", help="JSONL file (JsonlSink stream and/or "
                                 "dump_jsonl snapshots)")
    ap.add_argument("--interval", type=float, default=1.0,
                    help="seconds between redraws (default 1.0)")
    ap.add_argument("--once", action="store_true",
                    help="print one frame and exit (CI mode)")
    args = ap.parse_args(argv)

    while True:
        records = read_jsonl(args.path)
        frame = render(snapshot_values(records), records)
        if args.once:
            print(frame)
            return 0
        sys.stdout.write("\x1b[H\x1b[J" + frame + "\n")
        sys.stdout.flush()
        time.sleep(max(0.05, args.interval))


if __name__ == "__main__":
    sys.exit(main())
