"""Gossip health observatory: per-node convergence diagnostics on host.

The training loop's per-node telemetry leaves (``TrainTelemetry(per_node=
True)``) come back on ``TrainTrace`` as ``(count, m)`` rings — per-node
disagreement-to-consensus, per-node Push-Sum mass ratio, per-node fault-drop
counts. This module turns those raw rings into operator-facing health
records:

* :func:`analyze` — one :class:`ObservatoryReport` per trace: the empirical
  **mixing rate** (least-squares log-slope of the fleet disagreement, the
  measured counterpart of the paper's spectral-gap convergence factor),
  per-node :class:`NodeHealth` rows, and the flagged **stragglers** (nodes
  whose final disagreement stands far above the fleet median), **dead
  nodes** (disagreement not decaying while the fleet's is — a crashed node's
  weights freeze, so its distance to the moving consensus stays put) and the
  fleet-level **mass leak** (Push-Sum mass below 1 under message-drop
  faults).
* :func:`publish_node_health` — mirror a report onto a registry as
  ``node.disagreement{node=i}`` / ``node.mass{node=i}`` /
  ``node.drops{node=i}`` series plus ``train.mixing_rate`` /
  ``train.mass_leak`` gauges, which is what ``python -m
  repro.telemetry.top`` renders as its node table.

Everything here is host-side numpy over already-decoded rings — the traced
device program is untouched.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

from .registry import Registry, default_registry
from .train import TrainTrace

__all__ = [
    "NodeHealth",
    "ObservatoryReport",
    "analyze",
    "publish_node_health",
]


class NodeHealth(NamedTuple):
    """Health record for one gossip node, decoded from the per-node rings.

    ``disagreement``/``mass`` are at the last retained record; ``drops`` is
    the node's total faulted messages over the retained window (by
    mixing-matrix row — what this node failed to deliver). ``straggler``
    and ``dead`` are the flags :func:`analyze` raised for the node.
    """

    node: int
    disagreement: float
    mass: float
    drops: int
    straggler: bool
    dead: bool


class ObservatoryReport(NamedTuple):
    """Fleet-level health decoded from one per-node training trace.

    ``mixing_rate`` is the least-squares slope of ``log(median-over-nodes
    disagreement)`` per iteration over the retained records (negative =
    converging; the empirical twin of the gossip matrix's second-eigenvalue
    rate). The median — not the max the scalar ``disagreement`` ring uses —
    keeps one dead straggler from masking the live fleet's decay.
    ``mass_leak`` is ``max(0, 1 - min node mass)`` at the last record —
    0 under link-drop or fault-free gossip, positive when message drops
    destroyed Push-Sum mass. ``stragglers``/``dead`` list the flagged node
    ids (sorted; a dead node is not double-listed as a straggler).
    """

    nodes: tuple[NodeHealth, ...]
    mixing_rate: float
    mass_leak: float
    stragglers: tuple[int, ...]
    dead: tuple[int, ...]

    @property
    def healthy(self) -> bool:
        """True when no node is flagged and no mass leaked."""
        return not self.stragglers and not self.dead and self.mass_leak == 0.0


def _mixing_rate(iterations: np.ndarray, disagreement: np.ndarray) -> float:
    """Log-slope of the fleet disagreement per iteration (NaN when fewer
    than two positive records exist to fit)."""
    pos = disagreement > 0
    if int(pos.sum()) < 2:
        return float("nan")
    it = iterations[pos].astype(np.float64)
    if it[-1] == it[0]:
        return float("nan")
    slope = np.polyfit(it, np.log(disagreement[pos]), 1)[0]
    return float(slope)


def analyze(trace: TrainTrace, *, straggler_factor: float = 4.0,
            dead_decay: float = 0.9, fleet_decay: float = 0.5,
            mass_tol: float = 1e-3) -> ObservatoryReport:
    """Decode a per-node training trace into an :class:`ObservatoryReport`.

    ``trace`` must carry the per-node rings (train with
    ``TrainTelemetry(per_node=True)``; raises ``ValueError`` otherwise).

    Flag semantics:

    * **straggler** — final disagreement > ``straggler_factor`` × the fleet
      median (and strictly positive): the node is converging far behind its
      peers (slow link, partitioned corner of the topology, dead node).
    * **dead** — needs ≥ 2 records: the node's disagreement decayed by less
      than ``1 - dead_decay`` (last/first ≥ ``dead_decay``) while the fleet
      median decayed below ``fleet_decay`` of its start. A crashed node's
      weights freeze, so its distance to the still-moving consensus holds
      (or grows) while everyone else closes in — that divergence-in-decay is
      the signature, since a dead node sends nothing and therefore shows
      *zero* fault drops of its own.
    * **mass leak** — fleet-level: ``1 - min_i mass_i`` at the last record
      beyond ``mass_tol`` (message-drop faults destroy Push-Sum mass; link
      drops and fault-free gossip conserve it exactly).
    """
    nd, nm, ndr = (trace.node_disagreement, trace.node_mass, trace.node_drops)
    if nd is None or nm is None or ndr is None:
        raise ValueError(
            "trace carries no per-node telemetry — train with "
            "TrainTelemetry(per_node=True) to record the node rings")
    count, m = nd.shape
    if count == 0:
        return ObservatoryReport(nodes=(), mixing_rate=float("nan"),
                                 mass_leak=0.0, stragglers=(), dead=())
    final_dis = nd[-1]
    final_mass = nm[-1]
    total_drops = ndr.sum(axis=0)
    median = float(np.median(final_dis))
    stragglers = set()
    if median >= 0.0:
        for i in range(m):
            if final_dis[i] > straggler_factor * median and final_dis[i] > 0:
                stragglers.add(i)
    dead = set()
    if count >= 2:
        first_dis = nd[0]
        first_median = float(np.median(first_dis))
        fleet_decayed = (first_median > 0
                         and median < fleet_decay * first_median)
        if fleet_decayed:
            for i in range(m):
                if first_dis[i] > 0 and \
                        final_dis[i] / first_dis[i] >= dead_decay:
                    dead.add(i)
    stragglers -= dead
    leak = max(0.0, 1.0 - float(final_mass.min()))
    if leak <= mass_tol:
        leak = 0.0
    fleet_dis = np.median(nd, axis=1)
    nodes = tuple(
        NodeHealth(node=i, disagreement=float(final_dis[i]),
                   mass=float(final_mass[i]), drops=int(total_drops[i]),
                   straggler=i in stragglers, dead=i in dead)
        for i in range(m))
    return ObservatoryReport(
        nodes=nodes,
        mixing_rate=_mixing_rate(trace.iterations, fleet_dis),
        mass_leak=leak,
        stragglers=tuple(sorted(stragglers)),
        dead=tuple(sorted(dead)),
    )


def publish_node_health(report: ObservatoryReport,
                        registry: Registry | None = None) -> None:
    """Mirror a report onto a registry as per-node labelled series.

    Sets ``node.disagreement{node=i}`` / ``node.mass{node=i}`` gauges and
    ``node.drops{node=i}`` counters (set-to-total via inc from zero is
    wrong for repeat publishes, so drops ride a gauge too), plus
    ``train.mixing_rate`` / ``train.mass_leak`` and the flag gauges
    ``node.straggler{node=i}`` / ``node.dead{node=i}`` (0/1). The top
    console renders these.
    """
    reg = default_registry() if registry is None else registry
    for h in report.nodes:
        label = str(h.node)
        reg.gauge("node.disagreement", node=label).set(h.disagreement)
        reg.gauge("node.mass", node=label).set(h.mass)
        reg.gauge("node.drops", node=label).set(float(h.drops))
        reg.gauge("node.straggler", node=label).set(float(h.straggler))
        reg.gauge("node.dead", node=label).set(float(h.dead))
    if np.isfinite(report.mixing_rate):
        reg.gauge("train.mixing_rate").set(report.mixing_rate)
    reg.gauge("train.mass_leak").set(report.mass_leak)
