"""CLI to tail or summarize a telemetry JSONL run.

Usage::

    python -m repro.telemetry.dump run.jsonl            # summary
    python -m repro.telemetry.dump run.jsonl --tail 20  # last 20 raw lines
    python -m repro.telemetry.dump run.jsonl --prometheus out.prom

The summary groups records by (kind, name): counters/gauges show their
last value, histograms show count/mean/p50/p90/p99/max reconstructed from
the bucket snapshot, spans show count and total seconds.
"""
from __future__ import annotations

import argparse
import json
import math
import sys

from .export import read_jsonl

__all__ = ["summarize", "main"]


def _hist_quantile(buckets, count: int, q: float, mx) -> float:
    """Quantile from a JSONL bucket snapshot (upper-edge convention,
    overflow/None bucket reports the tracked max)."""
    if not count:
        return math.nan
    target = max(1, math.ceil(q * count))
    cum = 0
    for le, c in buckets:
        cum += c
        if cum >= target:
            if le is None:
                return mx if mx is not None else math.inf
            return le
    return mx if mx is not None else math.nan


def _label_key(rec: dict) -> str:
    labels = rec.get("labels") or {}
    if not labels:
        return rec["name"]
    inner = ",".join(f"{k}={v}" for k, v in sorted(labels.items()))
    return f"{rec['name']}{{{inner}}}"


def summarize(records: list[dict]) -> list[str]:
    """Render one summary line per series (last record wins per series)."""
    last: dict[tuple, dict] = {}
    span_agg: dict[str, list] = {}
    for rec in records:
        kind = rec.get("kind")
        if kind == "span":
            agg = span_agg.setdefault(rec["name"], [0, 0.0])
            agg[0] += 1
            agg[1] += float(rec.get("seconds", 0.0))
        elif kind in ("counter", "gauge", "histogram"):
            last[(kind, _label_key(rec))] = rec
    lines = []
    for (kind, key), rec in sorted(last.items(), key=lambda kv: kv[0][1]):
        if kind == "histogram":
            count = rec.get("count", 0)
            buckets = rec.get("buckets", [])
            mean = rec.get("sum", 0.0) / count if count else math.nan
            p50 = _hist_quantile(buckets, count, 0.50, rec.get("max"))
            p90 = _hist_quantile(buckets, count, 0.90, rec.get("max"))
            p99 = _hist_quantile(buckets, count, 0.99, rec.get("max"))
            lines.append(
                f"histogram {key}: count={count} mean={mean:.6g} "
                f"p50={p50:.6g} p90={p90:.6g} p99={p99:.6g} "
                f"max={rec.get('max')}")
        else:
            lines.append(f"{kind} {key}: {rec.get('value')}")
    for name, (n, total) in sorted(span_agg.items()):
        lines.append(f"span {name}: count={n} total_seconds={total:.6g}")
    return lines


def main(argv=None) -> int:
    """Entry point for ``python -m repro.telemetry.dump``."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry.dump",
        description="Tail or summarize a telemetry JSONL run.")
    ap.add_argument("path", help="telemetry JSONL file")
    ap.add_argument("--tail", type=int, metavar="N", default=0,
                    help="print the last N raw records instead of a summary")
    ap.add_argument("--prometheus", metavar="OUT", default=None,
                    help="also rebuild a registry from the last snapshot "
                         "and write Prometheus text to OUT")
    args = ap.parse_args(argv)

    records = read_jsonl(args.path)
    if args.tail:
        for rec in records[-args.tail:]:
            print(json.dumps(rec))
    else:
        for line in summarize(records):
            print(line)
        if not records:
            print("(no records)")

    if args.prometheus:
        from .export import write_prometheus
        from .registry import Registry
        reg = Registry()
        for rec in records:
            kind, name = rec.get("kind"), rec.get("name")
            labels = rec.get("labels") or {}
            if kind == "counter":
                c = reg.counter(name, **labels)
                c._value = float(rec.get("value", 0.0))
            elif kind == "gauge":
                reg.gauge(name, **labels).set(float(rec.get("value", 0.0)))
        write_prometheus(reg, args.prometheus)
        print(f"wrote {args.prometheus}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
