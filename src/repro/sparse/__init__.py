"""Sparse feature subsystem: CSR/ELL containers and partition helpers.

Unlocks the paper's full-scale text workloads (CCAT: 0.16% nonzeros at
d=47,236 — ~147 GB dense, ~0.5 GB as ELL planes). See formats.py for the
layout contract; the sparse Pallas kernels live in
``repro.kernels.hinge_subgrad`` and the streaming LibSVM ingest in
``repro.data.libsvm``.
"""
from repro.sparse.formats import (  # noqa: F401
    CSR, ELL, BlockBuckets, DEFAULT_BUCKET_BLK_D, EllPartitions,
    block_map, bucket_by_block, frequency_remap, minibatch_block_bound,
    pad_query_planes, partition_rows, row_block_counts,
)
