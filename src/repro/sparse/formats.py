"""Sparse feature containers: CSR (host/streaming) and padded ELL (device).

The paper's flagship large-scale result is CCAT — 781,265 rows at d = 47,236
with 0.16% nonzeros. Dense, the train split is ~147 GB; as index/value planes
it is ~0.5 GB. Two layouts, two jobs:

  * :class:`CSR` — the classic compressed-sparse-row triplet
    (data/indices/indptr), the natural container for *streaming ingest*
    (LibSVM chunk readers append rows for free) and host-side row surgery.
  * :class:`ELL` — a padded "ELLPACK" layout: every row stores exactly
    ``k_max`` (column-index, value) pairs as two dense (rows, k_max) planes.
    Ragged rows are padded with the inert entry ``(col=0, val=0.0)`` — a zero
    value contributes nothing to a gather-dot or a scatter-add, so kernels
    need no per-entry mask. Rectangular planes are what TPUs (and XLA on any
    backend) want: fixed shapes, contiguous lanes, one validity convention.

``partition_ell`` produces the stacked per-node planes GADGET's device loop
consumes; it composes with the PR 2 ``n_counts`` API (padded tail rows carry
all-zero vals and are excluded from sampling/mass/objective by the caller's
counts). This module is NumPy-only on purpose — it is the host substrate; the
jnp/Pallas consumers live in ``repro.kernels.hinge_subgrad``.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["CSR", "ELL", "EllPartitions", "partition_rows"]


@dataclass
class CSR:
    """Compressed sparse row matrix: ``data[indptr[r]:indptr[r+1]]`` are the
    nonzero values of row r at columns ``indices[indptr[r]:indptr[r+1]]``."""

    data: np.ndarray     # (nnz,) float
    indices: np.ndarray  # (nnz,) int32, 0-based column ids, < shape[1]
    indptr: np.ndarray   # (rows+1,) int64, monotone, indptr[0] == 0
    shape: tuple[int, int]

    def __post_init__(self):
        self.data = np.asarray(self.data)
        self.indices = np.asarray(self.indices, np.int32)
        self.indptr = np.asarray(self.indptr, np.int64)
        n, d = self.shape
        if self.indptr.shape != (n + 1,) or self.indptr[0] != 0:
            raise ValueError(f"bad indptr for {n} rows")
        if self.indptr[-1] != len(self.data) or len(self.data) != len(self.indices):
            raise ValueError("indptr/data/indices lengths disagree")
        if len(self.indices) and (self.indices.min() < 0 or self.indices.max() >= d):
            raise ValueError(f"column index out of range for d={d}")

    @property
    def nnz(self) -> int:
        return int(self.indptr[-1])

    @property
    def nbytes(self) -> int:
        return self.data.nbytes + self.indices.nbytes + self.indptr.nbytes

    def row_nnz(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int64)

    @classmethod
    def from_dense(cls, X: np.ndarray) -> "CSR":
        X = np.asarray(X)
        n, d = X.shape
        mask = X != 0
        indptr = np.zeros(n + 1, np.int64)
        np.cumsum(mask.sum(axis=1), out=indptr[1:])
        cols = np.nonzero(mask)[1].astype(np.int32)
        return cls(X[mask].astype(X.dtype), cols, indptr, (n, d))

    def to_dense(self, dtype=None) -> np.ndarray:
        n, d = self.shape
        X = np.zeros((n, d), dtype or self.data.dtype)
        rows = np.repeat(np.arange(n), self.row_nnz())
        X[rows, self.indices] = self.data
        return X

    def take_rows(self, idx: np.ndarray) -> "CSR":
        """New CSR holding rows ``idx`` (in that order) — partition shuffles."""
        idx = np.asarray(idx, np.int64)
        counts = self.row_nnz()[idx]
        indptr = np.zeros(len(idx) + 1, np.int64)
        np.cumsum(counts, out=indptr[1:])
        starts = self.indptr[idx]
        # gather each selected row's span: offset-within-row + row start
        flat = (np.repeat(starts - indptr[:-1], counts)
                + np.arange(int(indptr[-1]), dtype=np.int64))
        return CSR(self.data[flat], self.indices[flat], indptr,
                   (len(idx), self.shape[1]))

    def to_ell(self, k_max: int | None = None) -> "ELL":
        counts = self.row_nnz()
        widest = int(counts.max()) if len(counts) else 0
        if k_max is None:
            k_max = max(widest, 1)
        elif widest > k_max:
            raise ValueError(f"k_max={k_max} < widest row nnz {widest}")
        n, d = self.shape
        cols = np.zeros((n, k_max), np.int32)
        vals = np.zeros((n, k_max), np.float32)
        within = np.arange(self.nnz, dtype=np.int64) - np.repeat(self.indptr[:-1], counts)
        rows = np.repeat(np.arange(n), counts)
        cols[rows, within] = self.indices
        vals[rows, within] = self.data
        return ELL(cols, vals, (n, d))


@dataclass
class ELL:
    """Padded ELLPACK planes. Pad entries are ``(col=0, val=0.0)`` — inert in
    every gather-dot and scatter-add, so no mask plane is stored; anything
    that must *count* entries uses ``row_nnz()`` (vals != 0)."""

    cols: np.ndarray  # (n, k_max) int32
    vals: np.ndarray  # (n, k_max) float32
    shape: tuple[int, int]

    def __post_init__(self):
        self.cols = np.asarray(self.cols, np.int32)
        self.vals = np.asarray(self.vals, np.float32)
        if self.cols.shape != self.vals.shape or self.cols.ndim != 2:
            raise ValueError("cols/vals must be equal-shape (n, k_max) planes")
        if self.cols.shape[0] != self.shape[0]:
            raise ValueError("plane row count disagrees with shape")
        if self.cols.size and (self.cols.min() < 0 or self.cols.max() >= self.shape[1]):
            raise ValueError(f"column index out of range for d={self.shape[1]}")

    @property
    def k_max(self) -> int:
        return self.cols.shape[1]

    @property
    def nnz(self) -> int:
        return int((self.vals != 0).sum())

    @property
    def nbytes(self) -> int:
        return self.cols.nbytes + self.vals.nbytes

    def row_nnz(self) -> np.ndarray:
        return (self.vals != 0).sum(axis=1).astype(np.int64)

    @classmethod
    def from_dense(cls, X: np.ndarray, k_max: int | None = None) -> "ELL":
        return CSR.from_dense(X).to_ell(k_max)

    def to_dense(self, dtype=np.float32) -> np.ndarray:
        n, d = self.shape
        X = np.zeros((n, d), dtype)
        rows = np.repeat(np.arange(n), self.k_max).reshape(n, self.k_max)
        # += so the shared pad slot (0,0) accumulates only zeros
        np.add.at(X, (rows, self.cols), self.vals)
        return X

    def to_csr(self) -> CSR:
        live = self.vals != 0
        counts = live.sum(axis=1)
        indptr = np.zeros(self.shape[0] + 1, np.int64)
        np.cumsum(counts, out=indptr[1:])
        return CSR(self.vals[live], self.cols[live], indptr, self.shape)

    def take_rows(self, idx: np.ndarray) -> "ELL":
        idx = np.asarray(idx, np.int64)
        return ELL(self.cols[idx], self.vals[idx], (len(idx), self.shape[1]))

    def matvec(self, w: np.ndarray) -> np.ndarray:
        """X @ w as a gather-dot — the host-side oracle for the kernels."""
        return (self.vals * np.asarray(w)[self.cols]).sum(axis=1)


@dataclass
class EllPartitions:
    """Per-node stacked ELL planes for GADGET: node i's rows are
    ``cols[i], vals[i], y-padded`` with the first ``n_counts[i]`` valid.
    Produced by :func:`repro.data.svm_datasets.partition`; consumed by
    ``gadget_train(..., n_counts=...)`` in place of a dense (m, n_i, d)."""

    cols: np.ndarray  # (m, n_i, k_max) int32
    vals: np.ndarray  # (m, n_i, k_max) float32
    d: int            # feature dimension (planes don't carry it)

    @property
    def shape(self) -> tuple[int, int, int]:
        m, n_i, _ = self.cols.shape
        return (m, n_i, self.d)

    @property
    def nbytes(self) -> int:
        return self.cols.nbytes + self.vals.nbytes


def partition_rows(n: int, m: int, seed: int = 0) -> tuple[np.ndarray, np.ndarray, int]:
    """Shuffled near-equal split of n rows over m nodes — the one statement of
    the padded-partition convention.

    Returns ``(idx, counts, n_i)``: a permutation of ``arange(n)`` laid out so
    node i owns ``idx[i*n_i : i*n_i + counts[i]]``, per-node valid counts
    summing to exactly n (no dropped tail rows), and the common padded length
    ``n_i = ceil(n/m)``. The first ``n % m`` nodes hold one extra row.
    """
    if n < m:
        raise ValueError(f"cannot partition {n} rows over {m} nodes")
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    q, r = divmod(n, m)
    counts = np.full(m, q, np.int64)
    counts[:r] += 1
    n_i = q + (1 if r else 0)
    # scatter each node's slice to its padded offset; pad slots point at row
    # perm[0] but carry count-masked semantics (callers zero them out)
    idx = np.zeros(m * n_i, np.int64)
    offsets = np.concatenate([[0], np.cumsum(counts)[:-1]])
    for i in range(m):
        idx[i * n_i: i * n_i + counts[i]] = perm[offsets[i]: offsets[i] + counts[i]]
    return idx, counts, n_i
