"""Sparse feature containers: CSR (host/streaming) and padded ELL (device).

The paper's flagship large-scale result is CCAT — 781,265 rows at d = 47,236
with 0.16% nonzeros. Dense, the train split is ~147 GB; as index/value planes
it is ~0.5 GB. Two layouts, two jobs:

  * :class:`CSR` — the classic compressed-sparse-row triplet
    (data/indices/indptr), the natural container for *streaming ingest*
    (LibSVM chunk readers append rows for free) and host-side row surgery.
  * :class:`ELL` — a padded "ELLPACK" layout: every row stores exactly
    ``k_max`` (column-index, value) pairs as two dense (rows, k_max) planes.
    Ragged rows are padded with the inert entry ``(col=0, val=0.0)`` — a zero
    value contributes nothing to a gather-dot or a scatter-add, so kernels
    need no per-entry mask. Rectangular planes are what TPUs (and XLA on any
    backend) want: fixed shapes, contiguous lanes, one validity convention.

``partition_ell`` produces the stacked per-node planes GADGET's device loop
consumes; it composes with the PR 2 ``n_counts`` API (padded tail rows carry
all-zero vals and are excluded from sampling/mass/objective by the caller's
counts). This module is NumPy-only on purpose — it is the host substrate; the
jnp/Pallas consumers live in ``repro.kernels.hinge_subgrad``.

Block bucketing (sweep-free scheduling): the one-hot sparse kernels sweep all
``d/blk_d`` weight blocks per node even though a (B, k) minibatch touches only
a few. The helpers at the bottom of this module are the *host* statement of
the touched-block schedule the scalar-prefetch kernels consume:

  * :func:`block_map` — the compact ``(m, n_blocks_max)`` touched-block-id map
    (distinct live d-block ids first, then the inert sentinel ``n_d_blocks``,
    which callers alias to an all-zero pad block of w);
  * :func:`bucket_by_block` — entries sorted/bucketed by d-block with
    per-block entry slices (:class:`BlockBuckets`), the reference layout the
    bench uses to count blocks/FLOPs per schedule;
  * :func:`row_block_counts` / :func:`minibatch_block_bound` — the static
    ``n_blocks_max`` cap: any B sampled rows touch at most the sum of the B
    largest per-row distinct-block counts, so the bound is sound for every
    minibatch the training loop can draw;
  * :func:`frequency_remap` — rank columns by document frequency so hot
    columns share blocks. Real tf-idf text is Zipf-distributed; after the
    remap a minibatch's entries concentrate in a handful of leading blocks,
    which is what makes touched-block scheduling worth dispatching.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

__all__ = [
    "CSR", "ELL", "EllPartitions", "partition_rows",
    "BlockBuckets", "DEFAULT_BUCKET_BLK_D", "block_map", "bucket_by_block",
    "row_block_counts", "minibatch_block_bound", "frequency_remap",
    "pad_query_planes",
]

# Default d-block width for touched-block schedules: the TPU lane minimum.
# Fine blocks over-fetch the least per touched block — the opposite trade from
# the sweep schedule, which wants coarse blocks to keep its grid short.
DEFAULT_BUCKET_BLK_D = 128


@dataclass
class CSR:
    """Compressed sparse row matrix: ``data[indptr[r]:indptr[r+1]]`` are the
    nonzero values of row r at columns ``indices[indptr[r]:indptr[r+1]]``."""

    data: np.ndarray     # (nnz,) float
    indices: np.ndarray  # (nnz,) int32, 0-based column ids, < shape[1]
    indptr: np.ndarray   # (rows+1,) int64, monotone, indptr[0] == 0
    shape: tuple[int, int]

    def __post_init__(self):
        self.data = np.asarray(self.data)
        self.indices = np.asarray(self.indices, np.int32)
        self.indptr = np.asarray(self.indptr, np.int64)
        n, d = self.shape
        if self.indptr.shape != (n + 1,) or self.indptr[0] != 0:
            raise ValueError(f"bad indptr for {n} rows")
        if self.indptr[-1] != len(self.data) or len(self.data) != len(self.indices):
            raise ValueError("indptr/data/indices lengths disagree")
        if len(self.indices) and (self.indices.min() < 0 or self.indices.max() >= d):
            raise ValueError(f"column index out of range for d={d}")

    @property
    def nnz(self) -> int:
        return int(self.indptr[-1])

    @property
    def nbytes(self) -> int:
        return self.data.nbytes + self.indices.nbytes + self.indptr.nbytes

    def row_nnz(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int64)

    @classmethod
    def from_dense(cls, X: np.ndarray) -> "CSR":
        X = np.asarray(X)
        n, d = X.shape
        mask = X != 0
        indptr = np.zeros(n + 1, np.int64)
        np.cumsum(mask.sum(axis=1), out=indptr[1:])
        cols = np.nonzero(mask)[1].astype(np.int32)
        return cls(X[mask].astype(X.dtype), cols, indptr, (n, d))

    def to_dense(self, dtype=None) -> np.ndarray:
        n, d = self.shape
        X = np.zeros((n, d), dtype or self.data.dtype)
        rows = np.repeat(np.arange(n), self.row_nnz())
        X[rows, self.indices] = self.data
        return X

    def take_rows(self, idx: np.ndarray) -> "CSR":
        """New CSR holding rows ``idx`` (in that order) — partition shuffles."""
        idx = np.asarray(idx, np.int64)
        counts = self.row_nnz()[idx]
        indptr = np.zeros(len(idx) + 1, np.int64)
        np.cumsum(counts, out=indptr[1:])
        starts = self.indptr[idx]
        # gather each selected row's span: offset-within-row + row start
        flat = (np.repeat(starts - indptr[:-1], counts)
                + np.arange(int(indptr[-1]), dtype=np.int64))
        return CSR(self.data[flat], self.indices[flat], indptr,
                   (len(idx), self.shape[1]))

    def to_ell(self, k_max: int | None = None) -> "ELL":
        counts = self.row_nnz()
        widest = int(counts.max()) if len(counts) else 0
        if k_max is None:
            k_max = max(widest, 1)
        elif widest > k_max:
            raise ValueError(f"k_max={k_max} < widest row nnz {widest}")
        n, d = self.shape
        cols = np.zeros((n, k_max), np.int32)
        vals = np.zeros((n, k_max), np.float32)
        within = np.arange(self.nnz, dtype=np.int64) - np.repeat(self.indptr[:-1], counts)
        rows = np.repeat(np.arange(n), counts)
        cols[rows, within] = self.indices
        vals[rows, within] = self.data
        return ELL(cols, vals, (n, d))


@dataclass
class ELL:
    """Padded ELLPACK planes. Pad entries are ``(col=0, val=0.0)`` — inert in
    every gather-dot and scatter-add, so no mask plane is stored; anything
    that must *count* entries uses ``row_nnz()`` (vals != 0)."""

    cols: np.ndarray  # (n, k_max) int32
    vals: np.ndarray  # (n, k_max) float32
    shape: tuple[int, int]

    def __post_init__(self):
        self.cols = np.asarray(self.cols, np.int32)
        self.vals = np.asarray(self.vals, np.float32)
        if self.cols.shape != self.vals.shape or self.cols.ndim != 2:
            raise ValueError("cols/vals must be equal-shape (n, k_max) planes")
        if self.cols.shape[0] != self.shape[0]:
            raise ValueError("plane row count disagrees with shape")
        if self.cols.size and (self.cols.min() < 0 or self.cols.max() >= self.shape[1]):
            raise ValueError(f"column index out of range for d={self.shape[1]}")

    @property
    def k_max(self) -> int:
        return self.cols.shape[1]

    @property
    def nnz(self) -> int:
        return int((self.vals != 0).sum())

    @property
    def nbytes(self) -> int:
        return self.cols.nbytes + self.vals.nbytes

    def row_nnz(self) -> np.ndarray:
        return (self.vals != 0).sum(axis=1).astype(np.int64)

    @classmethod
    def from_dense(cls, X: np.ndarray, k_max: int | None = None) -> "ELL":
        return CSR.from_dense(X).to_ell(k_max)

    def to_dense(self, dtype=np.float32) -> np.ndarray:
        n, d = self.shape
        X = np.zeros((n, d), dtype)
        rows = np.repeat(np.arange(n), self.k_max).reshape(n, self.k_max)
        # += so the shared pad slot (0,0) accumulates only zeros
        np.add.at(X, (rows, self.cols), self.vals)
        return X

    def to_csr(self) -> CSR:
        live = self.vals != 0
        counts = live.sum(axis=1)
        indptr = np.zeros(self.shape[0] + 1, np.int64)
        np.cumsum(counts, out=indptr[1:])
        return CSR(self.vals[live], self.cols[live], indptr, self.shape)

    def take_rows(self, idx: np.ndarray) -> "ELL":
        idx = np.asarray(idx, np.int64)
        return ELL(self.cols[idx], self.vals[idx], (len(idx), self.shape[1]))

    def matvec(self, w: np.ndarray) -> np.ndarray:
        """X @ w as a gather-dot — the host-side oracle for the kernels."""
        return (self.vals * np.asarray(w)[self.cols]).sum(axis=1)


@dataclass
class EllPartitions:
    """Per-node stacked ELL planes for GADGET: node i's rows are
    ``cols[i], vals[i], y-padded`` with the first ``n_counts[i]`` valid.
    Produced by :func:`repro.data.svm_datasets.partition`; consumed by
    ``gadget_train(..., n_counts=...)`` in place of a dense (m, n_i, d).

    ``row_block_counts`` (lazy, cached per blk_d) feeds the static
    ``n_blocks_max`` grid bound of the scalar-prefetch kernel schedule — see
    :func:`minibatch_block_bound`."""

    cols: np.ndarray  # (m, n_i, k_max) int32
    vals: np.ndarray  # (m, n_i, k_max) float32
    d: int            # feature dimension (planes don't carry it)
    _block_counts: dict = field(default_factory=dict, repr=False, compare=False)

    @property
    def shape(self) -> tuple[int, int, int]:
        m, n_i, _ = self.cols.shape
        return (m, n_i, self.d)

    @property
    def nbytes(self) -> int:
        return self.cols.nbytes + self.vals.nbytes

    def row_block_counts(self, blk_d: int = DEFAULT_BUCKET_BLK_D) -> np.ndarray:
        """(m, n_i) distinct-d-block counts per row, cached per blk_d."""
        if blk_d not in self._block_counts:
            self._block_counts[blk_d] = row_block_counts(self.cols, self.vals, blk_d)
        return self._block_counts[blk_d]

    def block_bound(self, batch_size: int, blk_d: int = DEFAULT_BUCKET_BLK_D) -> int:
        """Static ``n_blocks_max`` cap for a batch_size-row minibatch drawn
        from any node — sound for every draw the training loop can make."""
        return minibatch_block_bound(self.cols, self.vals, batch_size, blk_d,
                                     d=self.d,
                                     counts=self.row_block_counts(blk_d))


def partition_rows(n: int, m: int, seed: int = 0) -> tuple[np.ndarray, np.ndarray, int]:
    """Shuffled near-equal split of n rows over m nodes — the one statement of
    the padded-partition convention.

    Returns ``(idx, counts, n_i)``: a permutation of ``arange(n)`` laid out so
    node i owns ``idx[i*n_i : i*n_i + counts[i]]``, per-node valid counts
    summing to exactly n (no dropped tail rows), and the common padded length
    ``n_i = ceil(n/m)``. The first ``n % m`` nodes hold one extra row.
    """
    if n < m:
        raise ValueError(f"cannot partition {n} rows over {m} nodes")
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    q, r = divmod(n, m)
    counts = np.full(m, q, np.int64)
    counts[:r] += 1
    n_i = q + (1 if r else 0)
    # scatter each node's slice to its padded offset; pad slots point at row
    # perm[0] but carry count-masked semantics (callers zero them out)
    idx = np.zeros(m * n_i, np.int64)
    offsets = np.concatenate([[0], np.cumsum(counts)[:-1]])
    for i in range(m):
        idx[i * n_i: i * n_i + counts[i]] = perm[offsets[i]: offsets[i] + counts[i]]
    return idx, counts, n_i


# ---------------------------------------------------------------------------
# Block-bucketed schedules (sweep-free sparse hot path)
# ---------------------------------------------------------------------------


def _entry_blocks(cols: np.ndarray, vals: np.ndarray, blk_d: int,
                  sentinel: int) -> np.ndarray:
    """Per-entry d-block id with pad entries (val == 0) mapped to sentinel."""
    return np.where(vals != 0, cols // blk_d, sentinel)


def row_block_counts(cols: np.ndarray, vals: np.ndarray, blk_d: int) -> np.ndarray:
    """Distinct live d-blocks per row: ``(..., k)`` planes → ``(...,)`` int32.

    Pad entries (val = 0) count nothing. One vectorized O(nnz log k) pass —
    cheap enough to run eagerly on full-shape CCAT planes.
    """
    cols = np.asarray(cols)
    if cols.shape[-1] == 0:
        return np.zeros(cols.shape[:-1], np.int32)
    blocks = np.sort(_entry_blocks(cols, np.asarray(vals), blk_d, -1), axis=-1)
    live = blocks >= 0
    first = live[..., :1]
    changed = (blocks[..., 1:] != blocks[..., :-1]) & live[..., 1:]
    return (first.sum(axis=-1) + changed.sum(axis=-1)).astype(np.int32)


def minibatch_block_bound(cols: np.ndarray, vals: np.ndarray, batch_size: int,
                          blk_d: int = DEFAULT_BUCKET_BLK_D, *,
                          d: int | None = None,
                          counts: np.ndarray | None = None) -> int:
    """Sound static cap on distinct d-blocks any batch_size-row minibatch of
    any node can touch: ``max_i ( sum of the batch_size largest per-row
    distinct-block counts within node i )``, clamped to the structural limits
    ``n_d_blocks`` and ``batch_size·k``. Repeated draws of the same row (the
    sampler draws with replacement) only shrink the union, so the top-B sum
    dominates every realizable minibatch. Always ≥ 1 so degenerate schedules
    (all-pad minibatches, k = 0 planes) still grid-launch.
    """
    cols = np.asarray(cols, np.int64)
    vals = np.asarray(vals)
    if counts is None:
        counts = row_block_counts(cols, vals, blk_d)
    counts = counts.reshape(len(counts), -1) if counts.ndim > 1 else counts[None, :]
    B = min(batch_size, counts.shape[1])
    top = -np.sort(-counts, axis=1)[:, :B]
    bound = int(top.sum(axis=1).max()) if counts.size else 0
    if d is None:
        d = int(cols.max()) + 1 if cols.size else 1
    n_d_blocks = -(-d // blk_d)
    k = cols.shape[-1]
    return max(1, min(bound, n_d_blocks, max(1, batch_size * k)))


def block_map(cols: np.ndarray, vals: np.ndarray, blk_d: int, n_d_blocks: int,
              n_blocks_max: int) -> np.ndarray:
    """Compact touched-block-id map for stacked minibatch planes: ``(m, B, k)``
    cols/vals → ``(m, n_blocks_max)`` int32, each row the node's distinct live
    d-block ids ascending, then the inert sentinel ``n_d_blocks``. The host
    twin of ``ops.ell_block_map`` (the trace-safe device version) — tests pin
    them together. Raises if a node touches more than ``n_blocks_max`` blocks
    (the cap from :func:`minibatch_block_bound` makes that unreachable)."""
    cols = np.asarray(cols)
    m = cols.shape[0]
    blocks = _entry_blocks(cols.reshape(m, -1), np.asarray(vals).reshape(m, -1),
                           blk_d, n_d_blocks)
    out = np.full((m, n_blocks_max), n_d_blocks, np.int32)
    for i in range(m):
        live = np.unique(blocks[i])
        live = live[live < n_d_blocks]
        if len(live) > n_blocks_max:
            raise ValueError(
                f"node {i} touches {len(live)} blocks > n_blocks_max={n_blocks_max}")
        out[i, :len(live)] = live
    return out


@dataclass
class BlockBuckets:
    """Entries of stacked ``(m, B, k)`` minibatch planes sorted by d-block,
    with per-block entry slices: bucket j of node i holds entries
    ``cols[i, starts[i, j]:starts[i, j+1]]`` — all in d-block
    ``block_ids[i, j]``. Empty slots carry the sentinel ``n_d_blocks`` and an
    empty slice; pad entries sort to the tail after the last live bucket
    (inert-pad convention preserved: their (col=0, val=0) payload stays
    self-masking). This is the bench/oracle layout — the kernels themselves
    keep the planes unsorted and rely on the one-hot rebase to mask
    out-of-block entries."""

    block_ids: np.ndarray  # (m, n_blocks_max) int32, sentinel = n_d_blocks
    starts: np.ndarray     # (m, n_blocks_max + 1) int64 slice offsets
    cols: np.ndarray       # (m, B*k) int32 sorted by block id
    vals: np.ndarray       # (m, B*k) float32 sorted with cols
    blk_d: int
    n_d_blocks: int

    @property
    def n_blocks_max(self) -> int:
        return self.block_ids.shape[1]

    def blocks_visited(self) -> np.ndarray:
        """(m,) live buckets per node — the blocks a touched-block schedule
        actually DMAs (sentinel slots alias one shared zero block)."""
        return (self.block_ids < self.n_d_blocks).sum(axis=1).astype(np.int64)


def bucket_by_block(cols: np.ndarray, vals: np.ndarray, blk_d: int, *,
                    d: int | None = None,
                    n_blocks_max: int | None = None) -> BlockBuckets:
    """Sort/bucket stacked ``(m, B, k)`` minibatch planes by d-block."""
    cols = np.asarray(cols, np.int32)
    vals = np.asarray(vals, np.float32)
    m = cols.shape[0]
    if d is None:
        d = int(cols.max()) + 1 if cols.size else 1
    n_d_blocks = -(-d // blk_d)
    flat_c, flat_v = cols.reshape(m, -1), vals.reshape(m, -1)
    blocks = _entry_blocks(flat_c, flat_v, blk_d, n_d_blocks)
    order = np.argsort(blocks, axis=1, kind="stable")
    sorted_b = np.take_along_axis(blocks, order, axis=1)
    if n_blocks_max is None:
        n_blocks_max = max(1, row_like_max(sorted_b, n_d_blocks))
    ids = np.full((m, n_blocks_max), n_d_blocks, np.int32)
    starts = np.zeros((m, n_blocks_max + 1), np.int64)
    for i in range(m):
        live, first = np.unique(sorted_b[i], return_index=True)
        keep = live < n_d_blocks
        live, first = live[keep], first[keep]
        if len(live) > n_blocks_max:
            raise ValueError(
                f"node {i} touches {len(live)} blocks > n_blocks_max={n_blocks_max}")
        ids[i, :len(live)] = live
        ends = np.append(first[1:], (sorted_b[i] < n_d_blocks).sum())
        starts[i, :len(live)] = first
        starts[i, len(live):] = ends[-1] if len(live) else 0
        starts[i, 1:len(live) + 1] = ends
    return BlockBuckets(ids, starts,
                        np.take_along_axis(flat_c, order, axis=1),
                        np.take_along_axis(flat_v, order, axis=1),
                        blk_d, n_d_blocks)


def row_like_max(sorted_blocks: np.ndarray, sentinel: int) -> int:
    """Max distinct live blocks over the leading axis of block-sorted ids."""
    live = sorted_blocks < sentinel
    first = live[:, :1]
    changed = (sorted_blocks[:, 1:] != sorted_blocks[:, :-1]) & live[:, 1:]
    per = first.sum(axis=1) + changed.sum(axis=1)
    return int(per.max()) if per.size else 0


def pad_query_planes(queries, rows: int, k: int) -> tuple[np.ndarray, np.ndarray]:
    """Pad a list of ragged sparse queries into one fixed-shape ELL batch.

    ``queries``: up to ``rows`` items of ``(cols_i, vals_i)`` 1-D arrays (a
    query's nonzero features). Returns ``(cols, vals)`` planes of exactly
    ``(rows, k)`` under the standard pad convention — entries beyond a query's
    nnz and whole rows beyond ``len(queries)`` carry the inert ``(0, 0.0)``.
    This is the serving micro-batcher's one statement of its bucket shapes:
    every batch it emits for a ``(rows, k)`` bucket goes through here, so the
    kernels always see one of a small fixed set of static shapes. Raises if a
    query exceeds the bucket's ``k`` (route it to a wider bucket instead of
    silently truncating features)."""
    if len(queries) > rows:
        raise ValueError(f"{len(queries)} queries > bucket rows={rows}")
    cols = np.zeros((rows, k), np.int32)
    vals = np.zeros((rows, k), np.float32)
    for i, (c, v) in enumerate(queries):
        c = np.asarray(c, np.int32).reshape(-1)
        v = np.asarray(v, np.float32).reshape(-1)
        if c.shape != v.shape:
            raise ValueError(f"query {i}: cols/vals lengths disagree")
        if len(c) > k:
            raise ValueError(f"query {i} has {len(c)} nonzeros > bucket k={k}")
        cols[i, :len(c)] = c
        vals[i, :len(v)] = v
    return cols, vals


def frequency_remap(cols: np.ndarray, vals: np.ndarray, d: int
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Relabel columns by descending document frequency (ties by old id).

    Returns ``(new_cols, perm)`` where ``perm[new] = old`` — i.e. a weight
    vector learned in remapped space maps back as ``w_old = w_new[inv]`` with
    ``inv = argsort(perm)``. A pure relabeling: margins, objectives and
    consensus are unchanged up to this permutation. Hot columns become
    low-rank and therefore share leading d-blocks — the preprocessing that
    turns Zipf-distributed text into few-touched-block minibatches (real
    LibSVM ids carry no such locality)."""
    cols = np.asarray(cols)
    vals = np.asarray(vals)
    freq = np.bincount(cols.reshape(-1)[vals.reshape(-1) != 0], minlength=d)
    perm = np.argsort(-freq, kind="stable").astype(np.int64)   # perm[new] = old
    rank = np.empty(d, np.int64)
    rank[perm] = np.arange(d)
    # pad entries stay canonical (col=0, val=0) rather than inheriting rank[0]
    return np.where(vals != 0, rank[cols], 0).astype(np.int32), perm
