"""Logical-axis sharding: model code names axes ("batch", "embed", ...);
launch code binds them to mesh axes and activates the binding around tracing.

``constrain(x, axes)`` is an identity outside an active binding, so the same
model code runs on one CPU device (tests) and on the production mesh
(dry-run/train) unchanged — the MaxText "logical axis rules" pattern.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["AxisRules", "activate", "constrain", "logical_to_spec", "param_spec", "current_rules"]

_state = threading.local()


class AxisRules:
    """logical axis name -> mesh axis (or tuple of mesh axes, or None)."""

    def __init__(self, mesh: Mesh, rules: dict[str, Any]):
        self.mesh = mesh
        self.rules = dict(rules)

    def spec(self, logical_axes: Sequence[str | None]) -> P:
        entries = []
        used: set[str] = set()
        for ax in logical_axes:
            m = self.rules.get(ax) if ax is not None else None
            # a mesh axis may appear at most once in a spec
            if m is None:
                entries.append(None)
                continue
            ms = (m,) if isinstance(m, str) else tuple(m)
            ms = tuple(a for a in ms if a not in used)
            used.update(ms)
            entries.append(ms if len(ms) > 1 else (ms[0] if ms else None))
        return P(*entries)


def current_rules() -> AxisRules | None:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def activate(rules: AxisRules):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def constrain(x: jax.Array, logical_axes: Sequence[str | None]) -> jax.Array:
    """with_sharding_constraint under the active rules; identity otherwise.

    Mesh-axis placements that do not divide the dim size are dropped (e.g.
    batch=1 decode can never shard its batch axis) — one rule set serves all
    shapes. Inside vmap the array rank is smaller than the annotation; the
    leading logical axes are dropped to match (the mapped axis is handled by
    the caller's ``spmd_axis_name``).
    """
    r = current_rules()
    if r is None:
        return x
    axes = list(logical_axes)
    if len(axes) > x.ndim:
        axes = axes[len(axes) - x.ndim:]
    elif len(axes) < x.ndim:
        axes = [None] * (x.ndim - len(axes)) + axes
    sizes = dict(zip(r.mesh.axis_names, r.mesh.devices.shape))
    spec_entries = []
    for dim, entry in zip(x.shape, tuple(r.spec(axes))):
        if entry is None:
            spec_entries.append(None)
            continue
        names = (entry,) if isinstance(entry, str) else tuple(entry)
        prod = 1
        kept = []
        for a in names:
            if dim % (prod * sizes[a]) == 0:
                kept.append(a)
                prod *= sizes[a]
        spec_entries.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(r.mesh, P(*spec_entries)))


def logical_to_spec(rules: AxisRules, logical_axes: Sequence[str | None]) -> P:
    return rules.spec(logical_axes)


def param_spec(rules: AxisRules, path: str, shape: tuple[int, ...]) -> P:
    """Fallback param spec derivation — launch.shardings assigns real specs;
    this exists for ad-hoc tools."""
    return P(*([None] * len(shape)))
