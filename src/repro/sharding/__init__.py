from repro.sharding.api import AxisRules, activate, constrain, current_rules  # noqa: F401
