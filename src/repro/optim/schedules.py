"""Learning-rate schedules, including the paper's Pegasos schedule."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["constant", "pegasos_schedule", "cosine_warmup"]


def constant(value: float):
    return lambda step: jnp.float32(value)


def pegasos_schedule(lam: float):
    """alpha_t = 1 / (lambda * t), t 1-based — paper step (d)."""
    return lambda step: 1.0 / (lam * (step.astype(jnp.float32) + 1.0))


def cosine_warmup(peak: float, warmup_steps: int, total_steps: int, floor: float = 0.0):
    def sched(step: jax.Array) -> jax.Array:
        s = step.astype(jnp.float32)
        warm = peak * (s + 1.0) / max(1, warmup_steps)  # nonzero lr at step 0
        prog = jnp.clip((s - warmup_steps) / max(1, total_steps - warmup_steps), 0.0, 1.0)
        cos = floor + (peak - floor) * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup_steps, warm, cos)

    return sched
