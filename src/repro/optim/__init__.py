"""Optimizer substrate (optax is not installed in the container; built here).

A minimal GradientTransformation protocol compatible with the familiar
(init, update) pair, plus the schedules the paper and the LM trainer need.
"""
from repro.optim.transforms import (  # noqa: F401
    GradientTransformation,
    adamw,
    apply_updates,
    chain,
    clip_by_global_norm,
    scale,
    scale_by_schedule,
    sgd,
)
from repro.optim.schedules import constant, cosine_warmup, pegasos_schedule  # noqa: F401
