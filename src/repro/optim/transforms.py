"""Gradient transformations (optax-style, self-contained).

Every transform is an (init_fn, update_fn) pair over pytrees; ``chain``
composes them; ``apply_updates`` applies the final update to params. State is
a plain pytree so it checkpoints and gossips like any other training state.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Pytree = Any
Schedule = Callable[[jax.Array], jax.Array]

__all__ = [
    "GradientTransformation",
    "chain",
    "scale",
    "scale_by_schedule",
    "clip_by_global_norm",
    "sgd",
    "adamw",
    "apply_updates",
    "global_norm",
]


class GradientTransformation(NamedTuple):
    init: Callable[[Pytree], Pytree]
    update: Callable[[Pytree, Pytree, Pytree], tuple[Pytree, Pytree]]
    # update(grads, state, params) -> (updates, new_state)


def chain(*transforms: GradientTransformation) -> GradientTransformation:
    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(grads, state, params):
        new_state = []
        for t, s in zip(transforms, state):
            grads, s = t.update(grads, s, params)
            new_state.append(s)
        return grads, tuple(new_state)

    return GradientTransformation(init, update)


def global_norm(tree: Pytree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(max_norm: float) -> GradientTransformation:
    def init(params):
        return ()

    def update(grads, state, params):
        norm = global_norm(grads)
        scale_ = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
        return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale_).astype(g.dtype), grads), state

    return GradientTransformation(init, update)


def scale(factor: float) -> GradientTransformation:
    def init(params):
        return ()

    def update(grads, state, params):
        return jax.tree.map(lambda g: g * factor, grads), state

    return GradientTransformation(init, update)


class ScheduleState(NamedTuple):
    step: jax.Array


def scale_by_schedule(schedule: Schedule) -> GradientTransformation:
    def init(params):
        return ScheduleState(step=jnp.zeros((), jnp.int32))

    def update(grads, state, params):
        lr = schedule(state.step)
        out = jax.tree.map(lambda g: g * lr.astype(g.dtype), grads)
        return out, ScheduleState(step=state.step + 1)

    return GradientTransformation(init, update)


class MomentumState(NamedTuple):
    momentum: Pytree


def sgd(learning_rate: float | Schedule, momentum: float = 0.0, nesterov: bool = False) -> GradientTransformation:
    lr_sched: Schedule = learning_rate if callable(learning_rate) else (lambda s: jnp.float32(learning_rate))

    def init(params):
        mom = jax.tree.map(jnp.zeros_like, params) if momentum else ()
        return (MomentumState(mom), ScheduleState(jnp.zeros((), jnp.int32)))

    def update(grads, state, params):
        mstate, sstate = state
        if momentum:
            new_m = jax.tree.map(lambda m, g: momentum * m + g, mstate.momentum, grads)
            eff = (jax.tree.map(lambda m, g: momentum * m + g, new_m, grads)
                   if nesterov else new_m)
            mstate = MomentumState(new_m)
        else:
            eff = grads
        lr = lr_sched(sstate.step)
        updates = jax.tree.map(lambda g: (-lr * g.astype(jnp.float32)).astype(g.dtype), eff)
        return updates, (mstate, ScheduleState(sstate.step + 1))

    return GradientTransformation(init, update)


class AdamState(NamedTuple):
    step: jax.Array
    mu: Pytree
    nu: Pytree


def adamw(
    learning_rate: float | Schedule,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> GradientTransformation:
    """AdamW with fp32 moments regardless of param dtype (bf16-safe)."""
    lr_sched: Schedule = learning_rate if callable(learning_rate) else (lambda s: jnp.float32(learning_rate))

    def init(params):
        f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(step=jnp.zeros((), jnp.int32),
                         mu=jax.tree.map(f32, params),
                         nu=jax.tree.map(f32, params))

    def update(grads, state, params):
        step = state.step + 1
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, g32)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, g32)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = lr_sched(state.step)

        def upd(m, v, p):
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype)

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, AdamState(step=step, mu=mu, nu=nu)

    return GradientTransformation(init, update)


def apply_updates(params: Pytree, updates: Pytree) -> Pytree:
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(p.dtype),
                        params, updates)
