"""Device-resident GADGET loop: parity with the seed-style host-loop
reference (same PRNG streams, same math — should agree to well under 1e-5),
mass conservation of the stacked on-device mixing matrices, and the anytime
traces coming straight off the device."""
import jax.numpy as jnp
import numpy as np
import pytest

import jax
from repro.core import topology as topo
from repro.core.gadget import GadgetConfig, gadget_train, gadget_train_reference
from tests.conftest import make_separable


def _partition(X, y, m):
    n_i = len(y) // m
    return (jnp.asarray(X[: m * n_i].reshape(m, n_i, -1)),
            jnp.asarray(y[: m * n_i].reshape(m, n_i)))


def _cfg(**kw):
    base = dict(lam=1e-3, batch_size=4, gossip_rounds=3, topology="exponential",
                max_iters=200, check_every=100, epsilon=1e-8)
    base.update(kw)
    return GadgetConfig(**base)


@pytest.mark.parametrize("topology,use_kernels", [
    ("exponential", True), ("exponential", False),
    ("random", True), ("random", False),
])
def test_device_matches_host_loop_reference(topology, use_kernels):
    X, y, _ = make_separable(n=1200, d=12, seed=0)
    Xp, yp = _partition(X, y, 6)
    cfg = _cfg(topology=topology, use_kernels=use_kernels)
    dev = gadget_train(Xp, yp, cfg)
    ref = gadget_train_reference(Xp, yp, cfg)
    assert dev.iters == ref.iters
    np.testing.assert_allclose(np.asarray(dev.w_consensus),
                               np.asarray(ref.w_consensus), atol=1e-5)
    np.testing.assert_allclose(np.asarray(dev.W), np.asarray(ref.W), atol=1e-5)
    np.testing.assert_allclose(dev.objective_trace, ref.objective_trace, rtol=1e-5)


@pytest.mark.parametrize("B,d", [(5, 130), (8, 20), (130, 513), (1, 7)])
@pytest.mark.parametrize("project", [True, False])
def test_local_half_step_padding_matches_oracle(B, d, project):
    """ops.local_half_step pads (B, d) to block multiples; padded rows carry
    y=0 and the d-pad is sliced off — must match the unpadded pure-jnp oracle
    at non-block-multiple shapes."""
    from repro.kernels.hinge_subgrad import ops
    from repro.kernels.hinge_subgrad.ref import half_step_ref

    rng = np.random.default_rng(B * 1000 + d)
    X = jnp.asarray(rng.normal(size=(B, d)).astype(np.float32))
    y = jnp.asarray(np.sign(rng.normal(size=B)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=d).astype(np.float32) * 0.1)
    t = jnp.float32(7.0)
    got = ops.local_half_step(w, X, y, lam=1e-3, t=t, project=project, interpret=True)
    want = half_step_ref(w, X, y, 1e-3, t, project=project)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=1e-5)


def test_gadget_exposes_averaged_iterate():
    X, y, _ = make_separable(n=800, d=8, seed=7)
    Xp, yp = _partition(X, y, 4)
    res = gadget_train(Xp, yp, _cfg(max_iters=100, check_every=50))
    assert res.W_avg.shape == res.W.shape
    # the averaged iterate stays inside the 1/sqrt(lam) ball like every iterate
    assert float(jnp.max(jnp.linalg.norm(res.W_avg, axis=1))) <= 1.0 / np.sqrt(1e-3) + 1e-4


def test_kernel_and_pure_half_steps_agree():
    X, y, _ = make_separable(n=800, d=10, seed=4)
    Xp, yp = _partition(X, y, 4)
    a = gadget_train(Xp, yp, _cfg(use_kernels=True))
    b = gadget_train(Xp, yp, _cfg(use_kernels=False))
    np.testing.assert_allclose(np.asarray(a.w_consensus),
                               np.asarray(b.w_consensus), atol=1e-4)


@pytest.mark.parametrize("topology", topo.DETERMINISTIC_TOPOLOGIES)
@pytest.mark.parametrize("n", [4, 7, 16])
def test_stacked_matrices_conserve_mass(topology, n):
    stack = topo.build_matrix_stack(topology, n)
    assert stack.shape == (topo.matrix_period(topology, n), n, n)
    for t, B in enumerate(stack):
        # x' = B^T x conserves total mass iff rows of B sum to 1
        np.testing.assert_allclose(B.sum(axis=1), 1.0, atol=1e-6, err_msg=f"t={t}")
        assert topo.is_doubly_stochastic(B, atol=1e-6), (topology, n, t)


def test_exponential_stack_period_covers_all_hops():
    n = 16
    stack = topo.build_matrix_stack("exponential", n)
    assert stack.shape[0] == 4  # log2(16) distinct hop matrices
    x = np.arange(n, dtype=np.float64)
    for B in stack:
        x = B.T @ x
    np.testing.assert_allclose(x, x.mean())  # full cycle = exact averaging


def test_device_random_matrix_mass_conserving():
    for i in range(5):
        B = np.asarray(topo.random_neighbor_matrix_device(jax.random.PRNGKey(i), 9))
        np.testing.assert_allclose(B.sum(axis=1), 1.0, atol=1e-6)
        np.testing.assert_allclose(np.diag(B), 0.5, atol=1e-6)  # no self-targets
        assert np.isclose(B.sum(), 9.0, atol=1e-5)


def test_torus_matrix_symmetric_doubly_stochastic():
    for n in (4, 6, 9, 16, 25):
        B = topo.torus_matrix(n)
        assert topo.is_doubly_stochastic(B, atol=1e-9)
        np.testing.assert_allclose(B, B.T)
        assert np.isfinite(topo.mixing_time_bound(B))


def test_traces_with_truncated_final_chunk():
    X, y, _ = make_separable(n=800, d=8, seed=5)
    Xp, yp = _partition(X, y, 4)
    # 130 iterations at check_every=50 → checks at 50, 100, 130
    res = gadget_train(Xp, yp, _cfg(max_iters=130, check_every=50))
    assert res.iters == 130
    assert list(res.time_trace) == [50, 100, 130]
    assert res.objective_trace.shape == (3,)
    assert np.all(np.isfinite(res.objective_trace))
    assert np.all(np.isfinite(res.eps_trace))
    assert res.epsilon == pytest.approx(float(res.eps_trace[-1]))


def test_anytime_stop_happens_on_device():
    X, y, _ = make_separable(n=800, d=8, seed=6)
    Xp, yp = _partition(X, y, 4)
    res = gadget_train(Xp, yp, _cfg(lam=1e-2, epsilon=0.5, max_iters=5000, check_every=100))
    assert res.iters < 5000
    assert res.epsilon < 0.5
    assert len(res.time_trace) == len(res.objective_trace) == len(res.eps_trace)
    assert res.time_trace[-1] == res.iters
