"""Push-Sum protocol invariants (Kempe et al. 2003 / paper Algorithm 1):
mass conservation at every round, convergence of v/w to the true average."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.push_sum import PushSumSim, exponential_schedule


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 16), st.sampled_from(["ring", "exponential", "random", "complete"]),
       st.integers(0, 3))
def test_mass_conservation_every_round(n, topology, seed):
    sim = PushSumSim(n, topology, seed=seed)
    x = jnp.asarray(np.random.default_rng(seed).normal(size=(n, 3)).astype(np.float32))
    state = sim.init((x,))
    total0 = float(jnp.sum(state.values[0]))
    for t in range(8):
        state = sim.round(state, t)
        assert np.isclose(float(jnp.sum(state.values[0])), total0, atol=1e-3)
        assert np.isclose(float(jnp.sum(state.weight)), n, atol=1e-4)


@pytest.mark.parametrize("topology,rounds,tol", [
    ("exponential", 4, 1e-5),   # exact after log2(16)=4 rounds
    ("complete", 1, 1e-5),
    ("ring", 200, 1e-3),
    ("random", 80, 1e-3),
])
def test_convergence_to_average(topology, rounds, tol):
    n = 16
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(n, 5)).astype(np.float32))
    sim = PushSumSim(n, topology, seed=2)
    st_ = sim.run((x,), rounds)
    est = st_.estimate()[0]
    true = jnp.mean(x, axis=0)
    assert float(jnp.max(jnp.abs(est - true))) < tol


def test_weighted_average_via_initial_weights():
    """Initializing mass weights with n_i makes v/w the data-weighted mean —
    the paper's sum(n_i w_i)/N consensus target."""
    n = 8
    rng = np.random.default_rng(0)
    vals = jnp.asarray(rng.normal(size=(n, 4)).astype(np.float32))
    counts = jnp.asarray(rng.integers(1, 50, size=n).astype(np.float32))
    sim = PushSumSim(n, "exponential")
    state = sim.init((vals * counts[:, None],))
    state = state._replace(weight=counts)
    for t in range(3 + 4):
        state = sim.round(state, t)
    est = state.estimate()[0]
    want = jnp.sum(vals * counts[:, None], axis=0) / jnp.sum(counts)
    assert float(jnp.max(jnp.abs(est - want))) < 1e-4


def test_rounds_for_error_monotone():
    sim = PushSumSim(16, "ring")
    assert sim.rounds_for_error(1e-4) > sim.rounds_for_error(1e-1)


def test_exponential_schedule_covers_axes():
    sched = exponential_schedule({"pod": 2, "data": 16})
    assert [r.axis for r in sched] == ["pod"] + ["data"] * 4
    assert [r.hop for r in sched] == [1, 1, 2, 4, 8]
    with pytest.raises(ValueError):
        exponential_schedule({"data": 12})
