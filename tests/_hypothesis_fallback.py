"""Minimal stand-in for `hypothesis` when the real package is unavailable.

CI installs the real hypothesis via the `[test]` extra; bare containers (no
network) fall back to this shim so the full tier-1 suite still collects and
runs. Only the surface this repo uses is implemented: ``given``, ``settings``
and the ``integers`` / ``sampled_from`` / ``booleans`` strategies. Examples are drawn from a
PRNG seeded by the test's qualified name, so runs are deterministic — no
shrinking, no example database.

conftest.py installs this module into ``sys.modules['hypothesis']`` only when
``import hypothesis`` fails; it is never used otherwise.
"""
from __future__ import annotations

import functools
import inspect
import types
import zlib

import numpy as np

DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, sample):
        self._sample = sample

    def example_from(self, rng: np.random.Generator):
        return self._sample(rng)


def _integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def _sampled_from(elements) -> _Strategy:
    pool = list(elements)
    return _Strategy(lambda rng: pool[int(rng.integers(len(pool)))])


def _booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.integers(2)))


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = _integers
strategies.sampled_from = _sampled_from
strategies.booleans = _booleans


def given(*strats: _Strategy):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # honor @settings whether applied above @given (sets it on this
            # wrapper) or below it (sets it on the original fn)
            n = getattr(wrapper, "_max_examples",
                        getattr(fn, "_max_examples", DEFAULT_MAX_EXAMPLES))
            rng = np.random.default_rng(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                drawn = [s.example_from(rng) for s in strats]
                fn(*args, *drawn, **kwargs)

        # pytest must not see the original (drawn) parameters as fixtures:
        # drop the functools.wraps introspection trail and present a bare
        # zero-argument signature.
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        wrapper._hypothesis_fallback = True
        return wrapper

    return deco


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco
