"""Shared test fixtures. NOTE: no XLA_FLAGS here by design — tests must see
the real single CPU device; only launch/dryrun.py forces 512 devices (in its
own subprocess, exercised by tests/test_dryrun_subprocess.py)."""
import sys

import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401  — real package wins when installed (CI)
except ImportError:  # bare container: install the deterministic fallback shim
    from tests import _hypothesis_fallback as _hf

    sys.modules["hypothesis"] = _hf
    sys.modules["hypothesis.strategies"] = _hf.strategies


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_separable(n=2000, d=20, noise=0.02, seed=0):
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=d)
    X = rng.normal(size=(n, d)).astype(np.float32)
    y = np.sign(X @ w_true).astype(np.float32)
    flip = rng.random(n) < noise
    y = np.where(flip, -y, y)
    return X, y, w_true
