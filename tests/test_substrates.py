"""Substrate tests: optim, checkpoint, data pipeline, libsvm parser."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import checkpoint as ckpt
from repro import optim
from repro.data import libsvm, svm_datasets, tokens


# ------------------------------------------------------------------- optim

def test_adamw_converges_quadratic():
    opt = optim.adamw(0.1)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        upd, state = opt.update(grads, state, params)
        params = optim.apply_updates(params, upd)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_sgd_momentum_converges():
    opt = optim.sgd(0.05, momentum=0.9)
    params = {"w": jnp.array([4.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        upd, state = opt.update(grads, state, params)
        params = optim.apply_updates(params, upd)
    assert abs(float(params["w"][0])) < 2e-2


def test_clip_by_global_norm():
    clip = optim.clip_by_global_norm(1.0)
    g = {"a": jnp.full((10,), 10.0)}
    out, _ = clip.update(g, (), None)
    from repro.optim.transforms import global_norm
    assert float(global_norm(out)) <= 1.0 + 1e-5


def test_schedules():
    s = optim.cosine_warmup(1.0, 10, 100)
    assert float(s(jnp.int32(0))) == pytest.approx(0.1)
    assert float(s(jnp.int32(10))) == pytest.approx(1.0)
    assert float(s(jnp.int32(100))) == pytest.approx(0.0, abs=1e-6)
    p = optim.pegasos_schedule(0.1)
    assert float(p(jnp.int32(0))) == pytest.approx(10.0)

    # bf16 moments stay fp32
    opt = optim.adamw(0.1)
    st_ = opt.init({"w": jnp.zeros(3, jnp.bfloat16)})
    assert st_.mu["w"].dtype == jnp.float32


# -------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip(tmp_path):
    tree = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
            "step": jnp.int32(7), "nested": [jnp.ones(4), jnp.zeros((2, 2))]}
    root = str(tmp_path / "ck")
    ckpt.save(root, 100, tree)
    ckpt.save(root, 200, tree)
    assert ckpt.latest_step(root) == 200
    out = ckpt.restore(root, tree)
    assert np.allclose(out["params"]["w"], np.arange(6.0).reshape(2, 3))
    assert int(out["step"]) == 7


def test_checkpoint_rotation_and_mismatch(tmp_path):
    root = str(tmp_path / "ck")
    tree = {"w": jnp.ones(3)}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(root, s, tree, keep=2)
    assert ckpt.latest_step(root) == 5
    assert len([d for d in os.listdir(root) if d.startswith("step_")]) == 2
    with pytest.raises(ValueError):
        ckpt.restore(root, {"w": jnp.ones(4)})


# -------------------------------------------------------------------- data

def test_libsvm_parser(tmp_path):
    p = tmp_path / "toy.svm"
    p.write_text("+1 1:0.5 3:2.0\n-1 2:1.5\n+1 3:1.0 4:-0.5\n")
    X, y = libsvm.load_libsvm(str(p))
    assert X.shape == (3, 4)
    assert np.allclose(y, [1, -1, 1])
    assert X[0, 0] == 0.5 and X[1, 1] == 1.5 and X[2, 3] == -0.5


def test_svm_dataset_signatures():
    ds = svm_datasets.make_dataset("reuters", scale=0.05)
    spec = svm_datasets.PAPER_DATASETS["reuters"]
    assert ds.d == spec.d
    assert set(np.unique(ds.y_train)) <= {-1.0, 1.0}
    # sparsity approx respected
    nnz_frac = (ds.X_train != 0).mean()
    assert nnz_frac < 3 * max(spec.sparsity, 1e-3) + 0.05
    # rows normalized
    norms = np.linalg.norm(ds.X_train, axis=1)
    assert np.all(norms < 1.0 + 1e-4)


def test_partition_shapes():
    # 101 rows over 10 nodes: padded to n_i=11, NO tail rows dropped — the
    # real counts come back for gadget_train's n_counts API
    X = np.arange(101 * 3, dtype=np.float32).reshape(101, 3) + 1.0
    y = np.ones(101, np.float32)
    Xp, yp, nc = svm_datasets.partition(X, y, 10)
    assert Xp.shape == (10, 11, 3) and yp.shape == (10, 11)
    assert nc.sum() == 101 and nc.max() == 11 and nc.min() == 10
    # padded rows carry X=0 / y=0 (the gadget padded-row convention)
    for i in range(10):
        assert np.all(Xp[i, nc[i]:] == 0) and np.all(yp[i, nc[i]:] == 0)
        assert np.all(Xp[i, :nc[i]] != 0)
    # every original row appears exactly once
    got = np.sort(np.concatenate([Xp[i, :nc[i], 0] for i in range(10)]))
    assert np.array_equal(got, np.sort(X[:, 0]))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 50))
def test_token_stream_deterministic(step):
    cfg = tokens.TokenStreamConfig(vocab_size=512, seq_len=32, global_batch=4, seed=1)
    a = tokens.synthetic_tokens(cfg, step)
    b = tokens.synthetic_tokens(cfg, step)
    assert np.array_equal(a, b)
    assert a.shape == (4, 33) and a.min() >= 0 and a.max() < 512


def test_batcher_host_slicing():
    cfg = tokens.TokenStreamConfig(vocab_size=64, seq_len=16, global_batch=8, seed=0)
    b = tokens.Batcher(cfg)
    g = b.global_batch(3)
    h0 = b.local_slice(3, 0, 4)
    h3 = b.local_slice(3, 3, 4)
    assert np.array_equal(h0["tokens"], g["tokens"][:2])
    assert np.array_equal(h3["tokens"], g["tokens"][6:])
    with pytest.raises(ValueError):
        b.local_slice(0, 0, 3)
