"""Quantized gossip shares (beyond-paper): bf16 payload halves wire bytes;
consensus must still hold to bf16-noise tolerance."""
import jax.numpy as jnp
import numpy as np

from repro.core.consensus import gossip_mix_stacked


def test_bf16_payload_mean_approximately_preserved():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32))
    out = gossip_mix_stacked({"w": x}, jnp.int32(0), n_nodes=8, rounds=3,
                             payload_dtype=jnp.bfloat16)["w"]
    # full exponential schedule => near-exact mean up to bf16 noise
    err = np.abs(np.asarray(out) - np.asarray(x).mean(0, keepdims=True))
    rel = err.max() / (np.abs(np.asarray(x)).max() + 1e-9)
    assert rel < 2e-2, rel


def test_bf16_payload_noise_bounded_per_round():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32))
    exact = gossip_mix_stacked({"w": x}, jnp.int32(0), n_nodes=4, rounds=1)["w"]
    quant = gossip_mix_stacked({"w": x}, jnp.int32(0), n_nodes=4, rounds=1,
                               payload_dtype=jnp.bfloat16)["w"]
    # noise <= (1 - self_share) * one bf16 ulp of the neighbor magnitude
    diff = np.abs(np.asarray(exact) - np.asarray(quant))
    bound = 0.5 * np.abs(np.asarray(jnp.roll(x, 1, axis=0))) * 2 ** -7 + 1e-6
    assert np.all(diff <= bound), diff.max()
