"""Deliverable (e) gate at CI scale: the dry-run module must lower + compile
on the production meshes. Runs in a subprocess because dryrun.py forces 512
placeholder devices before jax init (tests themselves see 1 CPU device)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


def _run(args, timeout=540):
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True, text=True, timeout=timeout, env=ENV, cwd=REPO)


def test_device_count_isolated():
    import jax
    assert len(jax.devices()) == 1  # the flag must NOT leak into tests


@pytest.mark.parametrize("arch,shape", [
    ("rwkv6-3b", "long_500k"),          # fastest full combo (recurrent decode)
    ("llama3-8b", "decode_32k"),        # KV-cache decode on the 16x16 mesh
])
def test_dryrun_single_pod(arch, shape):
    p = _run(["--arch", arch, "--shape", shape])
    assert p.returncode == 0, p.stdout[-2000:] + p.stderr[-2000:]
    assert "1 ok, 0 skipped, 0 failed" in p.stdout


def test_dryrun_multi_pod_gossip(tmp_path):
    out = tmp_path / "rec.jsonl"
    p = _run(["--arch", "rwkv6-3b", "--shape", "train_4k", "--multi-pod",
              "--consensus", "gossip", "--out", str(out)])
    assert p.returncode == 0, p.stdout[-2000:] + p.stderr[-2000:]
    rec = json.loads(out.read_text().splitlines()[-1])
    assert rec["status"] == "ok"
    assert rec["mesh"].startswith("2x16x16")
    # gossip must actually emit collective-permutes on the pod axis
    assert rec["collectives"]["count_by_op"].get("collective-permute", 0) >= 1


def test_dryrun_skip_rules():
    p = _run(["--arch", "llama3-8b", "--shape", "long_500k"])
    assert p.returncode == 0
    assert "skipped" in p.stdout and "sub-quadratic" in p.stdout
    p = _run(["--arch", "hubert-xlarge", "--shape", "decode_32k"])
    assert "encoder-only" in p.stdout
