"""Training must resume from a checkpoint onto the exact same trajectory —
pins optimizer-state serialization (Adam moments, schedule step) and the
stateless data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.configs import get_config
from repro.data.tokens import Batcher, TokenStreamConfig
from repro.launch import steps as steps_mod
from repro.models.transformer import Model


def _setup(consensus="allreduce"):
    cfg = get_config("llama3-8b").reduced(n_layers=2, d_model=64)
    model = Model(cfg)
    tcfg = steps_mod.TrainerConfig(optimizer="adamw", lr=1e-3, warmup_steps=2,
                                   total_steps=20, consensus=consensus,
                                   n_replicas=2 if consensus == "gossip" else 1)
    state = steps_mod.make_train_state(model, tcfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(steps_mod.make_train_step(model, tcfg))
    batcher = Batcher(TokenStreamConfig(cfg.vocab_size, 16, 4, seed=0))

    def batch(s):
        b = {k: jnp.asarray(v) for k, v in batcher.global_batch(s).items()}
        if consensus == "gossip":
            b = {k: v.reshape(2, 2, 16) for k, v in b.items()}
        return b

    return state, step_fn, batch


def test_resume_identical_trajectory(tmp_path):
    state, step_fn, batch = _setup()
    for s in range(5):
        state, _ = step_fn(state, batch(s))
    ckpt.save(str(tmp_path), 5, state)

    # continue 5 more steps directly
    cont = state
    direct = []
    for s in range(5, 10):
        cont, m = step_fn(cont, batch(s))
        direct.append(float(m["loss"]))

    # restore and continue — must match bit-for-bit trajectory
    restored = ckpt.restore(str(tmp_path), jax.tree.map(lambda x: x, state))
    restored = jax.tree.map(jnp.asarray, restored)
    resumed = []
    st = restored
    for s in range(5, 10):
        st, m = step_fn(st, batch(s))
        resumed.append(float(m["loss"]))
    np.testing.assert_allclose(direct, resumed, rtol=1e-6)


def test_resume_gossip_state(tmp_path):
    state, step_fn, batch = _setup("gossip")
    for s in range(3):
        state, _ = step_fn(state, batch(s))
    ckpt.save(str(tmp_path), 3, state)
    restored = jax.tree.map(jnp.asarray, ckpt.restore(str(tmp_path), state))
    a, _ = step_fn(state, batch(3))
    b, _ = step_fn(restored, batch(3))
    for x, y in zip(jax.tree.leaves(a["params"]), jax.tree.leaves(b["params"])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
