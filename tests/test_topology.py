"""Mixing-matrix invariants (paper §2.3/§3): stochasticity, connectivity,
mixing time — property-tested with hypothesis."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import topology as topo


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 64), st.integers(0, 7))
def test_deterministic_topologies_doubly_stochastic(n, t):
    for name in ("ring", "complete", "exponential"):
        B = topo.build_matrix(name, n, t=t)
        assert topo.is_doubly_stochastic(B), (name, n, t)


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 64), st.integers(0, 100))
def test_random_neighbor_mass_conserving(n, seed):
    B = topo.build_matrix("random", n, t=seed)
    # row-stochastic: each node distributes exactly its own mass
    assert np.allclose(B.sum(axis=1), 1.0)
    assert np.all(B >= 0)
    # column sums generally != 1 for a single draw — that is WHY Push-Sum
    # carries the weight scalar. Mass conservation is the column-sum total:
    assert np.isclose(B.sum(), n)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 6), st.integers(0, 20))
def test_exponential_partner_is_permutation(log_n, t):
    n = 2 ** log_n
    p = topo.exponential_partner(n, t)
    assert sorted(p.tolist()) == list(range(n))


def test_exponential_exact_after_log_rounds():
    n = 16
    x = np.arange(n, dtype=np.float64)
    for t in range(4):  # log2(16) rounds, hops 1,2,4,8
        B = topo.one_peer_exponential_matrix(n, t)
        x = B.T @ x
    assert np.allclose(x, 7.5)


def test_metropolis_arbitrary_graph():
    rng = np.random.default_rng(3)
    n = 12
    adj = rng.random((n, n)) < 0.4
    adj = adj | adj.T
    np.fill_diagonal(adj, False)
    # ensure connectivity via a ring
    for i in range(n):
        adj[i, (i + 1) % n] = adj[(i + 1) % n, i] = True
    B = topo.metropolis_matrix(adj)
    assert topo.is_doubly_stochastic(B)
    assert np.isfinite(topo.mixing_time_bound(B))


def test_mixing_time_ordering():
    # complete mixes instantly; ring mixes slower than exponential average
    n = 32
    t_complete = topo.mixing_time_bound(topo.complete_matrix(n))
    t_ring = topo.mixing_time_bound(topo.ring_matrix(n))
    assert t_complete <= 1.0 < t_ring


def test_unknown_topology_raises():
    with pytest.raises(ValueError):
        topo.build_matrix("star", 4)
