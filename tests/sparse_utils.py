"""Shared sparse test fixtures: the one statement of the "dense oracle" setup.

Every sparse parity test in this suite — the training-side
``ell_fleet_half_step`` one-hot sweep/prefetch checks in ``test_sparse.py``
AND the serving-side predict checks in ``test_serve.py`` — follows the same
recipe: draw a ragged sparse matrix, keep BOTH its dense form (the oracle
input) and its padded-ELL planes (the kernel input), and assert the kernels
land on the dense math. These helpers hold that recipe once so the oracle
setup cannot drift between the training and serving test files.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.sparse.formats import ELL

RNG = np.random.default_rng(0)


def random_sparse(n: int, d: int, nnz_max: int, rng=RNG) -> np.ndarray:
    """Dense matrix with ≤ nnz_max nonzeros per row (ragged on purpose)."""
    X = np.zeros((n, d), np.float32)
    for r in range(n):
        k = int(rng.integers(0, nnz_max + 1))
        cols = rng.choice(d, size=k, replace=False)
        X[r, cols] = rng.normal(size=k).astype(np.float32)
    return X


def ell_minibatch_planes(m: int, B: int, d: int, k: int, localized: bool = False,
                         rng=RNG):
    """Random (m, B, k) minibatch planes + labels + weights, plus the dense X
    the jnp oracles consume — the shared sweep-oracle fixture. ``localized``
    confines each node's columns to a narrow band (few touched d-blocks, the
    shape the prefetch schedules exist for)."""
    X = np.zeros((m * B, d), np.float32)
    for r in range(m * B):
        kk = int(rng.integers(0, k + 1))
        lo = (r // B) * 64 % max(1, d - 64) if localized else 0
        hi = min(d, lo + 64) if localized else d
        cc = rng.choice(np.arange(lo, hi), size=min(kk, hi - lo), replace=False)
        X[r, cc] = rng.normal(size=len(cc)).astype(np.float32)
    ell = ELL.from_dense(X)
    kw = ell.k_max
    return (X.reshape(m, B, d),
            jnp.asarray(ell.cols.reshape(m, B, kw)),
            jnp.asarray(ell.vals.reshape(m, B, kw)),
            jnp.asarray(np.sign(rng.normal(size=(m, B)) + 0.1).astype(np.float32)),
            jnp.asarray(rng.normal(size=(m, d)).astype(np.float32) * 0.1))


def random_ell_queries(n: int, d: int, k_max: int, rng=RNG):
    """Ragged serving queries: list of (cols, vals) 1-D pairs plus the ELL
    batch and dense matrix oracles for the same rows."""
    X = random_sparse(n, d, k_max, rng)
    ell = ELL.from_dense(X)
    queries = []
    for r in range(n):
        live = ell.vals[r] != 0
        queries.append((ell.cols[r][live], ell.vals[r][live]))
    return queries, ell, X
