"""Pegasos + SVM objective: sub-gradient correctness (vs autodiff where the
hinge is differentiable), objective decrease, separable accuracy."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import svm_objective as obj
from repro.core.pegasos import pegasos_train
from tests.conftest import make_separable


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 40), st.integers(2, 30), st.integers(0, 5))
def test_subgradient_matches_autodiff_off_kink(B, d, seed):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(B, d)).astype(np.float32))
    y = jnp.asarray(np.sign(rng.normal(size=B)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=d).astype(np.float32))
    margins = np.asarray(y * (X @ w))
    if np.any(np.abs(1.0 - margins) < 1e-3):
        return  # at the kink the sub-differential is a set; skip
    g_sub = obj.hinge_subgradient(w, X, y)
    g_auto = jax.grad(obj.hinge_loss)(w, X, y)
    assert float(jnp.max(jnp.abs(g_sub - g_auto))) < 1e-5


def test_projection_ball():
    lam = 0.01
    w = jnp.ones(100) * 10.0
    p = obj.project_ball(w, lam)
    assert float(jnp.linalg.norm(p)) <= 1.0 / np.sqrt(lam) + 1e-4
    small = jnp.ones(4) * 0.01
    assert np.allclose(obj.project_ball(small, lam), small)


def test_pegasos_accuracy_and_objective():
    X, y, _ = make_separable(n=3000, d=20, seed=1)
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    res = pegasos_train(Xj, yj, lam=1e-3, n_iters=1500, batch_size=8, seed=0)
    # assert on the iterate average — the vector Theorem 2 bounds; the last
    # iterate is minibatch-noisy and its accuracy varies with the PRNG version
    acc = float(obj.accuracy(res.w_avg, Xj, yj))
    assert acc > 0.93, acc
    # objective of the trained w beats the zero vector by a wide margin
    f_w = float(obj.primal_objective(res.w, Xj, yj, 1e-3))
    f_0 = float(obj.primal_objective(jnp.zeros_like(res.w), Xj, yj, 1e-3))
    assert f_w < 0.6 * f_0


def test_pegasos_trace():
    X, y, _ = make_separable(n=500, d=10, seed=2)
    res = pegasos_train(jnp.asarray(X), jnp.asarray(y), lam=1e-2, n_iters=300,
                        batch_size=4, trace_every=50)
    from repro.core.pegasos import pegasos_objective_trace
    tr = np.asarray(pegasos_objective_trace(res))
    assert len(tr) >= 4 and tr[-1] < tr[0]
