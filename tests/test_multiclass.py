"""Multi-class GADGET (paper §5 future work): one-vs-rest over shared gossip."""
import jax.numpy as jnp
import numpy as np

from repro.core.gadget import GadgetConfig
from repro.core.multiclass import gadget_train_multiclass, predict_multiclass


def _make_multiclass(n=3000, d=16, C=4, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(C, d)) * 3.0
    y = rng.integers(0, C, size=n)
    X = centers[y] + rng.normal(size=(n, d))
    return X.astype(np.float32), y.astype(np.int32)


def test_multiclass_gadget_learns():
    m, C = 8, 4
    X, y = _make_multiclass()
    n_i = len(y) // m
    Xp = jnp.asarray(X[: m * n_i].reshape(m, n_i, -1))
    yp = jnp.asarray(y[: m * n_i].reshape(m, n_i))
    res = gadget_train_multiclass(
        Xp, yp, C, GadgetConfig(lam=1e-3, batch_size=8, gossip_rounds=3,
                                max_iters=1200, check_every=300))
    pred = predict_multiclass(res.w_consensus, jnp.asarray(X))
    acc = float(jnp.mean((pred == jnp.asarray(y)).astype(jnp.float32)))
    assert acc > 0.85, acc
    # per-node models agree with the consensus prediction on most points
    pred0 = predict_multiclass(res.W[0], jnp.asarray(X))
    agree = float(jnp.mean((pred0 == pred).astype(jnp.float32)))
    assert agree > 0.95, agree
