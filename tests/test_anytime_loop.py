"""The live train-to-serve loop, end to end and at its edges: segmented
streaming trainer vs gadget_train (bit-identical trajectories), background
publisher (monotone versions, LATEST pointer discipline), hot-swap under load
(compile count flat, no dropped in-flight requests), torn-checkpoint
invisibility, version skip + rollback, and the streaming CSR query path
(dump_libsvm → iter_libsvm_chunks → submit_csr round trip)."""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.checkpoint import io as ckpt_io
from repro.core.gadget import GadgetConfig, gadget_train, gadget_train_stream
from repro.data.libsvm import dump_libsvm, iter_libsvm_chunks
from repro.serve import (MicroBatcher, SvmServer, TrainPublisher,
                         bucket_ladder, from_checkpoint)


def _toy_parts(m=3, n_i=20, d=32, seed=0):
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=d)
    X = rng.normal(size=(m * n_i, d)).astype(np.float32)
    y = np.sign(X @ w_true).astype(np.float32)
    return jnp.asarray(X.reshape(m, n_i, d)), jnp.asarray(y.reshape(m, n_i))


def _toy_cfg(max_iters=24, **kw):
    base = dict(lam=1e-3, batch_size=3, gossip_rounds=2, max_iters=max_iters,
                check_every=10, epsilon=0.0, use_kernels=False)
    base.update(kw)
    return GadgetConfig(**base)


# ---------------------------------------------------------------------------
# Segmented streaming trainer
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("segment_iters", [5, 7, 24, 40])
def test_stream_trajectory_bitmatches_gadget_train(segment_iters):
    """Segment boundaries (divisor, non-divisor, exact, over-length) never
    perturb the trajectory: final weights bit-match one gadget_train call."""
    X, y = _toy_parts()
    cfg = _toy_cfg()
    ref = gadget_train(X, y, cfg)
    segs = list(gadget_train_stream(X, y, cfg, segment_iters=segment_iters))
    assert segs[-1].done and not any(s.done for s in segs[:-1])
    assert segs[-1].iteration == ref.iters
    assert bool(jnp.all(segs[-1].W == ref.W))
    np.testing.assert_array_equal(segs[-1].w_consensus,
                                  np.asarray(ref.w_consensus))
    its = [s.iteration for s in segs]
    assert its == sorted(its) and len(set(its)) == len(its)  # monotone


def test_stream_epsilon_stop_and_validation():
    X, y = _toy_parts()
    # epsilon huge -> first segment converges and is marked done
    segs = list(gadget_train_stream(X, y, _toy_cfg(epsilon=1e9),
                                    segment_iters=4))
    assert len(segs) == 1 and segs[0].done and segs[0].iteration == 4
    with pytest.raises(ValueError):
        next(gadget_train_stream(X, y, _toy_cfg(), segment_iters=0))
    with pytest.raises(ValueError):
        next(gadget_train_stream(X, y, _toy_cfg(max_iters=0), segment_iters=4))


# ---------------------------------------------------------------------------
# Publisher + LATEST pointer
# ---------------------------------------------------------------------------


def test_publisher_publishes_monotone_versions(tmp_path):
    X, y = _toy_parts()
    root = str(tmp_path / "ckpts")
    pub = TrainPublisher(X, y, _toy_cfg(max_iters=20), root=root,
                         segment_iters=5).start()
    final = pub.join()
    assert pub.error is None and not pub.running
    assert pub.published == [5, 10, 15, 20] == sorted(pub.published)
    assert final.iteration == 20 and final.done
    assert ckpt.read_latest(root) == 20
    # every published version is a complete, loadable serving export
    for step in pub.published:
        w, extra = from_checkpoint(root, step)
        assert extra["iteration"] == step and w.shape == (32,)
        assert extra["lam"] == pytest.approx(1e-3)
    # keep=0 retained every version (no rotation races for readers)
    assert ckpt.latest_step(root) == 20 and len(pub.published) == 4


def test_publisher_surfaces_training_errors(tmp_path):
    X, y = _toy_parts()
    bad = _toy_cfg()._replace(topology="not-a-topology")
    pub = TrainPublisher(X, y, bad, root=str(tmp_path), segment_iters=5).start()
    # both supervisor entry points surface the crash: a completed wait()
    # raises (a parked supervisor can't mistake a crash for success) ...
    with pytest.raises(RuntimeError):
        pub.wait(timeout=30)
    assert pub.error is not None
    # ... and join() raises on the caller's thread
    with pytest.raises(RuntimeError):
        pub.join()


def test_publisher_poisoned_root_retries_then_fails(tmp_path):
    """Regression: a checkpoint root that can never be created (a regular
    file squats on the path) must fail the run loudly after exhausting the
    publish retries — not hang, not pass silently."""
    X, y = _toy_parts()
    blocker = tmp_path / "not-a-dir"
    blocker.write_text("")
    pub = TrainPublisher(X, y, _toy_cfg(max_iters=10), root=str(blocker),
                         segment_iters=5, publish_retries=2,
                         publish_backoff=0.001).start()
    with pytest.raises(RuntimeError):
        pub.join()
    assert isinstance(pub.error, OSError)
    assert pub.publish_retries_used == 2  # all retries spent on segment 1
    assert pub.published == []


def test_publisher_retry_recovers_transient_failure(tmp_path, monkeypatch):
    """A transient write failure (first N attempts raise OSError) is absorbed
    by the backoff loop: the run completes, every version lands."""
    from repro.serve import publisher as pub_mod
    real = pub_mod.to_checkpoint
    fail_twice = {"left": 2}

    def flaky(*a, **kw):
        if fail_twice["left"] > 0:
            fail_twice["left"] -= 1
            raise OSError("transient write failure")
        return real(*a, **kw)

    monkeypatch.setattr(pub_mod, "to_checkpoint", flaky)
    X, y = _toy_parts()
    root = str(tmp_path / "ckpts")
    pub = TrainPublisher(X, y, _toy_cfg(max_iters=10), root=root,
                         segment_iters=5, publish_retries=3,
                         publish_backoff=0.001).start()
    final = pub.join()
    assert pub.error is None and final.done
    assert pub.published == [5, 10]
    assert pub.publish_retries_used == 2
    assert ckpt.read_latest(root) == 10


def test_publisher_rejects_bad_resume_and_retries():
    X, y = _toy_parts()
    with pytest.raises(ValueError):
        TrainPublisher(X, y, _toy_cfg(), root="/tmp/x", segment_iters=5,
                       resume="not-latest")
    with pytest.raises(ValueError):
        TrainPublisher(X, y, _toy_cfg(), root="/tmp/x", segment_iters=5,
                       publish_retries=-1)


def test_save_advances_pointer_monotonically(tmp_path):
    root = str(tmp_path)
    ckpt.save(root, 7, {"w": np.ones(4, np.float32)}, keep=0)
    ckpt.save(root, 9, {"w": np.ones(4, np.float32)}, keep=0)
    assert ckpt.read_latest(root) == 9
    # saving an *older* step never moves the pointer backward
    ckpt.save(root, 3, {"w": np.ones(4, np.float32)}, keep=0)
    assert ckpt.read_latest(root) == 9
    # explicit rollback does
    ckpt.point_latest(root, 3)
    assert ckpt.read_latest(root) == 3
    with pytest.raises(FileNotFoundError):
        ckpt.point_latest(root, 555)


def test_corrupt_pointer_falls_back_to_scan(tmp_path):
    root = str(tmp_path)
    ckpt.save(root, 4, {"w": np.ones(4, np.float32)})
    with open(os.path.join(root, "LATEST"), "w") as fh:
        fh.write("not-a-step\n")
    assert ckpt.read_latest(root) == 4  # unparseable pointer -> scan
    with open(os.path.join(root, "LATEST"), "w") as fh:
        fh.write("999\n")
    assert ckpt.read_latest(root) == 4  # dangling pointer -> scan


# ---------------------------------------------------------------------------
# Torn checkpoints are invisible
# ---------------------------------------------------------------------------


def _tear(root, step, keep_file):
    """Fabricate a torn step dir: only ``keep_file`` of the two files."""
    path = os.path.join(root, f"step_{step:09d}")
    os.makedirs(path)
    if keep_file == "manifest":
        with open(os.path.join(path, "manifest.json"), "w") as fh:
            json.dump({"version": 1, "step": step, "n_leaves": 1}, fh)
    elif keep_file == "arrays":
        np.savez(os.path.join(path, "arrays.npz"), leaf_0=np.ones(4))
    return path


@pytest.mark.parametrize("keep_file", ["manifest", "arrays", "neither"])
def test_torn_checkpoint_never_loaded(tmp_path, keep_file):
    root = str(tmp_path)
    ckpt.save(root, 5, {"w": np.ones(4, np.float32)})
    _tear(root, 8, keep_file)  # newer but torn
    assert ckpt.latest_step(root) == 5
    assert ckpt.read_latest(root) == 5
    with pytest.raises(FileNotFoundError):
        ckpt.point_latest(root, 8)  # cannot aim the pointer at a torn dir


def test_staging_dirs_invisible_to_discovery(tmp_path):
    root = str(tmp_path)
    ckpt.save(root, 2, {"w": np.ones(4, np.float32)})
    os.makedirs(os.path.join(root, ".tmp_ckpt_inflight"))
    np.savez(os.path.join(root, ".tmp_ckpt_inflight", "arrays.npz"),
             leaf_0=np.ones(4))
    assert ckpt.latest_step(root) == 2
    assert ckpt.read_latest(root) == 2


# ---------------------------------------------------------------------------
# Hot swap: watch / maybe_reload / swap_weights
# ---------------------------------------------------------------------------


def _publish_run(tmp_path, max_iters=20, segment_iters=5):
    X, y = _toy_parts()
    root = str(tmp_path / "ckpts")
    pub = TrainPublisher(X, y, _toy_cfg(max_iters=max_iters), root=root,
                         segment_iters=segment_iters).start()
    pub.join()
    return root, pub


def test_watch_skip_and_rollback(tmp_path):
    root, pub = _publish_run(tmp_path)
    ckpt.point_latest(root, pub.published[0])
    srv = SvmServer.watch(root, use_kernels=False)
    assert srv.meta["iteration"] == pub.published[0]
    assert srv.maybe_reload() is None  # unchanged pointer -> no-op
    # version skip: jump straight past intermediate versions to the newest
    ckpt.point_latest(root, pub.published[-1])
    assert srv.maybe_reload() == pub.published[-1]
    assert srv.meta["iteration"] == pub.published[-1]
    # rollback: pointer moves backward, server follows
    ckpt.point_latest(root, pub.published[1])
    assert srv.maybe_reload() == pub.published[1]
    assert srv.stats()["swaps"] == 2 and srv.stats()["reload_errors"] == 0


def test_maybe_reload_survives_bad_checkpoint(tmp_path):
    root, pub = _publish_run(tmp_path)
    srv = SvmServer.watch(root, use_kernels=False)
    w_before = srv.W.copy()
    # a structurally-complete dir with garbage arrays: discovery accepts it,
    # restore fails — the server must keep serving and count the error
    path = os.path.join(root, "step_000000099")
    os.makedirs(path)
    with open(os.path.join(path, "manifest.json"), "w") as fh:
        fh.write("{ not json")
    with open(os.path.join(path, "arrays.npz"), "w") as fh:
        fh.write("not an npz")
    ckpt_io._write_pointer(root, 99)
    assert srv.maybe_reload() is None
    assert srv.stats()["reload_errors"] == 1
    np.testing.assert_array_equal(srv.W, w_before)
    # a later good publish recovers the watcher
    ckpt.point_latest(root, pub.published[0])
    assert srv.maybe_reload() == pub.published[0]


def test_unwatched_server_refuses_maybe_reload():
    srv = SvmServer(np.zeros(8, np.float32), use_kernels=False)
    with pytest.raises(RuntimeError):
        srv.maybe_reload()


def test_swap_rejects_shape_change():
    srv = SvmServer(np.zeros(8, np.float32), use_kernels=False)
    with pytest.raises(ValueError):
        srv.swap_weights(np.zeros(16, np.float32))
    with pytest.raises(ValueError):
        srv.swap_weights(np.zeros((2, 8), np.float32))


def test_swap_under_load_no_recompile_no_drops(tmp_path):
    """The acceptance-criteria test: ≥2 hot swaps under live traffic leave
    ``distinct_shapes`` (the measured compile count) unchanged, and every
    in-flight request is answered exactly once."""
    d = 32
    root, pub = _publish_run(tmp_path)
    ckpt.point_latest(root, pub.published[0])
    srv = SvmServer.watch(root, use_kernels=False, blk_d=16)
    mb = MicroBatcher(buckets=bucket_ladder(12, rows=4, min_k=4, d=d, blk_d=16))
    rng = np.random.default_rng(1)

    def some_queries(n):
        out = []
        for _ in range(n):
            nnz = int(rng.integers(1, 9))
            cols = rng.choice(d, size=nnz, replace=False).astype(np.int32)
            out.append((cols, rng.normal(size=nnz).astype(np.float32)))
        return out

    answered = set()
    submitted = []
    # warm every rung's shape once, then measure the compile count
    for b in mb.buckets:
        cols = rng.choice(d, size=b.k, replace=False).astype(np.int32)
        submitted.append(mb.submit(cols, rng.normal(size=b.k)
                                   .astype(np.float32)))
    for cols, vals in some_queries(6):
        submitted.append(mb.submit(cols, vals))
    answered |= set(mb.drain(srv.scorer_for()))
    shapes_before = srv.stats()["distinct_shapes"]
    assert shapes_before >= 1

    steps = pub.published[1:]  # >= 2 further versions to swap through
    assert len(steps) >= 2
    for step in steps:
        # requests in flight *across* the swap: submitted before, drained after
        for cols, vals in some_queries(5):
            submitted.append(mb.submit(cols, vals))
        ckpt.point_latest(root, step)
        assert srv.maybe_reload() == step
        out = mb.drain(srv.scorer_for())
        assert not (answered & set(out))  # no rid answered twice
        answered |= set(out)

    assert srv.stats()["swaps"] == len(steps)
    assert srv.stats()["distinct_shapes"] == shapes_before  # no recompiles
    assert answered == set(submitted)  # no request dropped
    assert mb.pending == 0


# ---------------------------------------------------------------------------
# Crash-resume: embedded train state + resume="latest"
# ---------------------------------------------------------------------------


def test_checkpoint_train_state_roundtrip(tmp_path):
    from repro.core.gadget import TrainState
    from repro.serve.snapshot import (Snapshot, latest_train_state,
                                      to_checkpoint, train_state_from_checkpoint)
    root = str(tmp_path)
    m, d = 3, 8
    ts = TrainState(iteration=7,
                    W=np.arange(m * d, dtype=np.float32).reshape(m, d),
                    W_sum=np.full((m, d), 2.5, np.float32))
    snap = Snapshot(iteration=7, w=np.arange(d, dtype=np.float32), objective=0.5)
    to_checkpoint(snap, root, train_state=ts, lam=0.1)
    # serving load is unchanged by the extra leaves
    w, extra = from_checkpoint(root)
    np.testing.assert_array_equal(w, snap.w)
    assert extra["train_state"]["iteration"] == 7
    # exact train-state round trip
    back = train_state_from_checkpoint(root)
    assert back.iteration == 7
    np.testing.assert_array_equal(np.asarray(back.W), np.asarray(ts.W))
    np.testing.assert_array_equal(np.asarray(back.W_sum), np.asarray(ts.W_sum))
    assert latest_train_state(root).iteration == 7
    # int8 export carries train state too (weights quantize, state doesn't)
    root_q = str(tmp_path / "q")
    to_checkpoint(snap, root_q, quantize="int8", train_state=ts)
    np.testing.assert_array_equal(
        np.asarray(train_state_from_checkpoint(root_q).W), np.asarray(ts.W))


def test_train_state_probe_cold_start_paths(tmp_path):
    from repro.serve.snapshot import (Snapshot, latest_train_state,
                                      to_checkpoint, train_state_from_checkpoint)
    # no directory / no checkpoint yet -> lenient None
    assert latest_train_state(str(tmp_path / "nowhere")) is None
    # checkpoint without embedded state -> lenient None, strict ValueError
    root = str(tmp_path)
    to_checkpoint(Snapshot(3, np.ones(4, np.float32), 0.1), root)
    assert latest_train_state(root) is None
    with pytest.raises(ValueError, match="no train state"):
        train_state_from_checkpoint(root)


def test_publisher_kill_and_resume_bit_identical(tmp_path):
    """The acceptance-criteria test: a publisher killed between segments and
    restarted with ``resume="latest"`` finishes with weights bit-identical to
    the uninterrupted run."""
    from repro.core.gadget import TrainState
    from repro.serve.snapshot import Snapshot, to_checkpoint
    X, y = _toy_parts()
    cfg = _toy_cfg(max_iters=20)
    # uninterrupted run
    root_full = str(tmp_path / "full")
    pub_full = TrainPublisher(X, y, cfg, root=root_full, segment_iters=5,
                              save_train_state=True).start()
    final_full = pub_full.join()
    # "crashed" run: publish exactly one segment, then die
    root = str(tmp_path / "crashed")
    for seg in gadget_train_stream(X, y, cfg, segment_iters=5):
        to_checkpoint(Snapshot(seg.iteration, np.asarray(seg.w_consensus),
                               seg.objective), root, lam=cfg.lam,
                      train_state=TrainState(seg.iteration, seg.W, seg.W_sum))
        break
    # restart from the published state
    pub2 = TrainPublisher(X, y, cfg, root=root, segment_iters=5,
                          save_train_state=True, resume="latest").start()
    final2 = pub2.join()
    assert pub2.resumed_from == 5
    assert pub2.published == [10, 15, 20]  # continues, never re-publishes 5
    assert final2.iteration == final_full.iteration
    np.testing.assert_array_equal(np.asarray(final2.w_consensus),
                                  np.asarray(final_full.w_consensus))
    assert bool(jnp.all(final2.W == final_full.W))


def test_publisher_resume_latest_falls_back_to_fresh(tmp_path):
    """resume="latest" on an empty root (or one whose checkpoints carry no
    train state) starts from scratch instead of failing."""
    X, y = _toy_parts()
    root = str(tmp_path / "ckpts")
    pub = TrainPublisher(X, y, _toy_cfg(max_iters=10), root=root,
                         segment_iters=5, resume="latest").start()
    final = pub.join()
    assert pub.resumed_from is None
    assert pub.published == [5, 10] and final.done


# ---------------------------------------------------------------------------
# Reload quarantine
# ---------------------------------------------------------------------------


def _poison_step(root, step):
    """A structurally-complete step dir whose contents can never load."""
    path = os.path.join(root, f"step_{step:09d}")
    os.makedirs(path)
    with open(os.path.join(path, "manifest.json"), "w") as fh:
        fh.write("{ not json")
    with open(os.path.join(path, "arrays.npz"), "w") as fh:
        fh.write("not an npz")
    ckpt_io._write_pointer(root, step)


def test_watcher_quarantines_repeated_bad_step(tmp_path):
    root, pub = _publish_run(tmp_path)
    srv = SvmServer.watch(root, use_kernels=False, reload_quarantine=3)
    w_before = srv.W.copy()
    _poison_step(root, 99)
    # three strikes, each counted, model untouched
    for k in range(3):
        assert srv.maybe_reload() is None
        assert srv.stats()["reload_errors"] == k + 1
    assert srv.stats()["quarantined"] == 1
    assert srv.quarantined_steps == [99]
    # quarantined: further polls stop burning I/O on the bad step
    assert srv.maybe_reload() is None
    assert srv.stats()["reload_errors"] == 3
    np.testing.assert_array_equal(srv.W, w_before)
    # rollback to a known-good step still swaps normally
    ckpt.point_latest(root, pub.published[0])
    assert srv.maybe_reload() == pub.published[0]
    assert srv.stats()["swaps"] == 1


def test_quarantine_scoped_per_step(tmp_path):
    """A new (different) published step gets a fresh chance after an earlier
    step was quarantined."""
    root, pub = _publish_run(tmp_path)
    ckpt.point_latest(root, pub.published[0])
    srv = SvmServer.watch(root, use_kernels=False, reload_quarantine=1)
    _poison_step(root, 99)
    assert srv.maybe_reload() is None
    assert srv.quarantined_steps == [99]
    # a later good publish supersedes the quarantined one
    ckpt.point_latest(root, pub.published[-1])
    assert srv.maybe_reload() == pub.published[-1]
    assert srv.stats()["swaps"] == 1 and srv.stats()["quarantined"] == 1


def test_server_rejects_bad_quarantine():
    with pytest.raises(ValueError):
        SvmServer(np.zeros(8, np.float32), use_kernels=False,
                  reload_quarantine=0)


# ---------------------------------------------------------------------------
# Streaming query path: dump -> chunks -> submit_csr
# ---------------------------------------------------------------------------


def test_dump_iter_submit_csr_roundtrip(tmp_path):
    d = 32
    rng = np.random.default_rng(2)
    Xq = rng.normal(size=(13, d)).astype(np.float32)
    Xq[np.abs(Xq) < 1.1] = 0.0  # ragged sparsity, incl. possibly-empty rows
    w = rng.normal(size=d).astype(np.float32)
    yq = np.where(Xq @ w >= 0, 1.0, -1.0).astype(np.float32)
    path = str(tmp_path / "q.svm")
    dump_libsvm(path, Xq, yq)

    srv = SvmServer(w, use_kernels=False, blk_d=16)
    mb = MicroBatcher(buckets=bucket_ladder(d, rows=4, d=d, blk_d=16))
    got_scores, got_labels = {}, []
    row = 0
    for csr, labels in iter_libsvm_chunks(path, d, chunk_rows=5):
        assert labels.shape[0] == csr.shape[0] <= 5
        rids = mb.submit_csr(csr)
        out = mb.drain(srv.scorer_for())
        assert set(rids) <= set(out)
        for rid in rids:
            got_scores[row] = out[rid][0]
            row += 1
        got_labels.extend(labels)
    assert row == 13
    # scores match the dense model applied to the original rows
    want = Xq @ w
    got = np.array([float(np.asarray(got_scores[i]).reshape(())) for i in range(13)])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(got_labels), yq)


def test_submit_csr_rejects_oversize_rows():
    mb = MicroBatcher(buckets=bucket_ladder(4, rows=2, min_k=2, d=64))

    class FatCSR:
        data = np.ones(8, np.float32)
        indices = np.arange(8, dtype=np.int32)
        indptr = np.array([0, 8], np.int64)

    with pytest.raises(ValueError):
        mb.submit_csr(FatCSR())
    assert mb.pending == 0  # nothing half-enqueued
