"""Unit tests for the sharding rule engine: divisibility fallback, rule
matching per family, gossip/zero1 axis stripping. Runs on the single CPU
device (specs are pure metadata; no mesh placement happens here)."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch import shardings as shard
from repro.models.transformer import Model


class FakeMesh:
    """Duck-typed mesh: shardings._spec only reads axis_names/devices.shape."""
    def __init__(self, sizes: dict):
        self.axis_names = tuple(sizes)
        import numpy as np
        self.devices = np.empty(tuple(sizes.values()), dtype=object)


MESH = FakeMesh({"data": 16, "model": 16})


def _specs_for(arch, **kw):
    cfg = get_config(arch)
    m = Model(cfg, dtype=jnp.bfloat16, param_dtype=jnp.bfloat16)
    shapes = jax.eval_shape(m.init, jax.random.PRNGKey(0))
    return shapes, shard.param_specs(MESH, shapes, **kw)


def test_divisibility_fallback():
    # Hkv=8 cannot shard on a 16-way axis; D=4096 can
    s = shard._spec(MESH, (4096, 8, 128), "data", "model", None)
    assert s == P("data", None, None)
    s = shard._spec(MESH, (4096, 32, 128), "data", "model", None)
    assert s == P("data", "model", None)


def test_axis_used_once():
    s = shard._spec(MESH, (4096, 4096), ("model", "data"), "model")
    # second dim cannot reuse model
    assert s == P(("model", "data"), None)


def test_dense_param_rules():
    shapes, specs = _specs_for("llama3-8b")
    blk = specs["stages"][0]["blk0"]
    assert blk["attn"]["wq"] == P(None, "data", "model", None)
    assert blk["ch"]["wi"]["w"] == P(None, "data", "model")
    assert blk["ch"]["wo"]["w"] == P(None, "model", "data")
    assert specs["embed"]["table"] == P("model", "data")


def test_moe_param_rules():
    shapes, specs = _specs_for("qwen2-moe-a2.7b")
    blk = specs["stages"][0]["blk0"]
    assert blk["ch"]["wi"] == P(None, None, "data", "model")     # (E,D,F)
    assert blk["ch"]["shared"]["wi"]["w"] == P(None, "data", "model")


def test_zero1_strips_data():
    _, specs = _specs_for("llama3-8b", mode="zero1")
    blk = specs["stages"][0]["blk0"]
    assert blk["ch"]["wi"]["w"] == P(None, None, "model")
    assert specs["embed"]["table"] == P("model", None)


def test_gossip_adds_replica_axis_and_strips_it_from_core():
    cfg = get_config("llama3-8b")
    m = Model(cfg, dtype=jnp.bfloat16, param_dtype=jnp.bfloat16)
    shapes = jax.eval_shape(m.init, jax.random.PRNGKey(0))
    stacked = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((16,) + s.shape, s.dtype), shapes)
    specs = shard.param_specs(MESH, stacked, gossip=True, replica_axis="data")
    blk = specs["stages"][0]["blk0"]
    # leading replica axis on `data`, and no other dim may use `data`
    assert blk["ch"]["wi"]["w"][0] == "data"
    assert "data" not in jax.tree.leaves(tuple(blk["ch"]["wi"]["w"][1:]))
    assert specs["embed"]["table"][0] == "data"


def test_cache_spec_tree():
    cfg = get_config("llama3-8b")
    m = Model(cfg, dtype=jnp.bfloat16, param_dtype=jnp.bfloat16)
    cache_shapes = jax.eval_shape(lambda: m.init_cache(128, 32768, jnp.bfloat16))
    specs = shard.cache_spec_tree(MESH, cache_shapes)
    kv = specs[0]["blk0"]
    assert kv.k == P(None, "data", "model", None, None)  # (R,B,S,Hkv,Dh)
