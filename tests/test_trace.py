"""Causal tracing + gossip health observatory (docs/ARCHITECTURE.md §10).

Covers the explicit-propagation ``TraceContext`` (cross-thread linkage, no
thread-locals), exception-path span closure (score_fn raise, publisher
OSError) with the ``error`` attribute, the version-lineage chain
train.segment → publish → swap → first-score end to end (including
publisher retries keeping one trace_id, quarantined reloads closing the
swap span with ``error="quarantined"``, and kill-and-resume linking the
fresh trace to the pre-crash lineage), sampled request-fate traces with
reservoir retention, the lineage CLI, the observatory's
straggler/dead/mass-leak flags, and the top console's frames.
"""
import json
import threading

import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro import serve
from repro import telemetry as tm
from repro.checkpoint import io as ckpt_io
from repro.core.faults import FaultPlan
from repro.core.gadget import (GadgetConfig, TrainState, gadget_train,
                               gadget_train_stream)
from repro.serve import MicroBatcher, SvmServer, TrainPublisher
from repro.serve.snapshot import Snapshot, to_checkpoint
from repro.telemetry import top as tmtop
from repro.telemetry import trace as tmtr
from repro.telemetry.registry import Registry

RNG = np.random.default_rng(0)


def _toy_parts(m=3, n_i=20, d=32, seed=0):
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=d)
    X = rng.normal(size=(m * n_i, d)).astype(np.float32)
    y = np.sign(X @ w_true).astype(np.float32)
    return X.reshape(m, n_i, d), y.reshape(m, n_i)


def _toy_cfg(max_iters=10, **kw):
    base = dict(lam=1e-3, batch_size=3, gossip_rounds=2, max_iters=max_iters,
                check_every=5, epsilon=0.0, use_kernels=False)
    base.update(kw)
    return GadgetConfig(**base)


def _sinked_registry(tmp_path, name="trace.jsonl"):
    """Registry streaming span/event records to a JSONL file."""
    path = tmp_path / name
    reg = Registry()
    reg.attach_sink(tm.JsonlSink(path))
    return reg, path


def _records(reg, path):
    reg.detach_sink()
    return tm.read_jsonl(path)


def _buckets(rows=2, k=4):
    return (serve.Bucket(rows, k, rows * k),)


def _query(nnz=2, d=64, rng=RNG):
    cols = np.sort(rng.choice(d, size=nnz, replace=False)).astype(np.int32)
    return cols, rng.normal(size=nnz).astype(np.float32)


def _ok(b, cols, vals):
    return np.zeros(b.rows), np.ones(b.rows)


# ---------------------------------------------------------------------------
# TraceContext: explicit propagation
# ---------------------------------------------------------------------------


class TestTraceContext:
    def test_child_links_parent_same_trace(self):
        root = tmtr.TraceContext.new()
        assert root.parent_id is None
        child = root.child()
        assert child.trace_id == root.trace_id
        assert child.parent_id == root.span_id
        assert child.span_id != root.span_id
        grand = child.child()
        assert grand.trace_id == root.trace_id
        assert grand.parent_id == child.span_id

    def test_extra_roundtrip_and_malformed(self):
        root = tmtr.TraceContext.new()
        assert tmtr.TraceContext.from_extra(root.to_extra()) == root
        assert tmtr.TraceContext.from_extra(None) is None
        assert tmtr.TraceContext.from_extra("t1/s1") is None
        assert tmtr.TraceContext.from_extra({"trace_id": "t"}) is None
        assert tmtr.TraceContext.from_extra(
            {"trace_id": "", "span_id": "s"}) is None

    def test_cross_thread_propagation(self, tmp_path):
        """A context handed explicitly to another thread emits spans into
        the same trace with correct parent linkage — the publisher-thread /
        watch-thread / drain-loop pattern (no thread-locals to diverge)."""
        reg, path = _sinked_registry(tmp_path)
        root = tmtr.TraceContext.new()
        tmtr.emit_span(reg, "train.segment", root, 0.25, iteration=5)

        def worker(ctx):
            tmtr.emit_span(reg, "publish.seconds", ctx.child(), 0.01,
                           iteration=5)

        t = threading.Thread(target=worker, args=(root,))
        t.start()
        t.join()
        recs = _records(reg, path)
        seg = next(r for r in recs if r["name"] == "train.segment")
        pub = next(r for r in recs if r["name"] == "publish.seconds")
        assert pub["trace_id"] == seg["trace_id"] == root.trace_id
        assert pub["parent_id"] == seg["span_id"] == root.span_id


# ---------------------------------------------------------------------------
# TracedSpan: exception-path closure
# ---------------------------------------------------------------------------


class TestTracedSpan:
    def test_closes_on_exception_with_error_attr(self, tmp_path):
        reg, path = _sinked_registry(tmp_path)
        ctx = tmtr.TraceContext.new()
        with pytest.raises(RuntimeError):
            with tmtr.TracedSpan(reg, "serve.score.seconds", ctx, bucket="k4"):
                raise RuntimeError("boom")
        (rec,) = _records(reg, path)
        assert rec["kind"] == "span" and rec["seconds"] >= 0
        assert rec["fields"]["error"] == "RuntimeError: boom"
        assert rec["fields"]["bucket"] == "k4"
        # the histogram observed the failed phase too
        assert reg.histogram("serve.score.seconds").count == 1

    def test_success_has_no_error_attr(self, tmp_path):
        reg, path = _sinked_registry(tmp_path)
        with tmtr.TracedSpan(reg, "x.seconds", tmtr.TraceContext.new()) as sp:
            pass
        assert sp.seconds is not None and sp.seconds >= 0
        (rec,) = _records(reg, path)
        assert "error" not in rec["fields"]

    def test_score_fn_raise_closes_span_and_request_traces(self, tmp_path):
        """Regression: a score_fn raise inside drain still closes the batch
        span (error attr) and does not orphan the traced requests."""
        reg, path = _sinked_registry(tmp_path)
        tracer = tmtr.RequestTracer(reg, sample=1.0)
        mb = MicroBatcher(_buckets(), registry=reg, tracer=tracer)
        for _ in range(2):
            mb.submit(*_query())

        def bomb(b, cols, vals):
            raise RuntimeError("scorer exploded")

        with pytest.raises(RuntimeError):
            mb.drain(bomb)
        recs = _records(reg, path)
        span = next(r for r in recs if r["name"] == "serve.score.seconds")
        assert span["fields"]["error"] == "RuntimeError: scorer exploded"


# ---------------------------------------------------------------------------
# RequestTracer: sampled fates, reservoir retention
# ---------------------------------------------------------------------------


class TestRequestTracer:
    def test_validation(self):
        with pytest.raises(ValueError):
            tmtr.RequestTracer(Registry(), sample=1.5)
        with pytest.raises(ValueError):
            tmtr.RequestTracer(Registry(), reservoir=0)

    def test_reservoir_bounded_over_soak(self):
        """A long soak holds O(reservoir) fate records while exact totals
        ride the counters — the 50k-soak memory contract (scaled down)."""
        reg = Registry()
        tracer = tmtr.RequestTracer(reg, sample=1.0, reservoir=32,
                                    clock=lambda: 0.0)
        n = 5000
        for rid in range(n):
            tracer.start(rid)
            tracer.finish(rid, "delivered")
        assert len(tracer.sampled_fates()) == 32
        assert tracer.pending == 0
        assert reg.value("trace.requests") == n
        assert tracer.fate_counts() == {"delivered": n}

    def test_sample_zero_emits_nothing(self, tmp_path):
        reg, path = _sinked_registry(tmp_path)
        tracer = tmtr.RequestTracer(reg, sample=0.0)
        tracer.start(1)
        tracer.finish(1, "delivered")
        tracer.reject()
        assert _records(reg, path) == []
        assert reg.value("trace.requests") == 0

    def test_finish_unknown_rid_is_noop(self):
        tracer = tmtr.RequestTracer(Registry())
        tracer.finish(999, "delivered")  # never started — must not throw
        assert tracer.fate_counts() == {}

    def test_batcher_fates_reconcile_exactly(self, tmp_path):
        """Every submission meets exactly one typed fate and the traced
        counters reconcile with the batcher's own accounting:
        ``trace.requests == submitted + rejected`` and per-fate counts match
        ``delivered`` / ``shed`` / ``deadline_missed``."""
        reg, path = _sinked_registry(tmp_path)
        clock = {"t": 0.0}
        tracer = tmtr.RequestTracer(reg, sample=1.0,
                                    clock=lambda: clock["t"])
        mb = MicroBatcher(_buckets(), registry=reg, tracer=tracer,
                          max_pending=3, admission="shed-oldest",
                          clock=lambda: clock["t"])
        # 5 submits into 3 slots: 2 shed-oldest
        for _ in range(5):
            mb.submit(*_query())
        # a refused-at-the-door submission (oversize for the k=4 ladder)
        with pytest.raises(serve.QueryRejected):
            mb.submit(np.arange(6, dtype=np.int32),
                      np.ones(6, np.float32))
        # one more with a deadline that expires before drain
        mb.submit(*_query(), deadline=1.0)
        clock["t"] = 2.0
        mb.drain(_ok)
        st = mb.stats()
        fates = tracer.fate_counts()
        assert fates == {"delivered": st["delivered"],
                         "shed": st["shed"],
                         "deadline": st["deadline_missed"],
                         "rejected": st["rejected"]}
        assert reg.value("trace.requests") == st["submitted"] + st["rejected"]
        assert (st["submitted"] == st["delivered"] + st["shed"]
                + st["deadline_missed"] + st["pending"])
        recs = _records(reg, path)
        req_spans = [r for r in recs if r["name"] == "serve.request"]
        assert len(req_spans) == reg.value("trace.requests")
        delivered = [r for r in req_spans
                     if r["fields"]["fate"] == "delivered"]
        assert delivered and all(
            r["fields"]["bucket"] == "k4" and r["fields"]["rung"] == 0
            for r in delivered)


# ---------------------------------------------------------------------------
# Version lineage: publisher, engine, resume
# ---------------------------------------------------------------------------


class TestLineage:
    def test_publish_retry_keeps_trace_with_attempt_spans(
            self, tmp_path, monkeypatch):
        """Transient OSErrors during publish stay inside ONE trace: the
        publish.seconds span plus one publish.attempt child per try, failed
        attempts carrying the error attr."""
        from repro.serve import publisher as pub_mod
        real = pub_mod.to_checkpoint
        fail = {"left": 2}

        def flaky(*a, **kw):
            if fail["left"] > 0:
                fail["left"] -= 1
                raise OSError("transient write failure")
            return real(*a, **kw)

        monkeypatch.setattr(pub_mod, "to_checkpoint", flaky)
        X, y = _toy_parts()
        reg, path = _sinked_registry(tmp_path)
        root = str(tmp_path / "ckpts")
        pub = TrainPublisher(X, y, _toy_cfg(max_iters=10), root=root,
                             segment_iters=5, publish_retries=3,
                             publish_backoff=0.001, registry=reg,
                             trace=True).start()
        pub.join()
        assert pub.publish_retries_used == 2
        recs = _records(reg, path)
        pubs = [r for r in recs if r["name"] == "publish.seconds"]
        atts = [r for r in recs if r["name"] == "publish.attempt"]
        assert len(pubs) == 2  # versions 5 and 10
        v5 = next(r for r in pubs if r["fields"]["iteration"] == 5)
        v5_atts = [a for a in atts if a["trace_id"] == v5["trace_id"]]
        assert [a["fields"]["attempt"] for a in v5_atts] == [0, 1, 2]
        assert all("OSError" in a["fields"]["error"] for a in v5_atts[:2])
        assert "error" not in v5_atts[-1]["fields"]
        # each attempt is a child of the publish span; publish hangs off the
        # segment root
        assert all(a["parent_id"] == v5["span_id"] for a in v5_atts)
        seg = next(r for r in recs if r["name"] == "train.segment"
                   and r["trace_id"] == v5["trace_id"])
        assert v5["parent_id"] == seg["span_id"]
        # the visibility event lands after the publish span closes
        vis = next(r for r in recs if r["name"] == "publish.visible"
                   and r["trace_id"] == v5["trace_id"])
        assert vis["ts"] >= v5["ts"]

    def test_full_chain_complete_for_every_version(self, tmp_path):
        """The acceptance shape: live publish + deterministic replay via
        point_latest makes every published version's chain complete and
        monotone, recoverable from the JSONL alone."""
        X, y = _toy_parts()
        reg, path = _sinked_registry(tmp_path)
        root = str(tmp_path / "ckpts")
        pub = TrainPublisher(X, y, _toy_cfg(max_iters=10), root=root,
                             segment_iters=5, registry=reg,
                             trace=True).start()
        pub.join()
        srv = SvmServer.watch(root, use_kernels=False, registry=reg)
        Xq = RNG.normal(size=(2, 32)).astype(np.float32)
        for step in pub.published:
            ckpt.point_latest(root, step)
            srv.maybe_reload()
            srv.score(Xq)
        chains = tmtr.lineage_chains(_records(reg, path))
        assert sorted(chains) == pub.published == [5, 10]
        for version, chain in chains.items():
            assert chain["complete"], (version, chain["events"].keys())
            assert chain["monotone"]
        # the manifest carried the propagation context + a wall-clock anchor
        manifest = ckpt.read_manifest(root, 10)
        assert "ts" in manifest
        trace = manifest["extra"]["trace"]
        assert trace["trace_id"] == chains[10]["trace_id"]

    def test_untraced_publisher_emits_no_trace_records(self, tmp_path):
        """Tracing off (the default) adds nothing to the stream — the
        invariance half of the overhead bound."""
        X, y = _toy_parts()
        reg, path = _sinked_registry(tmp_path)
        root = str(tmp_path / "ckpts")
        pub = TrainPublisher(X, y, _toy_cfg(max_iters=10), root=root,
                             segment_iters=5, registry=reg).start()
        pub.join()
        srv = SvmServer.watch(root, use_kernels=False, registry=reg)
        srv.score(RNG.normal(size=(2, 32)).astype(np.float32))
        recs = _records(reg, path)
        assert [r for r in recs if "trace_id" in r] == []
        assert "trace" not in (ckpt.read_manifest(root, 10).get("extra") or {})

    def test_quarantined_reload_closes_swap_span(self, tmp_path):
        """A checkpoint that fails to load until quarantine closes its
        serve.swap span with error="quarantined", linked to the publish
        trace recovered from the (readable) manifest."""
        X, y = _toy_parts()
        reg, path = _sinked_registry(tmp_path)
        root = str(tmp_path / "ckpts")
        pub = TrainPublisher(X, y, _toy_cfg(max_iters=10), root=root,
                             segment_iters=5, registry=reg,
                             trace=True).start()
        pub.join()
        srv = SvmServer.watch(root, use_kernels=False, registry=reg,
                              reload_quarantine=1)
        # a poisoned step: manifest intact (trace recoverable), arrays not
        import os
        bad = os.path.join(root, "step_000000099")
        os.makedirs(bad)
        poison_ctx = tmtr.TraceContext.new()
        with open(os.path.join(bad, "manifest.json"), "w") as fh:
            json.dump({"version": 1, "step": 99, "ts": 0.0,
                       "extra": {"trace": poison_ctx.to_extra()}}, fh)
        with open(os.path.join(bad, "arrays.npz"), "w") as fh:
            fh.write("not an npz")
        ckpt_io._write_pointer(root, 99)
        assert srv.maybe_reload() is None
        assert srv.quarantined_steps == [99]
        # no first-score event is armed for a failed swap
        srv.score(RNG.normal(size=(2, 32)).astype(np.float32))
        recs = _records(reg, path)
        swap = next(r for r in recs if r["name"] == "serve.swap"
                    and r["fields"].get("error"))
        assert swap["fields"]["error"] == "quarantined"
        assert swap["fields"]["version"] == 99
        assert swap["trace_id"] == poison_ctx.trace_id
        assert swap["parent_id"] == poison_ctx.span_id
        assert not any(r["name"] == "serve.first_score"
                       and r["trace_id"] == poison_ctx.trace_id
                       for r in recs)

    def test_resume_links_fresh_trace_to_prior(self, tmp_path):
        """Kill-and-resume: the restarted run starts fresh traces but stamps
        the pre-crash trace_id (recovered from the manifest) onto its first
        segment span as resumed_from_trace."""
        X, y = _toy_parts()
        cfg = _toy_cfg(max_iters=10)
        root = str(tmp_path / "ckpts")
        # "crashed" run: one traced segment published by hand, then death
        for seg in gadget_train_stream(X, y, cfg, segment_iters=5,
                                       trace=True):
            prior = seg.trace
            to_checkpoint(Snapshot(seg.iteration, np.asarray(seg.w_consensus),
                                   seg.objective), root, lam=cfg.lam,
                          train_state=TrainState(seg.iteration, seg.W,
                                                 seg.W_sum),
                          trace=prior.to_extra())
            break
        reg, path = _sinked_registry(tmp_path)
        pub = TrainPublisher(X, y, cfg, root=root, segment_iters=5,
                             save_train_state=True, resume="latest",
                             registry=reg, trace=True).start()
        pub.join()
        assert pub.resumed_from == 5 and pub.published == [10]
        recs = _records(reg, path)
        seg10 = next(r for r in recs if r["name"] == "train.segment")
        assert seg10["trace_id"] != prior.trace_id  # fresh trace per segment
        assert seg10["fields"]["resumed_from_trace"] == prior.trace_id


# ---------------------------------------------------------------------------
# Lineage assembly + CLI
# ---------------------------------------------------------------------------


def _synthetic_chain(version, t0=100.0, *, drop=(), swap_ts=None):
    """Hand-built lineage records for one version."""
    root = tmtr.TraceContext.new()
    pub = root.child()
    swap = pub.child()
    out = [
        {"ts": t0, "kind": "span", "name": "train.segment", "labels": {},
         "seconds": 0.5, "fields": {"iteration": version},
         **tmtr._trace_fields(root)},
        {"ts": t0 + 1, "kind": "span", "name": "publish.seconds",
         "labels": {}, "seconds": 0.01, "fields": {"iteration": version},
         **tmtr._trace_fields(pub)},
        {"ts": t0 + 1.1, "kind": "event", "name": "publish.visible",
         "labels": {}, "fields": {"iteration": version},
         **tmtr._trace_fields(pub)},
        {"ts": swap_ts if swap_ts is not None else t0 + 2, "kind": "span",
         "name": "serve.swap", "labels": {}, "seconds": 0.02,
         "fields": {"version": version}, **tmtr._trace_fields(swap)},
        {"ts": t0 + 3, "kind": "event", "name": "serve.first_score",
         "labels": {}, "fields": {"version": version},
         **tmtr._trace_fields(swap.child())},
    ]
    return [r for r in out if r["name"] not in drop]


class TestLineageAssembly:
    def test_complete_and_incomplete_chains(self):
        recs = (_synthetic_chain(5)
                + _synthetic_chain(10, t0=200.0, drop=("serve.swap",
                                                       "serve.first_score")))
        chains = tmtr.lineage_chains(recs)
        assert chains[5]["complete"] and chains[5]["monotone"]
        assert not chains[10]["complete"]
        text = tmtr.format_chain(5, chains[5])
        assert "complete" in text and "hops:" in text

    def test_non_monotone_flagged(self):
        chains = tmtr.lineage_chains(_synthetic_chain(5, swap_ts=50.0))
        assert chains[5]["complete"] and not chains[5]["monotone"]
        assert "NON-MONOTONE" in tmtr.format_chain(5, chains[5])

    def test_records_without_version_skipped(self):
        root = tmtr.TraceContext.new()
        recs = [{"ts": 1.0, "kind": "span", "name": "train.segment",
                 "labels": {}, "seconds": 0.1, "fields": {},
                 **tmtr._trace_fields(root)}]
        assert tmtr.lineage_chains(recs) == {}

    def test_cli(self, tmp_path, capsys):
        path = tmp_path / "run.jsonl"
        with open(path, "w") as fh:
            for rec in _synthetic_chain(5):
                fh.write(json.dumps(rec) + "\n")
        assert tmtr.main([str(path)]) == 0
        out = capsys.readouterr().out
        assert "1 chain(s), 1 complete" in out
        assert tmtr.main([str(path), "--version", "5"]) == 0
        assert "segment-end" in capsys.readouterr().out
        assert tmtr.main([str(path), "--version", "7"]) == 1
        empty = tmp_path / "empty.jsonl"
        empty.write_text("")
        assert tmtr.main([str(empty)]) == 1


# ---------------------------------------------------------------------------
# Observatory: per-node health
# ---------------------------------------------------------------------------


def _obs_parts(m=6, n_i=16, d=24, seed=0):
    """Fleet sized so a dead node separates cleanly from its peers."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(m, n_i, d)).astype(np.float32)
    w_true = rng.normal(size=d).astype(np.float32)
    y = np.sign(X @ w_true).astype(np.float32)
    y[y == 0] = 1.0
    return X, y


@pytest.fixture(scope="module")
def faulted_report():
    X, y = _obs_parts()
    cfg = GadgetConfig(max_iters=300, epsilon=0.0, seed=3, check_every=1,
                       use_kernels=False,
                       faults=FaultPlan(drop_prob=0.05, drop="message",
                                        dead_nodes=(2,), seed=5))
    res = gadget_train(X, y, cfg,
                       telemetry=tm.TrainTelemetry(every=10, slots=32,
                                                   per_node=True))
    return tm.analyze(res.telemetry)


class TestObservatory:
    def test_requires_per_node_rings(self):
        X, y = _toy_parts()
        res = gadget_train(X, y, _toy_cfg(),
                           telemetry=tm.TrainTelemetry())
        with pytest.raises(ValueError, match="per-node"):
            tm.analyze(res.telemetry)

    def test_faulted_fleet_flags_dead_node_and_leak(self, faulted_report):
        rep = faulted_report
        assert not rep.healthy
        assert 2 in rep.dead or 2 in rep.stragglers
        assert rep.mass_leak > 0  # message drops destroy Push-Sum mass
        flagged = next(h for h in rep.nodes if h.node == 2)
        assert flagged.dead or flagged.straggler
        assert flagged.drops == 0  # a dead node sends nothing to drop
        assert len(rep.nodes) == 6

    def test_healthy_fleet_clean_with_negative_mixing_rate(self):
        X, y = _obs_parts()
        cfg = GadgetConfig(max_iters=300, epsilon=0.0, seed=3, check_every=1,
                           use_kernels=False)
        res = gadget_train(X, y, cfg,
                           telemetry=tm.TrainTelemetry(every=10, slots=32,
                                                       per_node=True))
        rep = tm.analyze(res.telemetry)
        assert rep.healthy
        assert rep.stragglers == () and rep.dead == ()
        assert rep.mass_leak == 0.0
        assert rep.mixing_rate < 0  # fault-free gossip converges

    def test_publish_node_health_gauges(self, faulted_report):
        reg = Registry()
        tm.publish_node_health(faulted_report, reg)
        h = faulted_report.nodes[2]
        assert reg.value("node.disagreement", node="2") == h.disagreement
        assert reg.value("node.dead", node="2") == float(h.dead)
        assert reg.value("node.straggler", node="2") == float(h.straggler)
        assert reg.value("train.mass_leak") == faulted_report.mass_leak


# ---------------------------------------------------------------------------
# Top console
# ---------------------------------------------------------------------------


class TestTopConsole:
    def test_render_empty_placeholders(self):
        frame = tmtop.render({})
        assert "no node health published" in frame
        assert "=== serve fates ===" in frame
        assert "lineage needs span records" in frame

    def test_render_panes_from_run(self, tmp_path, faulted_report):
        reg, path = Registry(), tmp_path / "run.jsonl"
        tm.publish_node_health(faulted_report, reg)
        reg.counter("serve.submitted").inc(7)
        reg.counter("serve.delivered").inc(7)
        tm.dump_jsonl(reg, path, mode="a")
        with open(path, "a") as fh:
            for rec in _synthetic_chain(5):
                fh.write(json.dumps(rec) + "\n")
        records = tm.read_jsonl(path)
        frame = tmtop.render(tmtop.snapshot_values(records), records)
        assert "MASS LEAK" in frame
        assert "DEAD" in frame or "STRAGGLER" in frame
        assert "submitted 7" in frame and "delivered 7" in frame
        assert "v5: complete" in frame

    def test_cli_once(self, tmp_path, capsys, faulted_report):
        reg, path = Registry(), tmp_path / "run.jsonl"
        tm.publish_node_health(faulted_report, reg)
        tm.dump_jsonl(reg, path, mode="a")
        assert tmtop.main([str(path), "--once"]) == 0
        assert "gossip nodes" in capsys.readouterr().out
