"""Gossip consensus for deep-net training (core/consensus.py): the stacked
global-view mixing must match the matrix-form Push-Sum simulator exactly and
preserve the replica mean (hypothesis property)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.consensus import gossip_mix_stacked
from repro.core.push_sum import PushSumSim


@settings(max_examples=20, deadline=None)
@given(st.sampled_from([2, 4, 8]), st.integers(0, 6), st.integers(1, 3))
def test_mean_preserved(n, step, rounds):
    rng = np.random.default_rng(step)
    x = jnp.asarray(rng.normal(size=(n, 5)).astype(np.float32))
    out = gossip_mix_stacked({"w": x}, jnp.int32(step), n_nodes=n, rounds=rounds)["w"]
    assert np.allclose(np.asarray(out).mean(0), np.asarray(x).mean(0), atol=1e-5)


def test_matches_matrix_form():
    """roll-based stacked mixing == B^T x with the one-peer exponential B."""
    n = 8
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32))
    out = gossip_mix_stacked({"w": x}, jnp.int32(0), n_nodes=n, rounds=3)["w"]

    sim = PushSumSim(n, "exponential")
    ref = x
    for t in range(3):
        B = jnp.asarray(sim.matrix(t), jnp.float32)
        ref = B.T @ ref
    assert np.allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_full_schedule_reaches_exact_mean():
    n = 8
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(n, 4)).astype(np.float32))
    out = gossip_mix_stacked({"w": x}, jnp.int32(0), n_nodes=n, rounds=3)["w"]  # log2(8)=3
    assert np.allclose(np.asarray(out), np.asarray(x).mean(0, keepdims=True), atol=1e-5)


def test_schedule_rotation_progresses():
    """With 1 round/step the hop must rotate across steps (step 0: hop 1,
    step 1: hop 2, ...) — pinning the lax.switch rotation logic."""
    n = 4
    x = jnp.eye(4, dtype=jnp.float32)
    o0 = gossip_mix_stacked({"w": x}, jnp.int32(0), n_nodes=n, rounds=1)["w"]
    o1 = gossip_mix_stacked({"w": x}, jnp.int32(1), n_nodes=n, rounds=1)["w"]
    r0 = 0.5 * x + 0.5 * jnp.roll(x, 1, axis=0)
    r1 = 0.5 * x + 0.5 * jnp.roll(x, 2, axis=0)
    assert np.allclose(o0, r0) and np.allclose(o1, r1)
