"""End-to-end training integration: loss improves under both consensus
strategies; gossip replicas reach consensus; gossip matches all-reduce in the
exact-averaging limit (full schedule every step, same data)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.tokens import Batcher, TokenStreamConfig
from repro.launch import steps as steps_mod
from repro.models.transformer import Model


def _run(arch="llama3-8b", consensus="allreduce", n_replicas=4, steps=12,
         gossip_rounds=1, batch=8, seq=32, seed=0):
    cfg = get_config(arch).reduced(n_layers=2, d_model=128)
    model = Model(cfg)
    tcfg = steps_mod.TrainerConfig(optimizer="adamw", lr=3e-3, total_steps=steps,
                                   warmup_steps=2, consensus=consensus,
                                   n_replicas=n_replicas if consensus == "gossip" else 1,
                                   gossip_rounds=gossip_rounds)
    state = steps_mod.make_train_state(model, tcfg, jax.random.PRNGKey(seed))
    step_fn = jax.jit(steps_mod.make_train_step(model, tcfg))
    batcher = Batcher(TokenStreamConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                                        global_batch=batch, seed=seed))
    losses = []
    for s in range(steps):
        b = {k: jnp.asarray(v) for k, v in batcher.global_batch(s).items()}
        if consensus == "gossip":
            G = n_replicas
            b = {k: v.reshape(G, batch // G, seq) for k, v in b.items()}
        state, m = step_fn(state, b)
        losses.append(float(m["loss"]))
    return state, losses


def test_allreduce_loss_improves():
    _, losses = _run(consensus="allreduce", steps=15)
    assert np.mean(losses[-3:]) < np.mean(losses[:3]) - 0.2


def test_gossip_loss_improves():
    _, losses = _run(consensus="gossip", steps=15, n_replicas=4, gossip_rounds=1)
    assert np.mean(losses[-3:]) < np.mean(losses[:3]) - 0.2


def test_gossip_replicas_reach_consensus():
    state, _ = _run(consensus="gossip", steps=15, n_replicas=4, gossip_rounds=2)
    # replica disagreement small relative to param norm
    disagreements = []
    for leaf in jax.tree.leaves(state["params"]):
        center = leaf.mean(axis=0, keepdims=True)
        num = float(jnp.linalg.norm((leaf - center).astype(jnp.float32)))
        den = float(jnp.linalg.norm(center.astype(jnp.float32))) + 1e-9
        disagreements.append(num / den)
    assert max(disagreements) < 0.15, max(disagreements)


def test_gossip_exact_averaging_matches_allreduce_direction():
    """With rounds = log2(G) (exact mean) and identical per-replica batches,
    gossip keeps replicas IDENTICAL — sanity for the protocol algebra."""
    cfg = get_config("llama3-8b").reduced(n_layers=2, d_model=64)
    model = Model(cfg)
    G = 4
    tcfg = steps_mod.TrainerConfig(optimizer="sgd", lr=1e-2, consensus="gossip",
                                   n_replicas=G, gossip_rounds=2)  # log2(4)=2
    state = steps_mod.make_train_state(model, tcfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(steps_mod.make_train_step(model, tcfg))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    b = {"tokens": jnp.broadcast_to(toks, (G, 2, 16)),
         "targets": jnp.broadcast_to(toks, (G, 2, 16))}
    for _ in range(3):
        state, _ = step_fn(state, b)
    for leaf in jax.tree.leaves(state["params"]):
        spread = float(jnp.max(jnp.abs((leaf - leaf[:1]).astype(jnp.float32))))
        assert spread < 1e-5, spread
