"""GADGET SVM (paper Algorithm 2) — the paper's own claims at test scale:
accuracy comparable to centralized Pegasos, consensus across nodes, anytime
epsilon-termination, works under every topology incl. the paper's random
one-neighbor gossip."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import svm_objective as obj
from repro.core.gadget import GadgetConfig, gadget_train
from repro.core.pegasos import pegasos_train
from tests.conftest import make_separable


def _partition(X, y, m):
    n_i = len(y) // m
    return (jnp.asarray(X[: m * n_i].reshape(m, n_i, -1)),
            jnp.asarray(y[: m * n_i].reshape(m, n_i)))


@pytest.mark.parametrize("topology", ["exponential", "random", "ring"])
def test_gadget_comparable_to_centralized(topology):
    X, y, _ = make_separable(n=4000, d=20, seed=0)
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    lam = 1e-3
    cen = pegasos_train(Xj, yj, lam=lam, n_iters=1500, batch_size=8)
    acc_c = float(obj.accuracy(cen.w, Xj, yj))

    Xp, yp = _partition(X, y, 10)
    res = gadget_train(Xp, yp, GadgetConfig(lam=lam, batch_size=8, gossip_rounds=4,
                                            topology=topology, max_iters=1500,
                                            check_every=300, epsilon=1e-4))
    acc_g = float(obj.accuracy(res.w_consensus, Xj, yj))
    # paper Table 3: GADGET within a few points of centralized (often better)
    assert acc_g > acc_c - 0.05, (acc_g, acc_c)


def test_gadget_consensus_across_nodes():
    X, y, _ = make_separable(n=2000, d=15, seed=1)
    Xp, yp = _partition(X, y, 8)
    res = gadget_train(Xp, yp, GadgetConfig(lam=1e-3, gossip_rounds=3,
                                            topology="exponential",
                                            max_iters=800, check_every=200))
    W = np.asarray(res.W)
    center = W.mean(axis=0)
    dists = np.linalg.norm(W - center, axis=1) / (np.linalg.norm(center) + 1e-9)
    # nodes agree to within a few percent relative disagreement
    assert float(dists.max()) < 0.25, dists


def test_gadget_anytime_epsilon_stop():
    X, y, _ = make_separable(n=1000, d=10, seed=2)
    Xp, yp = _partition(X, y, 4)
    cfg = GadgetConfig(lam=1e-2, gossip_rounds=2, epsilon=0.5,  # loose -> early stop
                       max_iters=5000, check_every=100)
    res = gadget_train(Xp, yp, cfg)
    assert res.iters < 5000
    assert res.epsilon < 0.5


def test_gadget_objective_decreases():
    X, y, _ = make_separable(n=1500, d=12, seed=3)
    Xp, yp = _partition(X, y, 6)
    res = gadget_train(Xp, yp, GadgetConfig(lam=1e-3, gossip_rounds=3,
                                            max_iters=900, check_every=150))
    tr = res.objective_trace
    assert tr[-1] < tr[0]
