"""Sparse subsystem: ELL/CSR round-trips, sparse kernel parity vs the dense
oracles, streaming LibSVM ingest, generator sparsity guarantees, and
end-to-end sparse-vs-dense GADGET consensus agreement."""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.gadget import GadgetConfig, gadget_train, gadget_train_reference
from repro.data import libsvm, svm_datasets
from repro.kernels.hinge_subgrad import ops as hinge_ops
from repro.kernels.hinge_subgrad import ref as hinge_ref
from repro.kernels.hinge_subgrad import sparse as hinge_sparse
from repro.sparse import CSR, ELL, EllPartitions, partition_rows

RNG = np.random.default_rng(0)


def _random_sparse(n, d, nnz_max, rng=RNG):
    """Dense matrix with ≤ nnz_max nonzeros per row (ragged on purpose)."""
    X = np.zeros((n, d), np.float32)
    for r in range(n):
        k = int(rng.integers(0, nnz_max + 1))
        cols = rng.choice(d, size=k, replace=False)
        X[r, cols] = rng.normal(size=k).astype(np.float32)
    return X


# ------------------------------------------------------------- containers

class TestFormats:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(1, 12), st.integers(2, 40), st.integers(0, 6))
    def test_roundtrip_property(self, n, d, nnz_max):
        X = _random_sparse(n, d, min(nnz_max, d))
        csr = CSR.from_dense(X)
        ell = ELL.from_dense(X)
        np.testing.assert_array_equal(csr.to_dense(), X)
        np.testing.assert_array_equal(ell.to_dense(), X)
        np.testing.assert_array_equal(csr.to_ell().to_dense(), X)
        np.testing.assert_array_equal(ell.to_csr().to_dense(), X)
        assert csr.nnz == (X != 0).sum() == ell.nnz

    def test_take_rows_and_matvec(self):
        X = _random_sparse(20, 30, 5)
        w = RNG.normal(size=30).astype(np.float32)
        idx = RNG.permutation(20)[:7]
        csr, ell = CSR.from_dense(X), ELL.from_dense(X)
        np.testing.assert_array_equal(csr.take_rows(idx).to_dense(), X[idx])
        np.testing.assert_array_equal(ell.take_rows(idx).to_dense(), X[idx])
        np.testing.assert_allclose(ell.matvec(w), X @ w, atol=1e-5)

    def test_ell_k_max_validation(self):
        X = _random_sparse(5, 10, 4)
        widest = int((X != 0).sum(axis=1).max())
        if widest > 1:
            with pytest.raises(ValueError):
                CSR.from_dense(X).to_ell(k_max=widest - 1)
        padded = CSR.from_dense(X).to_ell(k_max=widest + 3)
        assert padded.k_max == widest + 3
        np.testing.assert_array_equal(padded.to_dense(), X)

    def test_bad_indices_rejected(self):
        with pytest.raises(ValueError):
            ELL(np.array([[5]], np.int32), np.array([[1.0]], np.float32), (1, 3))
        with pytest.raises(ValueError):
            CSR(np.ones(1), np.array([7], np.int32), np.array([0, 1]), (1, 4))

    def test_partition_rows_covers_everything(self):
        idx, counts, n_i = partition_rows(101, 10, seed=0)
        assert counts.sum() == 101 and n_i == 11
        valid = np.concatenate([idx[i * n_i: i * n_i + counts[i]] for i in range(10)])
        assert np.array_equal(np.sort(valid), np.arange(101))
        with pytest.raises(ValueError):
            partition_rows(3, 5)


# ------------------------------------------------------- kernels vs oracles

class TestSparseKernels:
    @pytest.mark.parametrize("m,B,d,k", [(1, 1, 64, 1), (3, 5, 300, 7),
                                         (4, 8, 1024, 40), (2, 3, 130, 129)])
    def test_fleet_parity_dense_oracle(self, m, B, d, k):
        """Sparse kernel == sparse ref == dense fleet ref on the same data."""
        X = _random_sparse(m * B, d, k).reshape(m, B, d)
        ell = ELL.from_dense(X.reshape(m * B, d))
        kw = ell.k_max
        cols = jnp.asarray(ell.cols.reshape(m, B, kw))
        vals = jnp.asarray(ell.vals.reshape(m, B, kw))
        y = jnp.asarray(np.sign(RNG.normal(size=(m, B)) + 0.1).astype(np.float32))
        W = jnp.asarray(RNG.normal(size=(m, d)).astype(np.float32) * 0.1)
        t = jnp.float32(3.0)

        want = hinge_ref.fleet_half_step_ref(W, jnp.asarray(X), y, 1e-3, t)
        got_ref = hinge_ref.ell_fleet_half_step_ref(W, cols, vals, y, 1e-3, t)
        got_kern = hinge_ops.ell_fleet_half_step(W, cols, vals, y, lam=1e-3,
                                                 t=t, interpret=True)
        np.testing.assert_allclose(np.asarray(got_ref), np.asarray(want), atol=2e-5)
        np.testing.assert_allclose(np.asarray(got_kern), np.asarray(want), atol=2e-5)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(1, 4), st.integers(1, 9), st.integers(2, 200),
           st.integers(1, 12))
    def test_fleet_parity_property(self, m, B, d, k):
        self.test_fleet_parity_dense_oracle(m, B, d, min(k, d))

    def test_margins_kernel_matches_ref(self):
        m, B, d, k = 3, 6, 500, 11
        X = _random_sparse(m * B, d, k)
        ell = ELL.from_dense(X)
        kw = ell.k_max
        cols = jnp.asarray(ell.cols.reshape(m, B, kw))
        vals = jnp.asarray(ell.vals.reshape(m, B, kw))
        y = jnp.asarray(np.sign(RNG.normal(size=(m, B))).astype(np.float32))
        W = jnp.asarray(RNG.normal(size=(m, d)).astype(np.float32) * 0.2)
        # kernel needs lane/sublane padding — go through a hand-padded call
        colsP = jnp.pad(cols, ((0, 0), (0, 2), (0, 128 - kw)))
        valsP = jnp.pad(vals, ((0, 0), (0, 2), (0, 128 - kw)))
        yP = jnp.pad(y, ((0, 0), (0, 2)))
        WP = jnp.pad(W, ((0, 0), (0, 512 - d)))
        got = hinge_sparse.ell_margins(colsP, valsP, WP, yP, blk_d=256,
                                       interpret=True)[:, :B]
        want = jnp.stack([
            hinge_ref.ell_margins_ref(W[i], cols[i], vals[i], y[i])
            for i in range(m)])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)

    def test_pad_entries_inert(self):
        """Extra (col=0, val=0) ELL entries change nothing — the pad
        convention the kernels rely on instead of a validity plane. (Row
        padding is NOT free: B is the batch-mean denominator, which is why
        only the wrapper pads rows, before computing scal.)"""
        m, B, d, k = 2, 4, 100, 5
        X = _random_sparse(m * B, d, k)
        ell = ELL.from_dense(X)
        kw = ell.k_max
        cols = jnp.asarray(ell.cols.reshape(m, B, kw))
        vals = jnp.asarray(ell.vals.reshape(m, B, kw))
        y = jnp.asarray(np.sign(RNG.normal(size=(m, B))).astype(np.float32))
        W = jnp.asarray(RNG.normal(size=(m, d)).astype(np.float32) * 0.1)
        t = jnp.float32(2.0)
        base = hinge_ops.ell_fleet_half_step(W, cols, vals, y, lam=1e-2, t=t,
                                             interpret=True)
        wide = hinge_ops.ell_fleet_half_step(
            W, jnp.pad(cols, ((0, 0), (0, 0), (0, 9))),
            jnp.pad(vals, ((0, 0), (0, 0), (0, 9))),
            y, lam=1e-2, t=t, interpret=True)
        np.testing.assert_allclose(np.asarray(base), np.asarray(wide), atol=1e-6)


# ----------------------------------------------------------------- libsvm

class TestLibsvmStreaming:
    CONTENT = "+1 1:0.5 3:2.0\n-1 2:1.5\n# comment\n+1 3:1.0 4:-0.5\n-1 1:0.25 4:1.0\n"

    def test_csr_loader_matches_dense(self, tmp_path):
        p = tmp_path / "toy.svm"
        p.write_text(self.CONTENT)
        Xd, yd = libsvm.load_libsvm(str(p))
        csr, ys = libsvm.load_libsvm_csr(str(p))
        assert csr.shape == Xd.shape
        np.testing.assert_array_equal(csr.to_dense(), Xd)
        np.testing.assert_array_equal(ys, yd)

    def test_chunked_iter_concatenates(self, tmp_path):
        p = tmp_path / "toy.svm"
        p.write_text(self.CONTENT)
        chunks = list(libsvm.iter_libsvm_chunks(str(p), n_features=4, chunk_rows=2))
        assert len(chunks) == 2 and chunks[0][0].shape == (2, 4)
        X = np.concatenate([c.to_dense() for c, _ in chunks])
        Xd, _ = libsvm.load_libsvm(str(p), n_features=4)
        np.testing.assert_array_equal(X, Xd)
        # streaming loader with explicit d matches too
        csr, _ = libsvm.load_libsvm_csr(str(p), n_features=4, chunk_rows=2)
        np.testing.assert_array_equal(csr.to_dense(), Xd)

    def test_out_of_range_strict_raises(self, tmp_path):
        p = tmp_path / "toy.svm"
        p.write_text("+1 1:1.0 9:2.0\n-1 2:1.0 8:3.0\n")
        for loader in (libsvm.load_libsvm, libsvm.load_libsvm_csr):
            with pytest.raises(ValueError, match="exceeds"):
                loader(str(p), n_features=4, strict=True)

    def test_out_of_range_warns_once_with_count(self, tmp_path):
        p = tmp_path / "toy.svm"
        p.write_text("+1 1:1.0 9:2.0\n-1 2:1.0 8:3.0\n")
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            X, _ = libsvm.load_libsvm(str(p), n_features=4)
        assert X.shape == (2, 4)
        assert len(caught) == 1 and "dropped 2" in str(caught[0].message)
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            csr, _ = libsvm.load_libsvm_csr(str(p), n_features=4)
        assert csr.shape == (2, 4) and csr.nnz == 2
        assert len(caught) == 1 and "dropped 2" in str(caught[0].message)


# ------------------------------------------------------ generator / dataset

class TestSparseDatasets:
    def test_generator_realized_nnz_exact(self):
        """Without-replacement sampling: realized nnz hits the spec exactly
        (the with-replacement draw undershot at higher densities)."""
        for name in ("reuters", "mnist"):
            spec = svm_datasets.PAPER_DATASETS[name]
            ds = svm_datasets.make_dataset(name, scale=0.003, seed=1)
            nnz_target = max(1, int(round(spec.sparsity * spec.d)))
            row_nnz = (np.asarray(ds.X_train) != 0).sum(axis=1)
            assert np.all(row_nnz == nnz_target), (name, row_nnz[:5], nnz_target)

    def test_sparse_dataset_emits_ell(self):
        spec = svm_datasets.PAPER_DATASETS["reuters"]
        ds = svm_datasets.make_dataset("reuters", scale=0.02, seed=0, sparse=True)
        assert ds.sparse and isinstance(ds.X_train, ELL)
        assert ds.d == spec.d
        nnz_target = max(1, int(round(spec.sparsity * spec.d)))
        assert ds.X_train.k_max == nnz_target
        assert np.all(ds.X_train.row_nnz() == nnz_target)
        assert set(np.unique(ds.y_train)) <= {-1.0, 1.0}
        norms = np.linalg.norm(ds.X_train.vals, axis=1)
        np.testing.assert_allclose(norms, 1.0, atol=1e-4)

    def test_sparse_rejected_for_dense_spec(self):
        with pytest.raises(ValueError, match="dense"):
            svm_datasets.make_dataset("usps", sparse=True)

    def test_partition_ell_matches_dense(self):
        ds = svm_datasets.make_dataset("reuters", scale=0.02, seed=0, sparse=True)
        Xd = ds.X_train.to_dense()
        Pe, yps, ncs = svm_datasets.partition(ds.X_train, ds.y_train, 4, seed=7)
        Xp, ypd, ncd = svm_datasets.partition(Xd, ds.y_train, 4, seed=7)
        assert isinstance(Pe, EllPartitions) and Pe.shape == Xp.shape
        np.testing.assert_array_equal(yps, ypd)
        np.testing.assert_array_equal(ncs, ncd)
        dense_again = np.stack([
            ELL(Pe.cols[i], Pe.vals[i], (Pe.cols.shape[1], Pe.d)).to_dense()
            for i in range(4)])
        np.testing.assert_array_equal(dense_again, Xp)

    def test_partition_csr_input(self):
        X = _random_sparse(33, 40, 6)
        y = np.sign(RNG.normal(size=33)).astype(np.float32)
        Pe, yp, nc = svm_datasets.partition(CSR.from_dense(X), y, 5, seed=1)
        assert isinstance(Pe, EllPartitions)
        assert nc.sum() == 33


# ------------------------------------------------------------- end-to-end

class TestSparseGadget:
    def _reuters_shaped(self, m=5, seed=0):
        ds = svm_datasets.make_dataset("reuters", scale=0.05, seed=seed, sparse=True)
        Pe, yp, nc = svm_datasets.partition(ds.X_train, ds.y_train, m, seed=3)
        Xp, ypd, ncd = svm_datasets.partition(ds.X_train.to_dense(), ds.y_train,
                                              m, seed=3)
        return ds, Pe, Xp, yp, nc

    @pytest.mark.parametrize("topology", ["exponential", "random"])
    def test_sparse_vs_dense_consensus(self, topology):
        """The acceptance bar: same data, same PRNG streams — the sparse path
        must land on the dense path's consensus weights to ≤ 1e-5."""
        ds, Pe, Xp, yp, nc = self._reuters_shaped()
        cfg = GadgetConfig(lam=ds.lam, batch_size=4, gossip_rounds=3,
                           topology=topology, max_iters=200, check_every=50,
                           epsilon=0.0)
        rs = gadget_train(Pe, jnp.asarray(yp), cfg, n_counts=nc)
        rd = gadget_train(jnp.asarray(Xp), jnp.asarray(yp), cfg, n_counts=nc)
        diff = float(jnp.max(jnp.abs(rs.w_consensus - rd.w_consensus)))
        assert diff <= 1e-5, diff
        np.testing.assert_allclose(rs.objective_trace, rd.objective_trace,
                                   atol=1e-5)

    def test_sparse_kernel_path_matches_jnp_path(self):
        ds, Pe, Xp, yp, nc = self._reuters_shaped(m=4)
        cfg = GadgetConfig(lam=ds.lam, batch_size=4, gossip_rounds=2,
                           max_iters=60, check_every=30, epsilon=0.0)
        rk = gadget_train(Pe, jnp.asarray(yp), cfg._replace(use_kernels=True),
                          n_counts=nc)
        rj = gadget_train(Pe, jnp.asarray(yp), cfg._replace(use_kernels=False),
                          n_counts=nc)
        assert float(jnp.max(jnp.abs(rk.w_consensus - rj.w_consensus))) < 1e-4

    def test_sparse_reference_oracle_agrees(self):
        ds, Pe, Xp, yp, nc = self._reuters_shaped(m=4)
        cfg = GadgetConfig(lam=ds.lam, batch_size=4, gossip_rounds=2,
                           max_iters=80, check_every=40, epsilon=0.0)
        dev = gadget_train(Pe, jnp.asarray(yp), cfg._replace(fused=False),
                           n_counts=nc)
        ref = gadget_train_reference(Pe, jnp.asarray(yp), cfg, n_counts=nc)
        assert float(jnp.max(jnp.abs(dev.W - ref.W))) < 1e-5

    def test_sparse_training_learns(self):
        """Sanity: the sparse path actually fits the training data (at this
        tiny scale d >> n, so held-out accuracy is not meaningful)."""
        from repro.core import svm_objective as obj
        ds, Pe, Xp, yp, nc = self._reuters_shaped()
        cfg = GadgetConfig(lam=ds.lam, batch_size=8, gossip_rounds=3,
                           max_iters=500, check_every=100, epsilon=0.0)
        res = gadget_train(Pe, jnp.asarray(yp), cfg, n_counts=nc)
        Xtr = jnp.asarray(ds.X_train.to_dense())
        acc = float(obj.accuracy(res.w_consensus, Xtr, jnp.asarray(ds.y_train)))
        assert acc > 0.9, acc
        assert res.objective_trace[-1] < res.objective_trace[0]
