"""Sparse subsystem: ELL/CSR round-trips, sparse kernel parity vs the dense
oracles (sweep AND touched-block/prefetch schedules), block-bucketed schedule
helpers, streaming LibSVM ingest, generator sparsity guarantees, and
end-to-end sparse-vs-dense GADGET consensus agreement."""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.gadget import GadgetConfig, gadget_train, gadget_train_reference
from repro.data import libsvm, svm_datasets
from repro.kernels.hinge_subgrad import ops as hinge_ops
from repro.kernels.hinge_subgrad import ref as hinge_ref
from repro.kernels.hinge_subgrad import sparse as hinge_sparse
from repro.sparse import (CSR, ELL, EllPartitions, block_map, bucket_by_block,
                          frequency_remap, minibatch_block_bound,
                          partition_rows, row_block_counts)
# shared oracle fixtures (also used by test_serve.py's predict parity tests)
from tests.sparse_utils import ell_minibatch_planes, random_sparse as _random_sparse

RNG = np.random.default_rng(0)


# ------------------------------------------------------------- containers

class TestFormats:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(1, 12), st.integers(2, 40), st.integers(0, 6))
    def test_roundtrip_property(self, n, d, nnz_max):
        X = _random_sparse(n, d, min(nnz_max, d))
        csr = CSR.from_dense(X)
        ell = ELL.from_dense(X)
        np.testing.assert_array_equal(csr.to_dense(), X)
        np.testing.assert_array_equal(ell.to_dense(), X)
        np.testing.assert_array_equal(csr.to_ell().to_dense(), X)
        np.testing.assert_array_equal(ell.to_csr().to_dense(), X)
        assert csr.nnz == (X != 0).sum() == ell.nnz

    def test_take_rows_and_matvec(self):
        X = _random_sparse(20, 30, 5)
        w = RNG.normal(size=30).astype(np.float32)
        idx = RNG.permutation(20)[:7]
        csr, ell = CSR.from_dense(X), ELL.from_dense(X)
        np.testing.assert_array_equal(csr.take_rows(idx).to_dense(), X[idx])
        np.testing.assert_array_equal(ell.take_rows(idx).to_dense(), X[idx])
        np.testing.assert_allclose(ell.matvec(w), X @ w, atol=1e-5)

    def test_ell_k_max_validation(self):
        X = _random_sparse(5, 10, 4)
        widest = int((X != 0).sum(axis=1).max())
        if widest > 1:
            with pytest.raises(ValueError):
                CSR.from_dense(X).to_ell(k_max=widest - 1)
        padded = CSR.from_dense(X).to_ell(k_max=widest + 3)
        assert padded.k_max == widest + 3
        np.testing.assert_array_equal(padded.to_dense(), X)

    def test_bad_indices_rejected(self):
        with pytest.raises(ValueError):
            ELL(np.array([[5]], np.int32), np.array([[1.0]], np.float32), (1, 3))
        with pytest.raises(ValueError):
            CSR(np.ones(1), np.array([7], np.int32), np.array([0, 1]), (1, 4))

    def test_partition_rows_covers_everything(self):
        idx, counts, n_i = partition_rows(101, 10, seed=0)
        assert counts.sum() == 101 and n_i == 11
        valid = np.concatenate([idx[i * n_i: i * n_i + counts[i]] for i in range(10)])
        assert np.array_equal(np.sort(valid), np.arange(101))
        with pytest.raises(ValueError):
            partition_rows(3, 5)


# ------------------------------------------------------- kernels vs oracles

class TestSparseKernels:
    @pytest.mark.parametrize("m,B,d,k", [(1, 1, 64, 1), (3, 5, 300, 7),
                                         (4, 8, 1024, 40), (2, 3, 130, 129)])
    def test_fleet_parity_dense_oracle(self, m, B, d, k):
        """Sparse kernel == sparse ref == dense fleet ref on the same data."""
        X = _random_sparse(m * B, d, k).reshape(m, B, d)
        ell = ELL.from_dense(X.reshape(m * B, d))
        kw = ell.k_max
        cols = jnp.asarray(ell.cols.reshape(m, B, kw))
        vals = jnp.asarray(ell.vals.reshape(m, B, kw))
        y = jnp.asarray(np.sign(RNG.normal(size=(m, B)) + 0.1).astype(np.float32))
        W = jnp.asarray(RNG.normal(size=(m, d)).astype(np.float32) * 0.1)
        t = jnp.float32(3.0)

        want = hinge_ref.fleet_half_step_ref(W, jnp.asarray(X), y, 1e-3, t)
        got_ref = hinge_ref.ell_fleet_half_step_ref(W, cols, vals, y, 1e-3, t)
        got_kern = hinge_ops.ell_fleet_half_step(W, cols, vals, y, lam=1e-3,
                                                 t=t, interpret=True)
        np.testing.assert_allclose(np.asarray(got_ref), np.asarray(want), atol=2e-5)
        np.testing.assert_allclose(np.asarray(got_kern), np.asarray(want), atol=2e-5)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(1, 4), st.integers(1, 9), st.integers(2, 200),
           st.integers(1, 12))
    def test_fleet_parity_property(self, m, B, d, k):
        self.test_fleet_parity_dense_oracle(m, B, d, min(k, d))

    def test_margins_kernel_matches_ref(self):
        m, B, d, k = 3, 6, 500, 11
        X = _random_sparse(m * B, d, k)
        ell = ELL.from_dense(X)
        kw = ell.k_max
        cols = jnp.asarray(ell.cols.reshape(m, B, kw))
        vals = jnp.asarray(ell.vals.reshape(m, B, kw))
        y = jnp.asarray(np.sign(RNG.normal(size=(m, B))).astype(np.float32))
        W = jnp.asarray(RNG.normal(size=(m, d)).astype(np.float32) * 0.2)
        # kernel needs lane/sublane padding — go through a hand-padded call
        colsP = jnp.pad(cols, ((0, 0), (0, 2), (0, 128 - kw)))
        valsP = jnp.pad(vals, ((0, 0), (0, 2), (0, 128 - kw)))
        yP = jnp.pad(y, ((0, 0), (0, 2)))
        WP = jnp.pad(W, ((0, 0), (0, 512 - d)))
        got = hinge_sparse.ell_margins(colsP, valsP, WP, yP, blk_d=256,
                                       interpret=True)[:, :B]
        want = jnp.stack([
            hinge_ref.ell_margins_ref(W[i], cols[i], vals[i], y[i])
            for i in range(m)])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)

    # shared with test_serve.py: tests/sparse_utils.ell_minibatch_planes is
    # the one statement of the planes-plus-dense-oracle fixture
    def _ell_planes(self, m, B, d, k, localized=False):
        return ell_minibatch_planes(m, B, d, k, localized)

    @settings(max_examples=12, deadline=None)
    @given(st.integers(1, 3), st.integers(1, 6), st.integers(64, 700),
           st.integers(1, 10), st.booleans())
    def test_prefetch_parity_property(self, m, B, d, k, localized):
        """The satellite acceptance sweep: the touched-block (prefetch)
        schedule must match the one-hot sweep kernels AND the jnp oracle to
        ≤ 1e-5 on arbitrary shapes, with the data-derived grid bound."""
        X, cols, vals, y, W = self._ell_planes(m, B, d, min(k, d), localized)
        t = jnp.float32(4.0)
        want = hinge_ref.fleet_half_step_ref(W, jnp.asarray(X), y, 1e-3, t)
        bound = minibatch_block_bound(np.asarray(cols), np.asarray(vals), B,
                                      d=d)
        sweep = hinge_ops.ell_fleet_half_step(W, cols, vals, y, lam=1e-3, t=t,
                                              interpret=True, schedule="sweep")
        pref = hinge_ops.ell_fleet_half_step(W, cols, vals, y, lam=1e-3, t=t,
                                             interpret=True, schedule="prefetch",
                                             n_blocks_max=bound)
        np.testing.assert_allclose(np.asarray(pref), np.asarray(want), atol=1e-5)
        np.testing.assert_allclose(np.asarray(pref), np.asarray(sweep), atol=1e-5)

    def test_prefetch_degenerate_single_block(self):
        """All nnz inside one d-block: the map holds one live id, the rest
        sentinel; n_blocks_max=1 is a legal (tight) grid."""
        m, B, d = 2, 4, 640
        cols = jnp.asarray(128 + RNG.integers(0, 128, size=(m, B, 5)).astype(np.int32))
        vals = jnp.asarray(RNG.normal(size=(m, B, 5)).astype(np.float32))
        y = jnp.asarray(np.sign(RNG.normal(size=(m, B))).astype(np.float32))
        W = jnp.asarray(RNG.normal(size=(m, d)).astype(np.float32) * 0.1)
        t = jnp.float32(2.0)
        want = hinge_ref.ell_fleet_half_step_ref(W, cols, vals, y, 1e-2, t)
        got = hinge_ops.ell_fleet_half_step(W, cols, vals, y, lam=1e-2, t=t,
                                            interpret=True, schedule="prefetch",
                                            n_blocks_max=1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)

    def test_prefetch_degenerate_all_pad_node(self):
        """A node whose minibatch is entirely pad rows (vals=0, y=0): its map
        is all sentinel, its half-step is pure decay (+projection)."""
        m, B, d = 3, 4, 300
        _, cols, vals, y, W = self._ell_planes(m, B, d, 6)
        cols = cols.at[1].set(0)
        vals = vals.at[1].set(0.0)
        y = y.at[1].set(0.0)
        t = jnp.float32(3.0)
        want = hinge_ref.ell_fleet_half_step_ref(W, cols, vals, y, 1e-2, t)
        got = hinge_ops.ell_fleet_half_step(W, cols, vals, y, lam=1e-2, t=t,
                                            interpret=True, schedule="prefetch")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)

    def test_prefetch_degenerate_k_zero(self):
        """k_max=0 planes (every row empty after bucketing) still dispatch."""
        m, B, d = 2, 3, 200
        cols = jnp.zeros((m, B, 0), jnp.int32)
        vals = jnp.zeros((m, B, 0), jnp.float32)
        y = jnp.zeros((m, B), jnp.float32)
        W = jnp.asarray(RNG.normal(size=(m, d)).astype(np.float32))
        t = jnp.float32(2.0)
        want = hinge_ref.ell_fleet_half_step_ref(
            W, jnp.zeros((m, B, 1), jnp.int32), jnp.zeros((m, B, 1), jnp.float32),
            y, 1e-2, t)
        for sched in ("sweep", "prefetch"):
            got = hinge_ops.ell_fleet_half_step(W, cols, vals, y, lam=1e-2, t=t,
                                                interpret=True, schedule=sched)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)

    def test_margins_prefetch_kernel_matches_ref(self):
        """Kernel-level check of the scalar-prefetched DMA steering."""
        m, B, d, k, blk_d = 2, 6, 500, 9, 128
        X, cols, vals, y, W = self._ell_planes(m, B, d, k)
        n_d_blocks = -(-d // blk_d)
        kw = cols.shape[2]
        colsP = jnp.pad(cols, ((0, 0), (0, 2), (0, 128 - kw)))
        valsP = jnp.pad(vals, ((0, 0), (0, 2), (0, 128 - kw)))
        yP = jnp.pad(y, ((0, 0), (0, 2)))
        WP = jnp.pad(W, ((0, 0), (0, (n_d_blocks + 1) * blk_d - d)))
        bids = jnp.asarray(block_map(np.asarray(colsP), np.asarray(valsP),
                                     blk_d, n_d_blocks, 5))
        got = hinge_sparse.ell_margins_prefetch(colsP, valsP, WP, yP, bids,
                                                blk_d=blk_d, n_d_blocks=n_d_blocks,
                                                interpret=True)[:, :B]
        want = jnp.stack([
            hinge_ref.ell_margins_ref(W[i], cols[i], vals[i], y[i])
            for i in range(m)])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)

    def test_pad_entries_inert(self):
        """Extra (col=0, val=0) ELL entries change nothing — the pad
        convention the kernels rely on instead of a validity plane. (Row
        padding is NOT free: B is the batch-mean denominator, which is why
        only the wrapper pads rows, before computing scal.)"""
        m, B, d, k = 2, 4, 100, 5
        X = _random_sparse(m * B, d, k)
        ell = ELL.from_dense(X)
        kw = ell.k_max
        cols = jnp.asarray(ell.cols.reshape(m, B, kw))
        vals = jnp.asarray(ell.vals.reshape(m, B, kw))
        y = jnp.asarray(np.sign(RNG.normal(size=(m, B))).astype(np.float32))
        W = jnp.asarray(RNG.normal(size=(m, d)).astype(np.float32) * 0.1)
        t = jnp.float32(2.0)
        base = hinge_ops.ell_fleet_half_step(W, cols, vals, y, lam=1e-2, t=t,
                                             interpret=True)
        wide = hinge_ops.ell_fleet_half_step(
            W, jnp.pad(cols, ((0, 0), (0, 0), (0, 9))),
            jnp.pad(vals, ((0, 0), (0, 0), (0, 9))),
            y, lam=1e-2, t=t, interpret=True)
        np.testing.assert_allclose(np.asarray(base), np.asarray(wide), atol=1e-6)


# ----------------------------------------------------- block-bucketed ELL

class TestBlockBucketing:
    def _planes(self, m, B, k, d, pad_frac=0.3):
        cols = RNG.integers(0, d, size=(m, B, k)).astype(np.int32)
        vals = RNG.normal(size=(m, B, k)).astype(np.float32)
        vals[RNG.random((m, B, k)) < pad_frac] = 0.0
        cols[vals == 0] = 0
        return cols, vals

    @settings(max_examples=15, deadline=None)
    @given(st.integers(1, 4), st.integers(1, 5), st.integers(1, 9),
           st.integers(8, 300), st.integers(8, 64))
    def test_bucket_by_block_properties(self, m, B, k, d, blk_d):
        """Sorted planes are a permutation; every slice is block-pure; pads
        and sentinel slots are inert; blocks_visited counts live buckets."""
        cols, vals = self._planes(m, B, k, d)
        bb = bucket_by_block(cols, vals, blk_d, d=d)
        n_blk = -(-d // blk_d)
        for i in range(m):
            assert (sorted(zip(bb.cols[i], bb.vals[i]))
                    == sorted(zip(cols[i].reshape(-1), vals[i].reshape(-1))))
            for j in range(bb.n_blocks_max):
                s, e = bb.starts[i, j], bb.starts[i, j + 1]
                if bb.block_ids[i, j] < n_blk:
                    assert np.all(bb.cols[i, s:e] // blk_d == bb.block_ids[i, j])
                    assert np.all(bb.vals[i, s:e] != 0)
                else:
                    assert s == e  # sentinel slot: empty slice
            live = np.unique(cols[i][vals[i] != 0] // blk_d)
            assert bb.blocks_visited()[i] == len(live)
            np.testing.assert_array_equal(
                np.sort(bb.block_ids[i][bb.block_ids[i] < n_blk]), live)

    @settings(max_examples=15, deadline=None)
    @given(st.integers(1, 4), st.integers(1, 5), st.integers(1, 9),
           st.integers(8, 300), st.integers(8, 64), st.integers(1, 12))
    def test_block_map_host_device_agree(self, m, B, k, d, blk_d, extra):
        """formats.block_map and ops.ell_block_map are pinned together,
        including maps wider than the block count (all-sentinel tail)."""
        cols, vals = self._planes(m, B, k, d)
        n_blk = -(-d // blk_d)
        nbm = min(B * k, n_blk) + extra
        host = block_map(cols, vals, blk_d, n_blk, nbm)
        dev = np.asarray(hinge_ops.ell_block_map(
            jnp.asarray(cols), jnp.asarray(vals), blk_d=blk_d,
            n_d_blocks=n_blk, n_blocks_max=nbm))
        np.testing.assert_array_equal(host, dev)

    def test_minibatch_block_bound_sound(self):
        """No B-row draw (with replacement) can exceed the static cap."""
        m, n_i, k, d, blk_d, B = 3, 40, 7, 500, 64, 4
        cols, vals = self._planes(m, n_i, k, d)
        bound = minibatch_block_bound(cols, vals, B, blk_d, d=d)
        n_blk = -(-d // blk_d)
        for _ in range(200):
            i = int(RNG.integers(0, m))
            rows = RNG.integers(0, n_i, size=B)  # with replacement, like _batch_ids
            cc, vv = cols[i][rows], vals[i][rows]
            realized = len(np.unique(cc[vv != 0] // blk_d))
            assert realized <= bound <= n_blk

    def test_row_block_counts_matches_naive(self):
        cols, vals = self._planes(2, 6, 5, 200)
        got = row_block_counts(cols, vals, 32)
        for i in range(2):
            for r in range(6):
                want = len(np.unique(cols[i, r][vals[i, r] != 0] // 32))
                assert got[i, r] == want

    def test_frequency_remap_is_pure_relabeling(self):
        cols, vals = self._planes(2, 8, 6, 120, pad_frac=0.2)
        new_cols, perm = frequency_remap(cols, vals, 120)
        assert np.all(new_cols[vals == 0] == 0)  # pads stay canonical
        # dense matrices agree after permuting columns back
        def dense(c):
            X = np.zeros((16, 120), np.float32)
            np.add.at(X, (np.repeat(np.arange(16), 6),
                          c.reshape(16, 6).reshape(-1)), vals.reshape(-1))
            return X
        np.testing.assert_allclose(dense(cols)[:, perm], dense(new_cols))
        # hot columns got the leading ranks: frequencies are non-increasing
        freq = np.bincount(new_cols.reshape(-1)[vals.reshape(-1) != 0], minlength=120)
        assert np.all(np.diff(freq) <= 0) or freq.max() == freq.min()

    def test_ccat_skew_concentrates_blocks(self):
        """The CCAT spec's Zipf column profile: leading (frequency-ranked)
        columns dominate, so a single-row minibatch touches few d-blocks —
        the structure the prefetch schedule's ≤1/10 acceptance rides on."""
        ds = svm_datasets.make_dataset("ccat", scale=0.0005, seed=0, sparse=True)
        assert np.all(ds.X_train.row_nnz() == 76)  # skew keeps nnz exact
        Pe, yp, nc = svm_datasets.partition(ds.X_train, ds.y_train, 4, seed=0)
        n_blk = -(-Pe.d // 128)
        bound = Pe.block_bound(1)
        assert bound <= n_blk // 10, (bound, n_blk)


# ----------------------------------------------------------------- libsvm

class TestLibsvmStreaming:
    CONTENT = "+1 1:0.5 3:2.0\n-1 2:1.5\n# comment\n+1 3:1.0 4:-0.5\n-1 1:0.25 4:1.0\n"

    def test_csr_loader_matches_dense(self, tmp_path):
        p = tmp_path / "toy.svm"
        p.write_text(self.CONTENT)
        Xd, yd = libsvm.load_libsvm(str(p))
        csr, ys = libsvm.load_libsvm_csr(str(p))
        assert csr.shape == Xd.shape
        np.testing.assert_array_equal(csr.to_dense(), Xd)
        np.testing.assert_array_equal(ys, yd)

    def test_chunked_iter_concatenates(self, tmp_path):
        p = tmp_path / "toy.svm"
        p.write_text(self.CONTENT)
        chunks = list(libsvm.iter_libsvm_chunks(str(p), n_features=4, chunk_rows=2))
        assert len(chunks) == 2 and chunks[0][0].shape == (2, 4)
        X = np.concatenate([c.to_dense() for c, _ in chunks])
        Xd, _ = libsvm.load_libsvm(str(p), n_features=4)
        np.testing.assert_array_equal(X, Xd)
        # streaming loader with explicit d matches too
        csr, _ = libsvm.load_libsvm_csr(str(p), n_features=4, chunk_rows=2)
        np.testing.assert_array_equal(csr.to_dense(), Xd)

    def test_out_of_range_strict_raises(self, tmp_path):
        p = tmp_path / "toy.svm"
        p.write_text("+1 1:1.0 9:2.0\n-1 2:1.0 8:3.0\n")
        for loader in (libsvm.load_libsvm, libsvm.load_libsvm_csr):
            with pytest.raises(ValueError, match="exceeds"):
                loader(str(p), n_features=4, strict=True)

    def test_out_of_range_warns_once_with_count(self, tmp_path):
        p = tmp_path / "toy.svm"
        p.write_text("+1 1:1.0 9:2.0\n-1 2:1.0 8:3.0\n")
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            X, _ = libsvm.load_libsvm(str(p), n_features=4)
        assert X.shape == (2, 4)
        assert len(caught) == 1 and "dropped 2" in str(caught[0].message)
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            csr, _ = libsvm.load_libsvm_csr(str(p), n_features=4)
        assert csr.shape == (2, 4) and csr.nnz == 2
        assert len(caught) == 1 and "dropped 2" in str(caught[0].message)


# ------------------------------------------------------ generator / dataset

class TestSparseDatasets:
    def test_generator_realized_nnz_exact(self):
        """Without-replacement sampling: realized nnz hits the spec exactly
        (the with-replacement draw undershot at higher densities)."""
        for name in ("reuters", "mnist"):
            spec = svm_datasets.PAPER_DATASETS[name]
            ds = svm_datasets.make_dataset(name, scale=0.003, seed=1)
            nnz_target = max(1, int(round(spec.sparsity * spec.d)))
            row_nnz = (np.asarray(ds.X_train) != 0).sum(axis=1)
            assert np.all(row_nnz == nnz_target), (name, row_nnz[:5], nnz_target)

    def test_sparse_dataset_emits_ell(self):
        spec = svm_datasets.PAPER_DATASETS["reuters"]
        ds = svm_datasets.make_dataset("reuters", scale=0.02, seed=0, sparse=True)
        assert ds.sparse and isinstance(ds.X_train, ELL)
        assert ds.d == spec.d
        nnz_target = max(1, int(round(spec.sparsity * spec.d)))
        assert ds.X_train.k_max == nnz_target
        assert np.all(ds.X_train.row_nnz() == nnz_target)
        assert set(np.unique(ds.y_train)) <= {-1.0, 1.0}
        norms = np.linalg.norm(ds.X_train.vals, axis=1)
        np.testing.assert_allclose(norms, 1.0, atol=1e-4)

    def test_sparse_rejected_for_dense_spec(self):
        with pytest.raises(ValueError, match="dense"):
            svm_datasets.make_dataset("usps", sparse=True)

    def test_partition_ell_matches_dense(self):
        ds = svm_datasets.make_dataset("reuters", scale=0.02, seed=0, sparse=True)
        Xd = ds.X_train.to_dense()
        Pe, yps, ncs = svm_datasets.partition(ds.X_train, ds.y_train, 4, seed=7)
        Xp, ypd, ncd = svm_datasets.partition(Xd, ds.y_train, 4, seed=7)
        assert isinstance(Pe, EllPartitions) and Pe.shape == Xp.shape
        np.testing.assert_array_equal(yps, ypd)
        np.testing.assert_array_equal(ncs, ncd)
        dense_again = np.stack([
            ELL(Pe.cols[i], Pe.vals[i], (Pe.cols.shape[1], Pe.d)).to_dense()
            for i in range(4)])
        np.testing.assert_array_equal(dense_again, Xp)

    def test_partition_csr_input(self):
        X = _random_sparse(33, 40, 6)
        y = np.sign(RNG.normal(size=33)).astype(np.float32)
        Pe, yp, nc = svm_datasets.partition(CSR.from_dense(X), y, 5, seed=1)
        assert isinstance(Pe, EllPartitions)
        assert nc.sum() == 33


# ------------------------------------------------------------- end-to-end

class TestSparseGadget:
    def _reuters_shaped(self, m=5, seed=0):
        ds = svm_datasets.make_dataset("reuters", scale=0.05, seed=seed, sparse=True)
        Pe, yp, nc = svm_datasets.partition(ds.X_train, ds.y_train, m, seed=3)
        Xp, ypd, ncd = svm_datasets.partition(ds.X_train.to_dense(), ds.y_train,
                                              m, seed=3)
        return ds, Pe, Xp, yp, nc

    @pytest.mark.parametrize("topology", ["exponential", "random"])
    def test_sparse_vs_dense_consensus(self, topology):
        """The acceptance bar: same data, same PRNG streams — the sparse path
        must land on the dense path's consensus weights to ≤ 1e-5."""
        ds, Pe, Xp, yp, nc = self._reuters_shaped()
        cfg = GadgetConfig(lam=ds.lam, batch_size=4, gossip_rounds=3,
                           topology=topology, max_iters=200, check_every=50,
                           epsilon=0.0)
        rs = gadget_train(Pe, jnp.asarray(yp), cfg, n_counts=nc)
        rd = gadget_train(jnp.asarray(Xp), jnp.asarray(yp), cfg, n_counts=nc)
        diff = float(jnp.max(jnp.abs(rs.w_consensus - rd.w_consensus)))
        assert diff <= 1e-5, diff
        np.testing.assert_allclose(rs.objective_trace, rd.objective_trace,
                                   atol=1e-5)

    def test_prefetch_schedule_consensus(self):
        """Tentpole acceptance: the touched-block schedule, run through the
        whole device-resident loop (device map + prefetch kernels + bucket
        fold), lands on the dense path's consensus to ≤ 1e-5."""
        ds, Pe, Xp, yp, nc = self._reuters_shaped(m=4)
        cfg = GadgetConfig(lam=ds.lam, batch_size=4, gossip_rounds=2,
                           max_iters=60, check_every=30, epsilon=0.0)
        rd = gadget_train(jnp.asarray(Xp), jnp.asarray(yp), cfg, n_counts=nc)
        rp = gadget_train(Pe, jnp.asarray(yp),
                          cfg._replace(use_kernels=True, sparse_schedule="prefetch"),
                          n_counts=nc)
        assert float(jnp.max(jnp.abs(rp.w_consensus - rd.w_consensus))) <= 1e-5
        # and the sweep schedule agrees with prefetch bit-for-bit-ish
        rs = gadget_train(Pe, jnp.asarray(yp),
                          cfg._replace(use_kernels=True, sparse_schedule="sweep"),
                          n_counts=nc)
        assert float(jnp.max(jnp.abs(rp.W - rs.W))) <= 1e-5

    def test_sparse_kernel_path_matches_jnp_path(self):
        ds, Pe, Xp, yp, nc = self._reuters_shaped(m=4)
        cfg = GadgetConfig(lam=ds.lam, batch_size=4, gossip_rounds=2,
                           max_iters=60, check_every=30, epsilon=0.0)
        rk = gadget_train(Pe, jnp.asarray(yp), cfg._replace(use_kernels=True),
                          n_counts=nc)
        rj = gadget_train(Pe, jnp.asarray(yp), cfg._replace(use_kernels=False),
                          n_counts=nc)
        assert float(jnp.max(jnp.abs(rk.w_consensus - rj.w_consensus))) < 1e-4

    def test_sparse_reference_oracle_agrees(self):
        ds, Pe, Xp, yp, nc = self._reuters_shaped(m=4)
        cfg = GadgetConfig(lam=ds.lam, batch_size=4, gossip_rounds=2,
                           max_iters=80, check_every=40, epsilon=0.0)
        dev = gadget_train(Pe, jnp.asarray(yp), cfg._replace(fused=False),
                           n_counts=nc)
        ref = gadget_train_reference(Pe, jnp.asarray(yp), cfg, n_counts=nc)
        assert float(jnp.max(jnp.abs(dev.W - ref.W))) < 1e-5

    def test_sparse_training_learns(self):
        """Sanity: the sparse path actually fits the training data (at this
        tiny scale d >> n, so held-out accuracy is not meaningful)."""
        from repro.core import svm_objective as obj
        ds, Pe, Xp, yp, nc = self._reuters_shaped()
        cfg = GadgetConfig(lam=ds.lam, batch_size=8, gossip_rounds=3,
                           max_iters=500, check_every=100, epsilon=0.0)
        res = gadget_train(Pe, jnp.asarray(yp), cfg, n_counts=nc)
        Xtr = jnp.asarray(ds.X_train.to_dense())
        acc = float(obj.accuracy(res.w_consensus, Xtr, jnp.asarray(ds.y_train)))
        assert acc > 0.9, acc
        assert res.objective_trace[-1] < res.objective_trace[0]


# ------------------------------------------------------------- mesh path

MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.core.gadget import GadgetConfig, make_gadget_mesh_step
from repro.data import svm_datasets

m = 4
ds = svm_datasets.make_dataset("reuters", scale=0.02, seed=0, sparse=True)
Pe, yp, nc = svm_datasets.partition(ds.X_train, ds.y_train, m, seed=1)
Xd, _, _ = svm_datasets.partition(ds.X_train.to_dense(), ds.y_train, m, seed=1)
mesh = Mesh(np.array(jax.devices()), ("nodes",))
cfg = GadgetConfig(lam=ds.lam, batch_size=2, gossip_rounds=2)
step_s = make_gadget_mesh_step(
    cfg._replace(use_kernels=True, sparse_schedule="prefetch"), {"nodes": m},
    sparse_block_bound=Pe.block_bound(cfg.batch_size))
step_d = make_gadget_mesh_step(cfg._replace(use_kernels=False), {"nodes": m})

def sharded(step, sparse):
    def per_node(w, c, v, x, y, keys, t):
        X_local = (c[0], v[0]) if sparse else x[0]
        return step(w[0], X_local, y[0], t, keys[0])[None]
    specs = (P("nodes"),) * 6 + (P(),)
    # check_rep=False: no replication rule for pallas_call in shard_map yet
    return shard_map(per_node, mesh=mesh, in_specs=specs, out_specs=P("nodes"),
                     check_rep=False)

cols, vals = jnp.asarray(Pe.cols), jnp.asarray(Pe.vals)
Xd, yj = jnp.asarray(Xd), jnp.asarray(yp)
Ws = Wd = jnp.zeros((m, Pe.d), jnp.float32)
run_s = jax.jit(sharded(step_s, True))
run_d = jax.jit(sharded(step_d, False))
for t in range(1, 4):
    keys = jax.random.split(jax.random.fold_in(jax.random.PRNGKey(0), t), m)
    Ws = run_s(Ws, cols, vals, Xd, yj, keys, jnp.int32(t))
    Wd = run_d(Wd, cols, vals, Xd, yj, keys, jnp.int32(t))
diff = float(jnp.max(jnp.abs(Ws - Wd)))
assert diff <= 1e-5, f"sparse-vs-dense mesh step diff {diff:.2e}"
assert float(jnp.max(jnp.abs(Ws))) > 0, "mesh step produced all-zero weights"
print(f"MESH_SPARSE_OK diff={diff:.2e}")
"""


class TestMeshSparse:
    def test_mesh_step_sparse_vs_dense_multidevice(self, tmp_path):
        """Node-sharded ELL planes inside shard_map (4 forced CPU devices,
        subprocess so the flag cannot leak): the sparse prefetch-kernel mesh
        step matches the dense jnp mesh step on the same data and keys."""
        import subprocess
        import sys
        script = tmp_path / "mesh_sparse.py"
        script.write_text(MESH_SCRIPT)
        repo = __file__.rsplit("/tests/", 1)[0]
        env = {**__import__("os").environ, "PYTHONPATH": f"{repo}/src"}
        p = subprocess.run([sys.executable, str(script)], capture_output=True,
                           text=True, timeout=540, env=env)
        assert p.returncode == 0, p.stdout[-2000:] + p.stderr[-2000:]
        assert "MESH_SPARSE_OK" in p.stdout

    def test_mesh_step_single_device_axis(self):
        """Axis size 1 (this process's real device count): no neighbors, so
        the step is just the local sparse half-step — and it runs the ELL
        kernels inside shard_map without a mesh-collective in sight."""
        import jax
        from jax.sharding import Mesh, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.core.gadget import make_gadget_mesh_step

        ds = svm_datasets.make_dataset("reuters", scale=0.02, seed=0, sparse=True)
        Pe, yp, nc = svm_datasets.partition(ds.X_train, ds.y_train, 1, seed=1)
        cfg = GadgetConfig(lam=ds.lam, batch_size=3, gossip_rounds=2,
                           use_kernels=True, sparse_schedule="prefetch")
        step = make_gadget_mesh_step(cfg, {"nodes": 1},
                                     sparse_block_bound=Pe.block_bound(3))
        mesh = Mesh(np.array(jax.devices()[:1]), ("nodes",))
        cols, vals = jnp.asarray(Pe.cols[0]), jnp.asarray(Pe.vals[0])
        y0 = jnp.asarray(yp[0])
        w0 = jnp.zeros((Pe.d,), jnp.float32)
        key = jax.random.PRNGKey(7)
        f = shard_map(lambda w, c, v, y, k: step(w, (c, v), y, jnp.int32(1), k),
                      mesh=mesh, in_specs=(P(), P(), P(), P(), P()),
                      out_specs=P(), check_rep=False)
        got = jax.jit(f)(w0, cols, vals, y0, key)
        want = step(w0, (cols, vals), y0, jnp.int32(1), key)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)
        assert float(jnp.max(jnp.abs(got))) > 0
