"""Serving subsystem: snapshot ring vs the host-loop reference (bit-matching
sweep over K), versioned checkpoint round-trips incl. int8 dtype fidelity and
treedef-mismatch errors, predict kernel parity (dense fused argmax + query-side
touched-block sparse) against the shared sweep-oracle fixture, the bucketed
micro-batcher's static-shape/recompile guarantees, the SvmServer engine end to
end, and the shard_map batch-parallel scorer."""
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import checkpoint as ckpt
from repro import serve
from repro.core.gadget import GadgetConfig, gadget_train, gadget_train_reference
from repro.kernels.hinge_subgrad import ops as hinge_ops
from repro.kernels.hinge_subgrad import ref as hinge_ref
from repro.serve import snapshot as snap_mod
from tests.sparse_utils import ell_minibatch_planes, random_ell_queries

RNG = np.random.default_rng(0)


def _toy_parts(m=3, n_i=20, d=32, seed=0):
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=d)
    X = rng.normal(size=(m * n_i, d)).astype(np.float32)
    y = np.sign(X @ w_true).astype(np.float32)
    return jnp.asarray(X.reshape(m, n_i, d)), jnp.asarray(y.reshape(m, n_i))


def _toy_cfg(max_iters=24, **kw):
    base = dict(lam=1e-3, batch_size=3, gossip_rounds=2, max_iters=max_iters,
                check_every=10, epsilon=0.0)
    base.update(kw)
    return GadgetConfig(**base)


# ------------------------------------------------------------ snapshot ring


class TestSnapshotRing:
    @settings(max_examples=12, deadline=None)
    @given(st.integers(1, 30), st.integers(1, 6), st.integers(5, 24))
    def test_device_ring_bit_matches_reference(self, K, slots, iters):
        """The acceptance sweep: device snapshots (unfused loop) must equal
        the host-loop reference trace at every K — including K > iters, where
        only the final-iter snapshot exists — slot for slot and bit for bit."""
        Xp, yp = _toy_parts()
        cfg = _toy_cfg(max_iters=iters, fused=False)
        dev = gadget_train(Xp, yp, cfg, snapshot_every=K, snapshot_slots=slots)
        ref = gadget_train_reference(Xp, yp, cfg, snapshot_every=K,
                                     snapshot_slots=slots)
        rd, rr = dev.snapshots, ref.snapshots
        assert rd.count == rr.count == iters // K
        np.testing.assert_array_equal(rd.iterations, rr.iterations)
        np.testing.assert_array_equal(rd.W, rr.W)  # weights: bit for bit
        np.testing.assert_array_equal(rd.final_w, rr.final_w)
        assert rd.final_iteration == rr.final_iteration == iters
        # the objective scalar is a full-data reduction — inside the jitted
        # while_loop XLA may fuse it differently than the reference's
        # standalone jit, so it matches to float rounding, not bitwise
        np.testing.assert_allclose(np.nan_to_num(rd.objectives),
                                   np.nan_to_num(rr.objectives), rtol=1e-5,
                                   atol=1e-6)
        np.testing.assert_allclose(rd.final_objective, rr.final_objective,
                                   rtol=1e-5, atol=1e-6)

        sd, sr = serve.snapshots_from(dev), serve.snapshots_from(ref)
        assert [s.iteration for s in sd] == [s.iteration for s in sr]
        assert sd[-1].iteration == iters  # final-iter snapshot always present
        its = [s.iteration for s in sd]
        assert its == sorted(its) and len(set(its)) == len(its)
        # ring semantics: the latest min(count, slots) periodic snapshots
        # survive, then the final iterate (deduped when iters % K == 0)
        periodic = [j * K for j in range(1, iters // K + 1)]
        expect = periodic[len(periodic) - min(slots, len(periodic)):]
        if not expect or expect[-1] != iters:
            expect = expect + [iters]
        assert its == expect

    def test_k_larger_than_iters_yields_final_only(self):
        Xp, yp = _toy_parts()
        res = gadget_train(Xp, yp, _toy_cfg(max_iters=7), snapshot_every=50)
        assert res.snapshots.count == 0
        snaps = serve.snapshots_from(res)
        assert len(snaps) == 1 and snaps[0].iteration == 7
        np.testing.assert_array_equal(snaps[0].w, np.asarray(res.w_consensus))

    def test_ring_wraparound_keeps_latest(self):
        Xp, yp = _toy_parts()
        res = gadget_train(Xp, yp, _toy_cfg(max_iters=20), snapshot_every=2,
                           snapshot_slots=3)
        assert res.snapshots.count == 10
        snaps = serve.snapshots_from(res)
        # last 3 periodic snapshots survive; 20 is both periodic and final
        assert [s.iteration for s in snaps] == [16, 18, 20]

    def test_fused_ring_matches_reference_loosely(self):
        """The default fused loop reorders float math; its snapshots must
        still land on the reference trace to the standing 1e-5 bar."""
        Xp, yp = _toy_parts()
        dev = gadget_train(Xp, yp, _toy_cfg(max_iters=20), snapshot_every=5)
        ref = gadget_train_reference(Xp, yp, _toy_cfg(max_iters=20),
                                     snapshot_every=5)
        np.testing.assert_array_equal(dev.snapshots.iterations,
                                      ref.snapshots.iterations)
        assert np.max(np.abs(dev.snapshots.W - ref.snapshots.W)) <= 1e-5

    def test_zero_iteration_run_still_exports_initial_state(self):
        """max_iters=0 with snapshot_every must hand back a servable ring
        (the initial w=0 iterate, objective exactly 1), not None."""
        Xp, yp = _toy_parts()
        res = gadget_train(Xp, yp, _toy_cfg(max_iters=0), snapshot_every=5)
        snaps = serve.snapshots_from(res)
        assert len(snaps) == 1 and snaps[0].iteration == 0
        np.testing.assert_array_equal(snaps[0].w, np.zeros(Xp.shape[-1]))
        assert snaps[0].objective == 1.0

    def test_snapshot_validation(self):
        Xp, yp = _toy_parts()
        with pytest.raises(ValueError, match="snapshot_every"):
            gadget_train(Xp, yp, _toy_cfg(max_iters=4), snapshot_every=0)
        with pytest.raises(ValueError, match="snapshot_slots"):
            gadget_train(Xp, yp, _toy_cfg(max_iters=4), snapshot_every=2,
                         snapshot_slots=0)
        res = gadget_train(Xp, yp, _toy_cfg(max_iters=4))
        assert res.snapshots is None
        with pytest.raises(ValueError, match="snapshot_every"):
            serve.snapshots_from(res)


# ---------------------------------------------------------- predict kernels


class TestPredictKernels:
    @pytest.mark.parametrize("B,d,C", [(1, 64, 1), (5, 300, 3), (16, 1024, 10),
                                       (9, 130, 129)])
    def test_dense_predict_parity(self, B, d, C):
        X = RNG.normal(size=(B, d)).astype(np.float32)
        W = RNG.normal(size=(C, d)).astype(np.float32)
        scores, labels = hinge_ops.dense_predict(jnp.asarray(W), jnp.asarray(X),
                                                 interpret=True)
        # rtol: blocked accumulation vs BLAS ordering at d=1024 differs by a
        # few f32 ulps on O(30) scores
        np.testing.assert_allclose(np.asarray(scores), X @ W.T, rtol=1e-5,
                                   atol=2e-5)
        np.testing.assert_array_equal(
            np.asarray(labels),
            np.asarray(hinge_ref.predict_labels_ref(jnp.asarray(W), jnp.asarray(X))))

    def test_dense_predict_binary(self):
        B, d = 11, 200
        X = RNG.normal(size=(B, d)).astype(np.float32)
        w = RNG.normal(size=d).astype(np.float32)
        scores, labels = hinge_ops.dense_predict(jnp.asarray(w), jnp.asarray(X),
                                                 interpret=True)
        assert scores.shape == (B,) and labels.shape == (B,)
        np.testing.assert_allclose(np.asarray(scores), X @ w, atol=2e-5)
        np.testing.assert_array_equal(np.asarray(labels),
                                      np.where(X @ w >= 0, 1.0, -1.0))

    def test_argmax_pad_classes_masked(self):
        """Pad class rows are zero ⇒ score 0, which beats all-negative real
        scores unless masked — the kernel must never emit a pad label."""
        B, d, C = 8, 64, 3
        X = -np.abs(RNG.normal(size=(B, d))).astype(np.float32)
        W = np.abs(RNG.normal(size=(C, d))).astype(np.float32)  # scores < 0
        _, labels = hinge_ops.dense_predict(jnp.asarray(W), jnp.asarray(X),
                                            interpret=True)
        assert np.all(np.asarray(labels) < C)

    def test_argmax_first_occurrence_ties(self):
        X = np.ones((4, 16), np.float32)
        W = np.stack([np.ones(16), np.ones(16), np.zeros(16)]).astype(np.float32)
        _, labels = hinge_ops.dense_predict(jnp.asarray(W), jnp.asarray(X),
                                            interpret=True)
        np.testing.assert_array_equal(np.asarray(labels), np.zeros(4, np.int32))

    @settings(max_examples=10, deadline=None)
    @given(st.integers(1, 8), st.integers(32, 500), st.integers(1, 8),
           st.integers(1, 12), st.booleans())
    def test_ell_predict_parity_property(self, B, d, C, k, localized):
        """Sparse predict == dense predict == jnp oracle on the same rows —
        the satellite's shared-oracle check: the planes/dense pair comes from
        the same tests/sparse_utils fixture the training sweep-kernel parity
        tests use, not a re-derived copy."""
        X, cols, vals, _, _ = ell_minibatch_planes(1, B, d, min(k, d), localized)
        X, cols, vals = X[0], cols[0], vals[0]
        W = RNG.normal(size=(C, d)).astype(np.float32)
        want_s, want_l = hinge_ops.dense_predict(jnp.asarray(W), jnp.asarray(X),
                                                 interpret=True)
        got_s, got_l = hinge_ops.ell_predict(jnp.asarray(W), cols, vals,
                                             interpret=True)
        np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                                   atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(got_s),
            np.asarray(hinge_ref.ell_predict_scores_ref(jnp.asarray(W), cols, vals)),
            atol=1e-5)
        np.testing.assert_array_equal(np.asarray(got_l), np.asarray(want_l))

    def test_ell_predict_host_map_and_bound(self):
        """A host-computed per-bucket map (the serving engine's path) gives
        identical scores, and the realized live count respects the bound."""
        from repro.sparse.formats import block_map, minibatch_block_bound
        B, d, k = 6, 700, 9
        X, cols, vals, _, _ = ell_minibatch_planes(1, B, d, k, localized=True)
        X, cols, vals = X[0], cols[0], vals[0]
        w = RNG.normal(size=d).astype(np.float32)
        blk_d = hinge_ops.ELL_PREFETCH_BLK_D
        n_blk = -(-d // blk_d)
        bound = minibatch_block_bound(np.asarray(cols), np.asarray(vals), B,
                                      blk_d, d=d)
        bm = block_map(np.asarray(cols)[None], np.asarray(vals)[None], blk_d,
                       n_blk, bound)[0]
        assert (bm < n_blk).sum() <= bound
        base_s, _ = hinge_ops.ell_predict(jnp.asarray(w), cols, vals,
                                          interpret=True)
        got_s, _ = hinge_ops.ell_predict(jnp.asarray(w), cols, vals,
                                         block_ids=jnp.asarray(bm),
                                         interpret=True)
        np.testing.assert_allclose(np.asarray(got_s), np.asarray(base_s),
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(got_s), X @ w, atol=2e-5)

    def test_ell_predict_degenerate(self):
        w = RNG.normal(size=100).astype(np.float32)
        for k in (0, 3):
            cols = jnp.zeros((4, k), jnp.int32)
            vals = jnp.zeros((4, k), jnp.float32)
            scores, labels = hinge_ops.ell_predict(jnp.asarray(w), cols, vals,
                                                   interpret=True)
            np.testing.assert_array_equal(np.asarray(scores), np.zeros(4))
            np.testing.assert_array_equal(np.asarray(labels), np.ones(4))


# ------------------------------------------------- checkpoint + quantization


class TestServeCheckpoints:
    def _snap(self, d=48, C=None):
        w = RNG.normal(size=(C, d) if C else d).astype(np.float32)
        return snap_mod.Snapshot(iteration=17, w=w, objective=0.5)

    def test_f32_roundtrip_serves_identical(self, tmp_path):
        snap = self._snap()
        serve.to_checkpoint(snap, str(tmp_path), lam=1e-3)
        srv_disk = serve.SvmServer.load(str(tmp_path), use_kernels=True)
        srv_live = serve.SvmServer.from_snapshot(snap, use_kernels=True)
        assert srv_disk.meta["iteration"] == 17
        assert srv_disk.meta["lam"] == 1e-3
        X = RNG.normal(size=(9, snap.d)).astype(np.float32)
        s_disk, l_disk = srv_disk.score(X)
        s_live, l_live = srv_live.score(X)
        np.testing.assert_array_equal(s_disk, s_live)  # bit-identical weights
        np.testing.assert_array_equal(l_disk, l_live)

    def test_int8_roundtrip_dtype_faithful(self, tmp_path):
        """Regression (satellite): int8 leaves survive save/restore as int8,
        and the quantized engine serves exactly its dequantized weights."""
        snap = self._snap(C=3)
        serve.to_checkpoint(snap, str(tmp_path), quantize="int8")
        q, scale = snap_mod.quantize_int8(snap.w)
        like = {"w": np.zeros_like(q), "scale": np.zeros(3, np.float32)}
        tree = ckpt.restore(str(tmp_path), like)
        assert tree["w"].dtype == np.int8
        np.testing.assert_array_equal(tree["w"], q)
        np.testing.assert_array_equal(tree["scale"], scale)

        srv = serve.SvmServer.load(str(tmp_path), use_kernels=True)
        assert srv.meta["dtype"] == "int8"
        X = RNG.normal(size=(6, snap.d)).astype(np.float32)
        s_q, _ = srv.score(X)
        w_deq = snap_mod.dequantize_int8(q, scale)
        np.testing.assert_allclose(s_q, X @ w_deq.T, atol=2e-5)
        # quantization error is bounded by the scale, not hidden
        assert np.max(np.abs(w_deq - snap.w)) <= np.max(scale) / 2 + 1e-7

    def test_restore_treedef_mismatch_clear_error(self, tmp_path):
        """Regression (satellite): structure mismatch fails with the saved
        and expected treedefs named, not an unflatten crash or silent
        leaf-order scramble."""
        ckpt.save(str(tmp_path), 0, {"w": np.zeros(4), "scale": np.zeros(())})
        with pytest.raises(ValueError, match="treedef"):
            ckpt.restore(str(tmp_path), {"weights": np.zeros(4),
                                         "gain": np.zeros(())})
        with pytest.raises(ValueError, match="structure mismatch"):
            ckpt.restore(str(tmp_path), {"w": np.zeros(4)})

    def test_restore_dtype_mismatch_clear_error(self, tmp_path):
        ckpt.save(str(tmp_path), 0, {"w": np.zeros(4, np.int8)})
        with pytest.raises(ValueError, match="dtype"):
            ckpt.restore(str(tmp_path), {"w": np.zeros(4, np.float32)})

    def test_from_checkpoint_rejects_foreign(self, tmp_path):
        ckpt.save(str(tmp_path), 0, {"w": np.zeros(4)})
        with pytest.raises(ValueError, match="serving export"):
            snap_mod.from_checkpoint(str(tmp_path))

    def test_manifest_versioned(self, tmp_path):
        from repro.checkpoint.io import MANIFEST_VERSION
        serve.to_checkpoint(self._snap(), str(tmp_path))
        manifest = ckpt.read_manifest(str(tmp_path))
        assert manifest["version"] == MANIFEST_VERSION
        extra = manifest["extra"]
        assert extra["kind"] == snap_mod.SERVE_KIND
        assert extra["serve_format"] == snap_mod.SERVE_FORMAT_VERSION


# ---------------------------------------------------------------- batcher


class TestMicroBatcher:
    def _server(self, d=256, seed=1):
        w = np.random.default_rng(seed).normal(size=d).astype(np.float32)
        return serve.SvmServer(w, use_kernels=True)

    def test_bucket_ladder_shape_policy(self):
        buckets = serve.bucket_ladder(100, rows=8, min_k=16, d=1280)
        assert [b.k for b in buckets] == [16, 32, 64, 100]
        assert all(b.n_blocks_max <= 10 for b in buckets)  # n_d_blocks cap
        mb = serve.MicroBatcher(buckets)
        assert mb.bucket_for(1).k == 16 and mb.bucket_for(33).k == 64
        with pytest.raises(ValueError, match="widest bucket"):
            mb.bucket_for(101)

    def test_drain_parity_and_pad_inertness(self):
        d = 256
        srv = self._server(d)
        queries, ell, X = random_ell_queries(13, d, 10, RNG)
        mb = serve.MicroBatcher(serve.bucket_ladder(ell.k_max or 1, rows=4,
                                                    min_k=4, d=d))
        rids = [mb.submit(c, v) for c, v in queries]
        out = mb.drain(srv.scorer_for())
        assert len(out) == len(queries) and mb.pending == 0
        want = X @ srv.W
        for i, rid in enumerate(rids):
            score, label = out[rid]
            np.testing.assert_allclose(score, want[i], atol=2e-5)
            assert label == (1.0 if want[i] >= 0 else -1.0)

    def test_compile_count_bounded_by_buckets(self):
        """The tentpole's static-shape guarantee, measured: many drains of
        wildly ragged traffic compile at most one executable per bucket."""
        d = 512
        srv = self._server(d)
        buckets = serve.bucket_ladder(24, rows=4, min_k=8, d=d)
        mb = serve.MicroBatcher(buckets)
        rng = np.random.default_rng(7)
        for _ in range(5):
            for _ in range(int(rng.integers(1, 11))):
                nnz = int(rng.integers(1, 25))
                cols = rng.choice(d, size=nnz, replace=False)
                mb.submit(cols, rng.normal(size=nnz))
            mb.drain(srv.scorer_for())
        assert srv.stats()["distinct_shapes"] <= len(buckets)
        st = mb.stats()
        assert st["requests"] >= 5 and st["batches"] >= 5
        assert st["latency_p50_ms"] <= st["latency_p99_ms"]

    def test_latency_accounting_with_fake_clock(self):
        times = iter(np.arange(0.0, 100.0, 0.5))
        mb = serve.MicroBatcher((serve.Bucket(2, 4, 2),),
                                clock=lambda: float(next(times)))
        mb.submit([1], [1.0])
        mb.submit([2], [0.5])
        mb.drain(lambda b, c, v: (np.zeros(b.rows), np.ones(b.rows)))
        st = mb.stats()
        assert st["requests"] == 2 and st["batches"] == 1
        assert st["latency_p99_ms"] >= st["latency_p50_ms"] > 0
        assert st["queries_per_sec"] > 0

    def test_oversize_rejected_at_submit(self):
        mb = serve.MicroBatcher((serve.Bucket(2, 4, 2),))
        with pytest.raises(ValueError, match="widest bucket"):
            mb.submit(np.arange(5), np.ones(5))

    def test_drain_requeues_unscored_on_error(self):
        """A failing score_fn must lose neither requests nor results:
        unscored batches (including the failing one) go back on the queue,
        already-scored results are delivered by the next drain."""
        mb = serve.MicroBatcher((serve.Bucket(2, 4, 2),))
        rids = [mb.submit([i], [1.0]) for i in range(6)]  # 3 batches of 2
        calls = []

        def flaky(b, cols, vals):
            calls.append(1)
            if len(calls) == 2:
                raise RuntimeError("boom")
            return np.zeros(b.rows), np.ones(b.rows)

        with pytest.raises(RuntimeError, match="boom"):
            mb.drain(flaky)
        assert mb.pending == 4  # batch 2 (failed) + batch 3 (never reached)
        out = mb.drain(lambda b, c, v: (np.zeros(b.rows), np.ones(b.rows)))
        assert sorted(out) == rids  # all six: held batch-1 results included
        assert mb.stats()["requests"] == 6 and mb.pending == 0


# ----------------------------------------------------------------- engine


class TestSvmServer:
    def test_sparse_dense_agree_and_blocks_tracked(self):
        d, C = 640, 4
        W = RNG.normal(size=(C, d)).astype(np.float32)
        srv = serve.SvmServer(W, use_kernels=True)
        X, cols, vals, _, _ = ell_minibatch_planes(1, 6, d, 8, localized=True)
        s_d, l_d = srv.score(X[0])
        s_s, l_s = srv.score_sparse(np.asarray(cols[0]), np.asarray(vals[0]))
        np.testing.assert_allclose(s_s, s_d, atol=2e-5)
        np.testing.assert_array_equal(l_s, l_d)
        st = srv.stats()
        assert st["blocks_visited_ratio"] < 1.0  # localized queries skip blocks
        assert st["queries"] == 12 and st["sparse_batches"] == 1

    def test_kernel_and_jnp_paths_agree(self):
        d = 200
        w = RNG.normal(size=d).astype(np.float32)
        X, cols, vals, _, _ = ell_minibatch_planes(1, 5, d, 6)
        a = serve.SvmServer(w, use_kernels=True)
        b = serve.SvmServer(w, use_kernels=False)
        np.testing.assert_allclose(a.score(X[0])[0], b.score(X[0])[0], atol=2e-5)
        np.testing.assert_allclose(
            a.score_sparse(np.asarray(cols[0]), np.asarray(vals[0]))[0],
            b.score_sparse(np.asarray(cols[0]), np.asarray(vals[0]))[0],
            atol=2e-5)

    def test_shape_validation(self):
        srv = serve.SvmServer(np.zeros(8, np.float32))
        with pytest.raises(ValueError, match="d=4"):
            srv.score(np.zeros((2, 4), np.float32))
        with pytest.raises(ValueError, match=r"\(d,\) or \(C, d\)"):
            serve.SvmServer(np.zeros((2, 3, 4), np.float32))

    def test_over_cap_batch_widens_instead_of_raising(self):
        """Live traffic heavier than the calibrated cap must still be served
        correctly (map widens, counted in stats) — a mis-sized bucket may
        cost a compile, never a wedged queue."""
        d = 1280  # 10 d-blocks at blk_d=128
        w = RNG.normal(size=d).astype(np.float32)
        srv = serve.SvmServer(w, use_kernels=True)
        # one query per d-block: 10 live blocks >> cap 2
        cols = np.arange(0, d, 128, dtype=np.int32).reshape(1, -1)
        vals = np.ones_like(cols, dtype=np.float32)
        scores, _ = srv.score_sparse(cols, vals, n_blocks_max=2)
        want = np.zeros(d, np.float32)
        want[cols[0]] = 1.0
        np.testing.assert_allclose(scores, [w[cols[0]].sum()], atol=2e-5)
        assert srv.stats()["cap_overflows"] == 1


# ------------------------------------------------------------- mesh scorer


MESH_SERVE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax, numpy as np, jax.numpy as jnp
from repro.serve import make_mesh_scorer

rng = np.random.default_rng(0)
d, B, C = 96, 16, 3
W = rng.normal(size=(C, d)).astype(np.float32)
X = rng.normal(size=(B, d)).astype(np.float32)
scorer = make_mesh_scorer(W, use_kernels=True)
scores, labels = scorer(jnp.asarray(X))
np.testing.assert_allclose(np.asarray(scores), X @ W.T, atol=2e-5)
np.testing.assert_array_equal(np.asarray(labels), np.argmax(X @ W.T, axis=1))
print("MESH_SERVE_OK devices=%d" % jax.device_count())
"""


class TestMeshScorer:
    def test_single_device_parity(self):
        d = 128
        w = RNG.normal(size=d).astype(np.float32)
        X = RNG.normal(size=(8, d)).astype(np.float32)
        scorer = serve.make_mesh_scorer(w, use_kernels=True)
        scores, labels = scorer(jnp.asarray(X))
        np.testing.assert_allclose(np.asarray(scores), X @ w, atol=2e-5)
        np.testing.assert_array_equal(np.asarray(labels),
                                      np.where(X @ w >= 0, 1.0, -1.0))

    def test_four_device_subprocess(self, tmp_path):
        """Queries sharded over 4 forced CPU devices, w replicated — the
        batch-parallel serving path (subprocess so the flag cannot leak)."""
        script = tmp_path / "mesh_serve.py"
        script.write_text(MESH_SERVE_SCRIPT)
        repo = __file__.rsplit("/tests/", 1)[0]
        env = {**__import__("os").environ, "PYTHONPATH": f"{repo}/src:{repo}"}
        p = subprocess.run([sys.executable, str(script)], capture_output=True,
                           text=True, timeout=300, env=env)
        assert p.returncode == 0, p.stdout[-2000:] + p.stderr[-2000:]
        assert "MESH_SERVE_OK devices=4" in p.stdout


# ------------------------------------------------------------- multiclass


def test_predict_multiclass_routes_through_fused_kernel():
    """core.multiclass.predict_multiclass dispatches the fused predict op
    (kernel path forced here — the None default resolves per the package
    convention) — same labels as the original jnp argmax."""
    from repro.core.multiclass import predict_multiclass
    C, d, N = 5, 64, 40
    W = RNG.normal(size=(C, d)).astype(np.float32)
    X = RNG.normal(size=(N, d)).astype(np.float32)
    want = np.argmax(X @ W.T, axis=1)
    for uk in (True, False, None):
        got = predict_multiclass(jnp.asarray(W), jnp.asarray(X), use_kernels=uk)
        np.testing.assert_array_equal(np.asarray(got), want)
