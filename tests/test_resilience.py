"""Fault-tolerant Push-Sum (paper §5 future work): link failures, message
loss, and dead nodes — the mass-conservation algebra under each model."""
import jax.numpy as jnp
import numpy as np

from repro.core.resilience import FaultySim


def _vals(n=16, d=4, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32))


def test_link_drop_conserves_mass_and_converges():
    x = _vals()
    sim = FaultySim(16, "random", drop_prob=0.3, drop="link", seed=1)
    st = sim.run((x,), 120)
    # exact mass conservation under ack'd links
    assert np.isclose(float(jnp.sum(st.values[0][:, 0])), float(jnp.sum(x[:, 0])), atol=1e-3)
    assert np.isclose(float(jnp.sum(st.weight)), 16.0, atol=1e-3)
    est = st.estimate()[0]
    true = jnp.mean(x, axis=0)
    assert float(jnp.max(jnp.abs(est - true))) < 1e-2


def test_message_drop_estimates_stay_consistent():
    """Lost messages lose mass, but every node's v/w ratio remains a convex
    combination of initial values (no double counting) — node estimates
    stay within the convex hull of the inputs."""
    x = _vals(seed=2)
    sim = FaultySim(16, "random", drop_prob=0.2, drop="message", seed=3)
    st = sim.run((x,), 80)
    est = np.asarray(st.estimate()[0])
    lo, hi = np.asarray(x).min(0), np.asarray(x).max(0)
    assert np.all(est >= lo - 1e-4) and np.all(est <= hi + 1e-4)
    # mass strictly lost
    assert float(jnp.sum(st.weight)) < 16.0


def test_dead_nodes_freeze_but_survivors_agree():
    x = _vals(seed=4)
    sim = FaultySim(16, "random", dead_nodes=(3, 7), seed=5)
    st = sim.run((x,), 150)
    est = np.asarray(st.estimate()[0])
    # dead nodes keep their initial value
    assert np.allclose(est[3], np.asarray(x)[3], atol=1e-5)
    assert np.allclose(est[7], np.asarray(x)[7], atol=1e-5)
    # survivors reach consensus among themselves
    alive = [i for i in range(16) if i not in (3, 7)]
    spread = est[alive].max(0) - est[alive].min(0)
    assert float(spread.max()) < 1e-2


def test_zero_drop_matches_clean_pushsum():
    from repro.core.push_sum import PushSumSim
    x = _vals(seed=6)
    a = FaultySim(8, "random", drop_prob=0.0, seed=7).run((x[:8],), 40)
    b = PushSumSim(8, "random", seed=7).run((x[:8],), 40)
    assert np.allclose(np.asarray(a.estimate()[0]), np.asarray(b.estimate()[0]), atol=1e-5)
